"""End-to-end driver (paper kind = query serving): a resident recursive-
query service answering batched shortest-path requests.

Demonstrates the production serving path: graph loaded & partitioned once,
engines compiled once per policy and reused, per-batch policy selection by
the paper's robustness rule, mixed lengths/paths workloads, and latency
percentiles.

    PYTHONPATH=src python examples/serve_queries.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main([
        "--dataset", "ldbc",
        "--scale", "0.4",
        "--batches", "12",
        "--sources-per-batch", "8",
    ]))
