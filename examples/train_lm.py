"""Fault-tolerant LM training end-to-end (reduced-scale on CPU).

Drives launch/train.py: MiniCPM-family smoke config, a few hundred steps,
checkpoint-every-50 with the async atomic writer, then SIMULATES A CRASH
and restarts from the latest checkpoint — the thousand-node-pod restart
path exercised end-to-end.

    PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import sys
import tempfile

from repro.launch.train import main

ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_")
try:
    # phase 1: train to step 120 (checkpoints at 50, 100)
    rc = main([
        "--arch", "minicpm-2b", "--steps", "120", "--batch", "8",
        "--seq", "64", "--ckpt-dir", ckpt_dir, "--save-every", "50",
    ])
    assert rc == 0
    print("\n--- simulated crash: restarting from latest checkpoint ---\n")
    # phase 2: a fresh process would do exactly this — resume and finish
    rc = main([
        "--arch", "minicpm-2b", "--steps", "200", "--batch", "8",
        "--seq", "64", "--ckpt-dir", ckpt_dir, "--save-every", "50",
    ])
    assert rc == 0
    print("train_lm (with crash-restart) OK")
finally:
    shutil.rmtree(ckpt_dir, ignore_errors=True)
sys.exit(0)
