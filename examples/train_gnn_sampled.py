"""Minibatch GNN training with the IFE-driven neighbor sampler.

The paper-technique integration point for the GNN archs (DESIGN.md §4):
multi-hop fanout sampling IS bounded frontier expansion — each hop extends
the sampled frontier through the same ELL adjacency the query engine scans.
Trains PNA on sampled subgraphs of the LDBC proxy to predict a node-id
derived label (learnable rule).

    PYTHONPATH=src python examples/train_gnn_sampled.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import GraphSeedStream
from repro.graph.csr import ell_from_csr
from repro.graph.generators import ldbc_proxy
from repro.graph.sampler import sample_subgraph
from repro.models.gnn import pna as pna_m
from repro.models.gnn.pna import PNAConfig
from repro.nn.module import split_boxed
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

N_CLASSES = 8
FANOUTS = (10, 5)

csr = ldbc_proxy(scale=0.3)
g = ell_from_csr(csr, max_deg=64)
print(f"graph: {csr.n_nodes} nodes, {csr.n_edges} edges")

cfg = PNAConfig(n_layers=2, d_hidden=32, d_feat=16, n_out=N_CLASSES)
params, _ = split_boxed(pna_m.init(jax.random.PRNGKey(0), cfg))
ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
opt = adamw_init(params, ocfg)
stream = GraphSeedStream(
    n_nodes=csr.n_nodes, batch_nodes=64, n_classes=N_CLASSES
)


def featurize(node_ids):
    """Node features derived from the id (so the label rule is learnable)."""
    bits = (node_ids[:, None] >> jnp.arange(16)) & 1
    return bits.astype(jnp.float32)


def loss_fn(params, sub_nodes, edge_src, edge_dst, labels, n_seeds):
    batch = {
        "edge_src": edge_src,
        "edge_dst": edge_dst,
        "node_feat": featurize(sub_nodes),
    }
    logits = pna_m.apply(params, cfg, batch)["node_out"][:n_seeds]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


@jax.jit
def train_step(params, opt, sub_nodes, edge_src, edge_dst, labels):
    loss, grads = jax.value_and_grad(loss_fn)(
        params, sub_nodes, edge_src, edge_dst, labels, 64
    )
    params, opt, _ = adamw_update(grads, opt, params, ocfg)
    return params, opt, loss


losses = []
rng = jax.random.PRNGKey(1)
for step in range(60):
    b = stream.batch(step)
    rng, sk = jax.random.split(rng)
    # IFE-style bounded frontier expansion from the seed nodes
    sub = sample_subgraph(g, jnp.asarray(b["seeds"]), FANOUTS, sk)
    params, opt, loss = train_step(
        params, opt, sub.nodes, sub.edge_src, sub.edge_dst,
        jnp.asarray(b["labels"]),
    )
    losses.append(float(loss))
    if step % 10 == 0:
        print(f"step {step:3d}  sampled {sub.nodes.shape[0]} nodes  "
              f"loss {losses[-1]:.4f}")

print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert losses[-1] < losses[0], "sampled GNN training must descend"
print("train_gnn_sampled OK")
