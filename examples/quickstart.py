"""Quickstart: run a recursive shortest-path query through the public API.

Mirrors the paper's motivating Cypher query
    MATCH p = (a)-[r* SHORTEST]->(b) WHERE a.id IN [...] RETURN len(p) / p
executed by the IFE engine under the recommended morsel dispatching policy.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (
    POLICIES,
    histogram_lengths,
    recommend_policy,
    reconstruct_paths,
    run_recursive_query,
    validate_parents,
)
from repro.graph.generators import ldbc_proxy, pick_sources

# 1. a property-graph adjacency (LDBC social-network proxy)
csr = ldbc_proxy(scale=0.3)
print(f"graph: {csr.n_nodes} nodes, {csr.n_edges} edges, "
      f"avg degree {csr.avg_degree:.0f}")

# 2. the query's source nodes (WHERE a.id IN [...])
sources = pick_sources(csr, 8, seed=42)
print("sources:", sources.tolist())

# 3. pick a policy the way the paper recommends (§5: nTkS is the robust
#    hybrid; nTkMS once >=64 sources saturate a lane morsel)
mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
policy_name = recommend_policy(
    len(sources), mesh.size, csr.avg_degree, returns_paths=True,
    n_nodes=csr.n_nodes,
)
print("recommended policy:", policy_name)

# 4. RETURN len(p): shortest-path lengths from every source
res = run_recursive_query(
    mesh, csr, sources, POLICIES[policy_name](), "sp_lengths"
)
lengths = np.asarray(res.state.levels)[: len(sources), : csr.n_nodes]
hist = np.asarray(histogram_lengths(res.state.levels))
reached = (lengths >= 0).sum(axis=1)
print("reached per source:", reached.tolist())
print("path-length histogram (first 8 levels):", hist[:8].tolist())

# 5. RETURN p: actual paths via the parents structure (paper Listing 4)
res_p = run_recursive_query(
    mesh, csr, sources, POLICIES[policy_name](), "sp_parents"
)
ok = validate_parents(
    res_p.state.levels[0, : csr.n_nodes],
    res_p.state.parents[0, : csr.n_nodes],
    jax.numpy.asarray(sources[:1]),
)
assert bool(ok), "parent pointers must form valid shortest-path trees"
dests = np.where(np.asarray(res_p.state.levels[0, : csr.n_nodes]) == 3)[0][:3]
paths = np.asarray(
    reconstruct_paths(
        res_p.state.parents[0, : csr.n_nodes],
        dests.astype(np.int32),
        max_len=8,
    )
)
for d, p in zip(dests, paths):
    hops = [int(x) for x in p if x >= 0]
    print(f"shortest path to {d}: {' -> '.join(map(str, reversed(hops)))}")
print("quickstart OK")
