#!/usr/bin/env bash
# Tier-1 CI runner with a wall-clock budget and a fast/full marker split.
#
#   scripts/ci.sh               # fast lane: -m "not slow" (skips subprocess /
#                               # multi-device / train-driver / heavy-tail
#                               # sharded tests; FAILS if it exceeds its own
#                               # wall budget so the growing parity corpus
#                               # stays cheap)
#   scripts/ci.sh --full        # the whole tier-1 suite
#   scripts/ci.sh --bench-smoke # perf-trajectory lane: run the direction-opt
#                               # benchmark on tiny ER + power-law graphs,
#                               # validate the emitted BENCH_direction_opt.json
#                               # schema v2 (per-bucket binned-slab fields),
#                               # the >=2x large-frontier scan reduction AND
#                               # the <=1.1x binned-pull scan-overhead floor;
#                               # then run the hybrid-adaptive benchmark in
#                               # --smoke mode and validate the emitted
#                               # BENCH_hybrid_adaptive.json schema plus the
#                               # ganged-vs-serial phase-2 iteration-slot
#                               # floor (gang slots = max survivor trips <=
#                               # serial slots = sum, with >=2 survivors
#                               # actually ganged); then run the online-adapt
#                               # drift benchmark in --smoke mode and validate
#                               # BENCH_online_adapt.json (schema + the
#                               # mispredict-rate floor: the per-bucket budget
#                               # learner strictly below the static global-p90
#                               # baseline, and in-flight threshold refits
#                               # bit-equal to the offline fit of the same
#                               # accumulated trace); finally run the serving
#                               # SLO benchmark in --smoke mode and validate
#                               # BENCH_serving_slo.json (schema + the serving
#                               # floors: overlap occupancy > 0, async warm
#                               # p99 <= synchronous-flush p99 on the same
#                               # open-loop stream, results bit-identical,
#                               # zero deadline misses at low load)
#
# CI_BUDGET_SECONDS caps any lane via timeout (default 1800); a hung XLA
# compile or subprocess fails the lane instead of wedging the pipeline.
# FAST_LANE_BUDGET_SECONDS (default 900) is the fast lane's pass/fail wall
# gate: finishing late is a FAILURE even when every test passed — new tests
# that belong to the fast lane must stay cheap or be marked `slow`.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BUDGET="${CI_BUDGET_SECONDS:-1800}"

if [[ "${1:-}" == "--full" ]]; then
  exec timeout --signal=INT "$BUDGET" python -m pytest -x -q
elif [[ "${1:-}" == "--bench-smoke" ]]; then
  OUT="${BENCH_OUT:-/tmp/BENCH_direction_opt.smoke.json}"
  # the benchmark validates its own schema before writing and exits nonzero
  # if the dense-ER reduction or the binned-pull overhead floor is missed
  timeout --signal=INT "$BUDGET" \
    python benchmarks/direction_opt.py --smoke --out "$OUT"
  python - "$OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from direction_opt import validate
doc = json.loads(open(sys.argv[1]).read())
validate(doc)  # schema v2: per-bucket slab fields + powerlaw floor
pl = doc["summary"]["powerlaw_binned"]
assert pl["passes_overhead_floor"], pl
print(f"bench-smoke OK: {sys.argv[1]} schema valid, "
      f"dense-ER reduction "
      f"{doc['summary']['dense_er']['scan_reduction_dopt_vs_push']}x, "
      f"binned pull {pl['binned_overhead_vs_ideal']}x ideal / "
      f"{pl['scan_reduction_binned_vs_ell_pull']}x fewer slots than padded "
      f"pull")
EOF
  HOUT="${BENCH_HYBRID_OUT:-/tmp/BENCH_hybrid_adaptive.smoke.json}"
  # the benchmark validates before writing; re-validate the artifact here
  # so a stale/hand-edited file also fails the lane
  timeout --signal=INT "$BUDGET" \
    python benchmarks/hybrid_adaptive.py --smoke --out "$HOUT"
  python - "$HOUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from hybrid_adaptive import validate
doc = json.loads(open(sys.argv[1]).read())
validate(doc)  # schema + the ganged-vs-serial phase-2 iteration-slot floor
g = doc["gang"]
print(f"bench-smoke OK: {sys.argv[1]} schema valid, "
      f"{g['survivors']} survivors ganged (occupancy {g['occupancy']:.2f}), "
      f"phase-2 slots {g['phase2_slots_ganged']} ganged vs "
      f"{g['phase2_slots_serial']} serial, wall ratio serial/ganged "
      f"{g['phase2_wall_ratio_serial_over_ganged']:.2f}x")
EOF
  AOUT="${BENCH_ONLINE_OUT:-/tmp/BENCH_online_adapt.smoke.json}"
  # the benchmark validates before writing; re-validate the artifact here
  # so a stale/hand-edited file also fails the lane
  timeout --signal=INT "$BUDGET" \
    python benchmarks/online_adapt.py --smoke --out "$AOUT"
  python - "$AOUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from online_adapt import validate
doc = json.loads(open(sys.argv[1]).read())
validate(doc)  # schema + mispredict-rate floor + threshold-refit parity
s = doc["summary"]
print(f"bench-smoke OK: {sys.argv[1]} schema valid, mispredict rate "
      f"{s['mispredict_rate_online']:.3f} online vs "
      f"{s['mispredict_rate_baseline']:.3f} static global-p90, "
      f"threshold refit parity {s['passes_threshold_parity']}, "
      f"results bit-identical {s['results_bit_identical']}")
EOF
  SOUT="${BENCH_SERVING_OUT:-/tmp/BENCH_serving_slo.smoke.json}"
  # the benchmark validates before writing; re-validate the artifact here
  # so a stale/hand-edited file also fails the lane
  timeout --signal=INT "$BUDGET" \
    python benchmarks/serving_slo.py --smoke --out "$SOUT"
  python - "$SOUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from serving_slo import validate
doc = json.loads(open(sys.argv[1]).read())
validate(doc)  # schema + occupancy/p99/bit-identity/zero-miss floors
s = doc["summary"]
print(f"bench-smoke OK: {sys.argv[1]} schema valid, sustained warm p99 "
      f"{s['async_p99_ms']:.1f} ms async vs {s['sync_p99_ms']:.1f} ms "
      f"sync-flush ({s['p99_speedup']:.2f}x), occupancy "
      f"{doc['async']['overlap_occupancy']:.2f}, bit-identical "
      f"{s['results_bit_identical']}, zero low-load misses "
      f"{s['zero_misses_at_low_load']}")
EOF
else
  FAST_BUDGET="${FAST_LANE_BUDGET_SECONDS:-900}"
  START=$(date +%s)
  timeout --signal=INT "$BUDGET" python -m pytest -x -q -m "not slow"
  ELAPSED=$(( $(date +%s) - START ))
  if (( ELAPSED > FAST_BUDGET )); then
    echo "FAIL: fast lane took ${ELAPSED}s > ${FAST_BUDGET}s budget" \
         "(mark expensive new tests 'slow' or raise" \
         "FAST_LANE_BUDGET_SECONDS deliberately)" >&2
    exit 1
  fi
  echo "fast lane OK: ${ELAPSED}s (budget ${FAST_BUDGET}s)"
fi
