#!/usr/bin/env bash
# Tier-1 CI runner with a wall-clock budget and a fast/full marker split.
#
#   scripts/ci.sh               # fast lane: -m "not slow" (skips subprocess /
#                               # multi-device / train-driver / heavy-tail
#                               # sharded tests; FAILS if it exceeds its own
#                               # wall budget so the growing parity corpus
#                               # stays cheap)
#   scripts/ci.sh --full        # the whole tier-1 suite
#   scripts/ci.sh --bench-smoke # perf-trajectory lane: run the direction-opt
#                               # benchmark on tiny ER + power-law graphs,
#                               # validate the emitted BENCH_direction_opt.json
#                               # schema v3 (per-bucket binned-slab fields +
#                               # per-backend measured-wall joins on the push
#                               # records), the >=2x large-frontier scan
#                               # reduction, the <=1.1x binned-pull
#                               # scan-overhead floor AND the fused-kernel
#                               # wall floor (fused Pallas binned pull <=
#                               # jnp binned pull x the documented interpret
#                               # tolerance; 1.0x on real TPU lowering);
#                               # then run the hybrid-adaptive benchmark in
#                               # --smoke mode and validate the emitted
#                               # BENCH_hybrid_adaptive.json schema plus the
#                               # ganged-vs-serial phase-2 iteration-slot
#                               # floor (gang slots = max survivor trips <=
#                               # serial slots = sum, with >=2 survivors
#                               # actually ganged); then run the online-adapt
#                               # drift benchmark in --smoke mode and validate
#                               # BENCH_online_adapt.json (schema + the
#                               # mispredict-rate floor: the per-bucket budget
#                               # learner strictly below the static global-p90
#                               # baseline, and in-flight threshold refits
#                               # bit-equal to the offline fit of the same
#                               # accumulated trace); finally run the serving
#                               # SLO benchmark in --smoke mode and validate
#                               # BENCH_serving_slo.json (schema + the serving
#                               # floors: overlap occupancy > 0, async warm
#                               # p99 <= synchronous-flush p99 on the same
#                               # open-loop stream, results bit-identical,
#                               # zero deadline misses at low load); finally
#                               # run the mutable-ops benchmark in --smoke
#                               # mode and validate BENCH_mutable_ops.json
#                               # (schema + the mutability floors: same-shape
#                               # delta folds cheaper in total wall than the
#                               # from-scratch operand rebuild of every live
#                               # bundle, compile_events flat across the
#                               # delta chain, every post-delta query
#                               # bit-identical to the BFS oracle, and the
#                               # reshape probe invalidating stale engines);
#                               # finally run the query-scenarios benchmark
#                               # in --smoke mode and validate
#                               # BENCH_query_scenarios.json (schema + the
#                               # scenario floors: top-k paths / PPR /
#                               # pattern counts all oracle-identical
#                               # through the live serving stack with no
#                               # lane-packed engine, and the weighted
#                               # weight-only churn chain folding for less
#                               # total wall than the wholesale re-place
#                               # baseline, bit-identical to a rebuild);
#                               # finally run the scale-out benchmark in
#                               # --smoke mode and validate
#                               # BENCH_scale_out.json (schema + the
#                               # scale-out floors: the streamed per-shard
#                               # operand build's traced host peak strictly
#                               # below the wholesale build's, every
#                               # device-assembled operand leaf bitwise-
#                               # identical across the two builds, and the
#                               # degree-chunked hub-slab gathers exact
#                               # against the unchunked oracle)
#
# CI_BUDGET_SECONDS caps any lane via timeout (default 1800); a hung XLA
# compile or subprocess fails the lane instead of wedging the pipeline.
# FAST_LANE_BUDGET_SECONDS (default 900) is the fast lane's pass/fail wall
# gate: finishing late is a FAILURE even when every test passed — new tests
# that belong to the fast lane must stay cheap or be marked `slow`.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BUDGET="${CI_BUDGET_SECONDS:-1800}"

# Each benchmark validates its own schema before writing and exits nonzero
# on a missed floor; re-validate the artifact here so a stale/hand-edited
# file also fails the lane. Modules exposing a versioned `load` (e.g.
# direction_opt's v2/v3 loader) get it used instead of raw json so schema
# drift is caught at read time; every module supplies `validate(doc)` and
# `smoke_line(doc)` (the one-line summary printed below).
validate_bench() {  # validate_bench <benchmarks-module> <artifact-path>
  python - "$1" "$2" <<'EOF'
import importlib, json, sys
sys.path.insert(0, "benchmarks")
mod = importlib.import_module(sys.argv[1])
path = sys.argv[2]
if hasattr(mod, "load"):
    doc = mod.load(path)
else:
    doc = json.loads(open(path).read())
mod.validate(doc)
print(f"bench-smoke OK: {path} schema valid, {mod.smoke_line(doc)}")
EOF
}

if [[ "${1:-}" == "--full" ]]; then
  exec timeout --signal=INT "$BUDGET" python -m pytest -x -q
elif [[ "${1:-}" == "--bench-smoke" ]]; then
  OUT="${BENCH_OUT:-/tmp/BENCH_direction_opt.smoke.json}"
  timeout --signal=INT "$BUDGET" \
    python benchmarks/direction_opt.py --smoke --out "$OUT"
  validate_bench direction_opt "$OUT"
  HOUT="${BENCH_HYBRID_OUT:-/tmp/BENCH_hybrid_adaptive.smoke.json}"
  timeout --signal=INT "$BUDGET" \
    python benchmarks/hybrid_adaptive.py --smoke --out "$HOUT"
  validate_bench hybrid_adaptive "$HOUT"
  AOUT="${BENCH_ONLINE_OUT:-/tmp/BENCH_online_adapt.smoke.json}"
  timeout --signal=INT "$BUDGET" \
    python benchmarks/online_adapt.py --smoke --out "$AOUT"
  validate_bench online_adapt "$AOUT"
  SOUT="${BENCH_SERVING_OUT:-/tmp/BENCH_serving_slo.smoke.json}"
  timeout --signal=INT "$BUDGET" \
    python benchmarks/serving_slo.py --smoke --out "$SOUT"
  validate_bench serving_slo "$SOUT"
  MOUT="${BENCH_MUTABLE_OUT:-/tmp/BENCH_mutable_ops.smoke.json}"
  timeout --signal=INT "$BUDGET" \
    python benchmarks/mutable_ops.py --smoke --out "$MOUT"
  validate_bench mutable_ops "$MOUT"
  QOUT="${BENCH_QUERY_OUT:-/tmp/BENCH_query_scenarios.smoke.json}"
  timeout --signal=INT "$BUDGET" \
    python benchmarks/query_scenarios.py --smoke --out "$QOUT"
  validate_bench query_scenarios "$QOUT"
  XOUT="${BENCH_SCALE_OUT:-/tmp/BENCH_scale_out.smoke.json}"
  timeout --signal=INT "$BUDGET" \
    python benchmarks/scale_out.py --smoke --out "$XOUT"
  validate_bench scale_out "$XOUT"
else
  FAST_BUDGET="${FAST_LANE_BUDGET_SECONDS:-900}"
  START=$(date +%s)
  timeout --signal=INT "$BUDGET" python -m pytest -x -q -m "not slow"
  ELAPSED=$(( $(date +%s) - START ))
  if (( ELAPSED > FAST_BUDGET )); then
    echo "FAIL: fast lane took ${ELAPSED}s > ${FAST_BUDGET}s budget" \
         "(mark expensive new tests 'slow' or raise" \
         "FAST_LANE_BUDGET_SECONDS deliberately)" >&2
    exit 1
  fi
  echo "fast lane OK: ${ELAPSED}s (budget ${FAST_BUDGET}s)"
fi
