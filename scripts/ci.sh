#!/usr/bin/env bash
# Tier-1 CI runner with a wall-clock budget and a fast/full marker split.
#
#   scripts/ci.sh               # fast lane: -m "not slow" (skips subprocess /
#                               # multi-device / train-driver tests; ~3 min on
#                               # the 1-core reference box)
#   scripts/ci.sh --full        # the whole tier-1 suite (~6 min)
#   scripts/ci.sh --bench-smoke # perf-trajectory lane: run the direction-opt
#                               # benchmark on a tiny graph, validate the
#                               # emitted BENCH_direction_opt.json schema and
#                               # the >=2x large-frontier scan reduction
#
# CI_BUDGET_SECONDS caps the run (default 1800); a hung XLA compile or
# subprocess fails the lane instead of wedging the pipeline.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BUDGET="${CI_BUDGET_SECONDS:-1800}"

if [[ "${1:-}" == "--full" ]]; then
  exec timeout --signal=INT "$BUDGET" python -m pytest -x -q
elif [[ "${1:-}" == "--bench-smoke" ]]; then
  OUT="${BENCH_OUT:-/tmp/BENCH_direction_opt.smoke.json}"
  # the benchmark validates its own schema before writing and exits nonzero
  # if the dense-ER reduction target is missed
  timeout --signal=INT "$BUDGET" \
    python benchmarks/direction_opt.py --smoke --out "$OUT"
  python - "$OUT" <<'EOF'
import json, sys
sys.path.insert(0, "benchmarks")
from direction_opt import validate
doc = json.loads(open(sys.argv[1]).read())
validate(doc)
print(f"bench-smoke OK: {sys.argv[1]} schema valid, "
      f"reduction {doc['summary']['dense_er']['scan_reduction_dopt_vs_push']}x")
EOF
else
  exec timeout --signal=INT "$BUDGET" python -m pytest -x -q -m "not slow"
fi
