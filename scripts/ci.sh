#!/usr/bin/env bash
# Tier-1 CI runner with a wall-clock budget and a fast/full marker split.
#
#   scripts/ci.sh          # fast lane: -m "not slow" (skips subprocess /
#                          # multi-device / train-driver tests; ~3 min on
#                          # the 1-core reference box)
#   scripts/ci.sh --full   # the whole tier-1 suite (~6 min)
#
# CI_BUDGET_SECONDS caps the run (default 1800); a hung XLA compile or
# subprocess fails the lane instead of wedging the pipeline.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BUDGET="${CI_BUDGET_SECONDS:-1800}"

if [[ "${1:-}" == "--full" ]]; then
  exec timeout --signal=INT "$BUDGET" python -m pytest -x -q
else
  exec timeout --signal=INT "$BUDGET" python -m pytest -x -q -m "not slow"
fi
