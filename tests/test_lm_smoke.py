"""Per-arch LM smoke tests: reduced configs, fwd + train step + decode parity.

Decode parity (cache-based decode == full forward) is the strongest
correctness check for attention variants (GQA, sliding window, chunked,
softcaps, NoPE) and the scan-over-layers serving path.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.nn.module import split_boxed, count_params
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

from repro.configs import base as cfgbase
from repro.configs.deepseek_coder_33b import smoke_config as smoke_deepseek
from repro.configs.gemma2_2b import smoke_config as smoke_gemma2
from repro.configs.minicpm_2b import smoke_config as smoke_minicpm
from repro.configs.olmoe_1b_7b import smoke_config as smoke_olmoe
from repro.configs.llama4_maverick import smoke_config as smoke_llama4

SMOKES = {
    "deepseek-coder-33b": smoke_deepseek,
    "gemma2-2b": smoke_gemma2,
    "minicpm-2b": smoke_minicpm,
    "olmoe-1b-7b": smoke_olmoe,
    "llama4-maverick-400b-a17b": smoke_llama4,
}


def _setup(cfg, batch=2, seq=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    boxed = tfm.init(rng, cfg)
    params, _ = split_boxed(boxed)
    tokens = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (batch, seq), 0, cfg.vocab
    )
    return params, tokens


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_forward_shapes_and_finite(arch):
    cfg = SMOKES[arch]()
    params, tokens = _setup(cfg)
    logits, aux = tfm.forward(params, cfg, tokens)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab]).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_train_step(arch):
    cfg = SMOKES[arch]()
    params, tokens = _setup(cfg)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(tfm.loss_fn)(params, cfg, batch)
        params, opt, gnorm = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss, gnorm

    p1, opt1, loss1, g1 = step(params, opt, batch)
    p2, _, loss2, _ = step(p1, opt1, batch)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    assert float(loss2) < float(loss1)  # same-batch overfit must descend
    assert np.isfinite(float(g1)) and float(g1) > 0
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, p1
    )
    assert max(jax.tree.leaves(changed)) > 0


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_decode_matches_forward(arch):
    cfg = SMOKES[arch]()
    params, tokens = _setup(cfg, batch=2, seq=16)
    logits_full, _ = tfm.forward(params, cfg, tokens)

    # prefill on the first 8 tokens, then decode 8..15 one at a time
    last_logits, caches = tfm.prefill(params, cfg, tokens[:, :8], max_seq=16)
    np.testing.assert_allclose(
        np.asarray(last_logits[..., : cfg.vocab]),
        np.asarray(logits_full[:, 7, : cfg.vocab]),
        rtol=2e-4,
        atol=2e-4,
    )
    for p in range(8, 16):
        step_logits, caches = tfm.decode(
            params, cfg, caches, tokens[:, p : p + 1], jnp.int32(p)
        )
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0, : cfg.vocab]),
            np.asarray(logits_full[:, p, : cfg.vocab]),
            rtol=3e-4,
            atol=3e-4,
            err_msg=f"{arch} decode pos {p}",
        )


def test_ring_buffer_window_decode():
    """Decode far beyond the sliding window: ring cache must still match the
    windowed full forward (gemma2-style local attention)."""
    cfg = smoke_gemma2()
    assert cfg.window == 32
    params, tokens = _setup(cfg, batch=1, seq=48)
    logits_full, _ = tfm.forward(params, cfg, tokens)
    _, caches = tfm.prefill(params, cfg, tokens[:, :40], max_seq=48)
    for p in range(40, 48):
        step_logits, caches = tfm.decode(
            params, cfg, caches, tokens[:, p : p + 1], jnp.int32(p)
        )
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0, : cfg.vocab]),
            np.asarray(logits_full[:, p, : cfg.vocab]),
            rtol=5e-4,
            atol=5e-4,
            err_msg=f"window decode pos {p}",
        )


def test_vocab_padding_masked():
    cfg = smoke_minicpm()  # vocab 515 -> padded 768
    assert cfg.vocab_padded == 768
    params, tokens = _setup(cfg)
    logits, _ = tfm.forward(params, cfg, tokens)
    assert bool((logits[..., cfg.vocab :] < -1e29).all())


def test_param_counts_match_analytic():
    for arch, smoke in SMOKES.items():
        cfg = smoke()
        params, _ = _setup(cfg)
        analytic = cfg.total_params()
        actual = count_params(params)
        # analytic ignores norm scales & vocab padding; must be within 20%
        assert abs(actual - analytic) / analytic < 0.2, (
            arch, actual, analytic
        )


def test_registry_cells():
    cells, skips = cfgbase.all_cells()
    assert len(cells) + len(skips) == 44  # 40 assigned + 4 paper-engine cells
    skip_archs = {a for a, _, _ in skips}
    assert skip_archs == {"deepseek-coder-33b", "minicpm-2b", "olmoe-1b-7b"}
