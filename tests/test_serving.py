"""Layered serving-core tests (ISSUE 6).

Covers the admission layer's edge cases (zero-source submit, duplicate
qid, quota-exhausted tenant, deadline expired at admission,
flush-during-drain) with every result asserted bit-identical to a
synchronous one-batch-at-a-time run of the same stream; the LanePacker
repack-on-arrival contract; the EngineCache public mapping surface;
deadline-aware pack eviction + hopeless-query shedding; the overlap
pipeline's occupancy/warm-cold telemetry; and the ISSUE-6 determinism
lock — the async overlapped loop replays a seeded stream bit-identically
(results, learned budgets, refit thresholds, mispredict counters) to the
strictly serial loop and the synchronous AdaptiveScheduler façade.
"""
import functools

import numpy as np
import pytest

from oracle import bfs_levels

from repro.core.msbfs import LanePacker
from repro.graph.csr import csr_from_edges
from repro.graph.generators import powerlaw
from repro.launch.mesh import make_mesh
from repro.runtime.admission import (
    AdmissionQueue,
    SHED_EXPIRED,
    SHED_HOPELESS,
    SHED_QUOTA,
)
from repro.runtime.scheduler import AdaptiveScheduler
from repro.runtime.service import ServingLoop


@functools.lru_cache(maxsize=None)
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


@functools.lru_cache(maxsize=None)
def serve_graph(n_main: int = 160, paths: tuple = (40,), seed: int = 0):
    """Small-diameter powerlaw main component plus long-path straggler
    components (same shape as test_scheduler.skew_graph): path-head
    sources are deep/low-degree, main-component sources shallow/denser —
    distinct budget-model buckets with very different learned depths,
    which is what the deadline-eviction math keys on."""
    main = powerlaw(n_main, 5.0, seed=seed)
    src_m, dst_m = main.edge_list()
    srcs, dsts, base, heads = [src_m], [dst_m], n_main, []
    for length in paths:
        p = np.arange(length - 1, dtype=np.int64) + base
        srcs += [p, p + 1]
        dsts += [p + 1, p]
        heads.append(base)
        base += length
    csr = csr_from_edges(base, np.concatenate(srcs), np.concatenate(dsts))
    return csr, tuple(heads)


class ManualClock:
    """Injectable clock for deterministic admission decisions."""

    def __init__(self, t: float = 1000.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt_s: float) -> None:
        self.t += dt_s


def _loop(csr, **kw):
    kw.setdefault("backend", "dopt")
    kw.setdefault("family", "powerlaw")
    kw.setdefault("max_iters", 64)
    return ServingLoop(mesh11(), csr, **kw)


def _facade(csr, **kw):
    kw.setdefault("backend", "dopt")
    kw.setdefault("family", "powerlaw")
    kw.setdefault("max_iters", 64)
    return AdaptiveScheduler(mesh11(), csr, **kw)


def _sync_reference(csr, rounds, **kw):
    """The satellite's reference: the same stream served synchronously,
    one flush per submission round, through the AdaptiveScheduler façade."""
    sched = _facade(csr, **kw)
    out = {}
    for round_ in rounds:
        for qid, s in round_:
            sched.submit(s, qid=qid)
        out.update(sched.flush())
    return sched, out


# ---------------------------------------------------------------------------
# LanePacker: repack-on-arrival
# ---------------------------------------------------------------------------

def test_lane_packer_pack_evict_repack():
    pk = LanePacker(lanes=64)
    a = np.arange(5, dtype=np.int32)
    b = np.arange(10, 13, dtype=np.int32)
    c = np.arange(20, 24, dtype=np.int32)
    pk.add("qa", a)
    pk.add("qb", b)
    pk.add("qc", c)
    assert len(pk) == 3 and pk.n_sources == 12 and pk.n_morsels == 1
    assert "qb" in pk and pk.qids == ["qa", "qb", "qc"]
    flat, spans = pk.pack()
    np.testing.assert_array_equal(flat, np.concatenate([a, b, c]))
    assert spans == {"qa": (0, 5), "qb": (5, 8), "qc": (8, 12)}

    # eviction is a pure deletion: survivors keep arrival order, so their
    # sources (and therefore result rows) are byte-identical post-repack
    got = pk.evict("qb")
    np.testing.assert_array_equal(got, b)
    flat2, spans2 = pk.pack()
    np.testing.assert_array_equal(flat2, np.concatenate([a, c]))
    assert spans2 == {"qa": (0, 5), "qc": (5, 9)}
    assert pk.evict("missing") is None

    with pytest.raises(ValueError):
        pk.add("qa", a)  # duplicate qid in one pack


# ---------------------------------------------------------------------------
# EngineCache public mapping surface
# ---------------------------------------------------------------------------

def test_engine_cache_public_api():
    csr, _ = serve_graph()
    sched = _facade(csr, online_adapt=False)
    sched.query(np.arange(4, dtype=np.int32))
    cache = sched.cache
    assert len(cache) > 0
    keys = list(cache.keys())
    assert list(iter(cache)) == keys
    assert all(k in cache for k in keys)
    assert [k for k, _ in cache.items()] == keys
    assert all(cache.get(k) is not None for k in keys)
    assert cache.get("no-such-key", "fallback") == "fallback"
    assert sum(cache.count_by_kind(k.kind) for k in set(keys)) >= len(keys)
    # the public surface is a view, not a copy: a fresh compile shows up
    n = len(cache)
    sched.query(np.arange(4, dtype=np.int32), returns_paths=True)
    assert len(cache) > n and len(list(cache.keys())) == len(cache)


def test_pow2_morsel_padding_bit_identical_and_shape_tracked():
    """The serving dispatcher's pow2 morsel padding: a 3-morsel batch runs
    as 4 morsels (pad morsels inert), results bit-identical to the exact-
    shape dispatcher; first-seen morsel shapes are counted apart from
    build misses so serving's warm/cold split can see XLA retraces."""
    from repro.runtime.dispatch import QueryDispatcher

    csr, _ = serve_graph()
    # main-component sources only: everything converges inside the pinned
    # phase-1 budget, so the only engine in play is phase 1 and the miss
    # ledger below isn't confounded by resume/gang compiles
    srcs = np.asarray(
        np.random.default_rng(11).integers(0, 160, 160), np.int32
    )  # 160 sources / 64 lanes = 3 morsels -> pow2-padded to 4
    # phase1_iters pinned: the global-p90 fallback budget must not drift
    # between calls below (a budget change is a legitimate build miss,
    # but this test isolates the shape ledger from it)
    exact = QueryDispatcher(
        mesh11(), csr, backend="dopt", family="powerlaw",
        online_adapt=False, phase1_iters=16,
    )
    padded = QueryDispatcher(
        mesh11(), csr, backend="dopt", family="powerlaw",
        online_adapt=False, phase1_iters=16, pad_pow2_morsels=True,
    )
    out_e = exact.query(srcs, policy="ntkms")
    out_p = padded.query(srcs, policy="ntkms")
    lv_e = np.asarray(out_e.result.state.levels)
    lv_p = np.asarray(out_p.result.state.levels)
    assert lv_e.shape[0] == 3 and lv_p.shape[0] == 4
    np.testing.assert_array_equal(lv_e, lv_p[:3])
    # pad morsel: inert, zero iterations
    assert np.asarray(out_p.result.iterations)[3] == 0
    # shape ledger: first call noted one shape per engine used; replaying
    # the same batch adds none, a new morsel count adds one without a
    # build miss — and compile_events moves while misses does not
    cache = padded.cache
    shapes0, misses0 = cache.shape_misses, cache.misses
    assert shapes0 > 0
    padded.query(srcs, policy="ntkms")
    assert cache.shape_misses == shapes0 and cache.misses == misses0
    padded.query(srcs[:64], policy="ntkms")  # 1 morsel: new phase-1 shape
    assert cache.shape_misses > shapes0
    assert cache.misses == misses0
    assert cache.compile_events == cache.misses + cache.shape_misses


# ---------------------------------------------------------------------------
# Admission edge cases — each bit-identical to the synchronous reference
# ---------------------------------------------------------------------------

def test_zero_source_submit_completes_empty():
    csr, _ = serve_graph()
    s = np.arange(4, dtype=np.int32)
    loop = _loop(csr, overlap=True)
    t_empty = loop.submit(np.zeros(0, np.int32), qid="empty")
    t_real = loop.submit(s, qid="real")
    assert t_empty.admitted and t_empty.done and t_real.admitted
    results = loop.drain()
    assert results["empty"].shape == (0, csr.n_nodes)
    assert results["empty"].dtype == np.int32
    _, ref = _sync_reference(
        csr, [[("empty", np.zeros(0, np.int32)), ("real", s)]]
    )
    for qid in ("empty", "real"):
        np.testing.assert_array_equal(results[qid], ref[qid])
    assert loop.admission.stats.zero_source == 1


def test_duplicate_qid_raises_until_completed():
    csr, _ = serve_graph()
    s = np.arange(4, dtype=np.int32)
    loop = _loop(csr)
    loop.submit(s, qid="dup")
    with pytest.raises(ValueError):
        loop.submit(s + 1, qid="dup")  # still in flight
    loop.drain()
    loop.submit(s + 1, qid="dup")  # completed: the qid is free again
    loop.drain()
    sched = _facade(csr)
    sched.submit(s, qid="dup")
    with pytest.raises(ValueError):
        sched.submit(s, qid="dup")


def test_quota_exhausted_tenant_sheds_not_others():
    csr, _ = serve_graph()
    rng = np.random.default_rng(3)
    qs = [rng.integers(0, 160, 4).astype(np.int32) for _ in range(4)]
    loop = _loop(csr, tenant_quota=2)
    t0 = loop.submit(qs[0], tenant="busy", qid="a")
    t1 = loop.submit(qs[1], tenant="busy", qid="b")
    t2 = loop.submit(qs[2], tenant="busy", qid="c")  # over quota: shed
    t3 = loop.submit(qs[3], tenant="calm", qid="d")  # other tenant: fine
    assert t0.admitted and t1.admitted and t3.admitted
    assert not t2.admitted and t2.shed_reason == SHED_QUOTA
    results = loop.drain()
    assert "c" not in results
    assert loop.stats.tenant("busy").shed == 1
    assert loop.stats.tenant("calm").shed == 0
    assert loop.admission.stats.sheds_by_reason[SHED_QUOTA] == 1
    # quota is released on completion: the tenant can submit again
    assert loop.submit(qs[2], tenant="busy", qid="c2").admitted
    results = loop.drain()
    # admitted queries are served bit-identically to the sync reference
    _, ref = _sync_reference(
        csr,
        [[("a", qs[0]), ("b", qs[1]), ("d", qs[3])], [("c2", qs[2])]],
    )
    for qid in ("a", "b", "d", "c2"):
        np.testing.assert_array_equal(results[qid], ref[qid])


def test_deadline_expired_at_admission_and_at_plan():
    csr, _ = serve_graph()
    clock = ManualClock()
    s = np.arange(4, dtype=np.int32)
    loop = _loop(csr, clock=clock)
    # expired before it was even queued (non-positive SLO)
    t = loop.submit(s, deadline_ms=0.0, qid="late")
    assert not t.admitted and t.shed_reason == SHED_EXPIRED
    # expires while queued: admitted, then shed at plan time
    loop.submit(s, deadline_ms=5.0, qid="stale")
    loop.submit(s + 8, qid="live")
    clock.advance(0.050)  # 50 ms > 5 ms deadline
    results = loop.drain()
    assert "late" not in results and "stale" not in results
    assert "live" in results
    assert loop.admission.stats.sheds_by_reason[SHED_EXPIRED] == 2
    _, ref = _sync_reference(csr, [[("live", s + 8)]])
    np.testing.assert_array_equal(results["live"], ref["live"])


def test_flush_during_drain_serves_followup():
    csr, _ = serve_graph()
    s0 = np.arange(4, dtype=np.int32)
    s1 = np.arange(50, 54, dtype=np.int32)
    state = {"fired": False}

    def on_result(qid, levels):
        if not state["fired"]:  # submit from inside result delivery
            state["fired"] = True
            loop.submit(s1, qid="followup")

    loop = _loop(csr, overlap=True, on_result=on_result)
    loop.submit(s0, qid="first")
    results = loop.drain()
    assert state["fired"]
    assert set(results) >= {"first", "followup"}
    _, ref = _sync_reference(csr, [[("first", s0)], [("followup", s1)]])
    for qid in ("first", "followup"):
        np.testing.assert_array_equal(results[qid], ref[qid])


# ---------------------------------------------------------------------------
# Deadline-aware pack eviction / load shedding
# ---------------------------------------------------------------------------

def test_deadline_eviction_and_hopeless_shed():
    """A tight-deadline shallow query packed next to a deep straggler
    cannot survive the pack's slowest lane: it must be EVICTED to a solo
    batch (and still answer correctly); a query whose deadline even a
    solo batch would blow is shed as hopeless, not executed."""
    csr, heads = serve_graph()
    clock = ManualClock()
    loop = _loop(csr, clock=clock, refit_every=1000)
    rng = np.random.default_rng(5)
    # mid-degree main-component nodes: a degree bucket the straggler head
    # (degree 1) does NOT share, so the learned depths stay distinct
    deg = np.asarray(csr.degrees)[:160]
    mid = np.nonzero((deg >= 4) & (deg < 8))[0].astype(np.int32)
    assert len(mid) >= 8
    # warm the budget model: shallow mid-degree batches + one deep
    # straggler batch, served solo (no deadlines involved yet)
    loop.submit(mid[:8])
    for i in range(2):
        loop.submit(rng.integers(0, 160, 8).astype(np.int32))
    loop.submit(np.asarray([heads[0]], np.int32))
    loop.drain()
    assert loop.dispatcher.depth_hint(np.asarray([heads[0]]), 1) is not None
    # the manual clock froze wall time, so the measured ms-per-iteration
    # EWMA never warmed — pin it (white-box) to make predictions live
    loop._ms_per_iter = 1.0
    deep_depth = loop.dispatcher.depth_hint(np.asarray([heads[0]]), 1)
    shallow = mid[:4]
    shallow_depth = loop.dispatcher.depth_hint(shallow, 1)
    assert shallow_depth < deep_depth  # distinct buckets, distinct depths

    # pool > 64 sources so recommend_policy packs ntkms, with the deep
    # straggler inside: pack slowest-lane estimate = deep_depth ms
    fill = [rng.integers(0, 160, 31).astype(np.int32) for _ in range(2)]
    loop.submit(fill[0], qid="f0")
    loop.submit(fill[1], qid="f1")
    loop.submit(np.asarray([heads[0]], np.int32), qid="deep")
    # slack between solo time and pack time: must be evicted, then served
    mid_ms = (shallow_depth + deep_depth) / 2.0
    loop.submit(shallow, qid="tight", deadline_ms=mid_ms)
    # slack under even the solo estimate: hopeless, shed at plan
    loop.submit(shallow, qid="doomed",
                deadline_ms=max(0.5, shallow_depth / 2.0))
    results = loop.drain()
    assert loop.admission.stats.evictions == 1
    assert loop.admission.stats.sheds_by_reason[SHED_HOPELESS] == 1
    assert "doomed" not in results and "tight" in results
    assert loop.stats.deadline_misses == 0  # frozen clock: nothing late
    # the evicted query's solo answer is still the exact BFS
    ref = np.stack([bfs_levels(csr, int(x)) for x in shallow])
    np.testing.assert_array_equal(results["tight"], ref)
    # pack members unaffected by the eviction repack
    ref_f0 = np.stack([bfs_levels(csr, int(x)) for x in fill[0]])
    np.testing.assert_array_equal(results["f0"], ref_f0)


# ---------------------------------------------------------------------------
# Overlap pipeline telemetry
# ---------------------------------------------------------------------------

def test_overlap_occupancy_and_warm_cold_split():
    csr, _ = serve_graph()
    rng = np.random.default_rng(11)
    loop = _loop(csr, overlap=True)
    for r in range(3):
        for q in range(2):
            loop.submit(rng.integers(0, 160, 4).astype(np.int32),
                        tenant=f"t{q}")
        loop.pump()
    loop.drain()
    st = loop.stats
    assert st.batches >= 6 and st.finalizes == st.batches
    # sub-64-source solo batches pump in pairs: every first-of-pair
    # finalize hides behind the second's phase 1
    assert st.overlapped_finalizes > 0
    assert 0.0 < st.overlap_occupancy <= 1.0
    assert st.cold_batches >= 1  # first batch compiled
    warm = st._all(warm=True)
    assert len(warm) < len(st._all(warm=False))
    assert st.cold_ms > 0.0
    # strictly serial loop never overlaps
    serial = _loop(csr, overlap=False)
    serial.submit(rng.integers(0, 160, 4).astype(np.int32))
    serial.submit(rng.integers(0, 160, 4).astype(np.int32))
    serial.drain()
    assert serial.stats.overlapped_finalizes == 0
    assert serial.stats.overlap_occupancy == 0.0


# ---------------------------------------------------------------------------
# ISSUE-6 determinism lock: async loop ≡ serial loop ≡ synchronous façade
# ---------------------------------------------------------------------------

def _replay_rounds(heads):
    """Seeded multi-round stream mixing shallow sources with straggler
    heads — the PR-5 replay corpus shape, as (qid, sources) rounds."""
    rng = np.random.default_rng(7)
    rounds = []
    for r in range(5):
        round_ = []
        for q in range(2):
            fill = rng.integers(0, 160, 4).astype(np.int32)
            if (r + q) % 2 == 0:
                fill = np.concatenate(
                    [[heads[r % len(heads)]], fill[:3]]
                ).astype(np.int32)
            round_.append((f"r{r}q{q}", fill))
        rounds.append(round_)
    return rounds


@pytest.mark.slow
def test_replay_async_loop_bit_identical_to_sync_facade():
    """The determinism lock: the overlapped async loop, the strictly
    serial loop, and the synchronous AdaptiveScheduler façade must
    produce bit-identical results, learned budgets, accumulated sample
    traces, refit thresholds, and mispredict counters on the same seeded
    admission order — the overlap moves WHEN the host works, never what
    any batch computes or what the learners observe."""
    csr, heads = serve_graph()
    rounds = _replay_rounds(heads)
    kw = dict(online_adapt=True, refit_every=2)

    def run_loop(overlap):
        loop = _loop(csr, overlap=overlap, **kw)
        for round_ in rounds:
            for qid, s in round_:
                loop.submit(s, qid=qid)
            loop.pump()
        loop.drain()
        loop.dispatcher.refit_thresholds()
        return loop.dispatcher, loop.results

    async_d, async_res = run_loop(overlap=True)
    serial_d, serial_res = run_loop(overlap=False)
    facade, facade_res = _sync_reference(csr, rounds, **kw)
    facade.refit_thresholds()

    assert set(async_res) == set(serial_res) == set(facade_res)
    for qid in async_res:
        np.testing.assert_array_equal(async_res[qid], serial_res[qid])
        np.testing.assert_array_equal(async_res[qid], facade_res[qid])

    table = dict(async_d.direction_thresholds.table)
    assert table, "refit produced an empty table"
    for other in (serial_d, facade):
        assert table == dict(other.direction_thresholds.table)
        assert (
            async_d.budget_model.budgets(64)
            == other.budget_model.budgets(64)
        )
        assert async_d.online_trace() == other.online_trace()
        for f in ("queries", "hybrid_runs", "redispatched",
                  "budget_too_low", "budget_too_high",
                  "budget_inert_slots", "budget_observed", "refits"):
            assert getattr(async_d.stats, f) == getattr(other.stats, f), f
        m, mo = async_d.budget_model.mispredicts, other.budget_model.mispredicts
        assert (m.too_low, m.too_high, m.inert_slots, m.observed) == (
            mo.too_low, mo.too_high, mo.inert_slots, mo.observed
        )


# ---------------------------------------------------------------------------
# AdmissionQueue unit behavior
# ---------------------------------------------------------------------------

def test_admission_queue_plan_matches_legacy_batching():
    csr, _ = serve_graph()
    q = AdmissionQueue(
        n_nodes=csr.n_nodes, n_devices=1, avg_degree=csr.avg_degree
    )
    assert q.submit(np.arange(4)).qid == "q0"  # legacy qid naming
    assert q.submit(np.arange(4, 8)).qid == "q1"
    assert q.pending() == 2 and q.in_flight() == 2
    plan = q.plan()
    # 8 pooled sources: under the lane-saturation bar => one solo batch
    # per query, arrival order — the legacy per-query flush branch
    assert [pb.packed for pb in plan.batches] == [False, False]
    assert [pb.queries[0].qid for pb in plan.batches] == ["q0", "q1"]
    assert plan.batches[0].spans == {"q0": (0, 4)}
    assert q.pending() == 0 and q.in_flight() == 2  # still uncompleted
    q.complete("q0")
    q.complete("q1")
    assert q.in_flight() == 0
    # >= 64 pooled sources => ONE packed ntkms batch, spans in
    # submission order — the legacy pooled branch
    a = q.submit(np.arange(40)).qid
    b = q.submit(np.arange(40, 80)).qid
    plan = q.plan()
    assert len(plan.batches) == 1 and plan.batches[0].packed
    assert plan.batches[0].policy == "ntkms"
    assert plan.batches[0].spans == {a: (0, 40), b: (40, 80)}
    np.testing.assert_array_equal(
        plan.batches[0].sources, np.arange(80, dtype=np.int32)
    )


def test_admission_queue_capped_batches_order_and_bit_identity():
    """max_batch_sources bounds each plan round to an arrival-order
    prefix of the queue (saxml-style bucketed batching): pooled sources
    per round never exceed the cap, queries are served strictly in
    arrival order across rounds, and slicing a stream into capped
    batches does not move a single result bit."""
    csr, heads = serve_graph()
    q = AdmissionQueue(
        n_nodes=csr.n_nodes, n_devices=1, avg_degree=csr.avg_degree,
        max_batch_sources=128,
    )
    rng = np.random.default_rng(7)
    qids = [
        q.submit(rng.integers(0, 160, 32).astype(np.int32)).qid
        for _ in range(10)
    ]
    served, plans = [], 0
    while q.pending():
        plan = q.plan()
        plans += 1
        assert sum(len(pb.sources) for pb in plan.batches) <= 128
        for pb in plan.batches:
            served.extend(query.qid for query in pb.queries)
            for query in pb.queries:
                q.complete(query.qid)
    assert served == qids  # arrival order survives the capped rounds
    assert plans == 3  # 10 queries x 32 sources under a 4-query cap

    # end to end: a capped ServingLoop slices the same stream into three
    # packed batches; the uncapped synchronous façade serves it as one —
    # results must be bit-identical (straggler head included so the
    # phase-2 gang path crosses a batch boundary too)
    rng = np.random.default_rng(8)
    queries = [
        (f"c{i}", np.concatenate([
            [heads[0]] if i == 0 else np.zeros(0, np.int64),
            rng.integers(0, 160, 31 if i == 0 else 32),
        ]).astype(np.int32))
        for i in range(10)
    ]
    loop = _loop(csr, overlap=True, max_batch_sources=128,
                 online_adapt=False)
    for qid, s in queries:
        loop.submit(s, qid=qid)
    capped = loop.drain()
    assert loop.stats.batches == 3
    _, ref = _sync_reference(
        csr, [queries], online_adapt=False
    )
    for qid, _s in queries:
        np.testing.assert_array_equal(capped[qid], ref[qid])


# ---------------------------------------------------------------------------
# PPR epsilon-termination determinism (ISSUE 9 satellite)
# ---------------------------------------------------------------------------


def test_ppr_epsilon_termination_deterministic_across_stack_knobs():
    """PPR's iterate-until-epsilon exit is a pure function of the graph
    and seeds: the same seeded stream must settle to bit-identical mass,
    residuals, and iteration counts with online learning on or off and
    in the replicated or sharded engine state layout. The learners and
    layouts may move WHEN work happens (budget caps, resume, gang), but
    never the float trajectory of the converging diffusion."""
    from repro.runtime.dispatch import QueryDispatcher

    csr, heads = serve_graph()
    rng = np.random.default_rng(11)
    subs = [
        (f"p{i}", rng.integers(0, csr.n_nodes, 2).astype(np.int32))
        for i in range(4)
    ]

    # served stream: online-adapt on vs off, delivered mass rows bitwise
    def run_loop(online_adapt):
        loop = _loop(csr, online_adapt=online_adapt, max_iters=512)
        for qid, s in subs:
            loop.submit(s, qid=qid, query_kind="ppr")
        return loop.drain()

    adapt_on = run_loop(True)
    adapt_off = run_loop(False)
    assert set(adapt_on) == set(adapt_off) == {qid for qid, _ in subs}
    for qid in adapt_on:
        np.testing.assert_array_equal(adapt_on[qid], adapt_off[qid])

    # dispatcher level: every (online_adapt, layout) cell agrees on the
    # full state — mass, residual, AND the iteration count at which the
    # epsilon exit fired
    runs = {}
    for adapt in (True, False):
        for layout in ("replicated", "sharded"):
            d = QueryDispatcher(
                mesh11(), csr, max_iters=512, online_adapt=adapt,
                backend="dopt", family="powerlaw",
            )
            outs = [
                d.query(s, query_kind="ppr", state_layout=layout)
                for _qid, s in subs
            ]
            runs[(adapt, layout)] = [
                (
                    np.asarray(o.result.state.mass),
                    np.asarray(o.result.state.residual),
                    np.asarray(o.result.iterations),
                )
                for o in outs
            ]
    ref = runs[(True, "replicated")]
    from repro.core.edge_compute import PPRDiffusion

    for (mass, residual, iters) in ref:
        assert (residual <= PPRDiffusion.EPS).all()
        assert (iters < 512).all()
    for cell, got in runs.items():
        for (m0, r0, i0), (m1, r1, i1) in zip(ref, got):
            np.testing.assert_array_equal(m0, m1, err_msg=str(cell))
            np.testing.assert_array_equal(r0, r1, err_msg=str(cell))
            np.testing.assert_array_equal(i0, i1, err_msg=str(cell))
