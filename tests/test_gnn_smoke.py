"""GNN arch smoke tests + rotation-equivariance property tests + sampler."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.nn.module import split_boxed
from repro.models.gnn import mace as mace_m
from repro.models.gnn import equiformer_v2 as eqv2_m
from repro.models.gnn import pna as pna_m
from repro.models.gnn import schnet as schnet_m
from repro.configs.mace import smoke_config as mace_smoke
from repro.configs.equiformer_v2 import smoke_config as eqv2_smoke
from repro.configs.pna import smoke_config as pna_smoke
from repro.configs.schnet import smoke_config as schnet_smoke

MODELS = {
    "mace": (mace_m, mace_smoke),
    "equiformer-v2": (eqv2_m, eqv2_smoke),
    "pna": (pna_m, pna_smoke),
    "schnet": (schnet_m, schnet_smoke),
}


def toy_batch(seed=0, n=24, e=80, d_feat=16, geometric=True):
    rng = np.random.default_rng(seed)
    batch = {
        "edge_src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "node_feat": jnp.asarray(
            rng.standard_normal((n, d_feat)), jnp.float32
        ),
    }
    if geometric:
        batch["positions"] = jnp.asarray(
            rng.standard_normal((n, 3)) * 2.0, jnp.float32
        )
        batch["species"] = jnp.asarray(rng.integers(0, 8, n), jnp.int32)
    return batch


def _make(arch):
    module, smoke = MODELS[arch]
    cfg = smoke()
    if hasattr(cfg, "d_feat") and arch != "pna":
        import dataclasses

        cfg = dataclasses.replace(cfg, d_feat=16)
    params, _ = split_boxed(module.init(jax.random.PRNGKey(0), cfg))
    return module, cfg, params


@pytest.mark.parametrize("arch", sorted(MODELS))
def test_forward_finite(arch):
    module, cfg, params = _make(arch)
    batch = toy_batch(d_feat=cfg.d_feat)
    out = module.apply(params, cfg, batch)["node_out"]
    assert out.shape == (24, cfg.n_out)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("arch", sorted(MODELS))
def test_train_step_descends(arch):
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    module, cfg, params = _make(arch)
    batch = toy_batch(d_feat=cfg.d_feat)
    target = jnp.asarray(
        np.random.default_rng(1).standard_normal((24, cfg.n_out)), jnp.float32
    )

    def loss(p):
        out = module.apply(p, cfg, batch)["node_out"]
        return jnp.mean(jnp.square(out - target))

    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step(p, o):
        l, g = jax.value_and_grad(loss)(p)
        p, o, _ = adamw_update(g, o, p, ocfg)
        return p, o, l

    p, o, l0 = step(params, opt)
    for _ in range(4):
        p, o, l1 = step(p, o)
    assert np.isfinite(float(l0)) and float(l1) < float(l0)


@pytest.mark.parametrize("arch", ["mace", "equiformer-v2", "schnet"])
def test_rotation_invariance(arch):
    """Scalar node outputs must be invariant under global rotation of the
    input geometry (the E(3)/SO(2)-eSCN equivariance property)."""
    module, cfg, params = _make(arch)
    batch = toy_batch(d_feat=cfg.d_feat)
    out1 = module.apply(params, cfg, batch)["node_out"]
    # random rotation matrix via QR
    rng = np.random.default_rng(5)
    Q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] @ jnp.asarray(
        Q.T, jnp.float32
    )
    out2 = module.apply(params, cfg, batch2)["node_out"]
    np.testing.assert_allclose(
        np.asarray(out1), np.asarray(out2), rtol=2e-3, atol=2e-3
    )


def test_translation_invariance():
    module, cfg, params = _make("mace")
    batch = toy_batch(d_feat=cfg.d_feat)
    out1 = module.apply(params, cfg, batch)["node_out"]
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] + jnp.asarray([10.0, -3.0, 7.0])
    out2 = module.apply(params, cfg, batch2)["node_out"]
    np.testing.assert_allclose(
        np.asarray(out1), np.asarray(out2), rtol=1e-4, atol=1e-4
    )


def test_graph_readout():
    module, cfg, params = _make("schnet")
    batch = toy_batch(d_feat=cfg.d_feat)
    batch["graph_ids"] = jnp.asarray([0] * 12 + [1] * 12, jnp.int32)
    batch["n_graphs"] = 2
    out = module.apply(params, cfg, batch)
    assert out["graph_out"].shape == (2, cfg.n_out)
    np.testing.assert_allclose(
        np.asarray(out["graph_out"].sum(0)),
        np.asarray(out["node_out"].sum(0)),
        rtol=1e-5,
    )


def test_sampler_subgraph():
    from repro.graph.csr import ell_from_csr
    from repro.graph.generators import erdos_renyi
    from repro.graph.sampler import sample_subgraph

    csr = erdos_renyi(500, 8.0, seed=3)
    g = ell_from_csr(csr)
    seeds = jnp.asarray([5, 100, 250, 499], jnp.int32)
    sub = sample_subgraph(g, seeds, (4, 3), jax.random.PRNGKey(0))
    assert sub.nodes.shape[0] == 4 + 16 + 48
    assert sub.edge_src.shape[0] == 16 + 48
    nodes = np.asarray(sub.nodes)
    src = np.asarray(sub.edge_src)
    dst = np.asarray(sub.edge_dst)
    # every sampled edge (child -> parent) must exist in the graph
    # (reverse direction: child is a sampled out-neighbor of parent) or be a
    # zero-degree self-loop
    for s_loc, d_loc in zip(src[:30], dst[:30]):
        child, parent = int(nodes[s_loc]), int(nodes[d_loc])
        nbrs = set(int(v) for v in csr.neighbors(parent))
        assert child in nbrs or (child == parent and len(nbrs) == 0)


def test_sampler_runs_gnn():
    """minibatch cell path: sampled subgraph through a GNN apply."""
    from repro.graph.csr import ell_from_csr
    from repro.graph.generators import erdos_renyi
    from repro.graph.sampler import sample_subgraph

    csr = erdos_renyi(300, 6.0, seed=4)
    g = ell_from_csr(csr)
    module, cfg, params = _make("pna")
    seeds = jnp.arange(8, dtype=jnp.int32) * 30
    sub = sample_subgraph(g, seeds, (5, 3), jax.random.PRNGKey(1))
    feat_table = jnp.asarray(
        np.random.default_rng(0).standard_normal((300, cfg.d_feat)),
        jnp.float32,
    )
    batch = {
        "node_feat": jnp.take(feat_table, sub.nodes, axis=0),
        "edge_src": sub.edge_src,
        "edge_dst": sub.edge_dst,
    }
    out = module.apply(params, cfg, batch)["node_out"]
    seed_out = out[: sub.seed_count]
    assert seed_out.shape == (8, cfg.n_out)
    assert bool(jnp.isfinite(seed_out).all())
