"""System-level tests: sharded-state engine, dst-aligned slab aggregation,
query serving end-to-end, the dry-run cell builder, elastic checkpoints,
and the fault-tolerant train driver."""
import collections
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest


def _bfs(csr, s):
    lv = np.full(csr.n_nodes, -1, np.int32)
    lv[s] = 0
    q = collections.deque([s])
    while q:
        u = q.popleft()
        for v in csr.neighbors(u):
            if lv[int(v)] < 0:
                lv[int(v)] = lv[u] + 1
                q.append(int(v))
    return lv


# ---------------------------------------------------------------------------
# HLO collective parser (pure string-level unit test)
# ---------------------------------------------------------------------------

def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import parse_collectives, roofline_terms

    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(f32[1,1024] %x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = bf16[128]{0} all-reduce(bf16[128] %y), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = u32[64]{0} collective-permute(u32[64] %z), source_target_pairs={{0,1}}
  %rs = f32[8]{0} reduce-scatter(f32[128] %w), replica_groups=[32,16]<=[512], dimensions={0}
"""
    st = parse_collectives(hlo)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.out_bytes["all-gather"] == 16 * 1024 * 4
    assert st.out_bytes["all-reduce"] == 128 * 2
    # ring factors: AG (K-1)/K x out, AR 2(K-1)/K, RS (K-1) x out, CP 1x
    assert abs(st.wire_bytes["all-gather"] - 15 / 16 * 16 * 1024 * 4) < 1
    assert abs(st.wire_bytes["all-reduce"] - 2 * 3 / 4 * 256) < 1
    assert st.wire_bytes["collective-permute"] == 64 * 4
    assert abs(st.wire_bytes["reduce-scatter"] - 15 * 32) < 1

    rl = roofline_terms(
        {"flops": 1e12, "bytes accessed": 1e9}, st, 256, 2.56e14,
        iters_scale=2.0,
    )
    assert rl.flops == 2e12
    assert rl.dominant in ("compute", "memory", "collective")
    assert 0 < rl.useful_fraction < 1


# ---------------------------------------------------------------------------
# dst-aligned slab aggregation == flat aggregation (all GNN models)
# ---------------------------------------------------------------------------

def test_slab_aggregation_matches_flat():
    import dataclasses

    from repro.graph.partition import slab_edges
    from repro.models.gnn import common as C
    from repro.models.gnn import equiformer_v2 as eqv2_m
    from repro.models.gnn import pna as pna_m
    from repro.models.gnn import schnet as schnet_m
    from repro.nn.module import split_boxed
    from repro.configs.equiformer_v2 import smoke_config as eqv2_smoke
    from repro.configs.pna import smoke_config as pna_smoke
    from repro.configs.schnet import smoke_config as schnet_smoke

    rng = np.random.default_rng(0)
    n, e, K = 32, 120, 4
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    batch = {
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "node_feat": jnp.asarray(rng.standard_normal((n, 16)), jnp.float32),
        "positions": jnp.asarray(
            rng.standard_normal((n, 3)) * 2, jnp.float32
        ),
        "species": jnp.asarray(rng.integers(0, 8, n), jnp.int32),
    }
    ssrc, sdst, bounds = slab_edges(src, dst, n, K)
    assert len(ssrc) % K == 0
    assert bounds[0] == 0 and bounds[-1] == n
    bsrc, bdst, bbounds = slab_edges(src, dst, n, K, balance="edges")
    assert len(bsrc) % K == 0
    # edge-balanced layout pads no wider than the node-balanced one
    assert len(bsrc) <= len(ssrc)
    batch_slab = dict(
        batch, edge_src=jnp.asarray(ssrc), edge_dst=jnp.asarray(sdst)
    )
    batch_bal = dict(
        batch, edge_src=jnp.asarray(bsrc), edge_dst=jnp.asarray(bdst)
    )
    for name, (mod, smoke) in {
        "pna": (pna_m, pna_smoke),
        "schnet": (schnet_m, schnet_smoke),
        "eqv2": (eqv2_m, eqv2_smoke),
    }.items():
        cfg = smoke()
        if name != "pna":
            cfg = dataclasses.replace(cfg, d_feat=16)
        params, _ = split_boxed(mod.init(jax.random.PRNGKey(0), cfg))
        C.set_edge_slabs(None)
        out_flat = mod.apply(params, cfg, batch)["node_out"]
        try:
            C.set_edge_slabs(K)
            out_slab = mod.apply(params, cfg, batch_slab)["node_out"]
            C.set_edge_slabs(K, bounds=bbounds)
            out_bal = mod.apply(params, cfg, batch_bal)["node_out"]
        finally:
            C.set_edge_slabs(None)
        np.testing.assert_allclose(
            np.asarray(out_flat), np.asarray(out_slab),
            rtol=2e-5, atol=2e-5, err_msg=name,
        )
        np.testing.assert_allclose(
            np.asarray(out_flat), np.asarray(out_bal),
            rtol=2e-5, atol=2e-5, err_msg=name + "-balanced",
        )


# ---------------------------------------------------------------------------
# Query serving end-to-end (engine reuse + policy recommendation + outputs)
# ---------------------------------------------------------------------------

def test_query_service_end_to_end():
    from repro.graph.generators import powerlaw, pick_sources
    from repro.launch.serve import QueryService

    from repro.launch.mesh import make_mesh

    csr = powerlaw(400, 6.0, seed=5)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = QueryService(mesh, csr, max_iters=64)

    srcs = pick_sources(csr, 4, seed=1)
    res, pol = svc.query(srcs, returns_paths=False)
    assert pol == "ntks"  # < 64 sources -> hybrid (paper §5 recommendation)
    got = np.asarray(res.state.levels)[: len(srcs), : csr.n_nodes]
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(got[i], _bfs(csr, int(s)))

    # engine reuse: same (policy, ec) key must not recompile — counted
    # through the EngineCache's public mapping surface
    cache = svc.scheduler.cache
    n_engines = len(cache)
    keys = set(cache.keys())
    svc.query(pick_sources(csr, 4, seed=2), returns_paths=False)
    assert len(cache) == n_engines
    assert set(cache.keys()) == keys
    assert all(k in cache and cache.get(k) is not None for k in keys)

    # >= 64 sources -> lane-packed multi-source morsels
    srcs64 = pick_sources(csr, 64, seed=3)
    res, pol = svc.query(srcs64, returns_paths=False)
    assert pol == "ntkms"
    lanes = np.asarray(res.state.levels)[0, : csr.n_nodes, :]
    lv = lanes[:, 7].astype(np.int32)
    lv[lv == 255] = -1
    np.testing.assert_array_equal(lv, _bfs(csr, int(srcs64[7])))

    # paths workload routes to the parents edge compute
    res, pol = svc.query(srcs, returns_paths=True)
    assert np.asarray(res.state.parents).shape[-1] >= csr.n_nodes


# ---------------------------------------------------------------------------
# multi-device system paths (subprocess: needs its own XLA device count)
# ---------------------------------------------------------------------------

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import collections

from repro.core import run_recursive_query, policy_ntks, policy_ntkms
from repro.graph.generators import powerlaw
from repro.launch.mesh import make_mesh

def bfs(csr, s):
    lv = np.full(csr.n_nodes, -1, np.int32); lv[s] = 0
    q = collections.deque([s])
    while q:
        u = q.popleft()
        for v in csr.neighbors(u):
            if lv[int(v)] < 0: lv[int(v)] = lv[u]+1; q.append(int(v))
    return lv

mesh = make_mesh((2, 4), ("data", "model"))
csr = powerlaw(300, 5.0, seed=1)
srcs = np.array([0, 3, 17, 44, 123, 200, 250, 280], np.int32)
exp = np.stack([bfs(csr, int(s)) for s in srcs])

# 1. sharded-state engine == replicated-state engine == oracle
for layout in ("replicated", "sharded"):
    for impl in ("ring", "allgather"):
        r = run_recursive_query(mesh, csr, srcs, policy_ntks(or_impl=impl),
                                "sp_lengths", state_layout=layout)
        got = np.asarray(r.state.levels)[: len(srcs), : csr.n_nodes]
        assert (got == exp).all(), (layout, impl)
print("engine layouts OK")

# 2. sharded-state msbfs lanes
r = run_recursive_query(mesh, csr, srcs, policy_ntkms(or_impl="ring"),
                        "msbfs_lengths", state_layout="sharded")
lanes = np.asarray(r.state.levels)[0, : csr.n_nodes]
for i, s in enumerate(srcs):
    got = lanes[:, i].astype(np.int32); got[got == 255] = -1
    assert (got == exp[i]).all(), i
print("sharded msbfs OK")

# 3. elastic checkpoint: save under (2,4) sharding, restore under (4,2)
from repro.checkpoint.checkpoint import CheckpointManager
import tempfile
d = tempfile.mkdtemp()
ck = CheckpointManager(d, async_write=False)
x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                   NamedSharding(mesh, P("data", "model")))
state = {"w": x, "step": jnp.int32(7)}
ck.save(3, state, blocking=True)
mesh2 = make_mesh((4, 2), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("model", "data")),
       "step": NamedSharding(mesh2, P())}
restored, step = ck.restore(state, shardings=sh2)
assert step == 3
assert (np.asarray(restored["w"]) == np.asarray(x)).all()
assert restored["w"].sharding.mesh.shape["data"] == 4
print("elastic checkpoint OK")

# 4. dry-run cell builder: paper engine on this 8-device mesh
from repro.launch.steps import build_cell, lower_cell
from repro.launch.hlo_analysis import parse_collectives
cell = build_cell("paper-bfs-engine", "ldbc100", mesh, False)
lowered = lower_cell(cell, mesh)
compiled = lowered.compile()
cost = compiled.cost_analysis()
cost = cost[0] if isinstance(cost, list) else cost
assert cost.get("flops", 0) > 0
st = parse_collectives(compiled.as_text())
assert sum(st.counts.values()) > 0, "graph-partitioned engine must communicate"
print("cell builder OK")

# 5. adaptive hybrid runtime on a real 2x4 mesh: phase 1 (nTkS, per-shard
# convergence) + phase 2 (nT1S resume) must equal the oracle, reuse engines
from repro.runtime.scheduler import AdaptiveScheduler
sched = AdaptiveScheduler(mesh, csr, max_iters=64, phase1_iters=2)
out = sched.query(srcs)
assert out.hybrid and out.redispatched > 0, (out.hybrid, out.redispatched)
got = np.asarray(out.result.state.levels)[: len(srcs), : csr.n_nodes]
assert (got == exp).all(), "hybrid vs oracle"
out2 = sched.query(srcs)
assert sched.cache.hits >= 2, sched.cache.hits
print("adaptive hybrid OK")
print("ALL_SYSTEM_OK")
"""


@pytest.mark.slow
def test_multidevice_system_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", SUBPROC],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_SYSTEM_OK" in r.stdout


# ---------------------------------------------------------------------------
# fault-tolerant train driver end-to-end (tiny; includes resume)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_driver_resumes(tmp_path):
    from repro.launch.train import main

    rc = main([
        "--arch", "minicpm-2b", "--steps", "30", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--save-every", "10",
        "--log-every", "100",
    ])
    assert rc == 0
    assert (tmp_path / "step_30").exists()
    # crash-restart: second invocation resumes from 30 and continues
    rc = main([
        "--arch", "minicpm-2b", "--steps", "40", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--save-every", "10",
        "--log-every", "100",
    ])
    assert rc == 0
    assert (tmp_path / "step_40").exists()
