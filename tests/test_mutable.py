"""Mutable graphs (ISSUE 8): versioned delta operands + stale-state sweep.

Locks the tentpole contract — ``apply_delta`` folds a ``GraphDelta`` into
the live operand bundles and the result of update-then-query is
bit-identical to rebuild-then-query on every backend — plus the satellite
bugfixes: the EngineCache LRU bound, in-flight bundle pinning across a
delta, the exact-deadline admission boundary, the dedup-consistency
contract between ``apply_delta_csr`` and a from-scratch
``csr_from_edges`` build, and the random-edit-script property test
against the rebuild oracle (bucket-boundary crossings, zero<->nonzero
degree transitions, an edgeless ``[n, 0]``-slab start).
"""
import functools

import numpy as np
import pytest

from oracle import bfs_levels

from repro.graph.csr import csr_from_edges
from repro.graph.delta import (
    GraphDelta,
    apply_delta_csr,
    diff_effective,
    random_delta,
)
from repro.graph.generators import powerlaw
from repro.launch.mesh import make_mesh
from repro.runtime.admission import AdmissionQueue, SHED_EXPIRED
from repro.runtime.dispatch import EngineCache, EngineKey, QueryDispatcher
from repro.runtime.service import ServingLoop


@functools.lru_cache(maxsize=None)
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _rand_csr(n=100, m=700, seed=0, weighted=False):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.1, 2.0, m).astype(np.float32) if weighted else None
    return csr_from_edges(
        n, rng.integers(0, n, m), rng.integers(0, n, m), weights=w
    )


def _levels(disp, srcs, **kw):
    out = disp.query(srcs, **kw)
    return np.asarray(out.result.state.levels)


# ---------------------------------------------------------------------------
# GraphDelta semantics + satellite 4: dedup/self-loop consistency
# ---------------------------------------------------------------------------

def test_delta_normalization_and_validation():
    d = GraphDelta(add_src=[1, 2], add_dst=[3, 4])
    assert d.n_adds == 2 and d.n_dels == 0
    assert d.del_src.dtype == np.int64 and len(d.del_src) == 0
    np.testing.assert_array_equal(d.touched_rows(), [1, 2])
    with pytest.raises(ValueError):
        GraphDelta(add_src=[1], add_dst=[2, 3])
    with pytest.raises(ValueError):
        GraphDelta(add_src=[1], add_dst=[2], add_weights=[1.0, 2.0])
    with pytest.raises(ValueError):
        GraphDelta(add_src=[99], add_dst=[0]).validate(n_nodes=10)
    with pytest.raises(ValueError):
        # weighted delta against an unweighted graph
        apply_delta_csr(
            _rand_csr(),
            GraphDelta(add_src=[0], add_dst=[1], add_weights=[2.0]),
        )


def test_apply_delta_matches_concat_rebuild_dedup_and_self_loops():
    """Satellite 4: ``apply_delta_csr(g, d)`` must agree edge-for-edge
    (weights included) with ``csr_from_edges`` over the concatenated
    surviving + inserted edge list — duplicate adds collapse, self-loops
    are ordinary edges, deleting an absent edge is a no-op, and
    re-inserting a live edge keeps the OLD weight (stable keep-first)."""
    csr = _rand_csr(n=40, m=200, seed=3, weighted=True)
    src, dst = csr.edge_list()
    live = (int(src[7]), int(dst[7]))
    delta = GraphDelta(
        add_src=[5, 5, 5, live[0], 11],
        add_dst=[5, 5, 9, live[1], 11],  # dup self-loops + live re-insert
        del_src=[src[0], 13],
        del_dst=[dst[0], 13],            # second delete likely absent
        add_weights=[9.0, 8.0, 7.0, 123.0, 6.0],
    )
    got = apply_delta_csr(csr, delta)

    # hand-built oracle over the same concatenation order
    n = csr.n_nodes
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    dkey = np.unique(delta.del_src * n + delta.del_dst)
    keep = ~np.isin(key, dkey)
    ref = csr_from_edges(
        n,
        np.concatenate([src[keep], delta.add_src]),
        np.concatenate([dst[keep], delta.add_dst]),
        weights=np.concatenate([csr.weights[keep], delta.add_weights]),
        dedup=True,
    )
    np.testing.assert_array_equal(got.indptr, ref.indptr)
    np.testing.assert_array_equal(got.indices, ref.indices)
    np.testing.assert_array_equal(got.weights, ref.weights)

    # keep-first: the re-inserted live edge kept its original weight
    w_live = csr.weights[7]
    pos = np.flatnonzero(
        got.edge_keys() == live[0] * n + live[1]
    )
    assert len(pos) == 1 and got.weights[pos[0]] == w_live
    # dedup'd CSR edge keys are strictly increasing (no duplicates)
    assert (np.diff(got.edge_keys()) > 0).all()
    # the duplicate self-loop collapsed to one edge with the FIRST weight
    pos55 = np.flatnonzero(got.edge_keys() == 5 * n + 5)
    assert len(pos55) == 1 and got.weights[pos55[0]] == np.float32(9.0)


def test_diff_effective_sees_truncation_boundary():
    """A delete under a degree cap can pull a previously truncated edge
    into the effective set — the diff compares full per-row effective
    sets, so the fold rewrites that row."""
    from repro.core.extend import effective_csr

    # row 0 with degree 10, cap at 8 -> 2 truncated edges
    src = np.zeros(10, np.int64)
    dst = np.arange(1, 11, dtype=np.int64)
    csr = csr_from_edges(12, src, dst)
    delta = GraphDelta(del_src=[0], del_dst=[1])
    new = apply_delta_csr(csr, delta)
    diff = diff_effective(
        effective_csr(csr, 8), effective_csr(new, 8), delta
    )
    # edge (0,1) left, a truncated edge entered: both directions dirty
    assert 0 in diff.fwd_dirty and diff.n_changed_edges >= 2


# ---------------------------------------------------------------------------
# Satellite 1: EngineCache bounded LRU
# ---------------------------------------------------------------------------

def _key(i, epoch=0):
    return EngineKey(
        kind="static", policy=("p",), edge_compute="sp",
        n_nodes_padded=64, max_iters=i, state_layout="replicated",
        extend=None, stats=True, operands_epoch=epoch,
    )


def test_engine_cache_lru_eviction_and_accounting():
    c = EngineCache(max_entries=2)
    c.get_or_build(_key(1), lambda: "e1")
    c.get_or_build(_key(2), lambda: "e2")
    c.note_shape(_key(1), (4,))
    c.note_shape(_key(2), (4,))
    assert c.compile_events == 4 and len(c) == 2
    c.get_or_build(_key(1), lambda: "BUG")  # hit refreshes recency
    c.get_or_build(_key(3), lambda: "e3")   # evicts key 2 (LRU), not 1
    assert _key(2) not in c and _key(1) in c and _key(3) in c
    assert c.evictions == 1 and len(c) == 2
    # the evicted key's shape ledger went with it: same shape is a fresh
    # miss again, exactly what the re-compile will cost
    assert c.note_shape(_key(2), (4,)) is True
    assert c.get_or_build(_key(2), lambda: "e2b") == "e2b"
    assert c.misses == 4 and c.evictions == 2  # reinsert evicted key 1


def test_engine_cache_invalidate_and_bounds():
    c = EngineCache(max_entries=8)
    for i in range(4):
        c.get_or_build(_key(i, epoch=i % 2), lambda i=i: f"e{i}")
        c.note_shape(_key(i, epoch=i % 2), (8,))
    n = c.invalidate(lambda k: k.operands_epoch == 1)
    assert n == 2 and c.invalidations == 2 and len(c) == 2
    assert all(k.operands_epoch == 0 for k in c.keys())
    # pruned ledger: invalidated keys pay fresh shape misses on return
    assert c.note_shape(_key(1, epoch=1), (8,)) is True
    with pytest.raises(ValueError):
        EngineCache(max_entries=0)
    # unbounded cache never evicts
    u = EngineCache(max_entries=None)
    for i in range(300):
        u.get_or_build(_key(i), lambda: i)
    assert len(u) == 300 and u.evictions == 0


# ---------------------------------------------------------------------------
# Satellite 3: exact-deadline admission boundary (injectable clock)
# ---------------------------------------------------------------------------

def test_deadline_exact_boundary_sheds_at_plan():
    clock = [1000.0]
    q = AdmissionQueue(
        n_nodes=100, n_devices=1, avg_degree=5.0, clock=lambda: clock[0]
    )
    q.submit(np.array([1, 2], np.int32), qid="exact", deadline_ms=50.0)
    clock[0] = 1000.050  # plan at EXACTLY the deadline instant
    plan = q.plan()
    assert not plan.batches and "exact" not in plan.instant
    assert q.stats.sheds_by_reason[SHED_EXPIRED] == 1
    # one tick earlier the same ticket is NOT expired (it may still be
    # shed as hopeless, but never as expired)
    q2 = AdmissionQueue(
        n_nodes=100, n_devices=1, avg_degree=5.0, clock=lambda: clock[0]
    )
    clock[0] = 1000.0
    q2.submit(np.array([1, 2], np.int32), qid="alive", deadline_ms=50.0)
    clock[0] = 1000.0499
    q2.plan()
    assert q2.stats.sheds_by_reason[SHED_EXPIRED] == 0


# ---------------------------------------------------------------------------
# Tentpole: update-then-query == rebuild-then-query, per backend
# ---------------------------------------------------------------------------

BACKENDS_FAST = ["dopt", "pull_binned_fused", "block_mxu"]
BACKENDS_SLOW = ["ell_push", "ell_pull", "pull_binned"]


def _parity_case(backend, state_layout="replicated", policy=None,
                 weighted=False):
    csr = _rand_csr(n=120, m=900, seed=1, weighted=weighted)
    rng = np.random.default_rng(5)
    delta = random_delta(csr, n_adds=25, n_dels=25, seed=7)
    srcs = rng.integers(0, 120, 8).astype(np.int32)
    d = QueryDispatcher(mesh11(), csr, max_iters=32)
    d.query(srcs, backend=backend, state_layout=state_layout, policy=policy)
    rep = d.apply_delta(delta)
    assert rep.version == 1 and d.operands_version == 1
    lv = _levels(d, srcs, backend=backend, state_layout=state_layout,
                 policy=policy)
    d2 = QueryDispatcher(mesh11(), apply_delta_csr(csr, delta), max_iters=32)
    lv2 = _levels(d2, srcs, backend=backend, state_layout=state_layout,
                  policy=policy)
    np.testing.assert_array_equal(lv, lv2)


@pytest.mark.parametrize("backend", BACKENDS_FAST)
def test_update_then_query_matches_rebuild(backend):
    _parity_case(backend)


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS_SLOW)
def test_update_then_query_matches_rebuild_all_backends(backend):
    _parity_case(backend)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["dopt", "pull_binned_fused"])
def test_update_then_query_matches_rebuild_sharded(backend):
    _parity_case(backend, state_layout="sharded")


@pytest.mark.slow
def test_update_then_query_matches_rebuild_msbfs_lanes():
    _parity_case("dopt", policy="ntkms")


def test_update_then_query_weighted_graph():
    _parity_case("dopt", weighted=True)


# ---------------------------------------------------------------------------
# Tentpole: same-shape delta keeps compiled engines warm
# ---------------------------------------------------------------------------

def _warm_graph():
    """In-degrees only {10, 11}: one refined reverse bucket of width 11,
    so swapping an 11-in-degree target with a 10-in-degree one (same
    source, out-degree unchanged) moves rows *within* existing slabs and
    every operand structure keeps its exact shape."""
    n = 64
    rng = np.random.default_rng(7)
    src_l, dst_l = [], []
    targets = list(range(32, 56))
    for i, t in enumerate(targets):
        for s in rng.choice(32, size=(10 if i % 2 == 0 else 11),
                            replace=False):
            src_l.append(int(s))
            dst_l.append(int(t))
    csr = csr_from_edges(n, np.array(src_l), np.array(dst_l))
    indeg = np.zeros(n, int)
    np.add.at(indeg, np.array(dst_l), 1)
    edges = set(zip(src_l, dst_l))
    for (s, t) in edges:
        if indeg[t] == 11:
            for t2 in targets:
                if indeg[t2] == 10 and (s, t2) not in edges:
                    return csr, GraphDelta(
                        add_src=[s], add_dst=[t2],
                        del_src=[s], del_dst=[t],
                    )
    raise AssertionError("unreachable: constructed graph has both degrees")


@pytest.mark.parametrize("backend", ["pull_binned_fused", "dopt"])
def test_same_shape_delta_keeps_engines_warm(backend):
    csr, delta = _warm_graph()
    d = QueryDispatcher(mesh11(), csr, max_iters=32)
    rng = np.random.default_rng(0)
    srcs = rng.integers(0, 32, 8).astype(np.int32)
    for _ in range(2):  # warm up: let the budget model's choice settle
        d.query(srcs, backend=backend)
    before = d.cache.compile_events
    rep = d.apply_delta(delta)
    assert rep.same_shape and rep.engines_invalidated == 0
    assert rep.structures_rebuilt == 0
    lv = _levels(d, srcs, backend=backend)
    assert d.cache.compile_events == before, (
        "same-shape delta must not trigger any engine compile or retrace"
    )
    d2 = QueryDispatcher(mesh11(), apply_delta_csr(csr, delta), max_iters=32)
    np.testing.assert_array_equal(lv, _levels(d2, srcs, backend=backend))


def test_shape_changing_delta_invalidates_only_stale_engines():
    csr = _rand_csr(n=100, m=400, seed=2)
    d = QueryDispatcher(mesh11(), csr, max_iters=32)
    srcs = np.arange(6, dtype=np.int32)
    d.query(srcs, backend="dopt")
    n_engines = len(d.cache)
    # 60 adds onto one target forces a reverse-slab reshape
    rng = np.random.default_rng(9)
    delta = GraphDelta(
        add_src=rng.integers(0, 100, 60), add_dst=np.full(60, 3)
    )
    rep = d.apply_delta(delta)
    assert not rep.same_shape and rep.engines_invalidated > 0
    assert rep.engines_invalidated <= n_engines
    lv = _levels(d, srcs, backend="dopt")
    d2 = QueryDispatcher(mesh11(), apply_delta_csr(csr, delta), max_iters=32)
    np.testing.assert_array_equal(lv, _levels(d2, srcs, backend="dopt"))


# ---------------------------------------------------------------------------
# Satellite 2: in-flight batches pin their operand bundle
# ---------------------------------------------------------------------------

def test_inflight_batch_pins_pre_delta_bundle():
    csr = powerlaw(160, 5.0, seed=0)
    rng = np.random.default_rng(3)
    delta = random_delta(csr, 15, 15, seed=9)
    csr2 = apply_delta_csr(csr, delta)
    d = QueryDispatcher(mesh11(), csr, max_iters=64)
    srcs = rng.integers(0, 160, 4).astype(np.int32)
    inflight = d.begin_batch(srcs, backend="dopt")
    d.apply_delta(delta)  # lands while the batch is in flight
    outcome = d.finalize_batch(d.settle_batch(inflight))
    lv = np.asarray(outcome.result.state.levels)[: len(srcs), : csr.n_nodes]
    ref_old = np.stack([bfs_levels(csr, int(s)) for s in srcs])
    np.testing.assert_array_equal(
        lv, ref_old, err_msg="in-flight batch must finish on the OLD graph"
    )
    lv2 = _levels(d, srcs, backend="dopt")[: len(srcs), : csr2.n_nodes]
    ref_new = np.stack([bfs_levels(csr2, int(s)) for s in srcs])
    np.testing.assert_array_equal(
        lv2, ref_new, err_msg="post-delta query must see the NEW graph"
    )


# ---------------------------------------------------------------------------
# ServingLoop fence
# ---------------------------------------------------------------------------

def test_serving_loop_delta_fence_old_before_new_after():
    csr = powerlaw(160, 5.0, seed=0)
    rng = np.random.default_rng(3)
    delta = random_delta(csr, 15, 15, seed=9)
    csr2 = apply_delta_csr(csr, delta)
    loop = ServingLoop(mesh11(), csr, backend="dopt", family="powerlaw",
                       max_iters=64, overlap=True)
    pre = {f"pre{q}": rng.integers(0, 160, 4).astype(np.int32)
           for q in range(2)}
    for qid, s in pre.items():
        loop.submit(s, qid=qid)
    rep = loop.apply_delta(delta)
    assert rep.version == 1 and loop.graph_version == 1
    assert loop.stats.deltas_applied == 1
    assert loop.delta_reports == [rep]
    post = {f"post{q}": rng.integers(0, 160, 4).astype(np.int32)
            for q in range(2)}
    for qid, s in post.items():
        loop.submit(s, qid=qid)
    results = loop.drain()
    for qid, s in pre.items():
        ref = np.stack([bfs_levels(csr, int(x)) for x in s])
        np.testing.assert_array_equal(
            results[qid], ref,
            err_msg=f"{qid}: admitted before the delta -> old graph",
        )
    for qid, s in post.items():
        ref = np.stack([bfs_levels(csr2, int(x)) for x in s])
        np.testing.assert_array_equal(
            results[qid], ref,
            err_msg=f"{qid}: admitted after the delta -> new graph",
        )
    # the admission estimator follows the mutated graph's density
    assert loop.admission.avg_degree == pytest.approx(csr2.avg_degree)


def test_run_stream_applies_delta_entries_in_order():
    csr = powerlaw(160, 5.0, seed=0)
    rng = np.random.default_rng(4)
    delta = random_delta(csr, 20, 20, seed=8)
    csr2 = apply_delta_csr(csr, delta)
    a = rng.integers(0, 160, 4).astype(np.int32)
    b = rng.integers(0, 160, 4).astype(np.int32)
    loop = ServingLoop(mesh11(), csr, backend="dopt", family="powerlaw",
                       max_iters=64, overlap=True)
    out = loop.run_stream([
        {"t_ms": 0.0, "sources": a, "qid": "a"},
        {"t_ms": 5.0, "delta": delta},
        {"t_ms": 9.0, "sources": b, "qid": "b"},
    ])
    np.testing.assert_array_equal(
        out["a"], np.stack([bfs_levels(csr, int(x)) for x in a])
    )
    np.testing.assert_array_equal(
        out["b"], np.stack([bfs_levels(csr2, int(x)) for x in b])
    )
    assert loop.stats.deltas_applied == 1


@pytest.mark.slow
def test_serving_loop_same_shape_delta_flat_compile_events():
    """The ISSUE acceptance bar: a same-shape delta applied mid-stream
    leaves ``EngineCache.compile_events`` unchanged while serving correct
    post-delta results."""
    csr, delta = _warm_graph()
    csr2 = apply_delta_csr(csr, delta)
    rng = np.random.default_rng(1)
    loop = ServingLoop(mesh11(), csr, backend="pull_binned_fused",
                       max_iters=32, overlap=True)
    for q in range(3):  # warm the cache and the budget model
        loop.submit(rng.integers(0, 32, 8).astype(np.int32), qid=f"w{q}")
        loop.pump()
    loop.drain()
    before = loop.dispatcher.cache.compile_events
    rep = loop.apply_delta(delta)
    assert rep.same_shape
    s = rng.integers(0, 32, 8).astype(np.int32)
    loop.submit(s, qid="after")
    results = loop.drain()
    assert loop.dispatcher.cache.compile_events == before
    ref = np.stack([bfs_levels(csr2, int(x)) for x in s])
    np.testing.assert_array_equal(results["after"], ref)


# ---------------------------------------------------------------------------
# Satellite 5: random edit scripts vs the rebuild oracle
# ---------------------------------------------------------------------------

def _check_bundle_invariants(disp):
    """Structural invariants of every live host mirror: perm/inverse
    roundtrip and the refinement bound width <= 1.1 * in_degree."""
    from repro.core.extend import effective_csr

    eff = effective_csr(disp.csr, disp.max_deg)
    rev = eff.reverse()
    indeg = np.diff(rev.indptr)
    for bundle in disp._graphs.values():
        host = bundle.host
        if host is None or host.rev_binned is None:
            continue
        bn = host.rev_binned
        K = bn.perm.shape[0]
        rows_local = bn.inv.shape[-1]
        for k in range(K):
            filled = bn.perm[k][bn.perm[k] < rows_local]
            assert len(np.unique(filled)) == len(filled)
            np.testing.assert_array_equal(
                bn.perm[k][bn.inv[k]], np.arange(rows_local)
            )
        widths = [s.shape[-1] for s in bn.slabs]
        starts = np.cumsum([0] + [s.shape[1] for s in bn.slabs])
        for k in range(K):
            for b, w in enumerate(widths):
                rows = bn.perm[k][starts[b]:starts[b + 1]]
                for r in rows[rows < rows_local]:
                    g = k * rows_local + int(r)
                    if g >= eff.n_nodes:
                        continue
                    d = int(indeg[g])
                    if d == 0:
                        assert w == 0 or b == 0
                    else:
                        assert d <= w <= 1.1 * d + 1e-9, (k, b, g, d, w)


@pytest.mark.parametrize("seed", [1, 2])
def test_random_edit_scripts_vs_rebuild_oracle(seed):
    csr = _rand_csr(n=100, m=700, seed=seed)
    d = QueryDispatcher(mesh11(), csr, max_iters=32)
    cur = csr
    r = np.random.default_rng(seed)
    versions = [0]
    for step in range(6):
        kind = step % 4
        n = cur.n_nodes
        if kind == 0:  # mixed random edits (dup deletes included)
            delta = random_delta(
                cur, n_adds=int(r.integers(0, 15)),
                n_dels=int(r.integers(0, 15)),
                seed=int(r.integers(10**6)),
            )
        elif kind == 1:  # duplicate adds + self-loops
            v = r.integers(0, n, 4)
            delta = GraphDelta(
                add_src=np.concatenate([v, v]),
                add_dst=np.concatenate([v, v]),
            )
        elif kind == 2:  # zero a node's out-degree (nonzero -> zero)
            u = int(r.integers(0, n))
            s, t = cur.edge_list()
            mine = t[s == u]
            delta = GraphDelta(del_src=np.full(len(mine), u), del_dst=mine)
        else:  # pile 20 edges onto one target: bucket-boundary crossing
            t0 = int(r.integers(0, n))
            delta = GraphDelta(
                add_src=r.integers(0, n, 20), add_dst=np.full(20, t0)
            )
        rep = d.apply_delta(delta)
        versions.append(rep.version)
        cur = apply_delta_csr(cur, delta)
        _check_bundle_invariants(d)
        srcs = r.integers(0, n, 5).astype(np.int32)
        lv = _levels(d, srcs, backend="dopt")
        oracle = QueryDispatcher(mesh11(), cur, max_iters=32)
        np.testing.assert_array_equal(
            lv, _levels(oracle, srcs, backend="dopt"),
            err_msg=f"step {step} (kind {kind})",
        )
    assert versions == list(range(7))  # monotone operands_version


def test_edgeless_slab_round_trip():
    """[n, 0]-slab start: populate an edgeless graph by delta, query,
    then delete every edge again — parity with the rebuild at each stop."""
    rng = np.random.default_rng(5)
    empty = csr_from_edges(50, np.zeros(0, np.int64), np.zeros(0, np.int64))
    d = QueryDispatcher(mesh11(), empty, max_iters=16)
    src = np.array([3], np.int32)
    assert _levels(d, src, backend="dopt") is not None

    grow = GraphDelta(
        add_src=rng.integers(0, 50, 60), add_dst=rng.integers(0, 50, 60)
    )
    d.apply_delta(grow)
    cur = apply_delta_csr(empty, grow)
    assert cur.n_edges > 0
    oracle = QueryDispatcher(mesh11(), cur, max_iters=16)
    np.testing.assert_array_equal(
        _levels(d, src, backend="dopt"), _levels(oracle, src, backend="dopt")
    )

    s, t = cur.edge_list()
    shrink = GraphDelta(del_src=s, del_dst=t)
    d.apply_delta(shrink)
    back = apply_delta_csr(cur, shrink)
    assert back.n_edges == 0
    oracle2 = QueryDispatcher(mesh11(), back, max_iters=16)
    np.testing.assert_array_equal(
        _levels(d, src, backend="dopt"), _levels(oracle2, src, backend="dopt")
    )


# ---------------------------------------------------------------------------
# ISSUE 9 satellites: weighted edit scripts + learned-state delta fence
# ---------------------------------------------------------------------------

def _topk(disp, srcs, **kw):
    out = disp.query(srcs, query_kind="topk_paths", **kw)
    return np.asarray(out.result.state.dists)


@pytest.mark.parametrize("seed", [1, 2])
def test_weighted_edit_scripts_fold_vs_rebuild(seed):
    """Weighted folds are dirty-row-only but must still land every
    changed weight: random weighted edit scripts — including weight-ONLY
    churn, where each edge is deleted and re-inserted at a new weight so
    the structure keeps its exact shape and only the reweighted-row path
    of ``diff_effective`` fires — stay bit-identical to a from-scratch
    rebuild under a weight-sensitive query (top-k path distances)."""
    csr = _rand_csr(n=80, m=500, seed=seed, weighted=True)
    d = QueryDispatcher(mesh11(), csr, max_iters=64)
    cur = csr
    r = np.random.default_rng(seed + 100)
    for step in range(4):
        kind = step % 3
        n = cur.n_nodes
        if kind == 0:  # mixed weighted edits (random_delta draws weights)
            delta = random_delta(
                cur, n_adds=int(r.integers(1, 12)),
                n_dels=int(r.integers(0, 12)),
                seed=int(r.integers(10**6)),
            )
        elif kind == 1:  # weight-only churn: same edges, new weights
            s, t = cur.edge_list()
            pick = np.unique(r.integers(0, cur.n_edges, size=20))
            delta = GraphDelta(
                add_src=s[pick], add_dst=t[pick],
                del_src=s[pick], del_dst=t[pick],
                add_weights=r.uniform(0.1, 2.0, len(pick)).astype(
                    np.float32
                ),
            )
        else:  # weighted pile-on: bucket-boundary crossing
            t0 = int(r.integers(0, n))
            delta = GraphDelta(
                add_src=r.integers(0, n, 15), add_dst=np.full(15, t0),
                add_weights=r.uniform(0.1, 2.0, 15).astype(np.float32),
            )
        rep = d.apply_delta(delta)
        if kind == 1:
            # structure untouched: the fold must take the warm path and
            # still rewrite the reweighted rows
            assert rep.same_shape and rep.dirty_fwd_rows > 0
        cur = apply_delta_csr(cur, delta)
        srcs = r.integers(0, n, 3).astype(np.int32)
        oracle = QueryDispatcher(mesh11(), cur, max_iters=64)
        np.testing.assert_array_equal(
            _topk(d, srcs), _topk(oracle, srcs),
            err_msg=f"step {step} (kind {kind})",
        )


def test_delta_fence_resets_learned_state():
    """A graph delta re-buckets every source degree, so the online
    learners keyed to pre-delta buckets — budget-model windows, the
    global-p90 fallback, direction samples, refit thresholds — must be
    invalidated by ``apply_delta`` (cumulative mispredict telemetry is
    accounting, not bucket-keyed state, and survives)."""
    csr = powerlaw(160, 5.0, seed=0)
    d = QueryDispatcher(
        mesh11(), csr, max_iters=64, online_adapt=True, refit_every=2,
        backend="dopt", family="powerlaw",
    )
    rng = np.random.default_rng(2)
    for _q in range(4):
        d.query(rng.integers(0, 160, 6).astype(np.int32))
    assert len(d.budget_model) > 0 and d.budget_model.n_samples > 0
    assert d._dir_samples and d._iter_p90s
    d.refit_thresholds()
    assert d.direction_thresholds is not None
    observed_before = d.budget_model.mispredicts.observed

    rep = d.apply_delta(random_delta(csr, 10, 10, seed=5))
    assert rep.version == 1
    assert len(d.budget_model) == 0 and d.budget_model.n_samples == 0
    assert not d._dir_samples and not d._iter_p90s
    assert d.direction_thresholds is None
    assert d.budget_model.mispredicts.observed == observed_before

    # post-delta serving re-learns against the NEW bucketing
    d.query(rng.integers(0, 160, 6).astype(np.int32))
    assert d.budget_model.n_samples > 0


def test_delta_fence_keeps_pinned_thresholds():
    """Explicitly-provided thresholds are an operator pin, not learned
    state: ``apply_delta`` must leave them in place."""
    csr = powerlaw(160, 5.0, seed=0)
    trainer = QueryDispatcher(
        mesh11(), csr, max_iters=64, online_adapt=True, refit_every=2,
        backend="dopt", family="powerlaw",
    )
    rng = np.random.default_rng(3)
    for _q in range(3):
        trainer.query(rng.integers(0, 160, 6).astype(np.int32))
    pinned = trainer.refit_thresholds()
    assert pinned is not None

    d = QueryDispatcher(
        mesh11(), csr, max_iters=64, online_adapt=True,
        direction_thresholds=pinned, backend="dopt", family="powerlaw",
    )
    d.query(rng.integers(0, 160, 6).astype(np.int32))
    d.apply_delta(random_delta(csr, 10, 10, seed=6))
    assert d.direction_thresholds is pinned
