import os
import sys

# Tests must see exactly ONE device (the dry-run alone forces 512); keep any
# inherited XLA_FLAGS from leaking a device-count override into tests.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.dirname(__file__))  # proptest/oracle importable


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: subprocess / multi-device / multi-minute tests excluded from "
        "the fast CI lane (scripts/ci.sh runs them only with --full)",
    )
