"""Substrate tests: optimizer, schedules, checkpoint, fault tolerance,
gradient compression, pipeline parallelism, data determinism."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from proptest import given, st_ints, st_seeds


def test_adamw_converges_quadratic():
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    opt = adamw_init(params, cfg)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2)
        )(p)
        p, o, _ = adamw_update(g, o, p, cfg)
        return p, o, loss

    for _ in range(300):
        params, opt, loss = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), target, atol=1e-2)


def test_adamw_bf16_moments():
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    params = {"w": jnp.ones(4)}
    cfg = AdamWConfig(lr=0.01, moment_dtype=jnp.bfloat16)
    opt = adamw_init(params, cfg)
    assert opt.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4)}
    p, o, _ = adamw_update(g, opt, params, cfg)
    assert o.mu["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(p["w"]).all())


def test_schedules():
    from repro.optim.schedules import cosine_schedule, wsd_schedule

    cos = cosine_schedule(warmup=10, total=100)
    assert float(cos(0)) == 0.0
    assert abs(float(cos(10)) - 1.0) < 1e-5
    assert float(cos(100)) <= 0.11
    wsd = wsd_schedule(warmup=10, total=100, decay_frac=0.2)
    assert abs(float(wsd(50)) - 1.0) < 1e-6  # stable plateau
    assert abs(float(wsd(79)) - 1.0) < 1e-6
    assert float(wsd(100)) < 0.02  # decayed
    # monotone decay in the decay phase
    vals = [float(wsd(s)) for s in range(80, 101)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones(4, jnp.bfloat16), "d": jnp.int32(7)},
    }
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    mgr.save(10, tree)
    mgr.save(20, jax.tree.map(lambda x: x * 2, tree))
    mgr.save(30, jax.tree.map(lambda x: x * 3, tree))
    mgr.wait()
    assert mgr.all_steps() == [20, 30]  # pruned to keep=2
    restored, step = mgr.restore(tree)
    assert step == 30
    np.testing.assert_allclose(
        np.asarray(restored["a"], np.float32),
        np.asarray(tree["a"]) * 3,
    )
    assert restored["b"]["c"].dtype == jnp.bfloat16
    restored20, _ = mgr.restore(tree, step=20)
    np.testing.assert_allclose(
        np.asarray(restored20["a"]), np.asarray(tree["a"]) * 2
    )


def test_train_guard_recovers_from_failures(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.runtime.fault_tolerance import StragglerDetector, TrainGuard

    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    failures = {7: 2}  # step 7 fails twice, then succeeds

    def step_fn(state, step):
        if failures.get(step, 0) > 0:
            failures[step] -= 1
            raise RuntimeError("simulated node failure")
        return {"x": state["x"] + 1}

    guard = TrainGuard(ckpt=mgr, save_every=2, max_retries=5,
                       detector=StragglerDetector())
    state, step = guard.run({"x": jnp.int32(0)}, step_fn, n_steps=10)
    assert step == 10
    assert int(state["x"]) == 10  # every increment applied exactly once


def test_straggler_detector():
    from repro.runtime.fault_tolerance import StragglerDetector

    det = StragglerDetector(warmup=3, threshold=2.0)
    for s in range(20):
        det.observe(s, 1.0 + 0.01 * (s % 3))
    assert det.incidents == []
    det.observe(20, 5.0)
    assert len(det.incidents) == 1
    # ewma must not absorb the straggler sample
    assert det.ewma < 1.5


def test_straggler_warmup_seeds_first_sample_once():
    from repro.runtime.fault_tolerance import StragglerDetector

    # constant step time through warmup: the EWMA must equal it EXACTLY.
    # Seeding from the first sample and then EWMA-ing that same sample
    # (the old bug) leaves ewma == dt only by luck of the constant input,
    # so also check an increasing ramp against the hand-rolled recurrence.
    det = StragglerDetector(warmup=4, threshold=2.0, alpha=0.25)
    for s in range(4):
        det.observe(s, 2.0)
    assert det.ewma == 2.0

    det2 = StragglerDetector(warmup=4, threshold=2.0, alpha=0.25)
    ref = None
    for s, dt in enumerate([1.0, 1.2, 1.4, 1.6]):
        det2.observe(s, dt)
        ref = dt if ref is None else 0.75 * ref + 0.25 * dt
    assert det2.ewma == pytest.approx(ref)
    # no incident can fire during warmup, however wild the sample
    det3 = StragglerDetector(warmup=3, threshold=2.0)
    for s, dt in enumerate([1.0, 50.0, 1.0]):
        assert det3.observe(s, dt) is False
    assert det3.incidents == []


def test_straggler_ewma_adapts_to_persistent_slow_regime():
    from repro.runtime.fault_tolerance import StragglerDetector

    # a permanent 10x slowdown must flag when it starts, then the
    # clamped update lets the baseline converge to the new normal and
    # the flagging STOPS — the old unclamped-skip behavior froze the
    # EWMA at the fast regime and flagged every later step forever
    det = StragglerDetector(warmup=3, threshold=2.0, alpha=0.2)
    for s in range(10):
        det.observe(s, 1.0)
    flags = [det.observe(10 + i, 10.0) for i in range(60)]
    assert flags[0] is True
    assert not all(flags), "EWMA never adapted to the persistent regime"
    tail = flags[-10:]
    assert not any(tail), "still flagging after convergence"
    assert det.ewma == pytest.approx(10.0, rel=0.05)
    # and a genuine outlier on top of the NEW baseline still flags
    assert det.observe(99, 25.0) is True


def test_compression_error_feedback():
    from repro.optim.compression import (
        compress_grads,
        compression_init,
        decompress_grads,
    )

    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
    state = compression_init(g)
    # accumulated dequantized grads over steps ≈ accumulated true grads
    # (error feedback property)
    acc_true = np.zeros(1000)
    acc_deq = np.zeros(1000)
    for step in range(50):
        gs = {"w": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
        qs, scales, state = compress_grads(gs, state)
        deq = decompress_grads(qs, scales)
        acc_true += np.asarray(gs["w"])
        acc_deq += np.asarray(deq["w"])
    # residual bounds the drift: accumulated error == final residual
    drift = np.abs(acc_true - acc_deq).max()
    res = np.abs(np.asarray(state.residual["w"])).max()
    np.testing.assert_allclose(drift, res, rtol=1e-3, atol=1e-4)
    assert drift < 0.2  # one quantization step's worth, not 50


@given(st_seeds(), st_ints(1, 5), cases=4)
def test_data_determinism(seed, step):
    from repro.data.pipeline import RecsysStream, TokenStream

    ts = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=seed)
    b1, b2 = ts.batch(step), ts.batch(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 100).all()
    # shards partition the work deterministically
    sh0 = TokenStream(100, 16, 8, seed=seed, shard=0, n_shards=2).batch(step)
    sh1 = TokenStream(100, 16, 8, seed=seed, shard=1, n_shards=2).batch(step)
    assert sh0["tokens"].shape == (4, 16)
    assert not np.array_equal(sh0["tokens"], sh1["tokens"])
    rs = RecsysStream(field_vocabs=(50, 60), global_batch=16, seed=seed)
    rb = rs.batch(step)
    assert rb["sparse"][:, 0].max() < 50 and rb["sparse"][:, 1].max() < 60


PIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("pipe",))
S, M, D = 4, 6, 8
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)
xs = jnp.asarray(rng.standard_normal((M, D)), jnp.float32)

def stage_fn(W, x):
    return jnp.tanh(x @ W)

out = pipeline_apply(mesh, {"W": Ws}, xs, lambda p, x: stage_fn(p["W"], x))
# serial oracle
ref = xs
for s in range(S):
    ref = jnp.tanh(ref @ Ws[s])
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err
print("PIPE_OK")
"""


import pytest


@pytest.mark.slow
def test_pipeline_parallel_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", PIPE_SCRIPT],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPE_OK" in r.stdout
