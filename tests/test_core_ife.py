"""IFE engine vs numpy oracles + engine invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

from proptest import given, st_ints, st_seeds
from oracle import bfs_levels, sssp

from repro.graph.csr import csr_from_edges, ell_from_csr
from repro.graph.generators import erdos_renyi, powerlaw, rmat
from repro.core.ife import (
    run_ife,
    run_ife_batch,
    run_ife_scan,
    histogram_lengths,
    reconstruct_paths,
    validate_parents,
)
from repro.core.edge_compute import NO_PARENT


def small_graph(seed=0, n=64, deg=4.0):
    return erdos_renyi(n, deg, seed=seed)


def test_sp_lengths_matches_oracle():
    csr = small_graph()
    g = ell_from_csr(csr)
    res = run_ife(g, jnp.array([0]), "sp_lengths")
    expect = bfs_levels(csr, [0])
    np.testing.assert_array_equal(np.asarray(res.state.levels), expect)


def test_multi_seed_query_frontier():
    # several sources seeding ONE shared frontier (a multi-source *query*)
    csr = small_graph(seed=3)
    g = ell_from_csr(csr)
    srcs = jnp.array([0, 5, 9])
    res = run_ife(g, srcs, "sp_lengths")
    expect = bfs_levels(csr, [0, 5, 9])
    np.testing.assert_array_equal(np.asarray(res.state.levels), expect)


@given(st_seeds(), st_ints(16, 200), st_ints(1, 8))
def test_prop_sp_lengths_oracle(seed, n, deg):
    csr = erdos_renyi(n, float(deg), seed=seed)
    g = ell_from_csr(csr)
    src = seed % n
    res = run_ife(g, jnp.array([src]), "sp_lengths")
    np.testing.assert_array_equal(
        np.asarray(res.state.levels), bfs_levels(csr, [src])
    )


@given(st_seeds(), st_ints(16, 128))
def test_prop_powerlaw_and_rmat(seed, n):
    for csr in (powerlaw(n, 4.0, seed=seed), rmat(6, 4, seed=seed)):
        g = ell_from_csr(csr)
        src = seed % csr.n_nodes
        res = run_ife(g, jnp.array([src]), "bfs_levels")
        np.testing.assert_array_equal(
            np.asarray(res.state.levels), bfs_levels(csr, [src])
        )


def test_sp_parents_valid_and_levels_match():
    csr = small_graph(seed=7, n=128, deg=3.0)
    g = ell_from_csr(csr)
    res = run_ife(g, jnp.array([1]), "sp_parents")
    st = res.state
    np.testing.assert_array_equal(
        np.asarray(st.levels), bfs_levels(csr, [1])
    )
    assert bool(validate_parents(st.levels, st.parents, jnp.array([1])))


def test_reconstruct_paths():
    csr = small_graph(seed=11, n=96, deg=3.0)
    g = ell_from_csr(csr)
    res = run_ife(g, jnp.array([2]), "sp_parents")
    st = res.state
    levels = np.asarray(st.levels)
    reach = np.nonzero(levels > 0)[0]
    if len(reach) == 0:
        pytest.skip("degenerate graph")
    dests = jnp.asarray(reach[:8].astype(np.int32))
    paths = np.asarray(reconstruct_paths(st.parents, dests, max_len=32))
    for row, d in zip(paths, reach[:8]):
        # path walks d -> source with strictly decreasing levels
        nodes = row[row >= 0]
        assert nodes[0] == d
        assert levels[nodes[-1]] == 0
        assert all(
            levels[a] == levels[b] + 1 for a, b in zip(nodes[:-1], nodes[1:])
        )


def test_bellman_ford_matches_dijkstra():
    rng = np.random.default_rng(0)
    csr = small_graph(seed=5, n=80, deg=4.0)
    csr = type(csr)(
        indptr=csr.indptr,
        indices=csr.indices,
        weights=rng.uniform(0.1, 2.0, size=csr.n_edges).astype(np.float32),
    )
    g = ell_from_csr(csr)
    res = run_ife(g, jnp.array([0]), "bellman_ford")
    expect = sssp(csr, [0])
    np.testing.assert_allclose(
        np.asarray(res.state.dist), expect, rtol=1e-5, atol=1e-5
    )


def test_batch_and_scan_match_single():
    csr = small_graph(seed=9, n=100, deg=4.0)
    g = ell_from_csr(csr)
    srcs = jnp.array([3, 17, 42, 77])
    b = run_ife_batch(g, srcs, "sp_lengths")
    s = run_ife_scan(g, srcs, "sp_lengths")
    for i, src in enumerate(srcs):
        single = run_ife(g, src[None], "sp_lengths")
        np.testing.assert_array_equal(
            np.asarray(b.state.levels[i]), np.asarray(single.state.levels)
        )
        np.testing.assert_array_equal(
            np.asarray(s.state.levels[i]), np.asarray(single.state.levels)
        )


def test_histogram_lengths():
    levels = jnp.array([-1, 0, 1, 1, 2, 5])
    h = np.asarray(histogram_lengths(levels, max_len=8))
    assert h[0] == 1 and h[1] == 2 and h[2] == 1 and h[5] == 1
    assert h.sum() == 5


def test_max_iters_caps_iterations():
    csr = small_graph(seed=13)
    g = ell_from_csr(csr)
    res = run_ife(g, jnp.array([0]), "sp_lengths", max_iters=2)
    assert int(res.iterations) <= 2
    assert int((np.asarray(res.state.levels) > 2).sum()) == 0


def test_invariants_monotone_visited():
    # visited only grows; frontier ⊆ visited at every step — checked via a
    # manual unrolled loop mirroring run_ife.
    from repro.core.edge_compute import EDGE_COMPUTES

    csr = small_graph(seed=21)
    g = ell_from_csr(csr)
    ec = EDGE_COMPUTES["sp_lengths"]
    state = ec.init(g.n_nodes, jnp.array([0]))
    prev_visited = np.asarray(state.visited)
    for it in range(10):
        contribution = ec.local_extend(g, state)
        state = ec.apply(state, contribution, jnp.int32(it))
        vis = np.asarray(state.visited)
        assert (vis | prev_visited == vis).all()  # monotone
        assert (np.asarray(state.frontier) & ~vis).sum() == 0  # frontier⊆visited
        prev_visited = vis
