"""Pallas kernel validation: interpret-mode vs pure-jnp oracles, with
shape/dtype sweeps per kernel (the per-kernel allclose requirement)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from proptest import given, st_ints, st_seeds

from repro.graph.csr import blocks_from_csr
from repro.graph.generators import erdos_renyi, powerlaw
from repro.kernels.msbfs_extend.ops import (
    kernel_blocks_from_csr,
    msbfs_extend,
)
from repro.kernels.msbfs_extend.ref import msbfs_extend_ref
from repro.kernels.block_spmm.ops import spmm, spmm_blocks_from_csr
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------- msbfs ----

@pytest.mark.parametrize("block", [64, 128])
@pytest.mark.parametrize("lanes", [32, 64])
def test_msbfs_extend_shapes(block, lanes):
    csr = erdos_renyi(300, 5.0, seed=0)
    n_pad = -(-csr.n_nodes // block) * block
    kb = kernel_blocks_from_csr(csr, block=block)
    rng = np.random.default_rng(1)
    f = (rng.random((n_pad, lanes)) < 0.05).astype(np.uint8)
    f[csr.n_nodes :] = 0
    got = np.asarray(msbfs_extend(kb, jnp.asarray(f)))
    B = block
    ref = np.asarray(
        msbfs_extend_ref(
            kb.blocks, kb.block_rows, kb.block_cols,
            jnp.asarray(f.reshape(-1, B, lanes)),
        )
    )
    ref = (ref > 0).astype(np.uint8).reshape(n_pad, lanes)
    np.testing.assert_array_equal(got, ref)


@given(st_seeds(), st_ints(100, 500), st_ints(2, 10), cases=6)
def test_prop_msbfs_kernel_vs_engine(seed, n, deg):
    """Kernel extension == pure-ELL engine extension on random graphs."""
    from repro.graph.csr import ell_from_csr
    from repro.graph.partition import pad_ell
    from repro.core.edge_compute import ell_reach_lanes
    from repro.core.frontier import lanes_from_sources

    csr = powerlaw(n, float(deg), seed=seed)
    block = 128
    n_pad = -(-csr.n_nodes // block) * block
    kb = kernel_blocks_from_csr(csr, block=block)
    g = pad_ell(ell_from_csr(csr), shards=1, block=block)
    rng = np.random.default_rng(seed)
    srcs = rng.integers(0, csr.n_nodes, size=64).astype(np.int32)
    lanes = lanes_from_sources(n_pad, jnp.asarray(srcs))
    ref = np.asarray(ell_reach_lanes(g, lanes))
    got = np.asarray(msbfs_extend(kb, lanes))
    np.testing.assert_array_equal(got, ref)


def test_msbfs_full_bfs_through_kernel():
    """Run complete MS-BFS iterations with the kernel and compare levels."""
    from oracle import bfs_levels

    csr = erdos_renyi(260, 4.0, seed=7)
    block = 128
    n_pad = -(-csr.n_nodes // block) * block
    kb = kernel_blocks_from_csr(csr, block=block)
    srcs = np.array([3, 77, 150], np.int32)
    L = 64
    f = np.zeros((n_pad, L), np.uint8)
    lv = np.full((n_pad, L), 255, np.uint8)
    for l, s in enumerate(srcs):
        f[s, l] = 1
        lv[s, l] = 0
    visited = f.copy()
    f, lv, visited = jnp.asarray(f), jnp.asarray(lv), jnp.asarray(visited)
    for it in range(n_pad):
        reached = msbfs_extend(kb, f)
        new = reached & ~visited
        if not bool(jnp.any(new)):
            break
        visited = visited | new
        lv = jnp.where(new != 0, jnp.uint8(it + 1), lv)
        f = new
    lv = np.asarray(lv)
    for l, s in enumerate(srcs):
        exp = bfs_levels(csr, [s])
        got = lv[: csr.n_nodes, l].astype(np.int32)
        got[got == 255] = -1
        np.testing.assert_array_equal(got, exp)


def test_msbfs_extend_sparse_frontier_activity_skip():
    """Frontier active in ONE row-block stripe: the activity-skip kernel
    (inactive blocks gated by pl.when + DMA-elided via the cummax select
    index) must still match the dense reference exactly."""
    csr = erdos_renyi(400, 6.0, seed=9)
    block = 128
    n_pad = -(-csr.n_nodes // block) * block
    kb = kernel_blocks_from_csr(csr, block=block)
    f = np.zeros((n_pad, 64), np.uint8)
    f[5:40, :7] = 1  # only stripe 0 is active
    got = np.asarray(msbfs_extend(kb, jnp.asarray(f)))
    ref = np.asarray(msbfs_extend(kb, jnp.asarray(f), use_ref=True))
    np.testing.assert_array_equal(got, ref)

    # all-zero frontier: every block inactive, output must be all zeros
    # (output tiles still initialize on first visit)
    z = np.zeros((n_pad, 64), np.uint8)
    out = np.asarray(msbfs_extend(kb, jnp.asarray(z)))
    assert (out == 0).all()


def test_msbfs_block_activity_counter():
    """core.msbfs.active_block_count == the numpy count of materialized
    blocks whose source stripe holds a frontier bit."""
    from repro.core.msbfs import active_block_count, block_extend_lanes

    csr = powerlaw(300, 4.0, seed=3)
    block = 64
    n_pad = -(-csr.n_nodes // block) * block
    adj = blocks_from_csr(csr, block=block)
    rng = np.random.default_rng(0)
    f = np.zeros((n_pad, 8), np.uint8)
    f[rng.integers(0, csr.n_nodes, 5), 0] = 1
    stripe = f.reshape(-1, block, 8).any(axis=(1, 2))
    expect = int(stripe[np.asarray(adj.block_rows)].sum())
    got = int(active_block_count(adj, jnp.asarray(f)))
    assert got == expect
    # masking inactive stripes must not change the extension result
    from repro.core.edge_compute import ell_reach_lanes
    from repro.graph.csr import ell_from_csr
    from repro.graph.partition import pad_ell

    g = pad_ell(ell_from_csr(csr), shards=1, block=block)
    ref = np.asarray(ell_reach_lanes(g, jnp.asarray(f)))
    out = np.asarray(block_extend_lanes(adj, jnp.asarray(f)))
    np.testing.assert_array_equal(out, ref)


# ----------------------------------------------------------------- spmm ----

@pytest.mark.parametrize("block,feat", [(128, 128), (128, 256), (64, 128)])
def test_block_spmm_shapes(block, feat):
    csr = erdos_renyi(300, 6.0, seed=2)
    n_pad = -(-csr.n_nodes // block) * block
    sb = spmm_blocks_from_csr(csr, block=block)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((n_pad, feat)).astype(np.float32)
    x[csr.n_nodes :] = 0
    got = np.asarray(spmm(sb, jnp.asarray(x)))
    ref = np.asarray(spmm(sb, jnp.asarray(x), use_ref=True))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_block_spmm_vs_segment_sum():
    csr = erdos_renyi(200, 5.0, seed=4)
    block = 64
    n_pad = -(-csr.n_nodes // block) * block
    sb = spmm_blocks_from_csr(csr, block=block)
    rng = np.random.default_rng(5)
    x = rng.standard_normal((n_pad, 32 * 4)).astype(np.float32)
    x[csr.n_nodes :] = 0
    got = np.asarray(spmm(sb, jnp.asarray(x)))[: csr.n_nodes]
    src, dst = csr.edge_list()
    expect = np.zeros((csr.n_nodes, x.shape[1]), np.float32)
    np.add.at(expect, dst, x[src])
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-4)


def test_block_spmm_normalization():
    csr = erdos_renyi(150, 4.0, seed=6)
    block = 64
    n_pad = -(-csr.n_nodes // block) * block
    sb = spmm_blocks_from_csr(csr, block=block, normalize="mean")
    x = np.ones((n_pad, 64), np.float32)
    x[csr.n_nodes :] = 0
    got = np.asarray(spmm(sb, jnp.asarray(x)))[: csr.n_nodes]
    # mean-normalized aggregation of ones = 1 wherever in-degree > 0
    src, dst = csr.edge_list()
    has_in = np.zeros(csr.n_nodes, bool)
    has_in[dst] = True
    np.testing.assert_allclose(
        got[has_in], np.ones_like(got[has_in]), rtol=1e-4
    )


# ------------------------------------------------------------ attention ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(1, 2, 256, 64), (2, 1, 384, 128)])
def test_flash_attention_sweep(dtype, causal, shape):
    B, H, S, D = shape
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.standard_normal(shape), dtype)
    k = jnp.asarray(rng.standard_normal(shape), dtype)
    v = jnp.asarray(rng.standard_normal(shape), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        rtol=tol,
        atol=tol,
    )


def test_flash_attention_block_sizes():
    rng = np.random.default_rng(9)
    shape = (1, 2, 512, 64)
    q, k, v = (
        jnp.asarray(rng.standard_normal(shape), jnp.float32) for _ in range(3)
    )
    ref = attention_ref(q, k, v, causal=True)
    for bq, bk in [(128, 256), (256, 128), (512, 512)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5
        )
