"""Extension-backend parity corpus (ISSUE 2 + ISSUE 3 acceptance).

ell_push / ell_pull / pull_binned / pull_binned_fused / block_mxu and the
direction-optimized switch flavors must produce bit-identical final states
vs the numpy oracle
and vs each other, across ER and power-law graphs — including a pathological
heavy-tail fixture (one node with in-degree ≈ n) and graphs with
zero-in-degree / isolated nodes — all dense edge computes, the msbfs lane
computes, and both engine state layouts; plus operand-construction,
degree-binned slab pack/unpack + permutation-inverse, and frontier
pack/unpack invariants.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from proptest import given, st_ints, st_sampled, st_seeds
from oracle import bfs_levels

from repro.graph.csr import (
    CSRGraph,
    binned_rev_csr,
    csr_from_edges,
    ell_from_csr,
    truncate_csr,
)
from repro.graph.generators import erdos_renyi, powerlaw
from repro.core import (
    build_operands,
    policy_ntks,
    policy_ntkms,
    recommend_backend,
    run_recursive_query,
)
from repro.core.extend import ExtendSpec, GraphOperands, as_spec
from repro.core.ife import run_ife
from repro.launch.mesh import make_mesh

BACKENDS = ["ell_push", "ell_pull", "pull_binned", "pull_binned_fused",
            "block_mxu", "dopt", "dopt_ell"]
DENSE_ECS = ["sp_lengths", "sp_parents", "bellman_ford", "reachability"]


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def full_operands(csr, block=128):
    """One bundle carrying every operand at a common pad so final states
    are comparable bitwise across backends (engines strip what they don't
    scan)."""
    pull, n1 = build_operands(csr, "dopt_ell", block=block)
    binned, n3 = build_operands(csr, "pull_binned_fused", block=block)
    blk, n2 = build_operands(
        csr, ExtendSpec(backend="block_mxu", block=block), block=block
    )
    assert n1 == n2 == n3
    return (
        GraphOperands(
            fwd=pull.fwd,
            rev=pull.rev,
            rev_binned=binned.rev_binned,
            rev_binned_pack=binned.rev_binned_pack,
            blocks=blk.blocks,
        ),
        n1,
    )


def heavy_tail_csr(n: int, seed: int = 0) -> CSRGraph:
    """Pathological skew fixture: a hub with in-degree ≈ n (every other
    node points at it), a thin ring so BFS needs several hops, the hub
    fanning back out to a few nodes, and trailing isolated nodes with
    zero in- AND out-degree."""
    rng = np.random.default_rng(seed)
    live = n - max(n // 8, 1)  # the tail stays fully isolated
    hub = 0
    srcs = []
    dsts = []
    for v in range(1, live):
        srcs.append(v)  # v -> hub: rev degree of hub ≈ n
        dsts.append(hub)
        srcs.append(v)  # ring: v -> v+1
        dsts.append(1 + (v % (live - 1)))
    out_fan = rng.choice(np.arange(1, live), size=min(4, live - 1),
                         replace=False)
    for d in out_fan:
        srcs.append(hub)
        dsts.append(int(d))
    return csr_from_edges(n, np.asarray(srcs), np.asarray(dsts))


def assert_states_equal(a, b, msg=""):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb), msg
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=msg)


@given(st_seeds(), st_ints(48, 160), st_sampled(["er", "pl"]), cases=4)
def test_prop_backend_parity_all_dense_edge_computes(seed, n, kind):
    rng = np.random.default_rng(seed)
    csr = (
        erdos_renyi(n, 5.0, seed=seed)
        if kind == "er"
        else powerlaw(n, 4.0, seed=seed)
    )
    csr_w = CSRGraph(
        indptr=csr.indptr,
        indices=csr.indices,
        weights=rng.uniform(0.1, 2.0, csr.n_edges).astype(np.float32),
    )
    ops, n_pad = full_operands(csr)
    ops_w, _ = full_operands(csr_w)
    srcs = jnp.asarray(
        rng.integers(0, csr.n_nodes, size=2).astype(np.int32)
    )
    for ec in DENSE_ECS:
        use = ops_w if ec == "bellman_ford" else ops
        ref = run_ife(use, srcs, ec, extend="ell_push")
        if ec in ("sp_lengths",):
            exp = bfs_levels(csr, np.asarray(srcs))
            np.testing.assert_array_equal(
                np.asarray(ref.state.levels)[: csr.n_nodes], exp
            )
        for be in BACKENDS[1:]:
            got = run_ife(use, srcs, ec, extend=be)
            assert_states_equal(ref.state, got.state, f"{ec}/{be}")


@given(st_seeds(), st_ints(64, 200), cases=3)
def test_prop_backend_parity_msbfs(seed, n):
    csr = powerlaw(n, 4.0, seed=seed)
    ops, n_pad = full_operands(csr)
    rng = np.random.default_rng(seed)
    srcs = jnp.asarray(rng.integers(0, n, size=8).astype(np.int32))
    for ec in ("msbfs_lengths", "msbfs_parents"):
        ref = run_ife(ops, srcs, ec, extend="ell_push")
        for be in BACKENDS[1:]:
            got = run_ife(ops, srcs, ec, extend=be)
            assert_states_equal(ref.state, got.state, f"{ec}/{be}")


# ---------------------------------------------------------------------------
# Heavy-tail + degenerate-graph corpus (ISSUE 3): the fixtures that punish
# the padded reverse slab are exactly where binned pull must stay
# bit-identical.
# ---------------------------------------------------------------------------


def test_heavy_tail_hub_parity_all_edge_computes():
    """One node with in-degree ≈ n: the padded reverse ELL pays n·max ≈ n²
    here; the binned layout isolates the hub in its own slab. Parity must
    hold for every edge compute, dense and lanes."""
    csr = heavy_tail_csr(96, seed=5)
    rev_deg = csr.reverse().degrees
    assert rev_deg.max() >= 0.8 * (csr.n_nodes - csr.n_nodes // 8)
    rng = np.random.default_rng(1)
    csr_w = CSRGraph(
        indptr=csr.indptr,
        indices=csr.indices,
        weights=rng.uniform(0.1, 2.0, csr.n_edges).astype(np.float32),
    )
    ops, _ = full_operands(csr)
    ops_w, _ = full_operands(csr_w)
    srcs = jnp.array([1, 7], jnp.int32)
    for ec in DENSE_ECS + ["msbfs_lengths", "msbfs_parents"]:
        use = ops_w if ec == "bellman_ford" else ops
        ref = run_ife(use, srcs, ec, extend="ell_push")
        for be in BACKENDS[1:]:
            got = run_ife(use, srcs, ec, extend=be)
            assert_states_equal(ref.state, got.state, f"{ec}/{be}")
    # the hub's slab really is its own bucket: widths strictly separate
    # the hub from the degree-1 mass
    bn = ops.rev_binned
    w = bn.row_widths()[0]
    assert w.max() >= int(rev_deg.max())
    assert (w[w > 0].min()) <= 2


def test_zero_in_degree_and_isolated_nodes_parity():
    """Zero-in-degree rows (sources of a DAG) and fully isolated nodes land
    in the zero-width slab and must neither contribute nor corrupt
    placement."""
    # star out of node 0 only: every other node has in-degree <= 1, node 0
    # has in-degree 0; nodes [n-8, n) are fully isolated
    n = 72
    srcs_e = np.arange(1, n - 8)
    csr = csr_from_edges(n, np.zeros_like(srcs_e), srcs_e)
    ops, n_pad = full_operands(csr)
    bn = ops.rev_binned
    w = bn.row_widths()[0]
    assert w[0] == 0  # root: zero in-degree => zero-width slab
    assert (w[n - 8 : n] == 0).all()  # isolated tail
    for ec in ("sp_lengths", "sp_parents", "reachability"):
        ref = run_ife(ops, jnp.array([0]), ec, extend="ell_push")
        for be in BACKENDS[1:]:
            got = run_ife(ops, jnp.array([0]), ec, extend=be)
            assert_states_equal(ref.state, got.state, f"{ec}/{be}")


def test_truncation_emptied_rows_zero_width_slab():
    """Regression (latent ell_from_csr/truncate_csr edge case the binning
    exposed): a degree cap of 0 — or an edgeless graph — must produce a
    genuine zero-width ELL/slab, not a 1-wide (8-padded) row whose slots
    every backend would scan forever. The historical ``max_deg or 1``
    coercion silently turned an explicit 0 into width 8."""
    csr = erdos_renyi(64, 3.0, seed=2)
    # truncate away every edge, then convert: zero-width, zero-degree
    eff = truncate_csr(csr, 0)
    assert eff.n_edges == 0
    g = ell_from_csr(eff)
    assert g.indices.shape == (64, 0)
    assert int(np.asarray(g.degrees).sum()) == 0
    # explicit max_deg=0 on a graph WITH edges: same contract
    g0 = ell_from_csr(csr, max_deg=0)
    assert g0.indices.shape == (64, 0)
    # an edgeless graph's binned reverse: single zero-width slab, zero
    # capacity — scanning it costs nothing
    bn = binned_rev_csr(eff, 64, shards=1)
    assert bn.widths == (0,)
    assert bn.capacity_slots == 0
    # and the full pipeline still converges under EVERY backend flavor
    # that can scan a zero-width layout (sources never spread) — including
    # the min-reduction edge computes, whose jnp reductions have no
    # identity over a size-0 axis and need explicit width-0 guards
    for be in ("ell_push", "ell_pull", "pull_binned", "pull_binned_fused",
               "dopt", "dopt_ell"):
        ops, n_pad = build_operands(eff, be)
        for ec in ("sp_lengths", "sp_parents", "bellman_ford",
                   "msbfs_parents"):
            res = run_ife(ops, jnp.array([3]), ec, extend=be)
            if hasattr(res.state, "levels"):
                lv = np.asarray(res.state.levels)[:64]
                lv = lv.reshape(64, -1)[:, 0].astype(np.int64)
                assert lv[3] == 0, (be, ec)  # the source itself
                assert (np.delete(lv, 3) != 0).all(), (be, ec)  # nobody else
            else:  # bellman_ford: only the source is at finite distance
                d = np.asarray(res.state.dist)[:64]
                assert d[3] == 0 and np.isinf(np.delete(d, 3)).all(), be
    # nonzero cap above the max degree keeps the historical pad-to-8 width
    g8 = ell_from_csr(csr, max_deg=3)
    assert g8.indices.shape[1] == 8


@given(st_seeds(), st_ints(24, 140), st_sampled(["er", "pl", "hub"]),
       cases=6)
def test_prop_binned_slab_pack_unpack_roundtrip(seed, n, kind):
    """Slab pack/unpack + permutation-inverse property: for random graphs
    (including the heavy-tail hub fixture), unpacking the binned slabs
    through the permutation recovers exactly the reverse adjacency of the
    truncated graph, perm/inv are mutually inverse over real rows, widths
    cover the true in-degrees, and total capacity respects the 1.1x
    overhead contract."""
    rng = np.random.default_rng(seed)
    if kind == "er":
        csr = erdos_renyi(n, 4.0, seed=seed)
    elif kind == "pl":
        csr = powerlaw(n, 4.0, seed=seed)
    else:
        csr = heavy_tail_csr(n, seed=seed)
    cap = None if seed % 2 else 4
    eff = truncate_csr(csr, cap)
    shards = 1 if seed % 3 else 2
    n_pad = -(-n // (shards * 8)) * (shards * 8)
    bn = binned_rev_csr(eff, n_pad, shards=shards)
    rows_local = n_pad // shards
    rev = eff.reverse()
    rev_deg = np.zeros(n_pad, np.int64)
    rev_deg[:n] = rev.degrees

    perm = np.asarray(bn.perm)
    inv = np.asarray(bn.inv)
    widths = bn.row_widths()
    # perm/inv inverse bijection over real rows, pad positions inert
    for k in range(shards):
        np.testing.assert_array_equal(
            perm[k][inv[k]], np.arange(rows_local)
        )
        pad_pos = np.setdiff1d(np.arange(perm.shape[1]), inv[k])
        assert (perm[k][pad_pos] == rows_local).all()
    # widths cover degrees within the overhead contract
    flat_w = widths.reshape(-1)
    assert (flat_w >= rev_deg).all()
    assert flat_w.sum() <= 1.1 * rev_deg.sum() + 1e-9
    assert bn.capacity_slots * shards >= flat_w.sum()  # count padding only adds

    # unpack: concatenated slab rows, un-permuted, reproduce the reverse
    # neighbor multisets exactly
    for k in range(shards):
        per_pos = []  # binned position -> that row's slab slots
        for s in bn.slabs:
            for r in range(s.shape[1]):
                per_pos.append(np.asarray(s[k, r]))
        for r in range(rows_local):
            g = k * rows_local + r
            got = per_pos[inv[k, r]]
            got = np.sort(got[got < n_pad])
            exp = np.sort(rev.indices[rev.indptr[g]:rev.indptr[g + 1]]) if (
                g < n
            ) else np.zeros(0, np.int32)
            np.testing.assert_array_equal(got, exp, err_msg=f"row {g}")


@pytest.mark.parametrize("state_layout", ["replicated", "sharded"])
def test_engine_backend_parity_both_layouts(state_layout):
    csr = powerlaw(150, 5.0, seed=3)
    n = csr.n_nodes
    mesh = mesh11()
    srcs = np.array([0, 11, 42], np.int32)
    expected = np.stack([bfs_levels(csr, [s]) for s in srcs])
    for be in BACKENDS:
        res = run_recursive_query(
            mesh, csr, srcs, policy_ntks(), "sp_lengths",
            state_layout=state_layout, extend=be,
        )
        got = np.asarray(res.state.levels)[: len(srcs), :n]
        np.testing.assert_array_equal(got, expected, err_msg=be)


@pytest.mark.parametrize(
    "state_layout",
    ["replicated", pytest.param("sharded", marks=pytest.mark.slow)],
)
def test_engine_heavy_tail_parity_both_layouts(state_layout):
    """The heavy-tail hub through the full shard_map engine path (the
    sharded heavy-tail case is the expensive one: every backend compiles
    its own scan program — fast lane keeps replicated only)."""
    csr = heavy_tail_csr(180, seed=11)
    n = csr.n_nodes
    mesh = mesh11()
    srcs = np.array([1, 9, 33], np.int32)
    expected = np.stack([bfs_levels(csr, [s]) for s in srcs])
    for be in BACKENDS:
        res = run_recursive_query(
            mesh, csr, srcs, policy_ntks(), "sp_lengths",
            state_layout=state_layout, extend=be,
        )
        got = np.asarray(res.state.levels)[: len(srcs), :n]
        np.testing.assert_array_equal(got, expected, err_msg=be)


def test_engine_backend_parity_lane_morsels():
    csr = erdos_renyi(140, 5.0, seed=9)
    n = csr.n_nodes
    mesh = mesh11()
    srcs = np.array([1, 7, 99], np.int32)
    ref = run_recursive_query(
        mesh, csr, srcs, policy_ntkms(), "msbfs_parents", extend="ell_push"
    )
    for be in BACKENDS[1:]:
        got = run_recursive_query(
            mesh, csr, srcs, policy_ntkms(), "msbfs_parents", extend=be
        )
        for fa, fb in zip(ref.state, got.state):
            np.testing.assert_array_equal(
                np.asarray(fa)[:, :n], np.asarray(fb)[:, :n], err_msg=be
            )


def test_scheduler_backend_selection_and_cache_keys():
    from repro.runtime.scheduler import AdaptiveScheduler

    csr = powerlaw(200, 5.0, seed=11)
    n = csr.n_nodes
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, phase1_iters=2)
    srcs = np.array([0, 17, 60], np.int32)
    ref = sched.query(srcs)  # scheduler default IS backend="recommend"
    n_engines = len(sched.cache)
    for be in ["ell_push", "ell_pull", "pull_binned", "pull_binned_fused",
               "block_mxu", "dopt", "recommend"]:
        out = sched.query(srcs, backend=be)
        np.testing.assert_array_equal(
            np.asarray(ref.result.state.levels)[:, :n],
            np.asarray(out.result.state.levels)[:, :n],
            err_msg=be,
        )
    # each distinct backend compiled its own engines under its own key ...
    assert len(sched.cache) > n_engines
    # ... and re-serving a backend is pure cache hits
    h0, m0 = sched.cache.hits, sched.cache.misses
    sched.query(srcs, backend="dopt")
    assert sched.cache.hits > h0 and sched.cache.misses == m0


def test_max_deg_truncation_consistent_across_backends():
    """Reverse/binned/block operands must be derived from the truncated
    forward graph, or pull would scan edges push cannot see."""
    csr = powerlaw(120, 6.0, seed=13)
    srcs = jnp.array([3])
    cap = 4
    ops_p, _ = build_operands(csr, "dopt_ell", max_deg=cap, block=128)
    ops_b, _ = build_operands(
        csr, "pull_binned_fused", max_deg=cap, block=128
    )
    blk_t, _ = build_operands(
        csr, ExtendSpec(backend="block_mxu"), max_deg=cap, block=128
    )
    ops_t = GraphOperands(
        fwd=ops_p.fwd, rev=ops_p.rev, rev_binned=ops_b.rev_binned,
        rev_binned_pack=ops_b.rev_binned_pack, blocks=blk_t.blocks,
    )
    ref = run_ife(ops_t, srcs, "sp_lengths", extend="ell_push")
    for be in BACKENDS[1:]:
        got = run_ife(ops_t, srcs, "sp_lengths", extend=be)
        assert_states_equal(ref.state, got.state, be)
    # and the effective graph really is capped
    eff = truncate_csr(csr, cap)
    assert int(eff.degrees.max()) <= cap
    assert eff.n_edges == int(np.minimum(csr.degrees, cap).sum())


@given(st_seeds(), st_ints(16, 120), st_ints(1, 9), cases=6)
def test_prop_ell_from_csr_vectorized_matches_loop(seed, n, deg):
    """The numpy-index ELL builder == the straightforward per-row loop,
    including weights and degree truncation."""
    rng = np.random.default_rng(seed)
    csr = erdos_renyi(n, float(deg), seed=seed)
    csr = CSRGraph(
        indptr=csr.indptr,
        indices=csr.indices,
        weights=rng.uniform(0.1, 1.0, csr.n_edges).astype(np.float32),
    )
    cap = None if seed % 2 else max(1, deg // 2)
    g = ell_from_csr(csr, max_deg=cap)
    # reference: the original interpreted loop
    degs = csr.degrees.astype(np.int32)
    width = g.indices.shape[1]
    ref_idx = np.full((n, width), n, np.int32)
    ref_w = np.zeros((n, width), np.float32)
    for v in range(n):
        d = min(int(degs[v]), width)
        lo = csr.indptr[v]
        ref_idx[v, :d] = csr.indices[lo : lo + d]
        ref_w[v, :d] = csr.weights[lo : lo + d]
    np.testing.assert_array_equal(np.asarray(g.indices), ref_idx)
    np.testing.assert_array_equal(np.asarray(g.weights), ref_w)
    np.testing.assert_array_equal(
        np.asarray(g.degrees), np.minimum(degs, width)
    )


@given(st_seeds(), st_ints(4, 64), cases=6)
def test_prop_pack_unpack_lanes_roundtrip(seed, n):
    from repro.core.frontier import LANES, pack_lanes, unpack_lanes

    rng = np.random.default_rng(seed)
    lanes = (rng.random((n, LANES)) < 0.3).astype(np.uint8)
    packed = pack_lanes(jnp.asarray(lanes))
    assert packed.shape == (n, LANES // 32) and packed.dtype == jnp.uint32
    back = unpack_lanes(packed)
    np.testing.assert_array_equal(np.asarray(back), lanes)
    repacked = pack_lanes(back)
    np.testing.assert_array_equal(np.asarray(repacked), np.asarray(packed))


def test_recommend_backend_rules():
    assert recommend_backend("bellman_ford", 300.0, n_nodes=1000) == "ell_push"
    assert (
        recommend_backend("msbfs_lengths", 300.0, n_nodes=1000, lanes=64)
        == "block_mxu"
    )
    # lane morsels on block-sparse (huge) graphs: stay direction-optimized
    # over the binned pull slabs (the post-binning default)
    assert (
        recommend_backend("msbfs_lengths", 8.0, n_nodes=10**7, lanes=64)
        == "dopt_binned"
    )
    assert recommend_backend("sp_lengths", 8.0, n_nodes=1000) == "dopt_binned"
    assert as_spec("dopt_binned").needs_binned
    assert not as_spec("dopt_binned").needs_rev


def test_block_operands_regroup_for_pad_shards():
    """prepare_graph(pad_shards=K) with K > the policy's own shard count
    must regroup the stacked block tiles (rebased local row-block ids) —
    the scheduler's shared-n_pad contract for the block backend."""
    from repro.core.dispatcher import (
        build_engine,
        pad_sources,
        prepare_graph,
    )

    csr = powerlaw(300, 5.0, seed=3)
    n = csr.n_nodes
    mesh = mesh11()
    spec = ExtendSpec(backend="block_mxu")
    pol = policy_ntks()
    g, n_pad = prepare_graph(csr, mesh, pol, pad_shards=4, extend=spec)
    assert n_pad % (4 * spec.block) == 0
    eng = build_engine(mesh, pol, "sp_lengths", n_pad, 64, extend=spec)
    srcs = np.array([0, 11, 42], np.int32)
    res = eng(g, jnp.asarray(pad_sources(srcs, 1, 1, n_pad)))
    expected = np.stack([bfs_levels(csr, [s]) for s in srcs])
    np.testing.assert_array_equal(
        np.asarray(res.state.levels)[:3, :n], expected
    )


def test_binned_operands_rebuild_for_pad_shards():
    """prepare_graph(pad_shards=K): binned slabs are re-binned at the
    policy's own shard count (per-shard binning can't just reshape) but on
    the SHARED n_pad — the scheduler's phase-1/phase-2 state-flow
    contract for the binned-pull backend."""
    from repro.core.dispatcher import (
        build_engine,
        pad_sources,
        prepare_graph,
    )

    csr = powerlaw(300, 5.0, seed=3)
    n = csr.n_nodes
    mesh = mesh11()
    spec = as_spec("pull_binned")
    pol = policy_ntks()
    g, n_pad = prepare_graph(csr, mesh, pol, pad_shards=4, extend=spec)
    assert n_pad % (4 * 32) == 0
    assert g.rev_binned is not None
    assert g.rev_binned.inv.shape == (1, n_pad)  # policy has 1 graph shard
    eng = build_engine(
        mesh, pol, "sp_lengths", n_pad, 64, extend=spec, operands=g
    )
    srcs = np.array([0, 11, 42], np.int32)
    res = eng(g, jnp.asarray(pad_sources(srcs, 1, 1, n_pad)))
    expected = np.stack([bfs_levels(csr, [s]) for s in srcs])
    np.testing.assert_array_equal(
        np.asarray(res.state.levels)[:3, :n], expected
    )


def test_extend_spec_validation_and_errors():
    with pytest.raises(ValueError):
        ExtendSpec(backend="nope")
    with pytest.raises(ValueError):
        ExtendSpec(direction="sometimes")
    with pytest.raises(ValueError):
        ExtendSpec(pull="bidirectional")
    with pytest.raises(ValueError):
        # auto IS the push/pull choice; pinning another backend with it
        # would otherwise be silently ignored
        ExtendSpec(backend="block_mxu", direction="auto")
    csr = erdos_renyi(64, 3.0, seed=1)
    ops, _ = build_operands(csr, "ell_push")
    with pytest.raises(ValueError):
        run_ife(ops, jnp.array([0]), "sp_lengths", extend="ell_pull")
    with pytest.raises(ValueError):
        run_ife(ops, jnp.array([0]), "sp_lengths", extend="pull_binned")
    with pytest.raises(ValueError):
        run_ife(ops, jnp.array([0]), "sp_lengths", extend="dopt")
    with pytest.raises(ValueError):
        run_ife(ops, jnp.array([0]), "sp_lengths", extend="block_mxu")
