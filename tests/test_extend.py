"""Extension-backend parity corpus (ISSUE 2 acceptance).

ell_push / ell_pull / block_mxu and the direction-optimized switch must
produce bit-identical final states vs the numpy oracle and vs each other,
across ER and power-law graphs, all dense edge computes, the msbfs lane
computes, and both engine state layouts; plus operand-construction and
frontier pack/unpack invariants.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from proptest import given, st_ints, st_sampled, st_seeds
from oracle import bfs_levels

from repro.graph.csr import CSRGraph, ell_from_csr, truncate_csr
from repro.graph.generators import erdos_renyi, powerlaw
from repro.core import (
    build_operands,
    policy_ntks,
    policy_ntkms,
    recommend_backend,
    run_recursive_query,
)
from repro.core.extend import ExtendSpec, GraphOperands, as_spec
from repro.core.ife import run_ife
from repro.launch.mesh import make_mesh

BACKENDS = ["ell_push", "ell_pull", "block_mxu", "dopt"]
DENSE_ECS = ["sp_lengths", "sp_parents", "bellman_ford", "reachability"]


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def full_operands(csr, block=128):
    """One bundle carrying every operand at a common pad so final states
    are comparable bitwise across backends (engines strip what they don't
    scan)."""
    pull, n1 = build_operands(csr, "dopt", block=block)
    blk, n2 = build_operands(
        csr, ExtendSpec(backend="block_mxu", block=block), block=block
    )
    assert n1 == n2
    return GraphOperands(fwd=pull.fwd, rev=pull.rev, blocks=blk.blocks), n1


def assert_states_equal(a, b, msg=""):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb), msg
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=msg)


@given(st_seeds(), st_ints(48, 160), st_sampled(["er", "pl"]), cases=4)
def test_prop_backend_parity_all_dense_edge_computes(seed, n, kind):
    rng = np.random.default_rng(seed)
    csr = (
        erdos_renyi(n, 5.0, seed=seed)
        if kind == "er"
        else powerlaw(n, 4.0, seed=seed)
    )
    csr_w = CSRGraph(
        indptr=csr.indptr,
        indices=csr.indices,
        weights=rng.uniform(0.1, 2.0, csr.n_edges).astype(np.float32),
    )
    ops, n_pad = full_operands(csr)
    ops_w, _ = full_operands(csr_w)
    srcs = jnp.asarray(
        rng.integers(0, csr.n_nodes, size=2).astype(np.int32)
    )
    for ec in DENSE_ECS:
        use = ops_w if ec == "bellman_ford" else ops
        ref = run_ife(use, srcs, ec, extend="ell_push")
        if ec in ("sp_lengths",):
            exp = bfs_levels(csr, np.asarray(srcs))
            np.testing.assert_array_equal(
                np.asarray(ref.state.levels)[: csr.n_nodes], exp
            )
        for be in BACKENDS[1:]:
            got = run_ife(use, srcs, ec, extend=be)
            assert_states_equal(ref.state, got.state, f"{ec}/{be}")


@given(st_seeds(), st_ints(64, 200), cases=3)
def test_prop_backend_parity_msbfs(seed, n):
    csr = powerlaw(n, 4.0, seed=seed)
    ops, n_pad = full_operands(csr)
    rng = np.random.default_rng(seed)
    srcs = jnp.asarray(rng.integers(0, n, size=8).astype(np.int32))
    for ec in ("msbfs_lengths", "msbfs_parents"):
        ref = run_ife(ops, srcs, ec, extend="ell_push")
        for be in BACKENDS[1:]:
            got = run_ife(ops, srcs, ec, extend=be)
            assert_states_equal(ref.state, got.state, f"{ec}/{be}")


@pytest.mark.parametrize("state_layout", ["replicated", "sharded"])
def test_engine_backend_parity_both_layouts(state_layout):
    csr = powerlaw(150, 5.0, seed=3)
    n = csr.n_nodes
    mesh = mesh11()
    srcs = np.array([0, 11, 42], np.int32)
    expected = np.stack([bfs_levels(csr, [s]) for s in srcs])
    for be in BACKENDS:
        res = run_recursive_query(
            mesh, csr, srcs, policy_ntks(), "sp_lengths",
            state_layout=state_layout, extend=be,
        )
        got = np.asarray(res.state.levels)[: len(srcs), :n]
        np.testing.assert_array_equal(got, expected, err_msg=be)


def test_engine_backend_parity_lane_morsels():
    csr = erdos_renyi(140, 5.0, seed=9)
    n = csr.n_nodes
    mesh = mesh11()
    srcs = np.array([1, 7, 99], np.int32)
    ref = run_recursive_query(
        mesh, csr, srcs, policy_ntkms(), "msbfs_parents", extend="ell_push"
    )
    for be in BACKENDS[1:]:
        got = run_recursive_query(
            mesh, csr, srcs, policy_ntkms(), "msbfs_parents", extend=be
        )
        for fa, fb in zip(ref.state, got.state):
            np.testing.assert_array_equal(
                np.asarray(fa)[:, :n], np.asarray(fb)[:, :n], err_msg=be
            )


def test_scheduler_backend_selection_and_cache_keys():
    from repro.runtime.scheduler import AdaptiveScheduler

    csr = powerlaw(200, 5.0, seed=11)
    n = csr.n_nodes
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, phase1_iters=2)
    srcs = np.array([0, 17, 60], np.int32)
    ref = sched.query(srcs)
    n_engines = len(sched.cache)
    for be in ["ell_pull", "block_mxu", "dopt", "recommend"]:
        out = sched.query(srcs, backend=be)
        np.testing.assert_array_equal(
            np.asarray(ref.result.state.levels)[:, :n],
            np.asarray(out.result.state.levels)[:, :n],
            err_msg=be,
        )
    # each distinct backend compiled its own engines under its own key ...
    assert len(sched.cache) > n_engines
    # ... and re-serving a backend is pure cache hits
    h0, m0 = sched.cache.hits, sched.cache.misses
    sched.query(srcs, backend="dopt")
    assert sched.cache.hits > h0 and sched.cache.misses == m0


def test_max_deg_truncation_consistent_across_backends():
    """Reverse/block operands must be derived from the truncated forward
    graph, or pull would scan edges push cannot see."""
    csr = powerlaw(120, 6.0, seed=13)
    srcs = jnp.array([3])
    cap = 4
    spec_pull = as_spec("ell_pull")
    ops_t, _ = build_operands(csr, spec_pull, max_deg=cap, block=128)
    blk_t, _ = build_operands(
        csr, ExtendSpec(backend="block_mxu"), max_deg=cap, block=128
    )
    ops_t = GraphOperands(fwd=ops_t.fwd, rev=ops_t.rev, blocks=blk_t.blocks)
    ref = run_ife(ops_t, srcs, "sp_lengths", extend="ell_push")
    for be in BACKENDS[1:]:
        got = run_ife(ops_t, srcs, "sp_lengths", extend=be)
        assert_states_equal(ref.state, got.state, be)
    # and the effective graph really is capped
    eff = truncate_csr(csr, cap)
    assert int(eff.degrees.max()) <= cap
    assert eff.n_edges == int(np.minimum(csr.degrees, cap).sum())


@given(st_seeds(), st_ints(16, 120), st_ints(1, 9), cases=6)
def test_prop_ell_from_csr_vectorized_matches_loop(seed, n, deg):
    """The numpy-index ELL builder == the straightforward per-row loop,
    including weights and degree truncation."""
    rng = np.random.default_rng(seed)
    csr = erdos_renyi(n, float(deg), seed=seed)
    csr = CSRGraph(
        indptr=csr.indptr,
        indices=csr.indices,
        weights=rng.uniform(0.1, 1.0, csr.n_edges).astype(np.float32),
    )
    cap = None if seed % 2 else max(1, deg // 2)
    g = ell_from_csr(csr, max_deg=cap)
    # reference: the original interpreted loop
    degs = csr.degrees.astype(np.int32)
    width = g.indices.shape[1]
    ref_idx = np.full((n, width), n, np.int32)
    ref_w = np.zeros((n, width), np.float32)
    for v in range(n):
        d = min(int(degs[v]), width)
        lo = csr.indptr[v]
        ref_idx[v, :d] = csr.indices[lo : lo + d]
        ref_w[v, :d] = csr.weights[lo : lo + d]
    np.testing.assert_array_equal(np.asarray(g.indices), ref_idx)
    np.testing.assert_array_equal(np.asarray(g.weights), ref_w)
    np.testing.assert_array_equal(
        np.asarray(g.degrees), np.minimum(degs, width)
    )


@given(st_seeds(), st_ints(4, 64), cases=6)
def test_prop_pack_unpack_lanes_roundtrip(seed, n):
    from repro.core.frontier import LANES, pack_lanes, unpack_lanes

    rng = np.random.default_rng(seed)
    lanes = (rng.random((n, LANES)) < 0.3).astype(np.uint8)
    packed = pack_lanes(jnp.asarray(lanes))
    assert packed.shape == (n, LANES // 32) and packed.dtype == jnp.uint32
    back = unpack_lanes(packed)
    np.testing.assert_array_equal(np.asarray(back), lanes)
    repacked = pack_lanes(back)
    np.testing.assert_array_equal(np.asarray(repacked), np.asarray(packed))


def test_recommend_backend_rules():
    assert recommend_backend("bellman_ford", 300.0, n_nodes=1000) == "ell_push"
    assert (
        recommend_backend("msbfs_lengths", 300.0, n_nodes=1000, lanes=64)
        == "block_mxu"
    )
    # lane morsels on block-sparse (huge) graphs: stay direction-optimized
    assert (
        recommend_backend("msbfs_lengths", 8.0, n_nodes=10**7, lanes=64)
        == "dopt"
    )
    assert recommend_backend("sp_lengths", 8.0, n_nodes=1000) == "dopt"


def test_block_operands_regroup_for_pad_shards():
    """prepare_graph(pad_shards=K) with K > the policy's own shard count
    must regroup the stacked block tiles (rebased local row-block ids) —
    the scheduler's shared-n_pad contract for the block backend."""
    from repro.core.dispatcher import (
        build_engine,
        pad_sources,
        prepare_graph,
    )

    csr = powerlaw(300, 5.0, seed=3)
    n = csr.n_nodes
    mesh = mesh11()
    spec = ExtendSpec(backend="block_mxu")
    pol = policy_ntks()
    g, n_pad = prepare_graph(csr, mesh, pol, pad_shards=4, extend=spec)
    assert n_pad % (4 * spec.block) == 0
    eng = build_engine(mesh, pol, "sp_lengths", n_pad, 64, extend=spec)
    srcs = np.array([0, 11, 42], np.int32)
    res = eng(g, jnp.asarray(pad_sources(srcs, 1, 1, n_pad)))
    expected = np.stack([bfs_levels(csr, [s]) for s in srcs])
    np.testing.assert_array_equal(
        np.asarray(res.state.levels)[:3, :n], expected
    )


def test_extend_spec_validation_and_errors():
    with pytest.raises(ValueError):
        ExtendSpec(backend="nope")
    with pytest.raises(ValueError):
        ExtendSpec(direction="sometimes")
    with pytest.raises(ValueError):
        # auto IS the push/pull choice; pinning another backend with it
        # would otherwise be silently ignored
        ExtendSpec(backend="block_mxu", direction="auto")
    csr = erdos_renyi(64, 3.0, seed=1)
    ops, _ = build_operands(csr, "ell_push")
    with pytest.raises(ValueError):
        run_ife(ops, jnp.array([0]), "sp_lengths", extend="ell_pull")
    with pytest.raises(ValueError):
        run_ife(ops, jnp.array([0]), "sp_lengths", extend="block_mxu")
