"""DCN-v2 + EmbeddingBag smoke & correctness."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.nn.module import split_boxed
from repro.nn.embedding_bag import (
    embedding_bag,
    fused_table_init,
    lookup_single,
)
from repro.models import dcn_v2
from repro.configs.dcn_v2 import smoke_config


def make_batch(cfg, B=32, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "dense": jnp.asarray(
            rng.random((B, cfg.n_dense)) * 100, jnp.float32
        ),
        "sparse": jnp.asarray(
            rng.integers(0, 97, (B, cfg.n_sparse)), jnp.int32
        ),
        "labels": jnp.asarray(rng.integers(0, 2, B), jnp.int32),
    }


def test_embedding_bag_matches_onehot():
    rng = jax.random.PRNGKey(0)
    vocabs = np.array([7, 11, 5])
    boxed, offsets = fused_table_init(rng, vocabs, 4)
    params, _ = split_boxed(boxed)
    nrng = np.random.default_rng(1)
    nnz = 20
    field_ids = jnp.asarray(nrng.integers(0, 3, nnz), jnp.int32)
    ids = jnp.asarray(
        [nrng.integers(0, vocabs[f]) for f in np.asarray(field_ids)],
        jnp.int32,
    )
    bag_ids = jnp.asarray(np.sort(nrng.integers(0, 6, nnz)), jnp.int32)
    out = embedding_bag(params, offsets, ids, field_ids, bag_ids, 6)
    # oracle: one-hot matmul over the fused table
    flat = np.asarray(ids) + offsets[np.asarray(field_ids)]
    onehot = np.zeros((6, int(vocabs.sum())), np.float32)
    for b, f in zip(np.asarray(bag_ids), flat):
        onehot[b, f] += 1
    expect = onehot @ np.asarray(params["table"])
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-6)
    # mean mode
    out_m = embedding_bag(
        params, offsets, ids, field_ids, bag_ids, 6, mode="mean"
    )
    counts = np.maximum(onehot.sum(1, keepdims=True), 1)
    np.testing.assert_allclose(
        np.asarray(out_m), expect / counts, rtol=1e-5, atol=1e-6
    )


def test_dcnv2_forward_and_train():
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    cfg = smoke_config()
    boxed, offsets = dcn_v2.init(jax.random.PRNGKey(0), cfg)
    params, _ = split_boxed(boxed)
    batch = make_batch(cfg)
    logits = dcn_v2.forward(params, cfg, batch, offsets)
    assert logits.shape == (32,)
    assert bool(jnp.isfinite(logits).all())

    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step(p, o):
        l, g = jax.value_and_grad(
            lambda p: dcn_v2.loss_fn(p, cfg, batch, offsets)
        )(p)
        p, o, _ = adamw_update(g, o, p, ocfg)
        return p, o, l

    p, o, l0 = step(params, opt)
    for _ in range(5):
        p, o, l1 = step(p, o)
    assert float(l1) < float(l0)


def test_cross_layer_identity_property():
    """With W=0, b=0 the cross layers are the identity."""
    cfg = smoke_config()
    boxed, offsets = dcn_v2.init(jax.random.PRNGKey(0), cfg)
    params, _ = split_boxed(boxed)
    zeroed = dict(params)
    zeroed["cross"] = jax.tree.map(jnp.zeros_like, params["cross"])
    batch = make_batch(cfg)
    x0 = dcn_v2.features(params, cfg, batch, offsets)
    x = x0
    for i in range(cfg.n_cross_layers):
        p = zeroed["cross"][f"w_{i}"]
        x = x0 * (x @ p["kernel"] + p["bias"]) + x
    np.testing.assert_allclose(np.asarray(x), np.asarray(x0))


def test_retrieval_topk():
    cfg = smoke_config()
    boxed, offsets = dcn_v2.init(jax.random.PRNGKey(0), cfg)
    params, _ = split_boxed(boxed)
    batch = make_batch(cfg, B=2)
    rng = np.random.default_rng(3)
    cands = jnp.asarray(
        rng.standard_normal((1000, cfg.retrieval_dim)), jnp.float32
    )
    vals, idx = dcn_v2.retrieval_scores(
        params, cfg, batch, offsets, cands, top_k=10
    )
    assert vals.shape == (2, 10) and idx.shape == (2, 10)
    # verify against brute force
    q = np.asarray(dcn_v2.query_embedding(params, cfg, batch, offsets))
    scores = q @ np.asarray(cands).T
    for b in range(2):
        expect = np.sort(scores[b])[::-1][:10]
        np.testing.assert_allclose(
            np.asarray(vals[b]), expect, rtol=1e-5, atol=1e-6
        )
