"""Adaptive morsel runtime + jax-compat regression tests.

Covers the two root-cause seed fixes (version-compatible mesh construction,
grad-through-optimization_barrier) and the runtime: engine-cache hit/miss
identity, two-phase hybrid bit-parity with static nTkS, chunked dispatch,
multi-tenant lane-packing admission, and the gang-scheduled phase-2 resume
(differential parity corpus: ganged vs serial per-morsel resume vs static
nTkS vs the numpy oracle, over both state layouts; pow2-pad boundary,
single-survivor fast path, all-inert resume, and zero-survivor fixtures).
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from oracle import bfs_levels
from proptest import given, st_ints, st_sampled, st_seeds, st_subset

from repro.core import (
    build_engine,
    pad_sources,
    policy_ntks,
    policy_ntkms,
    prepare_graph,
    run_recursive_query,
)
from repro.core.extend import as_spec
from repro.graph.csr import csr_from_edges
from repro.graph.generators import erdos_renyi, powerlaw
from repro.launch.mesh import make_mesh
from repro.runtime.scheduler import AdaptiveScheduler, _pow2ceil


@functools.lru_cache(maxsize=None)
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


@functools.lru_cache(maxsize=None)
def skew_graph(kind: str = "powerlaw", n_main: int = 160,
               paths: tuple = (40, 28, 22), seed: int = 0):
    """A small-diameter main component plus ``len(paths)`` long-path
    straggler components: sources on the path heads survive any small
    phase-1 budget, so the survivor count is controllable per test.
    Returns (csr, path_head_ids)."""
    main = (powerlaw if kind == "powerlaw" else erdos_renyi)(
        n_main, 5.0, seed=seed
    )
    src_m, dst_m = main.edge_list()
    srcs, dsts, base, heads = [src_m], [dst_m], n_main, []
    for length in paths:
        p = np.arange(length - 1, dtype=np.int64) + base
        srcs += [p, p + 1]
        dsts += [p + 1, p]
        heads.append(base)
        base += length
    csr = csr_from_edges(base, np.concatenate(srcs), np.concatenate(dsts))
    return csr, tuple(heads)


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------

def test_make_mesh_compat_old_and_new_api(monkeypatch):
    # whatever jax this is, the helper must produce a working mesh
    m = make_mesh((1, 1), ("a", "b"))
    assert dict(m.shape) == {"a": 1, "b": 1}

    real_make_mesh = jax.make_mesh

    # new-jax surface: AxisType exists and make_mesh takes axis_types
    class FakeAxisType:
        Auto = "auto"

    seen = {}

    def new_make_mesh(shapes, names, *, axis_types=None):
        seen["axis_types"] = axis_types
        return real_make_mesh(shapes, names)

    monkeypatch.setattr(jax, "make_mesh", new_make_mesh)
    monkeypatch.setattr(
        jax.sharding, "AxisType", FakeAxisType, raising=False
    )
    m = make_mesh((1, 1), ("a", "b"))
    assert seen["axis_types"] == ("auto", "auto")
    assert dict(m.shape) == {"a": 1, "b": 1}

    # mid-version surface: AxisType exists, make_mesh predates the kwarg
    def old_make_mesh(shapes, names):
        return real_make_mesh(shapes, names)

    monkeypatch.setattr(jax, "make_mesh", old_make_mesh)
    m = make_mesh((1, 1), ("a", "b"))
    assert dict(m.shape) == {"a": 1, "b": 1}


def test_grad_through_barrier_under_scan_and_remat():
    """jax 0.4.x regression: grad of optimization_barrier inside
    scan-of-checkpoint raised NotImplementedError; the custom_jvp wrapper
    must be numerically an identity for both primal and gradient."""
    from repro.models.transformer import grad_safe_barrier

    def net(w, use_barrier):
        def layer(x, _):
            h = jnp.tanh(x @ w)
            if use_barrier:
                h = grad_safe_barrier(h)
            return h, ()

        y, _ = jax.lax.scan(
            jax.checkpoint(layer), jnp.ones((4,)), None, length=3
        )
        return jnp.sum(y * y)

    w = jnp.asarray(np.random.default_rng(0).standard_normal((4, 4)) * 0.3,
                    jnp.float32)
    loss_b, grad_b = jax.value_and_grad(lambda w: net(w, True))(w)
    loss_p, grad_p = jax.value_and_grad(lambda w: net(w, False))(w)
    np.testing.assert_allclose(float(loss_b), float(loss_p), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(grad_b), np.asarray(grad_p), rtol=1e-6, atol=1e-7
    )


# ---------------------------------------------------------------------------
# Engine cache
# ---------------------------------------------------------------------------

def test_engine_cache_hit_miss_by_key():
    csr = erdos_renyi(96, 4.0, seed=4)
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=32, phase1_iters=2
    )
    srcs = np.array([0, 7, 23], np.int32)

    sched.query(srcs)
    n0, miss0 = len(sched.cache), sched.cache.misses
    assert n0 == miss0 and sched.cache.hits == 0
    assert n0 >= 1  # at least the phase-1 engine

    # same (policy, edge compute, shapes) => pure cache hits, no compiles
    sched.query(np.array([1, 2, 3], np.int32))
    assert len(sched.cache) == n0
    assert sched.cache.misses == miss0
    assert sched.cache.hits >= 1

    # different edge compute => new keys, old entries untouched
    sched.query(srcs, returns_paths=True)
    assert len(sched.cache) > n0
    assert sched.cache.misses > miss0


# ---------------------------------------------------------------------------
# Two-phase hybrid == static nTkS (bit-identical state)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("returns_paths", [False, True])
def test_hybrid_state_bit_identical_to_static_ntks(returns_paths):
    csr = powerlaw(260, 5.0, seed=7)
    mesh = mesh11()
    srcs = np.array([0, 11, 42, 97, 150, 201], np.int32)
    ec = "sp_parents" if returns_paths else "sp_lengths"

    static = run_recursive_query(mesh, csr, srcs, policy_ntks(), ec)
    sched = AdaptiveScheduler(mesh, csr, max_iters=64, phase1_iters=2)
    out = sched.query(srcs, returns_paths=returns_paths)
    assert out.hybrid
    assert out.redispatched > 0  # phase 2 must actually have run

    ref = jax.tree.map(np.asarray, static.state)
    got = jax.tree.map(np.asarray, out.result.state)
    for field in ref._fields:
        a, b = getattr(ref, field), getattr(got, field)
        assert a.dtype == b.dtype and a.shape == b.shape, field
        np.testing.assert_array_equal(a, b, err_msg=field)


def test_hybrid_budget_covers_convergence_skips_phase2():
    csr = erdos_renyi(80, 4.0, seed=2)
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=64
    )
    out = sched.query(np.array([3, 9], np.int32))
    assert out.hybrid and out.redispatched == 0
    assert out.phase_ms["phase2"] == 0.0
    lv = np.asarray(out.result.state.levels)[:2, : csr.n_nodes]
    np.testing.assert_array_equal(lv[0], bfs_levels(csr, [3]))
    np.testing.assert_array_equal(lv[1], bfs_levels(csr, [9]))


def test_chunked_dispatch_matches_unchunked():
    """recommend_k-style in-flight caps split the batch; results must be
    independent of the chunking."""
    csr = erdos_renyi(120, 4.0, seed=9)
    srcs = np.random.default_rng(1).integers(
        0, csr.n_nodes, 12
    ).astype(np.int32)
    capped = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=2, max_inflight=4
    )
    plain = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=2
    )
    la = np.asarray(capped.query(srcs).result.state.levels)
    lb = np.asarray(plain.query(srcs).result.state.levels)
    np.testing.assert_array_equal(
        la[: len(srcs), : csr.n_nodes], lb[: len(srcs), : csr.n_nodes]
    )


# ---------------------------------------------------------------------------
# backend="recommend" default (ISSUE 3): the served default must be
# bit-identical to any explicitly pinned backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("returns_paths", [False, True])
def test_recommend_default_bit_identical_to_explicit(returns_paths):
    """The scheduler's (and serve's) default is now backend="recommend"
    (direction-optimized binned pull for the BFS family). One scheduler
    left on the default and one pinned to each explicit backend must
    produce byte-identical result states — and both must match the static
    single-engine dispatcher."""
    csr = powerlaw(240, 5.0, seed=13)
    mesh = mesh11()
    srcs = np.array([0, 9, 41, 77, 160], np.int32)
    ec = "sp_parents" if returns_paths else "sp_lengths"

    sched = AdaptiveScheduler(mesh, csr, max_iters=64, phase1_iters=2)
    assert sched.backend == "recommend"
    out = sched.query(srcs, returns_paths=returns_paths)
    ref = jax.tree.map(np.asarray, out.result.state)

    static = run_recursive_query(mesh, csr, srcs, policy_ntks(), ec)
    for field in ref._fields:
        np.testing.assert_array_equal(
            getattr(ref, field),
            np.asarray(getattr(static.state, field)),
            err_msg=f"recommend-vs-static/{field}",
        )

    for be in ("ell_push", "ell_pull", "pull_binned", "dopt", "dopt_ell"):
        pinned = AdaptiveScheduler(
            mesh, csr, max_iters=64, phase1_iters=2, backend=be
        )
        got = jax.tree.map(
            np.asarray,
            pinned.query(srcs, returns_paths=returns_paths).result.state,
        )
        for field in ref._fields:
            a, b = getattr(ref, field), getattr(got, field)
            assert a.dtype == b.dtype and a.shape == b.shape, (be, field)
            np.testing.assert_array_equal(a, b, err_msg=f"{be}/{field}")


def test_recommend_with_fitted_thresholds_bit_identical():
    """A fitted threshold table changes WHEN the switch pulls, never WHAT
    it computes: results stay bit-identical, and the fitted spec is served
    through the same engine-cache path (fresh keys, then pure hits)."""
    from repro.core import DirectionThresholds

    csr = powerlaw(200, 6.0, seed=5)
    mesh = mesh11()
    srcs = np.array([2, 30, 71], np.int32)
    base = AdaptiveScheduler(mesh, csr, max_iters=64, phase1_iters=2)
    th = DirectionThresholds(table={("powerlaw", 4): (2.0, 2.0)})
    fitted = AdaptiveScheduler(
        mesh, csr, max_iters=64, phase1_iters=2,
        direction_thresholds=th, family="powerlaw",
    )
    a = np.asarray(base.query(srcs).result.state.levels)
    b = np.asarray(fitted.query(srcs).result.state.levels)
    np.testing.assert_array_equal(a, b)
    h0, m0 = fitted.cache.hits, fitted.cache.misses
    fitted.query(srcs)
    assert fitted.cache.hits > h0 and fitted.cache.misses == m0


# ---------------------------------------------------------------------------
# Multi-tenant admission
# ---------------------------------------------------------------------------

def test_admission_packs_lanes_only_when_saturated():
    csr = powerlaw(200, 5.0, seed=3)
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64)
    rng = np.random.default_rng(0)

    # 5 tenants x 16 sources = 80 >= 64 -> one packed MS-BFS run
    tenants = {
        sched.submit(s): s
        for s in [
            rng.integers(0, csr.n_nodes, 16).astype(np.int32)
            for _ in range(5)
        ]
    }
    res = sched.flush()
    assert sched.admissions == {"ntkms": 1, "per_query": 0}
    assert set(res) == set(tenants)
    for qid, srcs in tenants.items():
        assert res[qid].shape == (len(srcs), csr.n_nodes)
        for j, s in enumerate(srcs):
            np.testing.assert_array_equal(
                res[qid][j], bfs_levels(csr, [int(s)]), err_msg=f"{qid}/{j}"
            )

    # a lone small query must NOT be packed: per-query hybrid path
    qid = sched.submit(np.array([5, 17], np.int32))
    res = sched.flush()
    assert sched.admissions["per_query"] == 1
    np.testing.assert_array_equal(res[qid][0], bfs_levels(csr, [5]))
    np.testing.assert_array_equal(res[qid][1], bfs_levels(csr, [17]))

    assert sched.flush() == {}  # nothing pending


def test_pow2ceil():
    assert [_pow2ceil(x) for x in (0, 1, 2, 3, 4, 5, 8, 9)] == [
        1, 1, 2, 4, 4, 8, 8, 16,
    ]


# ---------------------------------------------------------------------------
# Gang-scheduled phase-2 resume (ISSUE 4): batched multi-frontier re-dispatch
# must be bit-identical to the serial per-morsel resume, to static nTkS, and
# to the numpy oracle — plus edge-case fixtures for the gang path itself.
# ---------------------------------------------------------------------------

_SCHED_CACHE: dict = {}
_STATIC_CACHE: dict = {}


def _sched(kind: str, backend: str, layout: str = "replicated",
           gang: bool = True, adapt: bool = False) -> AdaptiveScheduler:
    """One AdaptiveScheduler per corpus configuration — compiled engines
    are reused across fuzz cases, so the corpus pays each (graph, backend,
    engine-kind) compile exactly once. ``adapt=True`` is the
    online-learning configuration: no pinned budget (the per-bucket
    BudgetModel serves it), stats-tapped phase-1 engines, and in-flight
    threshold refits every few batches — the corpus proves none of that
    can move results."""
    key = (kind, backend, layout, gang, adapt)
    if key not in _SCHED_CACHE:
        csr, _ = skew_graph(kind)
        if adapt:
            _SCHED_CACHE[key] = AdaptiveScheduler(
                mesh11(), csr, max_iters=64, backend=backend,
                gang_resume=gang, family=kind, online_adapt=True,
                refit_every=4,
            )
        else:
            _SCHED_CACHE[key] = AdaptiveScheduler(
                mesh11(), csr, max_iters=64, phase1_iters=2,
                backend=backend, gang_resume=gang, online_adapt=False,
            )
    return _SCHED_CACHE[key]


def _static_levels(kind: str, backend: str, srcs: np.ndarray,
                   layout: str = "replicated") -> np.ndarray:
    """Static single-engine nTkS reference levels (cached engine)."""
    key = (kind, backend, layout)
    if key not in _STATIC_CACHE:
        csr, _ = skew_graph(kind)
        spec = as_spec(backend)
        g, n_pad = prepare_graph(csr, mesh11(), policy_ntks(), extend=spec)
        eng = build_engine(
            mesh11(), policy_ntks(), "sp_lengths", n_pad, 64,
            state_layout=layout, extend=spec, operands=g,
        )
        _STATIC_CACHE[key] = (csr, g, n_pad, eng)
    csr, g, n_pad, eng = _STATIC_CACHE[key]
    morsels = pad_sources(srcs, 1, 1, n_pad)
    res = eng(g, jnp.asarray(morsels))
    return np.asarray(res.state.levels)[: len(srcs), : csr.n_nodes]


def _gang_case_sources(kind: str, head_picks, rng) -> np.ndarray:
    """Fixed-size source batch (stable trace shapes across fuzz cases):
    the chosen straggler path heads + random main-component fillers."""
    csr, heads = skew_graph(kind)
    fill = rng.integers(0, 160, 6 - len(head_picks)).astype(np.int32)
    return np.concatenate(
        [np.asarray(head_picks, np.int32), fill]
    ).astype(np.int32)


@given(
    st_seeds(),
    st_sampled(["powerlaw", "er"]),
    st_sampled(["ell_push", "dopt"]),
    st_subset([0, 1, 2], min_size=0),
    cases=10,
)
def test_gang_parity_fuzz_corpus(seed, kind, backend, head_ids):
    """Differential engine-parity corpus (replicated layout): for a seeded
    random (graph family x backend x source set) case, the gang-scheduled
    hybrid, the serial per-morsel hybrid, the ONLINE-ADAPTING scheduler
    (per-bucket budget model + stats-tapped phase 1 + in-flight threshold
    refits, backend="recommend"), the static nTkS engine, and the numpy
    BFS oracle must agree bit-for-bit — online learning may only move
    iteration slots, never results."""
    rng = np.random.default_rng(seed)
    csr, heads = skew_graph(kind)
    srcs = _gang_case_sources(
        kind, [heads[i] for i in head_ids], rng
    )
    ganged = _sched(kind, backend).query(srcs)
    serial = _sched(kind, backend, gang=False).query(srcs)
    online = _sched(kind, "recommend", adapt=True).query(srcs)
    assert ganged.redispatched == serial.redispatched
    assert ganged.resumed_serial == 0 or ganged.gang_width == 0
    assert serial.resumed_ganged == 0

    a = jax.tree.map(np.asarray, ganged.result.state)
    b = jax.tree.map(np.asarray, serial.result.state)
    c = jax.tree.map(np.asarray, online.result.state)
    for field in a._fields:
        np.testing.assert_array_equal(
            getattr(a, field), getattr(b, field),
            err_msg=f"gang-vs-serial/{field}",
        )
        np.testing.assert_array_equal(
            getattr(a, field), getattr(c, field),
            err_msg=f"online-adapt-vs-disabled/{field}",
        )
    np.testing.assert_array_equal(
        np.asarray(ganged.result.iterations),
        np.asarray(serial.result.iterations),
        err_msg="gang-vs-serial/iterations",
    )
    # final iterations are each morsel's true convergence depth — the
    # learned budget moves the phase-1/phase-2 split, not the total
    np.testing.assert_array_equal(
        np.asarray(ganged.result.iterations),
        np.asarray(online.result.iterations),
        err_msg="online-adapt-vs-disabled/iterations",
    )

    lv = a.levels[: len(srcs), : csr.n_nodes]
    np.testing.assert_array_equal(
        lv, _static_levels(kind, backend, srcs), err_msg="gang-vs-static"
    )
    for j, s in enumerate(srcs):
        np.testing.assert_array_equal(
            lv[j], bfs_levels(csr, [int(s)]), err_msg=f"oracle/src{j}"
        )


@pytest.mark.slow
@given(
    st_seeds(),
    st_sampled(["powerlaw", "er"]),
    st_sampled(["ell_push", "dopt"]),
    st_subset([0, 1, 2], min_size=1),
    cases=6,
)
def test_gang_parity_fuzz_corpus_sharded(seed, kind, backend, head_ids):
    """Sharded-state layer of the corpus: the reduce-scatter/all-gather
    gang resume must match the replicated gang hybrid and the sharded
    static engine bit-for-bit — with online adaptation (stats-tapped
    sharded phase 1, learned budgets) enabled as well as disabled."""
    rng = np.random.default_rng(seed)
    csr, heads = skew_graph(kind)
    srcs = _gang_case_sources(kind, [heads[i] for i in head_ids], rng)
    out = _sched(kind, backend, layout="sharded").query(
        srcs, state_layout="sharded"
    )
    assert out.hybrid and out.resumed_ganged == out.redispatched > 0
    ref = _sched(kind, backend).query(srcs)
    onl = _sched(kind, "recommend", layout="sharded", adapt=True).query(
        srcs, state_layout="sharded"
    )
    a = jax.tree.map(np.asarray, out.result.state)
    b = jax.tree.map(np.asarray, ref.result.state)
    c = jax.tree.map(np.asarray, onl.result.state)
    for field in a._fields:
        np.testing.assert_array_equal(
            getattr(a, field), getattr(b, field),
            err_msg=f"sharded-vs-replicated/{field}",
        )
        np.testing.assert_array_equal(
            getattr(a, field), getattr(c, field),
            err_msg=f"sharded-online-adapt-vs-disabled/{field}",
        )
    lv = a.levels[: len(srcs), : csr.n_nodes]
    np.testing.assert_array_equal(
        lv, _static_levels(kind, backend, srcs, layout="sharded"),
        err_msg="sharded-gang-vs-sharded-static",
    )


def test_gang_pow2_pad_boundary_3_to_4():
    """3 survivors pad to a 4-wide gang; counters split accordingly."""
    csr, heads = skew_graph("powerlaw")
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=16
    )
    # budget 16 covers the main component (diameter << 16) but none of the
    # 3 path components (depths 39/27/21) => exactly the 3 heads survive
    srcs = np.concatenate([[heads[0], heads[1], heads[2]], [3, 9]]).astype(
        np.int32
    )
    out = sched.query(srcs)
    assert out.redispatched == 3
    assert out.resumed_ganged == 3 and out.resumed_serial == 0
    assert out.gang_width == 4
    assert sched.stats.gangs == 1 and sched.stats.gang_slots == 4
    assert sched.stats.gang_occupancy == 0.75
    lv = np.asarray(out.result.state.levels)
    for j, s in enumerate(srcs):
        np.testing.assert_array_equal(
            lv[j, : csr.n_nodes], bfs_levels(csr, [int(s)])
        )


def test_gang_pow2_pad_boundary_5_to_8():
    """5 survivors cross the pow2 boundary to an 8-wide gang."""
    csr, heads = skew_graph(
        "powerlaw", paths=(40, 38, 39, 41, 37), seed=1
    )
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=32
    )
    srcs = np.asarray(list(heads), np.int32)
    assert len(srcs) == 5
    out = sched.query(srcs)
    assert out.redispatched == 5
    assert out.resumed_ganged == 5 and out.gang_width == 8
    lv = np.asarray(out.result.state.levels)
    for j, s in enumerate(srcs):
        np.testing.assert_array_equal(
            lv[j, : csr.n_nodes], bfs_levels(csr, [int(s)])
        )


def test_gang_single_survivor_serial_fast_path():
    """Exactly one survivor skips gang packing: the serial per-morsel
    resume runs (no gang dispatch, gang_width 0)."""
    csr, heads = skew_graph("powerlaw", paths=(40,))
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=16
    )
    srcs = np.asarray([heads[0], 3, 9], np.int32)
    out = sched.query(srcs)
    assert out.redispatched == 1
    assert out.resumed_serial == 1 and out.resumed_ganged == 0
    assert out.gang_width == 0
    assert sched.stats.gangs == 0 and sched.stats.gang_slots == 0
    assert sched.stats.resumed_serial == 1
    lv = np.asarray(out.result.state.levels)
    for j, s in enumerate(srcs):
        np.testing.assert_array_equal(
            lv[j, : csr.n_nodes], bfs_levels(csr, [int(s)])
        )


def test_gang_all_survivors_inert_first_resume_iteration():
    """Survivors whose counters already sit at the iteration cap: the gang
    while_loop must be a zero-trip no-op (convergence masks keep capped
    morsels frozen), bit-identical to the static engine at the same cap."""
    cap = 4
    csr, heads = skew_graph("powerlaw")
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=cap, phase1_iters=cap
    )
    srcs = np.asarray(list(heads), np.int32)  # all three survive at it==cap
    out = sched.query(srcs)
    assert out.redispatched == 3 and out.resumed_ganged == 3
    static = run_recursive_query(
        mesh11(), csr, srcs, policy_ntks(), "sp_lengths", max_iters=cap
    )
    a = jax.tree.map(np.asarray, out.result.state)
    b = jax.tree.map(np.asarray, static.state)
    for field in a._fields:
        np.testing.assert_array_equal(
            getattr(a, field), getattr(b, field), err_msg=field
        )
    np.testing.assert_array_equal(
        np.asarray(out.result.iterations), np.full(len(srcs), cap)
    )


def test_gang_zero_survivor_flush():
    """Budget covering convergence => no survivors, no gang dispatch, and
    every phase-2 counter stays zero."""
    csr, _ = skew_graph("powerlaw", paths=())
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, phase1_iters=64)
    out = sched.query(np.asarray([3, 9, 17], np.int32))
    assert out.hybrid and out.redispatched == 0
    assert out.resumed_ganged == 0 and out.resumed_serial == 0
    assert out.gang_width == 0 and out.phase_ms["phase2"] == 0.0
    assert sched.stats.gangs == 0 and sched.stats.redispatched == 0
    assert sched.stats.gang_occupancy == 0.0


def test_stats_counter_split_invariant():
    """SchedulerStats aggregates the redispatched = ganged + serial split
    across queries, and the engine cache tracks gang compiles by kind."""
    csr, heads = skew_graph("powerlaw")
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, phase1_iters=16)
    sched.query(np.asarray([heads[0], 3], np.int32))  # 1 survivor: serial
    sched.query(np.asarray(list(heads), np.int32))  # 3 survivors: gang
    st = sched.stats
    assert st.queries == 2 and st.hybrid_runs == 2
    assert st.redispatched == st.resumed_ganged + st.resumed_serial == 4
    assert st.resumed_serial == 1 and st.resumed_ganged == 3
    assert st.gangs == 1 and st.gang_slots == 4
    assert sched.cache.misses_by_kind["gang"] == 1
    assert sched.cache.misses_by_kind["resume"] == 1
    assert sched.cache.misses_by_kind["phase1"] >= 1
    # same shapes again: pure cache hits, including the gang engine
    h0 = sched.cache.hits_by_kind["gang"]
    sched.query(np.asarray(list(heads), np.int32))
    assert sched.cache.misses_by_kind["gang"] == 1
    assert sched.cache.hits_by_kind["gang"] == h0 + 1


def test_gang_ntkms_lane_morsels():
    """Gang resume over 64-lane MS-BFS morsels: two surviving lane morsels
    fold into one [rows, 2*64] lane tensor; results bit-match static
    nTkMS over the logical node range (padding differs per backend)."""
    csr, heads = skew_graph("powerlaw")
    n = csr.n_nodes
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, phase1_iters=2)
    srcs = np.concatenate(
        [
            np.arange(60, dtype=np.int32) % n,
            np.asarray(list(heads), np.int32),
            np.arange(61, 120, dtype=np.int32) % 160,
            [heads[0]],
        ]
    ).astype(np.int32)
    out = sched.query(srcs, policy="ntkms")
    assert out.policy == "ntkms"
    assert out.redispatched == 2  # both lane morsels hold a path head
    assert out.resumed_ganged == 2 and out.gang_width == 2
    static = run_recursive_query(
        mesh11(), csr, srcs, policy_ntkms(), "msbfs_lengths"
    )
    np.testing.assert_array_equal(
        np.asarray(out.result.state.levels)[:, :n, :],
        np.asarray(static.state.levels)[:, :n, :],
    )


def test_gang_engine_direct_bellman_ford():
    """The gang engine is edge-compute generic: weighted relax (merge=min,
    no lane formulation => vmap batching) resumed from freshly-initialized
    states must match the BFS oracle on a unit-weight graph, with correct
    per-morsel trip counts and an inert pad slot."""
    from repro.core import build_gang_resume_engine
    from repro.core.edge_compute import EDGE_COMPUTES
    from repro.core.policies import hybrid_phases

    csr, heads = skew_graph("powerlaw")
    n = csr.n_nodes
    _, p2 = hybrid_phases()
    g2, n_pad = prepare_graph(csr, mesh11(), p2, pad_shards=1)
    ec = EDGE_COMPUTES["bellman_ford"]
    ks = [int(heads[0]), 3, int(heads[1])]
    state0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[ec.init(n_pad, jnp.asarray([s], jnp.int32)) for s in ks],
    )
    state0 = jax.tree.map(  # pow2 pad slot: all-zero state, must stay inert
        lambda x: jnp.concatenate(
            [x, jnp.zeros((1,) + x.shape[1:], x.dtype)]
        ),
        state0,
    )
    eng = build_gang_resume_engine(
        mesh11(), p2, "bellman_ford", n_pad, 64, operands=g2
    )
    res = eng(g2, state0, jnp.zeros((4,), jnp.int32))
    dist = np.asarray(res.state.dist)
    for i, s in enumerate(ks):
        lv = bfs_levels(csr, [s]).astype(np.float64)
        lv[lv < 0] = np.inf
        np.testing.assert_allclose(dist[i, :n], lv, err_msg=str(s))
    iters = np.asarray(res.iterations)
    assert iters[3] == 0  # pad slot never iterated
    assert iters[0] > iters[1]  # path head runs ~path-length iterations


# ---------------------------------------------------------------------------
# Online policy learning (ISSUE 5): deterministic replay, budget-model
# integration edge cases, and mispredict-counter invariants.
# ---------------------------------------------------------------------------


def _replay_stream(heads):
    """A fixed seeded batch stream mixing shallow main-component sources
    with straggler path heads (stable shapes per batch index)."""
    rng = np.random.default_rng(7)
    batches = []
    for b in range(5):
        fill = rng.integers(0, 160, 4).astype(np.int32)
        if b % 2 == 0:
            fill = np.concatenate(
                [[heads[b % len(heads)]], fill[:3]]
            ).astype(np.int32)
        batches.append(fill)
    return batches


@pytest.mark.slow
def test_online_learning_deterministic_replay():
    """The same seeded batch stream must yield bit-identical refitted
    thresholds, learned budgets, accumulated sample traces, and
    mispredict counters — across independent runs AND across
    gang_resume on/off (the learner holds no wall-clock/RNG hidden
    state, and the gang only changes how phase 2 executes, never what
    any morsel observes)."""
    csr, heads = skew_graph("powerlaw")

    def run(gang: bool):
        sched = AdaptiveScheduler(
            mesh11(), csr, max_iters=64, backend="dopt",
            family="powerlaw", online_adapt=True, refit_every=2,
            gang_resume=gang,
        )
        budgets = [
            int(sched.query(b).phase1_budget) for b in _replay_stream(heads)
        ]
        sched.refit_thresholds()
        return sched, budgets

    a, budgets_a = run(gang=True)
    b, budgets_b = run(gang=True)
    c, budgets_c = run(gang=False)
    assert budgets_a == budgets_b == budgets_c
    ta = dict(a.direction_thresholds.table)
    assert ta == dict(b.direction_thresholds.table)
    assert ta == dict(c.direction_thresholds.table)
    assert ta, "refit produced an empty table"
    for other in (b, c):
        assert a.budget_model.budgets(64) == other.budget_model.budgets(64)
        assert a.online_trace() == other.online_trace()
        for f in ("budget_too_low", "budget_too_high",
                  "budget_inert_slots", "budget_observed", "refits"):
            assert getattr(a.stats, f) == getattr(other.stats, f), f
        m, mo = a.budget_model.mispredicts, other.budget_model.mispredicts
        assert (m.too_low, m.too_high, m.inert_slots, m.observed) == (
            mo.too_low, mo.too_high, mo.inert_slots, mo.observed
        )


def test_phase1_budget_model_priority_and_fallbacks():
    """Budget source priority: pinned phase1_iters > warmed BudgetModel
    (covering max over the batch's buckets) > global pow2 p90 deque
    (the empty-model path) > cold-start default."""
    csr, _ = skew_graph("powerlaw", paths=())
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, family="er")
    assert sched._phase1_budget([2]) == 8  # cold start
    sched._iter_p90s.extend([11.0, 12.0, 13.0])
    assert sched._phase1_budget([2]) == 16  # empty model -> pow2 p90 path
    sched.budget_model.observe("er", 2, [30, 30, 30])
    assert sched._phase1_budget([2]) == 32  # model supersedes the deque
    sched.budget_model.observe("er", 0, [3, 3])
    assert sched._phase1_budget([0]) == 4
    assert sched._phase1_budget([0, 2]) == 32  # covering max over buckets
    pinned = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=2, family="er"
    )
    pinned.budget_model.observe("er", 2, [30] * 4)
    assert pinned._phase1_budget([2]) == 2  # pin bypasses the learner


def test_pinned_budget_bypasses_learning_pads_never_update():
    """phase1_iters pins the budget AND keeps the model untouched; with
    learning on, the model sees exactly the real morsels of a chunked
    batch — chunk-pad morsels (0-iteration inert slots) never land in
    any bucket's window (the per-bucket form of the pad guard)."""
    csr, _ = skew_graph("powerlaw", paths=())
    srcs = np.asarray([3, 9, 17], np.int32)
    pinned = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=2, max_inflight=2
    )
    out = pinned.query(srcs)
    assert out.phase1_budget == 2
    assert pinned.budget_model.n_samples == 0  # learner bypassed
    assert pinned.budget_model.mispredicts.observed == 0
    assert out.budget_observed == 3  # counters still see the real morsels

    learning = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, max_inflight=2
    )
    out2 = learning.query(srcs)  # chunks of 2: last chunk is 1 real + 1 pad
    assert learning.budget_model.n_samples == 3  # pads excluded
    assert out2.budget_observed == 3
    trips = np.asarray(out2.result.iterations)[:3]
    for (fam, bucket), win in learning.budget_model._windows.items():
        assert fam is None
        assert all(t in trips for t in win)
        assert 0 not in win  # no 0-iteration pad morsels


def test_budget_too_low_counts_every_real_morsel():
    """A budget forced to 1 sits below every real morsel's convergence
    depth: each one survives phase 1 and counts as a too_low mispredict
    (and nothing counts too_high / inert)."""
    csr, heads = skew_graph("powerlaw")
    srcs = np.asarray([heads[0], heads[1], 3, 9], np.int32)
    for s in srcs:  # premise: every source needs >= 2 IFE iterations
        assert bfs_levels(csr, [int(s)]).max() >= 2
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, phase1_iters=1)
    out = sched.query(srcs)
    assert out.phase1_budget == 1
    assert out.budget_too_low == len(srcs) == out.budget_observed
    assert out.budget_too_high == 0 and out.budget_inert_slots == 0
    assert out.redispatched == len(srcs)
    assert sched.stats.budget_too_low == len(srcs)
    assert sched.stats.budget_mispredict_rate == 1.0


def test_budget_too_high_counts_inert_spin_slots():
    """A budget forced past every morsel's oracle trip count converges
    everything in phase 1 and books the slack as inert-spin slots; the
    morsels a strictly smaller pow2 budget would have covered count
    too_high. Counters accumulate across batches in SchedulerStats."""
    csr, _ = skew_graph("powerlaw", paths=())
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64, phase1_iters=64)
    srcs = np.asarray([3, 9, 17], np.int32)
    out = sched.query(srcs)
    trips = np.asarray(out.result.iterations)[: len(srcs)]
    assert (trips * 2 < 64).all()  # shallow component: far under budget
    assert out.redispatched == 0 and out.budget_too_low == 0
    assert out.budget_too_high == len(srcs)
    assert out.budget_inert_slots == int((64 - trips).sum())
    sched.query(srcs)  # accumulate
    assert sched.stats.budget_too_high == 2 * len(srcs)
    assert sched.stats.budget_inert_slots == 2 * int((64 - trips).sum())
    assert sched.stats.budget_observed == 2 * len(srcs)
    assert sched.stats.budget_mispredict_rate == 1.0
    fresh = AdaptiveScheduler(mesh11(), csr, max_iters=64)
    assert fresh.stats.budget_observed == 0  # fresh stats start clean
    assert fresh.stats.budget_mispredict_rate == 0.0


def test_online_refit_matches_offline_fit_and_serves():
    """The in-flight refit must equal fit_direction_thresholds run on the
    scheduler's own accumulated trace (same decision boundaries), and the
    refitted table must be served through backend="recommend" without
    moving results."""
    from repro.core import fit_direction_thresholds

    csr, heads = skew_graph("powerlaw")
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, family="powerlaw",
        online_adapt=True, refit_every=2,
    )
    srcs = np.asarray([heads[0], 3, 9, 20], np.int32)
    before = np.asarray(sched.query(srcs).result.state.levels)
    sched.query(np.asarray([5, 11, 40], np.int32))  # triggers the refit
    assert sched.stats.refits >= 1
    fitted = sched.direction_thresholds
    assert fitted is not None and fitted.table
    offline = fit_direction_thresholds(sched.online_trace())
    assert dict(fitted.table) == dict(offline.table)
    # next batch serves the fitted alpha/beta (recommend path) — results
    # must stay bit-identical to the pre-refit run
    after = np.asarray(sched.query(srcs).result.state.levels)
    np.testing.assert_array_equal(before, after)


def test_explicit_thresholds_are_pinned_against_refit():
    """A caller-supplied threshold table must survive the auto-refit
    cadence untouched (serve --thresholds would otherwise be silently
    replaced by the live fit); a manual refit_thresholds() call still
    overrides the pin."""
    from repro.core import DirectionThresholds

    csr, _ = skew_graph("powerlaw", paths=())
    pinned_table = DirectionThresholds(table={("powerlaw", 2): (3.0, 5.0)})
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, family="powerlaw",
        direction_thresholds=pinned_table, online_adapt=True, refit_every=1,
    )
    for _ in range(3):  # cadence would refit every batch if unpinned
        sched.query(np.asarray([3, 9], np.int32))
    assert sched.direction_thresholds is pinned_table
    assert sched.stats.refits == 0
    sched.refit_thresholds()  # manual override still works
    assert sched.direction_thresholds is not pinned_table
    assert sched.stats.refits == 1
