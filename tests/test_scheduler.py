"""Adaptive morsel runtime + jax-compat regression tests.

Covers the two root-cause seed fixes (version-compatible mesh construction,
grad-through-optimization_barrier) and the new runtime: engine-cache hit/miss
identity, two-phase hybrid bit-parity with static nTkS, chunked dispatch, and
multi-tenant lane-packing admission.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from oracle import bfs_levels

from repro.core import run_recursive_query, policy_ntks
from repro.graph.generators import erdos_renyi, powerlaw
from repro.launch.mesh import make_mesh
from repro.runtime.scheduler import AdaptiveScheduler, _pow2ceil


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------

def test_make_mesh_compat_old_and_new_api(monkeypatch):
    # whatever jax this is, the helper must produce a working mesh
    m = make_mesh((1, 1), ("a", "b"))
    assert dict(m.shape) == {"a": 1, "b": 1}

    real_make_mesh = jax.make_mesh

    # new-jax surface: AxisType exists and make_mesh takes axis_types
    class FakeAxisType:
        Auto = "auto"

    seen = {}

    def new_make_mesh(shapes, names, *, axis_types=None):
        seen["axis_types"] = axis_types
        return real_make_mesh(shapes, names)

    monkeypatch.setattr(jax, "make_mesh", new_make_mesh)
    monkeypatch.setattr(
        jax.sharding, "AxisType", FakeAxisType, raising=False
    )
    m = make_mesh((1, 1), ("a", "b"))
    assert seen["axis_types"] == ("auto", "auto")
    assert dict(m.shape) == {"a": 1, "b": 1}

    # mid-version surface: AxisType exists, make_mesh predates the kwarg
    def old_make_mesh(shapes, names):
        return real_make_mesh(shapes, names)

    monkeypatch.setattr(jax, "make_mesh", old_make_mesh)
    m = make_mesh((1, 1), ("a", "b"))
    assert dict(m.shape) == {"a": 1, "b": 1}


def test_grad_through_barrier_under_scan_and_remat():
    """jax 0.4.x regression: grad of optimization_barrier inside
    scan-of-checkpoint raised NotImplementedError; the custom_jvp wrapper
    must be numerically an identity for both primal and gradient."""
    from repro.models.transformer import grad_safe_barrier

    def net(w, use_barrier):
        def layer(x, _):
            h = jnp.tanh(x @ w)
            if use_barrier:
                h = grad_safe_barrier(h)
            return h, ()

        y, _ = jax.lax.scan(
            jax.checkpoint(layer), jnp.ones((4,)), None, length=3
        )
        return jnp.sum(y * y)

    w = jnp.asarray(np.random.default_rng(0).standard_normal((4, 4)) * 0.3,
                    jnp.float32)
    loss_b, grad_b = jax.value_and_grad(lambda w: net(w, True))(w)
    loss_p, grad_p = jax.value_and_grad(lambda w: net(w, False))(w)
    np.testing.assert_allclose(float(loss_b), float(loss_p), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(grad_b), np.asarray(grad_p), rtol=1e-6, atol=1e-7
    )


# ---------------------------------------------------------------------------
# Engine cache
# ---------------------------------------------------------------------------

def test_engine_cache_hit_miss_by_key():
    csr = erdos_renyi(96, 4.0, seed=4)
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=32, phase1_iters=2
    )
    srcs = np.array([0, 7, 23], np.int32)

    sched.query(srcs)
    n0, miss0 = len(sched.cache), sched.cache.misses
    assert n0 == miss0 and sched.cache.hits == 0
    assert n0 >= 1  # at least the phase-1 engine

    # same (policy, edge compute, shapes) => pure cache hits, no compiles
    sched.query(np.array([1, 2, 3], np.int32))
    assert len(sched.cache) == n0
    assert sched.cache.misses == miss0
    assert sched.cache.hits >= 1

    # different edge compute => new keys, old entries untouched
    sched.query(srcs, returns_paths=True)
    assert len(sched.cache) > n0
    assert sched.cache.misses > miss0


# ---------------------------------------------------------------------------
# Two-phase hybrid == static nTkS (bit-identical state)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("returns_paths", [False, True])
def test_hybrid_state_bit_identical_to_static_ntks(returns_paths):
    csr = powerlaw(260, 5.0, seed=7)
    mesh = mesh11()
    srcs = np.array([0, 11, 42, 97, 150, 201], np.int32)
    ec = "sp_parents" if returns_paths else "sp_lengths"

    static = run_recursive_query(mesh, csr, srcs, policy_ntks(), ec)
    sched = AdaptiveScheduler(mesh, csr, max_iters=64, phase1_iters=2)
    out = sched.query(srcs, returns_paths=returns_paths)
    assert out.hybrid
    assert out.redispatched > 0  # phase 2 must actually have run

    ref = jax.tree.map(np.asarray, static.state)
    got = jax.tree.map(np.asarray, out.result.state)
    for field in ref._fields:
        a, b = getattr(ref, field), getattr(got, field)
        assert a.dtype == b.dtype and a.shape == b.shape, field
        np.testing.assert_array_equal(a, b, err_msg=field)


def test_hybrid_budget_covers_convergence_skips_phase2():
    csr = erdos_renyi(80, 4.0, seed=2)
    sched = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=64
    )
    out = sched.query(np.array([3, 9], np.int32))
    assert out.hybrid and out.redispatched == 0
    assert out.phase_ms["phase2"] == 0.0
    lv = np.asarray(out.result.state.levels)[:2, : csr.n_nodes]
    np.testing.assert_array_equal(lv[0], bfs_levels(csr, [3]))
    np.testing.assert_array_equal(lv[1], bfs_levels(csr, [9]))


def test_chunked_dispatch_matches_unchunked():
    """recommend_k-style in-flight caps split the batch; results must be
    independent of the chunking."""
    csr = erdos_renyi(120, 4.0, seed=9)
    srcs = np.random.default_rng(1).integers(
        0, csr.n_nodes, 12
    ).astype(np.int32)
    capped = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=2, max_inflight=4
    )
    plain = AdaptiveScheduler(
        mesh11(), csr, max_iters=64, phase1_iters=2
    )
    la = np.asarray(capped.query(srcs).result.state.levels)
    lb = np.asarray(plain.query(srcs).result.state.levels)
    np.testing.assert_array_equal(
        la[: len(srcs), : csr.n_nodes], lb[: len(srcs), : csr.n_nodes]
    )


# ---------------------------------------------------------------------------
# backend="recommend" default (ISSUE 3): the served default must be
# bit-identical to any explicitly pinned backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("returns_paths", [False, True])
def test_recommend_default_bit_identical_to_explicit(returns_paths):
    """The scheduler's (and serve's) default is now backend="recommend"
    (direction-optimized binned pull for the BFS family). One scheduler
    left on the default and one pinned to each explicit backend must
    produce byte-identical result states — and both must match the static
    single-engine dispatcher."""
    csr = powerlaw(240, 5.0, seed=13)
    mesh = mesh11()
    srcs = np.array([0, 9, 41, 77, 160], np.int32)
    ec = "sp_parents" if returns_paths else "sp_lengths"

    sched = AdaptiveScheduler(mesh, csr, max_iters=64, phase1_iters=2)
    assert sched.backend == "recommend"
    out = sched.query(srcs, returns_paths=returns_paths)
    ref = jax.tree.map(np.asarray, out.result.state)

    static = run_recursive_query(mesh, csr, srcs, policy_ntks(), ec)
    for field in ref._fields:
        np.testing.assert_array_equal(
            getattr(ref, field),
            np.asarray(getattr(static.state, field)),
            err_msg=f"recommend-vs-static/{field}",
        )

    for be in ("ell_push", "ell_pull", "pull_binned", "dopt", "dopt_ell"):
        pinned = AdaptiveScheduler(
            mesh, csr, max_iters=64, phase1_iters=2, backend=be
        )
        got = jax.tree.map(
            np.asarray,
            pinned.query(srcs, returns_paths=returns_paths).result.state,
        )
        for field in ref._fields:
            a, b = getattr(ref, field), getattr(got, field)
            assert a.dtype == b.dtype and a.shape == b.shape, (be, field)
            np.testing.assert_array_equal(a, b, err_msg=f"{be}/{field}")


def test_recommend_with_fitted_thresholds_bit_identical():
    """A fitted threshold table changes WHEN the switch pulls, never WHAT
    it computes: results stay bit-identical, and the fitted spec is served
    through the same engine-cache path (fresh keys, then pure hits)."""
    from repro.core import DirectionThresholds

    csr = powerlaw(200, 6.0, seed=5)
    mesh = mesh11()
    srcs = np.array([2, 30, 71], np.int32)
    base = AdaptiveScheduler(mesh, csr, max_iters=64, phase1_iters=2)
    th = DirectionThresholds(table={("powerlaw", 4): (2.0, 2.0)})
    fitted = AdaptiveScheduler(
        mesh, csr, max_iters=64, phase1_iters=2,
        direction_thresholds=th, family="powerlaw",
    )
    a = np.asarray(base.query(srcs).result.state.levels)
    b = np.asarray(fitted.query(srcs).result.state.levels)
    np.testing.assert_array_equal(a, b)
    h0, m0 = fitted.cache.hits, fitted.cache.misses
    fitted.query(srcs)
    assert fitted.cache.hits > h0 and fitted.cache.misses == m0


# ---------------------------------------------------------------------------
# Multi-tenant admission
# ---------------------------------------------------------------------------

def test_admission_packs_lanes_only_when_saturated():
    csr = powerlaw(200, 5.0, seed=3)
    sched = AdaptiveScheduler(mesh11(), csr, max_iters=64)
    rng = np.random.default_rng(0)

    # 5 tenants x 16 sources = 80 >= 64 -> one packed MS-BFS run
    tenants = {
        sched.submit(s): s
        for s in [
            rng.integers(0, csr.n_nodes, 16).astype(np.int32)
            for _ in range(5)
        ]
    }
    res = sched.flush()
    assert sched.admissions == {"ntkms": 1, "per_query": 0}
    assert set(res) == set(tenants)
    for qid, srcs in tenants.items():
        assert res[qid].shape == (len(srcs), csr.n_nodes)
        for j, s in enumerate(srcs):
            np.testing.assert_array_equal(
                res[qid][j], bfs_levels(csr, [int(s)]), err_msg=f"{qid}/{j}"
            )

    # a lone small query must NOT be packed: per-query hybrid path
    qid = sched.submit(np.array([5, 17], np.int32))
    res = sched.flush()
    assert sched.admissions["per_query"] == 1
    np.testing.assert_array_equal(res[qid][0], bfs_levels(csr, [5]))
    np.testing.assert_array_equal(res[qid][1], bfs_levels(csr, [17]))

    assert sched.flush() == {}  # nothing pending


def test_pow2ceil():
    assert [_pow2ceil(x) for x in (0, 1, 2, 3, 4, 5, 8, 9)] == [
        1, 1, 2, 4, 4, 8, 8, 16,
    ]
