"""Fused binned-pull Pallas kernel corpus (ISSUE 7 acceptance).

Three layers of parity, all bit-exact:

- kernel level: ``kernels.binned_pull.ops.binned_pull`` (Pallas, interpret
  auto-detected on CPU) vs its pure-jnp oracle (``use_ref=True``) across
  all five kernel ops, with and without visited-suppression, on ER /
  power-law / heavy-tail-hub / zero-in-degree / edgeless fixtures;
- engine level: ``pull_binned_fused`` vs ``pull_binned`` through
  ``run_recursive_query`` — final states AND iteration counts — for every
  applicable edge compute, dense and lanes, replicated and sharded state
  layouts (sharded compiles every backend's scan program twice: slow lane);
- structure level (proptest): the pack's slab-descriptor grid covers every
  nonzero-in-degree row in exactly one compute tile, zero-in-degree rows in
  none, and the padded permutation pair stays a bijection on live rows
  (``perm_pad[inv_pad[r]] == r``, pad positions all-sentinel).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from proptest import given, st_ints, st_sampled, st_seeds
from oracle import bfs_levels

from repro.core import build_operands, policy_ntks, policy_ntkms
from repro.core.dispatcher import run_recursive_query
from repro.graph.csr import CSRGraph, csr_from_edges, truncate_csr
from repro.graph.generators import erdos_renyi, powerlaw
from repro.kernels.binned_pull.binned_pull import LANE_OPS, OPS
from repro.kernels.binned_pull.ops import binned_pull, pack_tile_map
from repro.launch.mesh import make_mesh

from test_extend import heavy_tail_csr


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def star_csr(n: int) -> CSRGraph:
    """Node 0 fans out to every live node: the root has in-degree 0 and the
    trailing 8 nodes are fully isolated — both land in the zero-width
    slab."""
    dsts = np.arange(1, n - 8)
    return csr_from_edges(n, np.zeros_like(dsts), dsts)


def fixture(kind: str, seed: int = 0, n: int = 96) -> CSRGraph:
    if kind == "er":
        return erdos_renyi(n, 5.0, seed=seed)
    if kind == "pl":
        return powerlaw(n, 4.0, seed=seed)
    if kind == "hub":
        return heavy_tail_csr(n, seed=seed)
    if kind == "star":
        return star_csr(n)
    assert kind == "edgeless", kind
    return truncate_csr(erdos_renyi(n, 3.0, seed=seed), 0)


def weighted(csr: CSRGraph, seed: int) -> CSRGraph:
    rng = np.random.default_rng(seed)
    return CSRGraph(
        indptr=csr.indptr,
        indices=csr.indices,
        weights=rng.uniform(0.1, 2.0, csr.n_edges).astype(np.float32),
    )


def kernel_inputs(op: str, n_pad: int, rows_local: int, seed: int,
                  lanes: int = 4):
    """Random mid-traversal tensors: a ~30% frontier, a ~40% visited set,
    finite distances on the frontier only (the min_dist neutral elsewhere)."""
    rng = np.random.default_rng(seed)
    shape = (n_pad, lanes) if op in LANE_OPS else (n_pad,)
    mask = (rng.random(shape) < 0.3).astype(np.uint8)
    if op == "min_dist":
        gsrc = jnp.asarray(
            np.where(rng.random(n_pad) < 0.3,
                     rng.uniform(0.0, 9.0, n_pad), np.inf).astype(np.float32)
        )
        return gsrc, None  # min_dist has no suppression value
    vshape = (rows_local, lanes) if op in LANE_OPS else (rows_local,)
    vloc = jnp.asarray((rng.random(vshape) < 0.4))
    return jnp.asarray(mask), vloc


@pytest.mark.parametrize("kind", ["er", "pl", "hub", "star", "edgeless"])
def test_kernel_vs_ref_parity_all_ops(kind):
    """The Pallas kernel against the pure-jnp oracle, every op, with and
    without the visited-suppression operand, on every fixture class —
    including the edgeless graph whose pack has zero compute tiles."""
    csr = weighted(fixture(kind, seed=3), seed=4)
    ops, n_pad = build_operands(csr, "pull_binned_fused")
    pack = ops.rev_binned_pack
    for op in OPS:
        gsrc, vloc = kernel_inputs(op, n_pad, pack.rows_local, seed=11)
        for v in ([None, vloc] if vloc is not None else [None]):
            got = binned_pull(pack, gsrc, v, op=op)
            exp = binned_pull(pack, gsrc, v, op=op, use_ref=True)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(exp), err_msg=f"{kind}/{op}"
            )


# every edge compute the binned-pull scan applies to, with its morsel policy
ENGINE_CASES = [
    ("sp_lengths", policy_ntks),
    ("sp_parents", policy_ntks),
    ("reachability", policy_ntks),
    ("bellman_ford", policy_ntks),
    ("msbfs_lengths", policy_ntkms),
    ("msbfs_parents", policy_ntkms),
]


@pytest.mark.parametrize(
    "state_layout",
    ["replicated", pytest.param("sharded", marks=pytest.mark.slow)],
)
def test_engine_fused_parity_states_and_iterations(state_layout):
    """run_recursive_query under pull_binned_fused must match pull_binned
    bit-for-bit — final states AND per-morsel iteration counts (the fused
    kernel changes the scan, never the fixpoint trajectory) — and the BFS
    levels must match the numpy oracle."""
    mesh = mesh11()
    csr = weighted(powerlaw(150, 5.0, seed=3), seed=8)
    srcs = np.array([0, 11, 42], np.int32)
    for ec, pol in ENGINE_CASES:
        ref = run_recursive_query(
            mesh, csr, srcs, pol(), ec,
            state_layout=state_layout, extend="pull_binned",
        )
        got = run_recursive_query(
            mesh, csr, srcs, pol(), ec,
            state_layout=state_layout, extend="pull_binned_fused",
        )
        for fa, fb in zip(
            jax.tree_util.tree_leaves(ref.state),
            jax.tree_util.tree_leaves(got.state),
        ):
            np.testing.assert_array_equal(
                np.asarray(fa), np.asarray(fb), err_msg=ec
            )
        np.testing.assert_array_equal(
            np.asarray(ref.iterations), np.asarray(got.iterations),
            err_msg=f"{ec}: iteration counts diverged",
        )
    exp = np.stack([bfs_levels(csr, [s]) for s in srcs])
    res = run_recursive_query(
        mesh, csr, srcs, policy_ntks(), "sp_lengths",
        state_layout=state_layout, extend="pull_binned_fused",
    )
    np.testing.assert_array_equal(
        np.asarray(res.state.levels)[: len(srcs), : csr.n_nodes], exp
    )


def test_engine_fused_heavy_tail_and_star():
    """The fixtures that punish the padded reverse slab — a hub with
    in-degree ≈ n and a zero-in-degree root with an isolated tail — through
    the full engine path."""
    mesh = mesh11()
    for csr, srcs in (
        (heavy_tail_csr(120, seed=7), np.array([1, 9], np.int32)),
        (star_csr(72), np.array([0], np.int32)),
    ):
        ref = run_recursive_query(
            mesh, csr, srcs, policy_ntks(), "sp_lengths",
            extend="pull_binned",
        )
        got = run_recursive_query(
            mesh, csr, srcs, policy_ntks(), "sp_lengths",
            extend="pull_binned_fused",
        )
        np.testing.assert_array_equal(
            np.asarray(ref.state.levels), np.asarray(got.state.levels)
        )
        np.testing.assert_array_equal(
            np.asarray(ref.iterations), np.asarray(got.iterations)
        )


def test_fused_edgeless_zero_width_slab_engine():
    """An edgeless graph packs to a single zero-width slab ([n, 0]
    capacity): the fused engine must converge with zero compute tiles and
    spread nothing."""
    from repro.core.ife import run_ife

    eff = truncate_csr(erdos_renyi(64, 3.0, seed=2), 0)
    ops, n_pad = build_operands(eff, "pull_binned_fused")
    assert ops.rev_binned_pack.capacity_slots == 0
    assert len(ops.rev_binned_pack.slabs) == 0
    for ec in ("sp_lengths", "sp_parents", "bellman_ford"):
        res = run_ife(ops, jnp.array([3]), ec, extend="pull_binned_fused")
        if hasattr(res.state, "levels"):
            lv = np.asarray(res.state.levels)[:64].reshape(64, -1)[:, 0]
            assert lv[3] == 0 and (np.delete(lv, 3) != 0).all(), ec
        else:
            d = np.asarray(res.state.dist)[:64]
            assert d[3] == 0 and np.isinf(np.delete(d, 3)).all(), ec


@given(st_seeds(), st_ints(40, 160), st_sampled(["er", "pl", "hub", "star"]),
       cases=6)
def test_prop_pack_covers_every_row_exactly_once(seed, n, kind):
    """Coverage contract of the scalar-prefetched slab descriptors: the
    compute grid visits every nonzero-in-degree row in exactly one tile,
    zero-in-degree rows in none, and the padded perm/inverse pair is a
    bijection on live rows with all-sentinel pad positions."""
    csr = fixture(kind, seed=seed, n=max(n, 48))
    ops, n_pad = build_operands(csr, "pull_binned_fused")
    pack = ops.rev_binned_pack
    tile_of_row, tile_slots = pack_tile_map(pack)

    rev_deg = np.zeros(n_pad, np.int64)
    rev_deg[: csr.n_nodes] = np.asarray(csr.reverse().degrees)
    assert tile_of_row.shape == (pack.rows_local,) == (n_pad,)
    # exactly-once: live rows get one compute tile, dead rows get none
    assert (tile_of_row[rev_deg > 0] >= 0).all(), kind
    assert (tile_of_row[rev_deg == 0] == -1).all(), kind
    assert tile_slots.shape[0] == 0 or tile_of_row.max() < tile_slots.shape[0]
    assert (tile_slots > 0).all()
    # a tile's slot cost is its rows x its slab width; each row it covers
    # has true in-degree <= that width (binning invariant)
    # perm/inverse bijection on live rows
    inv = np.asarray(pack.inv_pad[0], np.int64)
    perm = np.asarray(pack.perm_pad[0], np.int64)
    np.testing.assert_array_equal(perm[inv], np.arange(pack.rows_local))
    assert np.unique(inv).size == pack.rows_local  # injective => once each
    pad_pos = np.ones(perm.size, bool)
    pad_pos[inv] = False
    assert (perm[pad_pos] == pack.rows_local).all()  # sentinel pad rows
    # the padded capacity never undercuts the source structure's
    assert pack.capacity_slots >= ops.rev_binned.capacity_slots
