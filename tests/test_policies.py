"""Policy dispatcher correctness on a 1x1 mesh (degenerate but full code path)
plus policy-equivalence invariants and the policy-layer oracles: direction-
threshold fitting recovers known crossovers, and ``recommend_backend`` is a
deterministic, total function (it never names a backend whose operands the
given bundle can't supply). Real multi-device parity is covered by
test_multidev.py (subprocess with forced host device count)."""
import itertools

import numpy as np
import jax
import jax.numpy as jnp

from oracle import bfs_levels
from proptest import given, st_ints, st_seeds

from repro.graph.generators import erdos_renyi, powerlaw
from repro.core import (
    BudgetModel,
    DirectionThresholds,
    as_spec,
    build_operands,
    count_budget_mispredicts,
    degree_bucket,
    fit_direction_thresholds,
    pow2ceil,
    run_recursive_query,
    policy_1t1s,
    policy_nt1s,
    policy_ntks,
    policy_ntkms,
    recommend_backend,
    recommend_policy,
    recommend_k,
)
from repro.core.ife import run_ife
from repro.launch.mesh import make_mesh


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _levels(res):
    return np.asarray(res.state.levels)


def test_all_policies_agree_with_oracle():
    csr = erdos_renyi(96, 4.0, seed=4)
    mesh = mesh11()
    sources = np.array([0, 7, 23], dtype=np.int32)
    expected = np.stack([bfs_levels(csr, [s]) for s in sources])

    for pol in (policy_1t1s(), policy_nt1s(), policy_ntks()):
        res = run_recursive_query(mesh, csr, sources, pol, "sp_lengths")
        got = _levels(res)[: len(sources), : csr.n_nodes]
        np.testing.assert_array_equal(got, expected, err_msg=pol.name)

    # nTkMS: one 64-lane morsel, first 3 lanes are our sources
    res = run_recursive_query(
        mesh, csr, sources, policy_ntkms(), "msbfs_lengths"
    )
    lanes = _levels(res)  # [n_morsels, n_pad, 64] uint8
    got = np.transpose(lanes[0, : csr.n_nodes, :3], (1, 0)).astype(np.int32)
    got[got == 255] = -1
    np.testing.assert_array_equal(got, expected)


@given(st_seeds(), st_ints(32, 160), st_ints(1, 12))
def test_prop_policy_equivalence(seed, n, n_sources):
    csr = powerlaw(n, 4.0, seed=seed)
    mesh = mesh11()
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, csr.n_nodes, size=n_sources).astype(np.int32)
    ref = None
    for pol in (policy_1t1s(), policy_ntks(or_impl="ring")):
        res = run_recursive_query(mesh, csr, sources, pol, "sp_lengths")
        got = _levels(res)[: len(sources), : csr.n_nodes]
        if ref is None:
            ref = got
        else:
            np.testing.assert_array_equal(got, ref)


def test_ntkms_empty_lanes_are_inert():
    csr = erdos_renyi(80, 3.0, seed=6)
    mesh = mesh11()
    res = run_recursive_query(
        mesh, csr, np.array([5], dtype=np.int32), policy_ntkms(), "msbfs_lengths"
    )
    lanes = _levels(res)[0]  # [n_pad, 64]
    # lanes 1..63 were padded -> never reach anything
    assert (lanes[:, 1:] == 255).all()
    got = lanes[: csr.n_nodes, 0].astype(np.int32)
    got[got == 255] = -1
    np.testing.assert_array_equal(got, bfs_levels(csr, [5]))


def test_parents_policy_invariant():
    from repro.core.ife import validate_parents

    csr = erdos_renyi(120, 4.0, seed=8)
    mesh = mesh11()
    src = np.array([11], dtype=np.int32)
    for pol in (policy_1t1s(), policy_ntks()):
        res = run_recursive_query(mesh, csr, src, pol, "sp_parents")
        st = jax.tree.map(lambda x: x[0], res.state)
        assert bool(
            validate_parents(
                st.levels[: csr.n_nodes],
                st.parents[: csr.n_nodes],
                jnp.asarray(src),
            )
        ), pol.name


def test_recommendations():
    assert recommend_policy(1, 32, 40.0) == "ntks"
    assert recommend_policy(8, 32, 40.0) == "ntks"
    assert recommend_policy(128, 32, 40.0) == "ntkms"
    # path outputs with huge graph: fall back (paper §5.6 OOM finding)
    assert (
        recommend_policy(
            256, 32, 35.0, returns_paths=True, n_nodes=120_000_000
        )
        == "ntks"
    )
    assert recommend_k(44.0) == 32
    assert recommend_k(535.0) == 4
    assert recommend_k(250.0) == 8


# ---------------------------------------------------------------------------
# Policy-layer oracles: threshold fitting + backend recommendation (ISSUE 3)
# ---------------------------------------------------------------------------


def _synthetic_trace(n, alpha_star, m_u=8000.0, push=1000.0):
    """A trace whose oracle-optimal direction flips exactly at
    ``m_f * alpha_star > m_u`` (beta non-binding: full frontier)."""
    iters = []
    for i in range(20):
        m_f = 150.0 * (i + 1)
        pull_wins = m_f * alpha_star > m_u
        iters.append({
            "it": i,
            "frontier": n,  # n_f*beta > n for any beta > 1
            "unvisited": n // 2,
            "m_frontier": m_f,
            "m_unexplored": m_u,
            "push_slots": push,
            "pull_slots_binned": 100.0 if pull_wins else 10 * push,
            "pull_slots_ell": 100.0 if pull_wins else 10 * push,
            "scanned_slots": push,
            "wall_ms": 0.1,
        })
    return iters


def test_fit_direction_thresholds_recovers_crossover():
    """A synthetic trace with a known optimal alpha crossover: the fitted
    alpha must land within one pow2 bucket (factor 2) of the true value,
    and the fitted table must beat Beamer's constants on its own trace."""
    n, alpha_star = 1024, 4.0
    doc = {
        "workloads": [{
            "graph": "synth", "kind": "powerlaw", "n": n,
            "n_edges": n * 8, "avg_degree": 8.0,
            "backends": {"ell_push": {
                "iterations": _synthetic_trace(n, alpha_star)
            }},
        }]
    }
    th = fit_direction_thresholds(doc)
    alpha, beta = th.table[("powerlaw", degree_bucket(8.0))]
    assert alpha_star / 2 <= alpha <= alpha_star * 2, alpha
    # fitted predicate reproduces the oracle labels over the whole trace
    for r in doc["workloads"][0]["backends"]["ell_push"]["iterations"]:
        use_pull = (r["m_frontier"] * alpha > r["m_unexplored"]) and (
            r["frontier"] * beta > n
        )
        assert use_pull == (r["pull_slots_binned"] < r["push_slots"]), r
    # degraded inputs never fail the fit: missing fields => Beamer defaults
    th0 = fit_direction_thresholds(
        {"workloads": [{"graph": "old", "kind": "er", "n": 64,
                        "n_edges": 128, "avg_degree": 2.0,
                        "backends": {"ell_push": {"iterations": [
                            {"it": 0, "frontier": 1, "scanned_slots": 9,
                             "wall_ms": 0.1}]}}}]}
    )
    assert th0.table[("er", 1)] == (14.0, 24.0)


def test_fit_direction_thresholds_mixed_sizes_one_group():
    """Two same-(family, bucket) workloads of very different node counts:
    the beta predicate must be evaluated against each record's OWN n, not
    the first workload's — a beta fitted for the small graph must still
    dispatch the big graph's iterations correctly."""
    alpha_star = 4.0
    small, big = 1024, 65536
    doc = {"workloads": [
        {"graph": "s", "kind": "powerlaw", "n": small, "n_edges": small * 8,
         "avg_degree": 8.0,
         "backends": {"ell_push": {
             "iterations": _synthetic_trace(small, alpha_star)}}},
        {"graph": "b", "kind": "powerlaw", "n": big, "n_edges": big * 8,
         "avg_degree": 8.0,
         "backends": {"ell_push": {
             "iterations": _synthetic_trace(big, alpha_star)}}},
    ]}
    th = fit_direction_thresholds(doc)
    alpha, beta = th.table[("powerlaw", 3)]
    # with each record's own n, the fit classifies BOTH workloads'
    # iterations optimally (each trace has frontier = its own n)
    for w in doc["workloads"]:
        n = w["n"]
        for r in w["backends"]["ell_push"]["iterations"]:
            use_pull = (r["m_frontier"] * alpha > r["m_unexplored"]) and (
                r["frontier"] * beta > n
            )
            assert use_pull == (
                r["pull_slots_binned"] < r["push_slots"]
            ), (w["graph"], r["it"])


def test_direction_threshold_lookup_fallbacks():
    th = DirectionThresholds(table={
        ("powerlaw", 3): (4.0, 16.0),
        ("powerlaw", 6): (30.0, 24.0),
        ("er", 2): (7.0, 12.0),
    })
    assert th.lookup("powerlaw", 8.0) == (4.0, 16.0)  # exact bucket
    assert th.lookup("powerlaw", 20.0) == (30.0, 24.0)  # nearest in family
    assert th.lookup("er", 4.0) == (7.0, 12.0)
    assert th.lookup("rmat", 4.0) == (7.0, 12.0)  # nearest cross-family
    empty = DirectionThresholds(table={})
    assert empty.lookup("powerlaw", 8.0) == (14.0, 24.0)  # Beamer default
    assert degree_bucket(1.0) == 0 and degree_bucket(8.0) == 3
    assert degree_bucket(9.0) == 4


def test_recommend_backend_deterministic_and_total():
    """recommend_backend is a pure function of its arguments (identical
    result on repeated calls across the whole argument grid) and total:
    with an operand bundle it only ever names a backend that bundle can
    actually run."""
    th = DirectionThresholds(table={("powerlaw", 3): (4.0, 16.0)})
    grid = itertools.product(
        ["sp_lengths", "sp_parents", "bellman_ford", "msbfs_lengths"],
        [2.0, 8.0, 300.0],
        [512, 10**7],
        [1, 64],
        [None, th],
    )
    for ec, deg, n, lanes, t in grid:
        r1 = recommend_backend(ec, deg, n_nodes=n, lanes=lanes,
                               family="powerlaw", thresholds=t)
        r2 = recommend_backend(ec, deg, n_nodes=n, lanes=lanes,
                               family="powerlaw", thresholds=t)
        assert r1 == r2, (ec, deg, n, lanes)
        as_spec(r1)  # always a constructible spec

    # totality vs concrete operand bundles: the recommendation must run
    csr = powerlaw(96, 4.0, seed=2)
    for built in ["ell_push", "ell_pull", "pull_binned", "dopt",
                  "dopt_ell"]:
        ops, _ = build_operands(csr, built)
        for ec, lanes in [("sp_lengths", 1), ("bellman_ford", 1),
                          ("msbfs_lengths", 64)]:
            rec = recommend_backend(
                ec, csr.avg_degree, n_nodes=csr.n_nodes, lanes=lanes,
                operands=ops, thresholds=th, family="powerlaw",
            )
            spec = as_spec(rec)
            assert not spec.needs_rev or ops.rev is not None
            assert not spec.needs_binned or ops.rev_binned is not None
            assert not spec.needs_blocks or ops.blocks is not None
            if ec != "msbfs_lengths":  # dense path: actually execute it
                run_ife(ops, jnp.array([0]), ec, extend=spec)
    # a bare-push bundle degrades all the way to ell_push
    ops_push, _ = build_operands(csr, "ell_push")
    assert recommend_backend(
        "sp_lengths", csr.avg_degree, n_nodes=csr.n_nodes,
        operands=ops_push,
    ) == "ell_push"


# ---------------------------------------------------------------------------
# Phase-1 budget model (ISSUE 5): per-(family, source-degree-bucket) windows,
# pow2-quantized quantile serving, lookup-style fallback, mispredict counters.
# ---------------------------------------------------------------------------


def test_budget_model_empty_predicts_none():
    """An empty model must predict None for every key — the scheduler's
    signal to fall back to the legacy global pow2 p90 path."""
    m = BudgetModel()
    assert len(m) == 0 and m.n_samples == 0
    assert m.predict("powerlaw", 3, 64) is None
    assert m.budget_for("powerlaw", [0, 1, 2], 64) is None
    assert m.budget_for("powerlaw", [], 64) is None
    assert m.budgets(64) == {}


def test_budget_model_pow2_quantile_serving():
    m = BudgetModel(floor=4)
    m.observe("er", 2, [5, 5, 6])
    # p90 of [5,5,6] = 5.8 -> int 5 -> +1 -> pow2 8
    assert m.predict("er", 2, 64) == 8
    m2 = BudgetModel(floor=4)
    m2.observe("er", 2, [40] * 8)
    assert m2.predict("er", 2, 64) == 64  # pow2ceil(41)
    assert m2.predict("er", 2, 32) == 32  # clamped to max_iters
    m3 = BudgetModel(floor=4)
    m3.observe("er", 2, [1, 1])
    assert m3.predict("er", 2, 64) == 4  # clamped to the floor
    assert pow2ceil(41) == 64 and pow2ceil(8) == 8 and pow2ceil(0) == 1


def test_budget_model_window_is_bounded():
    """Old observations age out: the window forgets a workload shift."""
    m = BudgetModel(window=8)
    m.observe("er", 2, [60] * 8)
    assert m.predict("er", 2, 64) == 64
    m.observe("er", 2, [3] * 8)  # window full of the new regime
    assert m.predict("er", 2, 64) == 4
    assert m.n_samples == 8


def test_budget_model_fallback_mirrors_threshold_lookup():
    """family -> nearest bucket in family -> nearest bucket globally —
    the DirectionThresholds.lookup chain, applied to budget windows."""
    m = BudgetModel()
    m.observe("er", 1, [3, 3, 3])  # -> 4
    m.observe("er", 4, [30, 30])  # -> 32
    m.observe("powerlaw", 6, [10, 10])  # -> 16
    assert m.predict("er", 1, 64) == 4  # exact
    assert m.predict("er", 2, 64) == 4  # nearest in family: bucket 1
    assert m.predict("er", 3, 64) == 32  # nearest in family: bucket 4
    assert m.predict("powerlaw", 0, 64) == 16  # family first, any distance
    assert m.predict("rmat", 5, 64) == 32  # global nearest: ("er", 4)
    assert m.predict(None, 5, 64) == 32  # no-family queries also served
    # covering budget of a mixed batch = max over its buckets
    assert m.budget_for("er", [1, 4], 64) == 32
    assert m.budget_for("er", [1], 64) == 4


def test_budget_model_empty_observations_are_ignored():
    """The all-pad guard's model half: zero-length observations (a batch
    with no real morsels) must not create windows or samples."""
    m = BudgetModel()
    m.observe("er", 2, [])
    m.observe_batch("er", [], [])
    assert len(m) == 0 and m.predict("er", 2, 64) is None


def test_count_budget_mispredicts_semantics():
    # survivors are too_low; converged morsels with trips*2 < budget are
    # too_high; inert_slots is the converged slack
    tl, th, inert = count_budget_mispredicts(
        8, trips=[8, 8, 5, 3, 2], survived=[True, True, False, False, False]
    )
    assert tl == 2
    assert th == 2  # trips 3 and 2 (2*t < 8); 5 is right-sized
    assert inert == (8 - 5) + (8 - 3) + (8 - 2)
    # the right-sized band is [budget/2, budget]: a steady depth-4 stream
    # served its own quantized budget pow2ceil(4+1)=8 never mispredicts
    tl, th, _ = count_budget_mispredicts(
        8, trips=[4, 4], survived=[False, False]
    )
    assert tl == 0 and th == 0
    # a budget at the quantization floor never counts too_high
    tl, th, inert = count_budget_mispredicts(
        4, trips=[1, 1], survived=[False, False]
    )
    assert tl == 0 and th == 0 and inert == 6
    # counters accumulate and reset on the model
    m = BudgetModel()
    m.mispredicts.count(2, 1, 9, 5)
    m.mispredicts.count(1, 0, 3, 5)
    assert (m.mispredicts.too_low, m.mispredicts.too_high) == (3, 1)
    assert m.mispredicts.inert_slots == 12 and m.mispredicts.observed == 10
    assert m.mispredicts.rate == 0.4
    m.mispredicts.reset()
    assert m.mispredicts.observed == 0 and m.mispredicts.rate == 0.0


def test_block_extend_matches_ell():
    from repro.graph.csr import ell_from_csr, blocks_from_csr
    from repro.graph.partition import pad_ell
    from repro.core.msbfs import block_extend_lanes
    from repro.core.edge_compute import ell_reach_lanes
    from repro.core.frontier import lanes_from_sources

    csr = erdos_renyi(200, 5.0, seed=10)
    block = 64
    n_pad = -(-csr.n_nodes // block) * block
    g = pad_ell(ell_from_csr(csr), shards=1, block=block)
    adj = blocks_from_csr(csr, block=block)
    lanes = lanes_from_sources(n_pad, jnp.arange(64, dtype=jnp.int32) * 3)
    ref = ell_reach_lanes(g, lanes)
    got = block_extend_lanes(adj, lanes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
