"""Policy dispatcher correctness on a 1x1 mesh (degenerate but full code path)
plus policy-equivalence invariants. Real multi-device parity is covered by
test_multidev.py (subprocess with forced host device count)."""
import numpy as np
import jax
import jax.numpy as jnp

from oracle import bfs_levels
from proptest import given, st_ints, st_seeds

from repro.graph.generators import erdos_renyi, powerlaw
from repro.core import (
    run_recursive_query,
    policy_1t1s,
    policy_nt1s,
    policy_ntks,
    policy_ntkms,
    recommend_policy,
    recommend_k,
)
from repro.launch.mesh import make_mesh


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _levels(res):
    return np.asarray(res.state.levels)


def test_all_policies_agree_with_oracle():
    csr = erdos_renyi(96, 4.0, seed=4)
    mesh = mesh11()
    sources = np.array([0, 7, 23], dtype=np.int32)
    expected = np.stack([bfs_levels(csr, [s]) for s in sources])

    for pol in (policy_1t1s(), policy_nt1s(), policy_ntks()):
        res = run_recursive_query(mesh, csr, sources, pol, "sp_lengths")
        got = _levels(res)[: len(sources), : csr.n_nodes]
        np.testing.assert_array_equal(got, expected, err_msg=pol.name)

    # nTkMS: one 64-lane morsel, first 3 lanes are our sources
    res = run_recursive_query(
        mesh, csr, sources, policy_ntkms(), "msbfs_lengths"
    )
    lanes = _levels(res)  # [n_morsels, n_pad, 64] uint8
    got = np.transpose(lanes[0, : csr.n_nodes, :3], (1, 0)).astype(np.int32)
    got[got == 255] = -1
    np.testing.assert_array_equal(got, expected)


@given(st_seeds(), st_ints(32, 160), st_ints(1, 12))
def test_prop_policy_equivalence(seed, n, n_sources):
    csr = powerlaw(n, 4.0, seed=seed)
    mesh = mesh11()
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, csr.n_nodes, size=n_sources).astype(np.int32)
    ref = None
    for pol in (policy_1t1s(), policy_ntks(or_impl="ring")):
        res = run_recursive_query(mesh, csr, sources, pol, "sp_lengths")
        got = _levels(res)[: len(sources), : csr.n_nodes]
        if ref is None:
            ref = got
        else:
            np.testing.assert_array_equal(got, ref)


def test_ntkms_empty_lanes_are_inert():
    csr = erdos_renyi(80, 3.0, seed=6)
    mesh = mesh11()
    res = run_recursive_query(
        mesh, csr, np.array([5], dtype=np.int32), policy_ntkms(), "msbfs_lengths"
    )
    lanes = _levels(res)[0]  # [n_pad, 64]
    # lanes 1..63 were padded -> never reach anything
    assert (lanes[:, 1:] == 255).all()
    got = lanes[: csr.n_nodes, 0].astype(np.int32)
    got[got == 255] = -1
    np.testing.assert_array_equal(got, bfs_levels(csr, [5]))


def test_parents_policy_invariant():
    from repro.core.ife import validate_parents

    csr = erdos_renyi(120, 4.0, seed=8)
    mesh = mesh11()
    src = np.array([11], dtype=np.int32)
    for pol in (policy_1t1s(), policy_ntks()):
        res = run_recursive_query(mesh, csr, src, pol, "sp_parents")
        st = jax.tree.map(lambda x: x[0], res.state)
        assert bool(
            validate_parents(
                st.levels[: csr.n_nodes],
                st.parents[: csr.n_nodes],
                jnp.asarray(src),
            )
        ), pol.name


def test_recommendations():
    assert recommend_policy(1, 32, 40.0) == "ntks"
    assert recommend_policy(8, 32, 40.0) == "ntks"
    assert recommend_policy(128, 32, 40.0) == "ntkms"
    # path outputs with huge graph: fall back (paper §5.6 OOM finding)
    assert (
        recommend_policy(
            256, 32, 35.0, returns_paths=True, n_nodes=120_000_000
        )
        == "ntks"
    )
    assert recommend_k(44.0) == 32
    assert recommend_k(535.0) == 4
    assert recommend_k(250.0) == 8


def test_block_extend_matches_ell():
    from repro.graph.csr import ell_from_csr, blocks_from_csr
    from repro.graph.partition import pad_ell
    from repro.core.msbfs import block_extend_lanes
    from repro.core.edge_compute import ell_reach_lanes
    from repro.core.frontier import lanes_from_sources

    csr = erdos_renyi(200, 5.0, seed=10)
    block = 64
    n_pad = -(-csr.n_nodes // block) * block
    g = pad_ell(ell_from_csr(csr), shards=1, block=block)
    adj = blocks_from_csr(csr, block=block)
    lanes = lanes_from_sources(n_pad, jnp.arange(64, dtype=jnp.int32) * 3)
    ref = ell_reach_lanes(g, lanes)
    got = block_extend_lanes(adj, lanes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
