"""Pure-numpy oracles for the recursive query engine."""
from __future__ import annotations

import collections

import numpy as np

from repro.graph.csr import CSRGraph


def bfs_levels(csr: CSRGraph, sources) -> np.ndarray:
    levels = np.full(csr.n_nodes, -1, dtype=np.int32)
    q = collections.deque()
    for s in np.atleast_1d(sources):
        s = int(s)
        if 0 <= s < csr.n_nodes and levels[s] < 0:
            levels[s] = 0
            q.append(s)
    while q:
        u = q.popleft()
        for v in csr.neighbors(u):
            v = int(v)
            if levels[v] < 0:
                levels[v] = levels[u] + 1
                q.append(v)
    return levels


def sssp(csr: CSRGraph, sources) -> np.ndarray:
    """Bellman-Ford distances (weights required)."""
    import heapq

    assert csr.weights is not None
    dist = np.full(csr.n_nodes, np.inf, dtype=np.float64)
    heap = []
    for s in np.atleast_1d(sources):
        dist[int(s)] = 0.0
        heapq.heappush(heap, (0.0, int(s)))
    while heap:
        d, u = heapq.heappop(heap)
        if d > dist[u]:
            continue
        lo, hi = csr.indptr[u], csr.indptr[u + 1]
        for v, w in zip(csr.indices[lo:hi], csr.weights[lo:hi]):
            nd = d + float(w)
            if nd < dist[int(v)] - 1e-12:
                dist[int(v)] = nd
                heapq.heappush(heap, (nd, int(v)))
    return dist
