"""Pure-numpy oracles for the recursive query engine."""
from __future__ import annotations

import collections

import numpy as np

from repro.graph.csr import CSRGraph


def bfs_levels(csr: CSRGraph, sources) -> np.ndarray:
    levels = np.full(csr.n_nodes, -1, dtype=np.int32)
    q = collections.deque()
    for s in np.atleast_1d(sources):
        s = int(s)
        if 0 <= s < csr.n_nodes and levels[s] < 0:
            levels[s] = 0
            q.append(s)
    while q:
        u = q.popleft()
        for v in csr.neighbors(u):
            v = int(v)
            if levels[v] < 0:
                levels[v] = levels[u] + 1
                q.append(v)
    return levels


def topk_dists(csr: CSRGraph, sources, k: int = 4) -> np.ndarray:
    """Weighted top-k loopy-path distances, [n, k] float32 sorted
    ascending (inf = fewer than k walks reach the node).

    Mirror of the engine's monotone full-Jacobi relax in float32: each
    round recomputes every node's k best from the seed row plus every
    in-edge candidate (parallel edges are distinct candidates), so the
    fixpoint is bit-identical to the ell_min_topk kernel's."""
    n = csr.n_nodes
    w = (
        csr.weights
        if csr.weights is not None
        else np.ones(csr.n_edges, np.float32)
    )
    ins: list[list] = [[] for _ in range(n)]
    for u in range(n):
        lo, hi = int(csr.indptr[u]), int(csr.indptr[u + 1])
        for v, wt in zip(csr.indices[lo:hi], w[lo:hi]):
            ins[int(v)].append((u, np.float32(wt)))
    seed = np.full((n, k), np.inf, np.float32)
    for s in np.atleast_1d(sources):
        s = int(s)
        if 0 <= s < n:
            seed[s, 0] = 0.0
    dists = seed.copy()
    while True:
        new = np.empty_like(dists)
        for v in range(n):
            cand = [seed[v]]
            for u, wt in ins[v]:
                cand.append((dists[u] + wt).astype(np.float32))
            new[v] = np.sort(np.concatenate(cand))[:k]
        if np.array_equal(new, dists):
            return dists
        dists = new


def ppr_mass(
    csr: CSRGraph, sources, alpha: float = 0.15, eps: float = 1e-4
) -> tuple[np.ndarray, np.ndarray, int]:
    """Personalized-PageRank residual diffusion: (mass, residual,
    iterations), all float32, mirroring the engine's synchronous push
    loop operation-for-operation (un-normalized unit seeds; nodes whose
    residual is <= eps hold their residual; out-degree-0 rows leak
    their pushed share, which is what guarantees termination)."""
    n = csr.n_nodes
    alpha = np.float32(alpha)
    eps = np.float32(eps)
    deg = np.maximum(
        (csr.indptr[1:] - csr.indptr[:-1]).astype(np.float32), 1.0
    ).astype(np.float32)
    residual = np.zeros(n, np.float32)
    for s in np.atleast_1d(sources):
        s = int(s)
        if 0 <= s < n:
            residual[s] = np.float32(1.0)
    mass = np.zeros(n, np.float32)
    frontier = np.where(residual > eps, residual, np.float32(0.0))
    it = 0
    while frontier.any():
        share = (
            (np.float32(1.0 - alpha) * frontier) / deg
        ).astype(np.float32)
        pushed = np.zeros(n, np.float32)
        for u in range(n):
            if share[u] != 0.0:
                lo, hi = int(csr.indptr[u]), int(csr.indptr[u + 1])
                np.add.at(pushed, csr.indices[lo:hi], share[u])
        residual = residual - frontier + pushed
        mass = mass + alpha * frontier
        frontier = np.where(residual > eps, residual, np.float32(0.0))
        it += 1
    return mass, residual, it


def pattern_counts(csr: CSRGraph, sources) -> tuple[np.ndarray, np.ndarray]:
    """(wedges, closed) int32 walk counts from the pooled sources: the
    number of length-2 and length-3 walks ending at each node (parallel
    edges are distinct walks) — exact matrix-power arithmetic. Sources
    seed a {0,1} indicator (duplicates collapse), like the engine."""
    n = csr.n_nodes
    x = np.zeros(n, np.int64)
    for s in np.atleast_1d(sources):
        s = int(s)
        if 0 <= s < n:
            x[s] = 1

    def push(v):
        out = np.zeros(n, np.int64)
        for u in range(n):
            if v[u]:
                lo, hi = int(csr.indptr[u]), int(csr.indptr[u + 1])
                np.add.at(out, csr.indices[lo:hi], v[u])
        return out

    hop1 = push(x)
    wedges = push(hop1)
    closed = push(wedges)
    return wedges.astype(np.int32), closed.astype(np.int32)


def sssp(csr: CSRGraph, sources) -> np.ndarray:
    """Bellman-Ford distances (weights required)."""
    import heapq

    assert csr.weights is not None
    dist = np.full(csr.n_nodes, np.inf, dtype=np.float64)
    heap = []
    for s in np.atleast_1d(sources):
        dist[int(s)] = 0.0
        heapq.heappush(heap, (0.0, int(s)))
    while heap:
        d, u = heapq.heappop(heap)
        if d > dist[u]:
            continue
        lo, hi = csr.indptr[u], csr.indptr[u + 1]
        for v, w in zip(csr.indices[lo:hi], csr.weights[lo:hi]):
            nd = d + float(w)
            if nd < dist[int(v)] - 1e-12:
                dist[int(v)] = nd
                heapq.heappush(heap, (nd, int(v)))
    return dist
