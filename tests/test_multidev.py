"""Multi-device (8 fake CPU devices) parity for collectives + policies.

Runs in a subprocess because XLA device count is locked at first jax init —
the main test process must keep seeing exactly 1 device.
"""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.collectives import or_allreduce, ring_or_u32
from repro.core import (run_recursive_query, policy_1t1s, policy_nt1s,
                        policy_ntks, policy_ntkms)
from repro.graph.generators import powerlaw
from repro.launch.mesh import make_mesh
import collections

def bfs_levels(csr, sources):
    levels = np.full(csr.n_nodes, -1, dtype=np.int32)
    q = collections.deque()
    for s in np.atleast_1d(sources):
        s = int(s)
        if levels[s] < 0:
            levels[s] = 0; q.append(s)
    while q:
        u = q.popleft()
        for v in csr.neighbors(u):
            if levels[int(v)] < 0:
                levels[int(v)] = levels[u] + 1; q.append(int(v))
    return levels

mesh = make_mesh((2, 4), ("data", "model"))

# --- collective parity: every or_allreduce impl must agree -----------------
rng = np.random.default_rng(0)
x = (rng.random((8, 1000)) < 0.2)
def run(impl):
    def f(xs):
        return or_allreduce(xs[0], ("data", "model"), impl)[None]
    sm = shard_map(f, mesh, P(("data","model"), None),
                   P(("data","model"), None))
    return np.asarray(jax.jit(sm)(jnp.asarray(x)))
ref = np.broadcast_to(x.any(axis=0), (8, 1000))
for impl in ("pmax", "allgather", "ring"):
    got = run(impl) != 0
    assert (got == ref).all(), f"or_allreduce[{impl}] mismatch"
print("collectives OK")

# --- ring on uint32 over one axis ------------------------------------------
xu = rng.integers(0, 2**32, size=(8, 37), dtype=np.uint32)
def fu(xs):
    return ring_or_u32(xs[0], "model")[None]
sm = shard_map(fu, mesh, P(("data","model"), None),
               P(("data","model"), None))
got = np.asarray(jax.jit(sm)(jnp.asarray(xu)))
expect = np.zeros_like(xu)
for d in range(2):
    grp = xu[d*4:(d+1)*4]
    r = np.bitwise_or.reduce(grp, axis=0)
    expect[d*4:(d+1)*4] = r
assert (got == expect).all(), "ring_or_u32 mismatch"
print("ring_or_u32 OK")

# --- policy parity on a real 2x4 mesh ---------------------------------------
csr = powerlaw(300, 5.0, seed=1)
sources = np.array([0, 3, 17, 44, 123, 200, 250, 280, 5, 9], dtype=np.int32)
expected = np.stack([bfs_levels(csr, [s]) for s in sources])
for pol in (policy_1t1s(), policy_nt1s(or_impl="ring"),
            policy_ntks(or_impl="allgather"), policy_ntks(or_impl="ring"),
            policy_ntks(or_impl="pmax")):
    res = run_recursive_query(mesh, csr, sources, pol, "sp_lengths")
    got = np.asarray(res.state.levels)[: len(sources), : csr.n_nodes]
    assert (got == expected).all(), f"policy {pol.name}/{pol.or_impl} mismatch"
print("policies OK")

# --- extension-backend parity on a real 2x4 mesh ----------------------------
# pull's inverse communication (global-frontier union) + the dopt lax.cond
# with psum'd predicate must agree with push under real collectives, in
# BOTH state layouts; pull_binned additionally exercises the multi-shard
# per-shard binning (4 graph shards here => stacked [K,...] slab operands)
for layout in ("replicated", "sharded"):
    for be in ("ell_pull", "pull_binned", "dopt", "dopt_ell", "block_mxu"):
        res = run_recursive_query(mesh, csr, sources, policy_ntks(),
                                  "sp_lengths", state_layout=layout,
                                  extend=be)
        got = np.asarray(res.state.levels)[: len(sources), : csr.n_nodes]
        assert (got == expected).all(), f"backend {be}/{layout} mismatch"
print("backends OK")

# nTkMS on multi-device with 70 sources -> 2 morsels over data axis
srcs70 = np.arange(70, dtype=np.int32) * 4 % csr.n_nodes
res = run_recursive_query(mesh, csr, srcs70, policy_ntkms(or_impl="ring"),
                          "msbfs_lengths")
lanes = np.asarray(res.state.levels)  # [2, n_pad, 64]
for i, s in enumerate(srcs70):
    m, l = divmod(i, 64)
    got = lanes[m, : csr.n_nodes, l].astype(np.int32)
    got[got == 255] = -1
    exp = bfs_levels(csr, [s])
    assert (got == exp).all(), f"ntkms lane {i} mismatch"
print("ntkms OK")

# Bellman-Ford merge=min across shards
res = run_recursive_query(mesh, csr, np.array([7], np.int32),
                          policy_ntks(), "bellman_ford")
dist = np.asarray(res.state.dist)[0, : csr.n_nodes]
lv = bfs_levels(csr, [7]).astype(np.float64)
lv[lv < 0] = np.inf
assert np.allclose(dist, lv), "bellman-ford (unit weights) != bfs levels"
print("bellman OK")

# --- gang-scheduled phase-2 resume on a real 2x4 mesh (ISSUE 4) -------------
# skewed workload: small-diameter powerlaw component + 3 long-path straggler
# components; with a tiny pinned phase-1 budget the path-head morsels survive
# on different source shards and must be ganged into ONE multi-frontier
# re-dispatch over all 8 devices — in BOTH state layouts the final state must
# bit-match the replicated reference and the oracle (the sharded phase 2
# exercises gang_handoff + the OR reduce-scatter merge across (data, model)).
from repro.graph.csr import csr_from_edges
from repro.runtime.scheduler import AdaptiveScheduler

pl = powerlaw(200, 5.0, seed=2)
src_pl, dst_pl = pl.edge_list()
srcs_e, dsts_e, base, heads = [src_pl], [dst_pl], 200, []
for L in (40, 28, 22):
    p = np.arange(L - 1, dtype=np.int64) + base
    srcs_e += [p, p + 1]; dsts_e += [p + 1, p]
    heads.append(base); base += L
skew = csr_from_edges(base, np.concatenate(srcs_e), np.concatenate(dsts_e))
gsrcs = np.array(heads + [3, 9, 17], dtype=np.int32)
expected_g = np.stack([bfs_levels(skew, [int(s)]) for s in gsrcs])

ref_levels = None
for layout in ("replicated", "sharded"):
    sched = AdaptiveScheduler(mesh, skew, max_iters=64, phase1_iters=2)
    out = sched.query(gsrcs, state_layout=layout)
    assert out.hybrid and out.resumed_ganged >= 3, (layout, out)
    assert out.gang_width >= out.resumed_ganged, (layout, out)
    assert out.resumed_serial == 0, (layout, out)
    got = np.asarray(out.result.state.levels)[: len(gsrcs), : skew.n_nodes]
    assert (got == expected_g).all(), f"gang {layout} != oracle"
    if ref_levels is None:
        ref_levels = np.asarray(out.result.state.levels)
    else:
        assert (np.asarray(out.result.state.levels) == ref_levels).all(), \
            "sharded gang != replicated gang"
    # serial per-morsel baseline must agree bit-for-bit (replicated only:
    # the sharded phase 2 IS the gang engine)
    if layout == "replicated":
        serial = AdaptiveScheduler(mesh, skew, max_iters=64, phase1_iters=2,
                                   gang_resume=False)
        sout = serial.query(gsrcs)
        assert sout.resumed_serial == out.resumed_ganged, (sout, out)
        assert (np.asarray(sout.result.state.levels) == ref_levels).all(), \
            "serial resume != gang resume"
print("gang OK")

# --- divergent-trip sharded phase 1 (ISSUE 9 deadlock regression) -----------
# sync="shard" lets source-shard groups exit the phase-1 while_loop at
# different trip counts. psum/pmin/all_gather rendezvous per replica group,
# so that divergence is safe — but the min/sum reduce-scatter merges of the
# sharded new-kind engines used ppermute rings, and a ring lowers to ONE
# CollectivePermute whose rendezvous spans every device: the group still
# iterating deadlocked forever once the other group exited. Budget 14 sits
# between this graph's group convergence depths (13 vs 15), so one group
# exits early while the other survives into the gang phase 2 — the exact
# pre-fix hang shape. A deadlock here trips the faulthandler exit below
# rather than the outer 900 s subprocess timeout.
import faulthandler
faulthandler.dump_traceback_later(300, exit=True)
from repro.runtime.dispatch import QueryDispatcher

rngq = np.random.default_rng(3)
nq, mq = 300, 1800
wq = rngq.uniform(0.1, 2.0, mq).astype(np.float32)
csrq = csr_from_edges(
    nq, rngq.integers(0, nq, mq), rngq.integers(0, nq, mq), weights=wq
)
srcsq = np.array([0, 3, 17, 44], dtype=np.int32)
# per-kind budgets straddle this graph's source-group convergence depths:
# topk converges at [12,13 | 15,12] trips per group, ppr at [46,41 | 42,50]
for kind, leaf, budget in (("topk_paths", "dists", 14), ("ppr", "mass", 48)):
    dq = QueryDispatcher(mesh, csrq, max_iters=512, phase1_iters=budget)
    refq = None
    for lay in ("replicated", "sharded"):
        out = dq.query(srcsq, query_kind=kind, state_layout=lay)
        assert out.hybrid and out.redispatched >= 1, (kind, lay, out)
        got = np.asarray(getattr(out.result.state, leaf))[:, :nq]
        its = np.asarray(out.result.iterations)
        if refq is None:
            refq, ref_its = got, its
        else:
            assert (its == ref_its).all(), (kind, its, ref_its)
            if kind == "ppr":
                np.testing.assert_allclose(got, refq, rtol=1e-6, atol=1e-9)
            else:
                assert (got == refq).all(), f"{kind} sharded != replicated"
faulthandler.cancel_dump_traceback_later()
print("divergent-shard OK")
print("ALL_MULTIDEV_OK")
"""


import pytest


@pytest.mark.slow
def test_multidev_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=900,
        env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_MULTIDEV_OK" in r.stdout
