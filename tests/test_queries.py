"""New query-scenario families through the UNCHANGED serving stack
(ISSUE 9 tentpole acceptance).

Weighted top-k path distances, personalized-PageRank diffusion, and
2/3-hop pattern (wedge/triangle-walk) counts are registered as first-class
edge computes and must flow through admission -> hybrid dispatch -> online
learning with zero scheduler-layer special-casing: the same AdmissionQueue
plans them (solo — none has a saturating lane form), the same
QueryDispatcher serves them through the two-phase hybrid + gang resume,
and every result is bit-identical to the pure-numpy oracle in BOTH engine
state layouts. Also pins the lanes_ok capability guard (weighted/new-kind
submissions are provably never MS-BFS lane-packed) and the block_mxu ==
ell_push exactness of integer pattern counts.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from proptest import given, st_ints, st_seeds
from oracle import pattern_counts, ppr_mass, topk_dists

from repro.graph.csr import CSRGraph, csr_from_edges
from repro.core import EDGE_COMPUTES, QUERY_KINDS, build_operands
from repro.core.edge_compute import PPRDiffusion, TopKPaths
from repro.core.extend import ExtendSpec, GraphOperands, as_spec
from repro.core.ife import run_ife
from repro.runtime.dispatch import QueryDispatcher
from repro.runtime.service import ServingLoop
from repro.launch.mesh import make_mesh


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def weighted_csr(n=96, m=640, seed=0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.1, 2.0, m).astype(np.float32)
    return csr_from_edges(
        n, rng.integers(0, n, m), rng.integers(0, n, m), weights=w
    )


def query_operands(csr, block=128):
    """One bundle carrying forward + reverse + block operands at a common
    pad, so every new-kind backend scans the identical edge set."""
    pull, n1 = build_operands(csr, "ell_pull", block=block)
    blk, n2 = build_operands(
        csr, ExtendSpec(backend="block_mxu", block=block), block=block
    )
    assert n1 == n2
    return GraphOperands(fwd=pull.fwd, rev=pull.rev, blocks=blk.blocks), n1


def test_query_kinds_registry_consistent():
    # every non-reach kind names a registered edge compute whose LANES_OK
    # capability matches the registry bit the admission/dispatch guards
    # read — the one source of truth for "can this pack into lanes"
    assert QUERY_KINDS["reach"].edge_compute is None
    for name, kind in QUERY_KINDS.items():
        if kind.edge_compute is None:
            continue
        ec = EDGE_COMPUTES[kind.edge_compute]
        assert getattr(ec, "LANES_OK") == kind.lanes_ok, name
        assert len(kind.result_leaves) >= 1, name
    # the weighted relax computes advertise no lane form
    assert not QUERY_KINDS["topk_paths"].lanes_ok
    assert not QUERY_KINDS["ppr"].lanes_ok
    assert not QUERY_KINDS["pattern_counts"].lanes_ok
    assert not EDGE_COMPUTES["bellman_ford"].LANES_OK
    assert EDGE_COMPUTES["msbfs_lengths"].LANES_OK


@given(st_seeds(), st_ints(48, 128), cases=3)
def test_prop_new_kinds_oracle_parity_run_ife(seed, n):
    """run_ife fixpoints == numpy oracles, bitwise, on random weighted
    graphs — the kernel-level ground truth the serving parity builds on."""
    csr = weighted_csr(n=n, m=6 * n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    srcs = rng.integers(0, n, size=2).astype(np.int32)
    ops, n_pad = query_operands(csr)

    r = run_ife(ops, srcs, "topk_paths", max_iters=512, extend="ell_pull")
    np.testing.assert_array_equal(
        np.asarray(r.state.dists)[:n], topk_dists(csr, srcs, k=TopKPaths.K)
    )

    r = run_ife(ops, srcs, "ppr", max_iters=512, extend="ell_push")
    mass, residual, iters = ppr_mass(
        csr, srcs, alpha=PPRDiffusion.ALPHA, eps=PPRDiffusion.EPS
    )
    # XLA's scatter-add visits a row's in-edges in a different order than
    # np.add.at, so engine-vs-ORACLE is ULP-tolerant; engine-vs-engine
    # (layouts, backends, replays) stays bitwise elsewhere in this file
    np.testing.assert_allclose(
        np.asarray(r.state.mass)[:n], mass, rtol=1e-5, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(r.state.residual)[:n], residual, rtol=1e-5, atol=1e-7
    )
    assert int(np.asarray(r.iterations)) == iters
    # epsilon termination: every node's residual is settled at exit
    assert (np.asarray(r.state.residual)[:n] <= PPRDiffusion.EPS).all()

    r = run_ife(ops, srcs, "pattern_counts", max_iters=512)
    wedges, closed = pattern_counts(csr, srcs)
    np.testing.assert_array_equal(np.asarray(r.state.wedges)[:n], wedges)
    np.testing.assert_array_equal(np.asarray(r.state.closed)[:n], closed)
    assert int(np.asarray(r.iterations)) == 3


def test_pattern_counts_block_mxu_bitwise_vs_push():
    """Integer walk counts are associative sums: the MXU block-matmul
    chain must equal the ELL push scatter bit-for-bit on real rows."""
    csr = weighted_csr(n=100, m=1400, seed=3)
    ops, n_pad = query_operands(csr)
    srcs = np.array([5, 9], np.int32)
    a = run_ife(ops, srcs, "pattern_counts", max_iters=16, extend="ell_push")
    b = run_ife(
        ops, srcs, "pattern_counts", max_iters=16,
        extend=ExtendSpec(backend="block_mxu", block=128),
    )
    n = csr.n_nodes
    np.testing.assert_array_equal(
        np.asarray(a.state.wedges)[:n], np.asarray(b.state.wedges)[:n]
    )
    np.testing.assert_array_equal(
        np.asarray(a.state.closed)[:n], np.asarray(b.state.closed)[:n]
    )
    assert int(np.asarray(a.iterations)) == int(np.asarray(b.iterations))


def test_new_kinds_through_unchanged_stack_both_layouts():
    """The headline acceptance: all three families served through the
    stock AdmissionQueue -> QueryDispatcher -> ServingLoop (no layer
    special-cases them beyond compute registration), oracle-identical in
    the replicated AND sharded engine state layouts."""
    csr = weighted_csr(n=96, m=640, seed=1)
    n = csr.n_nodes
    loop = ServingLoop(mesh11(), csr, max_iters=512)
    t_topk = loop.submit([3, 17], query_kind="topk_paths")
    t_ppr = loop.submit([5], query_kind="ppr")
    t_pat = loop.submit([7, 9], query_kind="pattern_counts")
    t_reach = loop.submit([0, 1])  # reach rides the same stream
    res = loop.drain()

    # per-source result rows against the oracles
    for i, s in enumerate([3, 17]):
        np.testing.assert_array_equal(
            res[t_topk.qid][i], topk_dists(csr, [s], k=TopKPaths.K)
        )
    mass, _, _ = ppr_mass(csr, [5])
    np.testing.assert_allclose(res[t_ppr.qid][0], mass, rtol=1e-5, atol=1e-7)
    for i, s in enumerate([7, 9]):
        wedges, closed = pattern_counts(csr, [s])
        np.testing.assert_array_equal(res[t_pat.qid]["wedges"][i], wedges)
        np.testing.assert_array_equal(res[t_pat.qid]["closed"][i], closed)
    assert res[t_reach.qid].shape == (2, n)

    # the stack really served them: one dispatcher, shared engine cache,
    # stats accounted — and nothing was lane-packed
    assert loop.stats.batches == 4
    assert loop.dispatcher.stats.queries == 4
    assert not any(k.policy.lanes > 1 for k in loop.dispatcher.cache.keys())

    # sharded engine layout is bit-identical through the same dispatcher
    d = QueryDispatcher(mesh11(), csr, max_iters=512)
    for kind, leaves, srcs in [
        ("topk_paths", ("dists",), [3, 17]),
        ("ppr", ("mass", "residual"), [5]),
        ("pattern_counts", ("wedges", "closed"), [7, 9]),
    ]:
        rep = d.query(srcs, query_kind=kind, state_layout="replicated")
        sh = d.query(srcs, query_kind=kind, state_layout="sharded")
        for leaf in leaves:
            np.testing.assert_array_equal(
                np.asarray(getattr(rep.result.state, leaf)),
                np.asarray(getattr(sh.result.state, leaf)),
                err_msg=f"{kind}.{leaf}",
            )
        np.testing.assert_array_equal(
            np.asarray(rep.result.iterations),
            np.asarray(sh.result.iterations),
        )


def test_lanes_ok_kinds_never_lane_packed():
    """Satellite guard: submissions of kinds with no saturating lane form
    are NEVER pooled into the shared MS-BFS lane pack, no matter how many
    sources are queued — and a caller pinning a lane policy gets a loud
    error instead of silent corruption."""
    csr = weighted_csr(seed=2)
    loop = ServingLoop(mesh11(), csr, max_iters=64)
    # 72 pooled sources would normally tip recommend_policy into ntkms
    for i in range(72):
        loop.submit([int(i % csr.n_nodes)], query_kind="ppr")
    plan = loop.admission.plan(now=loop.clock())
    assert len(plan.batches) == 72
    assert not any(pb.packed for pb in plan.batches)
    assert all(pb.policy is None for pb in plan.batches)
    assert all(pb.query_kind == "ppr" for pb in plan.batches)

    # mixed stream: the reach pool still packs, the weighted kinds stay
    # solo and do not tip the pool's policy decision
    loop2 = ServingLoop(mesh11(), csr, max_iters=64)
    for i in range(70):
        loop2.submit([int(i % csr.n_nodes)])
    for i in range(3):
        loop2.submit([int(i)], query_kind="topk_paths")
    plan2 = loop2.admission.plan(now=loop2.clock())
    packed = [pb for pb in plan2.batches if pb.packed]
    unpacked = [pb for pb in plan2.batches if not pb.packed]
    assert len(packed) == 1 and packed[0].query_kind == "reach"
    assert len(unpacked) == 3
    assert all(pb.query_kind == "topk_paths" for pb in unpacked)

    # dispatch-layer re-check: pinning a lane policy onto a lane-less
    # kind raises; the auto-recommended path degrades to per-source
    # morsels (ntks), never ntkms
    d = QueryDispatcher(mesh11(), csr, max_iters=64)
    many = np.arange(72, dtype=np.int32) % csr.n_nodes
    out = d.query(many, query_kind="ppr")
    assert out.policy == "ntks"
    with pytest.raises(ValueError, match="no lane form"):
        d.query(many, query_kind="ppr", policy="ntkms")


def test_query_kind_validation():
    csr = weighted_csr(seed=4)
    loop = ServingLoop(mesh11(), csr, max_iters=32)
    with pytest.raises(ValueError, match="unknown query_kind"):
        loop.submit([1], query_kind="nope")
    d = QueryDispatcher(mesh11(), csr, max_iters=32)
    with pytest.raises(ValueError, match="unknown query_kind"):
        d.query([1], query_kind="nope")
    with pytest.raises(ValueError, match="returns_paths"):
        d.query([1], query_kind="ppr", returns_paths=True)


def test_topk_local_extend_is_pull_only():
    ec = EDGE_COMPUTES["topk_paths"]
    csr = weighted_csr(seed=5)
    ops, _ = query_operands(csr)
    state = ec.init(csr.n_nodes, jnp.asarray([0], jnp.int32))
    with pytest.raises(NotImplementedError):
        ec.local_extend(ops.fwd, state)
