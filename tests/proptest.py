"""Minimal hypothesis-style property testing harness.

``hypothesis`` is not installable in this offline container, so this provides
the subset we need: seeded strategy sweeps with a deterministic case list and
first-failure reporting. Usage:

    @given(st_ints(1, 64), st_seeds())
    def test_foo(n, seed): ...

Each decorated test runs N_CASES deterministic samples; failures report the
exact arguments so the case is reproducible as a plain call.
"""
from __future__ import annotations

import functools
import itertools
import os

import numpy as np

N_CASES = int(os.environ.get("PROPTEST_CASES", "12"))


def st_ints(lo: int, hi: int):
    def draw(rng):
        return int(rng.integers(lo, hi + 1))

    return draw


def st_floats(lo: float, hi: float):
    def draw(rng):
        return float(rng.uniform(lo, hi))

    return draw


def st_seeds():
    return st_ints(0, 2**31 - 1)


def st_sampled(options):
    def draw(rng):
        return options[int(rng.integers(0, len(options)))]

    return draw


def st_subset(options, min_size: int = 0):
    """Random subset (stable order) of ``options`` with at least
    ``min_size`` elements — e.g. which straggler components a fuzz case
    seeds sources on."""
    opts = list(options)

    def draw(rng):
        k = int(rng.integers(min_size, len(opts) + 1))
        pick = rng.choice(len(opts), size=k, replace=False)
        return [opts[i] for i in sorted(pick)]

    return draw


def given(*strategies, cases: int | None = None):
    n_cases = cases or N_CASES

    def deco(fn):
        def wrapper():
            for case in range(n_cases):
                rng = np.random.default_rng(1_000_003 * case + 17)
                args = tuple(s(rng) for s in strategies)
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"property failed on case {case} args={args!r}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
