"""Scale-out regression suite (ISSUE 10): degree-chunked gathers stay
bitwise-exact on non-pow2 widths, the int32 node-id range is guarded,
the streamed per-shard operand build matches the wholesale build
bit-for-bit, and multi-device / multi-process ``prepare_graph`` places
identical shards (subprocess tests, marked ``slow``)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

import repro.core.edge_compute as EC
import repro.core.extend as E
from repro.core import NO_PARENT, build_operands, operand_stream
from repro.graph.csr import CSRGraph, csr_from_edges
from repro.graph.partition import slab_edges


# ---------------------------------------------------------------------------
# satellite: _deg_chunk pow2 fix


def test_deg_chunk_returns_pow2():
    """The chunk must be a power of two (so it divides pow2-padded slab
    widths and chunk_fold's remainder tail handles the rest) — the old
    round-to-multiple-of-8 result tripped ``D % chunk`` asserts on
    non-pow2 refined-bucket widths."""
    # regression: budget 72 bytes / 1 per-slot -> 72 slots -> largest
    # pow2 is 64... capped by rows*width arithmetic: old code gave 24
    assert EC._deg_chunk(3, 1, 72) == 16
    for rows, width, budget in [(3, 1, 72), (100, 8, 4096), (7, 4, 999),
                                (1, 1, 3), (1000, 64, 2 << 20)]:
        c = EC._deg_chunk(rows, width, budget)
        assert c >= 1
        assert c & (c - 1) == 0, f"not a pow2: {c}"
        assert rows * c * width <= max(budget, rows * width)


def test_chunk_fold_covers_remainder_tail():
    """chunk_fold(D, chunk) with chunk ∤ D must still visit every column
    exactly once (full chunks + one static remainder tail)."""
    for D, chunk in [(24, 16), (305, 64), (7, 8), (16, 16), (129, 32),
                     (1, 1)]:
        x = jnp.arange(D, dtype=jnp.int32)

        def step(start, width, acc):
            return acc + lax.dynamic_slice_in_dim(x, start, width).sum()

        total = E.chunk_fold(D, chunk, step, jnp.int32(0))
        assert int(total) == D * (D - 1) // 2, (D, chunk)


# ---------------------------------------------------------------------------
# satellite: chunked gathers bitwise-identical on a huge-hub fixture


def _hub_graph(n=512, hub_deg=300, seed=3):
    rng = np.random.default_rng(seed)
    src = np.concatenate([
        rng.integers(0, n, 3 * n),
        np.arange(hub_deg) % (n - 1) + 1,
    ])
    dst = np.concatenate([
        rng.integers(0, n, 3 * n),
        np.zeros(hub_deg, np.int64),
    ])
    return csr_from_edges(n, src, dst)


@pytest.fixture
def tiny_budget(monkeypatch):
    """Force every _deg_chunk call site (extend + edge_compute) down to a
    tiny byte budget so even the fixture's modest slabs get chunked."""
    orig = EC._deg_chunk

    def forced(rows, width, budget=0):
        # small enough that even the 1-row hub slab (per_slot = L) gets
        # a chunk narrower than its ~300-col width
        return orig(rows, width, 1024)

    monkeypatch.setattr(EC, "_deg_chunk", forced)
    monkeypatch.setattr(E, "_deg_chunk", forced)
    return forced


def test_binned_slab_gathers_chunk_parity(tiny_budget):
    csr = _hub_graph()
    ops, n_pad = build_operands(csr, extend="pull_binned")
    bn = ops.rev_binned
    widths = [int(s.shape[-1]) for s in bn.slabs]
    L = 8
    rng = np.random.default_rng(5)
    gl = jnp.asarray((rng.random((n_pad, L)) < 0.3).astype(np.uint8))

    # the hub slab must actually be wider than the forced chunk
    assert max(widths) > E._deg_chunk(int(bn.slabs[-1].shape[-2]), L)

    got_reach = np.asarray(E._binned_map(
        bn, lambda b, s: E._slab_gather_lanes(s, gl),
        lambda r: jnp.zeros((r, L), gl.dtype),
    ))
    got_par = np.asarray(E._binned_map(
        bn, lambda b, s: E._slab_min_parent_lanes(s, gl),
        lambda r: jnp.full((r, L), NO_PARENT, jnp.int32),
    ))

    # oracle: plain unchunked gathers over the same slabs
    def reach_ref(b, s):
        return gl.at[s].get(mode="fill", fill_value=0).max(axis=1)

    def par_ref(b, s):
        act = gl.at[s].get(mode="fill", fill_value=0)
        cand = jnp.where(act != 0, s[:, :, None].astype(jnp.int32),
                         NO_PARENT)
        return cand.min(axis=1)

    ref_reach = np.asarray(E._binned_map(
        bn, reach_ref, lambda r: jnp.zeros((r, L), gl.dtype)))
    ref_par = np.asarray(E._binned_map(
        bn, par_ref, lambda r: jnp.full((r, L), NO_PARENT, jnp.int32)))
    np.testing.assert_array_equal(got_reach, ref_reach)
    np.testing.assert_array_equal(got_par, ref_par)


def test_pull_and_topk_chunk_parity(tiny_budget):
    """The ELL pull gathers and the k-best relax stay bitwise-identical
    under forced chunking (non-pow2 forward widths -> remainder tail)."""
    csr = _hub_graph(n=256, hub_deg=150)
    w = np.random.default_rng(9).random(csr.n_edges).astype(np.float32)
    csr = CSRGraph(csr.indptr, csr.indices, weights=w)
    ops, n_pad = build_operands(csr, extend="ell_pull")
    rev = ops.rev
    L = 8
    rng = np.random.default_rng(5)
    gl = jnp.asarray((rng.random((n_pad, L)) < 0.3).astype(np.uint8))

    got_r = np.asarray(E._pull_gather_lanes(rev, gl))
    got_p = np.asarray(E._pull_min_parent_lanes(rev, gl))
    ref_r = np.asarray(
        gl.at[rev.indices].get(mode="fill", fill_value=0).max(axis=1)
    )
    act = gl.at[rev.indices].get(mode="fill", fill_value=0)
    ref_p = np.asarray(jnp.where(
        act != 0, rev.indices[:, :, None].astype(jnp.int32), NO_PARENT
    ).min(axis=1))
    np.testing.assert_array_equal(got_r, ref_r)
    np.testing.assert_array_equal(got_p, ref_p)

    k = 4
    gd = jnp.sort(
        jnp.asarray(rng.random((n_pad, k)).astype(np.float32)), axis=1
    )
    seed_row = jnp.full((rev.indices.shape[0],), jnp.inf)
    got_tk = np.asarray(EC.ell_min_topk(rev, gd, seed_row))
    wmat = rev.weights if rev.weights is not None else jnp.ones(
        rev.indices.shape, jnp.float32)
    cand = gd.at[rev.indices].get(
        mode="fill", fill_value=jnp.inf) + wmat[:, :, None]
    allc = jnp.concatenate(
        [cand.reshape(cand.shape[0], -1), seed_row[:, None]], axis=1
    )
    ref_tk = np.asarray(jnp.sort(allc, axis=1)[:, :k])
    np.testing.assert_array_equal(got_tk, ref_tk)


# ---------------------------------------------------------------------------
# satellite: int32 node-id overflow guards


def test_csr_from_edges_rejects_int32_overflow():
    with pytest.raises(ValueError, match="2\\*\\*31"):
        csr_from_edges(2**31, np.zeros(0, np.int64), np.zeros(0, np.int64))
    # guard fires before any O(n) allocation: a huge-but-valid count is
    # the caller's problem, one past the line is ours
    with pytest.raises(ValueError):
        csr_from_edges(2**31 + 5, np.array([0]), np.array([1]))


def test_edge_keys_rejects_int32_overflow():
    from unittest import mock

    csr = csr_from_edges(4, np.array([0, 1]), np.array([1, 2]))
    with mock.patch.object(
        CSRGraph, "n_nodes", property(lambda self: 2**31)
    ):
        with pytest.raises(ValueError, match="2\\*\\*31"):
            csr.edge_keys()


# ---------------------------------------------------------------------------
# satellite: slab_edges vectorized fill + edge-count balancing


def test_slab_edges_vectorized_fill_matches_naive():
    rng = np.random.default_rng(11)
    n, m, K = 96, 600, 4
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    fsrc, fdst, bounds = slab_edges(src, dst, n, K)
    assert bounds[0] == 0 and bounds[-1] == n
    assert np.all(np.diff(bounds) >= 0)
    # every (src, dst) edge appears exactly once across the slabs; pad
    # entries carry dst == n_nodes (dropped by segment reduces)
    valid = fdst < n
    got = sorted(zip(fsrc[valid].tolist(), fdst[valid].tolist()))
    assert got == sorted(zip(src.tolist(), dst.tolist()))
    # each kept edge sits in its destination's slab (arrays are flat
    # [K * width] in slab-major order)
    width = fdst.size // K
    k_of = np.searchsorted(bounds, fdst[valid], side="right") - 1
    slab_of = np.repeat(np.arange(K), width)[valid]
    assert np.array_equal(k_of, slab_of)


def test_slab_edges_edge_balance_tightens_width():
    """On a graph whose edges concentrate in one node-balance slab,
    edge-count balancing must shrink the padded payload (uniform node
    ranges pad every slab to the hot slab's count)."""
    rng = np.random.default_rng(13)
    n, K, m = 256, 4, 1000
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n // K, m)  # all edges land in node-slab 0
    nsrc, _, nb = slab_edges(src, dst, n, K, balance="nodes")
    bsrc, _, bb = slab_edges(src, dst, n, K, balance="edges")
    assert bb[0] == 0 and bb[-1] == n
    assert np.all(np.diff(bb) >= 0)

    def max_edges(bounds):
        k_of = np.clip(
            np.searchsorted(bounds, dst, side="right") - 1, 0, K - 1
        )
        return int(np.bincount(k_of, minlength=K).max())

    assert max_edges(nb) == m  # node balance: the hot slab holds all m
    assert max_edges(bb) < m  # edge balance actually splits it
    assert bsrc.size < nsrc.size  # ... so the padded payload shrinks


# ---------------------------------------------------------------------------
# tentpole: streamed per-shard build == wholesale build, bit for bit


@pytest.mark.parametrize("extend", ["ell_push", "ell_pull",
                                    "pull_binned_fused", "block_mxu"])
@pytest.mark.parametrize("weighted", [False, True])
def test_streamed_build_matches_wholesale(extend, weighted):
    from repro.graph.generators import powerlaw

    csr = powerlaw(600, 5.0, seed=21)
    if weighted:
        w = np.random.default_rng(4).random(csr.n_edges).astype(np.float32)
        csr = CSRGraph(csr.indptr, csr.indices, weights=w)
    shards, binned = 8, 4
    ref, n_pad_ref = build_operands(
        csr, extend=extend, shards=shards, binned_shards=binned
    )
    if ref.blocks is not None:
        # the streamed build emits blocks already folded to the policy
        # shard count, exactly like prepare_graph's regrouping of the
        # wholesale fine-shard build
        import dataclasses

        from repro.core.dispatcher import _regroup_block_rows

        sb = ref.blocks
        B = sb.block_size
        ref = dataclasses.replace(ref, blocks=dataclasses.replace(
            sb,
            blocks=sb.blocks.reshape(binned, -1, B, B),
            block_rows=_regroup_block_rows(sb, binned, n_pad_ref),
            block_cols=sb.block_cols.reshape(binned, -1),
        ))
    st = operand_stream(
        csr, extend=extend, shards=shards, binned_shards=binned
    )
    assert st.n_pad == n_pad_ref
    pieces = [st.build_shard(k) for k in range(st.k_shards)]
    assembled = st.assemble({
        key: np.concatenate([p[key] for p in pieces], axis=0)
        for key in pieces[0]
    })

    import jax

    ref_leaves = jax.tree_util.tree_flatten_with_path(ref)[0]
    got_leaves = jax.tree_util.tree_flatten_with_path(assembled)[0]
    assert [k for k, _ in got_leaves] == [k for k, _ in ref_leaves]
    for (kp, got), (_, want) in zip(got_leaves, ref_leaves):
        name = jax.tree_util.keystr(kp)
        got, want = np.asarray(got), np.asarray(want)
        assert got.shape == want.shape, (name, got.shape, want.shape)
        assert got.dtype == want.dtype, (name, got.dtype, want.dtype)
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_operand_stream_key_set_stable_across_shards():
    from repro.graph.generators import powerlaw

    csr = powerlaw(300, 4.0, seed=2)
    st = operand_stream(csr, extend="pull_binned_fused", shards=4)
    keys = {k: set(st.build_shard(k)) for k in range(st.k_shards)}
    first = keys[0]
    assert all(v == first for v in keys.values())


# ---------------------------------------------------------------------------
# tentpole: device-placed streamed prepare_graph (subprocess, 8 devices)

_PLACED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core.dispatcher import prepare_graph
from repro.core.policies import policy_ntks, policy_nt1s
from repro.graph.generators import powerlaw
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
csr = powerlaw(500, 5.0, seed=3)
for pol in (policy_ntks(), policy_nt1s()):
    for extend in ("ell_push", "pull_binned_fused", "block_mxu"):
        ref, n_ref = prepare_graph(csr, mesh, pol, pad_shards=mesh.size,
                                   extend=extend, stream=False)
        got, n_got = prepare_graph(csr, mesh, pol, pad_shards=mesh.size,
                                   extend=extend, stream=True)
        assert n_got == n_ref
        rl = jax.tree_util.tree_flatten_with_path(ref)[0]
        gl = jax.tree_util.tree_flatten_with_path(got)[0]
        assert [k for k, _ in gl] == [k for k, _ in rl]
        for (kp, g), (_, r) in zip(gl, rl):
            name = jax.tree_util.keystr(kp)
            assert g.shape == r.shape, (name, g.shape, r.shape)
            assert g.dtype == r.dtype, (name, g.dtype, r.dtype)
            assert g.sharding.is_equivalent_to(r.sharding, g.ndim), name
            assert (np.asarray(g) == np.asarray(r)).all(), name
print("placed-parity OK")
"""


@pytest.mark.slow
def test_prepare_graph_streamed_placement():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _PLACED],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "placed-parity OK" in out.stdout


# ---------------------------------------------------------------------------
# tentpole: multi-process placement — each process builds ONLY the
# shards its addressable devices own, and those shards match wholesale

_DIST = r"""
import os, sys
pid = int(sys.argv[1])
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
try:
    jax.distributed.initialize(coordinator_address="127.0.0.1:%d",
                               num_processes=2, process_id=pid)
    assert jax.process_count() == 2
except Exception as e:  # container without distributed CPU support
    print("DIST-UNAVAILABLE", repr(e))
    sys.exit(0)

import repro.core.extend as E
from repro.core import operand_stream
from repro.core.dispatcher import prepare_graph
from repro.core.policies import policy_ntks
from repro.graph.generators import powerlaw
from repro.launch.mesh import make_mesh

mesh = make_mesh((1, 8), ("data", "model"))  # 8 shards, 4 per process
csr = powerlaw(400, 5.0, seed=6)

built = []
orig = E.OperandStream.build_shard
E.OperandStream.build_shard = (
    lambda self, k: (built.append(k), orig(self, k))[1]
)
ops, n_pad = prepare_graph(csr, mesh, policy_ntks(), pad_shards=mesh.size,
                           extend="pull_binned_fused")  # stream=None -> auto
E.OperandStream.build_shard = orig

# shard k lives on mesh column k; this process must have built exactly
# the shards whose column device is locally addressable — half of them
local_ids = {d.id for d in jax.local_devices()}
expected = sorted(
    k for k in range(8) if mesh.devices[0, k].id in local_ids
)
assert len(expected) == 4, expected
assert sorted(set(built)) == expected, (sorted(set(built)), expected)

# every addressable shard's bytes match the host-side reference build
st = operand_stream(csr, extend="pull_binned_fused", shards=mesh.size,
                    binned_shards=8)
refs = {k: st.build_shard(k) for k in set(built)}
flat = {}
for kp, leaf in jax.tree_util.tree_flatten_with_path(ops)[0]:
    flat[jax.tree_util.keystr(kp)] = leaf
names = {
    ".fwd.indices": "fwd.indices", ".fwd.degrees": "fwd.degrees",
    ".rev_binned.perm": "bn.perm", ".rev_binned.inv": "bn.inv",
    ".rev_binned_pack.inv_pad": "pack.inv_pad",
}
checked = 0
for gname, sname in names.items():
    leaf = flat[gname]
    rl = leaf.shape[0] // 8
    for sh in leaf.addressable_shards:
        k = sh.index[0].start // rl if sh.index[0].start else 0
        assert (np.asarray(sh.data) == refs[k][sname]).all(), (gname, k)
        checked += 1
assert checked > 0
print(f"proc {pid}: local-shards-only OK ({sorted(set(built))})")
"""


@pytest.mark.slow
def test_prepare_graph_multiprocess_local_shards_only():
    import socket

    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    script = _DIST % port
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", script, str(pid)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env, cwd=cwd,
        )
        for pid in (0, 1)
    ]
    outs = []
    for p in procs:
        try:
            o, e = p.communicate(timeout=900)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            pytest.fail("multi-process prepare_graph timed out")
        outs.append((p.returncode, o, e))
    for rc, o, e in outs:
        assert rc == 0, e[-4000:]
        if "DIST-UNAVAILABLE" in o:
            pytest.skip(f"jax.distributed unavailable: {o.strip()}")
    for pid, (rc, o, e) in enumerate(outs):
        assert f"proc {pid}: local-shards-only OK" in o, (o, e[-2000:])
