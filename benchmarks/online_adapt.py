"""Online policy learning under workload drift — per-bucket budget model
vs the static global-p90 baseline, plus in-flight threshold-refit parity.

The adversarial workload for a GLOBAL phase-1 budget (ROADMAP "Budget
policy learning"): one graph whose source-degree buckets predict wildly
different convergence depths — a powerlaw main component (sources of
degree >= 3 converge in a few hops) sharing the CSR with long path
components (degree-1 path heads need ~path-length iterations) — served as
a DRIFTING stream: a shallow warm-up phase, then alternating deep/shallow
batches. The static learner (one pow2-quantized p90 deque over recent
batches, ``online_adapt=False``) is structurally unable to satisfy both
phases at once: its median lags the drift, so deep batches run under a
shallow budget (every morsel survives to phase 2 — ``too_low``) and/or
shallow batches run under a deep budget (``too_high`` + inert budget
slack). The per-(family, source-degree-bucket) ``BudgetModel``
(``online_adapt=True``) keys the budget on exactly the feature that
predicts depth here, so after one observation per bucket it serves both
phases correctly.

Measured (and asserted, here and by ``scripts/ci.sh --bench-smoke``):

- **mispredict-rate floor**: after warm-up, the online learner's phase-1
  budget mispredict rate (too_low + too_high per observed real morsel)
  is strictly below the static global-p90 baseline's on the same stream;
- **threshold-refit parity**: the thresholds the scheduler refit
  in-flight from its live sample tap equal ``fit_direction_thresholds``
  run offline on the same accumulated trace (``online_trace()``), with at
  least one fitted (non-default) table entry;
- **results invariance**: final levels of the last deep batch are
  bit-identical between online and baseline schedulers and match the
  numpy BFS oracle (learning moves iteration slots, never results).

Writes machine-readable ``BENCH_online_adapt.json`` (schema validated
in-process and re-validated by the CI lane).

    PYTHONPATH=src python benchmarks/online_adapt.py [--smoke] \
        [--out BENCH_online_adapt.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

SCHEMA = 1

REQUIRED = {
    "schema": int,
    "smoke": bool,
    "workload": dict,
    "stream": dict,
    "online": dict,
    "baseline": dict,
    "thresholds": dict,
    "summary": dict,
}
SIDE_FIELDS = (
    "too_low", "too_high", "inert_slots", "observed", "rate",
    "budgets_by_batch",
)


def validate(doc: dict) -> None:
    """Schema + acceptance guards for BENCH_online_adapt.json: both
    mispredict blocks complete, the post-warm-up online rate strictly
    below the static baseline's, and the in-flight refit bit-equal to the
    offline fit of the same trace with a non-trivial table."""
    for key, ty in REQUIRED.items():
        assert key in doc, f"missing top-level field: {key}"
        assert isinstance(doc[key], ty), (key, type(doc[key]))
    assert doc["schema"] == SCHEMA, doc["schema"]
    for side in ("online", "baseline"):
        for f in SIDE_FIELDS:
            assert f in doc[side], f"missing {side} field: {f}"
        assert doc[side]["observed"] > 0, (side, doc[side])
    th = doc["thresholds"]
    for f in ("refits", "n_samples", "fitted_table", "matches_offline_fit",
              "n_fitted_entries"):
        assert f in th, f"missing thresholds field: {f}"
    assert th["matches_offline_fit"] is True, th
    assert th["refits"] >= 1 and th["n_fitted_entries"] >= 1, th
    s = doc["summary"]
    for f in ("mispredict_rate_online", "mispredict_rate_baseline",
              "passes_rate_floor", "passes_threshold_parity",
              "results_bit_identical"):
        assert f in s, f
    assert s["results_bit_identical"] is True, s
    assert s["passes_threshold_parity"] is True, s
    assert s["passes_rate_floor"] is True, (
        "online mispredict rate must be strictly below the static "
        f"global-p90 baseline: {s['mispredict_rate_online']} vs "
        f"{s['mispredict_rate_baseline']}"
    )
    assert s["mispredict_rate_online"] < s["mispredict_rate_baseline"], s


def smoke_line(doc: dict) -> str:
    """One-line artifact summary for the CI bench-smoke lane."""
    s = doc["summary"]
    return (
        f"mispredict rate {s['mispredict_rate_online']:.3f} online vs "
        f"{s['mispredict_rate_baseline']:.3f} static global-p90, "
        f"threshold refit parity {s['passes_threshold_parity']}, "
        f"results bit-identical {s['results_bit_identical']}"
    )


def drift_graph(n_pl: int, n_paths: int, path_len: int, seed: int = 0):
    """Powerlaw main component + ``n_paths`` path components in one CSR.
    Returns (csr, shallow_sources, deep_sources): shallow sources are
    main-component nodes of out-degree >= 3 (high degree buckets, small
    eccentricity), deep sources are the degree-1 path heads (bucket 0,
    ~path_len convergence depth) — source degree predicts depth, which is
    exactly the signal the per-bucket model keys on."""
    from repro.graph.csr import csr_from_edges
    from repro.graph.generators import powerlaw

    pl = powerlaw(n_pl, 6.0, seed=seed)
    src_pl, dst_pl = pl.edge_list()
    srcs, dsts, base, heads = [src_pl], [dst_pl], n_pl, []
    for _ in range(n_paths):
        p = np.arange(path_len - 1, dtype=np.int64) + base
        srcs += [p, p + 1]
        dsts += [p + 1, p]
        heads.append(base)
        base += path_len
    csr = csr_from_edges(base, np.concatenate(srcs), np.concatenate(dsts))
    shallow = np.nonzero(csr.degrees[:n_pl] >= 3)[0].astype(np.int32)
    return csr, shallow, np.asarray(heads, np.int32)


def drift_stream(shallow, deep, n_warm: int, n_drift: int,
                 batch: int, seed: int = 0):
    """The seeded batch stream: ``n_warm`` shallow batches, then
    ``n_drift`` alternating deep/shallow batches. Returns a list of
    (kind, sources) with kind in {"shallow", "deep"}."""
    rng = np.random.default_rng(seed)
    stream = []
    for _ in range(n_warm):
        stream.append(
            ("shallow", rng.choice(shallow, size=batch, replace=False))
        )
    for b in range(n_drift):
        if b % 2 == 0:
            k = min(batch, len(deep))
            stream.append(("deep", rng.choice(deep, size=k, replace=False)))
        else:
            stream.append(
                ("shallow", rng.choice(shallow, size=batch, replace=False))
            )
    return stream


def serve_stream(sched, stream, warmup_batches: int):
    """Run the stream; returns (per-batch counter rows, post-warm-up
    mispredict tallies, last deep outcome, wall seconds)."""
    import jax

    rows, last_deep = [], None
    tl = th = inert = obs = 0
    t0 = time.perf_counter()
    for b, (kind, srcs) in enumerate(stream):
        out = sched.query(np.asarray(srcs, np.int32))
        jax.block_until_ready(out.result.state)
        rows.append({
            "batch": b,
            "kind": kind,
            "phase1_budget": int(out.phase1_budget),
            "too_low": int(out.budget_too_low),
            "too_high": int(out.budget_too_high),
            "inert_slots": int(out.budget_inert_slots),
            "observed": int(out.budget_observed),
            "redispatched": int(out.redispatched),
        })
        if b >= warmup_batches:
            tl += out.budget_too_low
            th += out.budget_too_high
            inert += out.budget_inert_slots
            obs += out.budget_observed
        if kind == "deep":
            last_deep = (b, out)
    wall = time.perf_counter() - t0
    return rows, (tl, th, inert, obs), last_deep, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph / short stream (CI bench-smoke lane)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parent.parent / "BENCH_online_adapt.json"
    ))
    args = ap.parse_args(argv)

    import jax

    from common import bfs_levels_np
    from repro.core import fit_direction_thresholds
    from repro.launch.mesh import make_mesh
    from repro.runtime.scheduler import AdaptiveScheduler

    if args.smoke:
        n_pl, n_paths, path_len = 192, 3, 36
        n_warm, n_drift, batch, refit_every = 4, 10, 4, 4
    else:
        n_pl, n_paths, path_len = 384, 5, 44
        n_warm, n_drift, batch, refit_every = 6, 20, 5, 4
    max_iters = 64
    csr, shallow, deep = drift_graph(n_pl, n_paths, path_len)
    stream = drift_stream(shallow, deep, n_warm, n_drift, batch)
    # warm-up for rate accounting: the shallow phase plus the first
    # deep/shallow alternation (both learners get one look at each regime
    # before being scored)
    warmup = n_warm + 2

    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    print(
        f"drift workload: {csr.n_nodes} nodes ({len(shallow)} shallow "
        f"deg>=3 sources, {n_paths} path heads depth ~{path_len - 1}); "
        f"stream {n_warm} warm + {n_drift} alternating batches of {batch} "
        f"(scored after batch {warmup})"
    )

    online = AdaptiveScheduler(
        mesh, csr, max_iters=max_iters, family="powerlaw",
        online_adapt=True, refit_every=refit_every,
    )
    baseline = AdaptiveScheduler(
        mesh, csr, max_iters=max_iters, family="powerlaw",
        online_adapt=False,
    )
    on_rows, (on_tl, on_th, on_in, on_obs), on_deep, on_wall = serve_stream(
        online, stream, warmup
    )
    bl_rows, (bl_tl, bl_th, bl_in, bl_obs), bl_deep, bl_wall = serve_stream(
        baseline, stream, warmup
    )
    rate_on = (on_tl + on_th) / max(on_obs, 1)
    rate_bl = (bl_tl + bl_th) / max(bl_obs, 1)

    # --- threshold-refit parity: in-flight refit == offline fit of the
    # accumulated live trace -------------------------------------------------
    online.refit_thresholds()
    offline = fit_direction_thresholds(online.online_trace())
    fitted = online.direction_thresholds
    matches = fitted is not None and dict(fitted.table) == dict(offline.table)
    from repro.core.policies import BEAMER_ALPHA, BEAMER_BETA

    n_fitted = sum(
        1 for v in (fitted.table.values() if fitted else [])
        if tuple(v) != (BEAMER_ALPHA, BEAMER_BETA)
    )
    n_samples = sum(len(r) for r in online._dir_samples.values())

    # --- results invariance: learning never moves results -------------------
    (b_on, out_on), (b_bl, out_bl) = on_deep, bl_deep
    assert b_on == b_bl
    n = csr.n_nodes
    kdeep = len(stream[b_on][1])
    lv_on = np.asarray(out_on.result.state.levels)[:kdeep, :n]
    lv_bl = np.asarray(out_bl.result.state.levels)[:kdeep, :n]
    bit_identical = bool((lv_on == lv_bl).all())
    assert bit_identical, "online-vs-baseline result divergence"
    for j, s in enumerate(stream[b_on][1]):
        ref = bfs_levels_np(csr, int(s))
        assert (lv_on[j] == ref).all(), f"oracle mismatch on source {s}"

    budgets = {
        f"{fam}/2^{b}": int(v)
        for (fam, b), v in online.budget_model.budgets(max_iters).items()
    }
    print(
        f"post-warm-up mispredicts: online {on_tl} too-low / {on_th} "
        f"too-high over {on_obs} morsels (rate {rate_on:.3f}, {on_in} "
        f"inert slots) vs baseline {bl_tl}/{bl_th} over {bl_obs} "
        f"(rate {rate_bl:.3f}, {bl_in} inert slots)"
    )
    print(
        f"learned budgets {budgets}; {online.stats.refits} refit(s) from "
        f"{n_samples} live samples, offline-fit parity: {matches} "
        f"({n_fitted} fitted table entries)"
    )

    doc = {
        "schema": SCHEMA,
        "smoke": bool(args.smoke),
        "workload": {
            "n_nodes": int(csr.n_nodes),
            "n_edges": int(csr.n_edges),
            "avg_degree": float(csr.avg_degree),
            "n_shallow_sources": int(len(shallow)),
            "n_path_heads": int(n_paths),
            "path_depth": int(path_len - 1),
        },
        "stream": {
            "n_warm": n_warm,
            "n_drift": n_drift,
            "batch": batch,
            "warmup_batches_excluded": warmup,
            "refit_every": refit_every,
        },
        "online": {
            "too_low": on_tl, "too_high": on_th, "inert_slots": on_in,
            "observed": on_obs, "rate": rate_on, "wall_s": on_wall,
            "learned_budgets": budgets,
            "budgets_by_batch": [r["phase1_budget"] for r in on_rows],
            "batches": on_rows,
        },
        "baseline": {
            "too_low": bl_tl, "too_high": bl_th, "inert_slots": bl_in,
            "observed": bl_obs, "rate": rate_bl, "wall_s": bl_wall,
            "budgets_by_batch": [r["phase1_budget"] for r in bl_rows],
            "batches": bl_rows,
        },
        "thresholds": {
            "refits": int(online.stats.refits),
            "n_samples": int(n_samples),
            "fitted_table": {
                f"{fam}/2^{b}": list(v)
                for (fam, b), v in sorted(
                    (fitted.table if fitted else {}).items()
                )
            },
            "n_fitted_entries": int(n_fitted),
            "matches_offline_fit": bool(matches),
        },
        "summary": {
            "mispredict_rate_online": rate_on,
            "mispredict_rate_baseline": rate_bl,
            "inert_slots_online": on_in,
            "inert_slots_baseline": bl_in,
            "passes_rate_floor": bool(rate_on < rate_bl),
            "passes_threshold_parity": bool(matches and n_fitted >= 1),
            "results_bit_identical": bit_identical,
        },
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(
        f"summary: mispredict rate {rate_on:.3f} online vs {rate_bl:.3f} "
        f"static global-p90 "
        f"(passes_rate_floor={doc['summary']['passes_rate_floor']})"
    )
    print(f"wrote {args.out} (schema v{SCHEMA} validated)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
