"""Paper Table 5: densest-frontier visit factor per dataset.

visit factor = (edge scans targeting nodes while extending the densest
frontier) / n_nodes — the paper's proxy for L3 locality of the shared
``visited`` array. Spotify's ~500x explains why large k hurts there.
"""
from __future__ import annotations

import numpy as np

from .common import bfs_levels_np, emit


def visit_factor(csr, source: int) -> tuple:
    levels = bfs_levels_np(csr, source)
    degs = csr.degrees
    lmax = levels.max()
    best_w, best_l = 0, 0
    for l in range(lmax + 1):
        w = int(degs[levels == l].sum())
        if w > best_w:
            best_w, best_l = w, l
    return best_w, best_w / max(csr.n_nodes, 1), best_l


def main(quick: bool = False):
    from repro.graph.generators import PAPER_DATASETS, pick_sources

    scale = 0.35 if quick else 0.6
    factors = {}
    for name, gen in PAPER_DATASETS.items():
        csr = gen(scale)
        src = int(pick_sources(csr, 1, seed=3)[0])
        visits, factor, level = visit_factor(csr, src)
        factors[name] = factor
        emit(f"table5_{name}", 0.0,
             f"densest_frontier_visits={visits} factor={factor:.1f} "
             f"at_level={level}")
    # paper claim: spotify's factor dwarfs the others (498.8 vs <=29.1)
    others = max(v for k, v in factors.items() if k != "spotify")
    assert factors["spotify"] > 3 * others, (factors, "spotify locality")
    emit("table5_claim", 0.0,
         f"spotify_factor={factors['spotify']:.0f} "
         f"next_highest={others:.0f} ratio>{factors['spotify']/others:.1f}x")


if __name__ == "__main__":
    main()
