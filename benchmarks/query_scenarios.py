"""New query-scenario families through the unchanged serving stack
(ISSUE 9).

Three scenario families — weighted top-k path distances, personalized
PageRank (epsilon-terminated residual diffusion), and 2/3-hop pattern
walk counts — are registered edge computes, so the admission -> hybrid
dispatch -> online-learning stack serves them with zero scheduler-layer
special-casing. Measured here, per family, through a live
``ServingLoop`` (admission plan, two-phase hybrid, budget learners all
on):

- **serve wall**: warm per-query wall of a small submitted stream, with
  every delivered result checked against the pure-numpy oracle
  (bitwise for the monotone/int families; ULP-tolerant for PPR, whose
  scatter-add order differs from ``np.add.at``);
- **lane guard**: none of the three families has a saturating lane
  form, so no engine the stream compiled may carry a multi-lane policy
  (the MS-BFS pack path is provably never taken);
- **weighted churn** (the weighted-delta fold floor): a chain of
  weight-only deltas — each edge deleted and re-inserted at a new
  weight, so every operand keeps its exact shape — folded into the live
  bundles dirty-row-only for less total wall than the wholesale
  re-place baseline (one ``prepare_graph`` per live bundle on the
  post-delta CSR), with the folded dispatcher's top-k distances
  bit-identical to a from-scratch rebuild at the end of the chain.

Floors (asserted in-process and by ``scripts/ci.sh --bench-smoke``):
every scenario oracle-identical through the stack, no lane-packed
engine, churn fold wall < wholesale re-place wall, churn results
bit-identical to the rebuild.

Writes machine-readable ``BENCH_query_scenarios.json`` (schema
validated in-process and re-validated by the CI lane).

    PYTHONPATH=src python benchmarks/query_scenarios.py [--smoke] \
        [--out BENCH_query_scenarios.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
# the bench reuses the test corpus' numpy oracles (single source of
# truth for the scenario semantics) rather than duplicating them here
sys.path.insert(0, str(Path(__file__).parent.parent / "tests"))

SCHEMA = 1

KINDS = ("topk_paths", "ppr", "pattern_counts")

REQUIRED = {
    "schema": int,
    "smoke": bool,
    "workload": dict,
    "scenarios": list,
    "weighted_churn": dict,
    "summary": dict,
}
SCENARIO_FIELDS = (
    "kind", "edge_compute", "queries", "serve_wall_ms_per_query",
    "iterations", "oracle_match", "lane_packed", "batches",
)
CHURN_FIELDS = (
    "n_deltas", "edges_reweighted", "fold_wall_ms", "replace_wall_ms",
    "wall_speedup", "same_shape_all", "oracle_match",
)


def validate(doc: dict) -> None:
    """Schema + acceptance guards for BENCH_query_scenarios.json: all
    three scenario families served oracle-identical through the stack
    with no lane-packed engine, and the weighted-churn fold strictly
    cheaper in total wall than the wholesale re-place baseline."""
    for key, ty in REQUIRED.items():
        assert key in doc, f"missing top-level field: {key}"
        assert isinstance(doc[key], ty), (key, type(doc[key]))
    assert doc["schema"] == SCHEMA, doc["schema"]
    kinds = [s["kind"] for s in doc["scenarios"]]
    assert sorted(kinds) == sorted(KINDS), kinds
    for s in doc["scenarios"]:
        for f in SCENARIO_FIELDS:
            assert f in s, f"scenario {s.get('kind')} missing field: {f}"
        assert s["oracle_match"] is True, s
        assert s["lane_packed"] is False, (
            "a no-lane-form kind compiled a multi-lane engine", s
        )
        assert s["queries"] >= 1 and s["batches"] >= 1, s
        assert s["serve_wall_ms_per_query"] > 0, s
        assert s["iterations"] >= 1, s
    c = doc["weighted_churn"]
    for f in CHURN_FIELDS:
        assert f in c, f"weighted_churn missing field: {f}"
    assert c["n_deltas"] >= 2 and c["edges_reweighted"] >= 1, c
    assert c["same_shape_all"] is True, (
        "weight-only churn must never change an operand shape", c
    )
    assert c["oracle_match"] is True, c
    assert c["fold_wall_ms"] < c["replace_wall_ms"], (
        "weighted-delta fold must beat the wholesale re-place: "
        f"{c['fold_wall_ms']:.1f} vs {c['replace_wall_ms']:.1f} ms"
    )
    s = doc["summary"]
    for f in ("all_oracle_match", "no_lane_packing",
              "passes_churn_floor"):
        assert f in s and s[f] is True, (f, s)


def smoke_line(doc: dict) -> str:
    """One-line artifact summary for the CI bench-smoke lane."""
    per = ", ".join(
        f"{s['kind']} {s['serve_wall_ms_per_query']:.1f} ms/q "
        f"({s['iterations']} iters)"
        for s in doc["scenarios"]
    )
    c = doc["weighted_churn"]
    return (
        f"{per}; all oracle-identical, no lane packing; weighted churn "
        f"fold {c['fold_wall_ms']:.1f} ms vs re-place "
        f"{c['replace_wall_ms']:.1f} ms ({c['wall_speedup']:.2f}x)"
    )


def weighted_graph(n: int, m: int, seed: int = 0):
    from repro.graph.csr import csr_from_edges

    rng = np.random.default_rng(seed)
    w = rng.uniform(0.1, 2.0, m).astype(np.float32)
    return csr_from_edges(
        n, rng.integers(0, n, m), rng.integers(0, n, m), weights=w
    )


def _oracle_match(kind: str, csr, sources, got) -> bool:
    from oracle import pattern_counts, ppr_mass, topk_dists

    n = csr.n_nodes
    if kind == "topk_paths":
        ref = np.stack([topk_dists(csr, [int(s)]) for s in sources])
        return bool(np.array_equal(np.asarray(got), ref))
    if kind == "ppr":
        ref = np.stack([ppr_mass(csr, [int(s)])[0] for s in sources])
        # XLA scatter-add order differs from np.add.at: ULP tolerance
        # against the oracle only (engine-vs-engine parity is bitwise
        # and lives in tests/test_queries.py)
        return bool(np.allclose(np.asarray(got), ref, rtol=1e-5,
                                atol=1e-7))
    refs = [pattern_counts(csr, [int(s)]) for s in sources]
    return bool(
        np.array_equal(np.asarray(got["wedges"]),
                       np.stack([r[0] for r in refs]))
        and np.array_equal(np.asarray(got["closed"]),
                           np.stack([r[1] for r in refs]))
    )


def run_scenarios(mesh, csr, n_queries: int, max_iters: int) -> list:
    from repro.core import QUERY_KINDS
    from repro.runtime.service import ServingLoop

    import jax

    rng = np.random.default_rng(3)
    records = []
    for kind in KINDS:
        loop = ServingLoop(mesh, csr, max_iters=max_iters)
        warm = loop.submit([int(rng.integers(0, csr.n_nodes))],
                           query_kind=kind)
        loop.drain()
        subs = {}
        for _q in range(n_queries):
            s = [int(rng.integers(0, csr.n_nodes))]
            subs[loop.submit(s, query_kind=kind).qid] = s
        t0 = time.perf_counter()
        res = loop.drain()
        wall_ms = (time.perf_counter() - t0) * 1e3
        ok = all(
            _oracle_match(kind, csr, s, res[qid])
            for qid, s in subs.items()
        )
        lane_packed = any(
            k.policy.lanes > 1 for k in loop.dispatcher.cache.keys()
        )
        # iteration depth telemetry from one direct dispatch
        out = loop.dispatcher.query(
            [int(rng.integers(0, csr.n_nodes))], query_kind=kind
        )
        iters = int(np.max(np.asarray(out.result.iterations)))
        records.append({
            "kind": kind,
            "edge_compute": QUERY_KINDS[kind].edge_compute,
            "queries": int(n_queries),
            "serve_wall_ms_per_query": float(wall_ms / n_queries),
            "iterations": iters,
            "oracle_match": bool(ok),
            "lane_packed": bool(lane_packed),
            "batches": int(loop.stats.batches),
        })
        print(
            f"{kind}: {n_queries} queries in {wall_ms:.1f} ms "
            f"({wall_ms / n_queries:.1f} ms/q), {iters} iters, "
            f"oracle match {ok}, lane_packed {lane_packed}"
        )
        del warm
    return records


def run_weighted_churn(mesh, csr, n_deltas: int, edges_per_delta: int,
                       max_iters: int) -> dict:
    """Weight-only churn: delete + re-insert the same edges at new
    weights (shapes pinned by construction), fold vs wholesale re-place
    of every live bundle, top-k results checked against a rebuild."""
    import jax

    from repro.core.dispatcher import prepare_graph
    from repro.graph.delta import GraphDelta, apply_delta_csr
    from repro.runtime.dispatch import QueryDispatcher

    rng = np.random.default_rng(11)
    disp = QueryDispatcher(mesh, csr, max_iters=max_iters)
    srcs = rng.integers(0, csr.n_nodes, 4).astype(np.int32)
    for _ in range(2):  # warm the engines and the budget model
        disp.query(srcs, query_kind="topk_paths")

    cur = csr
    fold_total = replace_total = 0.0
    same_shape_all = True
    reweighted = 0
    for i in range(n_deltas):
        s, t = cur.edge_list()
        pick = np.unique(
            rng.integers(0, cur.n_edges, size=edges_per_delta)
        )
        reweighted += len(pick)
        delta = GraphDelta(
            add_src=s[pick], add_dst=t[pick],
            del_src=s[pick], del_dst=t[pick],
            add_weights=rng.uniform(0.1, 2.0, len(pick)).astype(
                np.float32
            ),
        )
        t0 = time.perf_counter()
        rep = disp.apply_delta(delta)
        jax.block_until_ready([b.ops for b in disp._graphs.values()])
        fold_ms = (time.perf_counter() - t0) * 1e3
        same_shape_all = same_shape_all and rep.same_shape
        cur = apply_delta_csr(cur, delta)

        # wholesale re-place baseline: rebuild every live bundle's
        # operand set from the post-delta CSR (what a server without
        # weight-aware folds would redo on each re-weighting)
        t0 = time.perf_counter()
        rebuilt = [
            prepare_graph(cur, mesh, b.policy, None,
                          pad_shards=mesh.size, extend=b.spec)[0]
            for b in disp._graphs.values()
        ]
        jax.block_until_ready(rebuilt)
        replace_ms = (time.perf_counter() - t0) * 1e3
        fold_total += fold_ms
        replace_total += replace_ms
        print(
            f"churn {i}: fold {fold_ms:.1f} ms vs re-place "
            f"{replace_ms:.1f} ms, same_shape={rep.same_shape}, "
            f"{len(pick)} edges reweighted"
        )

    folded = np.asarray(
        disp.query(srcs, query_kind="topk_paths").result.state.dists
    )
    rebuilt_disp = QueryDispatcher(mesh, cur, max_iters=max_iters)
    ref = np.asarray(
        rebuilt_disp.query(srcs, query_kind="topk_paths").result.state.dists
    )
    ok = bool(np.array_equal(folded, ref))
    return {
        "n_deltas": int(n_deltas),
        "edges_reweighted": int(reweighted),
        "fold_wall_ms": float(fold_total),
        "replace_wall_ms": float(replace_total),
        "wall_speedup": (
            float(replace_total / fold_total) if fold_total else 1.0
        ),
        "same_shape_all": bool(same_shape_all),
        "oracle_match": ok,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph / short stream (CI bench-smoke)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parent.parent
        / "BENCH_query_scenarios.json"
    ))
    args = ap.parse_args(argv)

    import jax

    from repro.launch.mesh import make_mesh

    if args.smoke:
        n, m, n_queries, n_deltas, per_delta = 384, 2304, 2, 4, 24
        churn_n, churn_m = 3072, 24576
    else:
        n, m, n_queries, n_deltas, per_delta = 1536, 12288, 4, 6, 64
        churn_n, churn_m = 6144, 49152
    max_iters = 512
    csr = weighted_graph(n, m)
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    print(
        f"scenario workload: {csr.n_nodes} nodes, {csr.n_edges} weighted "
        f"edges; {n_queries} queries/family through a live ServingLoop"
    )

    scenarios = run_scenarios(mesh, csr, n_queries, max_iters)
    # the churn floor gets a larger graph: the fold's wall scales with
    # the dirty rows, the re-place baseline's with the whole operand
    # set, and the gap is the point being measured
    churn = run_weighted_churn(
        mesh, weighted_graph(churn_n, churn_m, seed=1), n_deltas,
        per_delta, max_iters,
    )

    doc = {
        "schema": SCHEMA,
        "smoke": bool(args.smoke),
        "workload": {
            "n_nodes": int(csr.n_nodes),
            "n_edges": int(csr.n_edges),
            "weighted": True,
            "queries_per_family": int(n_queries),
            "max_iters": int(max_iters),
        },
        "scenarios": scenarios,
        "weighted_churn": churn,
        "summary": {
            "all_oracle_match": bool(
                all(s["oracle_match"] for s in scenarios)
                and churn["oracle_match"]
            ),
            "no_lane_packing": bool(
                not any(s["lane_packed"] for s in scenarios)
            ),
            "passes_churn_floor": bool(
                churn["fold_wall_ms"] < churn["replace_wall_ms"]
            ),
        },
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(f"summary: {smoke_line(doc)}")
    print(f"wrote {args.out} (schema v{SCHEMA} validated)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
