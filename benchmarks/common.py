"""Shared benchmark utilities: frontier traces, timing, CSV rows.

Methodology note (CPU-only container): the paper's headline tables measure
multi-thread scalability on a 32-vcore Xeon. This box exposes ONE core, so
thread-scaling numbers are produced by a discrete-event simulation of the
morsel dispatching policies (benchmarks/sched_sim.py) driven by MEASURED
per-frontier work traces from the real graphs/engine; absolute work claims
(scan sharing, visit factors, frontier shapes) are measured directly on the
engine. The TPU-mapping performance story lives in the dry-run roofline
(benchmarks/roofline.py), which is hardware-model-based by design.
"""
from __future__ import annotations

import collections
import time

import numpy as np

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall-time in microseconds (jax results block via tree leaves)."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bfs_levels_np(csr, source: int) -> np.ndarray:
    """Vectorized numpy BFS: levels[-1] = unreached."""
    levels = np.full(csr.n_nodes, -1, dtype=np.int32)
    levels[source] = 0
    frontier = np.asarray([source], dtype=np.int64)
    l = 0
    indptr, indices = csr.indptr, csr.indices
    while frontier.size:
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        base = np.repeat(starts, counts)
        offs = np.arange(total) - np.repeat(
            np.cumsum(counts) - counts, counts
        )
        nbrs = indices[base + offs]
        new = np.unique(nbrs[levels[nbrs] < 0])
        if new.size == 0:
            break
        l += 1
        levels[new] = l
        frontier = new
    return levels


def frontier_trace(csr, source: int):
    """Per-level (n_active_nodes, edge_scan_work) for one IFE run.

    edge_scan_work = sum of out-degrees of the level's frontier — the
    paper's unit of frontier-morsel work (adjacency scans).
    """
    levels = bfs_levels_np(csr, source)
    degs = csr.degrees
    out = []
    lmax = levels.max()
    for l in range(lmax + 1):
        mask = levels == l
        out.append((int(mask.sum()), int(degs[mask].sum())))
    return out, levels


def union_trace(csr, sources) -> list:
    """MS-BFS union work: at iteration l, the nodes active in ANY lane.

    All lanes advance in lockstep (paper §3.4), so the shared edge scan per
    iteration covers the union frontier once instead of once per lane.
    """
    all_levels = np.stack([bfs_levels_np(csr, int(s)) for s in sources])
    degs = csr.degrees
    lmax = int(all_levels.max())
    out = []
    for l in range(lmax + 1):
        union = (all_levels == l).any(axis=0)
        out.append((int(union.sum()), int(degs[union].sum())))
    return out
