"""Direction-optimizing extension benchmark: scans & wall-clock per backend.

Measures — on live frontier traces, not analytically — what each extension
backend (core.extend) must touch per IFE iteration across frontier-density
regimes:

- ``scanned_slots``: adjacency slots the backend's scan semantics require
  this iteration, computed from the *actual* frontier/visited tensors of the
  run. ell_push gathers the full forward-ELL tensor every iteration (its
  measured cost is constant by construction — that is the problem this PR
  fixes); ell_pull scans only the padded in-neighbor lists of still-unvisited
  rows; dopt takes whichever side its alpha/beta predicate picks that
  iteration.
- ``touched_blocks`` (block_mxu): materialized adjacency tiles whose source
  stripe is frontier-active — exactly the tiles the jnp path masks and the
  Pallas kernel DMAs (inactive tiles are skip-listed), via
  ``core.msbfs.active_block_count`` semantics.
- ``wall_ms``: median wall-clock of the jitted per-iteration step at that
  live state.

Workloads: ER density sweep (the paper Fig 13 family — dense frontiers after
one hop) + a power-law proxy (heavy-tail degrees, ragged frontier growth).
Every backend's final levels are asserted bit-identical before anything is
reported.

Writes machine-readable ``BENCH_direction_opt.json`` (schema validated
in-process; `scripts/ci.sh --bench-smoke` runs the --smoke lane per PR).

    PYTHONPATH=src python benchmarks/direction_opt.py [--smoke] \
        [--out BENCH_direction_opt.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.edge_compute import EDGE_COMPUTES  # noqa: E402
from repro.core.extend import (  # noqa: E402
    ExtendCtx,
    as_spec,
    build_operands,
    make_backend,
)
from repro.graph.generators import erdos_renyi, powerlaw  # noqa: E402

BACKENDS = ("ell_push", "ell_pull", "dopt", "block_mxu")
SCHEMA_VERSION = 1


def _wall_ms(fn, *args, reps: int = 3) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def _use_pull_host(spec, fwd_deg, frontier, visited, n) -> bool:
    """Host replica of extend.AutoBackend's alpha/beta predicate."""
    act = frontier != 0
    n_f = float(act.sum())
    m_f = float(fwd_deg[act].sum())
    m_u = float(fwd_deg[~(visited != 0)].sum())
    return bool((m_f * spec.alpha > m_u) and (n_f * spec.beta > n))


def run_backend(csr, source: int, backend: str, max_iters: int) -> dict:
    """One full BFS under one backend, instrumented per iteration."""
    spec = as_spec(backend)
    # counters need rev (pull scan extents) regardless of backend; operands
    # handed to the engine carry exactly what the spec says
    full_ops, n_pad = build_operands(
        csr, as_spec("dopt"), shards=1, block=spec.pad_block
    )
    ops, n_pad2 = build_operands(csr, spec, shards=1)
    assert n_pad2 == n_pad, (n_pad2, n_pad)
    ec = EDGE_COMPUTES["sp_lengths"]
    be = make_backend(spec)
    ctx = ExtendCtx(n_out=n_pad)

    @jax.jit
    def step(state, it):
        contribution = ec.extend(be, ops, state, ctx)
        return ec.apply(state, contribution, it)

    fwd_slots = int(np.prod(full_ops.fwd.indices.shape))
    rev_row_w = int(full_ops.rev.indices.shape[1])
    fwd_deg = np.asarray(full_ops.fwd.degrees)

    touched_fn = None
    if spec.needs_blocks:
        sb = ops.blocks
        bcols = np.asarray(sb.block_cols[0])
        brows = jnp.asarray(sb.block_rows[0])
        valid = jnp.asarray(bcols < (n_pad // sb.block_size))
        B = sb.block_size

        @jax.jit
        def touched_fn(frontier):
            stripe = (
                frontier.reshape(n_pad // B, B) != 0
            ).any(axis=1)
            return (stripe[brows] & valid).sum(dtype=jnp.int32)

    state = ec.init(n_pad, jnp.array([source], jnp.int32))
    iters = []
    for it in range(max_iters):
        f = np.asarray(state.frontier)
        v = np.asarray(state.visited)
        n_f = int((f != 0).sum())
        if n_f == 0:
            break
        unvis = int((v == 0).sum())
        direction = None
        if backend == "ell_push":
            scanned = fwd_slots
        elif backend == "ell_pull":
            scanned = unvis * rev_row_w
        elif backend == "dopt":
            pull = _use_pull_host(spec, fwd_deg, f, v, n_pad)
            direction = "pull" if pull else "push"
            scanned = unvis * rev_row_w if pull else fwd_slots
        else:  # block_mxu: dense tiles, reported in tile cells
            tb = int(touched_fn(state.frontier))
            scanned = tb * spec.block * spec.block
        rec = {
            "it": it,
            "frontier": n_f,
            "unvisited": unvis,
            "scanned_slots": int(scanned),
            "touched_blocks": (
                int(touched_fn(state.frontier))
                if touched_fn is not None
                else None
            ),
            "direction": direction,
            "wall_ms": _wall_ms(step, state, jnp.int32(it)),
        }
        iters.append(rec)
        state = jax.block_until_ready(step(state, jnp.int32(it)))
    levels = np.asarray(state.levels)[: csr.n_nodes]
    return {
        "iterations": iters,
        "total_slots": int(sum(r["scanned_slots"] for r in iters)),
        "total_wall_ms": float(sum(r["wall_ms"] for r in iters)),
        "levels": levels,  # stripped before serialization (parity check)
    }


def bench_graph(name, kind, csr, max_iters: int) -> dict:
    from repro.graph.generators import pick_sources

    source = int(pick_sources(csr, 1, seed=7)[0])
    out = {
        "graph": name,
        "kind": kind,
        "n": int(csr.n_nodes),
        "n_edges": int(csr.n_edges),
        "avg_degree": float(csr.avg_degree),
        "source": source,
        "backends": {},
    }
    ref = None
    for be in BACKENDS:
        r = run_backend(csr, source, be, max_iters)
        levels = r.pop("levels")
        if ref is None:
            ref = levels
        else:
            assert (levels == ref).all(), f"{name}:{be} parity violation"
        out["backends"][be] = r
        print(
            f"  {name:12s} {be:10s} slots {r['total_slots']:>12,} "
            f"wall {r['total_wall_ms']:8.1f} ms "
            f"({len(r['iterations'])} iters)"
        )
    return out


def summarize(workloads: list[dict]) -> dict:
    """Acceptance metric: scanned-slot reduction at large-frontier
    iterations (frontier ≥ 10% of n) on the densest ER workload."""
    dense = [w for w in workloads if w["kind"] == "er"]
    dense.sort(key=lambda w: w["avg_degree"])
    w = dense[-1]
    push = w["backends"]["ell_push"]["iterations"]
    large = [r["it"] for r in push if r["frontier"] >= 0.1 * w["n"]]
    if not large:  # degenerate smoke graph: fall back to the peak iteration
        large = [max(push, key=lambda r: r["frontier"])["it"]]

    def slots_at(backend):
        recs = {
            r["it"]: r for r in w["backends"][backend]["iterations"]
        }
        return sum(recs[i]["scanned_slots"] for i in large if i in recs)

    push_slots = slots_at("ell_push")
    pull_slots = slots_at("ell_pull")
    dopt_slots = slots_at("dopt")
    reduction = push_slots / max(dopt_slots, 1)
    return {
        "dense_er": {
            "graph": w["graph"],
            "large_frontier_iterations": large,
            "push_slots": push_slots,
            "pull_slots": pull_slots,
            "dopt_slots": dopt_slots,
            "scan_reduction_dopt_vs_push": round(reduction, 2),
            "scan_reduction_pull_vs_push": round(
                push_slots / max(pull_slots, 1), 2
            ),
            "passes_2x": bool(reduction >= 2.0),
        }
    }


def validate(doc: dict) -> None:
    """Schema check (run in-process and by scripts/ci.sh --bench-smoke)."""
    assert doc["meta"]["bench"] == "direction_opt"
    assert doc["meta"]["schema_version"] == SCHEMA_VERSION
    for k in ("alpha", "beta", "block"):
        assert isinstance(doc["meta"][k], (int, float)), k
    assert isinstance(doc["workloads"], list) and doc["workloads"]
    for w in doc["workloads"]:
        for k in ("graph", "kind", "n", "n_edges", "avg_degree", "backends"):
            assert k in w, (w["graph"], k)
        assert set(w["backends"]) == set(BACKENDS), w["graph"]
        for be, r in w["backends"].items():
            assert r["iterations"], (w["graph"], be)
            for rec in r["iterations"]:
                for k in ("it", "frontier", "scanned_slots", "wall_ms"):
                    assert k in rec, (w["graph"], be, k)
            assert r["total_slots"] == sum(
                rec["scanned_slots"] for rec in r["iterations"]
            )
    s = doc["summary"]["dense_er"]
    for k in (
        "push_slots", "dopt_slots", "scan_reduction_dopt_vs_push",
        "passes_2x",
    ):
        assert k in s, k


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, schema-validation lane for CI")
    ap.add_argument("--out", default="BENCH_direction_opt.json")
    ap.add_argument("--max-iters", type=int, default=64)
    args = ap.parse_args(argv)

    spec = as_spec("dopt")
    if args.smoke:
        graphs = [("er_smoke", "er", erdos_renyi(512, 8.0, seed=5))]
    else:
        graphs = [
            ("er_d4", "er", erdos_renyi(2048, 4.0, seed=5)),
            ("er_d16", "er", erdos_renyi(2048, 16.0, seed=5)),
            ("er_d48", "er", erdos_renyi(2048, 48.0, seed=5)),
            ("powerlaw_d6", "powerlaw", powerlaw(4096, 6.0, seed=5)),
        ]
    workloads = [
        bench_graph(name, kind, csr, args.max_iters)
        for name, kind, csr in graphs
    ]
    doc = {
        "meta": {
            "bench": "direction_opt",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(args.smoke),
            "alpha": spec.alpha,
            "beta": spec.beta,
            "block": spec.block,
            "backend_list": list(BACKENDS),
            "jax": jax.__version__,
            "device": jax.default_backend(),
        },
        "workloads": workloads,
        "summary": summarize(workloads),
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1))
    s = doc["summary"]["dense_er"]
    print(
        f"summary [{s['graph']}] large-frontier scan reduction: "
        f"dopt {s['scan_reduction_dopt_vs_push']}x, "
        f"pull {s['scan_reduction_pull_vs_push']}x vs ell_push "
        f"(passes_2x={s['passes_2x']})"
    )
    print(f"wrote {args.out} (schema v{SCHEMA_VERSION} validated)")
    return 0 if s["passes_2x"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
