"""Direction-optimizing extension benchmark: scans & wall-clock per backend.

Measures — on live frontier traces, not analytically — what each extension
backend (core.extend) must touch per IFE iteration across frontier-density
regimes:

- ``scanned_slots``: adjacency slots the backend's scan semantics require
  this iteration, computed from the *actual* frontier/visited tensors of the
  run. ell_push gathers the full forward-ELL tensor every iteration;
  ell_pull scans the still-unvisited rows of the single reverse slab padded
  to ``max_in_deg``; pull_binned scans each unvisited row at its own
  degree-bucket slab width (~its true in-degree — asserted ≤ 1.1× the
  ideal ``sum(deg)`` accounting on every workload, the binning acceptance
  floor); pull_binned_fused scans at the Pallas kernel's tile granularity
  (a compute tile is skipped only when every row it feeds is visited);
  dopt takes whichever side its alpha/beta predicate picks that
  iteration (pull side = binned). Every iteration record also carries the
  frontier/unexplored edge masses and all three hypothetical costs
  (``m_frontier`` / ``m_unexplored`` / ``push_slots`` /
  ``pull_slots_ell`` / ``pull_slots_binned``) — the samples
  ``core.policies.fit_direction_thresholds`` fits per-(family,
  degree-bucket) alpha/beta from. Schema v3 additionally joins each
  backend's measured per-iteration wall onto the canonical ell_push
  records (``push_wall_ms`` / ``pull_wall_ms_binned`` /
  ``pull_wall_ms_fused``) — the ``cost="measured"`` fit's inputs — and
  reports the fused-kernel wall floor in the summary.
- ``touched_blocks`` (block_mxu): materialized adjacency tiles whose source
  stripe is frontier-active — exactly the tiles the jnp path masks and the
  Pallas kernel DMAs (inactive tiles are skip-listed), via
  ``core.msbfs.active_block_count`` semantics.
- ``wall_ms``: median wall-clock of the jitted per-iteration step at that
  live state.

Workloads: ER density sweep (the paper Fig 13 family — dense frontiers after
one hop) + a power-law proxy (heavy-tail degrees, ragged frontier growth).
Every backend's final levels are asserted bit-identical before anything is
reported.

Writes machine-readable ``BENCH_direction_opt.json`` (schema validated
in-process; `scripts/ci.sh --bench-smoke` runs the --smoke lane per PR).

    PYTHONPATH=src python benchmarks/direction_opt.py [--smoke] \
        [--out BENCH_direction_opt.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.edge_compute import EDGE_COMPUTES  # noqa: E402
from repro.core.extend import (  # noqa: E402
    ExtendCtx,
    ExtendSpec,
    GraphOperands,
    as_spec,
    build_operands,
    make_backend,
)
from repro.graph.generators import erdos_renyi, powerlaw  # noqa: E402
from repro.kernels.binned_pull.ops import pack_tile_map  # noqa: E402

BACKENDS = (
    "ell_push", "ell_pull", "pull_binned", "pull_binned_fused", "dopt",
    "block_mxu",
)
SCHEMA_VERSION = 3
#: binned-pull acceptance floor: scanned slots vs the ideal sum(deg) scan
BINNED_OVERHEAD_FLOOR = 1.1
#: fused-kernel wall floor tolerance under Pallas INTERPRET mode (this
#: container): interpret executes the kernel's grid as a python-level loop
#: with per-tile dispatch overhead, so the fused single-pass win is
#: invisible and the fused step measures a large constant factor SLOWER
#: than the jnp binned gather it fuses — on the smoke powerlaw workload
#: the observed ratio is ~4.5x, so the floor is checked at this
#: documented tolerance on CPU (empirical ~2x headroom for CI noise) and
#: at 1.0 (fused strictly <= jnp) when ``jax.default_backend() == "tpu"``
#: lowers the kernel for real.
FUSED_WALL_TOL_INTERPRET = 10.0


def _wall_ms(fn, *args, reps: int = 3) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def _use_pull_host(spec, fwd_deg, frontier, visited, n) -> bool:
    """Host replica of extend.AutoBackend's alpha/beta predicate."""
    act = frontier != 0
    n_f = float(act.sum())
    m_f = float(fwd_deg[act].sum())
    m_u = float(fwd_deg[~(visited != 0)].sum())
    return bool((m_f * spec.alpha > m_u) and (n_f * spec.beta > n))


#: one row-padding unit for every operand bundle the bench builds — the
#: block_mxu tile size, which every other backend's pad (32) divides — so
#: the shared counter bundle and each backend's scan operands agree on
#: n_pad for ANY node count, not just 128-multiples
BENCH_PAD_BLOCK = 128


def build_counter_operands(csr, block: int = BENCH_PAD_BLOCK):
    """The backend-independent scan-extent counters: one bundle carrying
    BOTH pull layouts (padded reverse ELL + binned slabs), built once per
    graph and shared by every backend's instrumentation."""
    ell_ops, n_pad = build_operands(
        csr, ExtendSpec(direction="auto", pull="ell"), shards=1, block=block
    )
    bin_ops, n_pad_b = build_operands(
        csr, as_spec("pull_binned"), shards=1, block=block
    )
    assert n_pad_b == n_pad, (n_pad_b, n_pad)
    return (
        GraphOperands(
            fwd=ell_ops.fwd, rev=ell_ops.rev, rev_binned=bin_ops.rev_binned
        ),
        n_pad,
    )


def run_backend(
    csr, source: int, backend: str, max_iters: int, full_ops, n_pad
) -> dict:
    """One full BFS under one backend, instrumented per iteration."""
    spec = as_spec(backend)
    # operands handed to the engine carry exactly what the spec says; the
    # shared ``full_ops`` bundle only feeds the scan counters
    ops, n_pad2 = build_operands(
        csr, spec, shards=1, block=BENCH_PAD_BLOCK
    )
    assert n_pad2 == n_pad, (n_pad2, n_pad)
    ec = EDGE_COMPUTES["sp_lengths"]
    be = make_backend(spec)
    ctx = ExtendCtx(n_out=n_pad)

    @jax.jit
    def step(state, it):
        contribution = ec.extend(be, ops, state, ctx)
        return ec.apply(state, contribution, it)

    fwd_slots = int(np.prod(full_ops.fwd.indices.shape))
    rev_row_w = int(full_ops.rev.indices.shape[1])
    fwd_deg = np.asarray(full_ops.fwd.degrees)
    # per-row binned slab widths + true in-degrees: the binned-pull scan
    # cost of one iteration is the widths of the still-unvisited rows
    # (the uncapped reverse ELL's degree vector IS the true in-degrees)
    bin_width = full_ops.rev_binned.row_widths()[0].astype(np.int64)
    rev_deg = np.asarray(full_ops.rev.degrees).astype(np.int64)

    # fused-kernel tile accounting: the Pallas kernel skips a compute tile
    # only when EVERY out-row it feeds is visited, so its scanned slots are
    # the tile_slots of tiles containing >=1 unvisited row (tile-granular,
    # vs the jnp path's row-granular widths)
    tile_of_row = tile_slots = None
    if spec.needs_binned_pack:
        tile_of_row, tile_slots = pack_tile_map(ops.rev_binned_pack)

    touched_fn = None
    if spec.needs_blocks:
        sb = ops.blocks
        bcols = np.asarray(sb.block_cols[0])
        brows = jnp.asarray(sb.block_rows[0])
        valid = jnp.asarray(bcols < (n_pad // sb.block_size))
        B = sb.block_size

        @jax.jit
        def touched_fn(frontier):
            stripe = (
                frontier.reshape(n_pad // B, B) != 0
            ).any(axis=1)
            return (stripe[brows] & valid).sum(dtype=jnp.int32)

    state = ec.init(n_pad, jnp.array([source], jnp.int32))
    iters = []
    ideal_pull_slots = 0  # sum over iterations of sum(deg of unvisited)
    for it in range(max_iters):
        f = np.asarray(state.frontier)
        v = np.asarray(state.visited)
        n_f = int((f != 0).sum())
        if n_f == 0:
            break
        unvis_mask = v == 0
        unvis = int(unvis_mask.sum())
        active = f != 0
        # the three hypothetical costs + the edge masses of the Beamer
        # predicate — identical across backends (bit-parity => identical
        # frontier trajectories), recorded for fit_direction_thresholds
        push_slots = fwd_slots
        pull_slots_ell = unvis * rev_row_w
        pull_slots_binned = int(bin_width[unvis_mask].sum())
        ideal_pull_slots += int(rev_deg[unvis_mask].sum())
        m_f = int(fwd_deg[active].sum())
        m_u = int(fwd_deg[unvis_mask].sum())
        direction = None
        if backend == "ell_push":
            scanned = push_slots
        elif backend == "ell_pull":
            scanned = pull_slots_ell
        elif backend == "pull_binned":
            scanned = pull_slots_binned
        elif backend == "pull_binned_fused":
            act_tiles = np.zeros(tile_slots.shape[0], bool)
            t = tile_of_row[unvis_mask]
            act_tiles[t[t >= 0]] = True
            scanned = int(tile_slots[act_tiles].sum())
        elif backend == "dopt":
            pull = _use_pull_host(spec, fwd_deg, f, v, n_pad)
            direction = "pull" if pull else "push"
            scanned = pull_slots_binned if pull else push_slots
        else:  # block_mxu: dense tiles, reported in tile cells
            tb = int(touched_fn(state.frontier))
            scanned = tb * spec.block * spec.block
        rec = {
            "it": it,
            "frontier": n_f,
            "unvisited": unvis,
            "scanned_slots": int(scanned),
            "push_slots": push_slots,
            "pull_slots_ell": pull_slots_ell,
            "pull_slots_binned": pull_slots_binned,
            "m_frontier": m_f,
            "m_unexplored": m_u,
            "touched_blocks": (
                int(touched_fn(state.frontier))
                if touched_fn is not None
                else None
            ),
            "direction": direction,
            "wall_ms": _wall_ms(step, state, jnp.int32(it)),
        }
        iters.append(rec)
        state = jax.block_until_ready(step(state, jnp.int32(it)))
    levels = np.asarray(state.levels)[: csr.n_nodes]
    bn = full_ops.rev_binned
    return {
        "iterations": iters,
        "total_slots": int(sum(r["scanned_slots"] for r in iters)),
        "total_wall_ms": float(sum(r["wall_ms"] for r in iters)),
        "ideal_pull_slots": int(ideal_pull_slots),
        "binned": {
            "n_slabs": int(bn.n_slabs),
            "widths": list(bn.widths),
            "capacity_slots": int(bn.capacity_slots),
            "rev_sum_deg": int(rev_deg.sum()),
            "overhead_vs_sum_deg": round(
                bn.capacity_slots / max(int(rev_deg.sum()), 1), 4
            ),
        },
        "levels": levels,  # stripped before serialization (parity check)
    }


def bench_graph(name, kind, csr, max_iters: int) -> dict:
    from repro.graph.generators import pick_sources

    source = int(pick_sources(csr, 1, seed=7)[0])
    full_ops, n_pad = build_counter_operands(csr)
    out = {
        "graph": name,
        "kind": kind,
        "n": int(csr.n_nodes),
        # the live Beamer predicate compares n_f*beta against the PADDED
        # row count (ExtendCtx.n_out); fit_direction_thresholds fits beta
        # against this field so served thresholds match the fit
        "n_pad": int(n_pad),
        "n_edges": int(csr.n_edges),
        "avg_degree": float(csr.avg_degree),
        "source": source,
        "backends": {},
    }
    ref = None
    for be in BACKENDS:
        r = run_backend(csr, source, be, max_iters, full_ops, n_pad)
        levels = r.pop("levels")
        if ref is None:
            ref = levels
        else:
            assert (levels == ref).all(), f"{name}:{be} parity violation"
        out.setdefault("binned", r.pop("binned"))
        out["backends"][be] = r
        print(
            f"  {name:12s} {be:11s} slots {r['total_slots']:>12,} "
            f"wall {r['total_wall_ms']:8.1f} ms "
            f"({len(r['iterations'])} iters)"
        )
    # binned-pull scanned-slot accounting floor (ISSUE 3 acceptance): the
    # degree-binned slabs must scan within BINNED_OVERHEAD_FLOOR of the
    # ideal sum(deg)-based scan — both as layout capacity and as actually
    # scanned slots over this live trace — on EVERY workload, and never
    # more than the single padded reverse slab.
    pb = out["backends"]["pull_binned"]
    ideal = max(pb["ideal_pull_slots"], 1)
    assert pb["total_slots"] <= BINNED_OVERHEAD_FLOOR * ideal, (
        name, pb["total_slots"], ideal,
    )
    assert pb["total_slots"] <= out["backends"]["ell_pull"]["total_slots"], name
    assert (
        out["binned"]["overhead_vs_sum_deg"] <= BINNED_OVERHEAD_FLOOR
    ), (name, out["binned"])
    # schema v3: join each backend's measured per-iteration wall onto the
    # canonical ell_push records (bit-parity => identical trajectories, so
    # iteration i is the same physical iteration under every backend) —
    # exactly the fields fit_direction_thresholds(cost="measured") reads
    binned_by_it = {r["it"]: r for r in pb["iterations"]}
    fused_by_it = {
        r["it"]: r
        for r in out["backends"]["pull_binned_fused"]["iterations"]
    }
    for r in out["backends"]["ell_push"]["iterations"]:
        r["push_wall_ms"] = r["wall_ms"]
        b, fz = binned_by_it.get(r["it"]), fused_by_it.get(r["it"])
        r["pull_wall_ms_binned"] = None if b is None else b["wall_ms"]
        r["pull_wall_ms_fused"] = None if fz is None else fz["wall_ms"]
    return out


def summarize(workloads: list[dict]) -> dict:
    """Acceptance metric: scanned-slot reduction at large-frontier
    iterations (frontier ≥ 10% of n) on the densest ER workload."""
    dense = [w for w in workloads if w["kind"] == "er"]
    dense.sort(key=lambda w: w["avg_degree"])
    w = dense[-1]
    push = w["backends"]["ell_push"]["iterations"]
    large = [r["it"] for r in push if r["frontier"] >= 0.1 * w["n"]]
    if not large:  # degenerate smoke graph: fall back to the peak iteration
        large = [max(push, key=lambda r: r["frontier"])["it"]]

    def slots_at(backend):
        recs = {
            r["it"]: r for r in w["backends"][backend]["iterations"]
        }
        return sum(recs[i]["scanned_slots"] for i in large if i in recs)

    push_slots = slots_at("ell_push")
    pull_slots = slots_at("ell_pull")
    dopt_slots = slots_at("dopt")
    reduction = push_slots / max(dopt_slots, 1)
    summary = {
        "dense_er": {
            "graph": w["graph"],
            "large_frontier_iterations": large,
            "push_slots": push_slots,
            "pull_slots": pull_slots,
            "dopt_slots": dopt_slots,
            "scan_reduction_dopt_vs_push": round(reduction, 2),
            "scan_reduction_pull_vs_push": round(
                push_slots / max(pull_slots, 1), 2
            ),
            "passes_2x": bool(reduction >= 2.0),
        }
    }
    # power-law acceptance: the heavy-tail graph where the padded reverse
    # slab pays n·max_in_deg and binning pays ~sum(deg)
    pls = [w for w in workloads if w["kind"] == "powerlaw"]
    if pls:
        w = max(pls, key=lambda w: w["n_edges"])
        pb = w["backends"]["pull_binned"]
        pe = w["backends"]["ell_pull"]
        ideal = max(pb["ideal_pull_slots"], 1)
        overhead = pb["total_slots"] / ideal
        summary["powerlaw_binned"] = {
            "graph": w["graph"],
            "ideal_pull_slots": ideal,
            "binned_pull_slots": pb["total_slots"],
            "ell_pull_slots": pe["total_slots"],
            "binned_overhead_vs_ideal": round(overhead, 4),
            "scan_reduction_binned_vs_ell_pull": round(
                pe["total_slots"] / max(pb["total_slots"], 1), 2
            ),
            "capacity_overhead_vs_sum_deg": w["binned"][
                "overhead_vs_sum_deg"
            ],
            "passes_overhead_floor": bool(
                overhead <= BINNED_OVERHEAD_FLOOR
                and w["binned"]["overhead_vs_sum_deg"]
                <= BINNED_OVERHEAD_FLOOR
            ),
        }
        # fused-kernel wall floor (schema v3): the Pallas slab-major kernel
        # vs the jnp binned gather it fuses, summed over the same live
        # trajectory. On real TPU lowering the fused single-VMEM-pass must
        # be no slower (tol 1.0); interpret mode pays python-loop grid
        # overhead instead, checked at the documented tolerance.
        pf = w["backends"]["pull_binned_fused"]
        interpret = jax.default_backend() != "tpu"
        tol = FUSED_WALL_TOL_INTERPRET if interpret else 1.0
        wall_f, wall_j = pf["total_wall_ms"], pb["total_wall_ms"]
        summary["fused_kernel"] = {
            "graph": w["graph"],
            "wall_ms_fused": round(wall_f, 3),
            "wall_ms_binned_jnp": round(wall_j, 3),
            "wall_ratio_fused_over_jnp": round(
                wall_f / max(wall_j, 1e-9), 3
            ),
            "interpret_mode": interpret,
            "wall_tolerance": tol,
            "scanned_slots_fused": pf["total_slots"],
            "scanned_slots_binned": pb["total_slots"],
            "passes_fused_wall_floor": bool(wall_f <= wall_j * tol),
        }
    return summary


def load(path) -> dict:
    """Versioned loader for ``BENCH_direction_opt.json`` artifacts.

    Accepts schema v2 (pre-fused, slots-only) and v3 documents; v2 docs
    are normalized in place to the v3 record surface — the wall-join
    fields read as ``None`` (so a measured-cost fit over an old trace
    degrades to the Beamer defaults instead of KeyError-ing) and the
    absent fused backend simply stays absent. Unknown versions raise."""
    doc = json.loads(Path(path).read_text())
    v = doc.get("meta", {}).get("schema_version")
    if v not in (2, SCHEMA_VERSION):
        raise ValueError(
            f"unsupported BENCH_direction_opt schema_version {v!r} "
            f"(supported: 2, {SCHEMA_VERSION})"
        )
    if v == 2:
        for w in doc.get("workloads", []):
            push = w.get("backends", {}).get("ell_push", {})
            for r in push.get("iterations", []):
                r.setdefault("push_wall_ms", None)
                r.setdefault("pull_wall_ms_binned", None)
                r.setdefault("pull_wall_ms_fused", None)
    return doc


def validate(doc: dict) -> None:
    """Schema check (run in-process and by scripts/ci.sh --bench-smoke)."""
    assert doc["meta"]["bench"] == "direction_opt"
    assert doc["meta"]["schema_version"] == SCHEMA_VERSION
    for k in ("alpha", "beta", "block"):
        assert isinstance(doc["meta"][k], (int, float)), k
    assert isinstance(doc["workloads"], list) and doc["workloads"]
    for w in doc["workloads"]:
        for k in ("graph", "kind", "n", "n_pad", "n_edges", "avg_degree",
                  "backends", "binned"):
            assert k in w, (w["graph"], k)
        assert set(w["backends"]) == set(BACKENDS), w["graph"]
        # per-bucket slab schema: widths ascending with a zero-width slab
        # first (the truncation-emptied / zero-in-degree rows), capacity
        # within the overhead floor of the true edge count
        b = w["binned"]
        for k in ("n_slabs", "widths", "capacity_slots", "rev_sum_deg",
                  "overhead_vs_sum_deg"):
            assert k in b, (w["graph"], k)
        assert b["n_slabs"] == len(b["widths"]) >= 1, b
        assert b["widths"][0] == 0, b["widths"]
        assert b["widths"] == sorted(b["widths"]), b["widths"]
        assert b["overhead_vs_sum_deg"] <= BINNED_OVERHEAD_FLOOR, b
        for be, r in w["backends"].items():
            assert r["iterations"], (w["graph"], be)
            for rec in r["iterations"]:
                for k in ("it", "frontier", "scanned_slots", "wall_ms",
                          "push_slots", "pull_slots_ell",
                          "pull_slots_binned", "m_frontier",
                          "m_unexplored"):
                    assert k in rec, (w["graph"], be, k)
            assert r["total_slots"] == sum(
                rec["scanned_slots"] for rec in r["iterations"]
            )
            assert "ideal_pull_slots" in r, (w["graph"], be)
        # v3: the canonical push records carry each backend's measured
        # per-iteration wall (the measured-cost fit's input fields)
        for rec in w["backends"]["ell_push"]["iterations"]:
            for k in ("push_wall_ms", "pull_wall_ms_binned",
                      "pull_wall_ms_fused"):
                assert k in rec and rec[k] is not None, (w["graph"], k)
    s = doc["summary"]["dense_er"]
    for k in (
        "push_slots", "dopt_slots", "scan_reduction_dopt_vs_push",
        "passes_2x",
    ):
        assert k in s, k
    pl = doc["summary"].get("powerlaw_binned")
    assert pl is not None, "powerlaw workload missing from bench"
    for k in ("ideal_pull_slots", "binned_pull_slots",
              "binned_overhead_vs_ideal",
              "scan_reduction_binned_vs_ell_pull",
              "passes_overhead_floor"):
        assert k in pl, k
    assert pl["passes_overhead_floor"], pl
    fk = doc["summary"].get("fused_kernel")
    assert fk is not None, "fused-kernel summary missing from bench"
    for k in ("wall_ms_fused", "wall_ms_binned_jnp",
              "wall_ratio_fused_over_jnp", "interpret_mode",
              "wall_tolerance", "passes_fused_wall_floor"):
        assert k in fk, k
    assert fk["passes_fused_wall_floor"], fk


def smoke_line(doc: dict) -> str:
    """One-line artifact summary for the CI bench-smoke lane."""
    pl = doc["summary"]["powerlaw_binned"]
    fk = doc["summary"]["fused_kernel"]
    return (
        f"dense-ER reduction "
        f"{doc['summary']['dense_er']['scan_reduction_dopt_vs_push']}x, "
        f"binned pull {pl['binned_overhead_vs_ideal']}x ideal / "
        f"{pl['scan_reduction_binned_vs_ell_pull']}x fewer slots than "
        f"padded pull, fused wall "
        f"{fk['wall_ratio_fused_over_jnp']}x jnp "
        f"(tol {fk['wall_tolerance']}"
        f"{' interpret' if fk['interpret_mode'] else ''}, "
        f"passes={fk['passes_fused_wall_floor']})"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, schema-validation lane for CI")
    ap.add_argument("--out", default="BENCH_direction_opt.json")
    ap.add_argument("--max-iters", type=int, default=64)
    args = ap.parse_args(argv)

    spec = as_spec("dopt")
    if args.smoke:
        graphs = [
            ("er_smoke", "er", erdos_renyi(512, 8.0, seed=5)),
            ("pl_smoke", "powerlaw", powerlaw(512, 4.0, seed=7)),
        ]
    else:
        graphs = [
            ("er_d4", "er", erdos_renyi(2048, 4.0, seed=5)),
            ("er_d16", "er", erdos_renyi(2048, 16.0, seed=5)),
            ("er_d48", "er", erdos_renyi(2048, 48.0, seed=5)),
            ("powerlaw_d6", "powerlaw", powerlaw(4096, 6.0, seed=5)),
        ]
    workloads = [
        bench_graph(name, kind, csr, args.max_iters)
        for name, kind, csr in graphs
    ]
    doc = {
        "meta": {
            "bench": "direction_opt",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(args.smoke),
            "alpha": spec.alpha,
            "beta": spec.beta,
            "block": spec.block,
            "backend_list": list(BACKENDS),
            "jax": jax.__version__,
            "device": jax.default_backend(),
        },
        "workloads": workloads,
        "summary": summarize(workloads),
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1))
    s = doc["summary"]["dense_er"]
    pl = doc["summary"]["powerlaw_binned"]
    print(
        f"summary [{s['graph']}] large-frontier scan reduction: "
        f"dopt {s['scan_reduction_dopt_vs_push']}x, "
        f"pull {s['scan_reduction_pull_vs_push']}x vs ell_push "
        f"(passes_2x={s['passes_2x']})"
    )
    print(
        f"summary [{pl['graph']}] binned pull: "
        f"{pl['binned_overhead_vs_ideal']}x the ideal sum(deg) scan "
        f"(floor {BINNED_OVERHEAD_FLOOR}), "
        f"{pl['scan_reduction_binned_vs_ell_pull']}x fewer slots than the "
        f"padded reverse slab "
        f"(passes_overhead_floor={pl['passes_overhead_floor']})"
    )
    fk = doc["summary"]["fused_kernel"]
    print(
        f"summary [{fk['graph']}] fused kernel: wall "
        f"{fk['wall_ms_fused']} ms vs {fk['wall_ms_binned_jnp']} ms jnp "
        f"({fk['wall_ratio_fused_over_jnp']}x, tol {fk['wall_tolerance']}"
        f"{' interpret' if fk['interpret_mode'] else ''}), "
        f"passes_fused_wall_floor={fk['passes_fused_wall_floor']}"
    )
    print(f"wrote {args.out} (schema v{SCHEMA_VERSION} validated)")
    return 0 if (
        s["passes_2x"]
        and pl["passes_overhead_floor"]
        and fk["passes_fused_wall_floor"]
    ) else 1


if __name__ == "__main__":
    raise SystemExit(main())
