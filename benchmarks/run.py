"""Benchmark harness entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]`` prints
``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and exits
non-zero if any paper-claim assertion fails.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced graph scales (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (
        fig13_er_density,
        fig14_msbfs,
        roofline,
        table1_frontier_scaling,
        table34_policies,
        table5_visits,
        table6_k_sweep,
    )

    suites = {
        "table1": lambda: table1_frontier_scaling.main(args.quick),
        "table34": lambda: table34_policies.main(args.quick),
        "table5": lambda: table5_visits.main(args.quick),
        "table6": lambda: table6_k_sweep.main(args.quick),
        "fig13": lambda: fig13_er_density.main(args.quick),
        "fig14": lambda: fig14_msbfs.main(args.quick),
        "roofline": lambda: roofline.main([]),
    }
    failures = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {name}: ok ({time.time()-t0:.1f}s)")
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
            print(f"# {name}: FAILED {e}")
    if failures:
        print(f"# {len(failures)} suite(s) failed: "
              f"{[n for n, _ in failures]}")
        return 1
    print("# all benchmark suites passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
