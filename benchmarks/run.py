"""Benchmark harness entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]`` prints
``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and exits
non-zero if any paper-claim assertion fails.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def _direction_opt_report(quick: bool) -> None:
    """Fold the direction-opt artifact into the CSV stream via its
    versioned v2/v3 loader.

    ``BENCH_DIRECTION_OPT_ARTIFACT`` names an existing artifact to
    aggregate (v2 slots-only traces still load — the wall fields read as
    None and the fused rows are simply absent); otherwise the benchmark
    runs fresh (``--smoke`` under --quick) and its floors gate the suite.
    """
    from . import direction_opt
    from .common import emit

    path = os.environ.get("BENCH_DIRECTION_OPT_ARTIFACT")
    if path is None:
        path = "/tmp/BENCH_direction_opt.run.json"
        argv = ["--out", path] + (["--smoke"] if quick else [])
        assert direction_opt.main(argv) == 0, "direction_opt floors failed"
    doc = direction_opt.load(path)
    v = doc["meta"]["schema_version"]
    s = doc["summary"]["dense_er"]
    emit(f"direction_opt_v{v}.dense_er.scan_reduction", 0.0,
         f"dopt {s['scan_reduction_dopt_vs_push']}x vs push "
         f"(passes_2x={s['passes_2x']})")
    pl = doc["summary"].get("powerlaw_binned")
    if pl is not None:
        emit(f"direction_opt_v{v}.powerlaw.binned_overhead", 0.0,
             f"{pl['binned_overhead_vs_ideal']}x ideal "
             f"(passes={pl['passes_overhead_floor']})")
    fk = doc["summary"].get("fused_kernel")  # absent from v2 artifacts
    if fk is not None:
        emit(f"direction_opt_v{v}.powerlaw.fused_wall",
             fk["wall_ms_fused"] * 1e3,
             f"{fk['wall_ratio_fused_over_jnp']}x jnp binned "
             f"(tol {fk['wall_tolerance']}, "
             f"passes={fk['passes_fused_wall_floor']})")
        assert fk["passes_fused_wall_floor"], fk
    assert s["passes_2x"], s


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced graph scales (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (
        fig13_er_density,
        fig14_msbfs,
        roofline,
        table1_frontier_scaling,
        table34_policies,
        table5_visits,
        table6_k_sweep,
    )

    suites = {
        "table1": lambda: table1_frontier_scaling.main(args.quick),
        "table34": lambda: table34_policies.main(args.quick),
        "table5": lambda: table5_visits.main(args.quick),
        "table6": lambda: table6_k_sweep.main(args.quick),
        "fig13": lambda: fig13_er_density.main(args.quick),
        "fig14": lambda: fig14_msbfs.main(args.quick),
        "roofline": lambda: roofline.main([]),
        "direction_opt": lambda: _direction_opt_report(args.quick),
    }
    failures = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {name}: ok ({time.time()-t0:.1f}s)")
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
            print(f"# {name}: FAILED {e}")
    if failures:
        print(f"# {len(failures)} suite(s) failed: "
              f"{[n for n, _ in failures]}")
        return 1
    print("# all benchmark suites passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
