"""Paper Table 1: per-frontier-level scalability of nT1S on LDBC.

Measures the real frontier trace (frontier sizes + edge-scan work per level)
on the LDBC proxy, then reports the per-level simulated speedup for 1..32
threads: dense middle levels scale near-linearly, sparse head/tail levels
pin at ~1x — the Amdahl decomposition that motivates the hybrid policy.
"""
from __future__ import annotations

from .common import emit, frontier_trace, time_fn
from .sched_sim import EPS, _morselize


def level_speedup(n_nodes: int, work: float, threads: int,
                  morsel_nodes: int = 64) -> float:
    morsels = _morselize(work, n_nodes, morsel_nodes)
    t1 = sum(m + EPS for m in morsels)
    # list scheduling of equal morsels over T threads
    rounds = -(-len(morsels) // threads)
    tT = rounds * (morsels[0] + EPS)
    return t1 / tT if tT > 0 else 1.0


def main(quick: bool = False):
    from repro.graph.generators import ldbc_proxy, pick_sources

    csr = ldbc_proxy(scale=0.5 if quick else 1.0)
    src = int(pick_sources(csr, 1, seed=7)[0])
    trace, levels = frontier_trace(csr, src)

    print("# level, n_nodes, edge_work, speedup@2, @8, @32")
    total_w = sum(w for _, w in trace)
    t1_total = 0.0
    tT_total = {t: 0.0 for t in (2, 8, 32)}
    for l, (n, w) in enumerate(trace):
        sp = {t: level_speedup(n, w, t) for t in (2, 8, 32)}
        t1 = sum(m + EPS for m in _morselize(w, n, 64))
        t1_total += t1
        for t in tT_total:
            tT_total[t] += t1 / sp[t]
        print(f"#   L{l}: {n} nodes, work {w}, "
              f"{sp[2]:.1f}x / {sp[8]:.1f}x / {sp[32]:.1f}x")
    overall = {t: t1_total / tT_total[t] for t in tT_total}
    emit(
        "table1_frontier_scaling",
        0.0,
        f"levels={len(trace)} work={total_w} "
        f"overall_speedup@32={overall[32]:.1f}x (paper: 4.8x) "
        f"dense_mid_scales_sparse_tails_pin=True",
    )
    # paper claim: cumulative sparse levels bound overall speedup well
    # below the densest level's own scalability
    dense_l = max(range(len(trace)), key=lambda l: trace[l][1])
    dense_sp = level_speedup(*trace[dense_l], 32)
    assert overall[32] < dense_sp, "Amdahl decomposition violated"
    return overall[32]


if __name__ == "__main__":
    main()
