"""Fill EXPERIMENTS.md placeholder tables from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
import re


def load_all(dir_="results/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def base_cells(recs):
    return [r for r in recs if not r.get("tag")]


def fmt(v, n=2):
    return f"{v:.{n}e}"


def dryrun_summary(recs):
    base = base_cells(recs)
    ok = [r for r in base if r["status"] == "ok"]
    fails = [r for r in base if r["status"] != "ok"]
    over = [
        r for r in ok
        if r.get("memory", {}).get("total_bytes_per_device", 0) > 16 * 2**30
    ]
    lines = [
        f"**{len(ok)}/{len(base)} cells compiled** "
        f"({len([r for r in ok if r['mesh'] == 'single'])} single-pod, "
        f"{len([r for r in ok if r['mesh'] == 'multi'])} multi-pod). ",
    ]
    if fails:
        lines.append("Failures: " + ", ".join(
            f"{r['arch']}×{r['shape']}×{r['mesh']}" for r in fails))
    if over:
        lines.append(
            "\nCells whose CPU-backend memory accounting exceeds 16 GiB "
            "(details in §Perf): "
            + ", ".join(sorted({
                f"{r['arch']}×{r['shape']} "
                f"({r['memory']['total_bytes_per_device']/2**30:.1f} GiB)"
                for r in over}))
        )
    # largest collective schedules as a sample
    lines.append(
        "\nPer-cell collective schedules (op counts × ring-weighted bytes) "
        "are in each JSON; e.g. "
    )
    for r in ok:
        if r["arch"] == "paper-bfs-engine" and r["shape"] == "livejournal" \
                and r["mesh"] == "multi":
            cc = r.get("collective_counts", {})
            lines.append(
                f"`paper-bfs-engine×livejournal×multi`: {cc} — identical "
                "frontier-union schedule to single-pod (unions never cross "
                "pods)."
            )
    return "\n".join(lines)


def roofline_table(recs):
    rows = [r for r in base_cells(recs)
            if r["mesh"] == "single" and r["status"] == "ok"]
    out = [
        "| arch | shape | GiB/dev | HLO flops/dev | compute s | memory s "
        "| collective s | dominant | useful |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        rl = r["roofline"]
        gib = r["memory"]["total_bytes_per_device"] / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {gib:.2f} "
            f"| {fmt(rl['flops_per_device'])} | {fmt(rl['compute_s'])} "
            f"| {fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} "
            f"| {rl['dominant']} | {rl['useful_fraction']:.2f} |"
        )
    out.append("")
    out.append(
        "(LM rows here are monolithic single-count numbers; the corrected "
        "LM accounting is the compositional table below. The paper-engine "
        "rows include iters_scale=32.)"
    )
    return "\n".join(out)


def comp_table(recs):
    rows = [r for r in recs if r.get("tag") == "comp"
            and r["status"] == "ok"]
    out = [
        "| arch | shape | flops/dev | compute s | memory s | collective s "
        "| dominant | useful | roofline % |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rl['flops_per_device'])} "
            f"| {fmt(rl['compute_s'])} | {fmt(rl['memory_s'])} "
            f"| {fmt(rl['collective_s'])} | {rl['dominant']} "
            f"| {rl['useful_fraction']:.2f} "
            f"| {rl['roofline_fraction']*100:.1f}% |"
        )
    return "\n".join(out)


def engine_variants(recs):
    rows = [r for r in recs if r["arch"] == "paper-bfs-engine"
            and r.get("tag") and r["tag"] != "comp"
            and r["status"] == "ok" and r["mesh"] == "single"]
    out = [
        "| shape | state layout | OR impl | GiB/dev | memory s "
        "| collective s | bound s |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["shape"], r["tag"])):
        rl = r["roofline"]
        layout, impl = r["tag"].split("_", 1)
        gib = r["memory"]["total_bytes_per_device"] / 2**30
        bound = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        out.append(
            f"| {r['shape']} | {layout} | {impl} | {gib:.2f} "
            f"| {fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} "
            f"| {fmt(bound)} |"
        )
    return "\n".join(out)


def main():
    recs = load_all()
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    subs = {
        "<!-- DRYRUN_SUMMARY -->": dryrun_summary(recs),
        "<!-- ROOFLINE_TABLE -->": roofline_table(recs),
        "<!-- ROOFLINE_COMP -->": comp_table(recs),
        "<!-- ENGINE_VARIANTS -->": engine_variants(recs),
    }
    for k, v in subs.items():
        assert k in text, k
        text = text.replace(k, v)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables filled")


if __name__ == "__main__":
    main()
