"""Discrete-event simulator of the paper's morsel dispatching policies.

Reproduces the paper's thread-scaling experiments (Tables 1/3/4, Figs 9-12)
from MEASURED per-frontier work traces on this container's single core.

Model (paper §3/§4):
- A *source morsel* is an IFE run: a list of per-level work amounts
  (edge-scan units, measured as sum of frontier out-degrees).
- A *frontier morsel* is a ≤ morsel_nodes slice of one level's frontier;
  the level's work divides evenly across its morsels (plus a fixed
  dispatch overhead EPS per morsel — the grabFrontierMorsel cost).
- checkIfFrontierFinished is a per-source barrier: level l+1 morsels
  become available when the last level-l morsel completes.
- 1T1S: a source is ONE indivisible unit of work (vanilla morsel scan).
- nT1S: k=1 — sources sequential, threads share each frontier.
- nTkS: up to k sources concurrently; idle threads grab frontier morsels
  from any active source ("sticky" preference for the last source).
- nTkMS: sources pack into 64-wide lane morsels whose per-level work is
  the measured UNION frontier scan (shared scans) × lane_cost_factor
  (the paper's §5.6 per-edge overhead of updating 64-bit lane state).

Cache-locality term (paper §5.5, Table 6 / Fig 13): running k concurrent
IFE states multiplies per-unit work by (1 + cache_alpha·min(1, (k·state -
llc)/llc · working-set pressure)); calibrated qualitatively — it reproduces
"denser graphs ⇒ lower optimal k", not absolute LLC counts.
"""
from __future__ import annotations

import dataclasses
import heapq

EPS = 0.02  # dispatch overhead per frontier morsel, in avg-morsel units


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy_fraction: float  # 'CPU utilization' analogue

    def speedup_vs(self, t1: "SimResult") -> float:
        return t1.makespan / self.makespan if self.makespan > 0 else 1.0


def _morselize(level_work: float, level_nodes: int, morsel_nodes: int):
    n_morsels = max(-(-level_nodes // morsel_nodes), 1)
    return [level_work / n_morsels] * n_morsels


def simulate(
    traces: list,  # per source: list of (n_nodes, work) levels
    n_threads: int,
    policy: str,
    k: int = 32,
    morsel_nodes: int = 64,
    lanes: int = 1,
    cache_alpha: float = 0.0,
    state_per_source: float = 0.0,
    llc: float = 1.0,
) -> SimResult:
    """Schedules the traces under a policy; returns makespan in work units."""
    if policy == "1t1s":
        # LPT-free greedy: threads grab whole sources
        totals = [sum(w for _, w in t) for t in traces]
        heap = [0.0] * n_threads
        heapq.heapify(heap)
        for w in totals:  # arrival order, like scanning a source table
            t0 = heapq.heappop(heap)
            heapq.heappush(heap, t0 + w)
        makespan = max(heap)
        busy = sum(totals) / (n_threads * makespan) if makespan else 1.0
        return SimResult(makespan, busy)

    if policy == "nt1s":
        k = 1
    elif policy == "ntkms":
        pass  # traces are already lane-packed by the caller
    elif policy != "ntks":
        raise ValueError(policy)

    # cache-pressure factor: concurrent per-source state vs LLC
    def slowdown(active: int) -> float:
        if cache_alpha <= 0 or state_per_source <= 0:
            return 1.0
        pressure = active * state_per_source / llc
        return 1.0 + cache_alpha * max(0.0, pressure - 1.0)

    # per-source state: level index, morsels left to hand out, morsels in
    # flight, work queue for the level
    sources = [
        {"trace": t, "level": 0, "queue": [], "inflight": 0, "done": False}
        for t in traces
    ]
    for s in sources:
        if s["trace"]:
            n, w = s["trace"][0]
            s["queue"] = _morselize(w, n, morsel_nodes)
        else:
            s["done"] = True

    active: list = []
    waiting = [s for s in sources if not s["done"]]
    while len(active) < k and waiting:
        active.append(waiting.pop(0))

    threads = [(0.0, i) for i in range(n_threads)]
    heapq.heapify(threads)
    sticky = {i: None for i in range(n_threads)}
    # events: (time, seq, source) barrier completions (seq breaks ties)
    pending: list = []  # (finish_time, seq, source)
    seq = 0
    busy_time = 0.0
    now = 0.0

    def grab(tid):
        # sticky preference, then any active source with queued morsels
        cand = sticky[tid]
        if cand is not None and not cand["done"] and cand["queue"]:
            return cand
        for s in active:
            if s["queue"]:
                return s
        return None

    while True:
        # retire finished morsels up to the earliest free thread time
        if not threads:
            break
        t_free, tid = heapq.heappop(threads)
        now = max(now, t_free)
        # process barrier completions at or before `now`
        while pending and pending[0][0] <= now:
            _, _, s = heapq.heappop(pending)
            s["inflight"] -= 1
            if not s["queue"] and s["inflight"] == 0:
                s["level"] += 1
                if s["level"] >= len(s["trace"]):
                    s["done"] = True
                    if s in active:
                        active.remove(s)
                    if waiting and len(active) < k:
                        active.append(waiting.pop(0))
                else:
                    n, w = s["trace"][s["level"]]
                    s["queue"] = _morselize(w, n, morsel_nodes)
        src = grab(tid)
        if src is None:
            if not pending:
                if all(s["done"] for s in sources):
                    heapq.heappush(threads, (now, tid))
                    break
                # stall: no morsels and nothing in flight => advance time
                heapq.heappush(threads, (now + EPS, tid))
                continue
            # wait for the next completion
            heapq.heappush(threads, (max(pending[0][0], now), tid))
            continue
        w = src["queue"].pop(0)
        src["inflight"] += 1
        sticky[tid] = src
        dur = (w * lanes_factor(lanes) + EPS) * slowdown(len(active))
        busy_time += dur
        heapq.heappush(pending, (now + dur, seq, src))
        seq += 1
        heapq.heappush(threads, (now + dur, tid))

    makespan = max(t for t, _ in threads) if threads else now
    busy = busy_time / (n_threads * makespan) if makespan > 0 else 1.0
    return SimResult(makespan, min(busy, 1.0))


def lanes_factor(lanes: int) -> float:
    """Per-edge-scan cost multiplier of lane-packed state updates
    (paper §5.6: the extra loop over set bits; calibrated ~1.3 at 64)."""
    return 1.0 + 0.3 * (lanes > 1)
