"""Mutable-graph operand folding vs from-scratch rebuild (ISSUE 8).

The mutability claim: a ``GraphDelta`` that keeps every operand shape
folds into the live device bundles — rewriting only the dirty rows /
slab cells and re-placing only the structures whose contents changed —
for less wall than rebuilding the operand set from the new CSR, and
without a single engine recompile (``EngineCache.compile_events`` stays
flat, because engines key on the per-structure shape *epoch*, not on the
graph version).

Measured here, on a degree-structured graph (in-degrees only {10, 11},
one refined reverse bucket) where swap deltas — move one target from
in-degree 11 to 10 and another from 10 to 11 off the same source — are
same-shape by construction:

- **delta path**: one warm ``QueryDispatcher``; per delta,
  ``apply_delta`` wall (host CSR update + effective diff + per-bundle
  fold + device re-placement), then a query checked bit-for-bit against
  a numpy BFS oracle on the mutated graph;
- **rebuild baseline**: per delta, ``prepare_graph`` wall on the
  post-delta CSR for every live operand bundle's (policy, spec) — the
  operand construction a server without delta support would redo; its
  engine recompiles would come on top and are NOT charged to the
  baseline here;
- **reshape probe** (reported, not a floor): one bucket-breaking delta
  at the end must flip ``same_shape`` off and invalidate exactly the
  engines whose scanned structures rebuilt.

Floors (asserted in-process and by ``scripts/ci.sh --bench-smoke``):
total delta-apply wall < total rebuild wall, ``compile_events`` flat
across every same-shape delta, every post-delta query bit-identical to
the oracle.

Writes machine-readable ``BENCH_mutable_ops.json`` (schema validated
in-process and re-validated by the CI lane).

    PYTHONPATH=src python benchmarks/mutable_ops.py [--smoke] \
        [--out BENCH_mutable_ops.json]
"""
from __future__ import annotations

import argparse
import collections
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

SCHEMA = 1

REQUIRED = {
    "schema": int,
    "smoke": bool,
    "workload": dict,
    "deltas": list,
    "reshape": dict,
    "summary": dict,
}
DELTA_FIELDS = (
    "delta_apply_wall_ms", "rebuild_wall_ms", "same_shape",
    "compile_events_after", "engines_invalidated", "binned_moves",
    "results_match",
)


def validate(doc: dict) -> None:
    """Schema + acceptance guards for BENCH_mutable_ops.json: every
    same-shape delta folded for less wall than the rebuild baseline (in
    total), left ``compile_events`` flat, and served oracle-identical
    results; the reshape probe invalidated at least one engine."""
    for key, ty in REQUIRED.items():
        assert key in doc, f"missing top-level field: {key}"
        assert isinstance(doc[key], ty), (key, type(doc[key]))
    assert doc["schema"] == SCHEMA, doc["schema"]
    assert len(doc["deltas"]) >= 1
    events = set()
    for i, d in enumerate(doc["deltas"]):
        for f in DELTA_FIELDS:
            assert f in d, f"delta {i} missing field: {f}"
        assert d["same_shape"] is True, (i, d)
        assert d["engines_invalidated"] == 0, (i, d)
        assert d["results_match"] is True, (i, d)
        events.add(d["compile_events_after"])
    s = doc["summary"]
    for f in ("delta_apply_wall_ms", "rebuild_wall_ms", "wall_speedup",
              "compile_events_flat", "all_results_match",
              "passes_wall_floor"):
        assert f in s, f"missing summary field: {f}"
    assert s["compile_events_flat"] is True and len(events) == 1, (
        "compile_events moved across same-shape deltas", doc["deltas"]
    )
    assert s["all_results_match"] is True, s
    assert s["passes_wall_floor"] is True, (
        "delta apply must beat the from-scratch operand rebuild: "
        f"{s['delta_apply_wall_ms']:.1f} vs {s['rebuild_wall_ms']:.1f} ms"
    )
    assert s["delta_apply_wall_ms"] < s["rebuild_wall_ms"], s
    r = doc["reshape"]
    assert r["same_shape"] is False and r["results_match"] is True, r
    assert r["engines_invalidated"] >= 1, (
        "reshape probe should invalidate the stale engines", r
    )


def smoke_line(doc: dict) -> str:
    """One-line artifact summary for the CI bench-smoke lane."""
    s = doc["summary"]
    return (
        f"{len(doc['deltas'])} same-shape deltas folded in "
        f"{s['delta_apply_wall_ms']:.1f} ms vs {s['rebuild_wall_ms']:.1f} "
        f"ms rebuild ({s['wall_speedup']:.2f}x), compile_events flat "
        f"{s['compile_events_flat']}, oracle-identical "
        f"{s['all_results_match']}, reshape invalidated "
        f"{doc['reshape']['engines_invalidated']} engine(s)"
    )


def bfs_levels(csr, source: int) -> np.ndarray:
    levels = np.full(csr.n_nodes, -1, dtype=np.int32)
    levels[source] = 0
    q = collections.deque([source])
    while q:
        u = q.popleft()
        for v in csr.neighbors(u):
            v = int(v)
            if levels[v] < 0:
                levels[v] = levels[u] + 1
                q.append(v)
    return levels


def structured_graph(n_targets: int, n_sources: int, seed: int = 0):
    """In-degrees only {10, 11}: one refined reverse bucket of width 11,
    so the swap deltas below never change an operand shape. Sources and
    targets are disjoint id ranges; queries start at sources."""
    from repro.graph.csr import csr_from_edges

    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    for i in range(n_targets):
        t = n_sources + i
        for s in rng.choice(n_sources, size=(10 if i % 2 == 0 else 11),
                            replace=False):
            src_l.append(int(s))
            dst_l.append(int(t))
    n = n_sources + n_targets
    return csr_from_edges(n, np.array(src_l), np.array(dst_l))


def swap_deltas(csr, n_sources: int, k: int):
    """k same-shape swap deltas: each moves one in-degree-11 target down
    to 10 and one in-degree-10 target up to 11, reusing the same source
    (out-degree unchanged). Generated against the evolving edge set so
    the whole chain stays inside the {10, 11} degree envelope."""
    from repro.graph.delta import GraphDelta

    src, dst = csr.edge_list()
    edges = set(zip(src.tolist(), dst.tolist()))
    indeg = np.zeros(csr.n_nodes, np.int64)
    np.add.at(indeg, dst, 1)
    by_src = collections.defaultdict(list)
    for s, t in edges:
        by_src[s].append(t)
    deltas = []
    for s in sorted(by_src):
        if len(deltas) == k:
            break
        t11 = next((t for t in by_src[s] if indeg[t] == 11), None)
        if t11 is None:
            continue
        t10 = next(
            (t for t in range(n_sources, csr.n_nodes)
             if indeg[t] == 10 and (s, t) not in edges),
            None,
        )
        if t10 is None:
            continue
        deltas.append(GraphDelta(add_src=[s], add_dst=[t10],
                                 del_src=[s], del_dst=[t11]))
        edges.remove((s, t11))
        edges.add((s, t10))
        by_src[s].remove(t11)
        by_src[s].append(t10)
        indeg[t11] -= 1
        indeg[t10] += 1
    assert len(deltas) == k, f"only {len(deltas)} swap deltas found"
    return deltas


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph / short chain (CI bench-smoke lane)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parent.parent / "BENCH_mutable_ops.json"
    ))
    args = ap.parse_args(argv)

    import jax

    from repro.core.dispatcher import prepare_graph
    from repro.graph.delta import GraphDelta, apply_delta_csr
    from repro.launch.mesh import make_mesh
    from repro.runtime.dispatch import QueryDispatcher

    if args.smoke:
        n_targets, n_sources, n_deltas = 512, 256, 4
    else:
        n_targets, n_sources, n_deltas = 2048, 1024, 8
    backend = "pull_binned_fused"  # scans fwd + binned + pack structures
    csr = structured_graph(n_targets, n_sources)
    deltas = swap_deltas(csr, n_sources, n_deltas)
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    print(
        f"mutable workload: {csr.n_nodes} nodes, {csr.n_edges} edges "
        f"(in-degrees 10/11, one reverse bucket); {n_deltas} same-shape "
        f"swap deltas, backend {backend}"
    )

    disp = QueryDispatcher(mesh, csr, max_iters=32)
    rng = np.random.default_rng(1)
    srcs = rng.integers(0, n_sources, 8).astype(np.int32)
    for _ in range(2):  # warm engines and the phase-1 budget model
        disp.query(srcs, backend=backend)
    events0 = disp.cache.compile_events

    def query_matches(cur):
        lv = np.asarray(
            disp.query(srcs, backend=backend).result.state.levels
        )[: len(srcs), : cur.n_nodes]
        ref = np.stack([bfs_levels(cur, int(s)) for s in srcs])
        return bool((lv == ref).all())

    cur = csr
    records = []
    for i, delta in enumerate(deltas):
        t0 = time.perf_counter()
        rep = disp.apply_delta(delta)
        jax.block_until_ready(
            [b.ops for b in disp._graphs.values()]
        )
        delta_ms = (time.perf_counter() - t0) * 1e3

        cur = apply_delta_csr(cur, delta)
        # the baseline rebuilds exactly the operand set the server holds:
        # one prepare_graph per live bundle, from each bundle's recorded
        # (policy, spec) provenance
        t0 = time.perf_counter()
        rebuilt = [
            prepare_graph(
                cur, mesh, b.policy, None, pad_shards=mesh.size,
                extend=b.spec,
            )[0]
            for b in disp._graphs.values()
        ]
        jax.block_until_ready(rebuilt)
        rebuild_ms = (time.perf_counter() - t0) * 1e3

        ok = query_matches(cur)
        records.append({
            "delta_apply_wall_ms": float(delta_ms),
            "rebuild_wall_ms": float(rebuild_ms),
            "same_shape": bool(rep.same_shape),
            "compile_events_after": int(disp.cache.compile_events),
            "engines_invalidated": int(rep.engines_invalidated),
            "binned_moves": int(rep.binned_moves),
            "results_match": ok,
        })
        print(
            f"delta {i}: fold {delta_ms:.1f} ms vs rebuild "
            f"{rebuild_ms:.1f} ms, same_shape={rep.same_shape}, "
            f"moves={rep.binned_moves}, compile_events "
            f"{disp.cache.compile_events} (was {events0}), match={ok}"
        )

    # reshape probe: 40 adds onto one target leave the {10,11} bucket
    # envelope -> the reverse structures rebuild, stale engines drop
    t0 = int(n_sources)
    probe = GraphDelta(
        add_src=rng.integers(0, n_sources, 40), add_dst=np.full(40, t0)
    )
    rep = disp.apply_delta(probe)
    cur = apply_delta_csr(cur, probe)
    reshape = {
        "same_shape": bool(rep.same_shape),
        "engines_invalidated": int(rep.engines_invalidated),
        "structures_rebuilt": int(rep.structures_rebuilt),
        "results_match": query_matches(cur),
        "compile_events_after": int(disp.cache.compile_events),
    }
    print(
        f"reshape probe: same_shape={reshape['same_shape']}, "
        f"{reshape['engines_invalidated']} engine(s) invalidated, "
        f"{reshape['structures_rebuilt']} structures rebuilt, "
        f"match={reshape['results_match']}"
    )

    delta_total = sum(r["delta_apply_wall_ms"] for r in records)
    rebuild_total = sum(r["rebuild_wall_ms"] for r in records)
    flat = all(r["compile_events_after"] == events0 for r in records)
    all_match = all(r["results_match"] for r in records)
    doc = {
        "schema": SCHEMA,
        "smoke": bool(args.smoke),
        "workload": {
            "n_nodes": int(csr.n_nodes),
            "n_edges": int(csr.n_edges),
            "n_targets": n_targets,
            "n_sources": n_sources,
            "backend": backend,
            "n_deltas": n_deltas,
        },
        "deltas": records,
        "reshape": reshape,
        "summary": {
            "delta_apply_wall_ms": float(delta_total),
            "rebuild_wall_ms": float(rebuild_total),
            "wall_speedup": (
                float(rebuild_total / delta_total) if delta_total else 1.0
            ),
            "compile_events_flat": bool(flat),
            "all_results_match": bool(all_match and
                                      reshape["results_match"]),
            "passes_wall_floor": bool(delta_total < rebuild_total),
            "final_graph_version": int(disp.operands_version),
        },
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(
        f"summary: {n_deltas} deltas folded in {delta_total:.1f} ms vs "
        f"{rebuild_total:.1f} ms rebuild "
        f"({doc['summary']['wall_speedup']:.2f}x), compile_events flat "
        f"{flat}"
    )
    print(f"wrote {args.out} (schema v{SCHEMA} validated)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
