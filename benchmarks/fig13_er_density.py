"""Paper Fig 13: k sweep on Erdős–Rényi graphs of increasing average degree.

Claim: the degree at which increasing k starts DEGRADING performance falls
as the graph densifies (onset at k=16/8/4 for avg degree 100/250/500).
"""
from __future__ import annotations

from .common import emit, frontier_trace
from .table6_k_sweep import k_sweep


def main(quick: bool = False):
    from repro.graph.generators import erdos_renyi, pick_sources

    n = 2000 if quick else 5000
    onsets = {}
    for deg in (25, 50, 100, 250, 500):
        csr = erdos_renyi(n, deg / 2.0, seed=deg)  # symmetric ~deg
        sources = pick_sources(csr, 64, seed=17)
        traces = [frontier_trace(csr, int(s))[0] for s in sources]
        from .table5_visits import visit_factor as vf_fn

        _, vf, _ = vf_fn(csr, int(sources[0]))
        imp = k_sweep(csr, traces, vf)
        ks = sorted(imp)
        onset = 32
        for a, b in zip(ks, ks[1:]):
            if imp[b] < imp[a] * 0.995:
                onset = b
                break
        onsets[deg] = onset
        emit(f"fig13_deg{deg}", 0.0,
             "imp=" + " ".join(f"k{k}:{imp[k]:.2f}" for k in ks) +
             f" degradation_onset_k={onset}")
    # monotone: denser => degradation at smaller (or equal) k
    degs = sorted(onsets)
    assert all(onsets[a] >= onsets[b] for a, b in zip(degs, degs[1:])), onsets
    emit("fig13_claim", 0.0, f"onset_monotone_in_density={onsets}")


if __name__ == "__main__":
    main()
