"""Roofline report: aggregates results/dryrun/*.json into the per-cell
three-term table (EXPERIMENTS.md §Roofline).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
                                                    [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str | None = None, tag: str | None = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if (r.get("tag") or "") != (tag or ""):
            continue
        rows.append(r)
    return rows


def fmt_row(r) -> str:
    rl = r.get("roofline", {})
    mem = r.get("memory", {})
    gib = mem.get("total_bytes_per_device", 0) / 2**30
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | | | "
                f"| {r.get('error', '')[:60]} |")
    dom = rl.get("dominant", "?")
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {gib:.2f} | {rl.get('flops_per_device', 0):.2e} "
        f"| {rl.get('compute_s', 0):.2e} | {rl.get('memory_s', 0):.2e} "
        f"| {rl.get('collective_s', 0):.2e} | {dom} "
        f"| {rl.get('useful_fraction', 0):.2f} "
        f"| {rl.get('roofline_fraction', 0)*100:.1f}% |"
    )


HEADER = (
    "| arch | shape | mesh | GiB/dev | HLO flops/dev | compute s | "
    "memory s | collective s | dominant | useful frac | roofline % |\n"
    "|---|---|---|---|---|---|---|---|---|---|---|"
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    rows = load(args.dir, args.mesh, args.tag)
    print(HEADER)
    n_ok = 0
    for r in rows:
        print(fmt_row(r))
        n_ok += r.get("status") == "ok"
    print(f"\n# {n_ok}/{len(rows)} cells ok")
    from .common import emit

    emit("roofline_cells", 0.0, f"{n_ok}/{len(rows)}_cells_compiled")
    return rows


if __name__ == "__main__":
    main()
