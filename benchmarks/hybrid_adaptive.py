"""Static nTkS vs adaptive two-phase hybrid on a skewed source set.

The adversarial workload for static source-morsel dispatch (paper §5.4):
most sources sit in a small-diameter powerlaw component and converge in a
few IFE iterations, while one source starts at the head of a long path
component and needs ~diameter iterations. Static nTkS reduces its
convergence check over source AND graph axes, so every source shard's
while_loop for a given morsel slot spins until the slowest shard's morsel
in that slot finishes — almost all of it inert. The adaptive runtime runs
phase 1 with per-shard convergence under a learned iteration budget, then
re-dispatches only the path morsel under nT1S frontier parallelism (ring
frontier union) with every device cooperating.

Runs on 8 forced host devices, mesh (4, 2): 4 source shards × 2 graph
shards, so the static waste is real (4 shards × inert slot iterations).
Standalone on purpose (NOT in benchmarks/run.py): it must force its own
XLA device count before first jax init, which would leak into sibling
suites in a shared process.

    PYTHONPATH=src python benchmarks/hybrid_adaptive.py
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import numpy as np

import common


def skewed_graph(n_pl: int = 400, path_len: int = 96, seed: int = 0):
    """Powerlaw component (small diameter) + a path component (diameter ≈
    path_len) in one CSR. Returns (csr, powerlaw_sources, path_head)."""
    from repro.graph.csr import csr_from_edges
    from repro.graph.generators import powerlaw

    pl = powerlaw(n_pl, 5.0, seed=seed)
    src_pl, dst_pl = pl.edge_list()
    p = np.arange(path_len - 1, dtype=np.int32) + n_pl
    src = np.concatenate([src_pl, p, p + 1])
    dst = np.concatenate([dst_pl, p + 1, p])
    csr = csr_from_edges(n_pl + path_len, src, dst)
    rng = np.random.default_rng(seed + 1)
    pl_sources = rng.integers(0, n_pl, 7).astype(np.int32)
    return csr, pl_sources, np.int32(n_pl)


def main() -> int:
    import jax

    from repro.core import (
        build_engine,
        pad_sources,
        policy_ntks,
        prepare_graph,
    )
    from repro.core.dispatcher import _axes_size
    from repro.launch.mesh import make_mesh
    from repro.runtime.scheduler import AdaptiveScheduler

    if jax.device_count() >= 8:
        mesh = make_mesh((4, 2), ("data", "model"))
    else:  # degraded single-device fallback (no inert spins to recover)
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    csr, pl_sources, path_src = skewed_graph()
    # the path source shares a morsel SLOT with powerlaw sources on the
    # other shards: its slot spins every shard under static global sync
    sources = np.concatenate([pl_sources, [path_src]]).astype(np.int32)
    max_iters = 128

    print(
        f"skewed workload: {csr.n_nodes} nodes ({len(pl_sources)} powerlaw "
        f"sources + 1 path source, path diameter ~96), mesh {dict(mesh.shape)}"
    )

    # --- static nTkS: one engine, globally-synchronized convergence --------
    pol = policy_ntks()
    g, n_pad = prepare_graph(csr, mesh, pol, pad_shards=mesh.size)
    eng = build_engine(mesh, pol, "sp_lengths", n_pad, max_iters)
    morsels = jax.numpy.asarray(
        pad_sources(sources, _axes_size(mesh, pol.source_axes), 1, n_pad)
    )
    static_res = jax.block_until_ready(eng(g, morsels))
    static_iters = np.asarray(static_res.iterations)[: len(sources)]
    static_us = common.time_fn(lambda: eng(g, morsels))

    # --- adaptive hybrid: warm it on the easy sources, then hit the skew ---
    sched = AdaptiveScheduler(mesh, csr, max_iters=max_iters)
    for _ in range(3):  # learn the phase-1 budget from easy batches
        sched.query(pl_sources)
    sched.query(sources)  # compile the skewed-batch shapes once
    out = sched.query(sources)
    adaptive_iters = np.asarray(out.result.iterations)[: len(sources)]
    # freeze the budget for the timed reps: otherwise the skewed batches
    # feed the learner mid-measurement and later reps time a different
    # (bigger-budget, no-phase-2) configuration than the one reported
    sched.phase1_iters = out.phase1_budget
    adaptive_us = common.time_fn(lambda: sched.query(sources).result)

    lv_s = np.asarray(static_res.state.levels)[: len(sources), : csr.n_nodes]
    lv_a = np.asarray(out.result.state.levels)[: len(sources), : csr.n_nodes]
    assert (lv_s == lv_a).all(), "hybrid result != static result"

    # iteration-slots: static reports each morsel's while trip count, which
    # under global sync is the max over its slot's source-shard group (inert
    # spins included); adaptive reports each morsel's own convergence point
    slots_static = int(static_iters.sum())
    slots_adaptive = int(adaptive_iters.sum())
    print(f"per-morsel iterations (static)  : {static_iters}")
    print(f"per-morsel iterations (adaptive): {adaptive_iters}")
    print(
        f"phase-1 budget {out.phase1_budget}, re-dispatched "
        f"{out.redispatched} morsel(s); phase latencies "
        f"p1 {out.phase_ms['phase1']:.1f} ms / "
        f"p2 {out.phase_ms['phase2']:.1f} ms"
    )
    common.emit("hybrid_adaptive.static_ntks", static_us,
                f"iter_slots={slots_static}")
    common.emit("hybrid_adaptive.adaptive", adaptive_us,
                f"iter_slots={slots_adaptive}")
    speedup = static_us / max(adaptive_us, 1e-9)
    print(
        f"iteration-slots: static {slots_static} vs adaptive "
        f"{slots_adaptive} ({slots_static / max(slots_adaptive, 1):.1f}x "
        f"fewer); wall: {static_us:.0f} us vs {adaptive_us:.0f} us "
        f"({speedup:.2f}x)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
