"""Static nTkS vs adaptive two-phase hybrid — ganged vs serial phase 2.

The adversarial workload for static source-morsel dispatch (paper §5.4):
most sources sit in a small-diameter powerlaw component and converge in a
few IFE iterations, while several sources start at the heads of long path
components of staggered lengths and need ~diameter iterations each. Static
nTkS reduces its convergence check over source AND graph axes, so every
source shard's while_loop for a given morsel slot spins until the slowest
shard's morsel in that slot finishes — almost all of it inert. The adaptive
runtime runs phase 1 with per-shard convergence under a learned iteration
budget, then re-dispatches only the straggler morsels under nT1S frontier
parallelism (ring frontier union) with every device cooperating.

Phase 2 itself is measured two ways (ISSUE 4):

- **serial** (``gang_resume=False``): the legacy per-morsel resume —
  ``lax.map`` drains survivors sequentially, so phase-2 iteration slots are
  the SUM of the survivors' remaining trip counts;
- **ganged** (default): one batched multi-frontier resume with per-survivor
  convergence masks — slots are the MAX of the remaining trips, because
  every survivor iterates in the same while_loop and early finishers go
  inert. The staggered path lengths make the gap visible: the shorter
  stragglers finish mid-gang without holding anyone up.

Emits ``BENCH_hybrid_adaptive.json`` (``--out``) with per-phase wall times,
the gang occupancy, and the ganged-vs-serial phase-2 iteration-slot floor;
``scripts/ci.sh --bench-smoke`` re-runs this in ``--smoke`` mode and
``validate()``s the document.

Runs on 8 forced host devices, mesh (4, 2): 4 source shards × 2 graph
shards, so the static waste is real (4 shards × inert slot iterations).
Standalone on purpose (NOT in benchmarks/run.py): it must force its own
XLA device count before first jax init, which would leak into sibling
suites in a shared process.

    PYTHONPATH=src python benchmarks/hybrid_adaptive.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import numpy as np

import common

SCHEMA = 1

REQUIRED = {
    "schema": int,
    "mesh": list,
    "smoke": bool,
    "workload": dict,
    "phase1_budget": int,
    "static_ntks": dict,
    "adaptive": dict,
    "gang": dict,
    "summary": dict,
}
GANG_FIELDS = (
    "survivors", "gang_width", "occupancy",
    "phase2_slots_ganged", "phase2_slots_serial",
    "phase2_wall_ms_ganged_p50", "phase2_wall_ms_serial_p50",
    "phase2_wall_ratio_serial_over_ganged",
)


def validate(doc: dict) -> None:
    """Schema + acceptance guard for BENCH_hybrid_adaptive.json: the gang
    block must be complete, at least two survivors must actually have been
    ganged, and the ganged phase-2 iteration-slot count must sit on its
    floor (<= the serial per-morsel drain's slot sum)."""
    for key, ty in REQUIRED.items():
        assert key in doc, f"missing top-level field: {key}"
        assert isinstance(doc[key], ty), (key, type(doc[key]))
    assert doc["schema"] == SCHEMA, doc["schema"]
    g = doc["gang"]
    for f in GANG_FIELDS:
        assert f in g, f"missing gang field: {f}"
    assert g["survivors"] >= 2, f"need >=2 ganged survivors, got {g}"
    assert g["gang_width"] >= g["survivors"], g
    assert 0.0 < g["occupancy"] <= 1.0, g
    assert g["phase2_slots_ganged"] >= 1, g
    assert g["phase2_slots_ganged"] <= g["phase2_slots_serial"], (
        "ganged phase-2 slot floor violated: "
        f"{g['phase2_slots_ganged']} > {g['phase2_slots_serial']}"
    )
    assert doc["summary"]["passes_slot_floor"] is True, doc["summary"]


def smoke_line(doc: dict) -> str:
    """One-line artifact summary for the CI bench-smoke lane."""
    g = doc["gang"]
    return (
        f"{g['survivors']} survivors ganged "
        f"(occupancy {g['occupancy']:.2f}), phase-2 slots "
        f"{g['phase2_slots_ganged']} ganged vs "
        f"{g['phase2_slots_serial']} serial, wall ratio serial/ganged "
        f"{g['phase2_wall_ratio_serial_over_ganged']:.2f}x"
    )


def skewed_graph(n_pl: int = 400, paths: tuple = (96, 80, 64), seed: int = 0):
    """Powerlaw component (small diameter) + ``len(paths)`` path components
    of staggered diameters in one CSR. Returns (csr, powerlaw_sources,
    path_heads)."""
    from repro.graph.csr import csr_from_edges
    from repro.graph.generators import powerlaw

    pl = powerlaw(n_pl, 5.0, seed=seed)
    src_pl, dst_pl = pl.edge_list()
    srcs, dsts, base, heads = [src_pl], [dst_pl], n_pl, []
    for length in paths:
        p = np.arange(length - 1, dtype=np.int64) + base
        srcs += [p, p + 1]
        dsts += [p + 1, p]
        heads.append(base)
        base += length
    csr = csr_from_edges(base, np.concatenate(srcs), np.concatenate(dsts))
    rng = np.random.default_rng(seed + 1)
    pl_sources = rng.integers(0, n_pl, 7).astype(np.int32)
    return csr, pl_sources, np.asarray(heads, np.int32)


def _timed_queries(sched, sources, reps: int):
    """Median wall (us) + median per-phase ms over ``reps`` repeat queries
    (budget pinned by the caller, so every rep runs the same program)."""
    import jax

    walls, p1, p2, last = [], [], [], None
    for _ in range(reps):
        t0 = time.perf_counter()
        last = sched.query(sources)
        jax.block_until_ready(last.result.state)
        walls.append((time.perf_counter() - t0) * 1e6)
        p1.append(last.phase_ms["phase1"])
        p2.append(last.phase_ms["phase2"])
    return (
        float(np.median(walls)),
        float(np.median(p1)),
        float(np.median(p2)),
        last,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph / few reps (CI bench-smoke lane)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parent.parent / "BENCH_hybrid_adaptive.json"
    ))
    args = ap.parse_args(argv)

    import jax

    from repro.core import (
        build_engine,
        pad_sources,
        policy_ntks,
        prepare_graph,
    )
    from repro.core.dispatcher import _axes_size
    from repro.launch.mesh import make_mesh
    from repro.runtime.scheduler import AdaptiveScheduler

    if jax.device_count() >= 8:
        mesh = make_mesh((4, 2), ("data", "model"))
    else:  # degraded single-device fallback (no inert spins to recover)
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    if args.smoke:
        n_pl, paths, reps, max_iters = 220, (48, 36), 3, 64
    else:
        n_pl, paths, reps, max_iters = 400, (96, 80, 64), 5, 128
    csr, pl_sources, heads = skewed_graph(n_pl, paths)
    # every path head shares a morsel SLOT with powerlaw sources on the
    # other shards: its slot spins every shard under static global sync
    sources = np.concatenate([pl_sources, heads]).astype(np.int32)

    print(
        f"skewed workload: {csr.n_nodes} nodes ({len(pl_sources)} powerlaw "
        f"sources + {len(heads)} path heads, path diameters "
        f"~{tuple(int(p) - 1 for p in paths)}), mesh {dict(mesh.shape)}"
    )

    # --- static nTkS: one engine, globally-synchronized convergence --------
    pol = policy_ntks()
    g, n_pad = prepare_graph(csr, mesh, pol, pad_shards=mesh.size)
    eng = build_engine(mesh, pol, "sp_lengths", n_pad, max_iters)
    morsels = jax.numpy.asarray(
        pad_sources(sources, _axes_size(mesh, pol.source_axes), 1, n_pad)
    )
    static_res = jax.block_until_ready(eng(g, morsels))
    static_iters = np.asarray(static_res.iterations)[: len(sources)]
    static_us = common.time_fn(lambda: eng(g, morsels))

    # --- adaptive hybrid: learn the budget, then pin it for both phase-2
    # modes so they see the *identical* phase-1 survivor set ---------------
    learner = AdaptiveScheduler(mesh, csr, max_iters=max_iters)
    for _ in range(3):  # learn the phase-1 budget from easy batches
        learner.query(pl_sources)
    budget = learner.query(sources).phase1_budget

    gang = AdaptiveScheduler(
        mesh, csr, max_iters=max_iters, phase1_iters=budget
    )
    serial = AdaptiveScheduler(
        mesh, csr, max_iters=max_iters, phase1_iters=budget,
        gang_resume=False,
    )
    gang.query(sources)  # compile the skewed-batch shapes once
    serial.query(sources)
    gang_us, gang_p1, gang_p2, out = _timed_queries(gang, sources, reps)
    serial_us, ser_p1, ser_p2, sout = _timed_queries(serial, sources, reps)

    adaptive_iters = np.asarray(out.result.iterations)[: len(sources)]
    lv_s = np.asarray(static_res.state.levels)[: len(sources), : csr.n_nodes]
    lv_g = np.asarray(out.result.state.levels)[: len(sources), : csr.n_nodes]
    lv_r = np.asarray(sout.result.state.levels)[: len(sources), : csr.n_nodes]
    assert (lv_s == lv_g).all(), "ganged hybrid result != static result"
    assert (lv_g == lv_r).all(), "ganged result != serial-resume result"

    # phase-2 iteration slots: each survivor still owes (iters - budget)
    # trips after phase 1. The serial lax.map drains them back-to-back
    # (slots = sum); the gang runs them in one masked while_loop
    # (slots = max) — the structural serialization this bench guards.
    trips = np.maximum(adaptive_iters - budget, 0)
    survivors = int(out.redispatched)
    slots_serial = int(trips.sum())
    slots_ganged = int(trips.max()) if trips.size else 0
    slots_static = int(static_iters.sum())
    slots_adaptive = int(adaptive_iters.sum())
    occupancy = survivors / out.gang_width if out.gang_width else 0.0

    print(f"per-morsel iterations (static)  : {static_iters}")
    print(f"per-morsel iterations (adaptive): {adaptive_iters}")
    print(
        f"phase-1 budget {budget}; {survivors} survivor(s) ganged into a "
        f"{out.gang_width}-wide dispatch (occupancy {occupancy:.2f})"
    )
    print(
        f"phase-2 iteration slots: ganged {slots_ganged} (max trips) vs "
        f"serial {slots_serial} (sum); wall p50 "
        f"{gang_p2:.1f} ms vs {ser_p2:.1f} ms"
    )
    common.emit("hybrid_adaptive.static_ntks", static_us,
                f"iter_slots={slots_static}")
    common.emit("hybrid_adaptive.adaptive_ganged", gang_us,
                f"iter_slots={slots_adaptive}")
    common.emit("hybrid_adaptive.adaptive_serial", serial_us,
                f"phase2_slots={slots_serial}")
    speedup = static_us / max(gang_us, 1e-9)
    print(
        f"iteration-slots: static {slots_static} vs adaptive "
        f"{slots_adaptive} ({slots_static / max(slots_adaptive, 1):.1f}x "
        f"fewer); wall: {static_us:.0f} us vs {gang_us:.0f} us "
        f"({speedup:.2f}x)"
    )

    doc = {
        "schema": SCHEMA,
        "mesh": [int(v) for v in mesh.shape.values()],
        "smoke": bool(args.smoke),
        "workload": {
            "n_nodes": int(csr.n_nodes),
            "n_edges": int(csr.n_edges),
            "avg_degree": float(csr.avg_degree),
            "n_sources": int(len(sources)),
            "path_lengths": [int(p) for p in paths],
        },
        "phase1_budget": int(budget),
        "static_ntks": {
            "wall_us": static_us,
            "iter_slots": slots_static,
        },
        "adaptive": {
            "wall_us_ganged": gang_us,
            "wall_us_serial": serial_us,
            "iter_slots": slots_adaptive,
            "phase1_wall_ms_p50": gang_p1,
        },
        "gang": {
            "survivors": survivors,
            "gang_width": int(out.gang_width),
            "occupancy": occupancy,
            "phase2_slots_ganged": slots_ganged,
            "phase2_slots_serial": slots_serial,
            "phase2_wall_ms_ganged_p50": gang_p2,
            "phase2_wall_ms_serial_p50": ser_p2,
            "phase2_wall_ratio_serial_over_ganged": (
                ser_p2 / max(gang_p2, 1e-9)
            ),
        },
        "summary": {
            "iter_slot_reduction_vs_static": (
                slots_static / max(slots_adaptive, 1)
            ),
            "wall_speedup_vs_static": speedup,
            "passes_slot_floor": slots_ganged <= slots_serial
            and survivors >= 2,
        },
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
