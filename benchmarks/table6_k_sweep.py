"""Paper Table 6 + Fig 12a: effect of k (concurrent source morsels) in nTkS.

64-source workload, 32 threads, k in {1..32}. The cache-pressure term uses
each dataset's measured per-source state footprint vs an L3-sized budget:
low-degree graphs gain monotonically with k; the dense Spotify proxy peaks
at small k and then DEGRADES — the paper's locality finding.
"""
from __future__ import annotations

import numpy as np

from .common import emit, frontier_trace
from .sched_sim import simulate

LLC_BYTES = 20e6  # paper's Xeon: 20 MB L3


def k_sweep(csr, traces, visit_factor: float):
    """Locality term (paper §5.5): concurrent source morsels evict each
    other's hot visited-array lines; the hotter the reuse (Table 5 visit
    factor), the more each extra concurrent morsel costs. Modeled as
    slowdown = 1 + alpha·(k_active - 1) with alpha ∝ visit factor —
    calibrated to reproduce the paper's QUALITATIVE finding (dense graphs
    peak at small k), not absolute LLC counts."""
    alpha = 0.04 * visit_factor / 500.0
    out = {}
    for k in (1, 2, 4, 8, 16, 32):
        r = simulate(
            traces, 32, "ntks", k=k,
            cache_alpha=alpha, state_per_source=1.0, llc=1.0,
        )
        out[k] = r.makespan
    base = out[1]
    return {k: base / v for k, v in out.items()}


def main(quick: bool = False):
    from repro.graph.generators import PAPER_DATASETS, pick_sources

    from .table5_visits import visit_factor as vf_fn

    scale = 0.35 if quick else 0.6
    best_k = {}
    for name, gen in PAPER_DATASETS.items():
        csr = gen(scale)
        sources = pick_sources(csr, 64, seed=13)
        traces = [frontier_trace(csr, int(s))[0] for s in sources]
        # locality pressure keyed on the measured visit factor (Table 5)
        _, vf, _ = vf_fn(csr, int(sources[0]))
        imp = k_sweep(csr, traces, vf)
        best = max(imp, key=imp.get)
        best_k[name] = best
        emit(f"table6_{name}", 0.0,
             "improvement_over_k1=" + " ".join(
                 f"k{k}:{imp[k]:.2f}x" for k in sorted(imp)) +
             f" best_k={best} avg_deg={csr.avg_degree:.0f}")
    # paper claim: spotify's optimum k is far below the sparse datasets'
    sparse_best = min(v for k, v in best_k.items() if k != "spotify")
    assert best_k["spotify"] <= 8 and best_k["spotify"] < sparse_best, best_k
    emit("table6_claim", 0.0,
         f"dense_graph_prefers_small_k={best_k}")


if __name__ == "__main__":
    main()
