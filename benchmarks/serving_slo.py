"""Always-on serving under sustained open-loop arrivals — the overlapped
async loop vs the synchronous-flush baseline, with tenant SLO accounting.

The serving claim of ISSUE 6: when arrivals are OPEN-LOOP (they keep
coming whether or not the server keeps up), the pre-split serving story
— a driver that collects a fixed-size round of requests and pushes it
through a synchronous ``flush()``, so admission happens ONLY at flush
time — makes every query gate on its round: the round's early members
wait for its LAST arrival before anything is even admitted, and the
round cannot start until the previous flush fully completes. The
always-on ``runtime.service.ServingLoop`` removes both waits: a query
is admitted the moment it arrives, joins the next capped batch's lane
packing as soon as the device frees, and batch i's deferred host work
(result-state transfer, survivor stitch, per-query unpacking) is hidden
behind batch i+1's device dispatch (begin(i+1) → finalize(i) →
settle(i+1)).

Measured here, on the same seeded Poisson arrival schedules for both
sides — the SAME admission/packing/dispatch/learning code serving each
stream, only the serving architecture differs:

- **async**: ``ServingLoop.run_stream`` (admit-on-arrival, capped
  batches, ``overlap=True`` pipelined finalize);
- **sync-flush baseline**: the same loop with ``overlap=False`` driven
  in legacy rounds (``run_flush_rounds``): wait for the next
  ``flush_group`` queries to all arrive, submit them, flush to
  completion, repeat — the pool size per flush matches the async cap,
  so both sides dispatch identical-size packs.

- **sustained phase** (arrival rate at ~half the warm service rate —
  see the tuning note in ``main`` — two tenants, batches capped at
  ``max_batch_sources``), repeated N times with fresh seeded schedules
  and the two sides INTERLEAVED (async_r then sync_r on the same warmed
  loops, so ambient machine noise hits both sides of every repeat). Latency is CLIENT-OBSERVED — scheduled arrival to delivered
  result, measured by the driver via ``on_result`` — because the flush
  baseline's defining cost is the wait OUTSIDE the server before a
  mid-round arrival is even admitted; server-side submit-to-delivery
  stats would not see it. Every compiled shape is pre-warmed and the
  measured repeats are asserted cold-free, so warm == all here. The
  reported p99 — and the floor — is the MEDIAN across repeats of each
  repeat's p99: one backlogged repeat's p99 is a single noisy sample,
  and a median over interleaved repeats makes the floor a property of
  the serving architecture rather than of one pool boundary's timing
  luck;
- **low-load SLO phase** (arrival rate below service rate, generous
  per-query deadline): deadline-miss and shed counts — both must be zero;
- **bit-identity**: every query's levels rows equal between the two modes
  (admission slicing may batch the stream differently at different wall
  speeds; results must not care).

Floors (asserted here and by ``scripts/ci.sh --bench-smoke``): overlap
occupancy > 0, async warm p99 <= synchronous-flush warm p99, results
bit-identical, zero deadline misses at low load.

Writes machine-readable ``BENCH_serving_slo.json`` (schema validated
in-process and re-validated by the CI lane).

    PYTHONPATH=src python benchmarks/serving_slo.py [--smoke] \
        [--out BENCH_serving_slo.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

SCHEMA = 1

REQUIRED = {
    "schema": int,
    "smoke": bool,
    "workload": dict,
    "stream": dict,
    "async": dict,
    "sync": dict,
    "slo": dict,
    "summary": dict,
}
MODE_FIELDS = (
    "p50_ms", "p99_ms", "p99_ms_runs", "all_p50_ms", "all_p99_ms",
    "batches", "cold_batches", "overlap_occupancy", "overlapped_finalizes",
    "finalizes", "completed", "shed", "deadline_misses", "sustained_wall_s",
)


def validate(doc: dict) -> None:
    """Schema + acceptance guards for BENCH_serving_slo.json: both mode
    blocks complete, the async loop actually overlapped (occupancy > 0),
    its sustained warm p99 (median across interleaved repeats) at or
    under the synchronous-flush baseline's, results bit-identical, and
    zero deadline misses/sheds at low load."""
    for key, ty in REQUIRED.items():
        assert key in doc, f"missing top-level field: {key}"
        assert isinstance(doc[key], ty), (key, type(doc[key]))
    assert doc["schema"] == SCHEMA, doc["schema"]
    for side in ("async", "sync"):
        for f in MODE_FIELDS:
            assert f in doc[side], f"missing {side} field: {f}"
        assert doc[side]["completed"] > 0, (side, doc[side])
        runs = doc[side]["p99_ms_runs"]
        assert isinstance(runs, list) and len(runs) >= 1, (side, runs)
    assert doc["async"]["overlap_occupancy"] > 0.0, (
        "async loop never overlapped a finalize", doc["async"]
    )
    assert doc["sync"]["overlap_occupancy"] == 0.0, doc["sync"]
    slo = doc["slo"]
    for f in ("deadline_ms", "async_deadline_misses", "async_shed",
              "sync_deadline_misses"):
        assert f in slo, f"missing slo field: {f}"
    s = doc["summary"]
    for f in ("async_p99_ms", "sync_p99_ms", "p99_speedup",
              "passes_p99_floor", "passes_occupancy_floor",
              "results_bit_identical", "zero_misses_at_low_load"):
        assert f in s, f"missing summary field: {f}"
    assert s["results_bit_identical"] is True, s
    assert s["passes_occupancy_floor"] is True, s
    assert s["zero_misses_at_low_load"] is True, (
        "deadline misses/sheds at LOW load", slo
    )
    assert s["passes_p99_floor"] is True, (
        "async overlapped p99 (median across interleaved sustained "
        "repeats) must not exceed the synchronous-flush baseline: "
        f"{s['async_p99_ms']:.1f} vs {s['sync_p99_ms']:.1f} ms "
        f"(runs: {doc['async']['p99_ms_runs']} vs "
        f"{doc['sync']['p99_ms_runs']})"
    )
    assert s["async_p99_ms"] <= s["sync_p99_ms"], s


def smoke_line(doc: dict) -> str:
    """One-line artifact summary for the CI bench-smoke lane."""
    s = doc["summary"]
    return (
        f"sustained warm p99 {s['async_p99_ms']:.1f} ms async vs "
        f"{s['sync_p99_ms']:.1f} ms sync-flush "
        f"({s['p99_speedup']:.2f}x), occupancy "
        f"{doc['async']['overlap_occupancy']:.2f}, bit-identical "
        f"{s['results_bit_identical']}, zero low-load misses "
        f"{s['zero_misses_at_low_load']}"
    )


def serving_graph(n_pl: int, n_paths: int, path_len: int, seed: int = 0):
    """Erdos-Renyi main component + path straggler components. ER keeps
    the max degree near the mean, so the padded ELL rows stay narrow and
    per-batch device time is interactive (a powerlaw hub would widen
    every row to the hub degree); the deep paths still hand phase 2 real
    stragglers to gang-resume."""
    from repro.graph.csr import csr_from_edges
    from repro.graph.generators import erdos_renyi

    pl = erdos_renyi(n_pl, 6.0, seed=seed)
    src_pl, dst_pl = pl.edge_list()
    srcs, dsts, base, heads = [src_pl], [dst_pl], n_pl, []
    for _ in range(n_paths):
        p = np.arange(path_len - 1, dtype=np.int64) + base
        srcs += [p, p + 1]
        dsts += [p + 1, p]
        heads.append(base)
        base += path_len
    csr = csr_from_edges(base, np.concatenate(srcs), np.concatenate(dsts))
    return csr, np.asarray(heads, np.int32)


def arrival_schedule(csr, heads, n_rand: int, n_arrivals: int,
                     rate_qps: float, k_sources: int, tenants: int,
                     tenant_prefix: str, deadline_ms: float | None,
                     seed: int):
    """Seeded Poisson schedule (identical for both modes): exponential
    gaps at ``rate_qps``, round-robin tenants, sources drawn per arrival
    with one straggler head mixed into every fourth query. Random
    sources come from the ER main component only (``[0, n_rand)``) so
    phase-2 survivors are exactly the scheduled straggler heads — the
    gang shapes the stream can hit stay inside the pre-warmed set."""
    rng = np.random.default_rng(seed)
    gaps_ms = rng.exponential(1e3 / rate_qps, size=n_arrivals)
    t_ms = np.cumsum(gaps_ms)
    arrivals = []
    for i in range(n_arrivals):
        srcs = rng.integers(0, n_rand, k_sources).astype(np.int32)
        if i % 4 == 0 and len(heads):
            srcs = np.concatenate(
                [[heads[i % len(heads)]], srcs[:-1]]
            ).astype(np.int32)
        arrivals.append({
            "t_ms": float(t_ms[i]),
            "sources": srcs,
            "tenant": f"{tenant_prefix}{i % tenants}",
            "deadline_ms": deadline_ms,
            "qid": f"{tenant_prefix}_{i}",
        })
    return arrivals


def warm_shapes(loop, csr, heads, n_rand, k_sources, warm_morsels,
                seed=3):
    """Pre-compile the engine/shape set the stream can hit. The serving
    dispatcher pow2-pads morsel counts, so pools of 64*m sources for each
    pow2 m cover every packed shape a backlogged queue can produce; one
    solo query warms the per-query path the low-load phase takes.
    Straggler heads are mixed in (same every-4th cadence as the stream)
    so phase-2 gang shapes compile too."""
    rng = np.random.default_rng(seed)

    def srcs(j):
        s = rng.integers(0, n_rand, k_sources).astype(np.int32)
        if j % 4 == 0 and len(heads):
            s = np.concatenate([[heads[j % len(heads)]], s[:-1]])
        return s.astype(np.int32)

    for m in warm_morsels:
        for j in range((64 * m) // k_sources):
            loop.submit(srcs(j), tenant="warm", qid=f"warm_{m}_{j}")
        loop.drain()  # one pooled pump: exactly m morsels
    # the per-query path, both flavors: all-shallow (phase 1 converges
    # everything) and with a straggler (compiles the solo gang engine)
    loop.submit(srcs(1), tenant="warm", qid="warm_solo")
    loop.drain()
    loop.submit(srcs(0), tenant="warm", qid="warm_solo_straggler")
    loop.drain()


def make_warm_loop(overlap: bool, csr, mesh, heads, n_rand, k_sources,
                   warm_morsels, max_batch_sources):
    """Build one serving loop and warm it (all compiles happen here).
    The phase-1 budget is pinned and online refits are off so both modes
    serve an identical, stable engine set: the measured delta is the
    serving architecture, not compile luck. ``max_batch_sources`` bounds
    each batch (both sides get it — the flush baseline's rounds are the
    same capped batches, just drained to empty before re-admission)."""
    from repro.runtime.service import ServingLoop

    loop = ServingLoop(
        mesh, csr, overlap=overlap, family="er", max_iters=64,
        backend="dopt", phase1_iters=16, online_adapt=False,
        max_batch_sources=max_batch_sources,
    )
    warm_shapes(loop, csr, heads, n_rand, k_sources, warm_morsels)
    return loop


def run_flush_rounds(loop, arrivals, group: int):
    """The legacy synchronous-flush serving pattern — the pre-split
    ``serve.py`` driver shape (fixed-size request rounds through
    ``AdaptiveScheduler.flush()``), replayed against a live stream:
    wait until the next ``group`` queries have ALL arrived, submit
    them, and flush the round to completion before looking at the
    stream again. Admission happens only at flush time: early members
    of a round gate on its last arrival and on the whole previous
    flush, which is exactly the dead time an always-on loop exists to
    remove. ``group`` is set to the same per-batch query budget the
    async loop's ``max_batch_sources`` cap yields, so both sides flush
    identically-sized pools — the serving architecture is the only
    difference. Same loop, same engines, same results."""
    order = sorted(arrivals, key=lambda a: a["t_ms"])
    t0 = loop.clock()
    for g0 in range(0, len(order), group):
        rnd = order[g0:g0 + group]
        while True:  # a synchronous driver cannot admit mid-flush
            now_ms = (loop.clock() - t0) * 1e3
            if rnd[-1]["t_ms"] <= now_ms:
                break
            time.sleep(min(0.005, (rnd[-1]["t_ms"] - now_ms) / 1e3))
        for a in rnd:
            loop.submit(
                a["sources"], tenant=a.get("tenant", "default"),
                deadline_ms=a.get("deadline_ms"), qid=a.get("qid"),
            )
        loop.drain()  # synchronous flush round
    return loop.results


def tenant_pctl(loop, prefix: str, p: float, warm: bool = True) -> float:
    vals = []
    for name, ts in loop.stats.tenants.items():
        if name.startswith(prefix):
            vals.extend(ts.warm_latencies_ms if warm else ts.latencies_ms)
    return float(np.percentile(np.asarray(vals), p)) if vals else float("nan")


def tenant_counts(loop, prefix: str):
    shed = misses = completed = 0
    for name, ts in loop.stats.tenants.items():
        if name.startswith(prefix):
            shed += ts.shed
            misses += ts.deadline_misses
            completed += ts.completed
    return completed, shed, misses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph / short stream (CI bench-smoke lane)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parent.parent / "BENCH_serving_slo.json"
    ))
    args = ap.parse_args(argv)

    import jax

    from repro.launch.mesh import make_mesh

    # the stream sits at ~half the warm service rate (a capped 2-morsel
    # batch serves 8 pooled queries in ~200-400 ms on the smoke graph).
    # That regime is chosen deliberately: with headroom, the always-on
    # loop serves each arrival as soon as the device frees (its tail is
    # ~one batch), while the flush driver still gates every round on the
    # round's LAST arrival — a wait of up to group/rate set by the
    # SCHEDULE, not by machine speed, which is what makes the p99 floor
    # reproducible. (A heavily backlogged stream would hide the
    # difference: both servers become work-conserving FIFO drains of the
    # same capped batches and their tails converge.)
    if args.smoke:
        n_pl, n_paths, path_len = 1536, 2, 24
        n_sustained, k_sources = 64, 16
        n_slo, rate_slo = 8, 12.0
        warm_morsels = (1, 2)
        rate_sustained = 16.0
        n_repeats = 5
    else:
        # the full graph serves ~6 q/s under load, so 3 q/s keeps the
        # same ~0.5 utilisation the smoke config has
        n_pl, n_paths, path_len = 6144, 3, 32
        n_sustained, k_sources = 48, 16
        n_slo, rate_slo = 12, 6.0
        warm_morsels = (1, 2)
        rate_sustained = 3.0
        n_repeats = 5
    max_batch_sources = 8 * k_sources  # 8 queries / 2 morsels per batch
    flush_group = max_batch_sources // k_sources
    deadline_ms = 5000.0
    csr, heads = serving_graph(n_pl, n_paths, path_len)
    mesh = make_mesh((1, jax.device_count()), ("data", "model"))
    print(
        f"serving workload: {csr.n_nodes} nodes, {csr.n_edges} edges, "
        f"avg degree {csr.avg_degree:.1f}; sustained {n_sustained} "
        f"arrivals at {rate_sustained:.0f} q/s x {k_sources} sources "
        f"x {n_repeats} interleaved repeats (batches capped at "
        f"{max_batch_sources} pooled sources), SLO phase {n_slo} "
        f"arrivals at {rate_slo:.0f} q/s, deadline {deadline_ms:.0f} ms"
    )

    # fresh seeded schedule per repeat; tenant prefix r{r}t keeps the
    # qid spaces disjoint and lets each repeat's warm p99 be read back
    # out of the shared per-tenant stats
    repeats = [
        arrival_schedule(
            csr, heads, n_pl, n_sustained, rate_sustained, k_sources, 2,
            f"r{r}t", None, seed=4 + r,
        )
        for r in range(n_repeats)
    ]
    slo = arrival_schedule(
        csr, heads, n_pl, n_slo, rate_slo, k_sources, 2, "slo",
        deadline_ms, seed=4 + n_repeats,
    )

    async_loop = make_warm_loop(
        True, csr, mesh, heads, n_pl, k_sources, warm_morsels,
        max_batch_sources,
    )
    sync_loop = make_warm_loop(
        False, csr, mesh, heads, n_pl, k_sources, warm_morsels,
        max_batch_sources,
    )

    # interleave the modes repeat-by-repeat so ambient machine noise
    # lands on both sides of every pair, then take the median across
    # repeats: one backlogged repeat's p99 is a single noisy sample
    # (its last pool's completion time)
    p99_runs = {True: [], False: []}
    lat_all = {True: [], False: []}
    walls = {True: 0.0, False: 0.0}
    colds = {True: 0, False: 0}
    for r, sched in enumerate(repeats):
        for overlap, loop, drive in (
            (True, async_loop, lambda lp, s: lp.run_stream(s)),
            (False, sync_loop,
             lambda lp, s: run_flush_rounds(lp, s, flush_group)),
        ):
            # client-observed latency: scheduled arrival -> delivery,
            # clocked by the driver — the flush baseline's gated wait
            # before admission must count, and the server's submit-based
            # stats cannot see it
            done_at = {}
            loop.on_result = lambda qid, _lv, _d=done_at: _d.__setitem__(
                qid, time.perf_counter()
            )
            cold0 = loop.stats.cold_batches
            t0 = time.perf_counter()
            drive(loop, sched)
            walls[overlap] += time.perf_counter() - t0
            loop.on_result = None
            colds[overlap] += loop.stats.cold_batches - cold0
            lats = np.array([
                (done_at[a["qid"]] - t0) * 1e3 - a["t_ms"] for a in sched
            ])
            lat_all[overlap].append(lats)
            p99_runs[overlap].append(float(np.percentile(lats, 99)))
        print(
            f"repeat {r}: client p99 async {p99_runs[True][-1]:.1f} ms "
            f"vs sync-flush {p99_runs[False][-1]:.1f} ms"
        )
    assert colds[True] == 0 and colds[False] == 0, (
        "sustained repeats hit an unwarmed engine shape", colds
    )
    async_loop.run_stream(slo)
    run_flush_rounds(sync_loop, slo, flush_group)
    async_wall, sync_wall = walls[True], walls[False]

    def mode_doc(loop, wall, runs, lats):
        st = loop.stats
        completed, shed, misses = tenant_counts(loop, "r")
        pooled = np.concatenate(lats)
        return {
            "p50_ms": float(np.percentile(pooled, 50)),
            "p99_ms": float(np.median(runs)),
            "p99_ms_runs": [float(x) for x in runs],
            "all_p50_ms": float(np.percentile(pooled, 50)),
            "all_p99_ms": float(np.percentile(pooled, 99)),
            "batches": int(st.batches),
            "cold_batches": int(st.cold_batches),
            "cold_ms": float(st.cold_ms),
            "overlap_occupancy": float(st.overlap_occupancy),
            "overlapped_finalizes": int(st.overlapped_finalizes),
            "finalizes": int(st.finalizes),
            "completed": int(completed),
            "shed": int(shed),
            "deadline_misses": int(misses),
            "sustained_wall_s": float(wall),
            "gangs": int(loop.dispatcher.stats.gangs),
            "hybrid_runs": int(loop.dispatcher.stats.hybrid_runs),
        }

    # bit-identity across modes: the wall-clock admission slicing may
    # batch the stream differently, the answers must not move
    shared = set(async_loop.results) & set(sync_loop.results)
    assert set(async_loop.results) == set(sync_loop.results), (
        sorted(set(async_loop.results) ^ set(sync_loop.results))
    )
    bit_identical = all(
        np.array_equal(async_loop.results[q], sync_loop.results[q])
        for q in shared
    )
    assert bit_identical, "async-vs-sync result divergence"

    a_doc = mode_doc(async_loop, async_wall, p99_runs[True], lat_all[True])
    s_doc = mode_doc(sync_loop, sync_wall, p99_runs[False], lat_all[False])
    _, a_slo_shed, a_slo_miss = tenant_counts(async_loop, "slo")
    _, s_slo_shed, s_slo_miss = tenant_counts(sync_loop, "slo")
    zero_misses = (
        a_slo_miss == 0 and a_slo_shed == 0 and s_slo_miss == 0
    )
    p99_async, p99_sync = a_doc["p99_ms"], s_doc["p99_ms"]

    print(
        f"sustained client p50/median-p99: async {a_doc['p50_ms']:.1f}/"
        f"{p99_async:.1f} ms (occupancy {a_doc['overlap_occupancy']:.2f}, "
        f"{a_doc['batches']} batches, wall {async_wall:.2f} s) vs "
        f"sync-flush {s_doc['p50_ms']:.1f}/{p99_sync:.1f} ms "
        f"(wall {sync_wall:.2f} s)"
    )
    print(
        f"low-load SLO phase: async {a_slo_miss} misses / {a_slo_shed} "
        f"shed, sync {s_slo_miss} misses; results bit-identical: "
        f"{bit_identical}"
    )

    doc = {
        "schema": SCHEMA,
        "smoke": bool(args.smoke),
        "workload": {
            "n_nodes": int(csr.n_nodes),
            "n_edges": int(csr.n_edges),
            "avg_degree": float(csr.avg_degree),
            "n_path_heads": int(n_paths),
            "path_depth": int(path_len - 1),
        },
        "stream": {
            "n_sustained": n_sustained,
            "n_repeats": n_repeats,
            "max_batch_sources": max_batch_sources,
            "flush_group_queries": flush_group,
            "rate_sustained_qps": rate_sustained,
            "n_slo": n_slo,
            "rate_slo_qps": rate_slo,
            "sources_per_query": k_sources,
            "deadline_ms": deadline_ms,
            "tenants": 2,
        },
        "async": a_doc,
        "sync": s_doc,
        "slo": {
            "deadline_ms": deadline_ms,
            "async_deadline_misses": int(a_slo_miss),
            "async_shed": int(a_slo_shed),
            "sync_deadline_misses": int(s_slo_miss),
            "sync_shed": int(s_slo_shed),
        },
        "summary": {
            "async_p99_ms": p99_async,
            "sync_p99_ms": p99_sync,
            "p99_speedup": (
                float(p99_sync / p99_async) if p99_async > 0 else 1.0
            ),
            "sustained_wall_async_s": float(async_wall),
            "sustained_wall_sync_s": float(sync_wall),
            "passes_p99_floor": bool(p99_async <= p99_sync),
            "passes_occupancy_floor": bool(
                a_doc["overlap_occupancy"] > 0.0
            ),
            "results_bit_identical": bool(bit_identical),
            "zero_misses_at_low_load": bool(zero_misses),
        },
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(
        f"summary: median client p99 {p99_async:.1f} ms async vs "
        f"{p99_sync:.1f} ms sync-flush across {n_repeats} repeats "
        f"(speedup {doc['summary']['p99_speedup']:.2f}x, "
        f"passes_p99_floor={doc['summary']['passes_p99_floor']})"
    )
    print(f"wrote {args.out} (schema v{SCHEMA} validated)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
