"""Paper Fig 14 + Fig 12b: multi-source (MS-BFS) morsels vs nTkS.

Two measurements:
1. REAL wall-clock on this core: the 64-lane engine (msbfs_lengths) vs 64
   independent single-source runs (sp_lengths, vmapped) — the shared-scan
   economy is a genuine single-device effect, so the crossover at lane
   saturation is measurable without threads.
2. Scan-work accounting: union-frontier work vs sum of per-source work
   (the paper's "reduces the amount of scans" claim), plus the simulated
   thread-scaling comparison nTkMS(k=4) vs nTkS(k=32) across 1..256 sources.
"""
from __future__ import annotations

import numpy as np

from .common import emit, frontier_trace, time_fn, union_trace
from .sched_sim import simulate


def main(quick: bool = False):
    import jax

    from repro.core import (
        policy_ntkms,
        policy_ntks,
        run_recursive_query,
    )
    from repro.graph.generators import ldbc_proxy, pick_sources

    csr = ldbc_proxy(scale=0.25 if quick else 0.5)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    crossover = {}
    for ns in (1, 8, 32) if quick else (1, 8, 32, 64, 128, 256):
        sources = pick_sources(csr, ns, seed=19)

        us_ntks = time_fn(
            lambda: run_recursive_query(
                mesh, csr, sources, policy_ntks(), "sp_lengths",
                max_deg=64,
            ),
            reps=1, warmup=1,
        )
        us_ntkms = time_fn(
            lambda: run_recursive_query(
                mesh, csr, sources, policy_ntkms(), "msbfs_lengths",
                max_deg=64,
            ),
            reps=1, warmup=1,
        )

        # scan-work accounting
        per_src = [frontier_trace(csr, int(s))[0] for s in sources]
        sum_work = sum(w for t in per_src for _, w in t)
        packs = [sources[i : i + 64] for i in range(0, ns, 64)]
        union_work = sum(
            w for p in packs for _, w in union_trace(csr, p)
        )
        scan_save = sum_work / max(union_work, 1)

        # simulated 32-thread comparison (paper Fig 14 setup)
        r_ntks = simulate(per_src, 32, "ntks", k=32)
        pack_traces = [union_trace(csr, p) for p in packs]
        r_ntkms = simulate(pack_traces, 32, "ntkms", k=4, lanes=64)
        sim_ratio = r_ntks.makespan / r_ntkms.makespan

        crossover[ns] = (us_ntks / us_ntkms, scan_save, sim_ratio)
        emit(
            f"fig14_{ns}src", us_ntkms,
            f"wallclock_ntks/ntkms={us_ntks/us_ntkms:.2f}x "
            f"scan_reduction={scan_save:.2f}x sim32t_ratio={sim_ratio:.2f}x",
        )
    # paper claim: benefits only once lanes saturate (>=64 sources)
    if 64 in crossover:
        assert crossover[64][1] > crossover[8][1], "scan economy grows"
        assert crossover[64][1] > 1.3, "64-src scan reduction"
    emit("fig14_claim", 0.0,
         "msbfs_beneficial_only_at_lane_saturation="
         + str({k: round(v[1], 2) for k, v in crossover.items()}))


if __name__ == "__main__":
    main()
