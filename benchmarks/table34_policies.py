"""Paper Tables 3/4 + Figs 9-11: policies × source counts × datasets.

For each proxy dataset and workload size (1/8/64 sources), runs the four
policies through the measured-trace scheduling simulator at 1/8/32 threads,
reporting speedup factors and utilization — the paper's robustness matrix.
Additionally runs the REAL query engine once per dataset/workload on this
core to ground the traces (wall-clock, single device).

Expected qualitative results (paper §5.2-5.4):
- 1 source:  1T1S ~1x; nT1S/nTkS parallelize.
- 8 sources: 1T1S caps at ~8x/25% util; nTkS >= nT1S.
- 64 sources: 1T1S recovers; nTkS matches/beats it (tail effect).
"""
from __future__ import annotations

import numpy as np

from .common import emit, frontier_trace, time_fn, union_trace
from .sched_sim import simulate


def run_dataset(name: str, csr, n_sources_list=(1, 8, 64), engine=True):
    from repro.core import policy_ntks, run_recursive_query
    from repro.graph.generators import pick_sources
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    results = {}
    for ns in n_sources_list:
        sources = pick_sources(csr, ns, seed=11)
        traces = [frontier_trace(csr, int(s))[0] for s in sources]
        row = {}
        t1 = {p: simulate(traces, 1, p, k=32) for p in
              ("1t1s", "nt1s", "ntks")}
        for threads in (8, 32):
            for pol in ("1t1s", "nt1s", "ntks"):
                r = simulate(traces, threads, pol, k=32)
                row[f"{pol}@{threads}"] = (
                    t1[pol].speedup_vs(r) if False else
                    t1[pol].makespan / r.makespan,
                    r.busy_fraction,
                )
        results[ns] = row
        if engine:
            # max_deg=64 ELL cap = the production dry-run layout (heavy-tail
            # rows would otherwise make the CPU wall-clock grounding run
            # O(n x max_degree))
            us = time_fn(
                lambda: run_recursive_query(
                    mesh, csr, sources, policy_ntks(), "sp_lengths",
                    max_deg=64,
                ),
                reps=1, warmup=1,
            )
            row["engine_us"] = us
        d = " ".join(
            f"{p}@{t}={row[f'{p}@{t}'][0]:.1f}x/"
            f"{row[f'{p}@{t}'][1]*100:.0f}%"
            for t in (8, 32) for p in ("1t1s", "nt1s", "ntks")
        )
        emit(f"table34_{name}_{ns}src", row.get("engine_us", 0.0), d)
    return results


def check_claims(results):
    """The paper's three headline behaviors, asserted qualitatively."""
    r1, r8, r64 = results[1], results[8], results[64]
    assert r1["1t1s@32"][0] < 1.5, "1T1S must not scale on 1 source"
    assert r1["ntks@32"][0] > 2.0, "nTkS must parallelize a single source"
    assert r8["1t1s@32"][0] <= 8.5, "1T1S caps at #sources"
    assert r8["ntks@32"][0] >= r8["1t1s@32"][0] - 0.51, "nTkS >= 1T1S @8src"
    assert r8["ntks@32"][0] >= r8["nt1s@32"][0] - 0.51, "nTkS >= nT1S @8src"
    assert r64["ntks@32"][0] >= r64["nt1s@32"][0] - 0.51, "nTkS >= nT1S @64"


def main(quick: bool = False):
    from repro.graph.generators import PAPER_DATASETS

    scale = 0.35 if quick else 0.6
    all_ok = []
    for name, gen in PAPER_DATASETS.items():
        csr = gen(scale)
        res = run_dataset(name, csr, engine=not quick)
        check_claims(res)
        all_ok.append(name)
    emit("table34_claims", 0.0,
         f"robustness_claims_hold_on={'/'.join(all_ok)}")


if __name__ == "__main__":
    main()
