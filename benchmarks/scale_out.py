"""Streamed vs wholesale operand build at scale-out size (ISSUE 10).

The scale-out claim: ``prepare_graph(stream=True)`` builds the operand
set one policy shard at a time (``operand_stream`` plans once, then
``build_shard(k)`` -> per-device placement -> global assembly via
``jax.make_array_from_single_device_arrays``), so host peak memory is
~one shard's operand bytes plus the resident CSR — instead of the whole
padded structure the wholesale path materializes before placing. On a
billion-edge graph the wholesale host peak is the thing that OOMs first;
this benchmark measures the two builds on a degree-matched proxy >=10x
the largest graph any other benchmark in this repo touches.

Measured here, each build mode in a **fresh subprocess** (``ru_maxrss``
is monotone per process, so wholesale-then-streamed in one process would
hide the streamed savings; ``multiprocessing`` spawn keeps the two
measurements independent), on 8 virtual CPU devices (2x4 mesh, nTkS
policy -> 4 graph shards), building the widest operand set
(``pull_binned_fused``: forward ELL + binned reverse slabs + kernel
pack):

- **wholesale**: ``prepare_graph(stream=False)`` — the seed path;
- **streamed**: ``prepare_graph(stream=True)`` — the scale-out path;
- per mode: build wall, ``tracemalloc`` peak (numpy allocations are
  traced, and the host-side operand build is pure numpy — this is the
  robust peak-host-memory signal at proxy scale), ``ru_maxrss``, and
  per-device live operand bytes (leaf shard ``nbytes``);
- **bitwise parity**: per-leaf sha256 digests of the device-assembled
  operands, compared across the two modes — the streamed build must be
  bit-identical, not just close;
- **chunked-hub oracle**: on a hub graph whose widest binned slab blows
  any reasonable gather budget, the degree-chunked slab gathers
  (``_slab_gather_lanes`` / ``_slab_min_parent_lanes``) under an
  artificially tiny ``_deg_chunk`` budget must match the unchunked
  gather bit-for-bit.

Floors (asserted in-process and by ``scripts/ci.sh --bench-smoke``):
streamed tracemalloc peak strictly below wholesale, digests identical,
chunked oracle exact; the full run additionally requires the >=10x
workload size and the streamed ``ru_maxrss`` no worse than wholesale.

Writes machine-readable ``BENCH_scale_out.json`` (schema validated
in-process and re-validated by the CI lane).

    PYTHONPATH=src python benchmarks/scale_out.py [--smoke] \
        [--out BENCH_scale_out.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

SCHEMA = 1

# largest graph any other benchmark builds (direction_opt's powerlaw_d6)
LARGEST_OTHER_BENCH_NODES = 4096

REQUIRED = {
    "schema": int,
    "smoke": bool,
    "workload": dict,
    "modes": dict,
    "parity": dict,
    "chunked_oracle": dict,
    "summary": dict,
}
MODE_FIELDS = (
    "build_wall_ms", "tracemalloc_peak_bytes", "ru_maxrss_kb",
    "device_bytes", "max_device_bytes", "total_device_bytes", "n_pad",
    "n_leaves",
)


def validate(doc: dict) -> None:
    """Schema + acceptance guards for BENCH_scale_out.json: the streamed
    build's traced host peak strictly below wholesale, every operand leaf
    bit-identical across the two builds, the chunked hub gather exact
    against the unchunked oracle; full runs must also hit the >=10x
    workload floor and keep streamed ``ru_maxrss`` no worse than
    wholesale."""
    for key, ty in REQUIRED.items():
        assert key in doc, f"missing top-level field: {key}"
        assert isinstance(doc[key], ty), (key, type(doc[key]))
    assert doc["schema"] == SCHEMA, doc["schema"]
    for mode in ("wholesale", "streamed"):
        assert mode in doc["modes"], f"missing mode: {mode}"
        for f in MODE_FIELDS:
            assert f in doc["modes"][mode], (mode, f)
    w, s = doc["modes"]["wholesale"], doc["modes"]["streamed"]
    assert w["n_pad"] == s["n_pad"], (w["n_pad"], s["n_pad"])
    assert w["n_leaves"] == s["n_leaves"], (w["n_leaves"], s["n_leaves"])
    assert doc["parity"]["digests_match"] is True, (
        "streamed operands must be bitwise-identical to wholesale",
        doc["parity"],
    )
    assert doc["parity"]["n_leaves"] >= 5, doc["parity"]
    assert doc["chunked_oracle"]["reach_match"] is True, doc["chunked_oracle"]
    assert doc["chunked_oracle"]["parent_match"] is True, (
        doc["chunked_oracle"]
    )
    assert doc["chunked_oracle"]["hub_width"] > doc["chunked_oracle"][
        "forced_chunk"
    ], ("oracle must actually exercise chunking", doc["chunked_oracle"])
    su = doc["summary"]
    for f in ("wholesale_peak_bytes", "streamed_peak_bytes",
              "peak_reduction", "passes_memory_floor"):
        assert f in su, f"missing summary field: {f}"
    assert su["passes_memory_floor"] is True, su
    assert su["streamed_peak_bytes"] < su["wholesale_peak_bytes"], (
        "streamed host peak must be strictly below wholesale: "
        f"{su['streamed_peak_bytes']} vs {su['wholesale_peak_bytes']}"
    )
    if not doc["smoke"]:
        assert doc["workload"]["n_nodes"] >= 10 * LARGEST_OTHER_BENCH_NODES, (
            "full run must be >=10x the largest other bench graph",
            doc["workload"],
        )
        assert s["ru_maxrss_kb"] <= w["ru_maxrss_kb"], (
            "streamed process RSS regressed past wholesale", s, w
        )


def smoke_line(doc: dict) -> str:
    """One-line artifact summary for the CI bench-smoke lane."""
    su = doc["summary"]
    wl = doc["workload"]
    return (
        f"{wl['n_nodes']} nodes / {wl['n_edges']} edges ({wl['extend']}): "
        f"streamed host peak {su['streamed_peak_bytes'] / 2**20:.1f} MiB "
        f"vs wholesale {su['wholesale_peak_bytes'] / 2**20:.1f} MiB "
        f"({su['peak_reduction']:.2f}x lower), operands bit-identical "
        f"{doc['parity']['digests_match']}, chunked hub oracle exact "
        f"{doc['chunked_oracle']['reach_match']}"
    )


def _measure_build(mode: str, cfg: dict, out_path: str) -> None:
    """Subprocess worker: one build mode, fresh process, fresh rusage.

    Sets the virtual-device count *before* jax imports, regenerates the
    workload graph from (n, degree, seed), runs ``prepare_graph`` with
    the mode's ``stream`` flag, and writes wall/peak/RSS/per-device
    bytes plus per-leaf sha256 digests as JSON."""
    import os

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={cfg['devices']}"
    )
    import hashlib
    import resource
    import time
    import tracemalloc

    import numpy as np

    import jax

    from repro.core.dispatcher import prepare_graph
    from repro.core.policies import policy_ntks
    from repro.graph.generators import powerlaw
    from repro.launch.mesh import make_mesh

    csr = powerlaw(cfg["n_nodes"], cfg["avg_degree"], seed=cfg["seed"])
    mesh = make_mesh(
        (cfg["devices"] // cfg["model_axis"], cfg["model_axis"]),
        ("data", "model"),
    )
    policy = policy_ntks()

    # the CSR is resident in both modes; trace only the build itself
    tracemalloc.start()
    t0 = time.perf_counter()
    ops, n_pad = prepare_graph(
        csr, mesh, policy, pad_shards=mesh.size, extend=cfg["extend"],
        stream=(mode == "streamed"),
    )
    jax.block_until_ready(jax.tree_util.tree_leaves(ops))
    wall_ms = (time.perf_counter() - t0) * 1e3
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

    # digests + device accounting AFTER the measurement window (the
    # device_get copies below must not pollute the traced peak)
    device_bytes: dict = {}
    digests = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(ops)[0]:
        name = jax.tree_util.keystr(kp)
        for sh in leaf.addressable_shards:
            did = str(sh.device.id)
            device_bytes[did] = device_bytes.get(did, 0) + int(
                sh.data.nbytes
            )
        arr = np.asarray(jax.device_get(leaf))
        h = hashlib.sha256()
        h.update(str((name, arr.shape, str(arr.dtype))).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
        digests[name] = h.hexdigest()

    Path(out_path).write_text(json.dumps({
        "mode": mode,
        "build_wall_ms": float(wall_ms),
        "tracemalloc_peak_bytes": int(peak),
        "ru_maxrss_kb": int(rss_kb),
        "device_bytes": device_bytes,
        "max_device_bytes": max(device_bytes.values()),
        "total_device_bytes": sum(device_bytes.values()),
        "n_pad": int(n_pad),
        "n_leaves": len(digests),
        "digests": digests,
        "n_edges": int(csr.n_edges),
    }))


def chunked_hub_oracle(forced_budget: int = 4096) -> dict:
    """Bitwise parity of the degree-chunked binned slab gathers against
    the unchunked gather on a hub graph (one node whose in-degree dwarfs
    the rest, i.e. the widest slab far exceeds the forced chunk)."""
    import numpy as np

    import jax.numpy as jnp

    import repro.core.extend as E
    from repro.graph.csr import csr_from_edges

    rng = np.random.default_rng(7)
    n, hub_deg = 2048, 1200
    src = np.concatenate([
        rng.integers(0, n, 3 * n), np.arange(hub_deg) % (n - 1) + 1,
    ])
    dst = np.concatenate([rng.integers(0, n, 3 * n), np.zeros(hub_deg, np.int64)])
    csr = csr_from_edges(n, src, dst)
    ops, n_pad = E.build_operands(csr, extend="pull_binned")
    bn = ops.rev_binned
    widths = tuple(int(s.shape[-1]) for s in bn.slabs)
    L = 8
    gl = jnp.asarray(
        (rng.random((n_pad, L)) < 0.3).astype(np.uint8)
    )

    def run():
        reach = E._binned_map(
            bn, lambda b, s: E._slab_gather_lanes(s, gl),
            lambda r: jnp.zeros((r, L), gl.dtype),
        )
        par = E._binned_map(
            bn, lambda b, s: E._slab_min_parent_lanes(s, gl),
            lambda r: jnp.full((r, L), E.NO_PARENT, jnp.int32),
        )
        return np.asarray(reach), np.asarray(par)

    ref_reach, ref_par = run()
    orig = E._deg_chunk
    try:
        E._deg_chunk = lambda rows, per_slot, budget=0: orig(
            rows, per_slot, forced_budget
        )
        forced_chunk = E._deg_chunk(
            int(bn.slabs[-1].shape[-2]), L
        )
        got_reach, got_par = run()
    finally:
        E._deg_chunk = orig
    return {
        "hub_width": int(max(widths)),
        "forced_chunk": int(forced_chunk),
        "reach_match": bool((got_reach == ref_reach).all()),
        "parent_match": bool((got_par == ref_par).all()),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph (CI bench-smoke lane)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parent.parent / "BENCH_scale_out.json"
    ))
    args = ap.parse_args(argv)

    import multiprocessing as mp

    if args.smoke:
        n_nodes, avg_degree = 8192, 6.0
    else:
        # >=10x the largest graph any other benchmark builds (4096 nodes)
        n_nodes, avg_degree = 65536, 8.0
    cfg = {
        "n_nodes": n_nodes,
        "avg_degree": avg_degree,
        "seed": 17,
        "devices": 8,
        "model_axis": 4,  # nTkS graph axis -> 4 policy shards
        "extend": "pull_binned_fused",  # widest operand set (fwd+binned+pack)
    }
    print(
        f"scale-out workload: {n_nodes} nodes x avg degree ~{avg_degree} "
        f"(symmetric), extend={cfg['extend']}, 2x4 mesh, one subprocess "
        f"per build mode"
    )

    ctx = mp.get_context("spawn")
    results = {}
    with tempfile.TemporaryDirectory() as td:
        for mode in ("wholesale", "streamed"):
            out = str(Path(td) / f"{mode}.json")
            p = ctx.Process(target=_measure_build, args=(mode, cfg, out))
            p.start()
            p.join()
            assert p.exitcode == 0, f"{mode} build subprocess failed"
            results[mode] = json.loads(Path(out).read_text())
            r = results[mode]
            print(
                f"{mode}: build {r['build_wall_ms']:.0f} ms, traced peak "
                f"{r['tracemalloc_peak_bytes'] / 2**20:.1f} MiB, maxrss "
                f"{r['ru_maxrss_kb'] / 2**10:.1f} MiB, device bytes "
                f"{r['total_device_bytes'] / 2**20:.1f} MiB total / "
                f"{r['max_device_bytes'] / 2**20:.2f} MiB max"
            )

    w, s = results["wholesale"], results["streamed"]
    digests_match = w.pop("digests") == s.pop("digests")
    print(f"parity: {w['n_leaves']} leaves, digests_match={digests_match}")

    oracle = chunked_hub_oracle()
    print(
        f"chunked hub oracle: widest slab {oracle['hub_width']} cols, "
        f"forced chunk {oracle['forced_chunk']}, reach_match="
        f"{oracle['reach_match']}, parent_match={oracle['parent_match']}"
    )

    wp, sp = w["tracemalloc_peak_bytes"], s["tracemalloc_peak_bytes"]
    doc = {
        "schema": SCHEMA,
        "smoke": bool(args.smoke),
        "workload": {
            "n_nodes": int(n_nodes),
            "n_edges": int(w["n_edges"]),
            "avg_degree": float(avg_degree),
            "extend": cfg["extend"],
            "devices": cfg["devices"],
            "graph_shards": cfg["model_axis"],
            "largest_other_bench_nodes": LARGEST_OTHER_BENCH_NODES,
        },
        "modes": results,
        "parity": {
            "digests_match": bool(digests_match),
            "n_leaves": int(w["n_leaves"]),
        },
        "chunked_oracle": oracle,
        "summary": {
            "wholesale_peak_bytes": int(wp),
            "streamed_peak_bytes": int(sp),
            "peak_reduction": float(wp / sp) if sp else 1.0,
            "wholesale_maxrss_kb": int(w["ru_maxrss_kb"]),
            "streamed_maxrss_kb": int(s["ru_maxrss_kb"]),
            "passes_memory_floor": bool(sp < wp),
        },
    }
    validate(doc)
    Path(args.out).write_text(json.dumps(doc, indent=1, sort_keys=True))
    print(
        f"summary: streamed peak {sp / 2**20:.1f} MiB vs wholesale "
        f"{wp / 2**20:.1f} MiB ({doc['summary']['peak_reduction']:.2f}x "
        f"lower)"
    )
    print(f"wrote {args.out} (schema v{SCHEMA} validated)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
