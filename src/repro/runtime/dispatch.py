"""Dispatch layer of the serving core: engine cache + two-phase hybrid.

This is the middle layer of the three-layer runtime (see docs/serving.md):

    admission  (runtime/admission.py) — who runs, when, in which morsel pack
    dispatch   (this module)          — how one admitted batch executes
    service    (runtime/service.py)   — the always-on loop overlapping batches

``QueryDispatcher`` owns everything about *executing* one batch of source
nodes: the compiled-engine cache, the paper's two-phase hybrid (nTkS phase 1
under a learned budget, gang-scheduled phase-2 re-dispatch of survivors),
backend recommendation, and the online policy learners (per-bucket budget
model + in-flight direction-threshold refits). Semantics are unchanged from
the pre-split ``AdaptiveScheduler`` — that class survives in
``runtime/scheduler.py`` as a thin synchronous façade over this layer plus
the admission queue, so every existing caller sees the same surface.

What is new here is the **split-phase batch API** the serving loop pipelines
on:

- ``begin_batch``  — choose policy/backend/budget and *dispatch* phase 1
  asynchronously (no ``block_until_ready``): jax async dispatch returns
  immediately with device futures, so the host is free while the device
  scans.
- ``settle_batch`` — block on the phase-1 frontier, re-dispatch survivors
  (phase 2, also async), block only on the tiny per-morsel iteration
  counters, run post-batch learning, and return a ``SettledBatch`` whose
  full result state is still on device.
- ``finalize_batch`` — the deferred host work: materialize the final state,
  stitch phase-2 survivors back over the phase-1 state, and hand back the
  completed ``QueryOutcome``. The serving loop runs this *after* dispatching
  the next batch's phase 1, so host-side stitching overlaps device compute
  (the double-buffered invocation: at most one settled-but-unfinalized batch
  rides behind the in-flight one, and the phase-1 buffers it consumed are
  dropped — donated — as soon as the stitch completes).

``query()`` composes the three steps back-to-back, which is bit-identical
to the pre-split synchronous path: the split only moves *when* the host
blocks, never what any morsel computes. Learning stays host-serial —
``settle_batch(i)`` always precedes ``begin_batch(i+1)`` — so budgets,
thresholds, traces, and counters are a deterministic function of the batch
stream regardless of overlap (the seeded-replay lock in
tests/test_serving.py).

Supported jax range: 0.4.35 — 0.8.x (see repro.compat / repro.launch.mesh).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import (
    BackendCostProbe,
    BudgetModel,
    DirectionThresholds,
    POLICIES,
    ExtendSpec,
    IFEResult,
    MorselPolicy,
    QUERY_KINDS,
    as_spec,
    build_engine,
    build_gang_resume_engine,
    build_resume_engine,
    count_budget_mispredicts,
    degree_bucket,
    fit_direction_thresholds,
    gang_handoff,
    gang_scatter_back,
    hybrid_phases,
    pad_sources,
    pow2ceil as _pow2ceil,
    prepare_graph,
    recommend_backend,
    recommend_k,
    recommend_policy,
)
from ..core.dispatcher import _axes_size
from ..core.extend import GraphOperands, effective_csr
from ..graph.csr import CSRGraph
from ..graph.delta import (
    DeltaReport,
    GraphDelta,
    apply_delta_csr,
    diff_effective,
    fold_operands,
)


@dataclasses.dataclass(frozen=True)
class EngineKey:
    """Cache identity of one compiled engine. ``kind`` distinguishes the
    static single-phase program, the per-shard-sync phase-1 program, and
    the state-resuming phase-2 program — same policy tuple, different HLO.
    ``extend`` carries the extension backend + direction mode (an
    ``ExtendSpec``): each backend is a different scan program. ``stats``
    marks the sample-tapped flavor (``build_engine(collect_stats=True)``
    returns ``(result, per-iteration stats)`` — same result state,
    different HLO).

    ``operands_epoch`` is the mutable-graph shape generation of the
    operand structures this engine scans: a ``GraphDelta`` that folds
    in place (same shapes, buffers swapped) leaves the epoch alone — the
    compiled engine stays warm and simply receives the new buffers at
    call time — while a delta that forces a structure rebuild with new
    shapes bumps it, so stale keys are invalidated and the next query
    compiles against the new shapes. Deliberately NOT the full
    ``operands_version``: keying on the version would cold-compile on
    every delta, which is the exact cliff this design removes."""

    kind: str  # "static" | "phase1" | "resume"
    policy: MorselPolicy
    edge_compute: str
    n_nodes_padded: int
    max_iters: int
    state_layout: str
    extend: ExtendSpec = ExtendSpec()
    stats: bool = False
    operands_epoch: int = 0


class EngineCache:
    """Compiled-QueryEngine cache: bounded LRU with hit/miss accounting
    and a public mapping surface. Hits and misses are additionally
    counted per engine kind (static/phase1/resume/gang) so the gang
    path's compile footprint is observable.

    ``max_entries`` bounds the store (None = unbounded): a shape-diverse
    serving stream — many (policy, backend, morsel-shape) combinations —
    previously grew both the engine dict and the ``note_shape`` ledger
    without bound. Least-recently-*used* entries evict first
    (``get_or_build`` hits refresh recency), the evicted key's shape
    ledger goes with it, and a later rebuild of an evicted key is a
    fresh ``miss`` + fresh shape misses — exactly what it costs the
    serving loop, so ``compile_events`` stays an honest cold counter.

    Iteration/lookup is part of the API — callers that count or inspect
    compiles use ``len(cache)``, ``iter(cache)`` / ``keys()``, ``key in
    cache``, ``get(key)`` and ``items()`` instead of reaching into the
    private store."""

    # Default bound: far above any one graph's engine population (a full
    # backend × policy × kind × budget sweep compiles a few dozen), so
    # eviction only engages on genuinely unbounded key streams.
    DEFAULT_MAX_ENTRIES = 128

    def __init__(self, max_entries: int | None = DEFAULT_MAX_ENTRIES):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1: {max_entries}")
        self.max_entries = max_entries
        self._engines: collections.OrderedDict[EngineKey, Any] = (
            collections.OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self.hits_by_kind: collections.Counter = collections.Counter()
        self.misses_by_kind: collections.Counter = collections.Counter()
        # morsel-count shapes each engine has been called with: a cached
        # engine hit can still pay a full XLA retrace when the batch's
        # morsel count is new — invisible to hit/miss, so tracked apart
        self._shapes: dict[EngineKey, set] = {}
        self.shape_misses = 0
        self.evictions = 0  # LRU capacity evictions
        self.invalidations = 0  # entries dropped by invalidate()

    @property
    def compile_events(self) -> int:
        """Engine builds plus first-time input shapes: everything that
        stalls a batch on XLA. Serving's warm/cold split keys off the
        delta of this, not ``misses`` — a hit engine retracing on a new
        morsel count is just as cold as a fresh build."""
        return self.misses + self.shape_misses

    def note_shape(self, key: EngineKey, shape) -> bool:
        """Record that ``key``'s engine is about to run with input
        ``shape`` (any hashable; callers pass the morsel-axis tuple).
        Returns True — and counts a ``shape_miss`` — the first time this
        (engine, shape) pair is seen."""
        seen = self._shapes.setdefault(key, set())
        if shape in seen:
            return False
        seen.add(shape)
        self.shape_misses += 1
        return True

    def __len__(self) -> int:
        return len(self._engines)

    def __iter__(self):
        return iter(self._engines)

    def __contains__(self, key: EngineKey) -> bool:
        return key in self._engines

    def keys(self):
        """The cached ``EngineKey``s, in compile order."""
        return self._engines.keys()

    def items(self):
        """(EngineKey, engine) pairs, in compile order."""
        return self._engines.items()

    def get(self, key: EngineKey, default=None):
        """Cached engine for ``key`` (no hit/miss accounting, no build)."""
        return self._engines.get(key, default)

    def count_by_kind(self, kind: str) -> int:
        """How many compiled engines of one ``EngineKey.kind`` are cached."""
        return sum(1 for k in self._engines if k.kind == kind)

    def get_or_build(self, key: EngineKey, builder: Callable[[], Any]):
        kind = getattr(key, "kind", "?")
        eng = self._engines.get(key)
        if eng is not None:
            self.hits += 1
            self.hits_by_kind[kind] += 1
            self._engines.move_to_end(key)  # LRU recency refresh
            return eng
        self.misses += 1
        self.misses_by_kind[kind] += 1
        eng = builder()
        self._engines[key] = eng
        if (
            self.max_entries is not None
            and len(self._engines) > self.max_entries
        ):
            old_key, _ = self._engines.popitem(last=False)
            self._shapes.pop(old_key, None)
            self.evictions += 1
        return eng

    def invalidate(self, predicate: Callable[[EngineKey], bool]) -> int:
        """Drop every cached engine whose key matches ``predicate`` (and
        its shape ledger). Returns the number of entries removed. The
        dispatcher calls this after a shape-changing ``GraphDelta`` with
        an epoch-mismatch predicate, so exactly the engines compiled
        against rebuilt structures recompile — a re-query of an
        invalidated key accounts as a fresh miss + fresh shape misses,
        like any other cold compile."""
        stale = [k for k in self._engines if predicate(k)]
        for k in stale:
            del self._engines[k]
            self._shapes.pop(k, None)
        self.invalidations += len(stale)
        return len(stale)


@dataclasses.dataclass
class QueryOutcome:
    """One served batch: result + how the runtime chose to execute it.

    ``redispatched`` counts the morsels *handed* to phase 2 (the phase-1
    survivors); ``resumed_ganged``/``resumed_serial`` split it by how they
    actually ran (one batched gang dispatch vs the per-morsel engine), so
    ``redispatched == resumed_ganged + resumed_serial`` always holds.
    ``gang_width`` is the pow2-padded width of the gang dispatch (0 when no
    gang ran; the max across chunks for chunked batches).

    The ``budget_*`` counters classify this batch's REAL morsels against
    the phase-1 budget (``core.policies.count_budget_mispredicts``
    semantics: too_low = survivors that paid a re-dispatch, too_high =
    morsels that converged strictly under half the budget, inert_slots =
    budget slack over converged morsels); zero on static runs."""

    result: IFEResult
    policy: str  # base policy name ("ntks", "ntkms", ...)
    hybrid: bool  # did the two-phase hybrid path run?
    redispatched: int  # morsels handed to phase 2
    phase_ms: dict  # {"phase1": ms, "phase2": ms}; static runs use phase1
    phase1_budget: int  # iteration cap phase 1 ran under (0 = static)
    resumed_ganged: int = 0  # survivors resumed in a gang dispatch
    resumed_serial: int = 0  # survivors resumed one-morsel-at-a-time
    gang_width: int = 0  # padded gang width (0 = no gang dispatch)
    budget_too_low: int = 0  # real morsels the budget undershot
    budget_too_high: int = 0  # real morsels a smaller pow2 budget covered
    budget_inert_slots: int = 0  # budget slack over converged real morsels
    budget_observed: int = 0  # real morsels the counters classified


@dataclasses.dataclass
class SchedulerStats:
    """Cumulative runtime counters across every served batch.

    The ``redispatched = resumed_ganged + resumed_serial`` split mirrors
    QueryOutcome; ``gangs``/``gang_slots`` make gang occupancy observable
    (survivors actually ganged over padded slots dispatched)."""

    queries: int = 0
    hybrid_runs: int = 0  # batches that took the two-phase path
    redispatched: int = 0  # survivors handed to phase 2
    resumed_ganged: int = 0
    resumed_serial: int = 0
    gangs: int = 0  # gang dispatches issued
    gang_slots: int = 0  # padded gang widths summed over dispatches
    phase1_ms: float = 0.0
    phase2_ms: float = 0.0
    budget_too_low: int = 0  # phase-1 budget mispredicts (QueryOutcome)
    budget_too_high: int = 0
    budget_inert_slots: int = 0
    budget_observed: int = 0
    refits: int = 0  # in-flight direction-threshold refits
    deltas: int = 0  # GraphDeltas applied (apply_delta calls)

    @property
    def gang_occupancy(self) -> float:
        """Real survivors per padded gang slot (1.0 = pow2-tight gangs)."""
        return self.resumed_ganged / self.gang_slots if self.gang_slots else 0.0

    @property
    def budget_mispredict_rate(self) -> float:
        """Mispredicted real morsels per observed real morsel (too_low +
        too_high over observed; 0.0 before any hybrid batch)."""
        if not self.budget_observed:
            return 0.0
        return (self.budget_too_low + self.budget_too_high) / (
            self.budget_observed
        )

    def record(self, outcome: "QueryOutcome") -> None:
        self.queries += 1
        if outcome.hybrid:
            self.hybrid_runs += 1
        self.redispatched += outcome.redispatched
        self.resumed_ganged += outcome.resumed_ganged
        self.resumed_serial += outcome.resumed_serial
        self.phase1_ms += outcome.phase_ms.get("phase1", 0.0)
        self.phase2_ms += outcome.phase_ms.get("phase2", 0.0)
        self.budget_too_low += outcome.budget_too_low
        self.budget_too_high += outcome.budget_too_high
        self.budget_inert_slots += outcome.budget_inert_slots
        self.budget_observed += outcome.budget_observed


@dataclasses.dataclass
class OperandBundle:
    """One device-placed operand bundle plus its mutability bookkeeping.

    ``version`` is the ``operands_version`` the buffers currently hold;
    ``epochs`` counts, per structure slot, how many times a delta had to
    REBUILD that structure with new shapes (in-place folds don't bump
    it) — ``EngineKey.operands_epoch`` derives from these. ``host`` is
    the lazily created writable numpy mirror deltas fold into (one
    device→host copy on the first delta, then reused forever).

    ``policy``/``spec`` record which (policy, ExtendSpec) pair first
    materialized the bundle — provenance for tooling that needs to
    rebuild the same operand set from scratch (benchmarks/mutable_ops.py
    prices the rebuild baseline off it).

    Iterates as ``(ops, n_pad)`` so the historical
    ``g, n_pad = self._graph_for(...)`` unpacking keeps working."""

    ops: GraphOperands
    n_pad: int
    version: int = 0
    epochs: dict = dataclasses.field(default_factory=dict)
    host: Any = None
    policy: Any = None
    spec: Any = None

    def __iter__(self):
        return iter((self.ops, self.n_pad))


@dataclasses.dataclass
class InflightBatch:
    """A batch whose phase 1 (or static engine) has been *dispatched* but
    not blocked on: the device futures ride in ``payload`` until
    ``settle_batch``. ``kind`` routes the settle path:

    - "hybrid"  — phase-1 futures from the sync="shard" engine
    - "static"  — single-engine futures (non-hybrid-eligible batch)
    - "chunked" — oversized batch that will run the synchronous chunked
      loop at settle time (the in-flight cap splits it; serving streams
      rarely hit this — admission packs under the cap)."""

    kind: str
    name: str  # resolved policy name for QueryOutcome.policy
    n_real: int
    buckets: np.ndarray
    payload: Any


@dataclasses.dataclass
class SettledBatch:
    """A batch past its device sync points: iterations, counters, and
    learning are done; the final result *state* may still live on device.
    ``finalize()`` (idempotent) runs the deferred host stitch and returns
    the completed ``QueryOutcome``."""

    outcome: QueryOutcome
    _materialize: Callable[[], IFEResult] | None = None

    @property
    def finalized(self) -> bool:
        return self._materialize is None

    def finalize(self) -> QueryOutcome:
        if self._materialize is not None:
            self.outcome.result = self._materialize()
            self._materialize = None
        return self.outcome


class QueryDispatcher:
    """Compile-once, serve-many execution layer over one graph.

    ``adaptive=True`` enables two-phase hybrid dispatch for any policy
    with source morsels (nTkS/nTkMS/1T1S) — pinning a policy picks WHICH
    morsels are issued, not the execution mode, and the hybrid is
    bit-identical in result state. Replicated state always qualifies; the
    sharded layout qualifies when ``gang_resume`` is on (its phase 2 is
    the gang engine + reduce-scatter merge — there is no serial sharded
    resume). ``adaptive=False`` degrades everything to the static
    dispatcher (one engine per policy), which is also the fallback for
    nT1S (no source morsels to re-dispatch).

    ``gang_resume=False`` pins phase 2 to the legacy one-morsel-at-a-time
    resume (kept as the differential baseline the parity corpus compares
    the gang against).

    ``online_adapt=True`` (the default) closes the policy feedback loop
    on the live stream:

    - the phase-1 iteration budget comes from a per-(dataset-family,
      source-degree-bucket) ``BudgetModel`` updated with every flushed
      batch's real-morsel convergence depths (the legacy global pow2 p90
      deque remains the empty-model cold path, and ``phase1_iters``
      still pins the budget outright, bypassing the learner);
    - phase-1 AND phase-2 (resume/gang) engines run with the
      ``collect_stats`` sample tap, and the accumulated per-iteration
      (m_frontier, m_unexplored, scan-cost / measured-cost)
      records are refit into ``direction_thresholds`` every
      ``refit_every`` batches (``fit_direction_thresholds`` over
      ``online_trace()``), so ``backend="recommend"`` serves alpha/beta
      tracking the live stream instead of a stale bench trace — unless
      a table was supplied explicitly, which pins it (only a manual
      ``refit_thresholds()`` call overrides a pin).

    Both loops only move iteration slots / scan layouts — results stay
    bit-identical with the learner on, off, or mid-refit — and both are
    deterministic functions of the served batch stream (same seeded
    stream => bit-identical budgets, thresholds, and mispredict
    counters, with or without ``gang_resume`` and with or without the
    serving loop's phase overlap — ``settle_batch(i)`` always precedes
    ``begin_batch(i+1)``, so the learners never see a reordered stream).
    ``online_adapt=False`` pins the legacy static behavior (global-p90
    budget, fixed thresholds) as the differential baseline.
    """

    def __init__(
        self,
        mesh,
        csr: CSRGraph,
        max_deg: int | None = None,
        max_iters: int = 64,
        adaptive: bool = True,
        phase1_iters: int | None = None,
        max_inflight: int | None = None,
        backend="recommend",
        direction_thresholds: DirectionThresholds | str | Path | None = None,
        family: str | None = None,
        gang_resume: bool = True,
        online_adapt: bool = True,
        budget_model: BudgetModel | None = None,
        refit_every: int = 16,
        sample_window: int = 2048,
        pad_pow2_morsels: bool = False,
        cost: str = "auto",
        stream: bool | None = None,
    ):
        self.mesh = mesh
        self.csr = csr
        self.max_deg = max_deg
        self.max_iters = max_iters
        # streamed (shard-at-a-time, multi-host-aware) operand placement;
        # None = prepare_graph's auto rule (stream iff multi-process)
        self.stream = stream
        self.adaptive = adaptive
        self.phase1_iters = phase1_iters  # pin the phase-1 budget (tests)
        self.max_inflight = max_inflight  # override recommend_k (tests)
        # default extension backend; per-query override via query(backend=).
        # The default IS "recommend": recommend_backend picks the scan
        # layout per batch (direction-optimized binned pull for the
        # BFS family), bit-identical to any explicit choice.
        self.backend = backend
        # fitted per-(family, degree-bucket) alpha/beta for the direction
        # switch (core.policies.fit_direction_thresholds); a path loads a
        # BENCH_direction_opt.json trace file. None = Beamer defaults.
        if isinstance(direction_thresholds, (str, Path)):
            direction_thresholds = fit_direction_thresholds(
                direction_thresholds
            )
        self.direction_thresholds = direction_thresholds
        # an explicitly supplied table is a pin: the auto-refit cadence
        # must not silently replace what the caller asked to serve (an
        # explicit refit_thresholds() call still overrides)
        self._thresholds_pinned = direction_thresholds is not None
        self.family = family  # dataset family key for threshold lookup
        self.gang_resume = gang_resume
        self.online_adapt = online_adapt
        # per-(family, source-degree-bucket) phase-1 budget learner; the
        # global deque below remains its empty-model cold path
        self.budget_model = (
            budget_model
            if budget_model is not None
            else (BudgetModel() if online_adapt else None)
        )
        self.refit_every = max(1, int(refit_every))
        # serving knob: round every batch's morsel count up to a pow2 so a
        # stream of arbitrary pool sizes hits O(log max-pool) compiled
        # shapes instead of one XLA retrace per distinct queue depth; pad
        # morsels are inert (0-iteration) and invisible to learning
        # (n_real) and extraction (spans). Off by default: the one-shot
        # query paths keep their historical exact shapes.
        self.pad_pow2_morsels = pad_pow2_morsels
        # threshold-fit cost model: "slots" scores directions by scan-slot
        # counts (deterministic, the only mode that existed before the
        # measured-cost tap); "measured" converts slots to wall-ms via the
        # BackendCostProbe's per-backend ms/slot rates; "auto" = measured
        # on real TPUs, slots on CPU/interpret (where probe timings are
        # noise and replay determinism matters more than calibration)
        if cost == "auto":
            cost = "measured" if jax.default_backend() == "tpu" else "slots"
        if cost not in ("slots", "measured"):
            raise ValueError(f"unknown cost mode: {cost!r}")
        self.cost_mode = cost
        self.cost_probe = BackendCostProbe()
        self._cost_rates: dict[int, dict] = {}  # n_pad -> probe rates
        self.stats = SchedulerStats()
        self.cache = EngineCache()
        self._graphs: dict[tuple, OperandBundle] = {}
        # monotonically increasing graph-mutation counter: bumped by every
        # apply_delta and stamped on each bundle's (host-side) version tag
        self.operands_version = 0
        # global pow2-p90 fallback budget (cold start / online_adapt off):
        # p90 per-morsel iteration count of recent batches — the per-bucket
        # BudgetModel supersedes it as soon as it holds samples.
        self._iter_p90s: collections.deque = collections.deque(maxlen=32)
        # per-iteration (n_f, m_f, m_u, pull-cost) samples from the phase-1
        # stats tap, grouped by the n_pad they were measured against (the
        # beta predicate compares n_f*beta to the PADDED row count)
        self._dir_samples: dict[int, collections.deque] = {}
        self._sample_window = int(sample_window)
        self._batches_since_refit = 0

    # ------------------------------------------------------------- engines

    @staticmethod
    def _bundle_key(policy: MorselPolicy, spec: ExtendSpec) -> tuple:
        return (
            policy.graph_axes,
            spec.needs_rev,
            spec.needs_binned,
            spec.needs_binned_pack,
            spec.needs_blocks,
            spec.pad_block,
        )

    def _graph_for(
        self, policy: MorselPolicy, spec: ExtendSpec = ExtendSpec()
    ) -> OperandBundle:
        # operand bundles are shared by every spec needing the same physical
        # structures (rev/blocks), not per backend string. Sharing is safe
        # across graph versions because a delta folds into the SHARED bundle
        # and bumps its version/epochs once: a spec can never observe a
        # bundle pinned at a different operands_version than its siblings —
        # in-flight batches instead pin the resolved (ops, epoch) pair at
        # begin time (see _begin_hybrid), so they keep their pre-delta
        # buffers without ever re-resolving through this cache.
        key = self._bundle_key(policy, spec)
        if key not in self._graphs:
            # pad for mesh.size so every policy's graph shares one n_pad and
            # phase-1 state can resume on the phase-2 graph unchanged
            ops, n_pad = prepare_graph(
                self.csr, self.mesh, policy, self.max_deg,
                pad_shards=self.mesh.size, extend=spec,
                version=self.operands_version, stream=self.stream,
            )
            self._graphs[key] = OperandBundle(
                ops=ops, n_pad=n_pad, version=self.operands_version,
                policy=policy, spec=spec,
            )
        return self._graphs[key]

    def _spec_epoch(self, bundle: OperandBundle, spec: ExtendSpec) -> int:
        """The shape generation an engine scanning ``spec``'s structures
        out of ``bundle`` compiles against: the max epoch over exactly
        the structures the spec scans — a rebuild of the blocks operand
        must not invalidate push engines sharing the bundle."""
        e = bundle.epochs
        v = e.get("fwd", 0)
        if spec.needs_rev:
            v = max(v, e.get("rev", 0))
        if spec.needs_binned:
            v = max(v, e.get("rev_binned", 0))
        if spec.needs_binned_pack:
            v = max(v, e.get("rev_binned_pack", 0))
        if spec.needs_blocks:
            v = max(v, e.get("blocks", 0))
        return v

    # ------------------------------------------------------- graph mutation

    def apply_delta(self, delta: GraphDelta) -> DeltaReport:
        """Mutate the served graph in place: fold ``delta`` into every
        cached operand bundle instead of rebuilding from scratch.

        Per bundle, only the structures whose content actually changed
        are re-placed on device (untouched device arrays are reused),
        and only structures whose SHAPES changed (a row overflowed its
        ELL width, a degree left every existing bucket's invariant
        range, a new block tile found no free slot) bump their epoch —
        so a same-shape delta leaves every compiled engine warm and
        ``cache.compile_events`` flat, while a shape-changing delta
        invalidates exactly the engine keys whose scanned structures
        were rebuilt. Queries planned after this call see the new graph;
        batches already in flight keep the operand buffers they pinned
        at begin time (never torn)."""
        new_csr = apply_delta_csr(self.csr, delta)
        old_eff = effective_csr(self.csr, self.max_deg)
        new_eff = effective_csr(new_csr, self.max_deg)
        diff = diff_effective(old_eff, new_eff, delta)
        self.operands_version += 1
        n_changed = n_rebuilt = moves = 0
        for key, bundle in self._graphs.items():
            if bundle.host is None:
                # first delta against this bundle: one device->host copy
                # into a writable mirror (np.array, not asarray — jax
                # buffer views are read-only), reused by every later fold
                bundle.host = jax.tree.map(
                    lambda x: np.array(x), bundle.ops
                )
            structs, rep = fold_operands(
                bundle.host, old_eff, new_eff, diff
            )
            bundle.host = GraphOperands(
                **structs, version=self.operands_version
            )
            bundle.ops = self._place_structures(key[0], bundle, rep)
            bundle.version = self.operands_version
            for s, r in rep.reshaped.items():
                if r:
                    bundle.epochs[s] = bundle.epochs.get(s, 0) + 1
            n_changed += rep.n_changed
            n_rebuilt += rep.n_reshaped
            moves += rep.binned_moves
        self.csr = new_csr
        # stale-state sweep: measured cost rates and probes were taken
        # against the pre-delta operands, and the online learners are
        # keyed to the PRE-delta degree buckets — serving them across the
        # fence would budget/steer post-delta batches with buckets their
        # sources no longer belong to
        self._cost_rates.clear()
        self.invalidate_learned_state()
        invalidated = self.cache.invalidate(self._engine_stale)
        self.stats.deltas += 1
        return DeltaReport(
            version=self.operands_version,
            n_adds=delta.n_adds,
            n_dels=delta.n_dels,
            changed_edges=diff.n_changed_edges,
            dirty_fwd_rows=int(len(diff.fwd_dirty)),
            dirty_rev_rows=int(len(diff.rev_dirty)),
            bundles=len(self._graphs),
            structures_changed=n_changed,
            structures_rebuilt=n_rebuilt,
            binned_moves=moves,
            engines_invalidated=invalidated,
        )

    def invalidate_learned_state(self) -> None:
        """Reset the online learners whose keys or samples embed the
        pre-delta degree distribution: the per-bucket budget windows,
        the global-p90 fallback deque, and the direction-threshold
        sample store (plus the refitted table itself, unless the caller
        pinned one — a pin is an explicit instruction to serve that
        table regardless of the stream). Part of ``apply_delta``'s
        fence; callers that rebuild operands out-of-band can invoke it
        directly."""
        if self.budget_model is not None:
            self.budget_model.reset()
        self._iter_p90s.clear()
        self._dir_samples.clear()
        self._batches_since_refit = 0
        if not self._thresholds_pinned:
            self.direction_thresholds = None

    def _place_structures(
        self, graph_axes, bundle: OperandBundle, rep
    ) -> GraphOperands:
        """Device-place exactly the structures a fold changed, with
        ``prepare_graph``'s sharding rule (leading row/stacked-shard axis
        over the policy's graph axes, everything else replicated);
        unchanged structures keep their existing device arrays."""
        ga = graph_axes
        mesh = self.mesh
        shard = lambda x: NamedSharding(
            mesh, P(ga if ga else None, *(None,) * (np.ndim(x) - 1))
        )
        old, host = bundle.ops, bundle.host
        # one batched transfer for every changed structure (a device_put
        # per leaf pays a dispatch round-trip each; the pytree form issues
        # them together)
        dirty = {
            name: getattr(host, name)
            for name in ("fwd", "rev", "rev_binned", "rev_binned_pack",
                         "blocks")
            if rep.changed[name]
        }
        placed = jax.device_put(dirty, jax.tree.map(shard, dirty))
        pick = lambda name, old_s: placed.get(name, old_s)
        return GraphOperands(
            fwd=pick("fwd", old.fwd),
            rev=pick("rev", old.rev),
            rev_binned=pick("rev_binned", old.rev_binned),
            rev_binned_pack=pick("rev_binned_pack", old.rev_binned_pack),
            blocks=pick("blocks", old.blocks),
            version=self.operands_version,
        )

    def _engine_stale(self, key: EngineKey) -> bool:
        """True when ``key`` was compiled against operand shapes an
        applied delta has since rebuilt (its epoch no longer matches the
        bundle's current epoch for the structures it scans)."""
        bundle = self._graphs.get(self._bundle_key(key.policy, key.extend))
        if bundle is None:
            return False
        return key.operands_epoch != self._spec_epoch(bundle, key.extend)

    def engine(
        self,
        kind: str,
        policy: MorselPolicy,
        edge_compute: str,
        n_pad: int,
        max_iters: int | None = None,
        state_layout: str = "replicated",
        extend: ExtendSpec = ExtendSpec(),
        operands=None,
        collect_stats: bool = False,
        morsel_shape=None,
        epoch: int | None = None,
    ):
        cap = int(max_iters if max_iters is not None else self.max_iters)
        if operands is None and (
            extend.needs_binned or extend.needs_rev or extend.needs_blocks
        ):
            bundle = self._graph_for(policy, extend)
            operands = bundle.ops
            if epoch is None:
                epoch = self._spec_epoch(bundle, extend)
        key = EngineKey(
            kind, policy, edge_compute, n_pad, cap, state_layout, extend,
            collect_stats, int(epoch) if epoch else 0,
        )
        if kind == "static":
            builder = lambda: build_engine(
                self.mesh, policy, edge_compute, n_pad, cap,
                state_layout=state_layout, extend=extend, operands=operands,
                collect_stats=collect_stats,
            )
        elif kind == "phase1":
            builder = lambda: build_engine(
                self.mesh, policy, edge_compute, n_pad, cap,
                state_layout=state_layout, sync="shard", extend=extend,
                operands=operands, collect_stats=collect_stats,
            )
        elif kind == "resume":
            builder = lambda: build_resume_engine(
                self.mesh, policy, edge_compute, n_pad, cap, extend=extend,
                operands=operands, collect_stats=collect_stats,
            )
        elif kind == "gang":
            builder = lambda: build_gang_resume_engine(
                self.mesh, policy, edge_compute, n_pad, cap, extend=extend,
                operands=operands, state_layout=state_layout,
                collect_stats=collect_stats,
            )
        else:
            raise ValueError(f"unknown engine kind: {kind}")
        eng = self.cache.get_or_build(key, builder)
        if morsel_shape is not None:
            # a hit engine still retraces on a new morsel count; record it
            # so serving can classify this batch as cold (compile_events)
            self.cache.note_shape(key, tuple(morsel_shape))
        return eng

    # ------------------------------------------------------------ dispatch

    def _phase1_budget(self, buckets=()) -> int:
        """Iteration cap for phase 1, pow2-quantized so the budget only
        compiles O(log max_iters) distinct phase-1 engines.

        Priority: a pinned ``phase1_iters`` bypasses learning outright;
        then the per-(family, source-degree-bucket) ``BudgetModel``
        serves the covering budget for this batch's ``buckets``; an
        empty model falls back to the global pow2 p90 of recent batches
        (the legacy path, and ``online_adapt=False``'s only path)."""
        if self.phase1_iters is not None:
            return max(1, min(self.phase1_iters, self.max_iters))
        if self.budget_model is not None:
            b = self.budget_model.budget_for(
                self.family, buckets, self.max_iters
            )
            if b is not None:
                return b
        if self._iter_p90s:
            b = _pow2ceil(int(np.median(self._iter_p90s)) + 1)
        else:
            # cold start: small-world graphs converge in a few hops
            b = (
                self.budget_model.cold_budget
                if self.budget_model is not None
                else 8
            )
        return max(4, min(b, self.max_iters))

    def _record_iters(self, iters: np.ndarray):
        if iters.size:
            self._iter_p90s.append(float(np.percentile(iters, 90)))

    def _morsel_buckets(self, sources: np.ndarray, lanes: int) -> np.ndarray:
        """pow2 source-degree bucket per REAL morsel: the budget model's
        key, from the mean out-degree of each morsel's (real) sources."""
        if len(sources) == 0:
            return np.zeros(0, np.int64)
        deg = self.csr.degrees[
            np.clip(sources, 0, self.csr.n_nodes - 1)
        ].astype(np.float64)
        n_m = -(-len(sources) // lanes)
        pad = np.full(n_m * lanes - len(sources), np.nan)
        mean = np.nanmean(
            np.concatenate([deg, pad]).reshape(n_m, lanes), axis=1
        )
        return np.asarray([degree_bucket(float(m)) for m in mean], np.int64)

    def depth_hint(self, sources, lanes: int = 1) -> int | None:
        """Predicted convergence depth (iterations) for a prospective
        batch of sources — the admission layer's deadline-packing signal.
        Serves the learned per-bucket budget when the model has samples;
        None when nothing has been learned yet (cold admission must not
        evict/shed on a guess)."""
        if self.budget_model is None or len(sources) == 0:
            return None
        buckets = self._morsel_buckets(
            np.asarray(sources, np.int64).reshape(-1), lanes
        )
        return self.budget_model.budget_for(
            self.family, buckets, self.max_iters
        )

    # ---------------------------------------------------- online adaptation

    def _record_samples(self, stats: np.ndarray, trips: np.ndarray,
                        n_pad: int, push_slots: int,
                        start: np.ndarray | None = None,
                        phase: int = 1) -> None:
        """Drain one batch's stats-tap buffer into the sample store: one
        fit-consumable record per (real morsel, iteration). ``start``
        gives each morsel's first recorded row (phase-2 taps resume at
        the survivor's absolute phase-1 exit counter; rows below it are
        zero-padding, not samples); ``phase`` labels the records so
        consumers can split head/tail iteration populations."""
        store = self._dir_samples.setdefault(
            int(n_pad), collections.deque(maxlen=self._sample_window)
        )
        for i in range(stats.shape[0]):
            j0 = int(start[i]) if start is not None else 0
            for j in range(j0, int(trips[i])):
                n_f, m_f, m_u, pull, _wall, pbytes = (
                    float(v) for v in stats[i, j]
                )
                store.append({
                    "it": j,
                    "phase": phase,
                    "frontier": n_f,
                    "m_frontier": m_f,
                    "m_unexplored": m_u,
                    "push_slots": float(push_slots),
                    "pull_slots_binned": None if pull < 0 else pull,
                    "pull_bytes_binned": None if pbytes < 0 else pbytes,
                })

    def _rates_for(self, n_pad: int) -> dict:
        """Measured per-backend ms/slot rates for ``n_pad``, probed lazily
        on first use (the probe jit-compiles one extension per backend —
        doing it at trace-READ time keeps the serving hot path and every
        slots-mode run probe-free) and cached for the dispatcher's life."""
        if n_pad in self._cost_rates:
            return self._cost_rates[n_pad]
        best = None
        score = lambda o: (
            (o.rev_binned is not None) + (o.rev_binned_pack is not None)
        )
        for b in self._graphs.values():
            ops = b.ops
            if int(b.n_pad) == int(n_pad) and (
                best is None or score(ops) > score(best)
            ):
                best = ops
        rates = (
            {} if best is None else self.cost_probe.rates(best, int(n_pad))
        )
        self._cost_rates[n_pad] = rates
        return rates

    def online_trace(self, cost: str | None = None) -> dict:
        """The accumulated live samples as a ``BENCH_direction_opt``-shaped
        trace document: one workload per observed n_pad (this graph's
        family/avg-degree), records under the canonical ``ell_push``
        backend key — exactly what ``fit_direction_thresholds`` consumes,
        so the offline fit of this trace IS the online refit.

        Scope: the phase-1 tap plus the resume/gang phase-2 taps — a
        survivor's post-budget tail iterations (``phase == 2`` records,
        starting at its absolute phase-1 exit counter) land in the same
        store, so deep-straggler tails are represented like a full
        offline bench trace.

        ``cost`` (default: the dispatcher's ``cost_mode``): "measured"
        annotates each record with ``push_wall_ms`` /
        ``pull_wall_ms_binned`` / ``pull_wall_ms_fused`` — slot counts
        converted through the lazily-probed per-backend ms/slot rates —
        so ``fit_direction_thresholds(..., cost="measured")`` can
        consume the document; "slots" emits the historical slots-only
        records."""
        c = self.cost_mode if cost is None else cost
        workloads = []
        for n_pad, recs in sorted(self._dir_samples.items()):
            records = [dict(r) for r in recs]
            if c == "measured":
                rates = self._rates_for(n_pad)
                pr = rates.get("ell_push", {}).get("ms_per_slot")
                br = rates.get("pull_binned", {}).get("ms_per_slot")
                fr = rates.get("pull_binned_fused", {}).get("ms_per_slot")
                for r in records:
                    ps = r.get("pull_slots_binned")
                    r["push_wall_ms"] = (
                        None if pr is None else pr * r["push_slots"]
                    )
                    r["pull_wall_ms_binned"] = (
                        None if (br is None or ps is None) else br * ps
                    )
                    r["pull_wall_ms_fused"] = (
                        None if (fr is None or ps is None) else fr * ps
                    )
            workloads.append({
                "graph": f"online_npad{n_pad}",
                "kind": self.family or "unknown",
                "n": int(self.csr.n_nodes),
                "n_pad": int(n_pad),
                "n_edges": int(self.csr.n_edges),
                "avg_degree": float(self.csr.avg_degree),
                "backends": {"ell_push": {"iterations": records}},
            })
        return {"workloads": workloads}

    def refit_thresholds(self, cost: str | None = None) -> (
        DirectionThresholds | None
    ):
        """Refit ``direction_thresholds`` from the accumulated live
        samples (no-op before any sample lands). ``backend="recommend"``
        serves the refitted alpha/beta on the next batch. ``cost``
        overrides the dispatcher's ``cost_mode`` for this one refit
        (measured-cost fits degrade per-record to slots parity when a
        backend's rate could not be probed)."""
        if not any(len(r) for r in self._dir_samples.values()):
            return None
        c = self.cost_mode if cost is None else cost
        self.direction_thresholds = fit_direction_thresholds(
            self.online_trace(cost=c), cost=c
        )
        self.stats.refits += 1
        return self.direction_thresholds

    def _learn(self, outcome: "QueryOutcome", buckets: np.ndarray,
               n_real: int) -> None:
        """Post-batch learning: feed the budget model (real morsels only
        — the per-bucket form of the pad-morsel guard; skipped entirely
        when ``phase1_iters`` pins the budget) and the global-p90
        fallback, then refit thresholds on the ``refit_every`` cadence."""
        iters = np.asarray(outcome.result.iterations)[:n_real]
        self._record_iters(iters)
        if (
            self.budget_model is not None
            and self.phase1_iters is None
            and n_real > 0
        ):
            self.budget_model.observe_batch(
                self.family, buckets[:n_real], iters
            )
            if outcome.hybrid:
                self.budget_model.mispredicts.count(
                    outcome.budget_too_low, outcome.budget_too_high,
                    outcome.budget_inert_slots, outcome.budget_observed,
                )
        if self.online_adapt and not self._thresholds_pinned:
            self._batches_since_refit += 1
            if self._batches_since_refit >= self.refit_every:
                self._batches_since_refit = 0
                self.refit_thresholds()

    # ------------------------------------------ split-phase hybrid internals

    def _begin_hybrid(self, pol, ec, g, n_pad, morsels, state_layout,
                      extend=ExtendSpec(), n_real=0, buckets=(), epoch=0):
        """Choose the budget, then DISPATCH phase 1 without blocking: jax
        async dispatch returns device futures immediately, so the caller's
        host thread is free until ``_settle_hybrid`` blocks on them.

        The phase-2 operand bundle is resolved and PINNED here, at begin
        time, even though it is only consumed at settle time: resolving
        it inside ``_settle_hybrid`` (the historical path) re-read the
        shared bundle cache, so an ``apply_delta`` landing between begin
        and settle would have torn the batch across graph versions —
        phase 1 on the old edges, phase 2 on the new. The pinned ops
        keep the pre-delta device buffers alive for exactly as long as
        the in-flight batch needs them."""
        p1, p2 = hybrid_phases(
            pol.source_axes, pol.graph_axes, lanes=pol.lanes,
            or_impl=pol.or_impl,
        )
        budget = self._phase1_budget(buckets)
        collect = bool(self.online_adapt)
        eng1 = self.engine(
            "phase1", p1, ec, n_pad, max_iters=budget,
            state_layout=state_layout, extend=extend, operands=g,
            collect_stats=collect, morsel_shape=morsels.shape[:1],
            epoch=epoch,
        )
        b2 = self._graph_for(p2, extend)
        t0 = time.perf_counter()
        out1 = eng1(g, morsels)  # async: no block_until_ready
        return {
            "pol": pol, "p2": p2, "ec": ec, "g": g, "n_pad": n_pad,
            "state_layout": state_layout, "extend": extend,
            "n_real": n_real, "budget": budget, "collect": collect,
            "out1": out1, "t0": t0, "epoch": epoch,
            "g2": b2.ops, "n_pad2": b2.n_pad,
            "epoch2": self._spec_epoch(b2, extend),
        }

    def _settle_hybrid(self, inf) -> SettledBatch:
        """Block on phase 1, re-dispatch survivors (phase 2), block only
        on the per-morsel iteration counters, and defer the final state
        stitch into ``SettledBatch.finalize`` — the host work the serving
        loop overlaps with the next batch's phase 1."""
        pol, p2, ec = inf["pol"], inf["p2"], inf["ec"]
        g, n_pad = inf["g"], inf["n_pad"]
        state_layout, extend = inf["state_layout"], inf["extend"]
        n_real, budget, collect = inf["n_real"], inf["budget"], inf["collect"]
        sharded = state_layout == "sharded"
        out1 = jax.block_until_ready(inf["out1"])
        t1 = time.perf_counter()
        res1, stats1 = out1 if collect else (out1, None)

        # survivor test reads ONLY the frontier leaf — and under the
        # sharded layout only a per-morsel any() reduction (the full state
        # never gathers to host; the handoff below stays on device)
        f1 = res1.state.frontier
        if sharded:
            active = np.asarray(
                jnp.any(f1 != 0, axis=tuple(range(1, f1.ndim)))
            )
        else:
            frontier1 = np.asarray(f1)
            m = frontier1.shape[0]
            active = frontier1.reshape(m, -1).any(axis=1)
        idx = np.nonzero(active)[0]
        phase_ms = {"phase1": (t1 - inf["t0"]) * 1e3, "phase2": 0.0}
        iters1 = np.asarray(res1.iterations)
        n_real = int(min(n_real, iters1.shape[0]))
        too_low, too_high, inert = count_budget_mispredicts(
            budget, iters1[:n_real], active[:n_real],
            floor=(
                self.budget_model.floor
                if self.budget_model is not None
                else 4
            ),
        )
        if stats1 is not None and n_real > 0:
            self._record_samples(
                np.asarray(stats1)[:n_real], iters1[:n_real], n_pad,
                push_slots=int(np.prod(g.fwd.indices.shape)),
            )
        if idx.size == 0:
            return SettledBatch(QueryOutcome(
                result=res1, policy=pol.name, hybrid=True, redispatched=0,
                phase_ms=phase_ms, phase1_budget=budget,
                budget_too_low=too_low, budget_too_high=too_high,
                budget_inert_slots=inert, budget_observed=n_real,
            ))
        use_gang = self.gang_resume and (idx.size > 1 or sharded)

        # pad survivors to a pow2 morsel count: stable resume-trace shapes
        # (pad morsels are all-zero state => inert / zero-trip loops)
        kp = _pow2ceil(idx.size)
        sub_it = np.zeros((kp,), iters1.dtype)
        sub_it[: idx.size] = iters1[idx]

        # the phase-2 operands pinned at begin time (never re-resolved:
        # a delta applied while this batch was in flight must not swap
        # the graph under phase 2 — see _begin_hybrid)
        g2, n_pad2 = inf["g2"], inf["n_pad2"]
        assert n_pad2 == n_pad, (n_pad2, n_pad)

        state1 = None
        if not sharded:
            state1 = jax.tree.map(np.asarray, res1.state)

            def pick(x):
                out = np.zeros((kp,) + x.shape[1:], np.asarray(x).dtype)
                out[: idx.size] = np.asarray(x)[idx]
                return out

            sub_state = jax.tree.map(pick, state1)
        else:
            # all-gather/slice handoff: phase-1 rows (policy graph axes)
            # -> phase-2 rows (every mesh axis), survivors gathered and
            # pow2-padded on device
            sub_state = gang_handoff(
                res1.state, idx, kp, self.mesh, p2.graph_axes
            )

        if use_gang:
            eng2 = self.engine(
                "gang", p2, ec, n_pad, state_layout=state_layout,
                extend=extend, operands=g2, collect_stats=collect,
                morsel_shape=(kp,), epoch=inf["epoch2"],
            )
            self.stats.gangs += 1
            self.stats.gang_slots += kp
        else:
            eng2 = self.engine(
                "resume", p2, ec, n_pad, extend=extend, operands=g2,
                collect_stats=collect, epoch=inf["epoch2"],
            )
        out2 = eng2(g2, sub_state, jnp.asarray(sub_it))  # async dispatch
        res2, stats2 = out2 if collect else (out2, None)
        # block only the tiny per-morsel counters: phase 2 has then fully
        # executed on device, but the state leaves stay there — the stitch
        # below is deferred host work
        iters2 = np.asarray(res2.iterations)
        t2 = time.perf_counter()
        phase_ms["phase2"] = (t2 - t1) * 1e3
        if stats2 is not None and idx.size > 0:
            # survivors' post-budget tails: rows run from each morsel's
            # absolute phase-1 exit counter to its final trip count
            self._record_samples(
                np.asarray(stats2)[: idx.size], iters2[: idx.size], n_pad,
                push_slots=int(np.prod(g.fwd.indices.shape)),
                start=sub_it[: idx.size], phase=2,
            )

        final_iters = iters1.copy()
        final_iters[idx] = iters2[: idx.size]

        def materialize() -> IFEResult:
            if sharded:
                final_state = gang_scatter_back(res1.state, res2.state, idx)
            else:
                state2 = jax.tree.map(np.asarray, res2.state)

                def put(full, sub):
                    out = np.asarray(full).copy()
                    out[idx] = sub[: idx.size]
                    return out

                final_state = jax.tree.map(
                    jnp.asarray, jax.tree.map(put, state1, state2)
                )
            return IFEResult(
                state=final_state, iterations=jnp.asarray(final_iters)
            )

        outcome = QueryOutcome(
            result=IFEResult(state=None, iterations=jnp.asarray(final_iters)),
            policy=pol.name, hybrid=True, redispatched=int(idx.size),
            phase_ms=phase_ms, phase1_budget=budget,
            resumed_ganged=int(idx.size) if use_gang else 0,
            resumed_serial=0 if use_gang else int(idx.size),
            gang_width=kp if use_gang else 0,
            budget_too_low=too_low, budget_too_high=too_high,
            budget_inert_slots=inert, budget_observed=n_real,
        )
        return SettledBatch(outcome, materialize)

    def _run_hybrid(self, pol, ec, g, n_pad, morsels, state_layout,
                    extend=ExtendSpec(), n_real=0, buckets=(), epoch=0):
        """Two-phase hybrid on one morsel batch, synchronously: begin +
        settle + finalize back-to-back. Returns a QueryOutcome whose
        result state is bit-identical to the static engine's.

        Phase-2 dispatch: >1 survivor => one gang-scheduled multi-frontier
        resume (pow2-padded batch, per-survivor convergence masks — see the
        module docstring's gang contract); exactly 1 survivor => the serial
        per-morsel engine (no packing win to pay for); ``gang_resume=False``
        pins the serial baseline (replicated layout only — the sharded
        phase 2 IS the gang engine).

        ``n_real``/``buckets``: this batch's real (non-pad) morsel count
        and their source-degree buckets — the budget model's prediction
        key and the mispredict counters' population. Under
        ``online_adapt`` phase 1 runs the stats-tapped engine and its
        per-iteration samples land in the threshold-refit store."""
        inf = self._begin_hybrid(
            pol, ec, g, n_pad, morsels, state_layout, extend=extend,
            n_real=n_real, buckets=buckets, epoch=epoch,
        )
        return self._settle_hybrid(inf).finalize()

    def _begin_static(self, pol, ec, g, n_pad, morsels, state_layout,
                      extend=ExtendSpec(), epoch=0):
        eng = self.engine(
            "static", pol, ec, n_pad, state_layout=state_layout,
            extend=extend, operands=g, morsel_shape=morsels.shape[:1],
            epoch=epoch,
        )
        t0 = time.perf_counter()
        res = eng(g, morsels)  # async: no block_until_ready
        return {"pol": pol, "res": res, "t0": t0}

    def _settle_static(self, inf) -> SettledBatch:
        res = jax.block_until_ready(inf["res"])
        t1 = time.perf_counter()
        return SettledBatch(QueryOutcome(
            result=res, policy=inf["pol"].name, hybrid=False, redispatched=0,
            phase_ms={"phase1": (t1 - inf["t0"]) * 1e3, "phase2": 0.0},
            phase1_budget=0,
        ))

    def _run_static(self, pol, ec, g, n_pad, morsels, state_layout,
                    extend=ExtendSpec(), n_real=0, buckets=(), epoch=0):
        inf = self._begin_static(
            pol, ec, g, n_pad, morsels, state_layout, extend=extend,
            epoch=epoch,
        )
        return self._settle_static(inf).finalize()

    # ------------------------------------------------------ batch planning

    def _plan_query(self, sources, returns_paths, policy, backend,
                    query_kind="reach"):
        """Shared preamble of query/begin_batch: resolve policy, edge
        compute, extension spec, operands, morsels, chunking, and the
        budget model's bucket keys for one source batch.

        ``query_kind`` selects the scenario family (``QUERY_KINDS``):
        "reach" is the historical BFS/MS-BFS surface; the other kinds
        name their edge compute directly and, when the compute has no
        saturating lane form (``lanes_ok=False``), must not run under a
        lane-packed multi-source policy — an auto-recommended one
        degrades to nTkS, an explicitly pinned one is an error."""
        kind = QUERY_KINDS.get(query_kind)
        if kind is None:
            raise ValueError(
                f"unknown query_kind: {query_kind!r} "
                f"(known: {sorted(QUERY_KINDS)})"
            )
        if query_kind != "reach" and returns_paths:
            raise ValueError(
                f"returns_paths is a reach-family option; "
                f"query_kind={query_kind!r} has its own result leaves"
            )
        sources = np.asarray(sources, np.int32).reshape(-1)
        name = policy or recommend_policy(
            len(sources),
            self.mesh.size,
            self.csr.avg_degree,
            returns_paths=returns_paths,
            n_nodes=self.csr.n_nodes,
        )
        pol = POLICIES[name]()
        if pol.is_multi_source and not kind.lanes_ok:
            if policy is not None:
                raise ValueError(
                    f"policy {policy!r} lane-packs sources but "
                    f"query_kind={query_kind!r} has no lane form"
                )
            # recommend_policy pooled >=64 sources into a lane policy;
            # this kind's state has no lane axis, so serve the same
            # batch as per-source morsels instead
            name = "ntks"
            pol = POLICIES[name]()
        if kind.edge_compute is not None:
            ec = kind.edge_compute
        elif pol.is_multi_source:
            ec = "msbfs_parents" if returns_paths else "msbfs_lengths"
        else:
            ec = "sp_parents" if returns_paths else "sp_lengths"
        backend = backend if backend is not None else self.backend
        if backend == "recommend":
            backend = recommend_backend(
                ec, self.csr.avg_degree, n_nodes=self.csr.n_nodes,
                lanes=pol.lanes, family=self.family,
                thresholds=self.direction_thresholds,
            )
        spec = as_spec(backend)
        bundle = self._graph_for(pol, spec)
        g, n_pad = bundle.ops, bundle.n_pad
        # pin the operand epoch at plan time: everything this batch
        # dispatches (phase 1, static, every chunk) keys its engines on
        # the shape generation of the buffers resolved HERE
        epoch = self._spec_epoch(bundle, spec)
        src_shards = _axes_size(self.mesh, pol.source_axes)
        morsels = pad_sources(sources, src_shards, pol.lanes, n_pad)
        # paper Fig 13: dense graphs cap concurrent source morsels (k);
        # oversized batches run in fixed-size chunks, stitched on host.
        k = (
            self.max_inflight
            if self.max_inflight is not None
            else recommend_k(self.csr.avg_degree)
        )
        chunk = max(src_shards, k * src_shards)
        if self.pad_pow2_morsels and 0 < morsels.shape[0] <= chunk:
            # serving: quantize the morsel count so a stream of arbitrary
            # pool sizes hits a bounded, pre-warmable set of XLA shapes
            # ({1, 2, 4, ..., chunk}) instead of retracing per queue
            # depth. Only below the chunk threshold: the chunked path
            # already normalizes its shapes (every chunk, including the
            # last, is padded to the chunk size), and pow2-rounding a big
            # pool would waste up to 2x device work. Capped at ``chunk``
            # so padding never flips a batch into the chunked path.
            m2 = min(_pow2ceil(morsels.shape[0]), chunk)
            if m2 > morsels.shape[0]:
                inert = np.full(
                    (m2 - morsels.shape[0], pol.lanes), n_pad, np.int32
                )
                morsels = np.concatenate([morsels, inert], axis=0)
        # budget learning and mispredict accounting see only the real
        # morsels: pad/inert ones exit at 0 iterations and would drag every
        # bucket's learned budget below its true convergence depth
        # (permanent re-dispatch)
        n_real = max(1, -(-len(sources) // pol.lanes))
        # buckets feed only the model's predict/observe; skip the host
        # work (degrees gather + per-morsel bucketing) when no model will
        # consume them (online_adapt off, or the budget pinned)
        buckets = (
            self._morsel_buckets(sources, pol.lanes)
            if self.budget_model is not None and self.phase1_iters is None
            else np.zeros(0, np.int64)
        )
        return sources, name, pol, ec, spec, g, n_pad, morsels, chunk, \
            n_real, buckets, epoch

    def _hybrid_eligible(self, pol, state_layout: str) -> bool:
        return (
            self.adaptive
            and bool(pol.source_axes)  # nT1S has no source morsels to split
            # sharded phase 2 is the gang engine; without it, fall back to
            # the static sharded dispatch (there is no serial sharded resume)
            and (state_layout == "replicated" or self.gang_resume)
        )

    # -------------------------------------------------- split-phase surface

    def begin_batch(
        self,
        sources,
        returns_paths: bool = False,
        policy: str | None = None,
        state_layout: str = "replicated",
        backend=None,
        query_kind: str = "reach",
    ) -> InflightBatch:
        """Plan one batch and dispatch its phase 1 (or static engine)
        asynchronously. The returned ``InflightBatch`` MUST be settled via
        ``settle_batch`` before the next ``begin_batch`` — learning is
        host-serial, and the budget/threshold state a later batch reads is
        only current once the earlier batch has settled."""
        (sources, name, pol, ec, spec, g, n_pad, morsels, chunk, n_real,
         buckets, epoch) = self._plan_query(
             sources, returns_paths, policy, backend, query_kind)
        if morsels.shape[0] > chunk:
            # oversized batch: the in-flight cap splits it into a host-
            # stitched chunk loop — run synchronously at settle time
            # (admission keeps serving batches under the cap)
            payload = {
                "sources": sources, "name": name, "pol": pol, "ec": ec,
                "spec": spec, "g": g, "n_pad": n_pad, "morsels": morsels,
                "chunk": chunk, "state_layout": state_layout,
                "epoch": epoch,
            }
            return InflightBatch("chunked", name, n_real, buckets, payload)
        if self._hybrid_eligible(pol, state_layout):
            inf = self._begin_hybrid(
                pol, ec, g, n_pad, jnp.asarray(morsels), state_layout,
                extend=spec, n_real=n_real, buckets=buckets, epoch=epoch,
            )
            return InflightBatch("hybrid", name, n_real, buckets, inf)
        inf = self._begin_static(
            pol, ec, g, n_pad, jnp.asarray(morsels), state_layout,
            extend=spec, epoch=epoch,
        )
        return InflightBatch("static", name, n_real, buckets, inf)

    def settle_batch(self, inflight: InflightBatch) -> SettledBatch:
        """Drive one in-flight batch through its device sync points and
        post-batch learning. The result state may still be deferred —
        ``finalize_batch`` (or ``SettledBatch.finalize``) materializes it;
        the serving loop calls that *after* dispatching the next phase 1
        so the host stitch overlaps device compute."""
        if inflight.kind == "chunked":
            p = inflight.payload
            outcome = self._run_chunked(
                p["pol"], p["ec"], p["g"], p["n_pad"], p["morsels"],
                p["chunk"], p["state_layout"], p["spec"],
                inflight.n_real, inflight.buckets, p.get("epoch", 0),
            )
            settled = SettledBatch(outcome)
        elif inflight.kind == "hybrid":
            settled = self._settle_hybrid(inflight.payload)
        else:
            settled = self._settle_static(inflight.payload)
        settled.outcome.policy = inflight.name
        self._learn(settled.outcome, inflight.buckets, inflight.n_real)
        self.stats.record(settled.outcome)
        return settled

    def finalize_batch(self, settled: SettledBatch) -> QueryOutcome:
        """Run the deferred host materialization (idempotent)."""
        return settled.finalize()

    def _run_chunked(self, pol, ec, g, n_pad, morsels, chunk, state_layout,
                     spec, n_real, buckets, epoch=0) -> QueryOutcome:
        """The in-flight-cap chunk loop: fixed-size chunks, host-stitched
        into one outcome (learning/stats are applied once by the caller)."""
        run_fn = (
            self._run_hybrid
            if self._hybrid_eligible(pol, state_layout)
            else self._run_static
        )
        outcomes = []
        for i in range(0, morsels.shape[0], chunk):
            part = morsels[i : i + chunk]
            if part.shape[0] < chunk:  # keep one trace shape per chunk size
                pad = np.full(
                    (chunk - part.shape[0], part.shape[1]), n_pad, np.int32
                )
                part = np.concatenate([part, pad], axis=0)
            real_in = max(0, min(chunk, n_real - i))
            outcomes.append(
                run_fn(
                    pol, ec, g, n_pad, jnp.asarray(part), state_layout,
                    extend=spec, n_real=real_in,
                    buckets=buckets[i : i + real_in], epoch=epoch,
                )
            )
        result = IFEResult(
            state=jax.tree.map(
                lambda *xs: jnp.concatenate([jnp.asarray(x) for x in xs]),
                *[o.result.state for o in outcomes],
            ),
            iterations=jnp.concatenate(
                [jnp.asarray(o.result.iterations) for o in outcomes]
            ),
        )
        return QueryOutcome(
            result=result,
            policy=pol.name,
            hybrid=any(o.hybrid for o in outcomes),
            redispatched=sum(o.redispatched for o in outcomes),
            phase_ms={
                "phase1": sum(o.phase_ms["phase1"] for o in outcomes),
                "phase2": sum(o.phase_ms["phase2"] for o in outcomes),
            },
            phase1_budget=max(o.phase1_budget for o in outcomes),
            resumed_ganged=sum(o.resumed_ganged for o in outcomes),
            resumed_serial=sum(o.resumed_serial for o in outcomes),
            gang_width=max(o.gang_width for o in outcomes),
            budget_too_low=sum(o.budget_too_low for o in outcomes),
            budget_too_high=sum(o.budget_too_high for o in outcomes),
            budget_inert_slots=sum(o.budget_inert_slots for o in outcomes),
            budget_observed=sum(o.budget_observed for o in outcomes),
        )

    def query(
        self,
        sources,
        returns_paths: bool = False,
        policy: str | None = None,
        state_layout: str = "replicated",
        backend=None,
        query_kind: str = "reach",
    ) -> QueryOutcome:
        """Serve one request batch of source nodes, synchronously.

        Policy is chosen per batch via ``recommend_policy`` unless pinned;
        execution is two-phase hybrid whenever eligible (adaptive mode,
        replicated state, source-level morsels to re-dispatch). This is
        ``begin_batch`` + ``settle_batch`` + ``finalize_batch`` run
        back-to-back — bit-identical to the serving loop's overlapped
        pipeline on the same batch stream.

        ``backend`` selects the frontier-extension backend for this batch
        ("ell_push" | "ell_pull" | "block_mxu" | "dopt" | an ExtendSpec;
        "recommend" applies ``recommend_backend``); None uses the
        scheduler's default. All choices are bit-identical in result.

        ``query_kind`` selects the scenario family ("reach" | "topk_paths"
        | "ppr" | "pattern_counts"): everything downstream of the edge
        compute — engine cache, two-phase hybrid, gang resume, online
        learning — is shared across kinds unchanged.
        """
        inflight = self.begin_batch(
            sources, returns_paths=returns_paths, policy=policy,
            state_layout=state_layout, backend=backend,
            query_kind=query_kind,
        )
        return self.settle_batch(inflight).finalize()
