"""Synchronous façade over the layered serving core (paper §5.4/§5.6).

The adaptive morsel runtime that used to live in this one module is now
three layers (docs/serving.md maps them to the paper's concepts):

- **admission** (``runtime/admission.py``) — multi-tenant queue, quotas,
  the Fig 14 pack-vs-solo rule, deadline-aware lane packing with eviction,
  load shedding;
- **dispatch** (``runtime/dispatch.py``) — the engine cache and the
  two-phase hybrid (learned phase-1 budgets, gang-scheduled phase-2
  resume, online threshold refits), plus the split-phase batch API
  (``begin_batch`` / ``settle_batch`` / ``finalize_batch``);
- **service** (``runtime/service.py``) — the always-on ``ServingLoop``
  overlapping batch i's deferred host work with batch i+1's device work,
  with per-tenant SLO telemetry.

``AdaptiveScheduler`` survives here as the thin synchronous façade every
pre-split caller (tests, benchmarks, the closed-loop driver) keeps using
unmodified: it IS the dispatch layer (subclass — ``query``, the engine
cache, stats, and the learners are inherited, semantics unchanged), and
its ``submit``/``flush`` run the admission layer's planner with no quotas
and no deadlines, which reproduces the legacy pooled batching bit-for-bit
(same qid naming, same arrival-order packing, same per-query result rows
— the replay corpus in tests/test_serving.py pins façade == ServingLoop).

Supported jax range: 0.4.35 — 0.8.x (see repro.compat / repro.launch.mesh).
"""
from __future__ import annotations

import numpy as np

from .admission import AdmissionQueue
from .dispatch import (  # noqa: F401  (re-exported: pre-split import site)
    EngineCache,
    EngineKey,
    QueryDispatcher,
    QueryOutcome,
    SchedulerStats,
    _pow2ceil,
)
from .service import unpack_levels


class AdaptiveScheduler(QueryDispatcher):
    """Compile-once, serve-many recursive-query runtime over one graph —
    the dispatch layer (see ``QueryDispatcher`` for the execution/learning
    contract) plus the legacy synchronous ``submit``/``flush`` admission
    surface. For the always-on overlapped loop with tenant SLOs, drive the
    same dispatcher through ``runtime.service.ServingLoop`` instead."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        # no quotas, no deadlines, no estimators: the admission planner in
        # this configuration is exactly the legacy flush batching
        self._admission = AdmissionQueue(
            n_nodes=self.csr.n_nodes,
            n_devices=self.mesh.size,
            avg_degree=self.csr.avg_degree,
        )
        self.admissions = {"ntkms": 0, "per_query": 0}

    def apply_delta(self, delta):
        """Graph mutation through the dispatcher, plus the façade's own
        stale-state refresh: its private admission queue captured
        ``avg_degree`` at construction and the pooled-policy decision in
        ``flush`` would keep keying on the pre-delta density."""
        report = super().apply_delta(delta)
        self._admission.avg_degree = float(self.csr.avg_degree)
        return report

    # ----------------------------------------------------------- admission

    def submit(self, sources, qid: str | None = None) -> str:
        """Queue one tenant's query for the next ``flush``."""
        return self._admission.submit(sources, qid=qid).qid

    def flush(self) -> dict[str, np.ndarray]:
        """Run all queued queries; returns {qid: levels [k, n_nodes] int32}
        (-1 = unreached), one row per submitted source.

        Admission rule (paper Fig 14): pack every tenant's sources into
        shared 64-wide MS-BFS lane morsels only when ``recommend_policy``
        says the pooled batch saturates the lanes; otherwise each query
        runs by itself under the hybrid (packing with too few sources
        would scan the graph for mostly-empty lanes).
        """
        if not self._admission.pending():
            return {}
        plan = self._admission.plan()
        out: dict[str, np.ndarray] = dict(plan.instant)
        packed = any(pb.packed for pb in plan.batches)
        if plan.batches:
            self.admissions["ntkms" if packed else "per_query"] += 1
        for pb in plan.batches:
            outcome = self.query(pb.sources, policy=pb.policy)
            out.update(unpack_levels(
                np.asarray(outcome.result.state.levels), pb.spans,
                self.csr.n_nodes, pb.packed,
            ))
            for q in pb.queries:
                self._admission.complete(q.qid)
        for qid in plan.instant:
            self._admission.complete(qid)
        return out
