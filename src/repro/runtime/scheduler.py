"""Adaptive morsel runtime: compiled-engine cache + dynamic hybrid dispatch
+ multi-tenant admission (paper §5.4/§5.6, realized at runtime).

The static dispatcher (core/dispatcher.py) encodes one morsel policy as one
mesh-axis assignment: robust, but a converged source shard burns inert
iterations until the globally slowest morsel finishes, and every caller pays
a fresh trace for every (policy, shape) combination. This module is the
serving layer that fixes both:

1. **Engine cache** — compiled ``QueryEngine``s keyed by (engine kind,
   policy, edge compute, padded graph shape, iteration cap, state layout).
   Serving never re-traces a combination it has seen; hit/miss counters make
   the warm/cold split observable.

2. **Dynamic hybrid dispatch** — the paper's hybrid policy ("issue morsels
   at both the source node and frontier levels") as a two-phase schedule:

   - *Phase 1* runs nTkS with per-shard convergence (``sync="shard"``) under
     an adaptive iteration budget served per batch by the per-(dataset-
     family, source-degree-bucket) ``BudgetModel`` (see point 5):
     source-shard groups whose morsels converge exit immediately.
   - *Phase 2* re-dispatches the surviving (unconverged) morsels with their
     saved state under nT1S frontier parallelism over ALL mesh axes (ring
     frontier union — collectives.REDISPATCH_OR_IMPL), so the stragglers
     get every device instead of idling most of them.

   Both graphs are padded to one shared row count (``prepare_graph
   pad_shards=mesh.size``) so state flows between phases unchanged, making
   the hybrid bit-identical in final state to a single-phase nTkS run.

   **Gang packing + convergence-mask contract (phase 2).** When more than
   one morsel survives phase 1 the survivors are NOT drained serially
   (``lax.map`` is a sequential scan — exactly the frontier-level
   serialization the hybrid exists to avoid). Instead they are ganged into
   one batched multi-frontier re-dispatch (``build_gang_resume_engine``):

   - survivor state pytrees are stacked and zero-padded to a pow2 gang
     width ``S_pad`` (stable trace shapes; all-zero pad morsels are inert
     because their frontier is empty and the convergence mask never fires);
   - dense survivor frontiers are repacked as MS-BFS lanes
     (``core.msbfs.gang_pack_lanes`` — morsel s owns lane column s) so ONE
     shared adjacency scan per iteration serves the whole gang; 64-lane
     morsels fold into one ``[rows, S*64]`` lane tensor;
   - a per-survivor convergence mask (own frontier globally non-empty AND
     own iteration counter under the cap) gates every state update and
     counter increment, so an early finisher goes *inert* — its state
     freezes mid-gang — instead of blocking the batch or overrunning its
     cap. This makes the gang bit-identical per morsel to the serial
     resume: each morsel sees exactly the same (state, iteration) update
     sequence, and OR/MIN merges are per-lane.

   A single survivor takes the serial fast path (no packing win to pay
   for). The sharded state layout gets the same treatment: survivor rows
   are handed from the phase-1 layout (rows over the policy's graph axes)
   to the phase-2 layout (rows over ALL axes) by
   ``collectives.gang_handoff``, and the per-iteration merge is the OR/MIN
   reduce-scatter (``collectives.gang_merge_scatter``) — so DESIGN §6
   billion-node graphs get a phase 2 at all. ``SchedulerStats`` exposes
   gang occupancy and the redispatched/ganged/serial counter split.

3. **Multi-tenant admission** — ``submit``/``flush`` pack queries from many
   callers into 64-wide MS-BFS lane morsels only when ``recommend_policy``
   says packing wins (enough sources to saturate lanes); otherwise each
   query runs under the hybrid. ``recommend_k`` caps in-flight source
   morsels per shard on dense graphs (paper Fig 13's locality cliff).

4. **Recommended scan layout by default** — ``backend="recommend"`` is the
   default: ``recommend_backend`` picks the physical frontier-extension
   layout per batch (Beamer direction switch over degree-binned pull slabs
   for the BFS family, block-MXU for saturated lane morsels on block-dense
   graphs, forward push for weighted relax), optionally with alpha/beta
   fitted per (dataset-family, degree-bucket) from bench traces
   (``direction_thresholds=``). Every choice is bit-identical in result
   state — the recommendation only moves scan cost.

5. **Online policy learning** (``online_adapt=True``, the default) — the
   scheduler's two learned knobs close their feedback loops on the live
   stream instead of offline artifacts:

   - the phase-1 budget is served per batch by ``core.policies.
     BudgetModel``: per-(dataset-family, source-degree-bucket) windows of
     observed real-morsel convergence depths, pow2-quantized p90 serving
     with DirectionThresholds-style bucket fallback. The legacy global
     p90 deque survives only as the empty-model cold path; a pinned
     ``phase1_iters`` bypasses the learner outright. Budget mispredicts
     are counted per batch (too_low = survivors that paid a re-dispatch;
     too_high = morsels that converged strictly under half the budget;
     inert_slots = budget slack) into ``SchedulerStats`` and
     ``BudgetModel.mispredicts``.
   - phase-1 engines run with the ``build_engine(collect_stats=True)``
     sample tap; the per-iteration (m_frontier, m_unexplored, scan-cost)
     records accumulate in a bounded store (``online_trace()`` exports
     them in BENCH_direction_opt schema) and every ``refit_every``
     batches ``fit_direction_thresholds`` refits the served alpha/beta
     in-flight, so ``backend="recommend"`` tracks the live stream.

   Both loops move only iteration slots / scan layouts, never results,
   and both are deterministic in the served batch stream (bit-identical
   budgets/thresholds/counters across replays and gang_resume on/off).

Supported jax range: 0.4.35 — 0.8.x (see repro.compat / repro.launch.mesh).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    BudgetModel,
    DirectionThresholds,
    POLICIES,
    ExtendSpec,
    IFEResult,
    MorselPolicy,
    as_spec,
    build_engine,
    build_gang_resume_engine,
    build_resume_engine,
    count_budget_mispredicts,
    degree_bucket,
    fit_direction_thresholds,
    gang_handoff,
    gang_scatter_back,
    hybrid_phases,
    pad_sources,
    pow2ceil as _pow2ceil,
    prepare_graph,
    recommend_backend,
    recommend_k,
    recommend_policy,
)
from ..core.dispatcher import _axes_size
from ..graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class EngineKey:
    """Cache identity of one compiled engine. ``kind`` distinguishes the
    static single-phase program, the per-shard-sync phase-1 program, and
    the state-resuming phase-2 program — same policy tuple, different HLO.
    ``extend`` carries the extension backend + direction mode (an
    ``ExtendSpec``): each backend is a different scan program. ``stats``
    marks the sample-tapped flavor (``build_engine(collect_stats=True)``
    returns ``(result, per-iteration stats)`` — same result state,
    different HLO)."""

    kind: str  # "static" | "phase1" | "resume"
    policy: MorselPolicy
    edge_compute: str
    n_nodes_padded: int
    max_iters: int
    state_layout: str
    extend: ExtendSpec = ExtendSpec()
    stats: bool = False


class EngineCache:
    """Compiled-QueryEngine cache with hit/miss accounting. Hits and misses
    are additionally counted per engine kind (static/phase1/resume/gang) so
    the gang path's compile footprint is observable."""

    def __init__(self):
        self._engines: dict[EngineKey, Any] = {}
        self.hits = 0
        self.misses = 0
        self.hits_by_kind: collections.Counter = collections.Counter()
        self.misses_by_kind: collections.Counter = collections.Counter()

    def __len__(self) -> int:
        return len(self._engines)

    def get_or_build(self, key: EngineKey, builder: Callable[[], Any]):
        kind = getattr(key, "kind", "?")
        eng = self._engines.get(key)
        if eng is not None:
            self.hits += 1
            self.hits_by_kind[kind] += 1
            return eng
        self.misses += 1
        self.misses_by_kind[kind] += 1
        eng = builder()
        self._engines[key] = eng
        return eng


@dataclasses.dataclass
class QueryOutcome:
    """One served batch: result + how the runtime chose to execute it.

    ``redispatched`` counts the morsels *handed* to phase 2 (the phase-1
    survivors); ``resumed_ganged``/``resumed_serial`` split it by how they
    actually ran (one batched gang dispatch vs the per-morsel engine), so
    ``redispatched == resumed_ganged + resumed_serial`` always holds.
    ``gang_width`` is the pow2-padded width of the gang dispatch (0 when no
    gang ran; the max across chunks for chunked batches).

    The ``budget_*`` counters classify this batch's REAL morsels against
    the phase-1 budget (``core.policies.count_budget_mispredicts``
    semantics: too_low = survivors that paid a re-dispatch, too_high =
    morsels that converged strictly under half the budget, inert_slots =
    budget slack over converged morsels); zero on static runs."""

    result: IFEResult
    policy: str  # base policy name ("ntks", "ntkms", ...)
    hybrid: bool  # did the two-phase hybrid path run?
    redispatched: int  # morsels handed to phase 2
    phase_ms: dict  # {"phase1": ms, "phase2": ms}; static runs use phase1
    phase1_budget: int  # iteration cap phase 1 ran under (0 = static)
    resumed_ganged: int = 0  # survivors resumed in a gang dispatch
    resumed_serial: int = 0  # survivors resumed one-morsel-at-a-time
    gang_width: int = 0  # padded gang width (0 = no gang dispatch)
    budget_too_low: int = 0  # real morsels the budget undershot
    budget_too_high: int = 0  # real morsels a smaller pow2 budget covered
    budget_inert_slots: int = 0  # budget slack over converged real morsels
    budget_observed: int = 0  # real morsels the counters classified


@dataclasses.dataclass
class SchedulerStats:
    """Cumulative runtime counters across every served batch.

    The ``redispatched = resumed_ganged + resumed_serial`` split mirrors
    QueryOutcome; ``gangs``/``gang_slots`` make gang occupancy observable
    (survivors actually ganged over padded slots dispatched)."""

    queries: int = 0
    hybrid_runs: int = 0  # batches that took the two-phase path
    redispatched: int = 0  # survivors handed to phase 2
    resumed_ganged: int = 0
    resumed_serial: int = 0
    gangs: int = 0  # gang dispatches issued
    gang_slots: int = 0  # padded gang widths summed over dispatches
    phase1_ms: float = 0.0
    phase2_ms: float = 0.0
    budget_too_low: int = 0  # phase-1 budget mispredicts (QueryOutcome)
    budget_too_high: int = 0
    budget_inert_slots: int = 0
    budget_observed: int = 0
    refits: int = 0  # in-flight direction-threshold refits

    @property
    def gang_occupancy(self) -> float:
        """Real survivors per padded gang slot (1.0 = pow2-tight gangs)."""
        return self.resumed_ganged / self.gang_slots if self.gang_slots else 0.0

    @property
    def budget_mispredict_rate(self) -> float:
        """Mispredicted real morsels per observed real morsel (too_low +
        too_high over observed; 0.0 before any hybrid batch)."""
        if not self.budget_observed:
            return 0.0
        return (self.budget_too_low + self.budget_too_high) / (
            self.budget_observed
        )

    def record(self, outcome: "QueryOutcome") -> None:
        self.queries += 1
        if outcome.hybrid:
            self.hybrid_runs += 1
        self.redispatched += outcome.redispatched
        self.resumed_ganged += outcome.resumed_ganged
        self.resumed_serial += outcome.resumed_serial
        self.phase1_ms += outcome.phase_ms.get("phase1", 0.0)
        self.phase2_ms += outcome.phase_ms.get("phase2", 0.0)
        self.budget_too_low += outcome.budget_too_low
        self.budget_too_high += outcome.budget_too_high
        self.budget_inert_slots += outcome.budget_inert_slots
        self.budget_observed += outcome.budget_observed


class AdaptiveScheduler:
    """Compile-once, serve-many recursive-query runtime over one graph.

    ``adaptive=True`` enables two-phase hybrid dispatch for any policy
    with source morsels (nTkS/nTkMS/1T1S) — pinning a policy picks WHICH
    morsels are issued, not the execution mode, and the hybrid is
    bit-identical in result state. Replicated state always qualifies; the
    sharded layout qualifies when ``gang_resume`` is on (its phase 2 is
    the gang engine + reduce-scatter merge — there is no serial sharded
    resume). ``adaptive=False`` degrades everything to the static
    dispatcher (one engine per policy), which is also the fallback for
    nT1S (no source morsels to re-dispatch).

    ``gang_resume=False`` pins phase 2 to the legacy one-morsel-at-a-time
    resume (kept as the differential baseline the parity corpus compares
    the gang against).

    ``online_adapt=True`` (the default) closes the policy feedback loop
    on the live stream:

    - the phase-1 iteration budget comes from a per-(dataset-family,
      source-degree-bucket) ``BudgetModel`` updated with every flushed
      batch's real-morsel convergence depths (the legacy global pow2 p90
      deque remains the empty-model cold path, and ``phase1_iters``
      still pins the budget outright, bypassing the learner);
    - phase-1 engines run with the ``collect_stats`` sample tap, and the
      accumulated per-iteration (m_frontier, m_unexplored, scan-cost)
      records are refit into ``direction_thresholds`` every
      ``refit_every`` batches (``fit_direction_thresholds`` over
      ``online_trace()``), so ``backend="recommend"`` serves alpha/beta
      tracking the live stream instead of a stale bench trace — unless
      a table was supplied explicitly, which pins it (only a manual
      ``refit_thresholds()`` call overrides a pin).

    Both loops only move iteration slots / scan layouts — results stay
    bit-identical with the learner on, off, or mid-refit — and both are
    deterministic functions of the served batch stream (same seeded
    stream => bit-identical budgets, thresholds, and mispredict
    counters, with or without ``gang_resume``).
    ``online_adapt=False`` pins the legacy static behavior (global-p90
    budget, fixed thresholds) as the differential baseline.
    """

    def __init__(
        self,
        mesh,
        csr: CSRGraph,
        max_deg: int | None = None,
        max_iters: int = 64,
        adaptive: bool = True,
        phase1_iters: int | None = None,
        max_inflight: int | None = None,
        backend="recommend",
        direction_thresholds: DirectionThresholds | str | Path | None = None,
        family: str | None = None,
        gang_resume: bool = True,
        online_adapt: bool = True,
        budget_model: BudgetModel | None = None,
        refit_every: int = 16,
        sample_window: int = 2048,
    ):
        self.mesh = mesh
        self.csr = csr
        self.max_deg = max_deg
        self.max_iters = max_iters
        self.adaptive = adaptive
        self.phase1_iters = phase1_iters  # pin the phase-1 budget (tests)
        self.max_inflight = max_inflight  # override recommend_k (tests)
        # default extension backend; per-query override via query(backend=).
        # The default IS "recommend": recommend_backend picks the scan
        # layout per batch (direction-optimized binned pull for the
        # BFS family), bit-identical to any explicit choice.
        self.backend = backend
        # fitted per-(family, degree-bucket) alpha/beta for the direction
        # switch (core.policies.fit_direction_thresholds); a path loads a
        # BENCH_direction_opt.json trace file. None = Beamer defaults.
        if isinstance(direction_thresholds, (str, Path)):
            direction_thresholds = fit_direction_thresholds(
                direction_thresholds
            )
        self.direction_thresholds = direction_thresholds
        # an explicitly supplied table is a pin: the auto-refit cadence
        # must not silently replace what the caller asked to serve (an
        # explicit refit_thresholds() call still overrides)
        self._thresholds_pinned = direction_thresholds is not None
        self.family = family  # dataset family key for threshold lookup
        self.gang_resume = gang_resume
        self.online_adapt = online_adapt
        # per-(family, source-degree-bucket) phase-1 budget learner; the
        # global deque below remains its empty-model cold path
        self.budget_model = (
            budget_model
            if budget_model is not None
            else (BudgetModel() if online_adapt else None)
        )
        self.refit_every = max(1, int(refit_every))
        self.stats = SchedulerStats()
        self.cache = EngineCache()
        self._graphs: dict[tuple, tuple] = {}  # (axes, operands) -> (ops, n_pad)
        # global pow2-p90 fallback budget (cold start / online_adapt off):
        # p90 per-morsel iteration count of recent batches — the per-bucket
        # BudgetModel supersedes it as soon as it holds samples.
        self._iter_p90s: collections.deque = collections.deque(maxlen=32)
        # per-iteration (n_f, m_f, m_u, pull-cost) samples from the phase-1
        # stats tap, grouped by the n_pad they were measured against (the
        # beta predicate compares n_f*beta to the PADDED row count)
        self._dir_samples: dict[int, collections.deque] = {}
        self._sample_window = int(sample_window)
        self._batches_since_refit = 0
        self._pending: list[tuple[str, np.ndarray]] = []
        self._next_qid = 0
        self.admissions = {"ntkms": 0, "per_query": 0}

    # ------------------------------------------------------------- engines

    def _graph_for(self, policy: MorselPolicy, spec: ExtendSpec = ExtendSpec()):
        # operand bundles are shared by every spec needing the same physical
        # structures (rev/blocks), not per backend string
        key = (
            policy.graph_axes,
            spec.needs_rev,
            spec.needs_binned,
            spec.needs_blocks,
            spec.pad_block,
        )
        if key not in self._graphs:
            # pad for mesh.size so every policy's graph shares one n_pad and
            # phase-1 state can resume on the phase-2 graph unchanged
            self._graphs[key] = prepare_graph(
                self.csr, self.mesh, policy, self.max_deg,
                pad_shards=self.mesh.size, extend=spec,
            )
        return self._graphs[key]

    def engine(
        self,
        kind: str,
        policy: MorselPolicy,
        edge_compute: str,
        n_pad: int,
        max_iters: int | None = None,
        state_layout: str = "replicated",
        extend: ExtendSpec = ExtendSpec(),
        operands=None,
        collect_stats: bool = False,
    ):
        cap = int(max_iters if max_iters is not None else self.max_iters)
        if collect_stats and kind not in ("static", "phase1"):
            raise ValueError(f"no stats tap for engine kind {kind!r}")
        key = EngineKey(
            kind, policy, edge_compute, n_pad, cap, state_layout, extend,
            collect_stats,
        )
        if operands is None and (
            extend.needs_binned or extend.needs_rev or extend.needs_blocks
        ):
            operands = self._graph_for(policy, extend)[0]
        if kind == "static":
            builder = lambda: build_engine(
                self.mesh, policy, edge_compute, n_pad, cap,
                state_layout=state_layout, extend=extend, operands=operands,
                collect_stats=collect_stats,
            )
        elif kind == "phase1":
            builder = lambda: build_engine(
                self.mesh, policy, edge_compute, n_pad, cap,
                state_layout=state_layout, sync="shard", extend=extend,
                operands=operands, collect_stats=collect_stats,
            )
        elif kind == "resume":
            builder = lambda: build_resume_engine(
                self.mesh, policy, edge_compute, n_pad, cap, extend=extend,
                operands=operands,
            )
        elif kind == "gang":
            builder = lambda: build_gang_resume_engine(
                self.mesh, policy, edge_compute, n_pad, cap, extend=extend,
                operands=operands, state_layout=state_layout,
            )
        else:
            raise ValueError(f"unknown engine kind: {kind}")
        return self.cache.get_or_build(key, builder)

    # ------------------------------------------------------------ dispatch

    def _phase1_budget(self, buckets=()) -> int:
        """Iteration cap for phase 1, pow2-quantized so the budget only
        compiles O(log max_iters) distinct phase-1 engines.

        Priority: a pinned ``phase1_iters`` bypasses learning outright;
        then the per-(family, source-degree-bucket) ``BudgetModel``
        serves the covering budget for this batch's ``buckets``; an
        empty model falls back to the global pow2 p90 of recent batches
        (the legacy path, and ``online_adapt=False``'s only path)."""
        if self.phase1_iters is not None:
            return max(1, min(self.phase1_iters, self.max_iters))
        if self.budget_model is not None:
            b = self.budget_model.budget_for(
                self.family, buckets, self.max_iters
            )
            if b is not None:
                return b
        if self._iter_p90s:
            b = _pow2ceil(int(np.median(self._iter_p90s)) + 1)
        else:
            # cold start: small-world graphs converge in a few hops
            b = (
                self.budget_model.cold_budget
                if self.budget_model is not None
                else 8
            )
        return max(4, min(b, self.max_iters))

    def _record_iters(self, iters: np.ndarray):
        if iters.size:
            self._iter_p90s.append(float(np.percentile(iters, 90)))

    def _morsel_buckets(self, sources: np.ndarray, lanes: int) -> np.ndarray:
        """pow2 source-degree bucket per REAL morsel: the budget model's
        key, from the mean out-degree of each morsel's (real) sources."""
        if len(sources) == 0:
            return np.zeros(0, np.int64)
        deg = self.csr.degrees[
            np.clip(sources, 0, self.csr.n_nodes - 1)
        ].astype(np.float64)
        n_m = -(-len(sources) // lanes)
        pad = np.full(n_m * lanes - len(sources), np.nan)
        mean = np.nanmean(
            np.concatenate([deg, pad]).reshape(n_m, lanes), axis=1
        )
        return np.asarray([degree_bucket(float(m)) for m in mean], np.int64)

    # ---------------------------------------------------- online adaptation

    def _record_samples(self, stats: np.ndarray, trips: np.ndarray,
                        n_pad: int, push_slots: int) -> None:
        """Drain one batch's phase-1 stats-tap buffer into the sample
        store: one fit-consumable record per (real morsel, iteration)."""
        store = self._dir_samples.setdefault(
            int(n_pad), collections.deque(maxlen=self._sample_window)
        )
        for i in range(stats.shape[0]):
            for j in range(int(trips[i])):
                n_f, m_f, m_u, pull = (float(v) for v in stats[i, j])
                store.append({
                    "it": j,
                    "frontier": n_f,
                    "m_frontier": m_f,
                    "m_unexplored": m_u,
                    "push_slots": float(push_slots),
                    "pull_slots_binned": None if pull < 0 else pull,
                })

    def online_trace(self) -> dict:
        """The accumulated live samples as a ``BENCH_direction_opt``-shaped
        trace document: one workload per observed n_pad (this graph's
        family/avg-degree), records under the canonical ``ell_push``
        backend key — exactly what ``fit_direction_thresholds`` consumes,
        so the offline fit of this trace IS the online refit.

        Scope: samples come from the PHASE-1 tap only — iterations a
        survivor runs past the budget (in the untapped resume/gang
        engines) are not observed, so deep-straggler tails are
        under-represented relative to a full offline bench trace (those
        tail iterations are tiny-frontier and fail the beta test, i.e.
        overwhelmingly push-side, but a resume-engine tap is the ROADMAP
        follow-on that would close the gap)."""
        return {"workloads": [
            {
                "graph": f"online_npad{n_pad}",
                "kind": self.family or "unknown",
                "n": int(self.csr.n_nodes),
                "n_pad": int(n_pad),
                "n_edges": int(self.csr.n_edges),
                "avg_degree": float(self.csr.avg_degree),
                "backends": {"ell_push": {"iterations": list(recs)}},
            }
            for n_pad, recs in sorted(self._dir_samples.items())
        ]}

    def refit_thresholds(self) -> DirectionThresholds | None:
        """Refit ``direction_thresholds`` from the accumulated live
        samples (no-op before any sample lands). ``backend="recommend"``
        serves the refitted alpha/beta on the next batch."""
        if not any(len(r) for r in self._dir_samples.values()):
            return None
        self.direction_thresholds = fit_direction_thresholds(
            self.online_trace()
        )
        self.stats.refits += 1
        return self.direction_thresholds

    def _learn(self, outcome: "QueryOutcome", buckets: np.ndarray,
               n_real: int) -> None:
        """Post-batch learning: feed the budget model (real morsels only
        — the per-bucket form of the pad-morsel guard; skipped entirely
        when ``phase1_iters`` pins the budget) and the global-p90
        fallback, then refit thresholds on the ``refit_every`` cadence."""
        iters = np.asarray(outcome.result.iterations)[:n_real]
        self._record_iters(iters)
        if (
            self.budget_model is not None
            and self.phase1_iters is None
            and n_real > 0
        ):
            self.budget_model.observe_batch(
                self.family, buckets[:n_real], iters
            )
            if outcome.hybrid:
                self.budget_model.mispredicts.count(
                    outcome.budget_too_low, outcome.budget_too_high,
                    outcome.budget_inert_slots, outcome.budget_observed,
                )
        if self.online_adapt and not self._thresholds_pinned:
            self._batches_since_refit += 1
            if self._batches_since_refit >= self.refit_every:
                self._batches_since_refit = 0
                self.refit_thresholds()

    def _run_hybrid(self, pol, ec, g, n_pad, morsels, state_layout,
                    extend=ExtendSpec(), n_real=0, buckets=()):
        """Two-phase hybrid on one morsel batch. Returns a QueryOutcome
        whose result state is bit-identical to the static engine's.

        Phase-2 dispatch: >1 survivor => one gang-scheduled multi-frontier
        resume (pow2-padded batch, per-survivor convergence masks — see the
        module docstring's gang contract); exactly 1 survivor => the serial
        per-morsel engine (no packing win to pay for); ``gang_resume=False``
        pins the serial baseline (replicated layout only — the sharded
        phase 2 IS the gang engine).

        ``n_real``/``buckets``: this batch's real (non-pad) morsel count
        and their source-degree buckets — the budget model's prediction
        key and the mispredict counters' population. Under
        ``online_adapt`` phase 1 runs the stats-tapped engine and its
        per-iteration samples land in the threshold-refit store."""
        sharded = state_layout == "sharded"
        p1, p2 = hybrid_phases(
            pol.source_axes, pol.graph_axes, lanes=pol.lanes,
            or_impl=pol.or_impl,
        )
        budget = self._phase1_budget(buckets)
        collect = bool(self.online_adapt)
        eng1 = self.engine(
            "phase1", p1, ec, n_pad, max_iters=budget,
            state_layout=state_layout, extend=extend, operands=g,
            collect_stats=collect,
        )
        t0 = time.perf_counter()
        out1 = jax.block_until_ready(eng1(g, morsels))
        t1 = time.perf_counter()
        res1, stats1 = out1 if collect else (out1, None)

        # survivor test reads ONLY the frontier leaf — and under the
        # sharded layout only a per-morsel any() reduction (the full state
        # never gathers to host; the handoff below stays on device)
        f1 = res1.state.frontier
        if sharded:
            active = np.asarray(
                jnp.any(f1 != 0, axis=tuple(range(1, f1.ndim)))
            )
        else:
            frontier1 = np.asarray(f1)
            m = frontier1.shape[0]
            active = frontier1.reshape(m, -1).any(axis=1)
        idx = np.nonzero(active)[0]
        phase_ms = {"phase1": (t1 - t0) * 1e3, "phase2": 0.0}
        iters1 = np.asarray(res1.iterations)
        n_real = int(min(n_real, iters1.shape[0]))
        too_low, too_high, inert = count_budget_mispredicts(
            budget, iters1[:n_real], active[:n_real],
            floor=(
                self.budget_model.floor
                if self.budget_model is not None
                else 4
            ),
        )
        if stats1 is not None and n_real > 0:
            self._record_samples(
                np.asarray(stats1)[:n_real], iters1[:n_real], n_pad,
                push_slots=int(np.prod(g.fwd.indices.shape)),
            )
        if idx.size == 0:
            return QueryOutcome(
                result=res1, policy=pol.name, hybrid=True, redispatched=0,
                phase_ms=phase_ms, phase1_budget=budget,
                budget_too_low=too_low, budget_too_high=too_high,
                budget_inert_slots=inert, budget_observed=n_real,
            )
        use_gang = self.gang_resume and (idx.size > 1 or sharded)

        # pad survivors to a pow2 morsel count: stable resume-trace shapes
        # (pad morsels are all-zero state => inert / zero-trip loops)
        kp = _pow2ceil(idx.size)
        sub_it = np.zeros((kp,), iters1.dtype)
        sub_it[: idx.size] = iters1[idx]

        g2, n_pad2 = self._graph_for(p2, extend)
        assert n_pad2 == n_pad, (n_pad2, n_pad)

        state1 = None
        if not sharded:
            state1 = jax.tree.map(np.asarray, res1.state)

            def pick(x):
                out = np.zeros((kp,) + x.shape[1:], np.asarray(x).dtype)
                out[: idx.size] = np.asarray(x)[idx]
                return out

            sub_state = jax.tree.map(pick, state1)
        else:
            # all-gather/slice handoff: phase-1 rows (policy graph axes)
            # -> phase-2 rows (every mesh axis), survivors gathered and
            # pow2-padded on device
            sub_state = gang_handoff(
                res1.state, idx, kp, self.mesh, p2.graph_axes
            )

        if use_gang:
            eng2 = self.engine(
                "gang", p2, ec, n_pad, state_layout=state_layout,
                extend=extend, operands=g2,
            )
            self.stats.gangs += 1
            self.stats.gang_slots += kp
        else:
            eng2 = self.engine(
                "resume", p2, ec, n_pad, extend=extend, operands=g2
            )
        res2 = jax.block_until_ready(eng2(g2, sub_state, jnp.asarray(sub_it)))
        t2 = time.perf_counter()
        phase_ms["phase2"] = (t2 - t1) * 1e3

        iters2 = np.asarray(res2.iterations)
        if sharded:
            final_state = gang_scatter_back(res1.state, res2.state, idx)
        else:
            state2 = jax.tree.map(np.asarray, res2.state)

            def put(full, sub):
                out = np.asarray(full).copy()
                out[idx] = sub[: idx.size]
                return out

            final_state = jax.tree.map(
                jnp.asarray, jax.tree.map(put, state1, state2)
            )
        final_iters = iters1.copy()
        final_iters[idx] = iters2[: idx.size]
        return QueryOutcome(
            result=IFEResult(
                state=final_state, iterations=jnp.asarray(final_iters)
            ),
            policy=pol.name, hybrid=True, redispatched=int(idx.size),
            phase_ms=phase_ms, phase1_budget=budget,
            resumed_ganged=int(idx.size) if use_gang else 0,
            resumed_serial=0 if use_gang else int(idx.size),
            gang_width=kp if use_gang else 0,
            budget_too_low=too_low, budget_too_high=too_high,
            budget_inert_slots=inert, budget_observed=n_real,
        )

    def _run_static(self, pol, ec, g, n_pad, morsels, state_layout,
                    extend=ExtendSpec(), n_real=0, buckets=()):
        eng = self.engine(
            "static", pol, ec, n_pad, state_layout=state_layout,
            extend=extend, operands=g,
        )
        t0 = time.perf_counter()
        res = jax.block_until_ready(eng(g, morsels))
        t1 = time.perf_counter()
        return QueryOutcome(
            result=res, policy=pol.name, hybrid=False, redispatched=0,
            phase_ms={"phase1": (t1 - t0) * 1e3, "phase2": 0.0},
            phase1_budget=0,
        )

    def query(
        self,
        sources,
        returns_paths: bool = False,
        policy: str | None = None,
        state_layout: str = "replicated",
        backend=None,
    ) -> QueryOutcome:
        """Serve one request batch of source nodes.

        Policy is chosen per batch via ``recommend_policy`` unless pinned;
        execution is two-phase hybrid whenever eligible (adaptive mode,
        replicated state, source-level morsels to re-dispatch).

        ``backend`` selects the frontier-extension backend for this batch
        ("ell_push" | "ell_pull" | "block_mxu" | "dopt" | an ExtendSpec;
        "recommend" applies ``recommend_backend``); None uses the
        scheduler's default. All choices are bit-identical in result.
        """
        sources = np.asarray(sources, np.int32).reshape(-1)
        name = policy or recommend_policy(
            len(sources),
            self.mesh.size,
            self.csr.avg_degree,
            returns_paths=returns_paths,
            n_nodes=self.csr.n_nodes,
        )
        pol = POLICIES[name]()
        if pol.is_multi_source:
            ec = "msbfs_parents" if returns_paths else "msbfs_lengths"
        else:
            ec = "sp_parents" if returns_paths else "sp_lengths"
        backend = backend if backend is not None else self.backend
        if backend == "recommend":
            backend = recommend_backend(
                ec, self.csr.avg_degree, n_nodes=self.csr.n_nodes,
                lanes=pol.lanes, family=self.family,
                thresholds=self.direction_thresholds,
            )
        spec = as_spec(backend)
        g, n_pad = self._graph_for(pol, spec)
        src_shards = _axes_size(self.mesh, pol.source_axes)
        morsels = pad_sources(sources, src_shards, pol.lanes, n_pad)

        use_hybrid = (
            self.adaptive
            and bool(pol.source_axes)  # nT1S has no source morsels to split
            # sharded phase 2 is the gang engine; without it, fall back to
            # the static sharded dispatch (there is no serial sharded resume)
            and (state_layout == "replicated" or self.gang_resume)
        )
        run_fn = self._run_hybrid if use_hybrid else self._run_static
        run = lambda *args, **kw: run_fn(*args, extend=spec, **kw)

        # paper Fig 13: dense graphs cap concurrent source morsels (k);
        # oversized batches run in fixed-size chunks, stitched on host.
        k = (
            self.max_inflight
            if self.max_inflight is not None
            else recommend_k(self.csr.avg_degree)
        )
        chunk = max(src_shards, k * src_shards)
        # budget learning and mispredict accounting see only the real
        # morsels: pad/inert ones exit at 0 iterations and would drag every
        # bucket's learned budget below its true convergence depth
        # (permanent re-dispatch)
        n_real = max(1, -(-len(sources) // pol.lanes))
        # buckets feed only the model's predict/observe; skip the host
        # work (degrees gather + per-morsel bucketing) when no model will
        # consume them (online_adapt off, or the budget pinned)
        buckets = (
            self._morsel_buckets(sources, pol.lanes)
            if self.budget_model is not None and self.phase1_iters is None
            else np.zeros(0, np.int64)
        )
        if morsels.shape[0] <= chunk:
            outcome = run(
                pol, ec, g, n_pad, jnp.asarray(morsels), state_layout,
                n_real=n_real, buckets=buckets,
            )
            outcome.policy = name
            self._learn(outcome, buckets, n_real)
            self.stats.record(outcome)
            return outcome

        outcomes = []
        for i in range(0, morsels.shape[0], chunk):
            part = morsels[i : i + chunk]
            if part.shape[0] < chunk:  # keep one trace shape per chunk size
                pad = np.full(
                    (chunk - part.shape[0], part.shape[1]), n_pad, np.int32
                )
                part = np.concatenate([part, pad], axis=0)
            real_in = max(0, min(chunk, n_real - i))
            outcomes.append(
                run(
                    pol, ec, g, n_pad, jnp.asarray(part), state_layout,
                    n_real=real_in, buckets=buckets[i : i + real_in],
                )
            )
        result = IFEResult(
            state=jax.tree.map(
                lambda *xs: jnp.concatenate([jnp.asarray(x) for x in xs]),
                *[o.result.state for o in outcomes],
            ),
            iterations=jnp.concatenate(
                [jnp.asarray(o.result.iterations) for o in outcomes]
            ),
        )
        outcome = QueryOutcome(
            result=result,
            policy=name,
            hybrid=any(o.hybrid for o in outcomes),
            redispatched=sum(o.redispatched for o in outcomes),
            phase_ms={
                "phase1": sum(o.phase_ms["phase1"] for o in outcomes),
                "phase2": sum(o.phase_ms["phase2"] for o in outcomes),
            },
            phase1_budget=max(o.phase1_budget for o in outcomes),
            resumed_ganged=sum(o.resumed_ganged for o in outcomes),
            resumed_serial=sum(o.resumed_serial for o in outcomes),
            gang_width=max(o.gang_width for o in outcomes),
            budget_too_low=sum(o.budget_too_low for o in outcomes),
            budget_too_high=sum(o.budget_too_high for o in outcomes),
            budget_inert_slots=sum(o.budget_inert_slots for o in outcomes),
            budget_observed=sum(o.budget_observed for o in outcomes),
        )
        self._learn(outcome, buckets, n_real)
        self.stats.record(outcome)
        return outcome

    # ----------------------------------------------------------- admission

    def submit(self, sources, qid: str | None = None) -> str:
        """Queue one tenant's query for the next ``flush``."""
        if qid is None:
            qid = f"q{self._next_qid}"
            self._next_qid += 1
        self._pending.append(
            (qid, np.asarray(sources, np.int32).reshape(-1))
        )
        return qid

    def flush(self) -> dict[str, np.ndarray]:
        """Run all queued queries; returns {qid: levels [k, n_nodes] int32}
        (-1 = unreached), one row per submitted source.

        Admission rule (paper Fig 14): pack every tenant's sources into
        shared 64-wide MS-BFS lane morsels only when ``recommend_policy``
        says the pooled batch saturates the lanes; otherwise each query
        runs by itself under the hybrid (packing with too few sources
        would scan the graph for mostly-empty lanes).
        """
        if not self._pending:
            return {}
        pending, self._pending = self._pending, []
        qids = [q for q, _ in pending]
        srcs = [s for _, s in pending]
        all_src = np.concatenate(srcs) if srcs else np.zeros(0, np.int32)
        n = self.csr.n_nodes
        name = recommend_policy(
            len(all_src), self.mesh.size, self.csr.avg_degree,
            n_nodes=n,
        )
        out: dict[str, np.ndarray] = {}
        if name == "ntkms":
            self.admissions["ntkms"] += 1
            outcome = self.query(all_src, policy="ntkms")
            lanes = np.asarray(outcome.result.state.levels)  # [m, n_pad, L]
            L = lanes.shape[-1]
            per_src = (
                lanes[:, :n, :].transpose(0, 2, 1).reshape(-1, n)
            ).astype(np.int32)
            per_src[per_src == 255] = -1
            i = 0
            for qid, s in zip(qids, srcs):
                out[qid] = per_src[i : i + len(s)]
                i += len(s)
        else:
            self.admissions["per_query"] += 1
            for qid, s in zip(qids, srcs):
                outcome = self.query(s)
                out[qid] = np.asarray(outcome.result.state.levels)[
                    : len(s), :n
                ].astype(np.int32)
        return out
