"""Admission layer of the serving core: queue, quotas, deadline-aware
lane packing, load shedding.

Top layer of the three-layer runtime (see docs/serving.md): everything
about WHO runs and in WHICH morsel pack is decided here, before the
dispatch layer (runtime/dispatch.py) ever sees a batch. The paper's Fig 14
admission rule — pool every tenant's sources into shared 64-wide MS-BFS
lane morsels only when ``recommend_policy`` says the pooled batch
saturates the lanes — is kept verbatim; what this module adds around it is
the serving policy:

- **Tenant quotas** (``tenant_quota``): a cap on each tenant's concurrent
  (queued + in-flight) queries. Submissions over quota are *shed* at
  admission — the open-loop stream keeps arriving whether or not we are
  keeping up, so one tenant's burst must not grow the shared queue without
  bound (Hauck et al.: inter-query parallelism has to be throttled jointly
  with intra-query width).

- **Deadline-aware lane packing with eviction**: a packed MS-BFS batch
  finishes when its SLOWEST lane converges, so a tight-deadline query
  packed next to a deep one inherits the deep query's completion time.
  When the runtime has a warm latency estimate (the dispatch layer's
  learned per-bucket depth × the serving loop's measured ms-per-iteration
  EWMA), ``plan()`` predicts the pack's slowest-lane time and EVICTS any
  member whose deadline slack cannot survive it — the evictee re-packs as
  its own solo batch (``core.msbfs.LanePacker.evict`` is a pure deletion:
  the survivors keep arrival order, so their rows are untouched).

- **Load shedding**: a query is dropped (never executed, reported shed)
  when its deadline has already expired at plan time, or when even a solo
  batch is predicted to blow it — running it would only steal capacity
  from queries that can still make their SLOs. Quota/queue-full rejections
  are shed at submit time. Sheds are never silent: every one lands in
  ``AdmissionStats`` with its reason and in the submitter's ticket.

Determinism: admission decisions are a pure function of (submission
order, quotas, the injected ``clock`` readings, and the dispatch layer's
learned state). With no deadlines and no quotas — the synchronous façade's
configuration — ``plan()`` reproduces the legacy ``flush`` batching
bit-for-bit: same pooled policy decision, same arrival-order source
concatenation, same per-query spans. The seeded-replay lock in
tests/test_serving.py pins this.

Supported jax range: 0.4.35 — 0.8.x (host-side module; no jax imports).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import numpy as np

from ..core import QUERY_KINDS, recommend_policy
from ..core.msbfs import LanePacker

# shed reasons (AdmissionTicket.shed_reason / AdmissionStats.sheds_by_reason)
SHED_QUOTA = "quota"  # tenant over its concurrent-query quota
SHED_QUEUE_FULL = "queue_full"  # global queue cap reached
SHED_EXPIRED = "expired"  # deadline already passed when planning began
SHED_HOPELESS = "hopeless"  # even a solo batch is predicted to miss


@dataclasses.dataclass
class AdmittedQuery:
    """One queued query: sources + tenant + its absolute deadline (clock
    seconds; None = no SLO)."""

    qid: str
    tenant: str
    sources: np.ndarray
    t_submit: float
    t_deadline: float | None = None
    query_kind: str = "reach"


@dataclasses.dataclass
class AdmissionTicket:
    """What ``submit`` hands back: admitted (queued), shed (with reason),
    or instantly done (zero-source queries complete at admission — there
    is nothing to traverse, and the result shape is known)."""

    qid: str
    admitted: bool
    shed_reason: str | None = None
    done: bool = False


@dataclasses.dataclass
class PlannedBatch:
    """One dispatch-ready batch: flat sources in arrival order + per-query
    row spans into the lane-major result rows. ``policy`` is "ntkms" for
    the shared lane pack, None for a solo batch (the dispatch layer's
    ``recommend_policy`` decides, exactly as the legacy per-query path)."""

    queries: list[AdmittedQuery]
    sources: np.ndarray
    spans: dict[str, tuple[int, int]]
    packed: bool
    policy: str | None
    query_kind: str = "reach"


@dataclasses.dataclass
class AdmissionPlan:
    """One ``plan()`` round: batches to dispatch (packed batch first, then
    evicted/solo batches in arrival order), instantly-complete results
    (zero-source), and the queries shed this round."""

    batches: list[PlannedBatch]
    instant: dict[str, np.ndarray]
    shed: list[tuple[str, str]]  # (qid, reason)


@dataclasses.dataclass
class AdmissionStats:
    submitted: int = 0
    admitted: int = 0
    shed: int = 0
    evictions: int = 0  # pulled out of the shared pack to a solo batch
    zero_source: int = 0
    sheds_by_reason: collections.Counter = dataclasses.field(
        default_factory=collections.Counter
    )


class AdmissionQueue:
    """Multi-tenant admission queue over one graph.

    ``depth_hint(sources, lanes)`` and ``ms_per_iter()`` are the dispatch/
    service layers' latency estimators (learned convergence depth, measured
    ms per iteration). Either returning None disables deadline
    eviction/shedding for that plan round — cold admission must not evict
    on a guess, and the no-estimator configuration is exactly the legacy
    deterministic batching.

    ``max_batch_sources`` bounds one plan round's packed pool (saxml-style
    bucketed batching): when set, ``plan()`` serves the arrival-order
    prefix of the queue whose pooled sources fit the cap and leaves the
    rest queued for the next round. A bounded batch bounds the serving
    loop's admission granularity — a query never waits behind more than
    one capped batch before it can join a pack, which is what keeps the
    tail latency of an always-on stream at O(batch) instead of
    O(backlog). ``None`` (default) keeps the legacy whole-queue pooling.

    ``clock`` is injectable so replay tests drive admission with a manual
    clock (determinism lock); it is read only at submit/plan, never inside
    dispatch."""

    def __init__(
        self,
        n_nodes: int,
        n_devices: int,
        avg_degree: float,
        lanes: int = 64,
        tenant_quota: int | None = None,
        max_queue: int | None = None,
        max_batch_sources: int | None = None,
        depth_hint: Callable[[np.ndarray, int], int | None] | None = None,
        ms_per_iter: Callable[[], float | None] | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.n_nodes = int(n_nodes)
        self.n_devices = int(n_devices)
        self.avg_degree = float(avg_degree)
        self.lanes = int(lanes)
        self.tenant_quota = tenant_quota
        self.max_queue = max_queue
        self.max_batch_sources = max_batch_sources
        self.depth_hint = depth_hint
        self.ms_per_iter = ms_per_iter
        self.clock = clock
        self.stats = AdmissionStats()
        self._queue: list[AdmittedQuery] = []
        self._instant: list[tuple[str, np.ndarray]] = []
        self._active: dict[str, str] = {}  # qid -> tenant (queued or in-flight)
        self._active_by_tenant: collections.Counter = collections.Counter()
        self._next_qid = 0

    # ------------------------------------------------------------- submit

    def pending(self) -> int:
        """Queries queued for the next plan round (instant results count:
        they still need a plan round to be delivered)."""
        return len(self._queue) + len(self._instant)

    def in_flight(self, tenant: str | None = None) -> int:
        """Admitted-but-not-completed queries (queued + dispatched)."""
        if tenant is None:
            return len(self._active)
        return self._active_by_tenant[tenant]

    def submit(
        self,
        sources,
        tenant: str = "default",
        deadline_ms: float | None = None,
        qid: str | None = None,
        now: float | None = None,
        query_kind: str = "reach",
    ) -> AdmissionTicket:
        """Admit (or shed) one query. ``deadline_ms`` is the SLO relative
        to submission; it becomes an absolute clock deadline here. A
        duplicate qid among admitted-but-uncompleted queries is a caller
        bug (two results would race for one key) and raises.

        ``query_kind`` names the scenario family (``core.QUERY_KINDS``);
        kinds whose edge compute has no saturating lane form
        (``lanes_ok=False``) are admitted normally but never join the
        shared MS-BFS lane pack — ``plan()`` always serves them solo."""
        if query_kind not in QUERY_KINDS:
            raise ValueError(
                f"unknown query_kind: {query_kind!r} "
                f"(known: {sorted(QUERY_KINDS)})"
            )
        self.stats.submitted += 1
        if qid is None:
            qid = f"q{self._next_qid}"
            self._next_qid += 1
        if qid in self._active:
            raise ValueError(f"duplicate qid: {qid!r} is already in flight")
        sources = np.asarray(sources, np.int32).reshape(-1)
        if len(sources) == 0:
            # nothing to traverse: complete at admission with the empty
            # (0, n_nodes) levels block a zero-row span would produce
            self.stats.admitted += 1
            self.stats.zero_source += 1
            self._instant.append(
                (qid, np.zeros((0, self.n_nodes), np.int32))
            )
            return AdmissionTicket(qid, admitted=True, done=True)
        if (
            self.max_queue is not None
            and len(self._queue) >= self.max_queue
        ):
            return self._shed_ticket(qid, SHED_QUEUE_FULL)
        if (
            self.tenant_quota is not None
            and self._active_by_tenant[tenant] >= self.tenant_quota
        ):
            return self._shed_ticket(qid, SHED_QUOTA)
        now = self.clock() if now is None else now
        t_deadline = None
        if deadline_ms is not None:
            if deadline_ms <= 0:  # expired before it was even queued
                return self._shed_ticket(qid, SHED_EXPIRED)
            t_deadline = now + deadline_ms / 1e3
        self.stats.admitted += 1
        self._active[qid] = tenant
        self._active_by_tenant[tenant] += 1
        self._queue.append(
            AdmittedQuery(qid, tenant, sources, now, t_deadline, query_kind)
        )
        return AdmissionTicket(qid, admitted=True)

    def _shed_ticket(self, qid: str, reason: str) -> AdmissionTicket:
        self.stats.shed += 1
        self.stats.sheds_by_reason[reason] += 1
        return AdmissionTicket(qid, admitted=False, shed_reason=reason)

    def complete(self, qid: str) -> None:
        """Release one query's quota slot (result delivered or shed after
        admission)."""
        tenant = self._active.pop(qid, None)
        if tenant is not None:
            self._active_by_tenant[tenant] -= 1

    # --------------------------------------------------------------- plan

    def _predicted_ms(self, sources: np.ndarray, lanes: int,
                      rate: float | None) -> float | None:
        if rate is None or self.depth_hint is None:
            return None
        depth = self.depth_hint(sources, lanes)
        return None if depth is None else depth * rate

    def plan(self, now: float | None = None) -> AdmissionPlan:
        """Drain the queue into dispatch-ready batches.

        Paper Fig 14 rule first: one pooled ``recommend_policy`` decision
        over every queued source. If the pool saturates the 64-wide lanes
        the queries pack into ONE shared MS-BFS batch — then the deadline
        pass predicts the pack's slowest-lane completion and evicts/sheds
        members that cannot survive it (see module docstring). Otherwise
        every query is its own solo batch, in arrival order."""
        now = self.clock() if now is None else now
        instant = dict(self._instant)
        self._instant.clear()
        queue, self._queue = self._queue, []
        shed: list[tuple[str, str]] = []

        def shed_query(q: AdmittedQuery, reason: str) -> None:
            self.stats.shed += 1
            self.stats.sheds_by_reason[reason] += 1
            self.complete(q.qid)
            shed.append((q.qid, reason))

        # drop queries whose deadline has already passed: executing them
        # cannot produce an in-SLO answer, only queueing delay for others.
        # >= — a ticket planned AT its exact deadline instant is expired
        # (the deadline is "done strictly before t"): with an injected
        # clock the boundary is deterministic, matching submit-time's
        # `deadline_ms <= 0` shed instead of racing past it
        live: list[AdmittedQuery] = []
        for q in queue:
            if q.t_deadline is not None and now >= q.t_deadline:
                shed_query(q, SHED_EXPIRED)
            else:
                live.append(q)
        if not live:
            return AdmissionPlan([], instant, shed)

        if self.max_batch_sources is not None and len(live) > 1:
            # bounded batch: serve the arrival-order prefix that fits the
            # cap (always at least one query), requeue the rest — the
            # driver's next pump re-plans them, after new arrivals had a
            # chance to join the queue
            k, pooled = 1, len(live[0].sources)
            while (
                k < len(live)
                and pooled + len(live[k].sources) <= self.max_batch_sources
            ):
                pooled += len(live[k].sources)
                k += 1
            self._queue = live[k:] + self._queue
            live = live[:k]

        # kinds without a lane form are carved out BEFORE the Fig 14
        # pooling decision: a burst of (say) weighted top-k or ppr sources
        # can neither be lane-packed itself nor tip the reach pool's
        # recommend_policy into ntkms on its behalf — they always dispatch
        # as solo batches (the dispatch layer re-checks the same
        # ``lanes_ok`` bit, so a bypassing caller still cannot lane-pack)
        poolable = [q for q in live if QUERY_KINDS[q.query_kind].lanes_ok]
        forced_solo = {
            q.qid for q in live if not QUERY_KINDS[q.query_kind].lanes_ok
        }
        total = sum(len(q.sources) for q in poolable)
        policy = (
            recommend_policy(
                total, self.n_devices, self.avg_degree, n_nodes=self.n_nodes
            )
            if poolable
            else None
        )
        batches: list[PlannedBatch] = []
        solo: list[AdmittedQuery] = []
        if policy == "ntkms":
            packer = LanePacker(self.lanes)
            by_qid = {q.qid: q for q in poolable}
            for q in poolable:
                packer.add(q.qid, q.sources)
            rate = self.ms_per_iter() if self.ms_per_iter else None
            # eviction fixpoint: a packed batch finishes with its SLOWEST
            # lane, so the pack estimate is the max over the members' solo
            # depth estimates; pulling the deepest member out lowers it,
            # so re-check until no member violates its slack
            # (arrival-order scan => determinism)
            while len(packer):
                ests = {
                    qid: self._predicted_ms(by_qid[qid].sources, 1, rate)
                    for qid in packer.qids
                }
                if any(v is None for v in ests.values()):
                    break  # cold: no estimate, no eviction
                pack_ms = max(ests.values())
                evicted = None
                for qid in packer.qids:
                    q = by_qid[qid]
                    if q.t_deadline is None:
                        continue
                    slack_ms = (q.t_deadline - now) * 1e3
                    if slack_ms < pack_ms:
                        evicted = q
                        break
                if evicted is None:
                    break
                packer.evict(evicted.qid)
                solo_ms = ests[evicted.qid]
                slack_ms = (evicted.t_deadline - now) * 1e3
                if solo_ms is not None and slack_ms < solo_ms:
                    # even alone it cannot make its deadline: shed instead
                    # of burning a solo batch on a guaranteed miss
                    shed_query(evicted, SHED_HOPELESS)
                else:
                    self.stats.evictions += 1
                    solo.append(evicted)
            if len(packer):
                flat, spans = packer.pack()
                batches.append(PlannedBatch(
                    queries=[by_qid[qid] for qid in packer.qids],
                    sources=flat, spans=spans, packed=True, policy="ntkms",
                ))
        else:
            solo = poolable
        # solo batches in arrival order, evictees keeping their original
        # queue position; forced-solo kinds interleave by the same rule
        solo_qids = {q.qid for q in solo} | forced_solo
        for q in live:  # arrival order
            if q.qid not in solo_qids:
                continue
            batches.append(PlannedBatch(
                queries=[q], sources=q.sources,
                spans={q.qid: (0, len(q.sources))}, packed=False,
                policy=None, query_kind=q.query_kind,
            ))
        return AdmissionPlan(batches, instant, shed)
