"""Fault-tolerant training runtime: restartable loop + straggler detection.

At thousand-node scale the framework must assume nodes WILL fail:
- ``TrainGuard.run`` wraps the step loop with checkpoint-every-N, crash
  resume from the latest manifest, and bounded retry on transient step
  failures (on a real pod: preemption signals / ICI timeouts surface as
  exceptions from the step function).
- ``StragglerDetector`` keeps an EWMA of step wall-time; a step slower than
  ``threshold × ewma`` flags a straggler incident. On TPU pods the action is
  to report the slow host for the controller to hot-swap; here the hook
  records incidents (and the decision logic is unit-tested with simulated
  timings).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.2  # EWMA coefficient
    threshold: float = 2.5  # step slower than threshold×ewma => incident
    warmup: int = 5  # ignore the first steps (compile)
    ewma: float = 0.0
    n: int = 0
    incidents: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.n += 1
        if self.n <= self.warmup:
            # seed the EWMA from the first sample ONLY — seeding and then
            # EWMA-ing the same sample would weight it twice
            if self.ewma == 0:
                self.ewma = dt
            else:
                self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
            return False
        flagged = dt > self.threshold * self.ewma and self.ewma > 0
        if flagged:
            self.incidents.append((step, dt, self.ewma))
            log.warning(
                "straggler: step %d took %.3fs (ewma %.3fs)", step, dt,
                self.ewma,
            )
            # clamped update: the baseline still adapts under a persistent
            # slow regime (otherwise every later step flags forever), but
            # one outlier can pull it up by at most the flag bar itself
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * min(
                dt, self.threshold * self.ewma
            )
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return flagged


@dataclasses.dataclass
class TrainGuard:
    """Restartable step loop with periodic checkpointing."""

    ckpt: Any  # CheckpointManager
    save_every: int = 100
    max_retries: int = 3
    detector: Optional[StragglerDetector] = None

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Any],
        n_steps: int,
        start_step: int = 0,
    ):
        """Runs step_fn(state, step) -> state for steps [start, n_steps),
        checkpointing every ``save_every``. Transient exceptions restore the
        latest checkpoint and retry (bounded)."""
        step = start_step
        retries = 0
        while step < n_steps:
            try:
                t0 = time.monotonic()
                state = step_fn(state, step)
                dt = time.monotonic() - t0
                if self.detector is not None:
                    self.detector.observe(step, dt)
                step += 1
                retries = 0
                if step % self.save_every == 0 or step == n_steps:
                    self.ckpt.save(step, state)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # transient node failure path
                retries += 1
                log.error("step %d failed (%s); retry %d/%d", step, e,
                          retries, self.max_retries)
                if retries > self.max_retries:
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, step = self.ckpt.restore(state)[0], latest
        self.ckpt.wait()
        return state, step
