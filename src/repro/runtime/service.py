"""Service layer of the serving core: the always-on overlapped loop.

Bottom of the three-layer runtime (see docs/serving.md): the admission
layer (runtime/admission.py) decides who runs in which morsel pack, the
dispatch layer (runtime/dispatch.py) executes one batch, and this module
keeps the machine *busy* across batches. ``ServingLoop`` is the paper's
robustness story made continuous: an open-loop arrival stream is admitted,
packed, dispatched, and accounted per tenant, with batch i's deferred host
work overlapped against batch i+1's device work.

**The overlap.** The dispatch layer's split-phase API makes one batch three
steps: ``begin_batch`` (jax async dispatch of phase 1 — device futures,
host returns immediately), ``settle_batch`` (device sync points + phase-2
re-dispatch + learning), ``finalize_batch`` (deferred host materialization:
state transfers and the survivor stitch). The loop pipelines them
double-buffered — at most one settled-but-unfinalized batch rides behind
the in-flight one:

    begin(i)            # device starts scanning batch i
    finalize(i-1)       # host stitches batch i-1 while the device runs
    settle(i)           # host blocks on batch i

so the host-side result materialization (the dominant non-device cost of a
served batch) is hidden behind phase-1 compute, and the phase-1 buffers
batch i-1 consumed are dropped (donated) the moment its stitch completes.
Learning order is untouched — ``settle(i)`` still precedes ``begin(i+1)``,
so budgets/thresholds/results are bit-identical to the synchronous façade
on the same admission order (``overlap=False`` runs the same code strictly
serially; the replay lock in tests/test_serving.py compares the two).

**Telemetry.** Per-tenant submitted/completed/shed/deadline-miss counters
and latency records, split warm/cold: a batch that compiled a new engine
(EngineCache miss during its dispatch) is a *cold* batch, its wall is
compile time, and the queries it served are excluded from warm percentiles
— the serving tail must not be reported as compile time (the p99 fix this
layer exists to make honest). ``overlap_occupancy`` reports how many
finalizes actually hid behind a later batch's device work.

Supported jax range: 0.4.35 — 0.8.x (see repro.compat / repro.launch.mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from ..core import QUERY_KINDS
from .admission import AdmissionQueue, AdmissionTicket, PlannedBatch
from .dispatch import QueryDispatcher, SettledBatch


def unpack_levels(
    levels: np.ndarray,
    spans: dict[str, tuple[int, int]],
    n_nodes: int,
    packed: bool,
) -> dict[str, np.ndarray]:
    """Per-query result rows out of one batch's levels tensor.

    Packed (nTkMS) batches carry levels as [morsels, n_pad, lanes] uint8
    with 255 = unreached: lane-major flatten to one row per source, map the
    sentinel to -1, slice each query's span. Solo batches carry [rows,
    n_pad] with one row per source already. Both slice off graph padding
    columns. This is the single extraction path shared by the synchronous
    façade's ``flush`` and the serving loop — bit-identical by
    construction."""
    n = n_nodes
    levels = np.asarray(levels)
    if packed:
        per_src = (
            levels[:, :n, :].transpose(0, 2, 1).reshape(-1, n)
        ).astype(np.int32)
        per_src[per_src == 255] = -1
        return {qid: per_src[a:b] for qid, (a, b) in spans.items()}
    return {
        qid: levels[a:b, :n].astype(np.int32)
        for qid, (a, b) in spans.items()
    }


def _pctl(values: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(values), p)) if values else float("nan")


@dataclasses.dataclass
class TenantStats:
    """One tenant's serving record. ``latencies_ms`` is every completed
    query (submit -> result delivered); ``warm_latencies_ms`` excludes
    queries served by a cold (engine-compiling) batch — SLO percentiles
    read the warm list."""

    submitted: int = 0
    completed: int = 0
    shed: int = 0
    deadline_misses: int = 0
    latencies_ms: list = dataclasses.field(default_factory=list)
    warm_latencies_ms: list = dataclasses.field(default_factory=list)

    def p50(self, warm: bool = True) -> float:
        return _pctl(self.warm_latencies_ms if warm else self.latencies_ms, 50)

    def p99(self, warm: bool = True) -> float:
        return _pctl(self.warm_latencies_ms if warm else self.latencies_ms, 99)


@dataclasses.dataclass
class ServingStats:
    """Loop-level counters. A *finalize* is one batch's deferred host
    materialization; it is *overlapped* when it ran while a later batch's
    phase 1 was in flight on device. ``cold_ms`` accumulates the wall of
    compiling batches — the cold-start cost reported separately from warm
    percentiles."""

    batches: int = 0
    cold_batches: int = 0
    finalizes: int = 0
    overlapped_finalizes: int = 0
    cold_ms: float = 0.0
    deltas_applied: int = 0  # graph mutations served mid-stream
    tenants: dict = dataclasses.field(default_factory=dict)

    @property
    def overlap_occupancy(self) -> float:
        """Fraction of finalizes hidden behind a later batch's device
        work (0.0 in synchronous mode / single-batch streams)."""
        return (
            self.overlapped_finalizes / self.finalizes
            if self.finalizes
            else 0.0
        )

    def tenant(self, name: str) -> TenantStats:
        return self.tenants.setdefault(name, TenantStats())

    def _all(self, warm: bool) -> list:
        out: list = []
        for ts in self.tenants.values():
            out.extend(ts.warm_latencies_ms if warm else ts.latencies_ms)
        return out

    def p50(self, warm: bool = True) -> float:
        return _pctl(self._all(warm), 50)

    def p99(self, warm: bool = True) -> float:
        return _pctl(self._all(warm), 99)

    @property
    def completed(self) -> int:
        return sum(ts.completed for ts in self.tenants.values())

    @property
    def shed(self) -> int:
        return sum(ts.shed for ts in self.tenants.values())

    @property
    def deadline_misses(self) -> int:
        return sum(ts.deadline_misses for ts in self.tenants.values())


class ServingLoop:
    """Always-on serving loop over one graph: open-loop admission in,
    per-tenant results + SLO telemetry out.

    ``overlap=True`` (default) runs the double-buffered pipeline described
    in the module docstring; ``overlap=False`` is the strictly serial
    baseline (begin/settle/finalize back-to-back per batch) used as the
    differential side of the replay lock and the synchronous-flush
    baseline in benchmarks/serving_slo.py.

    ``max_batch_sources`` (forwarded to the admission queue) bounds one
    batch's pooled sources: under backlog the queue then drains as a
    SEQUENCE of capped batches with re-admission between them, so a new
    arrival joins the next batch's lane packing instead of waiting for
    the whole backlog — the knob that keeps an always-on stream's tail
    at O(batch) instead of O(backlog), and the pipeline fed with real
    inter-batch boundaries to overlap.

    ``clock`` is injectable (shared with the admission queue) so replay
    tests drive deadlines with a manual clock; ``on_result`` fires once
    per delivered query — submissions from inside the callback are legal
    and join the next plan round (the flush-during-drain path)."""

    def __init__(
        self,
        mesh=None,
        csr=None,
        *,
        dispatcher: QueryDispatcher | None = None,
        overlap: bool = True,
        tenant_quota: int | None = None,
        max_queue: int | None = None,
        max_batch_sources: int | None = None,
        clock: Callable[[], float] = time.perf_counter,
        on_result: Callable[[str, np.ndarray], None] | None = None,
        **dispatcher_kw,
    ):
        if dispatcher is None:
            # serving default: pow2-pad morsel counts so the stream's
            # variable pool sizes hit a bounded, pre-warmable set of
            # compiled shapes (one-shot query paths keep exact shapes)
            dispatcher_kw.setdefault("pad_pow2_morsels", True)
            dispatcher = QueryDispatcher(mesh, csr, **dispatcher_kw)
        self.dispatcher = dispatcher
        self.overlap = overlap
        self.clock = clock
        self.on_result = on_result
        self.admission = AdmissionQueue(
            n_nodes=dispatcher.csr.n_nodes,
            n_devices=dispatcher.mesh.size,
            avg_degree=dispatcher.csr.avg_degree,
            tenant_quota=tenant_quota,
            max_queue=max_queue,
            max_batch_sources=max_batch_sources,
            depth_hint=dispatcher.depth_hint,
            ms_per_iter=lambda: self._ms_per_iter,
            clock=clock,
        )
        self.stats = ServingStats()
        self.results: dict[str, np.ndarray] = {}
        # (settled batch, its plan entry, begin time, cold?) — the one
        # settled-but-unfinalized batch the pipeline carries
        self._tail: tuple[SettledBatch, PlannedBatch, float, bool] | None = None
        # measured serving rate for the admission layer's deadline math:
        # EWMA of warm-batch wall per slowest-lane iteration
        self._ms_per_iter: float | None = None
        # submit-time record per in-flight qid: (tenant, t_submit, t_deadline)
        self._meta: dict[str, tuple[str, float, float | None]] = {}
        # DeltaReports of every apply_delta served by this loop, in order
        self.delta_reports: list = []

    @property
    def graph_version(self) -> int:
        """The dispatcher's current ``operands_version`` (0 = unmutated)."""
        return self.dispatcher.operands_version

    # ------------------------------------------------------------- intake

    def submit(
        self,
        sources,
        tenant: str = "default",
        deadline_ms: float | None = None,
        qid: str | None = None,
        query_kind: str = "reach",
    ) -> AdmissionTicket:
        """Admit one query into the stream (see AdmissionQueue.submit).
        Shed submissions are counted against the tenant and never run.

        ``query_kind`` selects the scenario family (``core.QUERY_KINDS``):
        "reach" delivers per-source level rows as before; other kinds
        deliver their own result leaves — a [rows, n(, k)] array for
        single-leaf kinds ("topk_paths" dists, "ppr" mass), a dict of
        such arrays for multi-leaf kinds ("pattern_counts")."""
        now = self.clock()
        ticket = self.admission.submit(
            sources, tenant=tenant, deadline_ms=deadline_ms, qid=qid,
            now=now, query_kind=query_kind,
        )
        ts = self.stats.tenant(tenant)
        ts.submitted += 1
        if not ticket.admitted:
            ts.shed += 1
        else:
            t_deadline = (
                now + deadline_ms / 1e3 if deadline_ms is not None else None
            )
            self._meta[ticket.qid] = (tenant, now, t_deadline)
        return ticket

    # ------------------------------------------------------------ pipeline

    def pump(self) -> int:
        """One plan round: drain the admission queue into batches and push
        them through the pipeline. Returns the number of batches
        dispatched. The pipeline tail (the last settled batch) stays
        unfinalized so the NEXT pump's first batch can overlap it —
        ``drain()`` flushes it when the stream ends."""
        plan = self.admission.plan(now=self.clock())
        for qid, levels in plan.instant.items():
            self._deliver(qid, levels, cold=False)
        for qid, reason in plan.shed:
            meta = self._meta.pop(qid, None)
            if meta is not None:
                self.stats.tenant(meta[0]).shed += 1
        for pb in plan.batches:
            self._dispatch(pb)
        return len(plan.batches)

    def _dispatch(self, pb: PlannedBatch) -> None:
        t0 = self.clock()
        compiles0 = self.dispatcher.cache.compile_events
        inflight = self.dispatcher.begin_batch(
            pb.sources, policy=pb.policy, query_kind=pb.query_kind,
        )
        if self._tail is not None and self.overlap:
            # batch i's phase 1 is now in flight on device: the host is
            # free to stitch batch i-1 — the overlap this loop exists for
            self._finalize_tail(overlapped=True)
        settled = self.dispatcher.settle_batch(inflight)
        # compile_events (builds + first-seen morsel shapes), not misses:
        # a cached engine retracing on a new morsel count stalls this
        # batch on XLA exactly like a fresh build would
        cold = self.dispatcher.cache.compile_events > compiles0
        self.stats.batches += 1
        if cold:
            self.stats.cold_batches += 1
        self._tail = (settled, pb, t0, cold)
        if not self.overlap:
            self._finalize_tail(overlapped=False)

    def _finalize_tail(self, overlapped: bool) -> None:
        settled, pb, t0, cold = self._tail
        self._tail = None
        outcome = settled.finalize()
        t1 = self.clock()
        self.stats.finalizes += 1
        if overlapped:
            self.stats.overlapped_finalizes += 1
        wall_ms = (t1 - t0) * 1e3
        iters = np.asarray(outcome.result.iterations)
        depth = float(iters.max()) if iters.size else 0.0
        if cold:
            self.stats.cold_ms += wall_ms
        elif depth > 0:
            rate = wall_ms / depth
            self._ms_per_iter = (
                rate
                if self._ms_per_iter is None
                else 0.5 * self._ms_per_iter + 0.5 * rate
            )
        n = self.dispatcher.csr.n_nodes
        if pb.query_kind == "reach":
            out = unpack_levels(
                np.asarray(outcome.result.state.levels), pb.spans,
                n, pb.packed,
            )
        else:
            # non-reach kinds are never lane-packed (admission's lanes_ok
            # carve-out), so the state leaves are already one row per
            # source: slice each query's span and the graph padding off
            # every result leaf the kind declares
            assert not pb.packed, pb.query_kind
            leaves = QUERY_KINDS[pb.query_kind].result_leaves
            arrs = {
                leaf: np.asarray(getattr(outcome.result.state, leaf))
                for leaf in leaves
            }
            out = {
                qid: (
                    arrs[leaves[0]][a:b, :n]
                    if len(leaves) == 1
                    else {
                        leaf: arrs[leaf][a:b, :n] for leaf in leaves
                    }
                )
                for qid, (a, b) in pb.spans.items()
            }
        for q in pb.queries:
            self._deliver(q.qid, out[q.qid], cold)

    def _deliver(self, qid: str, levels: np.ndarray, cold: bool) -> None:
        t_done = self.clock()
        tenant, t_sub, t_deadline = self._meta.pop(
            qid, ("default", t_done, None)
        )
        ts = self.stats.tenant(tenant)
        ts.completed += 1
        lat_ms = (t_done - t_sub) * 1e3
        ts.latencies_ms.append(lat_ms)
        if not cold:
            ts.warm_latencies_ms.append(lat_ms)
        if t_deadline is not None and t_done > t_deadline:
            ts.deadline_misses += 1
        self.results[qid] = levels
        self.admission.complete(qid)
        if self.on_result is not None:
            self.on_result(qid, levels)

    # ------------------------------------------------------------ mutation

    def apply_delta(self, delta):
        """Mutate the served graph mid-stream, with a defined fence:
        every query admitted BEFORE this call is planned, dispatched and
        settled against the pre-delta graph (the queue drains through
        the normal pipeline first), and every query admitted after sees
        the post-delta graph — no batch is ever torn across versions
        (the dispatcher additionally pins each in-flight batch's operand
        buffers at begin time, so even the overlapped pipeline can never
        mix graphs inside one batch). The settled-but-unfinalized
        pipeline tail may ride through the delta: its device work is
        already complete against the old buffers, which its payload
        keeps alive until the stitch.

        Same-shape deltas keep every compiled engine warm — the serving
        stream sees a buffer swap, not a cold start. Returns the
        dispatcher's ``DeltaReport``."""
        while self.admission.pending():
            self.pump()
        report = self.dispatcher.apply_delta(delta)
        # stale-state sweep: the admission planner's pooled-policy and
        # deadline math key on avg_degree, captured at construction —
        # refresh it against the mutated graph
        self.admission.avg_degree = float(self.dispatcher.csr.avg_degree)
        self.stats.deltas_applied += 1
        self.delta_reports.append(report)
        return report

    # ------------------------------------------------------------- driving

    def drain(self) -> dict[str, np.ndarray]:
        """Serve until the queue is empty and the pipeline tail is
        finalized. Queries submitted from ``on_result`` mid-drain join the
        stream and are served before drain returns."""
        while self.admission.pending() or self._tail is not None:
            if self.admission.pending():
                self.pump()
            elif self._tail is not None:
                self._finalize_tail(overlapped=False)
        return self.results

    def run_stream(self, arrivals: list[dict]) -> dict[str, np.ndarray]:
        """Serve an open-loop arrival schedule: each entry is a dict with
        ``t_ms`` (offset from stream start) and either ``sources`` (a
        query arrival, with optional ``tenant`` / ``deadline_ms`` /
        ``qid``) or ``delta`` (a ``GraphDelta`` mutation applied at its
        scheduled time through ``apply_delta``'s version fence — queries
        scheduled before it are served on the old graph, after it on the
        new). Arrivals are admitted when their time comes whether or not
        the loop is keeping up — queueing delay under overload is the
        point of open-loop measurement — and the stream is drained at
        the end."""
        order = sorted(range(len(arrivals)), key=lambda i: arrivals[i]["t_ms"])
        t0 = self.clock()
        i = 0
        while i < len(order):
            now_ms = (self.clock() - t0) * 1e3
            while i < len(order) and arrivals[order[i]]["t_ms"] <= now_ms:
                a = arrivals[order[i]]
                i += 1
                if "delta" in a:
                    self.apply_delta(a["delta"])
                    continue
                self.submit(
                    a["sources"], tenant=a.get("tenant", "default"),
                    deadline_ms=a.get("deadline_ms"), qid=a.get("qid"),
                    query_kind=a.get("query_kind", "reach"),
                )
            if self.admission.pending():
                self.pump()
            elif self._tail is not None:
                self._finalize_tail(overlapped=False)
            elif i < len(order):
                wait = arrivals[order[i]]["t_ms"] / 1e3 - (self.clock() - t0)
                if wait > 0:
                    time.sleep(min(wait, 0.005))
        self.drain()
        return self.results
