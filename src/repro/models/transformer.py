"""Config-driven transformer LM family (scan-over-layers + remat).

Covers the five assigned LM archs through one composable definition:
- llama-style GQA + SwiGLU (deepseek-coder-33b, minicpm-2b)
- local/global alternating attention + logit softcaps + post-norms (gemma2-2b)
- full MoE every layer (olmoe-1b-7b) / interleaved MoE + chunked-local
  attention + NoPE global layers (llama4-maverick-400b)

Layers are grouped by the repeating (attention-kind × moe-interleave) pattern
and scanned with ``lax.scan`` (stacked params, one group of layers per step),
keeping HLO size independent of depth; remat policy per config.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..nn.attention import (
    AttnSettings,
    KVCache,
    attn_init,
    attention_scan,
    decode_step as attn_decode,
    init_cache as attn_init_cache,
    prefill_kv,
)
from ..nn.layers import (
    embedding_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)
from ..nn.module import shard_activation
from ..nn.moe import MoESettings, ffn, ffn_init, moe, moe_init


@jax.custom_jvp
def grad_safe_barrier(x):
    """``lax.optimization_barrier`` that is transparent to autodiff.

    jax 0.4.x has no differentiation rule for ``optimization_barrier``
    (NotImplementedError under grad-of-scan-of-remat); newer jax added one.
    A custom_jvp identity passthrough makes the barrier version-independent:
    the primal keeps the scheduling barrier, tangents/cotangents flow
    through unbarriered (the barrier has no numeric effect, so derivatives
    are exactly the identity).
    """
    return jax.lax.optimization_barrier(x)


@grad_safe_barrier.defjvp
def _grad_safe_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return grad_safe_barrier(x), t


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 1e4
    layer_pattern: tuple = ("global",)  # cycled attention kinds
    window: int = 4096  # for local/chunk kinds
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    use_post_norm: bool = False  # gemma2 sandwich norms
    qk_norm: bool = False
    moe: Optional[MoESettings] = None
    tie_embeddings: bool = True
    emb_scale: Optional[float] = None
    logit_scale: float = 1.0
    residual_scale: float = 1.0
    norm_eps: float = 1e-6
    zero_centered_norm: bool = False
    dtype: Any = jnp.float32
    remat: str = "dots"  # none | dots | full
    attn_chunk: int = 512
    query_scale: Optional[float] = None
    # cross-entropy sequence chunk: the [B, S, vocab] logits tensor is never
    # materialized — the loss streams over S in ce_chunk slices with the
    # unembed rematerialized in the backward pass (a 256k-vocab model at
    # S=4096 would otherwise hold ~4 GB/device of logits alone).
    ce_chunk: int = 512

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 256) * 256

    @property
    def group_size(self) -> int:
        p = len(self.layer_pattern)
        m = self.moe.every if self.moe else 1
        return p * m // math.gcd(p, m)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (
            self.n_layers, self.group_size
        )
        return self.n_layers // self.group_size

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def layer_is_moe(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every == self.moe.every - 1)

    def attn_settings(self, kind: str) -> AttnSettings:
        return AttnSettings(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.d_head,
            rope_theta=self.rope_theta,
            kind=kind,
            window=self.window,
            logit_softcap=self.attn_logit_softcap,
            qk_norm=self.qk_norm,
            chunk_q=self.attn_chunk,
            query_scale=self.query_scale,
        )

    def active_params(self) -> int:
        """Analytic active-parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.d_head
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        per_layer = attn
        dense_ffn = 3 * d * self.d_ff
        n_moe = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        n_dense = self.n_layers - n_moe
        total = per_layer * self.n_layers + dense_ffn * n_dense
        if self.moe:
            act = 3 * d * self.moe.d_ff * (
                self.moe.top_k + self.moe.n_shared
            ) + d * self.moe.n_experts
            total += act * n_moe
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def total_params(self) -> int:
        d = self.d_model
        total = self.active_params()
        if self.moe:
            n_moe = sum(self.layer_is_moe(i) for i in range(self.n_layers))
            total += (
                3 * d * self.moe.d_ff
                * (self.moe.n_experts - self.moe.top_k)
                * n_moe
            )
        return total


# ----------------------------------------------------------------- init ----

def _layer_init(rng, cfg: TransformerConfig, i: int):
    r = jax.random.split(rng, 4)
    kind = cfg.layer_kind(i)
    p = {
        "ln_attn": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attn_init(r[0], cfg.attn_settings(kind), cfg.dtype),
        "ln_mlp": rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if cfg.layer_is_moe(i):
        p["moe"] = moe_init(r[1], cfg.d_model, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = ffn_init(r[2], cfg.d_model, cfg.d_ff, cfg.dtype)
    if cfg.use_post_norm:
        p["ln_attn_post"] = rmsnorm_init(cfg.d_model, cfg.dtype)
        p["ln_mlp_post"] = rmsnorm_init(cfg.d_model, cfg.dtype)
    return p


def _group_init(rng, cfg: TransformerConfig):
    rs = jax.random.split(rng, cfg.group_size)
    return {
        f"layer_{j}": _layer_init(rs[j], cfg, j) for j in range(cfg.group_size)
    }


def init(rng, cfg: TransformerConfig):
    r_emb, r_blocks, r_head = jax.random.split(rng, 3)
    group_rngs = jax.random.split(r_blocks, cfg.n_groups)
    blocks = jax.vmap(lambda r: _group_init(r, cfg))(group_rngs)
    # vmapped Boxed values gained a leading stack dim; axes stay as declared
    # (aux data) — prepend the "stack" logical axis.
    from ..nn.module import Boxed, is_boxed

    blocks = jax.tree.map(
        lambda b: Boxed(b.value, ("stack",) + b.axes),
        blocks,
        is_leaf=is_boxed,
    )
    params = {
        "embed": embedding_init(
            r_emb, cfg.vocab_padded, cfg.d_model, cfg.dtype
        ),
        "blocks": blocks,
        "ln_final": rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embedding_init(
            r_head, cfg.vocab_padded, cfg.d_model, cfg.dtype
        )
    return params


# -------------------------------------------------------------- forward ----

def _norm(cfg, p, x):
    return rmsnorm(p, x, cfg.norm_eps, cfg.zero_centered_norm)


def _layer_apply(lp, cfg: TransformerConfig, i: int, x, positions):
    from jax.ad_checkpoint import checkpoint_name

    kind = cfg.layer_kind(i)
    # SP gather point AFTER the norm (EXPERIMENTS.md §Perf iteration 3):
    # rmsnorm is per-token, so it runs on the seq-SHARDED residual; only its
    # bf16 output crosses the wire (XLA otherwise hoists the norm's f32
    # upcast before the gather and doubles the bytes). No-op without SP.
    # optimization_barrier pins the norm's bf16 output cast BEFORE the
    # gather — XLA otherwise commutes the f32 upcast past the collective
    # and ships 2x the bytes
    h_in = shard_activation(
        grad_safe_barrier(_norm(cfg, lp["ln_attn"], x)),
        ("batch", None, None),
    )
    h = attention_scan(lp["attn"], cfg.attn_settings(kind), h_in, positions)
    h = checkpoint_name(h, "attn_out")
    if cfg.use_post_norm:
        h = _norm(cfg, lp["ln_attn_post"], h)
    x = x + h * cfg.residual_scale
    aux = jnp.float32(0.0)
    m_in = shard_activation(
        grad_safe_barrier(_norm(cfg, lp["ln_mlp"], x)),
        ("batch", None, None),
    )
    if cfg.layer_is_moe(i):
        h, aux = moe(lp["moe"], cfg.moe, m_in)
    else:
        h = ffn(lp["mlp"], m_in)
    h = checkpoint_name(h, "mlp_out")
    if cfg.use_post_norm:
        h = _norm(cfg, lp["ln_mlp_post"], h)
    x = x + h * cfg.residual_scale
    x = shard_activation(x, ("batch", "res_seq", None))
    return x, aux


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    if cfg.remat == "minimal":
        # save only the d_model-wide layer outputs; everything wide
        # (attention internals, 2·d_ff gate/up, expert buffers) recomputes
        # in backward — the stacked per-scan-step saves stay O(S·d), not
        # O(S·d_ff) (the difference is 8x for gemma2).
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out"
        )
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full


def _embed_tokens(params, cfg, tokens):
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    if cfg.emb_scale is not None:
        x = x * jnp.asarray(cfg.emb_scale, x.dtype)
    return shard_activation(x, ("batch", "res_seq", None))


def _unembed(params, cfg, x):
    table = (
        params["embed"]["table"]
        if cfg.tie_embeddings
        else params["unembed"]["table"]
    )
    logits = (x @ table.T).astype(jnp.float32) * cfg.logit_scale
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    # mask vocab padding
    if cfg.vocab_padded != cfg.vocab:
        pad = cfg.vocab_padded - cfg.vocab
        logits = jnp.concatenate(
            [logits[..., : cfg.vocab],
             jnp.full((*logits.shape[:-1], pad), -1e30, logits.dtype)],
            axis=-1,
        )
    return shard_activation(logits, ("batch", None, "act_vocab"))


def hidden_states(params, cfg: TransformerConfig, tokens, positions=None):
    """tokens [B,S] -> (final-norm hidden [B,S,d], total aux loss)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _embed_tokens(params, cfg, tokens)

    def group_fn(x, gp):
        aux = jnp.float32(0.0)
        for j in range(cfg.group_size):
            x, a = _layer_apply(gp[f"layer_{j}"], cfg, j, x, positions)
            aux = aux + a
        return x, aux

    body = _remat(cfg, group_fn)
    x, auxs = jax.lax.scan(body, x, params["blocks"])
    return _norm(cfg, params["ln_final"], x), auxs.sum()


def forward(params, cfg: TransformerConfig, tokens, positions=None):
    """tokens [B,S] -> logits [B,S,vocab_padded] (+ total aux loss)."""
    x, aux = hidden_states(params, cfg, tokens, positions)
    return _unembed(params, cfg, x), aux


def loss_fn(params, cfg: TransformerConfig, batch):
    """batch: {"tokens": [B,S], "labels": [B,S]} -> scalar loss.

    Streamed cross-entropy: logits are computed per ce_chunk sequence slice
    inside a rematerialized scan body, so the full [B,S,vocab] tensor never
    exists (fwd or bwd)."""
    x, aux = hidden_states(params, cfg, batch["tokens"])
    B, S, d = x.shape
    C = min(cfg.ce_chunk, S)
    assert S % C == 0, (S, C)
    nc = S // C
    xs = jnp.moveaxis(x.reshape(B, nc, C, d), 1, 0)
    ys = jnp.moveaxis(
        batch["labels"].astype(jnp.int32).reshape(B, nc, C), 1, 0
    )

    def chunk_nll(total, xy):
        x_c, y_c = xy
        logits = _unembed(params, cfg, x_c)  # [B, C, vocab_padded] f32
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y_c[..., None], axis=-1)[..., 0]
        return total + ll.sum(), None

    total, _ = jax.lax.scan(
        jax.checkpoint(chunk_nll), jnp.float32(0.0), (xs, ys)
    )
    return -total / (B * S) + aux


# --------------------------------------------------------------- serving ---

def init_model_cache(
    cfg: TransformerConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
):
    one_group = {}
    for j in range(cfg.group_size):
        s = cfg.attn_settings(cfg.layer_kind(j))
        one_group[f"layer_{j}"] = attn_init_cache(s, batch, max_seq, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_groups, *a.shape)).copy(),
        one_group,
    )


def _layer_decode(lp, cfg, i, x, cache: KVCache, pos):
    kind = cfg.layer_kind(i)
    h, cache = attn_decode(
        lp["attn"], cfg.attn_settings(kind), _norm(cfg, lp["ln_attn"], x),
        cache, pos,
    )
    if cfg.use_post_norm:
        h = _norm(cfg, lp["ln_attn_post"], h)
    x = x + h * cfg.residual_scale
    if cfg.layer_is_moe(i):
        h, _ = moe(lp["moe"], cfg.moe, _norm(cfg, lp["ln_mlp"], x))
    else:
        h = ffn(lp["mlp"], _norm(cfg, lp["ln_mlp"], x))
    if cfg.use_post_norm:
        h = _norm(cfg, lp["ln_mlp_post"], h)
    return x + h * cfg.residual_scale, cache


def decode(params, cfg: TransformerConfig, caches, tokens, pos):
    """One decode step: tokens [B,1], pos scalar int32 ->
    (logits [B,1,vocab_padded], new caches)."""
    x = _embed_tokens(params, cfg, tokens)

    def body(x, inputs):
        gp, gcache = inputs
        new_caches = {}
        for j in range(cfg.group_size):
            key = f"layer_{j}"
            x, c = _layer_decode(gp[key], cfg, j, x, gcache[key], pos)
            new_caches[key] = c
        return x, new_caches

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    x = _norm(cfg, params["ln_final"], x)
    return _unembed(params, cfg, x), new_caches


def prefill(params, cfg: TransformerConfig, tokens, max_seq=None):
    """Prefill: tokens [B,S] -> (last-position logits [B,vocab_padded],
    caches ready for decode at pos=S)."""
    B, S = tokens.shape
    max_seq = max_seq or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _embed_tokens(params, cfg, tokens)

    def group_fn(x, gp):
        caches = {}
        for j in range(cfg.group_size):
            key = f"layer_{j}"
            lp = gp[key]
            kind = cfg.layer_kind(j)
            s = cfg.attn_settings(kind)
            xin = _norm(cfg, lp["ln_attn"], x)
            caches[key] = prefill_kv(lp["attn"], s, xin, positions, max_seq)
            x, _ = _layer_apply(lp, cfg, j, x, positions)
        return x, caches

    body = _remat(cfg, group_fn)
    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = _norm(cfg, params["ln_final"], x)
    logits = _unembed(params, cfg, x[:, -1:, :])
    return logits[:, 0, :], caches
