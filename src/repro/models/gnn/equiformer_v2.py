"""EquiformerV2 [arXiv:2306.12059] — equivariant graph attention via eSCN
SO(2) convolutions.

Assigned config: n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8.

The eSCN trick: rotate neighbor irreps into the edge-aligned frame (Wigner
blocks from irreps.align_matrices), where the SO(3) tensor product reduces to
per-|m| SO(2) linear maps (O(L³) instead of O(L⁶)); components with
|m| > m_max are truncated. Attention logits come from the frame's scalar
channel + radial basis; values are the SO(2)-convolved irreps, rotated back
after aggregation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ...nn.module import boxed_param, shard_activation
from ..gnn import common
from .irreps import align_matrices, lm_index, n_lm, rotate_irreps


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 32
    cutoff: float = 8.0
    n_species: int = 32
    d_feat: int = 0
    n_out: int = 1


def _m_indices(cfg):
    """For each m in 0..m_max: flat lm indices of (l, ±m) components."""
    out = []
    for m in range(cfg.m_max + 1):
        ls = [l for l in range(m, cfg.l_max + 1)]
        pos = [lm_index(l, m) for l in ls]
        neg = [lm_index(l, -m) for l in ls]
        out.append((np.array(pos), np.array(neg), len(ls)))
    return out


def _so2_init(rng, cfg):
    """Per-|m| SO(2) linear weights over the l-stack (+ channel mix)."""
    p = {}
    rs = jax.random.split(rng, 2 * (cfg.m_max + 1) + 1)
    for m in range(cfg.m_max + 1):
        nl = cfg.l_max + 1 - m
        p[f"wr_{m}"] = {
            "kernel": boxed_param(
                rs[2 * m], (nl, nl), (None, None), scale=1.0 / np.sqrt(nl)
            )
        }
        if m > 0:
            p[f"wi_{m}"] = {
                "kernel": boxed_param(
                    rs[2 * m + 1], (nl, nl), (None, None),
                    scale=1.0 / np.sqrt(nl),
                )
            }
    p["channel"] = {
        "kernel": boxed_param(
            rs[-1], (cfg.d_hidden, cfg.d_hidden), (None, None),
            scale=1.0 / np.sqrt(cfg.d_hidden),
        )
    }
    return p


def _so2_apply(p, cfg, x_rot, midx):
    """SO(2) conv in the edge frame: x_rot [E, nlm, C] -> [E, nlm, C]
    (m > m_max truncated to 0)."""
    E, nlm, C = x_rot.shape
    out = jnp.zeros_like(x_rot)
    for m, (pos, neg, nl) in enumerate(midx):
        wr = p[f"wr_{m}"]["kernel"]  # [nl, nl]
        xc = x_rot[:, pos, :]  # [E, nl, C] cos components
        if m == 0:
            yc = jnp.einsum("elc,lk->ekc", xc, wr)
            out = out.at[:, pos, :].set(yc)
        else:
            wi = p[f"wi_{m}"]["kernel"]
            xs = x_rot[:, neg, :]
            yc = jnp.einsum("elc,lk->ekc", xc, wr) - jnp.einsum(
                "elc,lk->ekc", xs, wi
            )
            ys = jnp.einsum("elc,lk->ekc", xc, wi) + jnp.einsum(
                "elc,lk->ekc", xs, wr
            )
            out = out.at[:, pos, :].set(yc)
            out = out.at[:, neg, :].set(ys)
    return out @ p["channel"]["kernel"]


def _eq_layernorm(x, eps=1e-6):
    """Equivariant norm: per-l RMS over (m, C)."""
    outs = []
    l_max = int(np.sqrt(x.shape[1])) - 1
    for l in range(l_max + 1):
        blk = x[:, l * l : (l + 1) ** 2, :]
        rms = jnp.sqrt(jnp.mean(jnp.square(blk), axis=(1, 2), keepdims=True))
        outs.append(blk / jnp.maximum(rms, eps))
    return jnp.concatenate(outs, axis=1)


def init(rng, cfg: EquiformerV2Config):
    rs = jax.random.split(rng, 4 + cfg.n_layers)
    params = {
        "species_embed": {
            "kernel": boxed_param(
                rs[0], (cfg.n_species, cfg.d_hidden), (None, None), scale=1.0
            )
        },
        "readout": {
            "kernel": boxed_param(rs[1], (cfg.d_hidden, cfg.n_out), (None, None))
        },
    }
    if cfg.d_feat:
        params["feat_proj"] = {
            "kernel": boxed_param(rs[2], (cfg.d_feat, cfg.d_hidden), ("embed", None))
        }
    C, H = cfg.d_hidden, cfg.n_heads
    for i in range(cfg.n_layers):
        r = jax.random.split(rs[3 + i], 6)
        params[f"layer_{i}"] = {
            "so2": _so2_init(r[0], cfg),
            "alpha": {
                "kernel": boxed_param(
                    r[1], (2 * C + cfg.n_rbf, H), (None, None)
                )
            },
            "ffn_scalar": {
                "w1": {"kernel": boxed_param(r[2], (C, 2 * C), (None, None))},
                "w2": {"kernel": boxed_param(r[3], (2 * C, C), (None, None))},
            },
            "gate": {"kernel": boxed_param(r[4], (C, cfg.l_max * C), (None, None))},
            "proj": {"kernel": boxed_param(r[5], (C, C), (None, None))},
        }
    return params


def apply(params, cfg: EquiformerV2Config, batch):
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    N = pos.shape[0]
    nlm = n_lm(cfg.l_max)
    C, H = cfg.d_hidden, cfg.n_heads
    midx = _m_indices(cfg)

    x = jnp.zeros((N, nlm, C), jnp.float32)
    x0 = jnp.take(
        params["species_embed"]["kernel"],
        jnp.clip(batch["species"], 0, cfg.n_species - 1),
        axis=0,
    )
    if cfg.d_feat and "node_feat" in batch:
        x0 = x0 + batch["node_feat"].astype(jnp.float32) @ params["feat_proj"]["kernel"]
    x = x.at[:, 0, :].set(x0)

    vec, r, valid = common.edge_vectors(pos, src, dst)
    mats = align_matrices(cfg.l_max, vec)  # per-l [E, 2l+1, 2l+1]
    rbf = common.gaussian_rbf(r, cfg.n_rbf, cfg.cutoff)

    # NOTE (EXPERIMENTS §Perf C): at ogb_products scale the per-edge irrep
    # tensors ([E, (l_max+1)^2, C] = 49C-wide at l_max=6) exceed any static
    # sharding budget; the production path needs STREAMED edge chunks
    # (two-pass online-softmax attention over edge slabs). Not implemented
    # — the cell compiles and its roofline is recorded, with memory far
    # over budget by design of the measurement.
    for i in range(cfg.n_layers):
        lp = params[f"layer_{i}"]
        xn = _eq_layernorm(x)
        xj = jnp.take(xn, src, axis=0)  # [E, nlm, C]
        xj_rot = rotate_irreps(mats, xj, cfg.l_max)  # into edge frame
        msg = _so2_apply(lp["so2"], cfg, xj_rot, midx)  # [E, nlm, C]
        msg = msg * valid[:, None, None]  # degenerate edges carry no message
        # attention logits: frame scalars of i and conv output + rbf
        xi_scal = jnp.take(xn[:, 0, :], dst, axis=0)  # [E, C]
        feats = jnp.concatenate([xi_scal, msg[:, 0, :], rbf], axis=-1)
        logits = jax.nn.leaky_relu(feats @ lp["alpha"]["kernel"])  # [E, H]
        alpha = common.segment_softmax(logits, dst, N)  # [E, H]
        vals = msg.reshape(-1, nlm, H, C // H) * alpha[:, None, :, None]
        vals = vals.reshape(-1, nlm, C)
        vals = rotate_irreps(mats, vals, cfg.l_max, inverse=True)
        agg = common.aggregate(vals, dst, N, "sum")  # [N, nlm, C]
        x = x + agg @ lp["proj"]["kernel"]
        # FFN: scalar MLP + gated non-scalars
        xn2 = _eq_layernorm(x)
        s = xn2[:, 0, :]
        h = jax.nn.silu(s @ lp["ffn_scalar"]["w1"]["kernel"])
        s_out = h @ lp["ffn_scalar"]["w2"]["kernel"]
        gates = jax.nn.sigmoid(s @ lp["gate"]["kernel"]).reshape(
            -1, cfg.l_max, C
        )
        gl = jnp.repeat(
            gates,
            np.array([2 * l + 1 for l in range(1, cfg.l_max + 1)]),
            axis=1,
        )  # [N, nlm-1, C]
        upd = jnp.concatenate([s_out[:, None, :], xn2[:, 1:, :] * gl], axis=1)
        x = x + upd
    node_out = x[:, 0, :] @ params["readout"]["kernel"]
    out = {"node_out": node_out}
    if "graph_ids" in batch:
        out["graph_out"] = jax.ops.segment_sum(
            node_out, batch["graph_ids"], num_segments=batch["n_graphs"]
        )
    return out
