"""MACE [arXiv:2206.07697] — higher-order equivariant message passing (E(3)-ACE).

Assigned config: n_layers=2, d_hidden=128, l_max=2, correlation_order=3,
n_rbf=8. Irreps features are flat [N, (l_max+1)², C]; products use the real
Gaunt tensor (irreps.gaunt_full). The ACE symmetric contraction to correlation
order ν is realized by iterated Gaunt products (B₂ = G·A·A, B₃ = G·B₂·A) with
per-order, per-l channelwise linear weights — the same product basis at
matching capacity, without e3nn.

Works on any shape cell: geometric inputs (positions, species) drive the edge
basis; optional node features project into the l=0 channels (full-graph node
classification cells).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ...nn.module import boxed_param
from ..gnn import common
from .irreps import gaunt_full, n_lm, sph_harm_real


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 32
    d_feat: int = 0  # >0: project node features into l=0
    n_out: int = 1  # 1 = energy; >1 = node classes


def _per_l_linear_init(rng, cfg, name_dims):
    """Per-l channel linear weights: dict l -> [C, C]."""
    rs = jax.random.split(rng, cfg.l_max + 1)
    return {
        f"l{l}": {
            "kernel": boxed_param(
                rs[l], (cfg.d_hidden, cfg.d_hidden), (None, None),
                scale=1.0 / np.sqrt(cfg.d_hidden),
            )
        }
        for l in range(cfg.l_max + 1)
    }


def _per_l_apply(p, cfg, x):
    """x [N, n_lm, C] -> same, block-diagonal per-l channel mixing."""
    out = []
    for l in range(cfg.l_max + 1):
        blk = x[:, l * l : (l + 1) ** 2, :]
        out.append(blk @ p[f"l{l}"]["kernel"])
    return jnp.concatenate(out, axis=1)


def init(rng, cfg: MACEConfig):
    rs = jax.random.split(rng, 4 + cfg.n_layers)
    params = {
        "species_embed": {
            "kernel": boxed_param(
                rs[0], (cfg.n_species, cfg.d_hidden), (None, None), scale=1.0
            )
        },
        "readout": {
            "kernel": boxed_param(
                rs[1], (cfg.d_hidden, cfg.n_out), (None, None)
            )
        },
    }
    if cfg.d_feat:
        params["feat_proj"] = {
            "kernel": boxed_param(
                rs[2], (cfg.d_feat, cfg.d_hidden), ("embed", None)
            )
        }
    for i in range(cfg.n_layers):
        r = jax.random.split(rs[3 + i], 6)
        params[f"layer_{i}"] = {
            "radial": {
                "kernel": boxed_param(
                    r[0],
                    (cfg.n_rbf, (cfg.l_max + 1) * cfg.d_hidden),
                    (None, None),
                )
            },
            "w_A": _per_l_linear_init(r[1], cfg, None),
            "w_B2": _per_l_linear_init(r[2], cfg, None),
            "w_B3": _per_l_linear_init(r[3], cfg, None),
            "w_self": _per_l_linear_init(r[4], cfg, None),
            "readout": {
                "kernel": boxed_param(
                    r[5], (cfg.d_hidden, cfg.n_out), (None, None)
                )
            },
        }
    return params


def apply(params, cfg: MACEConfig, batch):
    """batch: positions [N,3], species [N], edge_src/dst [E],
    optional node_feat [N,d_feat], optional graph_ids [N] (+ n_graphs).
    Returns per-node outputs [N, n_out] (and graph outputs if graph_ids)."""
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    N = pos.shape[0]
    nlm = n_lm(cfg.l_max)
    G = jnp.asarray(gaunt_full(cfg.l_max), jnp.float32)  # [a(Y), b(h), c(out)]

    h = jnp.zeros((N, nlm, cfg.d_hidden), jnp.float32)
    h0 = jnp.take(
        params["species_embed"]["kernel"],
        jnp.clip(batch["species"], 0, cfg.n_species - 1),
        axis=0,
    )
    if cfg.d_feat and "node_feat" in batch:
        h0 = h0 + batch["node_feat"].astype(jnp.float32) @ params["feat_proj"]["kernel"]
    h = h.at[:, 0, :].set(h0)

    vec, r, valid = common.edge_vectors(pos, src, dst)
    Y = sph_harm_real(cfg.l_max, vec)  # [E, nlm]
    rbf = common.bessel_rbf(r, cfg.n_rbf, cfg.cutoff)  # [E, n_rbf]
    rbf = rbf * valid[:, None]  # degenerate edges carry no message

    node_out = jnp.zeros((N, cfg.n_out), jnp.float32)
    for i in range(cfg.n_layers):
        lp = params[f"layer_{i}"]
        # radial weights per output-l, per channel
        R = (rbf @ lp["radial"]["kernel"]).reshape(
            -1, cfg.l_max + 1, cfg.d_hidden
        )  # [E, L+1, C]
        R_lm = jnp.repeat(
            R, np.array([2 * l + 1 for l in range(cfg.l_max + 1)]), axis=1
        )  # [E, nlm, C]
        hj = jnp.take(h, src, axis=0)  # [E, nlm, C]
        # tensor product via Gaunt: m[c(out)] = G[a,b,c] Y[a] h[b]
        msg = jnp.einsum("ea,abc,ebk->eck", Y, G, hj) * R_lm
        A = common.aggregate(msg, dst, N, "sum")  # [N, nlm, C]
        # ACE product basis (correlation order up to 3)
        B2 = jnp.einsum("abc,nak,nbk->nck", G, A, A)
        terms = (
            _per_l_apply(lp["w_A"], cfg, A)
            + _per_l_apply(lp["w_B2"], cfg, B2)
        )
        if cfg.correlation_order >= 3:
            B3 = jnp.einsum("abc,nak,nbk->nck", G, B2, A)
            terms = terms + _per_l_apply(lp["w_B3"], cfg, B3)
        h = _per_l_apply(lp["w_self"], cfg, h) + terms
        # per-layer scalar readout (MACE sums site energies per interaction)
        node_out = node_out + jax.nn.silu(h[:, 0, :]) @ lp["readout"]["kernel"]

    node_out = node_out + h[:, 0, :] @ params["readout"]["kernel"]
    out = {"node_out": node_out}
    if "graph_ids" in batch:
        out["graph_out"] = jax.ops.segment_sum(
            node_out, batch["graph_ids"], num_segments=batch["n_graphs"]
        )
    return out
