"""SchNet [arXiv:1706.08566] — continuous-filter convolutions.

Assigned config: n_interactions=3, d_hidden=64, rbf=300, cutoff=10.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...nn.module import boxed_param
from ..gnn import common


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 32
    d_feat: int = 0
    n_out: int = 1


def init(rng, cfg: SchNetConfig):
    rs = jax.random.split(rng, 3 + 4 * cfg.n_interactions)
    d = cfg.d_hidden
    params = {
        "species_embed": {
            "kernel": boxed_param(rs[0], (cfg.n_species, d), (None, None), scale=1.0)
        },
        "out1": {"kernel": boxed_param(rs[1], (d, d // 2), (None, None))},
        "out2": {"kernel": boxed_param(rs[2], (d // 2, cfg.n_out), (None, None))},
    }
    if cfg.d_feat:
        params["feat_proj"] = {
            "kernel": boxed_param(rs[-1], (cfg.d_feat, d), ("embed", None))
        }
    for i in range(cfg.n_interactions):
        r = rs[3 + 4 * i : 7 + 4 * i]
        params[f"interaction_{i}"] = {
            "filter1": {"kernel": boxed_param(r[0], (cfg.n_rbf, d), (None, None))},
            "filter2": {"kernel": boxed_param(r[1], (d, d), (None, None))},
            "in_proj": {"kernel": boxed_param(r[2], (d, d), (None, None))},
            "out_proj": {"kernel": boxed_param(r[3], (d, d), (None, None))},
        }
    return params


def apply(params, cfg: SchNetConfig, batch):
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    N = pos.shape[0]
    x = jnp.take(
        params["species_embed"]["kernel"],
        jnp.clip(batch["species"], 0, cfg.n_species - 1),
        axis=0,
    )
    if cfg.d_feat and "node_feat" in batch:
        x = x + batch["node_feat"].astype(jnp.float32) @ params["feat_proj"]["kernel"]
    _, r, valid = common.edge_vectors(pos, src, dst)
    rbf = common.gaussian_rbf(r, cfg.n_rbf, cfg.cutoff)  # [E, n_rbf]
    rbf = rbf * valid[:, None]  # degenerate edges carry no message

    for i in range(cfg.n_interactions):
        lp = params[f"interaction_{i}"]
        W = common.shifted_softplus(rbf @ lp["filter1"]["kernel"])
        W = W @ lp["filter2"]["kernel"]  # [E, d] continuous filter
        hj = jnp.take(x @ lp["in_proj"]["kernel"], src, axis=0)
        msg = hj * W
        agg = common.aggregate(msg, dst, N, "sum")
        v = common.shifted_softplus(agg @ lp["out_proj"]["kernel"])
        x = x + v
    h = common.shifted_softplus(x @ params["out1"]["kernel"])
    node_out = h @ params["out2"]["kernel"]
    out = {"node_out": node_out}
    if "graph_ids" in batch:
        out["graph_out"] = jax.ops.segment_sum(
            node_out, batch["graph_ids"], num_segments=batch["n_graphs"]
        )
    return out
