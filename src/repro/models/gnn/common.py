"""GNN message-passing substrate.

JAX sparse is BCOO-only, so message passing is built on edge-index arrays +
``jax.ops.segment_sum``-family scatter reductions (this IS the system, per the
assignment). The block-sparse Pallas kernel (kernels/block_spmm) is the
TPU-optimized path for the same aggregation on static full graphs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...nn.module import shard_activation


def _node_sharded(x):
    """Scatter outputs are full-width partials (+ all-reduce) under GSPMD;
    constraining them to the node (batch) sharding right here keeps the
    bwd-saved residuals at [N/K, d] instead of [N, d] — 16x on the 2.4M-node
    full-graph cells."""
    axes = ("batch",) + (None,) * (x.ndim - 1)
    return shard_activation(x, axes)


# ---------------------------------------------------------------------------
# Destination-aligned edge slabs (communication-avoiding aggregation).
#
# With edges sharded arbitrarily, every scatter produces a FULL-width [N, d]
# partial per device plus an all-reduce — at 2.4M nodes that is the memory
# bottleneck of the full-graph cells. If the loader instead buckets edges by
# destination node range (slab k only targets nodes [k·N/K, (k+1)·N/K), pad
# edges point at dst == N), the scatter becomes a vmapped per-slab segment
# reduce over LOCAL ids: output is born node-sharded, no full-width partials
# and no node-wide all-reduce. This is the 1-D version of the 2-D
# communication-avoiding SpMM partitioning (paper §6 related work), and the
# same owner-partition contract the sharded-state IFE engine uses.
#
# ``set_edge_slabs(K)`` (K = node-row shard count) switches every
# aggregate()/segment_softmax() below to the slab path; None restores plain
# flat scatters (single-device tests). ``graph/partition.slab_edges`` builds
# the host-side layout.
# ---------------------------------------------------------------------------

_EDGE_SLABS: int | None = None
_SLAB_BOUNDS = None  # [K+1] np.int64 node boundaries (edge-balanced slabs)


def set_edge_slabs(k: int | None, bounds=None):
    """``bounds`` (optional, host [K+1] array): non-uniform node ranges —
    slab j owns nodes ``[bounds[j], bounds[j+1])``. Produced by
    ``graph/partition.slab_edges(..., balance="edges")``; None keeps the
    uniform ``N/K``-range layout."""
    global _EDGE_SLABS, _SLAB_BOUNDS
    _EDGE_SLABS = k
    _SLAB_BOUNDS = None if bounds is None else np.asarray(bounds, np.int64)


def _slab_view(values, dst, n_nodes):
    """Flat [E, ...] + dst [E] -> ([K, E/K, ...], local dst [K, E/K],
    segments-per-slab, bounds-or-None), or None when slab mode is off /
    shapes don't divide. With edge-balanced bounds the per-slab segment
    count is the max node span; shorter slabs' trailing segments are never
    targeted and the reassembly gather skips them."""
    K = _EDGE_SLABS
    E = dst.shape[0]
    if K is None or K <= 1 or E % K:
        return None
    bounds = _SLAB_BOUNDS
    if bounds is None:
        if n_nodes % K:
            return None
        nl = n_nodes // K
        ds = dst.reshape(K, E // K)
        offs = (jnp.arange(K, dtype=ds.dtype) * nl)[:, None]
        his = offs + nl
    else:
        if len(bounds) != K + 1 or int(bounds[-1]) != n_nodes:
            return None
        nl = int((bounds[1:] - bounds[:-1]).max())
        ds = dst.reshape(K, E // K)
        offs = jnp.asarray(bounds[:-1], ds.dtype)[:, None]
        his = jnp.asarray(bounds[1:], ds.dtype)[:, None]
    in_slab = (ds >= offs) & (ds < his)
    dst_local = jnp.where(in_slab, ds - offs, nl)  # nl = dropped
    vals = values.reshape(K, E // K, *values.shape[1:])
    return vals, dst_local, nl, bounds


def _slab_reduce(vals, dst_local, nl, bounds, op):
    fn = {
        "sum": jax.ops.segment_sum,
        "max": jax.ops.segment_max,
        "min": jax.ops.segment_min,
    }[op]
    out = jax.vmap(lambda v, d: fn(v, d, num_segments=nl))(vals, dst_local)
    flat = out.reshape(out.shape[0] * nl, *out.shape[2:])
    if bounds is None:
        return _node_sharded(flat)
    # non-uniform spans: node n lives at (slab k(n), n - bounds[k(n)]);
    # the gather map is a host constant (bounds are static per layout)
    n_nodes = int(bounds[-1])
    node = np.arange(n_nodes, dtype=np.int64)
    k_of = np.searchsorted(bounds, node, side="right") - 1
    gather = jnp.asarray(k_of * nl + (node - bounds[k_of]), jnp.int32)
    return _node_sharded(flat[gather])


def segment_softmax(logits, segment_ids, num_segments):
    """Softmax over edges grouped by destination node."""
    slab = _slab_view(logits, segment_ids, num_segments)
    if slab is not None:
        lg, dl, nl, _ = slab

        def one(lg_k, d_k):
            mx = jax.ops.segment_max(lg_k, d_k, num_segments=nl)
            mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
            safe = jnp.minimum(d_k, nl - 1)  # pad edges: value irrelevant
            e = jnp.exp(lg_k - mx[safe])
            den = jax.ops.segment_sum(e, d_k, num_segments=nl)
            return e / jnp.maximum(den[safe], 1e-16)

        out = jax.vmap(one)(lg, dl)
        return out.reshape(logits.shape)
    mx = jax.ops.segment_max(
        logits, segment_ids, num_segments=num_segments
    )
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.exp(logits - mx[segment_ids])
    den = jax.ops.segment_sum(e, segment_ids, num_segments=num_segments)
    return e / jnp.maximum(den[segment_ids], 1e-16)


def _reduce(messages, dst, n_nodes, op):
    slab = _slab_view(messages, dst, n_nodes)
    if slab is not None:
        return _slab_reduce(*slab, op)
    fn = {
        "sum": jax.ops.segment_sum,
        "max": jax.ops.segment_max,
        "min": jax.ops.segment_min,
    }[op]
    return _node_sharded(fn(messages, dst, num_segments=n_nodes))


def aggregate(messages, dst, n_nodes, op: str = "sum"):
    """Scatter-reduce edge messages to destination nodes."""
    if op == "sum":
        return _reduce(messages, dst, n_nodes, "sum")
    if op == "mean":
        s = _reduce(messages, dst, n_nodes, "sum")
        c = _reduce(
            jnp.ones(messages.shape[:1], messages.dtype), dst, n_nodes, "sum"
        )
        return s / jnp.maximum(c[..., None] if s.ndim > 1 else c, 1.0)
    if op == "max":
        m = _reduce(messages, dst, n_nodes, "max")
        return jnp.where(jnp.isfinite(m), m, 0.0)
    if op == "min":
        m = _reduce(messages, dst, n_nodes, "min")
        return jnp.where(jnp.isfinite(m), m, 0.0)
    raise ValueError(op)


def degree(dst, n_nodes):
    return _reduce(
        jnp.ones(dst.shape, jnp.float32), dst, n_nodes, "sum"
    )


def bessel_rbf(r, n_rbf: int, cutoff: float):
    """Radial Bessel basis (DimeNet/MACE): sin(nπr/c)/r, smooth-enveloped."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rc = jnp.clip(r, 1e-4, cutoff)[..., None]
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * rc / cutoff) / rc
    # polynomial envelope p=6 for smooth cutoff
    x = jnp.clip(r / cutoff, 0.0, 1.0)[..., None]
    env = 1 - 28 * x**6 + 48 * x**7 - 21 * x**8
    return basis * env


def gaussian_rbf(r, n_rbf: int, cutoff: float):
    """Gaussian RBF expansion (SchNet)."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * jnp.square(r[..., None] - centers))


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


def edge_vectors(positions, src, dst, eps: float = 1e-6):
    """Returns (unit_vec [E,3], dist [E], valid [E]) for edges src->dst.

    Zero-length edges (self-loops / coincident atoms) have no direction —
    their unit vector is replaced by ẑ and ``valid`` is False; models must
    mask their messages (unmasked they silently break equivariance)."""
    d = positions[dst] - positions[src]
    r = jnp.linalg.norm(d, axis=-1)
    valid = r > eps
    unit = jnp.where(
        valid[..., None],
        d / jnp.maximum(r, eps)[..., None],
        jnp.asarray([0.0, 0.0, 1.0], d.dtype),
    )
    return unit, r, valid
