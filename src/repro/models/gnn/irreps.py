"""Real spherical-harmonic irreps machinery (e3nn is not available offline).

Provides, for l <= LMAX:
- ``sph_harm_real``      : real SH values Y_lm(n̂), flat (l,m) layout [.., (L+1)²]
- ``gaunt_tensor``       : real Gaunt coefficients ∫ Y_a Y_b Y_c dΩ computed by
                           Gauss–Legendre × uniform-φ quadrature (exact for
                           band-limited integrands) — the CG-contraction tensor
                           used by MACE-style tensor products.
- ``align_matrices``     : per-edge block-diagonal Wigner rotations W(n̂) with
                           W(n̂) @ sh(n̂) = sh(ẑ) — the eSCN trick
                           (EquiformerV2): rotate features into the edge frame
                           where tensor products become SO(2)-sparse.

Wigner small-d matrices come from the eigen-decomposition of J_y per l
(numpy, at import); the real-basis change is the standard complex→real SH
unitary. Conventions are locked by tests (alignment property + orthogonality).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

LMAX = 6


def n_lm(l_max: int) -> int:
    return (l_max + 1) ** 2


def lm_index(l: int, m: int) -> int:
    return l * l + l + m


# ---------------------------------------------------------------------------
# Associated Legendre + real SH (static unroll over (l, m); jnp-traceable).
# ---------------------------------------------------------------------------

def _legendre_all(l_max: int, x):
    """P_l^m(x) for 0<=m<=l<=l_max, dict[(l,m)] -> array like x."""
    P = {(0, 0): jnp.ones_like(x)}
    somx2 = jnp.sqrt(jnp.maximum(1.0 - x * x, 0.0))
    for m in range(1, l_max + 1):
        P[(m, m)] = -(2 * m - 1) * somx2 * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * x * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = (
                (2 * l - 1) * x * P[(l - 1, m)] - (l + m - 1) * P[(l - 2, m)]
            ) / (l - m)
    return P


def sph_harm_real(l_max: int, vecs):
    """Real orthonormal SH evaluated at unit vectors [..., 3] ->
    [..., (l_max+1)^2] in flat (l, m=-l..l) order."""
    x, y, z = vecs[..., 0], vecs[..., 1], vecs[..., 2]
    phi = jnp.arctan2(y, x)
    ct = jnp.clip(z, -1.0, 1.0)
    P = _legendre_all(l_max, ct)
    out = []
    for l in range(l_max + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            # orthonormal normalization
            norm = np.sqrt(
                (2 * l + 1)
                / (4 * np.pi)
                * _factorial_ratio(l - m, l + m)
            )
            if m == 0:
                row[l] = norm * P[(l, 0)]
            else:
                base = np.sqrt(2.0) * norm * P[(l, m)]
                row[l + m] = base * jnp.cos(m * phi)
                row[l - m] = base * jnp.sin(m * phi)
        out.extend(row)
    return jnp.stack(out, axis=-1)


def _factorial_ratio(a: int, b: int) -> float:
    """a! / b! for small ints."""
    out = 1.0
    if a >= b:
        for k in range(b + 1, a + 1):
            out *= k
        return out
    for k in range(a + 1, b + 1):
        out /= k
    return out


# ---------------------------------------------------------------------------
# Gaunt tensor via quadrature.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def gaunt_tensor(l1: int, l2: int, l3: int) -> np.ndarray:
    """G[a, b, c] = ∫ Y_{l1,a} Y_{l2,b} Y_{l3,c} dΩ (real SH), numpy."""
    n_theta = 2 * (l1 + l2 + l3) + 8
    n_phi = 2 * (l1 + l2 + l3) + 9
    xs, wts = np.polynomial.legendre.leggauss(n_theta)
    phis = np.linspace(0, 2 * np.pi, n_phi, endpoint=False)
    wphi = 2 * np.pi / n_phi
    ct, ph = np.meshgrid(xs, phis, indexing="ij")
    st = np.sqrt(1 - ct**2)
    pts = np.stack(
        [st * np.cos(ph), st * np.sin(ph), ct], axis=-1
    ).reshape(-1, 3)
    w = (wts[:, None] * np.ones_like(ph) * wphi).reshape(-1)
    lmax = max(l1, l2, l3)
    # host-side quadrature: must stay concrete even when first called inside
    # a jit trace (the dry-run traces apply() before any eager call warms
    # the lru_cache)
    with jax.ensure_compile_time_eval():
        Y = np.asarray(sph_harm_real(lmax, jnp.asarray(pts)))  # [P,(L+1)^2]

    def block(l):
        return Y[:, l * l : (l + 1) * (l + 1)]

    Y1, Y2, Y3 = block(l1), block(l2), block(l3)
    return np.einsum("pa,pb,pc,p->abc", Y1, Y2, Y3, w)


@functools.lru_cache(maxsize=None)
def gaunt_full(l_max: int) -> np.ndarray:
    """Dense [(L+1)², (L+1)², (L+1)²] Gaunt tensor (small for l_max<=3)."""
    n = n_lm(l_max)
    G = np.zeros((n, n, n))
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if (l1 + l2 + l3) % 2 or l3 < abs(l1 - l2) or l3 > l1 + l2:
                    continue
                g = gaunt_tensor(l1, l2, l3)
                G[
                    l1 * l1 : (l1 + 1) ** 2,
                    l2 * l2 : (l2 + 1) ** 2,
                    l3 * l3 : (l3 + 1) ** 2,
                ] = g
    return G


# ---------------------------------------------------------------------------
# Wigner rotations (real basis) for edge-frame alignment (eSCN).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jy_eig(l: int):
    """Eigendecomposition of J_y in the complex |l,m> basis."""
    m = np.arange(-l, l + 1)
    dim = 2 * l + 1
    jp = np.zeros((dim, dim), complex)  # J+
    for i in range(dim - 1):
        mm = m[i]
        jp[i + 1, i] = np.sqrt(l * (l + 1) - mm * (mm + 1))
    jm = jp.conj().T
    jy = (jp - jm) / 2j
    w, V = np.linalg.eigh(jy)
    return w, V


@functools.lru_cache(maxsize=None)
def _complex_to_real(l: int) -> np.ndarray:
    """Unitary T with Y_real = T @ Y_complex (rows: m=-l..l real;
    cols: m=-l..l complex), Condon–Shortley convention."""
    dim = 2 * l + 1
    T = np.zeros((dim, dim), complex)
    for m in range(1, l + 1):
        i_pos, i_neg = l + m, l - m
        T[i_neg, l - m] = 1j / np.sqrt(2)
        T[i_neg, l + m] = -1j * (-1) ** m / np.sqrt(2)
        T[i_pos, l - m] = 1 / np.sqrt(2)
        T[i_pos, l + m] = (-1) ** m / np.sqrt(2)
    T[l, l] = 1.0
    return T


def _dy_real_parts(l: int):
    """Returns (A, w, B) with d_real(β) = Re( A @ diag(e^{-iβw}) @ B )."""
    w, V = _jy_eig(l)
    T = _complex_to_real(l)
    A = T @ V
    B = V.conj().T @ T.conj().T
    return A, w, B


def _dz_real(l: int, alpha):
    """Rotation about z by alpha in the real SH basis: block 2x2 rotations
    mixing (m, -m): returns [..., dim, dim]."""
    dim = 2 * l + 1
    shape = alpha.shape
    out = jnp.zeros((*shape, dim, dim), jnp.float32)
    out = out.at[..., l, l].set(1.0)
    for m in range(1, l + 1):
        c, s = jnp.cos(m * alpha), jnp.sin(m * alpha)
        i, j = l + m, l - m
        out = out.at[..., i, i].set(c)
        out = out.at[..., j, j].set(c)
        out = out.at[..., i, j].set(s)
        out = out.at[..., j, i].set(-s)
    return out


def _dy_real(l: int, beta):
    A, w, B = _dy_real_parts(l)
    Aj = jnp.asarray(A.astype(np.complex64))
    Bj = jnp.asarray(B.astype(np.complex64))
    wj = jnp.asarray(w.astype(np.float32))
    phases = jnp.exp(-1j * beta[..., None] * wj)  # [..., dim]
    M = jnp.einsum("ij,...j,jk->...ik", Aj, phases.astype(jnp.complex64), Bj)
    return jnp.real(M).astype(jnp.float32)


def align_matrices(l_max: int, unit_vecs):
    """Per-l Wigner rotations W_l(n̂) [..., 2l+1, 2l+1] (real basis) with

        blockdiag(W) @ sph_harm_real(n̂) == sph_harm_real(ẑ)

    i.e. rotation into the edge-aligned frame (eSCN). Returns list per l.
    Inverse transform is the transpose (orthogonal).
    """
    x, y, z = unit_vecs[..., 0], unit_vecs[..., 1], unit_vecs[..., 2]
    alpha = jnp.arctan2(y, x)
    # arctan2 form: stable where arccos'(z) blows up near the poles (f32)
    beta = jnp.arctan2(jnp.sqrt(jnp.maximum(x * x + y * y, 0.0)), z)
    mats = []
    for l in range(l_max + 1):
        # convention (locked by tests): _d*_real(l, γ) is the matrix of the
        # argument rotation by R(-γ), so W = dy(+β) dz(+α) realizes
        # n̂ -> Rz(-α) -> xz-plane -> Ry(-β) -> ẑ.
        Ry = _dy_real(l, beta)
        Rz = _dz_real(l, alpha)
        mats.append(jnp.einsum("...ij,...jk->...ik", Ry, Rz))
    return mats


def rotate_irreps(mats, feats, l_max: int, inverse: bool = False):
    """Apply per-l rotation blocks to flat irreps [..., (L+1)², C]."""
    out = []
    for l in range(l_max + 1):
        blk = feats[..., l * l : (l + 1) ** 2, :]
        M = mats[l]
        if inverse:
            out.append(jnp.einsum("...ji,...jc->...ic", M, blk))
        else:
            out.append(jnp.einsum("...ij,...jc->...ic", M, blk))
    return jnp.concatenate(out, axis=-2)
