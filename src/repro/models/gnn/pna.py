"""PNA [arXiv:2004.05718] — Principal Neighbourhood Aggregation.

Assigned config: n_layers=4, d_hidden=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation (log-degree).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ...nn.module import boxed_param, shard_activation
from ..gnn import common


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_feat: int = 128
    n_out: int = 40
    avg_log_degree: float = 3.0  # δ: dataset-mean log(deg+1)


AGGS = ("mean", "max", "min", "std")
N_SCALERS = 3


def init(rng, cfg: PNAConfig):
    rs = jax.random.split(rng, 2 + 2 * cfg.n_layers)
    d = cfg.d_hidden
    params = {
        "feat_proj": {
            "kernel": boxed_param(rs[0], (cfg.d_feat, d), ("embed", None))
        },
        "readout": {"kernel": boxed_param(rs[1], (d, cfg.n_out), (None, None))},
    }
    for i in range(cfg.n_layers):
        params[f"layer_{i}"] = {
            "pre": {
                "kernel": boxed_param(rs[2 + 2 * i], (2 * d, d), (None, None))
            },
            "post": {
                "kernel": boxed_param(
                    rs[3 + 2 * i],
                    (len(AGGS) * N_SCALERS * d + d, d),
                    (None, None),
                )
            },
        }
    return params


def apply(params, cfg: PNAConfig, batch):
    src, dst = batch["edge_src"], batch["edge_dst"]
    feat = batch["node_feat"].astype(jnp.float32)
    N = feat.shape[0]
    x = feat @ params["feat_proj"]["kernel"]
    deg = common.degree(dst, N)
    logd = jnp.log1p(deg)[:, None]
    amp = logd / cfg.avg_log_degree
    att = cfg.avg_log_degree / jnp.maximum(logd, 1e-6)

    def layer(x, lp):
        hi = jnp.take(x, dst, axis=0)
        hj = jnp.take(x, src, axis=0)
        msg = jax.nn.relu(
            jnp.concatenate([hi, hj], axis=-1) @ lp["pre"]["kernel"]
        )  # [E, d]
        msg = shard_activation(msg, ("edges", None))
        aggs = []
        mean = common.aggregate(msg, dst, N, "mean")
        for a in AGGS:
            if a == "std":
                sq = common.aggregate(jnp.square(msg), dst, N, "mean")
                # +eps inside sqrt: d/dx sqrt at 0 is inf (NaN grads for
                # isolated nodes)
                agg = jnp.sqrt(jnp.maximum(sq - jnp.square(mean), 0.0) + 1e-6)
            elif a == "mean":
                agg = mean
            else:
                agg = common.aggregate(msg, dst, N, a)
            for scaler in (jnp.ones_like(amp), amp, att):
                aggs.append(agg * scaler)
        aggs = [shard_activation(a, ("batch", None)) for a in aggs]
        h = jnp.concatenate(aggs + [x], axis=-1) @ lp["post"]["kernel"]
        return shard_activation(jax.nn.relu(h) + x, ("batch", None))

    # remat per layer: only the [N/K, d] residual stream is saved for bwd,
    # not the 12 full-width aggregated tensors
    layer = jax.checkpoint(layer)
    for i in range(cfg.n_layers):
        x = layer(x, params[f"layer_{i}"])
    node_out = x @ params["readout"]["kernel"]
    out = {"node_out": node_out}
    if "graph_ids" in batch:
        out["graph_out"] = jax.ops.segment_sum(
            node_out, batch["graph_ids"], num_segments=batch["n_graphs"]
        )
    return out
