"""DCN-v2 [arXiv:2008.13535] — deep & cross network v2 for CTR.

Assigned config: n_dense=13, n_sparse=26, embed_dim=16, n_cross_layers=3,
MLP 1024-1024-512, interaction=cross (full-rank W per cross layer:
x_{l+1} = x0 ⊙ (W x_l + b) + x_l).

Embedding lookup is the hot path: fused-table EmbeddingBag
(nn/embedding_bag), rows sharded over the model axis.
``retrieval_cand`` scores one query against 10⁶ candidates as a batched
dot + top-k (no loop).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.embedding_bag import fused_table_init, lookup_single
from ..nn.module import Boxed, boxed_param, shard_activation


# Criteo-like heterogeneous vocabulary mix (~35.8M rows total).
CRITEO_VOCABS = tuple(
    [10_000_000] * 3 + [1_000_000] * 5 + [100_000] * 8 + [10_000] * 10
)


@dataclasses.dataclass(frozen=True)
class DCNv2Config:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: tuple = (1024, 1024, 512)
    field_vocabs: tuple = CRITEO_VOCABS
    retrieval_dim: int = 64

    @property
    def x0_dim(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def init(rng, cfg: DCNv2Config):
    rs = jax.random.split(rng, 6 + cfg.n_cross_layers + len(cfg.mlp))
    table, offsets = fused_table_init(
        rs[0], np.asarray(cfg.field_vocabs), cfg.embed_dim
    )
    d0 = cfg.x0_dim
    params = {"embed": table, "cross": {}, "mlp": {}}
    for i in range(cfg.n_cross_layers):
        params["cross"][f"w_{i}"] = {
            "kernel": boxed_param(rs[1 + i], (d0, d0), ("embed", "mlp")),
            "bias": Boxed(jnp.zeros((d0,), jnp.float32), (None,)),
        }
    d_in = d0
    for i, d_out in enumerate(cfg.mlp):
        params["mlp"][f"w_{i}"] = {
            "kernel": boxed_param(
                rs[1 + cfg.n_cross_layers + i], (d_in, d_out), ("embed", "mlp")
            )
        }
        d_in = d_out
    params["head"] = {"kernel": boxed_param(rs[-3], (d_in, 1), (None, None))}
    params["retrieval_proj"] = {
        "kernel": boxed_param(rs[-2], (d_in, cfg.retrieval_dim), (None, None))
    }
    return params, offsets


def features(params, cfg: DCNv2Config, batch, offsets):
    """batch: dense [B, 13] f32, sparse [B, 26] int -> x0 [B, x0_dim]."""
    emb = lookup_single(params["embed"], offsets, batch["sparse"])  # [B,26,16]
    dense = jnp.log1p(jnp.maximum(batch["dense"].astype(jnp.float32), 0.0))
    x0 = jnp.concatenate([dense, emb.reshape(emb.shape[0], -1)], axis=-1)
    return shard_activation(x0, ("batch", None))


def interaction(params, cfg: DCNv2Config, x0):
    """Cross layers then MLP -> final hidden [B, mlp[-1]]."""
    x = x0
    for i in range(cfg.n_cross_layers):
        p = params["cross"][f"w_{i}"]
        x = x0 * (x @ p["kernel"] + p["bias"]) + x
    x = shard_activation(x, ("batch", None))
    for i in range(len(cfg.mlp)):
        x = jax.nn.relu(x @ params["mlp"][f"w_{i}"]["kernel"])
        x = shard_activation(x, ("batch", "act_model"))
    return x


def forward(params, cfg: DCNv2Config, batch, offsets):
    """CTR logit [B]."""
    x0 = features(params, cfg, batch, offsets)
    h = interaction(params, cfg, x0)
    return (h @ params["head"]["kernel"])[:, 0]


def loss_fn(params, cfg: DCNv2Config, batch, offsets):
    logits = forward(params, cfg, batch, offsets)
    y = batch["labels"].astype(jnp.float32)
    # numerically-stable BCE with logits
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def query_embedding(params, cfg: DCNv2Config, batch, offsets):
    """Query tower for retrieval: [B, retrieval_dim], L2-normalized."""
    x0 = features(params, cfg, batch, offsets)
    h = interaction(params, cfg, x0)
    q = h @ params["retrieval_proj"]["kernel"]
    return q / jnp.maximum(
        jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9
    )


def retrieval_scores(params, cfg: DCNv2Config, batch, offsets, cand_embeds,
                     top_k: int = 100):
    """Score one query batch against [n_cand, retrieval_dim] candidates:
    batched dot + lax.top_k (assignment: 'not a loop')."""
    q = query_embedding(params, cfg, batch, offsets)  # [B, d]
    scores = q @ cand_embeds.T  # [B, n_cand]
    scores = shard_activation(scores, ("batch", "act_model"))
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, idx
