"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For 1000+-node depth scaling: stages live on a ``pipe`` mesh axis; the
schedule runs M microbatches through S stages in S+M-1 ticks. Each tick every
stage applies its layer block to its current microbatch, then activations
shift one stage forward via ``ppermute`` (compute/communication overlap is
XLA's async collective-permute on real ICI).

The stage function is user-provided (any (params, x) -> x), so the same
runner pipelines transformer groups, GNN blocks, or anything stackable.
Correctness contract (tested): output == serially applying all S stages to
every microbatch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def pipeline_apply(
    mesh: Mesh,
    stage_params,  # pytree with leading dim S (stacked per-stage params)
    xs,  # [M, ...] microbatches
    stage_fn,  # (params_for_stage, x) -> x
    axis: str = "pipe",
):
    """Runs all M microbatches through S pipeline stages."""
    S = mesh.shape[axis]
    M = xs.shape[0]

    def worker(params_local, xs_local):
        # params_local: this stage's params (leading dim 1); xs_local: all
        # microbatches (replicated input; stage 0 feeds them in).
        params_me = jax.tree.map(lambda p: p[0], params_local)
        stage = lax.axis_index(axis)
        n_ticks = S + M - 1
        buf = jnp.zeros_like(xs_local[0])  # current activation
        outs = jnp.zeros_like(xs_local)

        def tick(t, carry):
            buf, outs = carry
            mb_in = t  # microbatch entering stage 0 at tick t
            feed = xs_local[jnp.clip(mb_in, 0, M - 1)]
            x = jnp.where(stage == 0, feed, buf)
            y = stage_fn(params_me, x)
            # active iff this stage holds microbatch (t - stage) in [0, M)
            mb_here = t - stage
            active = (mb_here >= 0) & (mb_here < M)
            y = jnp.where(active, y, buf)
            # last stage writes its finished microbatch
            write_idx = jnp.clip(mb_here, 0, M - 1)
            outs = jnp.where(
                active & (stage == S - 1),
                outs.at[write_idx].set(y),
                outs,
            )
            # shift activations forward one stage
            buf_next = lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)]
            )
            return buf_next, outs

        _, outs = lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the last stage's outs are valid; broadcast via masked psum
        outs = lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        worker,
        mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
    )
    return fn(stage_params, xs)
