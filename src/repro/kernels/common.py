"""Shared conventions for the Pallas kernel packages.

Every kernel wrapper takes ``interpret: bool | None = None`` and resolves it
through :func:`default_interpret` — one copy of the auto-detect rule instead
of one per package.
"""
from __future__ import annotations

import jax


def default_interpret(interpret: bool | None) -> bool:
    """interpret=None ⇒ auto: compile for real on TPU, interpret elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
