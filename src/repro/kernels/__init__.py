"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as <name>/{<name>.py, ops.py, ref.py}: the pallas_call with
explicit BlockSpec VMEM tiling, the jit'd wrapper, and the pure-jnp oracle.
Kernels are validated in interpret mode on CPU (this container) and target
real TPU lowering (interpret=False) in production. All wrappers share the
``interpret=None`` auto-detect convention via ``common.default_interpret``.

- msbfs_extend   : MS-BFS frontier extension (paper hot loop, MXU int8)
- block_spmm     : block-sparse SpMM (GNN message passing)
- flash_attention: causal online-softmax attention (LM prefill/train)
- binned_pull    : fused slab-major degree-binned pull extension
                   (bottom-up hot loop behind ``pull_binned_fused``)
"""
from .common import default_interpret
from .binned_pull.ops import (
    BinnedPullPack,
    binned_pull,
    build_pack,
    pack_plan,
    pack_tile_map,
)

__all__ = [
    "default_interpret",
    "BinnedPullPack",
    "binned_pull",
    "build_pack",
    "pack_plan",
    "pack_tile_map",
]
