"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as <name>/{<name>.py, ops.py, ref.py}: the pallas_call with
explicit BlockSpec VMEM tiling, the jit'd wrapper, and the pure-jnp oracle.
Kernels are validated in interpret mode on CPU (this container) and target
real TPU lowering (interpret=False) in production.

- msbfs_extend   : MS-BFS frontier extension (paper hot loop, MXU int8)
- block_spmm     : block-sparse SpMM (GNN message passing)
- flash_attention: causal online-softmax attention (LM prefill/train)
"""
