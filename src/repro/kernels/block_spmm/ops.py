"""jit'd wrapper for block_spmm: weighted-adjacency blocks + aggregation."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...graph.csr import CSRGraph
from .block_spmm import block_spmm
from .ref import block_spmm_ref


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SpmmBlocks:
    blocks: jax.Array  # [nb, B, B] f32
    block_rows: jax.Array  # [nb] int32
    block_cols: jax.Array  # [nb] int32 (sorted, all cols present)


def spmm_blocks_from_csr(
    csr: CSRGraph, block: int = 128, normalize: str | None = None
) -> SpmmBlocks:
    """Dense-block adjacency with optional GCN-style normalization
    (normalize in {None, 'mean', 'sym'})."""
    n = csr.n_nodes
    g = -(-n // block)
    src, dst = csr.edge_list()
    w = (
        csr.weights.astype(np.float64)
        if csr.weights is not None
        else np.ones(len(src), np.float64)
    )
    if normalize == "mean":
        deg_in = np.zeros(n)
        np.add.at(deg_in, dst, w)
        w = w / np.maximum(deg_in[dst], 1e-9)
    elif normalize == "sym":
        deg_out = np.zeros(n)
        deg_in = np.zeros(n)
        np.add.at(deg_out, src, w)
        np.add.at(deg_in, dst, w)
        w = w / np.sqrt(np.maximum(deg_out[src] * deg_in[dst], 1e-9))
    br, bc = src // block, dst // block
    key = br.astype(np.int64) * g + bc
    uniq, inv = np.unique(key, return_inverse=True)
    nb = len(uniq)
    blocks = np.zeros((nb, block, block), np.float32)
    np.add.at(blocks, (inv, src % block, dst % block), w.astype(np.float32))
    rows = (uniq // g).astype(np.int32)
    cols = (uniq % g).astype(np.int32)
    missing = np.setdiff1d(np.arange(g, dtype=np.int32), cols)
    if len(missing):
        blocks = np.concatenate(
            [blocks, np.zeros((len(missing), block, block), np.float32)]
        )
        rows = np.concatenate([rows, np.zeros(len(missing), np.int32)])
        cols = np.concatenate([cols, missing])
    order = np.argsort(cols, kind="stable")
    return SpmmBlocks(
        blocks=jnp.asarray(blocks[order]),
        block_rows=jnp.asarray(rows[order]),
        block_cols=jnp.asarray(cols[order]),
    )


@partial(jax.jit, static_argnames=("interpret", "use_ref"))
def spmm(
    sb: SpmmBlocks,
    x: jax.Array,  # [n, F] node features (n divisible by block)
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Aggregated features Y[v] = sum_u A[u,v] X[u]: [n, F] f32."""
    n, F = x.shape
    B = sb.blocks.shape[1]
    G = n // B
    xb = x.reshape(G, B, F)
    fn = block_spmm_ref if use_ref else partial(
        block_spmm, interpret=interpret
    )
    out = fn(sb.blocks, sb.block_rows, sb.block_cols, xb)
    return out.reshape(n, F)
