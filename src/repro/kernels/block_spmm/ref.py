"""Pure-jnp oracle for block_spmm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_spmm_ref(
    blocks: jax.Array,
    block_rows: jax.Array,
    block_cols: jax.Array,
    x: jax.Array,
) -> jax.Array:
    G, B, F = x.shape
    src = jnp.take(x, block_rows, axis=0)  # [nb, B, F]
    partial = jnp.einsum(
        "nuv,nuf->nvf", blocks.astype(jnp.float32), src.astype(jnp.float32)
    )
    out = jnp.zeros((G, B, F), jnp.float32)
    return out.at[block_cols].add(partial, mode="drop")
