"""Pallas TPU kernel: block-sparse SpMM — Y = Aᵀ · X over nonzero blocks.

The GNN message-passing hot loop (sum aggregation over in-neighbors) in the
same block-sparse layout as msbfs_extend: one grid step multiplies one nonzero
adjacency block (bf16/f32) against a feature stripe and accumulates into the
destination feature tile (f32 accumulator in VMEM, revisiting pattern).

Grid = (feature_blocks, nonzero_adj_blocks); the adjacency index is the
innermost (fastest) dimension so all contributions to an output tile are
consecutive. Feature tile width 128 keeps the MXU shape square.

VMEM per step (B=128, F=128): adj 64 KiB (f32) + x 64 KiB + acc 64 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret


def _kernel(rows_ref, cols_ref, adj_ref, x_ref, out_ref):
    i = pl.program_id(1)
    is_first = jnp.where(
        i == 0, True, cols_ref[i] != cols_ref[jnp.maximum(i - 1, 0)]
    )

    @pl.when(is_first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = adj_ref[0]  # [B, B] A[u, v] edge weight (0 where no edge)
    x = x_ref[0]  # [B, F]
    partial = jax.lax.dot_general(
        a,
        x,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [B(v), F]
    out_ref[0] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_spmm(
    blocks: jax.Array,  # [nb, B, B] f32/bf16, sorted by dst block
    block_rows: jax.Array,  # [nb] int32
    block_cols: jax.Array,  # [nb] int32 non-decreasing, covering all cols
    x: jax.Array,  # [G, B, F] features by source block
    interpret: bool | None = None,
) -> jax.Array:
    """Returns [G, B, F] f32: per-destination aggregated features.

    ``interpret=None`` auto-detects: compile on TPU, interpret elsewhere."""
    interpret = default_interpret(interpret)
    nb, B, _ = blocks.shape
    G, _, F = x.shape
    FT = min(F, 128)
    assert F % FT == 0, (F, FT)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(F // FT, nb),
            in_specs=[
                pl.BlockSpec((1, B, B), lambda f, i, rows, cols: (i, 0, 0)),
                pl.BlockSpec(
                    (1, B, FT), lambda f, i, rows, cols: (rows[i], 0, f)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, B, FT), lambda f, i, rows, cols: (cols[i], 0, f)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((G, B, F), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, blocks, x)
    return out
