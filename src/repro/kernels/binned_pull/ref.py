"""Pure-jnp oracle for the fused binned-pull kernel.

Mirrors the kernel's padded semantics exactly (padded accumulator layout,
sentinel gathers filling the neutral, suppression after the un-permute) but
with one XLA gather per slab and no activity skipping — the reference the
parity corpus pins the kernel against, independent of the Pallas machinery.
"""
from __future__ import annotations

import jax.numpy as jnp

from .binned_pull import LANE_OPS, NO_PARENT, OPS, TilePlan, op_config


def fused_binned_pull_ref(
    op: str,
    plan: TilePlan,
    slabs,
    wslabs,
    gsrc,
    inv_pad,
    vloc,
):
    assert op in OPS, op
    lanes = op in LANE_OPS
    acc_dtype, neutral, src_pad, suppress, _ = op_config(op)
    tail = gsrc.shape[1:]
    acc = jnp.full((plan.rbp,) + tail, neutral, acc_dtype)
    for b, s in enumerate(slabs):
        got = gsrc.at[s].get(mode="fill", fill_value=src_pad)
        if op in ("reach", "reach_lanes"):
            part = got.max(axis=1)
        elif op == "min_parent":
            part = jnp.where(got != 0, s, NO_PARENT).min(axis=1)
        elif op == "min_parent_lanes":
            part = jnp.where(got != 0, s[:, :, None], NO_PARENT).min(axis=1)
        else:  # min_dist
            w = wslabs[b] if wslabs is not None else jnp.float32(1.0)
            part = (got + w).min(axis=1)
        a0 = plan.astarts[b]
        acc = acc.at[a0 : a0 + plan.rows_pad[b]].set(part.astype(acc_dtype))
    res = acc[inv_pad]
    if vloc is not None:
        res = jnp.where(vloc != 0, jnp.asarray(suppress, acc_dtype), res)
    return res
