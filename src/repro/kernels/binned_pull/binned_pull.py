"""Pallas TPU kernel: fused slab-major degree-binned pull extension.

One ``pallas_call`` realizes a full bottom-up (pull) frontier extension over
the degree-binned reverse slabs (``graph.csr.BinnedRevEll``): the per-slab
neighbor gathers, the OR / min / min-parent reduction over each row's slab
width, the ``inv`` un-permute back to local row order, and the visited
suppression — work the jnp path (``core.extend.BinnedPullBackend``) spreads
over one XLA gather per slab plus a final re-gather through HBM.

Grid layout (1-D sequential): ``T_compute`` slab row-tile steps followed by
``T_out`` output row-tile steps.

* Compute step ``i`` owns one ``[TR_b, width_b]`` tile of one nonzero-width
  slab ``b`` (native width — no cross-slab width padding; ``TR_b`` is chosen
  per slab so a tile holds ~``TILE_SLOTS`` int32 entries). It gathers the
  source value of every neighbor id from the VMEM-resident source vector,
  reduces over the width axis, and combines into a persistent VMEM scratch
  accumulator at the tile's padded-binned-position offset.
* Output step ``j = i - T_compute`` gathers the accumulator through the
  padded inverse permutation for one ``[TR_OUT]`` tile of local rows, applies
  the visited suppression, and writes the output tile.

Frontier-inactive tiles are skipped with the ``msbfs_extend`` activity trick:
a scalar-prefetched per-tile activity bitmap gates the compute under
``pl.when``, and a cummax'd per-slab tile selector re-addresses inactive
steps at the previously fetched tile so the slab DMA is elided entirely.
A tile is *inactive* when every (row, lane) it feeds is already visited —
its contribution is suppressed to the neutral element either way, so
skipping is bit-identical to computing.

The source vector (frontier / lane mask / distance vector being pulled from)
is held as a single VMEM-resident block padded to a multiple of 128 with the
gather-neutral value, so sentinel slab entries (= padded node count) gather
the neutral **in-bounds**. This sizes the kernel for graphs whose padded
node vector fits VMEM alongside one slab tile; the streaming row-block
variant for larger graphs is a ROADMAP follow-on. Validated in interpret
mode on CPU (this container); targets real TPU lowering (the accumulator
gather lowers through Mosaic's dynamic-gather path) in production.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

# matches core.edge_compute.NO_PARENT; a numpy scalar so the kernel closes
# over a compile-time constant rather than capturing a traced array
NO_PARENT = np.int32(2**31 - 1)

OPS = ("reach", "reach_lanes", "min_parent", "min_parent_lanes", "min_dist")
LANE_OPS = ("reach_lanes", "min_parent_lanes")

TILE_SLOTS = 4096  # target int32 adjacency slots per compute tile (16 KiB)
MIN_TILE_ROWS = 8
MAX_TILE_ROWS = 256


def tile_rows(width: int) -> int:
    """Compute-tile rows for a width-``width`` slab (multiple of 8)."""
    tr = TILE_SLOTS // max(int(width), 1)
    tr = (tr // MIN_TILE_ROWS) * MIN_TILE_ROWS
    return max(MIN_TILE_ROWS, min(MAX_TILE_ROWS, tr))


def out_tile_rows(rows_local: int) -> int:
    """Output-tile rows: the largest pow2 ≤ 256 dividing ``rows_local``."""
    for tro in (256, 128, 64, 32, 16, 8, 4, 2):
        if rows_local % tro == 0:
            return tro
    return 1


def op_config(op: str):
    """Per-op (accumulator dtype, reduction neutral, source-vector pad value,
    visited-suppression value, combine) — shared by kernel and oracle."""
    if op in ("reach", "reach_lanes"):
        return jnp.uint8, 0, 0, 0, jnp.maximum
    if op in ("min_parent", "min_parent_lanes"):
        return jnp.int32, NO_PARENT, 0, NO_PARENT, jnp.minimum
    assert op == "min_dist", op
    return jnp.float32, jnp.inf, jnp.inf, None, jnp.minimum


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Static slab→grid layout, derived purely from padded slab shapes.

    The accumulator lays padded binned positions out in bucket order: the
    zero-width bucket's rows first (no compute steps — they stay at the
    neutral), then each nonzero-width slab's row-padded segment."""

    widths: tuple  # nonzero-width slab widths, bucket order
    trs: tuple  # compute-tile rows per slab
    rows_pad: tuple  # row-padded rows per slab (multiple of trs[b])
    ntiles: tuple
    t_starts: tuple  # first grid step of each slab
    astarts: tuple  # accumulator offset of each slab
    zero_rows: int  # zero-width-bucket rows (accumulator prefix)
    t_compute: int
    rbp: int  # accumulator length (padded binned positions)


def make_plan(widths, rows_pad, zero_rows) -> TilePlan:
    trs = tuple(tile_rows(w) for w in widths)
    for w, r, tr in zip(widths, rows_pad, trs):
        assert w > 0 and r > 0 and r % tr == 0, (w, r, tr)
    ntiles = tuple(r // tr for r, tr in zip(rows_pad, trs))
    t_starts, astarts = [], []
    t, a = 0, int(zero_rows)
    for nt, r in zip(ntiles, rows_pad):
        t_starts.append(t)
        astarts.append(a)
        t += nt
        a += r
    return TilePlan(
        widths=tuple(int(w) for w in widths),
        trs=trs,
        rows_pad=tuple(int(r) for r in rows_pad),
        ntiles=ntiles,
        t_starts=tuple(t_starts),
        astarts=tuple(astarts),
        zero_rows=int(zero_rows),
        t_compute=t,
        rbp=a,
    )


def _make_kernel(op, plan, lanes, has_w, has_v):
    acc_dtype, neutral, _, suppress, combine = op_config(op)
    S = len(plan.widths)
    t_compute = plan.t_compute

    def kernel(*refs):
        act_ref = refs[0]
        k = 1 + S  # act + per-slab tile selectors
        slab_refs = refs[k : k + S]
        k += S
        if has_w:
            wslab_refs = refs[k : k + S]
            k += S
        gsrc_ref = refs[k]
        inv_ref = refs[k + 1]
        k += 2
        v_ref = refs[k] if has_v else None
        out_ref = refs[-2]
        acc_ref = refs[-1]

        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            acc_ref[...] = jnp.full(acc_ref.shape, neutral, acc_ref.dtype)

        for b in range(S):
            t0 = plan.t_starts[b]
            t1 = t0 + plan.ntiles[b]

            @pl.when((i >= t0) & (i < t1) & (act_ref[i] != 0))
            def _compute(b=b, t0=t0):
                idx = slab_refs[b][...]  # [tr, w] int32
                got = gsrc_ref[...][idx]  # [tr, w] or [tr, w, L]
                if op in ("reach", "reach_lanes"):
                    part = got.max(axis=1)
                elif op == "min_parent":
                    part = jnp.where(got != 0, idx, NO_PARENT).min(axis=1)
                elif op == "min_parent_lanes":
                    part = jnp.where(
                        got != 0, idx[:, :, None], NO_PARENT
                    ).min(axis=1)
                else:  # min_dist
                    w = wslab_refs[b][...] if has_w else jnp.float32(1.0)
                    part = (got + w).min(axis=1)
                tr = plan.trs[b]
                start = plan.astarts[b] + (i - t0) * tr
                sl = (pl.dslice(start, tr),) + (
                    (slice(None),) if lanes else ()
                )
                pl.store(
                    acc_ref, sl, combine(pl.load(acc_ref, sl), part)
                )

        @pl.when(i >= t_compute)
        def _emit():
            res = acc_ref[...][inv_ref[...]]  # [TRO] or [TRO, L]
            if has_v:
                res = jnp.where(v_ref[...] != 0, suppress, res)
            out_ref[...] = res

    return kernel


def fused_binned_pull(
    op: str,
    plan: TilePlan,
    slabs,  # list of [rows_pad_b, width_b] int32 (nonzero-width buckets)
    wslabs,  # None, or matching [rows_pad_b, width_b] f32 (min_dist only)
    gsrc: jax.Array,  # [n_out] or [n_out, L]: uint8 mask or f32 distance
    inv_pad: jax.Array,  # [rows_local] int32 into the padded accumulator
    vloc,  # None, or [rows_local](, L) uint8 (nonzero = visited)
    tile_act,  # None (= all active), or [t_compute] int32 activity bitmap
    interpret: bool | None = None,
) -> jax.Array:
    """Returns the fused pull result ``[rows_local]`` (or ``[rows_local, L]``
    for the lane ops) — uint8 / int32 / f32 per ``op``."""
    interpret = default_interpret(interpret)
    assert op in OPS, op
    lanes = op in LANE_OPS
    assert gsrc.ndim == (2 if lanes else 1), (op, gsrc.shape)
    acc_dtype, _, src_pad, _, _ = op_config(op)
    S = len(slabs)
    rows_local = int(inv_pad.shape[0])
    tro = out_tile_rows(rows_local)
    t_out = rows_local // tro
    t_total = plan.t_compute + t_out
    n_out = int(gsrc.shape[0])
    ne = -(-(n_out + 1) // 128) * 128  # sentinel (= n_out) gathers in-bounds
    tail = gsrc.shape[1:]
    gsrc_ext = jnp.concatenate(
        [gsrc, jnp.full((ne - n_out,) + tail, src_pad, gsrc.dtype)]
    )

    # scalar prefetch: activity per grid step + per-slab cummax'd tile
    # selectors (inactive / foreign steps re-address the previous tile so
    # the slab DMA is elided)
    if tile_act is None:
        act = jnp.ones((t_total,), jnp.int32)
    else:
        act = jnp.concatenate(
            [tile_act.astype(jnp.int32), jnp.ones((t_out,), jnp.int32)]
        )
    steps = jnp.arange(t_total, dtype=jnp.int32)
    sels = []
    for b in range(S):
        t0, nt = plan.t_starts[b], plan.ntiles[b]
        in_rng = (steps >= t0) & (steps < t0 + nt)
        cand = jnp.where(in_rng & (act != 0), steps - t0, -1)
        sel = jax.lax.associative_scan(jnp.maximum, cand)
        sels.append(jnp.clip(sel, 0, nt - 1).astype(jnp.int32))

    def slab_spec(b):
        return pl.BlockSpec(
            (plan.trs[b], plan.widths[b]),
            lambda i, a, *s, b=b: (s[b][i], 0),
        )

    def row_spec(shape):  # full-residency source vector
        return pl.BlockSpec(shape, lambda i, a, *s: (0,) * len(shape))

    def out_step_spec(shape):  # output-phase row tiles
        return pl.BlockSpec(
            shape,
            lambda i, a, *s: (jnp.maximum(i - plan.t_compute, 0),)
            + (0,) * (len(shape) - 1),
        )

    inputs = list(slabs)
    in_specs = [slab_spec(b) for b in range(S)]
    has_w = wslabs is not None
    if has_w:
        inputs += list(wslabs)
        in_specs += [slab_spec(b) for b in range(S)]
    inputs.append(gsrc_ext)
    in_specs.append(row_spec(gsrc_ext.shape))
    inputs.append(inv_pad.astype(jnp.int32))
    in_specs.append(out_step_spec((tro,)))
    has_v = vloc is not None
    if has_v:
        v = vloc.astype(jnp.uint8)
        inputs.append(v)
        in_specs.append(out_step_spec((tro,) + v.shape[1:]))

    out_shape = jax.ShapeDtypeStruct((rows_local,) + tail, acc_dtype)
    kernel = _make_kernel(op, plan, lanes, has_w, has_v)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1 + S,
            grid=(t_total,),
            in_specs=in_specs,
            out_specs=out_step_spec((tro,) + tail),
            scratch_shapes=[pltpu.VMEM((plan.rbp,) + tail, acc_dtype)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(act, *sels, *inputs)
