"""jit'd wrapper + host-side operand pack for the fused binned-pull kernel."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .binned_pull import (
    OPS,
    TilePlan,
    fused_binned_pull,
    make_plan,
    tile_rows,
)
from .ref import fused_binned_pull_ref


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BinnedPullPack:
    """Kernel-ready repack of ``graph.csr.BinnedRevEll``.

    Same edge set and the same perm/inverse contract, re-laid-out for the
    fused kernel: every nonzero-width slab is row-padded to a multiple of
    its compute-tile rows (pad rows all-sentinel ⇒ gather the neutral), and
    the permutation pair is re-indexed into the padded binned-position
    space. ``K`` is the graph shard count; leading axes shard over the
    policy's graph mesh axes exactly like the source ``BinnedRevEll``.
    """

    slabs: tuple  # of [K, rows_pad_b, width_b] int32 (nonzero-width buckets)
    inv_pad: jax.Array  # [K, rows_local] int32 (local row -> padded pos)
    perm_pad: jax.Array  # [K, rbp] int32 (padded pos -> local row;
    #                       sentinel rows_local at pad positions)
    slab_weights: Optional[tuple] = None  # matching [K, rows_pad_b, w] f32

    @property
    def rows_local(self) -> int:
        return int(self.inv_pad.shape[-1])

    @property
    def n_shards(self) -> int:
        return int(self.inv_pad.shape[0])

    @property
    def widths(self) -> tuple:
        return tuple(int(s.shape[-1]) for s in self.slabs)

    @property
    def capacity_slots(self) -> int:
        """One shard's full-scan adjacency slots **including** the kernel's
        row-tile padding (≥ the source structure's ``capacity_slots``)."""
        return int(sum(s.shape[-2] * s.shape[-1] for s in self.slabs))


def pack_plan(pack: BinnedPullPack) -> TilePlan:
    """Rebuild the static grid layout from the pack's shapes alone (the
    same deterministic rule ``build_pack`` padded with)."""
    rows_pad = tuple(int(s.shape[-2]) for s in pack.slabs)
    return make_plan(
        widths=tuple(int(s.shape[-1]) for s in pack.slabs),
        rows_pad=rows_pad,
        zero_rows=int(pack.perm_pad.shape[-1]) - sum(rows_pad),
    )


def build_pack(bn, n_pad: int, as_numpy: bool = False) -> BinnedPullPack:
    """Host-side (numpy, deterministic) repack of a ``BinnedRevEll``.

    ``n_pad`` is the padded node count — the slab sentinel value.
    ``as_numpy`` keeps the leaves as host numpy arrays (the streamed
    operand build places them per device itself); every transform is
    rowwise per shard, so a ``K=1`` input yields exactly the matching
    shard slice of the full pack."""
    conv = np.ascontiguousarray if as_numpy else jnp.asarray
    k = int(bn.inv.shape[0])
    rows_local = bn.rows_local
    widths = bn.widths
    assert widths[0] == 0 and all(w > 0 for w in widths[1:]), widths
    rows_raw = [int(s.shape[-2]) for s in bn.slabs]
    rows_pad = [
        -(-r // tile_rows(w)) * tile_rows(w)
        for w, r in zip(widths[1:], rows_raw[1:])
    ]
    # padded position of each unpadded binned position (bucket order:
    # zero-width rows first, then the row-padded nonzero slabs)
    starts = np.concatenate([[0], np.cumsum(rows_raw)])[:-1]
    seg = np.asarray([rows_raw[0]] + rows_pad, np.int64)
    pstarts = np.concatenate([[0], np.cumsum(seg)])[:-1]
    rbp = int(seg.sum())
    bop = np.repeat(np.arange(len(widths)), rows_raw)
    pp = pstarts[bop] + np.arange(int(np.sum(rows_raw))) - starts[bop]
    inv_pad = pp[np.asarray(bn.inv)].astype(np.int32)
    perm_pad = np.full((k, rbp), rows_local, np.int32)
    perm_pad[:, pp] = np.asarray(bn.perm)
    slabs, wslabs = [], []
    for b in range(1, len(widths)):
        s = np.asarray(bn.slabs[b])
        pad = rows_pad[b - 1] - s.shape[1]
        fill = np.full((k, pad, widths[b]), n_pad, np.int32)
        slabs.append(conv(np.concatenate([s, fill], axis=1)))
        if bn.slab_weights is not None:
            wv = np.asarray(bn.slab_weights[b])
            wfill = np.zeros((k, pad, widths[b]), np.float32)
            wslabs.append(conv(np.concatenate([wv, wfill], axis=1)))
    return BinnedPullPack(
        slabs=tuple(slabs),
        inv_pad=conv(inv_pad),
        perm_pad=conv(perm_pad),
        slab_weights=(
            tuple(wslabs) if bn.slab_weights is not None else None
        ),
    )


@partial(jax.jit, static_argnames=("op", "interpret", "use_ref"))
def binned_pull(
    pack: BinnedPullPack,
    gsrc: jax.Array,  # [n_out](, L): uint8 mask (reach/parent) or f32 dist
    vloc: jax.Array | None = None,  # [rows_local](, L) bool/uint8 visited
    *,
    op: str,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Fused pull extension of one shard's rows.

    Like the jnp path's ``slab[0]`` convention, the wrapper consumes shard 0
    of the pack it is given — inside ``shard_map`` every shard sees its own
    ``K=1`` slice. Returns ``[rows_local]`` (``[rows_local, L]`` for the
    ``*_lanes`` ops): uint8 reach mask, int32 min-parent, or f32 distance.
    """
    assert op in OPS, op
    plan = pack_plan(pack)
    slabs = [s[0] for s in pack.slabs]
    wslabs = None
    if op == "min_dist" and pack.slab_weights is not None:
        wslabs = [w[0] for w in pack.slab_weights]
    inv = pack.inv_pad[0]
    vloc_u8 = None if vloc is None else vloc.astype(jnp.uint8)
    if use_ref:
        return fused_binned_pull_ref(
            op, plan, slabs, wslabs, gsrc, inv, vloc_u8
        )
    tile_act = None
    if vloc_u8 is not None and plan.t_compute > 0:
        # per-compute-tile activity: a tile is active iff any (row, lane)
        # it feeds is still unvisited (else its output is suppressed)
        unvis = vloc_u8 == 0
        if unvis.ndim == 2:
            unvis = unvis.any(axis=-1)
        ub = jnp.concatenate([unvis, jnp.zeros((1,), bool)])[
            pack.perm_pad[0]
        ]
        acts = []
        for b in range(len(plan.widths)):
            a0 = plan.astarts[b]
            seg = ub[a0 : a0 + plan.rows_pad[b]]
            acts.append(
                seg.reshape(plan.ntiles[b], plan.trs[b]).any(axis=1)
            )
        tile_act = jnp.concatenate(acts).astype(jnp.int32)
    return fused_binned_pull(
        op, plan, slabs, wslabs, gsrc, inv, vloc_u8, tile_act,
        interpret=interpret,
    )


def pack_tile_map(pack: BinnedPullPack):
    """Host-side scanned-slot accounting for shard 0.

    Returns ``(tile_of_row, tile_slots)``: the compute-tile id of every
    local row (``-1`` for zero-in-degree rows, which no tile scans) and the
    int32 adjacency slots each compute tile pays. Used by the benchmark's
    fused-scan accounting and the coverage proptest."""
    plan = pack_plan(pack)
    inv = np.asarray(pack.inv_pad[0]).astype(np.int64)
    tile_of_acc = np.full(plan.rbp, -1, np.int64)
    slots = []
    for b in range(len(plan.widths)):
        a0, tr = plan.astarts[b], plan.trs[b]
        rel = np.arange(plan.rows_pad[b]) // tr
        tile_of_acc[a0 : a0 + plan.rows_pad[b]] = plan.t_starts[b] + rel
        slots.extend([tr * plan.widths[b]] * plan.ntiles[b])
    return tile_of_acc[inv], np.asarray(slots, np.int64)
