"""Pallas TPU kernel: causal flash attention forward (LM hot loop).

Online-softmax accumulation over KV blocks with running (m, l, acc) carried in
VMEM scratch across the innermost grid dimension. Grid =
(batch·heads, q_blocks, kv_blocks); causal block skipping via pl.when.

BlockSpec tiling (Bq = Bk = 128, d = head_dim):
  q    (1, Bq, d)     — revisited across kv steps (stays in VMEM)
  k/v  (1, Bk, d)     — streamed
  out  (1, Bq, d)     — written once at the final kv step
Scratch: m, l [Bq, 1] f32 + acc [Bq, d] f32 → ≈ (3·128·d + 2·128·128)·4 bytes
per step, ≪ VMEM for d ≤ 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, bq, bk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks fully above the causal diagonal (any bq/bk combination)
    run = (not causal) or (kj * bk <= qi * bq + (bq - 1))

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale  # [Bq, d]
        k = k_ref[0].astype(jnp.float32)  # [Bk, d]
        v = v_ref[0].astype(jnp.float32)  # [Bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [Bq, Bk]
        if causal:
            # mask within the diagonal block
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]  # [Bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [Bq, Bk]
        alpha = jnp.exp(m_prev - m_new)  # [Bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # [B, H, S, D]
    k: jax.Array,  # [B, H, S, D]
    v: jax.Array,  # [B, H, S, D]
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = default_interpret(interpret)
    B, H, S, D = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / (D ** 0.5)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)
    grid = (B * H, S // block_q, S // block_k)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, bq=block_q, bk=block_k
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
