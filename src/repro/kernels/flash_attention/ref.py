"""Pure-jnp oracle for flash_attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    B, H, S, D = q.shape
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32) * scale,
        k.astype(jnp.float32),
    )
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(
        q.dtype
    )
