"""Backend-aware wrapper: Pallas kernel on TPU, interpret-mode on CPU."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ref import attention_ref


def mha(q, k, v, causal: bool = True, use_kernel: bool | None = None):
    """Multi-head attention [B,H,S,D]. Chooses kernel vs oracle by backend."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return flash_attention(q, k, v, causal=causal)
    return attention_ref(q, k, v, causal=causal)
