"""jit'd wrapper + host-side block preparation for the msbfs_extend kernel."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...graph.csr import BlockAdjacency, CSRGraph, blocks_from_csr
from .msbfs_extend import msbfs_extend_blocks
from .ref import msbfs_extend_ref


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KernelBlocks:
    """Column-sorted block-sparse adjacency for the kernel.

    Every destination block id in [0, G) appears at least once (anchor zero
    blocks are inserted for empty columns) so each output tile is initialized
    by its first grid visit.
    """

    blocks: jax.Array  # [nb, B, B] int8
    block_rows: jax.Array  # [nb] int32
    block_cols: jax.Array  # [nb] int32 non-decreasing, covers all cols


def prepare_kernel_blocks(adj: BlockAdjacency) -> KernelBlocks:
    blocks = np.asarray(adj.blocks)
    rows = np.asarray(adj.block_rows)
    cols = np.asarray(adj.block_cols)
    g = adj.n_row_blocks
    missing = np.setdiff1d(np.arange(g, dtype=np.int32), cols)
    if len(missing):
        B = adj.block_size
        blocks = np.concatenate(
            [blocks, np.zeros((len(missing), B, B), np.int8)], axis=0
        )
        rows = np.concatenate([rows, np.zeros(len(missing), np.int32)])
        cols = np.concatenate([cols, missing.astype(np.int32)])
    order = np.argsort(cols, kind="stable")
    return KernelBlocks(
        blocks=jnp.asarray(blocks[order]),
        block_rows=jnp.asarray(rows[order].astype(np.int32)),
        block_cols=jnp.asarray(cols[order].astype(np.int32)),
    )


def kernel_blocks_from_csr(csr: CSRGraph, block: int = 128) -> KernelBlocks:
    return prepare_kernel_blocks(blocks_from_csr(csr, block=block))


@partial(jax.jit, static_argnames=("interpret", "use_ref"))
def msbfs_extend(
    kb: KernelBlocks,
    lanes: jax.Array,  # [n, L] uint8 (n divisible by block size)
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Frontier lane extension: [n, L] uint8 -> [n, L] uint8 reach mask."""
    n, L = lanes.shape
    B = kb.blocks.shape[1]
    G = n // B
    lane_blocks = lanes.reshape(G, B, L)
    if use_ref:
        out = msbfs_extend_ref(
            kb.blocks, kb.block_rows, kb.block_cols, lane_blocks
        )
    else:
        out = msbfs_extend_blocks(
            kb.blocks, kb.block_rows, kb.block_cols, lane_blocks,
            interpret=interpret,
        )
    return (out > 0).astype(jnp.uint8).reshape(n, L)
