"""Pure-jnp oracle for the msbfs_extend kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def msbfs_extend_ref(
    blocks: jax.Array,  # [nb, B, B] int8
    block_rows: jax.Array,  # [nb] int32
    block_cols: jax.Array,  # [nb] int32
    lanes: jax.Array,  # [G, B, L] uint8/int8
) -> jax.Array:
    """Reach mask [G, B, L] int32 (1 where reached, 0 elsewhere)."""
    G, B, L = lanes.shape
    src = jnp.take(lanes.astype(jnp.int32), block_rows, axis=0)  # [nb,B,L]
    partial = jnp.einsum(
        "nuv,nul->nvl",
        blocks.astype(jnp.int32),
        src,
        preferred_element_type=jnp.int32,
    )
    hit = (partial > 0).astype(jnp.int32)
    out = jnp.zeros((G, B, L), jnp.int32)
    return out.at[block_cols].max(hit, mode="drop")
