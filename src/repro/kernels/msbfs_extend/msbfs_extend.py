"""Pallas TPU kernel: MS-BFS frontier extension (DESIGN.md §2).

One grid step processes one nonzero 128×128 adjacency block: it computes
``(A_blockᵀ @ F_block) > 0`` on the MXU (int8 inputs, int32 accumulation) and
ORs it into the destination block of the next-frontier lane tensor. Blocks are
pre-sorted by destination block so all contributions to an output block are
consecutive grid steps — the output tile stays resident in VMEM and is written
back exactly once (the standard Pallas revisiting-accumulator pattern).

Block-sparsity via scalar prefetch: ``block_rows``/``block_cols`` are
prefetched scalars indexing which frontier stripe to DMA and which output tile
to accumulate — all-zero adjacency blocks are never touched. This is the
paper's MS-BFS "share one scan across 64 lanes" economy, realized as
block-sparse SpMM on the MXU.

Direction-optimizing upgrade: static block-sparsity only skips structurally
zero adjacency; blocks whose *frontier stripe* is empty this iteration still
stream. Two more prefetched scalars fix that — ``active[i]`` (does grid step
i's source stripe hold any frontier bit?) gates the MXU step with ``pl.when``,
and ``adj_sel[i]`` (the last active step ≤ i, a cummax computed in jnp by the
wrapper) replaces ``i`` in the adjacency/lane index maps, so an inactive
step's index map equals its predecessor's and Pallas elides the DMA entirely.
Net: per-iteration adjacency traffic ∝ frontier-active blocks, matching the
activity bitmap the jnp path (core.msbfs / extend.block_mxu) masks with.

VMEM working set per step (B=128, L=64):
  adj tile  128·128 int8   = 16 KiB
  lane tile 128·64  int8   =  8 KiB
  out tile  128·64  int32  = 32 KiB      → ~56 KiB ≪ 16 MiB VMEM; the
pipeline depth is bounded by DMA of the adj tile stream (the dominant stream),
which is exactly the term the activity skip list minimizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import default_interpret as _default_interpret


def _kernel(rows_ref, cols_ref, act_ref, sel_ref, adj_ref, lanes_ref, out_ref):
    i = pl.program_id(0)
    is_first = jnp.where(
        i == 0, True, cols_ref[i] != cols_ref[jnp.maximum(i - 1, 0)]
    )

    # output tiles still initialize on their first visit even when every
    # contributing stripe is inactive (empty frontier => zero reach)
    @pl.when(is_first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(act_ref[i] != 0)
    def _step():
        a = adj_ref[0].astype(jnp.int8)  # [B, B]   A[u, v]
        f = lanes_ref[0].astype(jnp.int8)  # [B, L]   F[u, l]
        # OR-aggregation as saturating matmul: contract the source dim on
        # the MXU.
        partial = jax.lax.dot_general(
            a,
            f,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # [B(v), L]
        out_ref[0] = out_ref[0] | (partial > 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def msbfs_extend_blocks(
    blocks: jax.Array,  # [nb, B, B] int8, sorted by dst block
    block_rows: jax.Array,  # [nb] int32 (src block ids)
    block_cols: jax.Array,  # [nb] int32 (dst block ids, non-decreasing)
    lanes: jax.Array,  # [G, B, L] int8/uint8 frontier lane blocks
    interpret: bool | None = None,
) -> jax.Array:
    """Returns reach counts [G, B, L] int32 (>0 where reached)."""
    interpret = _default_interpret(interpret)
    nb, B, _ = blocks.shape
    G, _, L = lanes.shape
    # per-step frontier-stripe activity + effective adjacency index: an
    # inactive step re-addresses the previously fetched tiles (cummax), so
    # its DMA is skipped and its compute is pl.when'd out
    stripe_act = (lanes != 0).any(axis=(1, 2))  # [G]
    act = stripe_act[block_rows].astype(jnp.int32)  # [nb]
    steps = jnp.arange(nb, dtype=jnp.int32)
    sel = jax.lax.associative_scan(
        jnp.maximum, jnp.where(act != 0, steps, -1)
    )
    sel = jnp.maximum(sel, 0)  # leading inactive run: any tile, compute off
    grid = (nb,)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, B, B), lambda i, rows, cols, act, sel: (sel[i], 0, 0)
                ),
                pl.BlockSpec(
                    (1, B, L),
                    lambda i, rows, cols, act, sel: (rows[sel[i]], 0, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, B, L), lambda i, rows, cols, act, sel: (cols[i], 0, 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((G, B, L), jnp.int32),
        interpret=interpret,
    )(block_rows, block_cols, act, sel, blocks, lanes.astype(jnp.int8))
    return out
