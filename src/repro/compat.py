"""jax version-compatibility shims.

Supported jax range: 0.4.35 — 0.8.x. The repo targets the newest API
surface (top-level ``jax.shard_map``, ``lax.axis_size``, explicit mesh
``axis_types``) but must also run on the 0.4.3x line, where those names
do not exist yet. Every version-dependent spelling lives here so the
rest of the codebase imports one stable name:

- ``shard_map(f, mesh, in_specs, out_specs)`` — top-level ``jax.shard_map``
  (>= 0.8, kwarg ``check_vma``) or ``jax.experimental.shard_map.shard_map``
  (0.4.x, kwarg ``check_rep``). Replication checking is disabled in both
  spellings: the IFE engines produce group-replicated outputs that the
  checker cannot prove.
- ``axis_size(name)`` — ``lax.axis_size`` where available, else the
  portable ``lax.psum(1, name)`` (static int for a literal operand).
- ``mesh_context(mesh)`` — ``jax.set_mesh`` (>= 0.7) /
  ``jax.sharding.use_mesh`` (0.5-0.6) / the Mesh object's own context
  manager (0.4.x): the ambient-mesh scope for jit lowering.

Mesh construction compat (``axis_types``) lives in ``repro.launch.mesh``
next to the production mesh builders.
"""
from __future__ import annotations

import jax
from jax import lax

try:  # jax >= 0.8 top-level
    from jax import shard_map as _shard_map_new

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_new(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
except ImportError:  # pragma: no cover - exercised on jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


if hasattr(lax, "axis_size"):

    def axis_size(name) -> int:
        """Static size of one named mesh axis inside shard_map/pmap."""
        return lax.axis_size(name)

else:  # jax 0.4.x: psum of a Python literal binds statically

    def axis_size(name) -> int:
        """Static size of one named mesh axis inside shard_map/pmap."""
        return lax.psum(1, name)


def mesh_context(mesh):
    """Ambient-mesh scope: ``with mesh_context(mesh): jf.lower(...)``."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # 0.4.x: Mesh itself is the context manager
