"""LR schedules: cosine and WSD (Warmup-Stable-Decay, MiniCPM arXiv:2404.06395).

Each returns lr_scale(step) in [0, 1] multiplying the optimizer's peak lr.
"""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        t = (step - warmup) / jnp.maximum(total - warmup, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return f


def wsd_schedule(
    warmup: int, total: int, decay_frac: float = 0.1, min_ratio: float = 0.01
):
    """Warmup -> stable plateau at 1.0 -> sharp decay over the last
    ``decay_frac`` of training (MiniCPM's schedule: enables continual
    pretraining from the stable phase)."""
    decay_start = int(total * (1 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        t = (step - decay_start) / jnp.maximum(total - decay_start, 1)
        t = jnp.clip(t, 0.0, 1.0)
        # exponential-style decay (MiniCPM uses ~exp decay to 10% then cut)
        decay = min_ratio ** t
        out = jnp.where(step < warmup, warm, 1.0)
        return jnp.where(step >= decay_start, decay, out)

    return f


SCHEDULES = {"cosine": cosine_schedule, "wsd": wsd_schedule}
