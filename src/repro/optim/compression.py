"""Gradient compression for cross-pod data parallelism.

int8 error-feedback compression (1-bit-Adam-family): quantize grads to int8
with a per-tensor scale before the cross-pod all-reduce, accumulate the
quantization residual locally, and add it back next step. 4× less DP
all-reduce traffic; error feedback keeps convergence (the residual carries
what quantization dropped).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..compat import axis_size


class CompressionState(NamedTuple):
    residual: Any  # pytree like grads (fp32 residuals)


def compression_init(grads_like) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, state: CompressionState):
    """Returns (int8 pytree, scales pytree, new_state). The caller all-reduces
    the int8 payload (sum of int8 across pods fits int32 accumulators) and
    dequantizes with the mean scale."""

    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, scale = quantize_int8(v)
        new_r = v - dequantize_int8(q, scale)
        return q, scale, new_r

    out = jax.tree.map(one, grads, state.residual)
    leaves, treedef = jax.tree.flatten(
        out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
    )
    qs = treedef.unflatten([l[0] for l in leaves])
    scales = treedef.unflatten([l[1] for l in leaves])
    new_state = CompressionState(
        residual=treedef.unflatten([l[2] for l in leaves])
    )
    return qs, scales, new_state


def decompress_grads(qs, scales):
    return jax.tree.map(dequantize_int8, qs, scales)


def compressed_psum(grads, state: CompressionState, axis_name: str):
    """End-to-end compressed DP all-reduce inside shard_map: quantize,
    psum int8 payloads (as int32), dequantize with the psum'd scale."""
    qs, scales, state = compress_grads(grads, state)
    n = axis_size(axis_name)
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), qs
    )
    mean_scale = jax.tree.map(
        lambda s: jax.lax.psum(s, axis_name) / n, scales
    )
    out = jax.tree.map(
        lambda sq, s: sq.astype(jnp.float32) * s / n, summed, mean_scale
    )
    return out, state
