"""AdamW with dtype-controlled moments (optax is not available offline).

Moments can be held in bf16 (with fp32 math) to halve optimizer HBM — the
lever that lets llama4-maverick-400b fit a 256-chip pod (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4  # peak; scaled by the schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    clip_norm: float | None = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any  # pytree like params
    nu: Any


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)
        )
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads, state: AdamWState, params, cfg: AdamWConfig, lr_scale=1.0
):
    """Returns (new_params, new_state, grad_norm)."""
    norm = global_norm(grads)
    if cfg.clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + g32 * (1 - b1)
        nu32 = nu.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = mu32 / c1
        vhat = nu32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            mu32.astype(cfg.moment_dtype),
            nu32.astype(cfg.moment_dtype),
        )

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_mu = treedef.unflatten([l[1] for l in leaves])
    new_nu = treedef.unflatten([l[2] for l in leaves])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), norm
