"""Deterministic synthetic data pipelines, sharded per host.

Every stream is a pure function of (seed, step, shard) — restart-safe (resume
at any step without replaying) and host-parallel (each host generates only
its shard; no data redistribution collective needed at scale).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """LM token batches [B, S+1] (inputs = [:, :-1], labels = [:, 1:]).

    Markov-chain tokens (order-1, banded transition) rather than uniform —
    gives a learnable signal so example runs show loss descending."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard
        )
        B, S = self.local_batch, self.seq_len
        # banded markov walk over the vocab
        start = rng.integers(0, self.vocab, size=(B, 1))
        steps = rng.integers(-8, 9, size=(B, S))
        toks = (start + np.cumsum(steps, axis=1)) % self.vocab
        toks = np.concatenate([start, toks], axis=1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class RecsysStream:
    """Criteo-like batches: 13 dense + 26 categorical + click label with a
    planted logistic rule (learnable)."""

    field_vocabs: tuple
    global_batch: int
    n_dense: int = 13
    seed: int = 0
    shard: int = 0
    n_shards: int = 1

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 999_983 + step) * 65_537 + self.shard
        )
        B = self.global_batch // self.n_shards
        dense = rng.lognormal(0.0, 2.0, size=(B, self.n_dense)).astype(
            np.float32
        )
        sparse = np.stack(
            [rng.integers(0, v, size=B) for v in self.field_vocabs], axis=1
        ).astype(np.int32)
        logit = (
            0.05 * dense[:, 0]
            - 0.04 * dense[:, 1]
            + 0.3 * ((sparse[:, 0] % 7) == 3)
            - 0.2 * ((sparse[:, 1] % 5) == 1)
        )
        p = 1 / (1 + np.exp(-logit))
        labels = (rng.random(B) < p).astype(np.int32)
        return {"dense": dense, "sparse": sparse, "labels": labels}


@dataclasses.dataclass(frozen=True)
class GraphSeedStream:
    """Seed-node batches for sampled GNN training."""

    n_nodes: int
    batch_nodes: int
    n_classes: int = 40
    seed: int = 0
    shard: int = 0
    n_shards: int = 1

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 424_243 + step) * 65_537 + self.shard
        )
        B = self.batch_nodes // self.n_shards
        seeds = rng.integers(0, self.n_nodes, size=B).astype(np.int32)
        labels = (seeds % self.n_classes).astype(np.int32)  # learnable rule
        return {"seeds": seeds, "labels": labels}
