"""Morsel dispatching policies (paper §3) as mesh-axis assignments.

A policy decides which mesh axes shard *source morsels* and which partition
the graph into *frontier morsels* (DESIGN.md §2 table). With the production
mesh ``("data", "model")`` of 16×16:

- 1T1S : sources over ("data","model"), graph replicated   (paper §3.1)
- nT1S : sources replicated, graph over ("data","model")   (paper §3.2)
- nTkS : sources over ("data",), graph over ("model",)     (paper §3.3)
         k = 16 × per-device source batch
- nTkMS: nTkS with 64-wide multi-source lane morsels       (paper §3.4)

``recommend_policy`` encodes the paper's robustness findings (§5) as code:
the hybrid is the default; lane packing turns on only when sources saturate
the 64-wide lanes; high average degree caps effective k (cache/HBM locality,
paper §5.5 + Fig 13).

``hybrid_phases`` returns the two policies the *adaptive* hybrid runtime
(repro.runtime.scheduler) executes in sequence: phase 1 issues source-level
morsels (nTkS, per-shard convergence), phase 2 re-dispatches the surviving
morsels as frontier-level morsels (nT1S over every mesh axis) — the paper's
"morsels at both the source node and frontier levels", realized at runtime
instead of as a static mesh assignment.

``recommend_backend`` + ``fit_direction_thresholds`` do the same for the
*physical scan layout* of the extension step (core.extend backends): the
default recommendation is the Beamer direction switch over degree-binned
pull slabs, and its alpha/beta constants — Beamer's hand-tuned CPU values —
can be replaced by thresholds fitted per (dataset-family, degree-bucket)
from the per-iteration scan traces ``benchmarks/direction_opt.py``
accumulates in ``BENCH_direction_opt.json`` (same shape as the adaptive
scheduler's phase-1 budget learner: measure, quantize, serve).
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Mapping, Sequence

from .collectives import REDISPATCH_OR_IMPL
from .extend import ExtendSpec


@dataclasses.dataclass(frozen=True)
class MorselPolicy:
    name: str
    source_axes: tuple[str, ...]  # mesh axes sharding source morsels
    graph_axes: tuple[str, ...]  # mesh axes partitioning the graph
    lanes: int = 1  # 64 => multi-source morsels (MS-BFS)
    or_impl: str = "allgather"  # frontier-union collective (see collectives)

    @property
    def is_multi_source(self) -> bool:
        return self.lanes > 1


def policy_1t1s(
    mesh_axes: Sequence[str] = ("data", "model")
) -> MorselPolicy:
    return MorselPolicy("1T1S", tuple(mesh_axes), ())


def policy_nt1s(
    mesh_axes: Sequence[str] = ("data", "model"), or_impl: str = "allgather"
) -> MorselPolicy:
    return MorselPolicy("nT1S", (), tuple(mesh_axes), or_impl=or_impl)


def policy_ntks(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    or_impl: str = "allgather",
) -> MorselPolicy:
    return MorselPolicy("nTkS", tuple(source_axes), tuple(graph_axes), or_impl=or_impl)


def policy_ntkms(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    lanes: int = 64,
    or_impl: str = "allgather",
) -> MorselPolicy:
    return MorselPolicy(
        "nTkMS", tuple(source_axes), tuple(graph_axes), lanes=lanes, or_impl=or_impl
    )


POLICIES = {
    "1t1s": policy_1t1s,
    "nt1s": policy_nt1s,
    "ntks": policy_ntks,
    "ntkms": policy_ntkms,
}


def hybrid_phases(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    lanes: int = 1,
    or_impl: str = "allgather",
) -> tuple[MorselPolicy, MorselPolicy]:
    """The adaptive hybrid's (phase-1, phase-2) policy pair.

    Phase 1: nTkS (or nTkMS when ``lanes`` > 1) with the caller's
    ``or_impl`` — source morsels over ``source_axes``, graph over
    ``graph_axes``. Phase 2: nT1S over BOTH axis groups with the ring
    frontier union (collectives.REDISPATCH_OR_IMPL): all devices gang up
    on each surviving morsel's frontier.
    """
    p1 = MorselPolicy(
        "nTkMS" if lanes > 1 else "nTkS",
        tuple(source_axes), tuple(graph_axes),
        lanes=lanes, or_impl=or_impl,
    )
    p2 = MorselPolicy(
        "nT1S", (), tuple(source_axes) + tuple(graph_axes),
        lanes=lanes, or_impl=REDISPATCH_OR_IMPL,
    )
    return p1, p2


def recommend_policy(
    n_sources: int,
    n_devices: int,
    avg_degree: float,
    returns_paths: bool = False,
    n_nodes: int | None = None,
    hbm_bytes: int = 16 * 2**30,
) -> str:
    """The paper's conclusions (§5, §7) as a dispatch rule.

    - nTkMS only when sources saturate ≥1 full 64-lane morsel (Fig 14) and,
      for path outputs, when the 536 B/node/morsel upfront allocation fits
      (§5.6's Graph500 OOM).
    - otherwise nTkS — the robust hybrid — everywhere (§5.4 recommendation).
      (1T1S/nT1S are never *better* than nTkS in the paper's study; they are
      kept as explicit baselines, not recommendations.)
    """
    if n_sources >= 64:
        if returns_paths and n_nodes is not None:
            morsels = -(-n_sources // 64)
            upfront = 536 * n_nodes * min(morsels, max(n_devices, 1))
            if upfront > 0.5 * hbm_bytes:
                return "ntks"
        return "ntkms"
    return "ntks"


# ---------------------------------------------------------------------------
# Direction thresholds: Beamer's constants, optionally re-fitted from traces.
# ---------------------------------------------------------------------------

BEAMER_ALPHA = 14.0
BEAMER_BETA = 24.0


def degree_bucket(avg_degree: float) -> int:
    """pow2 bucket id of a workload's average degree (the granularity the
    fitted threshold table is keyed at): 0 for <=1, else ceil(log2)."""
    if avg_degree <= 1.0:
        return 0
    return int(math.ceil(math.log2(avg_degree) - 1e-12))


@dataclasses.dataclass(frozen=True)
class DirectionThresholds:
    """Fitted (alpha, beta) per (dataset-family, degree-bucket).

    ``table`` maps ``(family, bucket)`` to ``(alpha, beta)``; lookups fall
    back family-first (nearest bucket of the same family), then to the
    Beamer defaults — so the table is total over every query even when the
    bench traces only covered a few workload families.
    """

    table: Mapping  # {(family, bucket): (alpha, beta)}
    default: tuple = (BEAMER_ALPHA, BEAMER_BETA)

    def lookup(self, family: str | None, avg_degree: float) -> tuple:
        b = degree_bucket(avg_degree)
        if family is not None:
            if (family, b) in self.table:
                return self.table[(family, b)]
            near = [
                (abs(kb - b), kb, v)
                for (kf, kb), v in self.table.items()
                if kf == family
            ]
            if near:
                return min(near)[2]
        # no family match: nearest bucket across all families, then default
        near = [(abs(kb - b), kb, v) for (_, kb), v in self.table.items()]
        if near:
            return min(near)[2]
        return self.default


def _fit_group(recs: list[tuple], pull_key: str) -> tuple:
    """One (family, bucket) group: pick (alpha, beta) minimizing the total
    scanned slots the Beamer predicate would have chosen over the trace.
    ``recs`` are (iteration_record, n) pairs — n travels per record, since
    one group may aggregate same-family workloads of different sizes.

    Candidate thresholds come from the trace itself — each iteration's
    ``m_u/m_f`` (resp. ``n/n_f``) ratio is the exact alpha (beta) at which
    that iteration's predicate flips — plus the Beamer defaults, so the
    search space is the set of distinct decision boundaries the trace can
    express. Deterministic: ties break toward the Beamer constants."""
    pts = []
    for r, n in recs:
        if any(
            r.get(k) is None
            for k in ("m_frontier", "m_unexplored", "frontier",
                      "push_slots", pull_key)
        ):
            continue  # pre-v2 / trimmed record: contributes no sample
        m_f = float(r["m_frontier"])
        m_u = float(r["m_unexplored"])
        n_f = float(r["frontier"])
        pts.append(
            (m_f, m_u, n_f, float(n), float(r["push_slots"]),
             float(r[pull_key]))
        )
    if not pts:
        return (BEAMER_ALPHA, BEAMER_BETA)
    eps = 1e-9
    alphas = sorted(
        {m_u / m_f * (1 + eps) for m_f, m_u, *_ in pts if m_f > 0}
        | {BEAMER_ALPHA, 0.0}
    )
    betas = sorted(
        {n / n_f * (1 + eps) for _, _, n_f, n, _, _ in pts if n_f > 0}
        | {BEAMER_BETA, 0.0}
    )

    def cost(a: float, b: float) -> float:
        tot = 0.0
        for m_f, m_u, n_f, n, push, pull in pts:
            use_pull = (m_f * a > m_u) and (n_f * b > n)
            tot += pull if use_pull else push
        return tot

    def key(ab):
        a, b = ab
        return (
            cost(a, b),
            abs(a - BEAMER_ALPHA) + abs(b - BEAMER_BETA),
            a,
            b,
        )

    return min(((a, b) for a in alphas for b in betas), key=key)


def fit_direction_thresholds(
    traces, pull: str = "binned"
) -> DirectionThresholds:
    """Fit per-(dataset-family, degree-bucket) alpha/beta from bench traces.

    ``traces``: a parsed ``BENCH_direction_opt.json`` document (or its
    ``workloads`` list, or a path to the file). Iteration records need the
    schema-v2 fields ``m_frontier`` / ``m_unexplored`` / ``push_slots`` /
    ``pull_slots_{binned,ell}`` (older records are skipped — the fit
    degrades to the Beamer defaults, never fails). ``pull`` selects which
    pull flavor's measured cost the thresholds optimize for; "binned" is
    what ``recommend_backend`` serves.
    """
    if isinstance(traces, (str, Path)):
        traces = json.loads(Path(traces).read_text())
    workloads = traces.get("workloads", traces) if isinstance(
        traces, dict
    ) else traces
    pull_key = f"pull_slots_{pull}"
    groups: dict[tuple, list] = {}
    for w in workloads:
        # the runtime predicate compares n_f*beta against the PADDED row
        # count (ExtendCtx.n_out), so beta must be fitted against n_pad,
        # not the logical node count; old traces fall back to n
        n = w.get("n_pad", w.get("n"))
        if n is None:
            continue
        fam = w.get("kind", "unknown")
        bucket = degree_bucket(float(w.get("avg_degree", 1.0)))
        recs = groups.setdefault((fam, bucket), [])
        # every backend replays the same frontier trajectory (bit-parity),
        # so the canonical push trace carries the group's cost samples
        be = w.get("backends", {}).get("ell_push", {})
        recs.extend((r, int(n)) for r in be.get("iterations", []))
    table = {
        k: _fit_group(recs, pull_key) for k, recs in groups.items()
    }
    return DirectionThresholds(table=table)


def recommend_backend(
    edge_compute: str = "sp_lengths",
    avg_degree: float = 8.0,
    n_nodes: int | None = None,
    lanes: int = 1,
    block: int = 128,
    family: str | None = None,
    thresholds: DirectionThresholds | None = None,
    operands=None,
):
    """Physical scan layout for the extension step (core.extend backends).

    The EmptyHeaded lesson as a dispatch rule: pick the layout by expected
    frontier/adjacency density, not globally.

    - ``bellman_ford`` (weighted relax, no monotone visited set): nothing to
      suppress, so bottom-up never wins — stay on the forward push scatter.
    - 64-wide lane morsels on graphs dense at block granularity (expected
      edges per ``block``² tile ≳ 1, i.e. ``avg_degree·block ≳ n``): the
      saturating-matmul block path amortizes one adjacency scan over all
      lanes on the MXU and skips frontier-empty stripes.
    - everything else (BFS-family traversals): the Beamer alpha/beta
      direction switch over **degree-binned** pull slabs — push while
      frontiers are sparse, binned pull with visited-suppression once the
      frontier's edge mass dominates. With a fitted ``thresholds`` table
      the switch runs the trace-fitted alpha/beta for this
      (``family``, degree-bucket) instead of Beamer's CPU constants.

    Deterministic and *total*: a pure function of its arguments, and when
    the caller passes the ``operands`` bundle (or a bare EllGraph, like
    every other operand-accepting entry point) it will only ever name a
    backend whose physical operands exist in that bundle (falling back
    toward ``ell_push``, which every bundle carries).
    """
    from .extend import as_operands

    ops = None if operands is None else as_operands(operands)
    have = lambda attr: ops is None or getattr(ops, attr) is not None
    if edge_compute == "bellman_ford":
        return "ell_push"
    dense_blocks = (
        n_nodes is not None and avg_degree * block * block >= n_nodes
    )  # expected edges per block² tile = avg_degree·block²/n ≥ 1
    if lanes >= 64 and dense_blocks and have("blocks"):
        return "block_mxu"
    if have("rev_binned"):
        if thresholds is not None:
            alpha, beta = thresholds.lookup(family, avg_degree)
            return ExtendSpec(
                direction="auto", alpha=float(alpha), beta=float(beta)
            )
        return "dopt_binned"
    if have("rev"):
        if thresholds is not None:
            alpha, beta = thresholds.lookup(family, avg_degree)
            return ExtendSpec(
                direction="auto", pull="ell",
                alpha=float(alpha), beta=float(beta),
            )
        return "dopt_ell"
    return "ell_push"


def recommend_k(avg_degree: float, n_threads: int = 32) -> int:
    """Paper §5.5 / Fig 13: optimal concurrent source morsels k vs density.
    Degradation onsets observed at k=16/8/4 for avg degree 100/250/500."""
    if avg_degree >= 500:
        return min(4, n_threads)
    if avg_degree >= 250:
        return min(8, n_threads)
    if avg_degree >= 100:
        return min(16, n_threads)
    return n_threads
