"""Morsel dispatching policies (paper §3) as mesh-axis assignments.

A policy decides which mesh axes shard *source morsels* and which partition
the graph into *frontier morsels* (DESIGN.md §2 table). With the production
mesh ``("data", "model")`` of 16×16:

- 1T1S : sources over ("data","model"), graph replicated   (paper §3.1)
- nT1S : sources replicated, graph over ("data","model")   (paper §3.2)
- nTkS : sources over ("data",), graph over ("model",)     (paper §3.3)
         k = 16 × per-device source batch
- nTkMS: nTkS with 64-wide multi-source lane morsels       (paper §3.4)

``recommend_policy`` encodes the paper's robustness findings (§5) as code:
the hybrid is the default; lane packing turns on only when sources saturate
the 64-wide lanes; high average degree caps effective k (cache/HBM locality,
paper §5.5 + Fig 13).

``hybrid_phases`` returns the two policies the *adaptive* hybrid runtime
(repro.runtime.scheduler) executes in sequence: phase 1 issues source-level
morsels (nTkS, per-shard convergence), phase 2 re-dispatches the surviving
morsels as frontier-level morsels (nT1S over every mesh axis) — the paper's
"morsels at both the source node and frontier levels", realized at runtime
instead of as a static mesh assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .collectives import REDISPATCH_OR_IMPL


@dataclasses.dataclass(frozen=True)
class MorselPolicy:
    name: str
    source_axes: tuple[str, ...]  # mesh axes sharding source morsels
    graph_axes: tuple[str, ...]  # mesh axes partitioning the graph
    lanes: int = 1  # 64 => multi-source morsels (MS-BFS)
    or_impl: str = "allgather"  # frontier-union collective (see collectives)

    @property
    def is_multi_source(self) -> bool:
        return self.lanes > 1


def policy_1t1s(
    mesh_axes: Sequence[str] = ("data", "model")
) -> MorselPolicy:
    return MorselPolicy("1T1S", tuple(mesh_axes), ())


def policy_nt1s(
    mesh_axes: Sequence[str] = ("data", "model"), or_impl: str = "allgather"
) -> MorselPolicy:
    return MorselPolicy("nT1S", (), tuple(mesh_axes), or_impl=or_impl)


def policy_ntks(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    or_impl: str = "allgather",
) -> MorselPolicy:
    return MorselPolicy("nTkS", tuple(source_axes), tuple(graph_axes), or_impl=or_impl)


def policy_ntkms(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    lanes: int = 64,
    or_impl: str = "allgather",
) -> MorselPolicy:
    return MorselPolicy(
        "nTkMS", tuple(source_axes), tuple(graph_axes), lanes=lanes, or_impl=or_impl
    )


POLICIES = {
    "1t1s": policy_1t1s,
    "nt1s": policy_nt1s,
    "ntks": policy_ntks,
    "ntkms": policy_ntkms,
}


def hybrid_phases(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    lanes: int = 1,
    or_impl: str = "allgather",
) -> tuple[MorselPolicy, MorselPolicy]:
    """The adaptive hybrid's (phase-1, phase-2) policy pair.

    Phase 1: nTkS (or nTkMS when ``lanes`` > 1) with the caller's
    ``or_impl`` — source morsels over ``source_axes``, graph over
    ``graph_axes``. Phase 2: nT1S over BOTH axis groups with the ring
    frontier union (collectives.REDISPATCH_OR_IMPL): all devices gang up
    on each surviving morsel's frontier.
    """
    p1 = MorselPolicy(
        "nTkMS" if lanes > 1 else "nTkS",
        tuple(source_axes), tuple(graph_axes),
        lanes=lanes, or_impl=or_impl,
    )
    p2 = MorselPolicy(
        "nT1S", (), tuple(source_axes) + tuple(graph_axes),
        lanes=lanes, or_impl=REDISPATCH_OR_IMPL,
    )
    return p1, p2


def recommend_policy(
    n_sources: int,
    n_devices: int,
    avg_degree: float,
    returns_paths: bool = False,
    n_nodes: int | None = None,
    hbm_bytes: int = 16 * 2**30,
) -> str:
    """The paper's conclusions (§5, §7) as a dispatch rule.

    - nTkMS only when sources saturate ≥1 full 64-lane morsel (Fig 14) and,
      for path outputs, when the 536 B/node/morsel upfront allocation fits
      (§5.6's Graph500 OOM).
    - otherwise nTkS — the robust hybrid — everywhere (§5.4 recommendation).
      (1T1S/nT1S are never *better* than nTkS in the paper's study; they are
      kept as explicit baselines, not recommendations.)
    """
    if n_sources >= 64:
        if returns_paths and n_nodes is not None:
            morsels = -(-n_sources // 64)
            upfront = 536 * n_nodes * min(morsels, max(n_devices, 1))
            if upfront > 0.5 * hbm_bytes:
                return "ntks"
        return "ntkms"
    return "ntks"


def recommend_backend(
    edge_compute: str = "sp_lengths",
    avg_degree: float = 8.0,
    n_nodes: int | None = None,
    lanes: int = 1,
    block: int = 128,
) -> str:
    """Physical scan layout for the extension step (core.extend backends).

    The EmptyHeaded lesson as a dispatch rule: pick the layout by expected
    frontier/adjacency density, not globally.

    - ``bellman_ford`` (weighted relax, no monotone visited set): nothing to
      suppress, so bottom-up never wins — stay on the forward push scatter.
    - 64-wide lane morsels on graphs dense at block granularity (expected
      edges per ``block``² tile ≳ 1, i.e. ``avg_degree·block ≳ n``): the
      saturating-matmul block path amortizes one adjacency scan over all
      lanes on the MXU and skips frontier-empty stripes.
    - everything else (BFS-family traversals): the Beamer alpha/beta
      direction switch — push while frontiers are sparse, pull with
      visited-suppression once the frontier's edge mass dominates.
    """
    if edge_compute == "bellman_ford":
        return "ell_push"
    dense_blocks = (
        n_nodes is not None and avg_degree * block * block >= n_nodes
    )  # expected edges per block² tile = avg_degree·block²/n ≥ 1
    if lanes >= 64 and dense_blocks:
        return "block_mxu"
    return "dopt"


def recommend_k(avg_degree: float, n_threads: int = 32) -> int:
    """Paper §5.5 / Fig 13: optimal concurrent source morsels k vs density.
    Degradation onsets observed at k=16/8/4 for avg degree 100/250/500."""
    if avg_degree >= 500:
        return min(4, n_threads)
    if avg_degree >= 250:
        return min(8, n_threads)
    if avg_degree >= 100:
        return min(16, n_threads)
    return n_threads
