"""Morsel dispatching policies (paper §3) as mesh-axis assignments.

A policy decides which mesh axes shard *source morsels* and which partition
the graph into *frontier morsels* (DESIGN.md §2 table). With the production
mesh ``("data", "model")`` of 16×16:

- 1T1S : sources over ("data","model"), graph replicated   (paper §3.1)
- nT1S : sources replicated, graph over ("data","model")   (paper §3.2)
- nTkS : sources over ("data",), graph over ("model",)     (paper §3.3)
         k = 16 × per-device source batch
- nTkMS: nTkS with 64-wide multi-source lane morsels       (paper §3.4)

``recommend_policy`` encodes the paper's robustness findings (§5) as code:
the hybrid is the default; lane packing turns on only when sources saturate
the 64-wide lanes; high average degree caps effective k (cache/HBM locality,
paper §5.5 + Fig 13).

``hybrid_phases`` returns the two policies the *adaptive* hybrid runtime
(repro.runtime.scheduler) executes in sequence: phase 1 issues source-level
morsels (nTkS, per-shard convergence), phase 2 re-dispatches the surviving
morsels as frontier-level morsels (nT1S over every mesh axis) — the paper's
"morsels at both the source node and frontier levels", realized at runtime
instead of as a static mesh assignment.

``recommend_backend`` + ``fit_direction_thresholds`` do the same for the
*physical scan layout* of the extension step (core.extend backends): the
default recommendation is the Beamer direction switch over degree-binned
pull slabs, and its alpha/beta constants — Beamer's hand-tuned CPU values —
can be replaced by thresholds fitted per (dataset-family, degree-bucket)
from the per-iteration scan traces ``benchmarks/direction_opt.py``
accumulates in ``BENCH_direction_opt.json`` — or, online, from the
scheduler's own live sample tap (``AdaptiveScheduler.online_trace``).
The fit minimizes either scan-slot counts (``cost="slots"``, the
deterministic proxy) or probe-measured wall-ms per backend
(``cost="measured"``, schema-v3 traces / the scheduler's lazy
``BackendCostProbe`` annotation).

``BudgetModel`` is the same measure/quantize/serve loop for the hybrid's
*phase-1 iteration budget*: per-(dataset-family, source-degree-bucket)
windows of observed convergence depths, pow2-quantized quantile serving
with DirectionThresholds-style bucket fallback, and mispredict counters
(budget too low => morsels pay a re-dispatch; too high => inert budget
slack) that make the learner's accuracy observable in SchedulerStats.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math
from pathlib import Path
from typing import Mapping, Sequence

import numpy as np

from .collectives import REDISPATCH_OR_IMPL
from .extend import ExtendSpec


def pow2ceil(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 1)."""
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class MorselPolicy:
    name: str
    source_axes: tuple[str, ...]  # mesh axes sharding source morsels
    graph_axes: tuple[str, ...]  # mesh axes partitioning the graph
    lanes: int = 1  # 64 => multi-source morsels (MS-BFS)
    or_impl: str = "allgather"  # frontier-union collective (see collectives)

    @property
    def is_multi_source(self) -> bool:
        return self.lanes > 1


def policy_1t1s(
    mesh_axes: Sequence[str] = ("data", "model")
) -> MorselPolicy:
    return MorselPolicy("1T1S", tuple(mesh_axes), ())


def policy_nt1s(
    mesh_axes: Sequence[str] = ("data", "model"), or_impl: str = "allgather"
) -> MorselPolicy:
    return MorselPolicy("nT1S", (), tuple(mesh_axes), or_impl=or_impl)


def policy_ntks(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    or_impl: str = "allgather",
) -> MorselPolicy:
    return MorselPolicy("nTkS", tuple(source_axes), tuple(graph_axes), or_impl=or_impl)


def policy_ntkms(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    lanes: int = 64,
    or_impl: str = "allgather",
) -> MorselPolicy:
    return MorselPolicy(
        "nTkMS", tuple(source_axes), tuple(graph_axes), lanes=lanes, or_impl=or_impl
    )


POLICIES = {
    "1t1s": policy_1t1s,
    "nt1s": policy_nt1s,
    "ntks": policy_ntks,
    "ntkms": policy_ntkms,
}


def hybrid_phases(
    source_axes: Sequence[str] = ("data",),
    graph_axes: Sequence[str] = ("model",),
    lanes: int = 1,
    or_impl: str = "allgather",
) -> tuple[MorselPolicy, MorselPolicy]:
    """The adaptive hybrid's (phase-1, phase-2) policy pair.

    Phase 1: nTkS (or nTkMS when ``lanes`` > 1) with the caller's
    ``or_impl`` — source morsels over ``source_axes``, graph over
    ``graph_axes``. Phase 2: nT1S over BOTH axis groups with the ring
    frontier union (collectives.REDISPATCH_OR_IMPL): all devices gang up
    on each surviving morsel's frontier.
    """
    p1 = MorselPolicy(
        "nTkMS" if lanes > 1 else "nTkS",
        tuple(source_axes), tuple(graph_axes),
        lanes=lanes, or_impl=or_impl,
    )
    p2 = MorselPolicy(
        "nT1S", (), tuple(source_axes) + tuple(graph_axes),
        lanes=lanes, or_impl=REDISPATCH_OR_IMPL,
    )
    return p1, p2


def recommend_policy(
    n_sources: int,
    n_devices: int,
    avg_degree: float,
    returns_paths: bool = False,
    n_nodes: int | None = None,
    hbm_bytes: int = 16 * 2**30,
) -> str:
    """The paper's conclusions (§5, §7) as a dispatch rule.

    - nTkMS only when sources saturate ≥1 full 64-lane morsel (Fig 14) and,
      for path outputs, when the 536 B/node/morsel upfront allocation fits
      (§5.6's Graph500 OOM).
    - otherwise nTkS — the robust hybrid — everywhere (§5.4 recommendation).
      (1T1S/nT1S are never *better* than nTkS in the paper's study; they are
      kept as explicit baselines, not recommendations.)
    """
    if n_sources >= 64:
        if returns_paths and n_nodes is not None:
            morsels = -(-n_sources // 64)
            upfront = 536 * n_nodes * min(morsels, max(n_devices, 1))
            if upfront > 0.5 * hbm_bytes:
                return "ntks"
        return "ntkms"
    return "ntks"


# ---------------------------------------------------------------------------
# Direction thresholds: Beamer's constants, optionally re-fitted from traces.
# ---------------------------------------------------------------------------

BEAMER_ALPHA = 14.0
BEAMER_BETA = 24.0


def degree_bucket(avg_degree: float) -> int:
    """pow2 bucket id of a workload's average degree (the granularity the
    fitted threshold table is keyed at): 0 for <=1, else ceil(log2)."""
    if avg_degree <= 1.0:
        return 0
    return int(math.ceil(math.log2(avg_degree) - 1e-12))


# ---------------------------------------------------------------------------
# Phase-1 budget learning: per-(dataset-family, source-degree-bucket) model.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BudgetMispredicts:
    """Cumulative phase-1 budget mispredict counters.

    ``too_low`` counts real morsels that survived phase 1 (the budget sat
    below their convergence depth, so they paid a re-dispatch); ``too_high``
    counts converged real morsels whose depth sat strictly under half the
    budget — a smaller pow2 budget would have covered them with room to
    spare. The right-sized band is ``[budget/2, budget]``: serving
    ``pow2ceil(depth + 1)`` for a steady depth never mispredicts (depth
    exactly a pow2 quantizes to ``2·depth``, the band's lower edge).
    ``inert_slots`` is the budget slack
    ``budget - trips`` summed over converged morsels — the iteration slots a
    lockstep phase-1 schedule would have burned inert, and the latency a
    straggler waits under few-device nTkS before its all-device phase 2.
    """

    too_low: int = 0
    too_high: int = 0
    inert_slots: int = 0
    observed: int = 0  # real morsels the counters classified

    @property
    def rate(self) -> float:
        """Mispredicted real morsels per observed real morsel."""
        if not self.observed:
            return 0.0
        return (self.too_low + self.too_high) / self.observed

    def count(self, too_low: int, too_high: int, inert_slots: int,
              observed: int) -> None:
        self.too_low += int(too_low)
        self.too_high += int(too_high)
        self.inert_slots += int(inert_slots)
        self.observed += int(observed)

    def reset(self) -> None:
        self.too_low = self.too_high = self.inert_slots = self.observed = 0


def count_budget_mispredicts(
    budget: int, trips, survived, floor: int = 4
) -> tuple[int, int, int]:
    """Classify one batch's REAL morsels against its phase-1 budget.

    ``trips`` are the morsels' phase-1 iteration counts, ``survived`` the
    phase-1 survivor mask (frontier still live at the budget). Returns
    ``(too_low, too_high, inert_slots)`` per the BudgetMispredicts
    semantics; a budget at the quantization floor never counts too_high
    (no smaller budget was available to pick).
    """
    trips = np.asarray(trips)
    survived = np.asarray(survived, bool)
    conv = trips[~survived]
    too_low = int(survived.sum())
    inert_slots = int(np.maximum(int(budget) - conv, 0).sum())
    too_high = (
        int((conv * 2 < int(budget)).sum()) if int(budget) > floor else 0
    )
    return too_low, too_high, inert_slots


class BudgetModel:
    """Per-(dataset-family, source-degree-bucket) phase-1 budget learner.

    Each key holds a bounded window of observed per-morsel convergence
    depths (final IFE trip counts); ``predict`` serves the window's
    ``quantile`` pow2-quantized (so the budget only compiles O(log
    max_iters) distinct phase-1 engines), with the same fallback chain as
    ``DirectionThresholds.lookup``: exact (family, bucket) -> nearest
    bucket within the family -> nearest bucket across all families ->
    ``None`` (the caller's global-p90 cold path; ``cold_budget`` is what
    the scheduler serves when that path holds no data either). The scheduler feeds it
    only *real* morsels — pad/inert morsels exit at 0 iterations and
    would drag every bucket's budget below its true convergence depth —
    and skips it entirely when ``phase1_iters`` is pinned.

    ``mispredicts`` accumulates the outcome counters for the batches this
    model budgeted (see BudgetMispredicts / count_budget_mispredicts).
    """

    def __init__(self, window: int = 64, quantile: float = 90.0,
                 floor: int = 4, cold_budget: int = 8):
        self.window = int(window)
        self.quantile = float(quantile)
        self.floor = int(floor)
        self.cold_budget = int(cold_budget)
        self._windows: dict[tuple, collections.deque] = {}
        self.mispredicts = BudgetMispredicts()

    def __len__(self) -> int:
        """Number of non-empty (family, bucket) windows."""
        return sum(1 for w in self._windows.values() if w)

    @property
    def n_samples(self) -> int:
        return sum(len(w) for w in self._windows.values())

    def observe(self, family, bucket: int, trips) -> None:
        """Append real-morsel convergence depths to one bucket's window."""
        trips = np.asarray(trips).reshape(-1)
        if trips.size == 0:
            return
        w = self._windows.setdefault(
            (family, int(bucket)), collections.deque(maxlen=self.window)
        )
        w.extend(int(t) for t in trips)

    def observe_batch(self, family, buckets, trips) -> None:
        """Per-morsel (bucket, trip) pairs of one served batch."""
        for b, t in zip(buckets, np.asarray(trips).reshape(-1)):
            self.observe(family, int(b), [int(t)])

    def reset(self) -> None:
        """Drop every learned window (the mispredict telemetry stays —
        it is cumulative accounting, not bucket-keyed state). The
        dispatcher calls this in its graph-delta fence: a mutation moves
        sources between degree buckets, so depths observed under the old
        bucketing must not budget post-delta batches."""
        self._windows.clear()

    def _window_for(self, family, bucket: int):
        w = self._windows.get((family, int(bucket)))
        if w:
            return w
        # nearest bucket within the family, then across all families —
        # ties break toward the smaller bucket id then the family repr,
        # mirroring DirectionThresholds.lookup determinism
        near = [
            (abs(kb - bucket), kb, str(kf), kf)
            for (kf, kb), win in self._windows.items()
            if win and kf == family
        ]
        if not near:
            near = [
                (abs(kb - bucket), kb, str(kf), kf)
                for (kf, kb), win in self._windows.items()
                if win
            ]
        if not near:
            return None
        _, kb, _, kf = min(near, key=lambda t: t[:3])
        return self._windows[(kf, kb)]

    def predict(self, family, bucket: int, max_iters: int) -> int | None:
        """pow2-quantized ``quantile`` of the bucket's window (with the
        lookup fallback chain), clamped to [floor, max_iters]; None when
        the model holds no samples at all."""
        w = self._window_for(family, bucket)
        if w is None:
            return None
        b = pow2ceil(
            int(np.percentile(np.asarray(w, np.float64), self.quantile)) + 1
        )
        return max(self.floor, min(b, int(max_iters)))

    def budget_for(self, family, buckets, max_iters: int) -> int | None:
        """One covering budget for a batch spanning ``buckets``: the max
        of the per-bucket predictions (most morsels should converge
        inside phase 1). None when the model is empty or no bucket is
        given."""
        preds = [
            self.predict(family, b, max_iters) for b in sorted(set(
                int(b) for b in buckets
            ))
        ]
        preds = [p for p in preds if p is not None]
        return max(preds) if preds else None

    def budgets(self, max_iters: int) -> dict:
        """Snapshot of every learned bucket's served budget (reporting)."""
        return {
            k: self.predict(k[0], k[1], max_iters)
            for k, w in sorted(self._windows.items(),
                               key=lambda kv: (str(kv[0][0]), kv[0][1]))
            if w
        }


@dataclasses.dataclass(frozen=True)
class DirectionThresholds:
    """Fitted (alpha, beta) per (dataset-family, degree-bucket).

    ``table`` maps ``(family, bucket)`` to ``(alpha, beta)``; lookups fall
    back family-first (nearest bucket of the same family), then to the
    Beamer defaults — so the table is total over every query even when the
    bench traces only covered a few workload families.
    """

    table: Mapping  # {(family, bucket): (alpha, beta)}
    default: tuple = (BEAMER_ALPHA, BEAMER_BETA)

    def lookup(self, family: str | None, avg_degree: float) -> tuple:
        b = degree_bucket(avg_degree)
        if family is not None:
            if (family, b) in self.table:
                return self.table[(family, b)]
            near = [
                (abs(kb - b), kb, v)
                for (kf, kb), v in self.table.items()
                if kf == family
            ]
            if near:
                return min(near)[2]
        # no family match: nearest bucket across all families, then default
        near = [(abs(kb - b), kb, v) for (_, kb), v in self.table.items()]
        if near:
            return min(near)[2]
        return self.default


#: cap on the per-axis candidate decision boundaries _fit_group searches.
#: Offline bench traces stay well under it (every boundary is searched);
#: the scheduler's ONLINE sample store can hold thousands of near-unique
#: ratios, and an uncapped grid would put an O(|A|·|B|·records) search on
#: the serving path — over the cap the sorted boundary set is subsampled
#: at evenly-spaced ranks (deterministic; Beamer anchors always kept).
MAX_FIT_CANDIDATES = 64


def _boundary_candidates(vals, anchor: float) -> list:
    cands = sorted(set(vals) | {anchor, 0.0})
    if len(cands) <= MAX_FIT_CANDIDATES:
        return cands
    idx = np.linspace(0, len(cands) - 1, MAX_FIT_CANDIDATES).astype(int)
    return sorted({cands[i] for i in idx} | {anchor, 0.0})


def _fit_group(recs: list[tuple], push_key: str, pull_key: str) -> tuple:
    """One (family, bucket) group: pick (alpha, beta) minimizing the total
    per-iteration scan cost the Beamer predicate would have chosen over the
    trace — where "cost" is whatever the caller's (``push_key``,
    ``pull_key``) record fields carry: slot counts under ``cost="slots"``
    (the deterministic proxy), probe-measured wall-ms under
    ``cost="measured"``. ``recs`` are (iteration_record, n) pairs — n
    travels per record, since one group may aggregate same-family
    workloads of different sizes.

    Candidate thresholds come from the trace itself — each iteration's
    ``m_u/m_f`` (resp. ``n/n_f``) ratio is the exact alpha (beta) at which
    that iteration's predicate flips — plus the Beamer defaults, so the
    search space is the set of distinct decision boundaries the trace can
    express (rank-subsampled past MAX_FIT_CANDIDATES — see above). The
    per-candidate cost is numpy-vectorized over the records, keeping the
    in-flight refit cheap enough for the serving path. Deterministic:
    ties break toward the Beamer constants."""
    pts = []
    for r, n in recs:
        if any(
            r.get(k) is None
            for k in ("m_frontier", "m_unexplored", "frontier",
                      push_key, pull_key)
        ):
            continue  # pre-v2 / trimmed / unmeasured record: no sample
        m_f = float(r["m_frontier"])
        m_u = float(r["m_unexplored"])
        n_f = float(r["frontier"])
        pts.append(
            (m_f, m_u, n_f, float(n), float(r[push_key]),
             float(r[pull_key]))
        )
    if not pts:
        return (BEAMER_ALPHA, BEAMER_BETA)
    eps = 1e-9
    alphas = _boundary_candidates(
        (m_u / m_f * (1 + eps) for m_f, m_u, *_ in pts if m_f > 0),
        BEAMER_ALPHA,
    )
    betas = _boundary_candidates(
        (n / n_f * (1 + eps) for _, _, n_f, n, _, _ in pts if n_f > 0),
        BEAMER_BETA,
    )
    arr = np.asarray(pts, np.float64)  # [P, 6]
    m_f, m_u, n_f, n = arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]
    push, pull = arr[:, 4], arr[:, 5]

    def cost(a: float, b: float) -> float:
        use_pull = (m_f * a > m_u) & (n_f * b > n)
        return float(np.where(use_pull, pull, push).sum())

    def key(ab):
        a, b = ab
        return (
            cost(a, b),
            abs(a - BEAMER_ALPHA) + abs(b - BEAMER_BETA),
            a,
            b,
        )

    return min(((a, b) for a in alphas for b in betas), key=key)


def fit_direction_thresholds(
    traces, pull: str = "binned", cost: str = "slots"
) -> DirectionThresholds:
    """Fit per-(dataset-family, degree-bucket) alpha/beta from bench traces.

    ``traces``: a parsed ``BENCH_direction_opt.json`` document (or its
    ``workloads`` list, or a path to the file). ``pull`` selects which
    pull flavor's cost the thresholds optimize for; "binned" is what
    ``recommend_backend`` serves ("fused" targets the Pallas kernel's
    rates under measured cost).

    ``cost`` picks the per-iteration cost fields the fit minimizes:

    - "slots" (default, deterministic): schema-v2 ``push_slots`` /
      ``pull_slots_{pull}`` scan-slot counts — the byte-proportional
      proxy that needs no timing.
    - "measured": ``push_wall_ms`` / ``pull_wall_ms_{pull}`` — wall
      costs from the schema-v3 bench (or ``online_trace(cost=
      "measured")``'s probe-rate annotation), so the fit weighs a slot
      by what it actually costs on this backend pairing.

    Records missing the selected fields are skipped — the fit degrades
    to the Beamer defaults (per group), never fails; a measured-cost fit
    over a slots-only trace is exactly such a degradation.
    """
    if cost not in ("slots", "measured"):
        raise ValueError(f"unknown cost mode: {cost!r}")
    if isinstance(traces, (str, Path)):
        traces = json.loads(Path(traces).read_text())
    workloads = traces.get("workloads", traces) if isinstance(
        traces, dict
    ) else traces
    if cost == "measured":
        push_key, pull_key = "push_wall_ms", f"pull_wall_ms_{pull}"
    else:
        push_key, pull_key = "push_slots", f"pull_slots_{pull}"
    groups: dict[tuple, list] = {}
    for w in workloads:
        # the runtime predicate compares n_f*beta against the PADDED row
        # count (ExtendCtx.n_out), so beta must be fitted against n_pad,
        # not the logical node count; old traces fall back to n
        n = w.get("n_pad", w.get("n"))
        if n is None:
            continue
        fam = w.get("kind", "unknown")
        bucket = degree_bucket(float(w.get("avg_degree", 1.0)))
        recs = groups.setdefault((fam, bucket), [])
        # every backend replays the same frontier trajectory (bit-parity),
        # so the canonical push trace carries the group's cost samples
        be = w.get("backends", {}).get("ell_push", {})
        recs.extend((r, int(n)) for r in be.get("iterations", []))
    table = {
        k: _fit_group(recs, push_key, pull_key)
        for k, recs in groups.items()
    }
    return DirectionThresholds(table=table)


def recommend_backend(
    edge_compute: str = "sp_lengths",
    avg_degree: float = 8.0,
    n_nodes: int | None = None,
    lanes: int = 1,
    block: int = 128,
    family: str | None = None,
    thresholds: DirectionThresholds | None = None,
    operands=None,
):
    """Physical scan layout for the extension step (core.extend backends).

    The EmptyHeaded lesson as a dispatch rule: pick the layout by expected
    frontier/adjacency density, not globally.

    - ``bellman_ford`` (weighted relax, no monotone visited set): nothing to
      suppress, so bottom-up never wins — stay on the forward push scatter.
    - 64-wide lane morsels on graphs dense at block granularity (expected
      edges per ``block``² tile ≳ 1, i.e. ``avg_degree·block ≳ n``): the
      saturating-matmul block path amortizes one adjacency scan over all
      lanes on the MXU and skips frontier-empty stripes.
    - everything else (BFS-family traversals): the Beamer alpha/beta
      direction switch over **degree-binned** pull slabs — push while
      frontiers are sparse, binned pull with visited-suppression once the
      frontier's edge mass dominates. With a fitted ``thresholds`` table
      the switch runs the trace-fitted alpha/beta for this
      (``family``, degree-bucket) instead of Beamer's CPU constants.

    Deterministic and *total*: a pure function of its arguments, and when
    the caller passes the ``operands`` bundle (or a bare EllGraph, like
    every other operand-accepting entry point) it will only ever name a
    backend whose physical operands exist in that bundle (falling back
    toward ``ell_push``, which every bundle carries).
    """
    from .extend import as_operands

    ops = None if operands is None else as_operands(operands)
    have = lambda attr: ops is None or getattr(ops, attr) is not None
    if edge_compute == "bellman_ford":
        return "ell_push"
    dense_blocks = (
        n_nodes is not None and avg_degree * block * block >= n_nodes
    )  # expected edges per block² tile = avg_degree·block²/n ≥ 1
    if edge_compute == "topk_paths":
        # pull-native: the k-slot relax only exists as a reverse-ELL gather
        return "ell_pull"
    if edge_compute == "ppr":
        # additive float diffusion has one order-stable physical form (the
        # push scatter-add); the block matmul would reorder float sums
        return "ell_push"
    if edge_compute == "pattern_counts":
        # exact int32 hop chains: MXU matmuls when the graph is dense at
        # block granularity, else the same sums via the push scatter
        if dense_blocks and have("blocks"):
            return "block_mxu"
        return "ell_push"
    if lanes >= 64 and dense_blocks and have("blocks"):
        return "block_mxu"
    if have("rev_binned"):
        if thresholds is not None:
            alpha, beta = thresholds.lookup(family, avg_degree)
            return ExtendSpec(
                direction="auto", alpha=float(alpha), beta=float(beta)
            )
        return "dopt_binned"
    if have("rev"):
        if thresholds is not None:
            alpha, beta = thresholds.lookup(family, avg_degree)
            return ExtendSpec(
                direction="auto", pull="ell",
                alpha=float(alpha), beta=float(beta),
            )
        return "dopt_ell"
    return "ell_push"


def recommend_k(avg_degree: float, n_threads: int = 32) -> int:
    """Paper §5.5 / Fig 13: optimal concurrent source morsels k vs density.
    Degradation onsets observed at k=16/8/4 for avg degree 100/250/500."""
    if avg_degree >= 500:
        return min(4, n_threads)
    if avg_degree >= 250:
        return min(8, n_threads)
    if avg_degree >= 100:
        return min(16, n_threads)
    return n_threads
