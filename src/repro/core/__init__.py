"""Paper core: IFE engine + morsel dispatching policies (DESIGN.md §1-2)."""
from .edge_compute import EDGE_COMPUTES, NO_PARENT, QUERY_KINDS, QueryKind
from .ife import (
    run_ife,
    run_ife_batch,
    run_ife_scan,
    histogram_lengths,
    reconstruct_paths,
    validate_parents,
    IFEResult,
)
from .policies import (
    MorselPolicy,
    POLICIES,
    BudgetMispredicts,
    BudgetModel,
    DirectionThresholds,
    count_budget_mispredicts,
    degree_bucket,
    fit_direction_thresholds,
    pow2ceil,
    policy_1t1s,
    policy_nt1s,
    policy_ntks,
    policy_ntkms,
    hybrid_phases,
    recommend_policy,
    recommend_backend,
    recommend_k,
)
from .edge_compute import chunk_fold
from .extend import (
    BACKENDS,
    STATS_WIDTH,
    BackendCostProbe,
    ExtendSpec,
    GraphOperands,
    OperandStream,
    as_spec,
    build_operands,
    effective_csr,
    frontier_stats,
    make_backend,
    operand_stream,
)
from .dispatcher import (
    QueryEngine,
    build_engine,
    build_gang_resume_engine,
    build_resume_engine,
    run_recursive_query,
    prepare_graph,
    pad_sources,
    strip_operands,
)
from .collectives import (
    REDISPATCH_OR_IMPL,
    gang_handoff,
    gang_merge_scatter,
    gang_scatter_back,
    or_allreduce,
    min_allreduce,
    ring_or_u32,
)
from .msbfs import (
    active_block_count,
    block_extend_dense,
    block_extend_lanes,
    frontier_block_activity,
    gang_pack_lanes,
    gang_unpack_lanes,
)
from . import frontier
