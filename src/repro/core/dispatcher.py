"""Morsel dispatcher (paper §4.3) — policy → shard_map program.

The paper's ``grabSrcMorselIfNecessary`` hands morsels to threads dynamically;
SPMD TPUs get a *static* schedule instead: source morsels are a sharded array
(one shard per source-axis coordinate), frontier morsels are the graph row
partition, and each device runs the IFE while_loop over its local morsels
(``lax.map`` = the paper's "sticky" worker: it finishes a source morsel before
grabbing the next). Collectives run only over the graph axes, so source groups
iterate independently — divergent per-morsel trip counts across source shards
are safe by construction.

Two engine flavors realize the paper's *hybrid* policy at runtime (§5.4,
driven by ``repro.runtime.scheduler``):

- ``build_engine(..., sync="shard")`` — phase 1: nTkS where the convergence
  check reduces over the graph axes only, so a source-shard group whose
  morsels have all converged exits its while_loop immediately instead of
  burning inert iterations until the globally slowest morsel finishes.
- ``build_resume_engine`` — phase 2: surviving (unconverged) morsels are
  re-dispatched with their saved state under nT1S frontier parallelism:
  every device cooperates on one morsel's frontier at a time, picking up
  at the iteration counter where phase 1 stopped.
- ``build_gang_resume_engine`` — batched phase 2: when more than one morsel
  survives, the survivors are ganged into a single multi-frontier resume
  (one while_loop, per-survivor convergence masks, frontiers lane-packed so
  one adjacency scan serves the gang) instead of draining one-at-a-time
  under ``lax.map``; works in both the replicated and sharded state layouts.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import axis_size, shard_map
from ..graph.csr import CSRGraph, EllGraph, ShardedBlocks
from .collectives import gang_merge_scatter, merge_contribution, merge_scatter
from .edge_compute import EDGE_COMPUTES
from .extend import (
    STATS_WIDTH,
    ExtendCtx,
    ExtendSpec,
    GraphOperands,
    as_operands,
    as_spec,
    build_operands,
    frontier_stats,
    make_backend,
    operand_stream,
)
from .ife import IFEResult
from .policies import MorselPolicy


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def _flat_axis_index(axes: tuple[str, ...]):
    """Flattened coordinate over ``axes`` (major-to-minor = tuple order,
    matching how PartitionSpec((a0, a1)) tiles a dimension)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * axis_size(a) + lax.axis_index(a)
    return idx


def pad_sources(
    sources: np.ndarray, shards: int, lanes: int, inert_id: int
) -> np.ndarray:
    """[(s,)] -> [n_morsels_padded, lanes]; pad entries get ``inert_id``
    (>= n_nodes ⇒ empty lanes, zero-iteration morsels)."""
    s = np.asarray(sources, dtype=np.int32).reshape(-1)
    n_morsels = -(-len(s) // lanes)
    n_morsels = -(-n_morsels // shards) * shards
    out = np.full((n_morsels * lanes,), inert_id, dtype=np.int32)
    out[: len(s)] = s
    return out.reshape(n_morsels, lanes)


@dataclasses.dataclass(frozen=True)
class QueryEngine:
    """A compiled recursive-query executor for one (mesh, policy, graph-shape,
    edge-compute, extension-backend) combination — the paper's IFE physical
    operator."""

    mesh: Mesh
    policy: MorselPolicy
    edge_compute: str
    n_nodes_padded: int
    max_iters: int
    fn: Any  # jitted shard_map program
    extend: ExtendSpec = ExtendSpec()

    def _coerce(self, graph):
        """Accept an EllGraph or any GraphOperands bundle and hand ``fn``
        exactly the operand structure its in_specs declare (push engines
        keep the historical bare-EllGraph calling convention)."""
        return strip_operands(self.extend, as_operands(graph))

    def __call__(self, graph, *args) -> IFEResult:
        """Static/phase-1 engines: ``engine(graph, source_morsels)``.
        Resume engines: ``engine(graph, state0, it0)``."""
        return self.fn(self._coerce(graph), *args)


def strip_operands(spec: ExtendSpec, ops: GraphOperands):
    """Exactly the operands ``spec`` scans (push engines keep the
    historical bare-EllGraph calling convention) — the structure shard_map
    in_specs are derived from, so treedefs always match."""
    if not (spec.needs_rev or spec.needs_binned or spec.needs_blocks):
        return ops.fwd
    if spec.needs_rev and ops.rev is None:
        raise ValueError(
            f"engine extend={spec.backend}/{spec.direction} needs reverse "
            "operands; use prepare_graph(..., extend=spec)"
        )
    if spec.needs_binned and ops.rev_binned is None:
        raise ValueError(
            f"engine extend={spec.backend}/{spec.direction} needs "
            "degree-binned reverse operands; use "
            "prepare_graph(..., extend=spec)"
        )
    if spec.needs_binned_pack and ops.rev_binned_pack is None:
        raise ValueError(
            f"engine extend={spec.backend}/{spec.direction} needs the "
            "fused-kernel binned operand pack; use "
            "prepare_graph(..., extend=spec)"
        )
    if spec.needs_blocks and ops.blocks is None:
        raise ValueError(
            "engine extend=block_mxu needs block operands; use "
            "prepare_graph(..., extend=spec)"
        )
    return GraphOperands(
        fwd=ops.fwd,
        rev=ops.rev if spec.needs_rev else None,
        rev_binned=ops.rev_binned if spec.needs_binned else None,
        rev_binned_pack=(
            ops.rev_binned_pack if spec.needs_binned_pack else None
        ),
        blocks=ops.blocks if spec.needs_blocks else None,
    )


def _operand_specs(spec: ExtendSpec, ga: tuple[str, ...], operands=None):
    """shard_map in_specs for the operand bundle an engine scans.

    Every operand leaf shards its leading (row / stacked-shard) axis over
    the graph axes and replicates the rest. When the actual ``operands``
    bundle is given the spec pytree is derived from its stripped
    structure leaf-by-leaf — required for binned slabs, whose bucket
    count (treedef) is graph-dependent; the hand-built fallback keeps the
    historical operand-free ``build_engine`` calling convention alive for
    specs with graph-independent treedefs."""
    row_leaf = lambda x: P(ga if ga else None, *(None,) * (x.ndim - 1))
    if operands is not None:
        return jax.tree.map(row_leaf, strip_operands(spec, as_operands(operands)))
    if spec.needs_binned:
        raise ValueError(
            "binned-pull engines need the operand bundle to derive "
            "shard_map specs (slab count is graph-dependent); pass "
            "operands=... to build_engine/build_resume_engine"
        )
    ell = EllGraph(
        indices=P(ga if ga else None, None),
        degrees=P(ga if ga else None),
        weights=None,
    )
    if not (spec.needs_rev or spec.needs_blocks):
        return ell
    blocks = None
    if spec.needs_blocks:
        blocks = ShardedBlocks(
            blocks=P(ga if ga else None, None, None, None),
            block_rows=P(ga if ga else None, None),
            block_cols=P(ga if ga else None, None),
        )
    return GraphOperands(
        fwd=ell, rev=ell if spec.needs_rev else None, blocks=blocks
    )


def _stats_bin_widths(ops: GraphOperands):
    """Per-local-row binned slab widths for the stats tap's pull-cost
    columns, derived from the CALL-TIME operands (inv is data, not shape:
    a same-structure graph may bin rows differently); ``None`` (the tap
    records ``-1``) when the engine scans no binned slabs."""
    if ops.rev_binned is None:
        return None
    bn = ops.rev_binned
    wvec = jnp.concatenate([
        jnp.full((s.shape[-2],), s.shape[-1], jnp.float32)
        for s in bn.slabs
    ])  # slab width per binned position (this shard's slice)
    return wvec[bn.inv[0]]


def build_engine(
    mesh: Mesh,
    policy: MorselPolicy,
    edge_compute: str,
    n_nodes_padded: int,
    max_iters: int | None = None,
    state_layout: str = "replicated",
    sync: str = "global",
    extend="ell_push",
    operands=None,
    collect_stats: bool = False,
) -> QueryEngine:
    """``operands``: the graph's GraphOperands bundle (or any graph whose
    stripped structure matches what the engine will be called with). Needed
    to derive shard_map specs for graph-dependent operand treedefs (binned
    pull slabs); optional for the other backends.

    ``collect_stats``: the online-policy sample tap. The engine's fn
    returns ``(IFEResult, stats)`` where ``stats[m, cap, STATS_WIDTH]``
    holds each morsel's per-iteration ``extend.frontier_stats`` record —
    the Beamer predicate's inputs (n_f, m_f, m_u) plus the binned-pull
    scan cost and measured-cost columns (-1 when the operand bundle
    carries no binned slabs) — written into the while_loop carry at the
    state about to extend (row ``it`` is the it-th iteration's sample;
    rows at/after the morsel's trip count stay zero). A pure readout:
    result state is bit-identical to the untapped engine. The adaptive
    scheduler drains these samples into its in-flight
    ``DirectionThresholds`` refit. The resume/gang builders take the
    same flag, so a survivor's post-budget tail feeds the learners too.

    ``state_layout``:

    - "replicated" — paper-faithful: every device holds the FULL per-node
      state of the morsels it works on ("every thread sees the whole next
      frontier"); graph-axis merge is an OR/MIN all-reduce.
    - "sharded" — beyond-paper memory optimization (DESIGN.md §6): each
      device holds only its graph partition's state rows; the merge is an
      OR/MIN *reduce-scatter* (half the wire bytes of allgather+fold, and
      per-device state drops from O(n) to O(n/K) — what lets Graph500-28
      scale MS-BFS morsels fit a 16 GB chip).

    ``sync``:

    - "global" — the loop condition (the paper's checkIfFrontierFinished
      pipeline break) is reduced over source AND graph axes: every device
      runs the same trip count; source shards whose morsels converged early
      burn inert iterations (empty frontier => no-op) until the slowest
      morsel finishes.
    - "shard" — the condition is reduced over the graph axes only. Each
      source-shard group exits as soon as ITS morsels converge. Divergent
      trip counts across source groups are only deadlock-free when every
      collective in the body rendezvous per replica group
      (psum/pmin/all_gather do; a ppermute ring does NOT — it lowers to
      one CollectivePermute spanning every device), so this builder
      degrades any ring flavor (``or_impl="ring"`` unions, the min/sum
      reduce-scatter merges of the sharded layout) to allgather. This is
      phase 1 of the adaptive hybrid: the saved inert iterations are
      handed to ``build_resume_engine`` instead of wasted.
    """
    ec = EDGE_COMPUTES[edge_compute]
    spec = as_spec(extend)
    ga = policy.graph_axes
    sa = policy.source_axes
    cap = int(max_iters if max_iters is not None else n_nodes_padded)
    n = n_nodes_padded
    sharded = state_layout == "sharded" and bool(ga)
    if sync not in ("global", "shard"):
        raise ValueError(f"unknown sync mode: {sync}")
    if not ga:
        sync_axes = ()
    elif sync == "global":
        sync_axes = tuple(sa) + tuple(ga)
    else:
        sync_axes = tuple(ga)
    # sync="shard" lets source-shard groups exit the while_loop at
    # different trip counts. psum/pmin/all_gather rendezvous per replica
    # group, so the divergence is safe — but ppermute lowers to ONE
    # CollectivePermute spanning every device, and the group still
    # iterating deadlocks waiting for the group that already exited. Any
    # ring collective inside the body (or_impl="ring" unions, the
    # min/sum reduce-scatter merges of the sharded layout) must degrade
    # to its allgather flavor here.
    divergent = sync == "shard" and any(
        int(mesh.shape[a]) > 1 for a in sa
    )
    or_impl = (
        "allgather"
        if divergent and policy.or_impl == "ring"
        else policy.or_impl
    )
    scatter_impl = "allgather" if divergent else "ring"

    def worker(graph_in, sources_local: jax.Array):
        ops = as_operands(graph_in)
        be = make_backend(spec)
        rows_local = ops.fwd.indices.shape[0]
        offset = (
            _flat_axis_index(ga) * rows_local if ga else None
        )
        ctx = ExtendCtx(
            n_out=n,
            row_offset=None if sharded else offset,
            row_base=offset if sharded else None,
            axes=tuple(ga),
            or_impl=or_impl,
            sharded=sharded,
        )
        bw = _stats_bin_widths(ops) if collect_stats else None

        def one_morsel(srcs):
            if sharded:
                # init only this shard's rows; out-of-shard sources become
                # the inert id rows_local (mode="drop" scatters vanish)
                local_srcs = jnp.where(
                    (srcs >= offset) & (srcs < offset + rows_local),
                    srcs - offset,
                    rows_local,
                )
                state0 = ec.init(rows_local, local_srcs)
            else:
                state0 = ec.init(n, srcs)

            def cond(carry):
                state, it = carry[0], carry[1]
                active = jnp.any(state.frontier != 0)
                if sync_axes:
                    active = (
                        lax.psum(active.astype(jnp.int32), sync_axes) > 0
                    )
                return active & (it < cap)

            def body(carry):
                state, it = carry[0], carry[1]
                if collect_stats:
                    rec = frontier_stats(ops, state, ctx, bin_widths=bw)
                    stats = lax.dynamic_update_slice_in_dim(
                        carry[2], rec[None, :], it, axis=0
                    )
                contribution = ec.extend(be, ops, state, ctx)
                if sharded:
                    merged = merge_scatter(
                        ec.MERGE, contribution, ga, or_impl,
                        impl=scatter_impl,
                    )
                else:
                    merged = merge_contribution(
                        ec.MERGE, contribution, ga, or_impl
                    )
                out = (ec.apply(state, merged, it), it + 1)
                return out + ((stats,) if collect_stats else ())

            init = (state0, jnp.int32(0))
            if collect_stats:
                init = init + (
                    jnp.zeros((cap, STATS_WIDTH), jnp.float32),
                )
            carry = lax.while_loop(cond, body, init)
            res = IFEResult(state=carry[0], iterations=carry[1])
            return (res, carry[2]) if collect_stats else res

        return lax.map(one_morsel, sources_local)

    g_specs = _operand_specs(spec, ga, operands)
    src_spec = P(sa if sa else None, None)
    if sharded:
        # state rows live on the graph axes: leaves are [morsel, rows, ...]
        lanes = getattr(ec, "LANES", 0)
        probe = jax.eval_shape(
            lambda: ec.init(8, jnp.zeros((max(lanes, 1),), jnp.int32))
        )
        state_spec = jax.tree.map(
            lambda _: P(sa if sa else None, ga), probe
        )
        out_spec = IFEResult(
            state=state_spec, iterations=P(sa if sa else None)
        )
    else:
        out_spec = P(sa if sa else None)
    if collect_stats:
        # stats stack over morsels like iterations: [m, cap, STATS_WIDTH]
        out_spec = (out_spec, P(sa if sa else None))
    fn = jax.jit(
        shard_map(
            worker,
            mesh,
            in_specs=(g_specs, src_spec),
            out_specs=out_spec,
        )
    )
    return QueryEngine(
        mesh=mesh,
        policy=policy,
        edge_compute=edge_compute,
        n_nodes_padded=n,
        max_iters=cap,
        fn=fn,
        extend=spec,
    )


def build_resume_engine(
    mesh: Mesh,
    policy: MorselPolicy,
    edge_compute: str,
    n_nodes_padded: int,
    max_iters: int | None = None,
    extend="ell_push",
    operands=None,
    collect_stats: bool = False,
) -> QueryEngine:
    """Phase-2 (re-dispatch) engine of the adaptive hybrid.

    Takes morsels *mid-flight*: instead of source ids it consumes a stacked
    replicated state pytree (leaves ``[m, n_pad, ...]``) plus per-morsel
    iteration counters ``it0 [m]``, and continues each morsel's IFE loop from
    ``it0`` under ``policy``'s (typically nT1S: graph over ALL mesh axes)
    frontier parallelism. Because BFS-style edge computes are deterministic
    functions of (state, iteration), resuming is bit-identical to having run
    the whole query under one engine. Morsels whose frontier is already
    empty are inert (zero-trip while_loop), so callers may pad the morsel
    batch freely to stabilize trace shapes.

    ``collect_stats``: same tap as ``build_engine`` — ``fn`` returns
    ``(IFEResult, stats)`` with ``stats[m, cap, STATS_WIDTH]``; each
    resumed iteration's record lands at its ABSOLUTE iteration row
    (``it``, which starts at ``it0``), so rows below ``it0`` stay zero
    and phase-1/phase-2 samples for a morsel never collide.

    The returned engine's ``fn`` signature is ``fn(graph, state0, it0)``.
    """
    ec = EDGE_COMPUTES[edge_compute]
    spec = as_spec(extend)
    ga = policy.graph_axes
    sa = policy.source_axes
    if sa:
        raise ValueError(
            "resume engine re-dispatches under frontier parallelism; "
            f"policy must not shard sources (got source_axes={sa})"
        )
    cap = int(max_iters if max_iters is not None else n_nodes_padded)
    sync_axes = tuple(ga)

    def worker(graph_in, state0, it0):
        ops = as_operands(graph_in)
        be = make_backend(spec)
        rows_local = ops.fwd.indices.shape[0]
        offset = _flat_axis_index(ga) * rows_local if ga else None
        ctx = ExtendCtx(
            n_out=n_nodes_padded,
            row_offset=offset,
            axes=tuple(ga),
            or_impl=policy.or_impl,
        )
        bw = _stats_bin_widths(ops) if collect_stats else None

        def one_morsel(args):
            state_m, it_m = args

            def cond(carry):
                state, it = carry[0], carry[1]
                active = jnp.any(state.frontier != 0)
                if sync_axes:
                    active = (
                        lax.psum(active.astype(jnp.int32), sync_axes) > 0
                    )
                return active & (it < cap)

            def body(carry):
                state, it = carry[0], carry[1]
                if collect_stats:
                    rec = frontier_stats(ops, state, ctx, bin_widths=bw)
                    stats = lax.dynamic_update_slice_in_dim(
                        carry[2], rec[None, :], it, axis=0
                    )
                contribution = ec.extend(be, ops, state, ctx)
                merged = merge_contribution(
                    ec.MERGE, contribution, ga, policy.or_impl
                )
                out = (ec.apply(state, merged, it), it + 1)
                return out + ((stats,) if collect_stats else ())

            init = (state_m, it_m)
            if collect_stats:
                init = init + (
                    jnp.zeros((cap, STATS_WIDTH), jnp.float32),
                )
            carry = lax.while_loop(cond, body, init)
            res = IFEResult(state=carry[0], iterations=carry[1])
            return (res, carry[2]) if collect_stats else res

        return lax.map(one_morsel, (state0, it0))

    g_specs = _operand_specs(spec, ga, operands)
    # state/it0 replicated in, outputs replicated (post-merge state is
    # identical on every device of the graph group)
    out_spec = IFEResult(state=P(), iterations=P())
    if collect_stats:
        out_spec = (out_spec, P())
    fn = jax.jit(
        shard_map(
            worker,
            mesh,
            in_specs=(g_specs, P(), P()),
            out_specs=out_spec,
        )
    )
    return QueryEngine(
        mesh=mesh,
        policy=policy,
        edge_compute=edge_compute,
        n_nodes_padded=n_nodes_padded,
        max_iters=cap,
        fn=fn,
        extend=spec,
    )


def build_gang_resume_engine(
    mesh: Mesh,
    policy: MorselPolicy,
    edge_compute: str,
    n_nodes_padded: int,
    max_iters: int | None = None,
    extend="ell_push",
    operands=None,
    state_layout: str = "replicated",
    collect_stats: bool = False,
) -> QueryEngine:
    """Gang-scheduled phase-2 (re-dispatch) engine of the adaptive hybrid.

    Where ``build_resume_engine`` drains survivors one-morsel-at-a-time
    (``lax.map`` is a sequential scan: morsel s+1's while_loop starts only
    after morsel s converges — frontier-level serialization, the exact
    failure mode the hybrid policy exists to avoid), this engine resumes
    the WHOLE survivor batch under ONE while_loop:

    - State arrives stacked ``[S_pad, ...]`` (pow2-padded by the caller for
      stable trace shapes; all-zero pad morsels are inert) plus per-morsel
      iteration counters ``it0 [S_pad]``.
    - Each iteration runs ONE batched multi-frontier extension
      (``ec.gang_extend``): dense survivors are repacked as MS-BFS lanes
      (``core.msbfs.gang_pack_lanes``) so a single shared adjacency scan
      serves the gang, and lane morsels fold into one ``[rows, S*64]``
      tensor.
    - Per-survivor convergence masks keep the batch bit-identical to the
      serial resume: a morsel is *live* while its own frontier is globally
      non-empty AND its own counter is under the cap; state updates and
      counter increments apply only to live morsels (early finishers go
      inert — their state freezes — instead of blocking or overrunning),
      and the loop exits when no morsel is live. Total phase-2 iteration
      slots drop from sum(survivor trips) to max(survivor trips).

    ``state_layout="sharded"`` resumes with state rows sharded over the
    policy's graph axes (all mesh axes under ``hybrid_phases``): the
    per-iteration merge is the OR/MIN reduce-scatter
    (``collectives.gang_merge_scatter``), which is what lets DESIGN.md §6
    billion-node morsels get a phase 2 at all. Callers hand state over via
    ``collectives.gang_handoff``.

    ``collect_stats``: same tap as ``build_engine`` — ``fn`` returns
    ``(IFEResult, stats)`` with ``stats[S_pad, cap, STATS_WIDTH]``.
    Records are written per live morsel at its own ABSOLUTE iteration
    row (counters start at ``it0``); inert/converged morsels' rows are
    left untouched, so the gang tap is sample-identical to draining the
    survivors one-at-a-time through the serial resume tap.

    The returned engine's ``fn`` signature is ``fn(graph, state0, it0)``.
    """
    ec = EDGE_COMPUTES[edge_compute]
    spec = as_spec(extend)
    ga = policy.graph_axes
    sa = policy.source_axes
    if sa:
        raise ValueError(
            "gang resume engine re-dispatches under frontier parallelism; "
            f"policy must not shard sources (got source_axes={sa})"
        )
    cap = int(max_iters if max_iters is not None else n_nodes_padded)
    n = n_nodes_padded
    sharded = state_layout == "sharded" and bool(ga)
    sync_axes = tuple(ga)

    def worker(graph_in, state0, it0):
        ops = as_operands(graph_in)
        be = make_backend(spec)
        rows_local = ops.fwd.indices.shape[0]
        offset = _flat_axis_index(ga) * rows_local if ga else None
        ctx = ExtendCtx(
            n_out=n,
            row_offset=None if sharded else offset,
            row_base=offset if sharded else None,
            axes=tuple(ga),
            or_impl=policy.or_impl,
            sharded=sharded,
        )
        bw = _stats_bin_widths(ops) if collect_stats else None

        def live(state, it):
            # [S_pad] bool: morsels whose own frontier is still globally
            # non-empty and whose own counter is under the cap
            f = state.frontier
            act = (f != 0).reshape(f.shape[0], -1).any(axis=1)
            if sync_axes:
                act = lax.psum(act.astype(jnp.int32), sync_axes) > 0
            return act & (it < cap)

        def cond(carry):
            state, it = carry[0], carry[1]
            return jnp.any(live(state, it))

        def body(carry):
            state, it = carry[0], carry[1]
            mask = live(state, it)
            if collect_stats:
                # one record per live gang member at its OWN absolute
                # iteration row (frontier_stats psums over the graph
                # axes internally, so recs are replicated like iters)
                recs = jax.vmap(
                    lambda st: frontier_stats(ops, st, ctx, bin_widths=bw)
                )(state)
                s_ix = jnp.arange(recs.shape[0])
                idx = jnp.clip(it, 0, cap - 1)
                stats = carry[2].at[s_ix, idx].set(
                    jnp.where(mask[:, None], recs, carry[2][s_ix, idx])
                )
            contribution = ec.gang_extend(be, ops, state, ctx)
            if sharded:
                merged = gang_merge_scatter(
                    ec.MERGE, contribution, ga, policy.or_impl
                )
            else:
                merged = merge_contribution(
                    ec.MERGE, contribution, ga, policy.or_impl
                )
            applied = jax.vmap(ec.apply)(state, merged, it)
            bmask = lambda x: mask.reshape((-1,) + (1,) * (x.ndim - 1))
            new_state = jax.tree.map(
                lambda new, old: jnp.where(bmask(new), new, old),
                applied, state,
            )
            out = (new_state, it + mask.astype(it.dtype))
            return out + ((stats,) if collect_stats else ())

        init = (state0, it0)
        if collect_stats:
            init = init + (
                jnp.zeros((it0.shape[0], cap, STATS_WIDTH), jnp.float32),
            )
        carry = lax.while_loop(cond, body, init)
        res = IFEResult(state=carry[0], iterations=carry[1])
        return (res, carry[2]) if collect_stats else res

    g_specs = _operand_specs(spec, ga, operands)
    if sharded:
        # state rows live on the graph axes: leaves are [gang, rows, ...]
        lanes = getattr(ec, "LANES", 0)
        probe = jax.eval_shape(
            lambda: ec.init(8, jnp.zeros((max(lanes, 1),), jnp.int32))
        )
        state_spec = jax.tree.map(lambda _: P(None, ga), probe)
        in_state, out_spec = state_spec, IFEResult(
            state=state_spec, iterations=P()
        )
    else:
        in_state, out_spec = P(), IFEResult(state=P(), iterations=P())
    if collect_stats:
        out_spec = (out_spec, P())
    fn = jax.jit(
        shard_map(
            worker,
            mesh,
            in_specs=(g_specs, in_state, P()),
            out_specs=out_spec,
        )
    )
    return QueryEngine(
        mesh=mesh,
        policy=policy,
        edge_compute=edge_compute,
        n_nodes_padded=n,
        max_iters=cap,
        fn=fn,
        extend=spec,
    )


def prepare_graph(
    csr: CSRGraph,
    mesh: Mesh,
    policy: MorselPolicy,
    max_deg: int | None = None,
    pad_shards: int | None = None,
    extend="ell_push",
    version: int = 0,
    stream: bool | None = None,
) -> tuple[GraphOperands, int]:
    """Host-side: CSR → padded, device-placed extension operands for this
    policy's mesh: the forward ELL always, plus the reverse ELL, the
    degree-binned reverse slabs, and/or the per-shard block adjacency when
    the ``extend`` spec scans them (all derived from the same truncated
    edge set — backend bit-parity).

    Rows pad to a multiple of shards×pad_block (32, or the MXU tile size
    for block operands) so the sharded-state engine's bit-packed ring
    reduce-scatter stays word-aligned per shard and block tiles divide
    every shard.

    ``pad_shards``: pad rows for this many shards (lcm'd with the policy's
    own shard count) instead of the policy's alone. The adaptive scheduler
    passes ``mesh.size`` so the phase-1 (nTkS, graph over a subset of axes)
    and phase-2 (nT1S, graph over all axes) graphs share one ``n_pad`` and
    state arrays can flow between the two engines unchanged.

    ``stream``: build operands one policy shard at a time and place each
    shard directly on its devices instead of materializing the whole host
    structure first — peak host memory drops to ~1/shards of the wholesale
    build, and under multi-process JAX each process builds only the shards
    its addressable devices own (``None`` = auto: stream exactly when
    ``jax.process_count() > 1``). Falls back to the wholesale build when
    the policy has no graph axes (replicated operands). The placed arrays
    are bitwise-identical to the wholesale path's either way."""
    spec = as_spec(extend)
    k_policy = _axes_size(mesh, policy.graph_axes)
    shards = k_policy
    if pad_shards is not None:
        shards = int(np.lcm(shards, int(pad_shards)))
    if stream is None:
        stream = jax.process_count() > 1
    if stream and policy.graph_axes and k_policy > 1:
        return _prepare_graph_streamed(
            csr, mesh, policy, spec, max_deg, shards, k_policy, version
        )
    # rows pad for the lcm shard count, but binned slabs are built directly
    # at the policy's own shard count (per-shard binning can't reshape)
    ops, n_pad = build_operands(
        csr, spec, max_deg=max_deg, shards=shards, binned_shards=k_policy
    )
    ga = policy.graph_axes
    row_sharding = NamedSharding(mesh, P(ga if ga else None, None))
    deg_sharding = NamedSharding(mesh, P(ga if ga else None))

    def put_ell(g: EllGraph) -> EllGraph:
        return EllGraph(
            indices=jax.device_put(g.indices, row_sharding),
            degrees=jax.device_put(g.degrees, deg_sharding),
            weights=None
            if g.weights is None
            else jax.device_put(g.weights, row_sharding),
        )

    k_shards = k_policy
    rev_binned = None
    rev_binned_pack = None
    leaf_sharding = lambda x: NamedSharding(
        mesh, P(ga if ga else None, *(None,) * (x.ndim - 1))
    )
    if ops.rev_binned is not None:
        bn = ops.rev_binned
        assert bn.rows_local * k_shards == n_pad, (bn.rows_local, k_shards)
        rev_binned = jax.tree.map(
            lambda x: jax.device_put(x, leaf_sharding(x)), bn
        )
    if ops.rev_binned_pack is not None:
        # same stacked-shard leading-axis layout as the jnp slabs
        rev_binned_pack = jax.tree.map(
            lambda x: jax.device_put(x, leaf_sharding(x)),
            ops.rev_binned_pack,
        )
    blocks = None
    if ops.blocks is not None:
        sb = ops.blocks
        if k_shards != shards:
            # operands were padded for more shards than this policy uses
            # (pad_shards lcm) — regroup the stacked tiles per policy shard
            sb = ShardedBlocks(
                blocks=jnp.reshape(
                    sb.blocks,
                    (k_shards, -1, *sb.blocks.shape[2:]),
                ),
                block_rows=_regroup_block_rows(sb, k_shards, n_pad),
                block_cols=jnp.reshape(sb.block_cols, (k_shards, -1)),
            )
        blocks = ShardedBlocks(
            blocks=jax.device_put(
                sb.blocks,
                NamedSharding(mesh, P(ga if ga else None, None, None, None)),
            ),
            block_rows=jax.device_put(
                sb.block_rows, NamedSharding(mesh, P(ga if ga else None, None))
            ),
            block_cols=jax.device_put(
                sb.block_cols, NamedSharding(mesh, P(ga if ga else None, None))
            ),
        )
    ops = GraphOperands(
        fwd=put_ell(ops.fwd),
        rev=None if ops.rev is None else put_ell(ops.rev),
        rev_binned=rev_binned,
        rev_binned_pack=rev_binned_pack,
        blocks=blocks,
        version=version,
    )
    return ops, n_pad


def _regroup_block_rows(sb: ShardedBlocks, k_shards: int, n_pad: int):
    """Re-base local row-block ids when folding ``shards`` stacked shard
    groups into ``k_shards`` coarser policy shards."""
    fine = sb.block_rows.shape[0]
    group = fine // k_shards
    rb_fine = (n_pad // fine) // sb.block_size
    offs = (jnp.arange(fine, dtype=jnp.int32) % group) * rb_fine
    rows = sb.block_rows + offs[:, None]
    return jnp.reshape(rows, (k_shards, -1))


def _device_shard_map(mesh: Mesh, ga, k_policy: int) -> dict:
    """Addressable device → policy-shard index, derived from how a
    ``P(ga)`` sharding chunks a virtual ``[k_policy]`` axis. The grouping
    is leaf-shape independent: every operand leaf shards its axis 0 over
    the same graph axes into ``k_policy`` equal contiguous chunks, so
    chunk ``k``'s device group is the same for all of them."""
    probe = NamedSharding(mesh, P(ga))
    idx_map = probe.addressable_devices_indices_map((k_policy,))
    out = {}
    for d, idx in idx_map.items():
        sl = idx[0]
        out[d] = 0 if sl.start is None else int(sl.start)
    return out


def _prepare_graph_streamed(
    csr: CSRGraph,
    mesh: Mesh,
    policy: MorselPolicy,
    spec: ExtendSpec,
    max_deg: int | None,
    shards: int,
    k_policy: int,
    version: int,
) -> tuple[GraphOperands, int]:
    """Shard-at-a-time, multi-host-aware operand placement.

    Plans the build once (``operand_stream``), then for each policy shard
    owned by an *addressable* device builds only that shard's host leaves,
    places them on its devices, and frees them before the next shard —
    host peak is one shard's bytes, and under multi-process JAX each
    process touches only its local shards. Global arrays are assembled
    from the per-device buffers (``jax.make_array_from_single_device_
    arrays``) under exactly the shardings the wholesale path uses, so
    engines see identical operands."""
    st = operand_stream(
        csr, spec, max_deg=max_deg, shards=shards, binned_shards=k_policy
    )
    n_pad = st.n_pad
    ga = policy.graph_axes
    dev_shard = _device_shard_map(mesh, ga, k_policy)
    local = sorted(set(dev_shard.values()))
    bufs: dict = {}  # leaf name -> list of single-device arrays
    shapes: dict = {}  # leaf name -> global shape
    for k in local:
        piece = st.build_shard(k)
        for name, arr in piece.items():
            shapes.setdefault(
                name, (arr.shape[0] * k_policy, *arr.shape[1:])
            )
            blist = bufs.setdefault(name, [])
            for d, kk in dev_shard.items():
                if kk == k:
                    blist.append(jax.device_put(arr, d))
        del piece  # free this shard's host leaves before the next build
    leaves = {}
    for name, blist in bufs.items():
        shape = shapes[name]
        ndim = len(shape)
        sharding = NamedSharding(mesh, P(ga, *(None,) * (ndim - 1)))
        leaves[name] = jax.make_array_from_single_device_arrays(
            shape, sharding, blist
        )
    return st.assemble(leaves, version=version), n_pad


def run_recursive_query(
    mesh: Mesh,
    csr: CSRGraph,
    sources,
    policy: MorselPolicy,
    edge_compute: str = "sp_lengths",
    max_iters: int | None = None,
    max_deg: int | None = None,
    state_layout: str = "replicated",
    extend="ell_push",
) -> IFEResult:
    """End-to-end: the paper Fig 3 IFETask. Returns states stacked over
    morsels: leaves have leading dim n_morsels (global). ``extend`` selects
    the frontier-extension backend ("ell_push" | "ell_pull" | "pull_binned"
    | "pull_binned_fused" | "block_mxu" | "dopt"/ExtendSpec) — results are
    bit-identical across all of them."""
    spec = as_spec(extend)
    g, n_pad = prepare_graph(csr, mesh, policy, max_deg, extend=spec)
    src_shards = _axes_size(mesh, policy.source_axes)
    morsels = pad_sources(np.asarray(sources), src_shards, policy.lanes, n_pad)
    sa = policy.source_axes
    morsels = jax.device_put(
        jnp.asarray(morsels), NamedSharding(mesh, P(sa if sa else None, None))
    )
    engine = build_engine(
        mesh, policy, edge_compute, n_pad, max_iters,
        state_layout=state_layout, extend=spec, operands=g,
    )
    return engine(g, morsels)
