"""Morsel dispatcher (paper §4.3) — policy → shard_map program.

The paper's ``grabSrcMorselIfNecessary`` hands morsels to threads dynamically;
SPMD TPUs get a *static* schedule instead: source morsels are a sharded array
(one shard per source-axis coordinate), frontier morsels are the graph row
partition, and each device runs the IFE while_loop over its local morsels
(``lax.map`` = the paper's "sticky" worker: it finishes a source morsel before
grabbing the next). Collectives run only over the graph axes, so source groups
iterate independently — divergent per-morsel trip counts across source shards
are safe by construction.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..graph.csr import CSRGraph, EllGraph, ell_from_csr
from ..graph.partition import pad_ell
from .collectives import merge_contribution, merge_scatter
from .edge_compute import EDGE_COMPUTES
from .ife import IFEResult
from .policies import MorselPolicy

try:  # jax >= 0.8 top-level
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def _flat_axis_index(axes: tuple[str, ...]):
    """Flattened coordinate over ``axes`` (major-to-minor = tuple order,
    matching how PartitionSpec((a0, a1)) tiles a dimension)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def pad_sources(
    sources: np.ndarray, shards: int, lanes: int, inert_id: int
) -> np.ndarray:
    """[(s,)] -> [n_morsels_padded, lanes]; pad entries get ``inert_id``
    (>= n_nodes ⇒ empty lanes, zero-iteration morsels)."""
    s = np.asarray(sources, dtype=np.int32).reshape(-1)
    n_morsels = -(-len(s) // lanes)
    n_morsels = -(-n_morsels // shards) * shards
    out = np.full((n_morsels * lanes,), inert_id, dtype=np.int32)
    out[: len(s)] = s
    return out.reshape(n_morsels, lanes)


@dataclasses.dataclass(frozen=True)
class QueryEngine:
    """A compiled recursive-query executor for one (mesh, policy, graph-shape,
    edge-compute) combination — the paper's IFE physical operator."""

    mesh: Mesh
    policy: MorselPolicy
    edge_compute: str
    n_nodes_padded: int
    max_iters: int
    fn: Any  # jitted shard_map program

    def __call__(self, graph: EllGraph, source_morsels: jax.Array) -> IFEResult:
        return self.fn(graph, source_morsels)


def build_engine(
    mesh: Mesh,
    policy: MorselPolicy,
    edge_compute: str,
    n_nodes_padded: int,
    max_iters: int | None = None,
    state_layout: str = "replicated",
) -> QueryEngine:
    """``state_layout``:

    - "replicated" — paper-faithful: every device holds the FULL per-node
      state of the morsels it works on ("every thread sees the whole next
      frontier"); graph-axis merge is an OR/MIN all-reduce.
    - "sharded" — beyond-paper memory optimization (DESIGN.md §6): each
      device holds only its graph partition's state rows; the merge is an
      OR/MIN *reduce-scatter* (half the wire bytes of allgather+fold, and
      per-device state drops from O(n) to O(n/K) — what lets Graph500-28
      scale MS-BFS morsels fit a 16 GB chip).
    """
    ec = EDGE_COMPUTES[edge_compute]
    ga = policy.graph_axes
    sa = policy.source_axes
    cap = int(max_iters if max_iters is not None else n_nodes_padded)
    n = n_nodes_padded
    sharded = state_layout == "sharded" and bool(ga)
    # When the body contains collectives (graph partitioned), every device must
    # execute them the same number of times: the loop condition is the paper's
    # checkIfFrontierFinished pipeline break, globally reduced. Devices whose
    # morsel converged early run inert iterations (empty frontier => no-op)
    # until the slowest source group finishes — the SPMD analogue of nTkS
    # keeping threads busy on other sources' denser frontiers.
    sync_axes = tuple(sa) + tuple(ga) if ga else ()

    def worker(g_shard: EllGraph, sources_local: jax.Array):
        rows_local = g_shard.indices.shape[0]
        offset = (
            _flat_axis_index(ga) * rows_local if ga else None
        )

        def one_morsel(srcs):
            if sharded:
                # init only this shard's rows; out-of-shard sources become
                # the inert id rows_local (mode="drop" scatters vanish)
                local_srcs = jnp.where(
                    (srcs >= offset) & (srcs < offset + rows_local),
                    srcs - offset,
                    rows_local,
                )
                state0 = ec.init(rows_local, local_srcs)
            else:
                state0 = ec.init(n, srcs)

            def cond(carry):
                state, it = carry
                active = jnp.any(state.frontier != 0)
                if sync_axes:
                    active = (
                        lax.psum(active.astype(jnp.int32), sync_axes) > 0
                    )
                return active & (it < cap)

            def body(carry):
                state, it = carry
                if sharded:
                    contribution = ec.local_extend(
                        g_shard, state, None, n_out=n, row_base=offset
                    )
                    merged = merge_scatter(
                        ec.MERGE, contribution, ga, policy.or_impl
                    )
                else:
                    contribution = ec.local_extend(g_shard, state, offset)
                    merged = merge_contribution(
                        ec.MERGE, contribution, ga, policy.or_impl
                    )
                return ec.apply(state, merged, it), it + 1

            state, iters = lax.while_loop(cond, body, (state0, jnp.int32(0)))
            return IFEResult(state=state, iterations=iters)

        return lax.map(one_morsel, sources_local)

    g_specs = EllGraph(
        indices=P(ga if ga else None, None),
        degrees=P(ga if ga else None),
        weights=None,
    )
    src_spec = P(sa if sa else None, None)
    if sharded:
        # state rows live on the graph axes: leaves are [morsel, rows, ...]
        lanes = getattr(ec, "LANES", 0)
        probe = jax.eval_shape(
            lambda: ec.init(8, jnp.zeros((max(lanes, 1),), jnp.int32))
        )
        state_spec = jax.tree.map(
            lambda _: P(sa if sa else None, ga), probe
        )
        out_spec = IFEResult(
            state=state_spec, iterations=P(sa if sa else None)
        )
    else:
        out_spec = P(sa if sa else None)
    fn = jax.jit(
        shard_map(
            worker,
            mesh,
            in_specs=(g_specs, src_spec),
            out_specs=out_spec,
        )
    )
    return QueryEngine(
        mesh=mesh,
        policy=policy,
        edge_compute=edge_compute,
        n_nodes_padded=n,
        max_iters=cap,
        fn=fn,
    )


def prepare_graph(
    csr: CSRGraph, mesh: Mesh, policy: MorselPolicy, max_deg: int | None = None
) -> tuple[EllGraph, int]:
    """Host-side: CSR → padded, device-placed ELL for this policy's mesh.

    Rows pad to a multiple of shards×32 so the sharded-state engine's
    bit-packed ring reduce-scatter stays word-aligned per shard."""
    g = ell_from_csr(csr, max_deg=max_deg)
    shards = _axes_size(mesh, policy.graph_axes)
    g = pad_ell(g, shards, block=32)
    ga = policy.graph_axes
    sharding = NamedSharding(mesh, P(ga if ga else None, None))
    g = EllGraph(
        indices=jax.device_put(g.indices, sharding),
        degrees=jax.device_put(
            g.degrees, NamedSharding(mesh, P(ga if ga else None))
        ),
        weights=None
        if g.weights is None
        else jax.device_put(g.weights, sharding),
    )
    return g, g.indices.shape[0]


def run_recursive_query(
    mesh: Mesh,
    csr: CSRGraph,
    sources,
    policy: MorselPolicy,
    edge_compute: str = "sp_lengths",
    max_iters: int | None = None,
    max_deg: int | None = None,
    state_layout: str = "replicated",
) -> IFEResult:
    """End-to-end: the paper Fig 3 IFETask. Returns states stacked over
    morsels: leaves have leading dim n_morsels (global)."""
    g, n_pad = prepare_graph(csr, mesh, policy, max_deg)
    src_shards = _axes_size(mesh, policy.source_axes)
    morsels = pad_sources(np.asarray(sources), src_shards, policy.lanes, n_pad)
    sa = policy.source_axes
    morsels = jax.device_put(
        jnp.asarray(morsels), NamedSharding(mesh, P(sa if sa else None, None))
    )
    engine = build_engine(
        mesh, policy, edge_compute, n_pad, max_iters,
        state_layout=state_layout,
    )
    return engine(g, morsels)
