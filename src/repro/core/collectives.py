"""Frontier-union collectives (DESIGN.md §6).

Per IFE iteration under nT1S/nTkS/nTkMS, graph shards must union their partial
next-frontier bitmaps across the graph axes. XLA exposes no OR all-reduce
through jax, so we provide three implementations:

- ``pmax``      — unpacked uint8/bool lanes, ``lax.pmax`` (OR ≡ max). True
                  all-reduce, but 8× wire width vs packed bits.
- ``allgather`` — bit-pack to uint32, ``all_gather`` + local OR fold.
                  (K−1)·N/8 wire bytes per device. Paper-faithful baseline
                  ("every thread sees the whole next frontier").
- ``ring``      — bit-pack + manual reduce-scatter/all-gather rings via
                  ``ppermute`` with bitwise-OR combine: 2·(K−1)/K·N/8 bytes.
                  Beyond-paper optimization (§Perf).

All entry points take/return the *unpacked* layout so callers stay oblivious.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..compat import axis_size

PACK = 32

#: Frontier-union impl for the hybrid's re-dispatch phase. Phase 2 runs nT1S
#: with the graph over ALL mesh axes, so the union spans the largest K in the
#: system — where ring's 2·(K−1)/K·N/8 wire bytes beat allgather's (K−1)·N/8
#: by ~2× and pmax's unpacked lanes by ~8×. Phase-1/static engines keep their
#: policy's own ``or_impl`` (allgather is the paper-faithful baseline).
REDISPATCH_OR_IMPL = "ring"


def _pack_bits(x: jax.Array) -> jax.Array:
    """[..., n] bool/uint8 -> [..., ceil(n/32)] uint32."""
    n = x.shape[-1]
    pad = (-n) % PACK
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((*x.shape[:-1], pad), x.dtype)], axis=-1
        )
    w = x.shape[-1] // PACK
    bits = x.reshape(*x.shape[:-1], w, PACK).astype(jnp.uint32)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def _unpack_bits(p: jax.Array, n: int) -> jax.Array:
    """[..., w] uint32 -> [..., n] bool."""
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (p[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*p.shape[:-1], p.shape[-1] * PACK)[..., :n] != 0


def _axis_size(axis_names) -> int:
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    s = 1
    for a in axis_names:
        s *= axis_size(a)
    return s


def ring_or_u32(x: jax.Array, axis_name: str) -> jax.Array:
    """Bitwise-OR all-reduce of a uint32 array over one mesh axis via
    ring reduce-scatter + ring all-gather (ppermute)."""
    K = axis_size(axis_name)
    if K == 1:
        return x
    d = lax.axis_index(axis_name)
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % K
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(K, -1)
    perm = [(i, (i + 1) % K) for i in range(K)]

    # K is static: the rings are UNROLLED python loops so every ppermute is
    # its own HLO op — correct roofline accounting (a fori_loop body would
    # be cost-counted once) and XLA can pipeline the steps
    def rs_body(t, ch):
        send_idx = (d - t) % K
        buf = jnp.take(ch, send_idx, axis=0)
        recv = lax.ppermute(buf, axis_name, perm)
        recv_idx = (d - t - 1) % K
        merged = jnp.take(ch, recv_idx, axis=0) | recv
        return ch.at[recv_idx].set(merged)

    for t in range(K - 1):
        chunks = rs_body(t, chunks)

    def ag_body(t, ch):
        send_idx = (d + 1 - t) % K
        buf = jnp.take(ch, send_idx, axis=0)
        recv = lax.ppermute(buf, axis_name, perm)
        recv_idx = (d - t) % K
        return ch.at[recv_idx].set(recv)

    for t in range(K - 1):
        chunks = ag_body(t, chunks)
    return chunks.reshape(-1)[:n].reshape(shape)


def or_allreduce(
    x: jax.Array, axis_names, impl: str = "ring"
) -> jax.Array:
    """OR-union of a bool/uint8 array across mesh axes. Shape-preserving."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not axis_names or _axis_size(axis_names) == 1:
        return x
    orig_dtype = x.dtype
    if impl == "pmax":
        out = lax.pmax(x.astype(jnp.uint8), axis_names)
        return out.astype(orig_dtype) if orig_dtype != jnp.uint8 else out
    # bit-packed paths
    shape = x.shape
    flat = (x != 0).reshape(1, -1)
    packed = _pack_bits(flat)[0]
    if impl == "allgather":
        for a in axis_names:
            gathered = lax.all_gather(packed, a)  # [K, w]
            packed = jax.lax.reduce(
                gathered,
                jnp.uint32(0),
                lax.bitwise_or,
                dimensions=(0,),
            )
    elif impl == "ring":
        for a in axis_names:
            packed = ring_or_u32(packed, a)
    else:
        raise ValueError(f"unknown or_allreduce impl: {impl}")
    out = _unpack_bits(packed[None], flat.shape[-1])[0].reshape(shape)
    return out.astype(orig_dtype)


def ring_reduce_scatter(x: jax.Array, axis_name: str, op) -> jax.Array:
    """Generic ring reduce-scatter over one mesh axis: x (flat, length
    divisible by K) -> this device's fully-reduced chunk [n/K].
    ``op(a, b)`` combines chunks (e.g. bitwise_or, minimum)."""
    K = axis_size(axis_name)
    flat = x.reshape(-1)
    if K == 1:
        return flat
    d = lax.axis_index(axis_name)
    n = flat.shape[0]
    assert n % K == 0, (n, K)
    chunks = flat.reshape(K, -1)
    perm = [(i, (i + 1) % K) for i in range(K)]

    def rs_body(t, ch):
        send_idx = (d - t) % K
        buf = jnp.take(ch, send_idx, axis=0)
        recv = lax.ppermute(buf, axis_name, perm)
        recv_idx = (d - t - 1) % K
        merged = op(jnp.take(ch, recv_idx, axis=0), recv)
        return ch.at[recv_idx].set(merged)

    for t in range(K - 1):  # unrolled: see ring_or_u32
        chunks = rs_body(t, chunks)
    # device d now owns chunk (d+1)%K; one rotation hands chunk d to d
    owned = jnp.take(chunks, (d + 1) % K, axis=0)
    return lax.ppermute(owned, axis_name, perm)


def allgather_reduce_scatter(x: jax.Array, axis_name: str, op) -> jax.Array:
    """Reduce-scatter over one mesh axis as all-gather + strict left fold
    (device-index order) + own-chunk slice. Same contract as
    ``ring_reduce_scatter`` but built only from group-safe collectives:
    ``all_gather`` compiles with per-replica-group rendezvous, whereas the
    ring's ``ppermute`` lowers to one CollectivePermute whose rendezvous
    spans EVERY device on the mesh. Engines whose while_loop trip count
    can diverge across source-shard groups (``sync="shard"``, the hybrid's
    phase 1) must use this flavor: a ring there deadlocks the groups still
    iterating once the first group exits (the early group never arrives at
    the all-device rendezvous)."""
    K = axis_size(axis_name)
    flat = x.reshape(-1)
    if K == 1:
        return flat
    n = flat.shape[0]
    assert n % K == 0, (n, K)
    gathered = lax.all_gather(flat, axis_name)  # [K, n]
    red = gathered[0]
    for k in range(1, K):  # strict fold: deterministic combine order
        red = op(red, gathered[k])
    d = lax.axis_index(axis_name)
    return lax.dynamic_slice_in_dim(red, d * (n // K), n // K)


def or_reduce_scatter(x: jax.Array, axis_names, impl: str = "ring") -> jax.Array:
    """OR-reduce-scatter of a bool/uint8 array over mesh axes: returns this
    device's row block (length = x.size / prod(K)). Used by the
    sharded-state engine (DESIGN.md §6): per-node state lives only on the
    owning graph shard, so billion-node graphs fit."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    orig_dtype = x.dtype
    shape_tail = x.shape[1:]
    if not axis_names or _axis_size(axis_names) == 1:
        return x
    if impl == "allgather":
        full = or_allreduce(x, axis_names, "allgather")
        # slice own rows
        rows = x.shape[0] // _axis_size(axis_names)
        idx = jnp.int32(0)
        for a in axis_names:
            idx = idx * axis_size(a) + lax.axis_index(a)
        return lax.dynamic_slice_in_dim(full, idx * rows, rows, axis=0)
    # ring on packed bits, sequentially over axes (major axis first)
    flat = (x != 0).reshape(1, -1)
    packed = _pack_bits(flat)[0]
    for a in axis_names:
        packed = ring_reduce_scatter(packed, a, jnp.bitwise_or)
    n_rows = x.shape[0] // _axis_size(axis_names)
    n_bits = n_rows * int(np.prod(shape_tail)) if shape_tail else n_rows
    out = _unpack_bits(packed[None], n_bits)[0]
    return out.reshape(n_rows, *shape_tail).astype(orig_dtype)


def _rs_impl(impl: str):
    if impl == "ring":
        return ring_reduce_scatter
    if impl == "allgather":
        return allgather_reduce_scatter
    raise ValueError(f"unknown reduce-scatter impl: {impl}")


def min_reduce_scatter(x: jax.Array, axis_names, impl: str = "ring") -> jax.Array:
    """Min-reduce-scatter (parents / Bellman-Ford / top-k contributions)."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not axis_names or _axis_size(axis_names) == 1:
        return x
    rs = _rs_impl(impl)
    shape_tail = x.shape[1:]
    flat = x.reshape(-1)
    for a in axis_names:
        flat = rs(flat, a, jnp.minimum)
    n_rows = x.shape[0] // _axis_size(axis_names)
    return flat.reshape(n_rows, *shape_tail)


def sum_reduce_scatter(x: jax.Array, axis_names, impl: str = "ring") -> jax.Array:
    """Sum-reduce-scatter (PPR residual pushes / pattern-count
    contributions). Each shard's additive partial over its local forward
    rows sums exactly once per target row — disjoint edge sets, so either
    impl reconstructs the global sum in a fixed deterministic order (ring:
    ring order; allgather: device-index fold order)."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not axis_names or _axis_size(axis_names) == 1:
        return x
    rs = _rs_impl(impl)
    shape_tail = x.shape[1:]
    flat = x.reshape(-1)
    for a in axis_names:
        flat = rs(flat, a, jnp.add)
    n_rows = x.shape[0] // _axis_size(axis_names)
    return flat.reshape(n_rows, *shape_tail)


def merge_scatter(merge: str, contribution, axis_names, or_impl: str,
                  impl: str = "ring"):
    """Sharded-state variant of merge_contribution: global contributions in,
    this shard's fully-merged row block out.

    ``impl`` selects the min/sum reduce-scatter flavor ("ring" |
    "allgather"); for ``merge="or"`` an ``impl="allgather"`` overrides the
    policy's ``or_impl`` so that NO ppermute ring runs — required inside
    ``sync="shard"`` engine bodies (see ``allgather_reduce_scatter``)."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not axis_names:
        return contribution
    if impl == "allgather" and or_impl == "ring":
        or_impl = "allgather"
    if merge == "or":
        return or_reduce_scatter(contribution, axis_names, or_impl)
    if merge == "min":
        return min_reduce_scatter(contribution, axis_names, impl)
    if merge == "sum":
        return sum_reduce_scatter(contribution, axis_names, impl)
    if merge == "or_min":
        reached, cand = contribution
        return (
            or_reduce_scatter(reached, axis_names, or_impl),
            min_reduce_scatter(cand, axis_names, impl),
        )
    raise ValueError(f"unknown merge: {merge}")


def gang_merge_scatter(merge: str, contribution, axis_names, or_impl: str):
    """Sharded-state merge for *gang-stacked* contributions.

    The gang-scheduled resume carries a leading morsel axis: contribution
    leaves are ``[S, n_out, ...]`` and the row axis to reduce-scatter is
    axis 1, not axis 0. Rotating the gang axis to the back makes rows
    leading again (row-major flattening keeps each device's row block
    contiguous and 32-bit word aligned — rows pad to 32×shards), so the
    existing OR/MIN reduce-scatter rings apply unchanged; the result
    rotates back to ``[S, rows_local, ...]``.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not axis_names or _axis_size(axis_names) == 1:
        return contribution
    move = lambda x: jnp.moveaxis(x, 0, -1)
    unmove = lambda x: jnp.moveaxis(x, -1, 0)
    if merge == "or":
        return unmove(or_reduce_scatter(move(contribution), axis_names, or_impl))
    if merge == "min":
        return unmove(min_reduce_scatter(move(contribution), axis_names))
    if merge == "sum":
        return unmove(sum_reduce_scatter(move(contribution), axis_names))
    if merge == "or_min":
        reached, cand = contribution
        return (
            unmove(or_reduce_scatter(move(reached), axis_names, or_impl)),
            unmove(min_reduce_scatter(move(cand), axis_names)),
        )
    raise ValueError(f"unknown merge: {merge}")


def gang_handoff(state, idx, gang: int, mesh, axes):
    """Phase-1 → phase-2 frontier handoff for the sharded state layout.

    ``state``: the phase-1 stacked state pytree (leaves ``[m, n, ...]``,
    rows sharded over the phase-1 graph axes, morsels over the source
    axes). Gathers the surviving morsels ``idx``, zero-pads the morsel
    axis to the pow2 ``gang`` width (all-zero frontiers are inert in the
    resume loop), and re-places rows over ``axes`` (every mesh axis) —
    the layout the sharded gang-resume engine consumes. XLA lowers the
    re-placement to the all-gather(phase-1 graph axes) + dynamic-slice
    (all axes) handoff; the per-iteration merge inside the resume stays
    the OR/MIN reduce-scatter (``gang_merge_scatter``).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    idxa = jnp.asarray(np.asarray(idx), jnp.int32)
    k = int(idxa.shape[0])

    def pick(x):
        sub = jnp.take(jnp.asarray(x), idxa, axis=0)
        if gang > k:
            pad = jnp.zeros((gang - k,) + sub.shape[1:], sub.dtype)
            sub = jnp.concatenate([sub, pad], axis=0)
        sharding = NamedSharding(
            mesh, P(None, tuple(axes), *(None,) * (sub.ndim - 2))
        )
        return jax.device_put(sub, sharding)

    return jax.tree.map(pick, state)


def gang_scatter_back(full, sub, idx):
    """Inverse handoff: write the ``len(idx)`` resumed survivors (leading
    rows of the padded ``sub`` pytree) back into the stacked phase-1-layout
    ``full`` state; gang pad slots are dropped."""
    idxa = jnp.asarray(np.asarray(idx), jnp.int32)
    k = int(idxa.shape[0])
    return jax.tree.map(
        lambda f, s: jnp.asarray(f).at[idxa].set(s[:k]), full, sub
    )


def min_allreduce(x: jax.Array, axis_names) -> jax.Array:
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not axis_names or _axis_size(axis_names) == 1:
        return x
    return lax.pmin(x, axis_names)


def merge_contribution(merge: str, contribution, axis_names, or_impl: str):
    """Apply an edge compute's MERGE across graph axes."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    if not axis_names:
        return contribution
    if merge == "or":
        return or_allreduce(contribution, axis_names, or_impl)
    if merge == "min":
        return min_allreduce(contribution, axis_names)
    if merge == "sum":
        return lax.psum(contribution, axis_names)
    if merge == "or_min":
        reached, cand = contribution
        return (
            or_allreduce(reached, axis_names, or_impl),
            min_allreduce(cand, axis_names),
        )
    raise ValueError(f"unknown merge: {merge}")
