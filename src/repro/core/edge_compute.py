"""edgeCompute() implementations (paper Listing 2/4) as JAX aggregation ops.

The paper's interface is a per-edge callback ``edgeCompute(u, v)`` mutating
shared auxiliary state under atomics/CAS. The SPMD re-think: an edge compute is
a triple

    local_extend(graph_shard, state) -> contribution        (pure, per shard)
    MERGE  : how contributions combine across graph shards  ('or' | 'min')
    apply(state, merged_contribution, it) -> state          (pure, replicated)

``local_extend`` is the frontier-extension scan (the hot loop the paper
parallelizes with frontier morsels); MERGE is the inter-chip frontier union
(nT1S/nTkS collective); ``apply`` is the pipeline-break at the end of each IFE
iteration (paper's ``checkIfFrontierFinished``).

Supported algorithms:
- ``bfs_levels`` / ``sp_lengths``: unweighted shortest-path lengths
  (paper Listing 2; identical math, both names kept).
- ``sp_parents``: shortest paths with parent edges (paper Listing 4). The CAS
  linked-list Parents structure becomes a deterministic segment-min over
  candidate parents (min node id wins — any parent on a shortest path is valid).
- ``bellman_ford``: weighted SSSP (paper Fig 1's recursive operator).
- ``reachability``: transitive closure from sources.
- ``msbfs_lengths`` / ``msbfs_parents``: 64-lane multi-source variants
  (paper §3.4 / §4.2) with the lane dimension as a tensor axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..graph.csr import EllGraph

INF_U8 = jnp.uint8(255)
NO_PARENT = jnp.int32(2**31 - 1)


def _gang_pack(x: jax.Array) -> jax.Array:
    from .msbfs import gang_pack_lanes

    return gang_pack_lanes(x)


def _gang_unpack(y: jax.Array, gang: int, lanes: int = 0) -> jax.Array:
    from .msbfs import gang_unpack_lanes

    return gang_unpack_lanes(y, gang, lanes)


# ---------------------------------------------------------------------------
# Extension primitives over ELL (pure jnp; Pallas kernels mirror these).
# ---------------------------------------------------------------------------

def _local_rows(frontier: jax.Array, g: EllGraph, row_offset) -> jax.Array:
    """Slice the global per-node array down to this graph shard's rows."""
    rows = g.indices.shape[0]
    if row_offset is None:
        return frontier
    return jax.lax.dynamic_slice_in_dim(frontier, row_offset, rows, axis=0)


def ell_reach_dense(
    g: EllGraph, frontier: jax.Array, row_offset=None, n_out=None
) -> jax.Array:
    """frontier bool -> [n_out] bool: v reached iff some active u has u->v.

    Two state layouts (DESIGN.md §6):
    - replicated: ``frontier`` is global [n]; ``row_offset`` slices this
      shard's rows; ``n_out`` defaults to n.
    - sharded: ``frontier`` is already this shard's rows [rows_local];
      ``row_offset`` is None and ``n_out`` gives the global width.
    Destinations in ``g.indices`` are global ids, so the contribution is
    always global-[n_out]-sized (padding sentinel drops).
    """
    n = frontier.shape[0] if n_out is None else n_out
    local_f = _local_rows(frontier, g, row_offset)
    contrib = jnp.broadcast_to(local_f[:, None], g.indices.shape)
    out = jnp.zeros((n,), dtype=jnp.bool_)
    return out.at[g.indices].max(contrib, mode="drop")


def _deg_chunk(rows: int, width: int, budget: int = 2 << 30) -> int:
    """Degree-dim chunk so the scatter temp [rows, chunk, width] stays under
    ``budget`` bytes (billion-node lane morsels would otherwise materialize a
    rows×max_deg×L broadcast — 31 GB/device for Graph500-28).

    Returns the largest power of two that fits the budget, so the chunk
    divides every pow2-padded slab width exactly. Widths that are NOT a
    chunk multiple (the forward ELL pads to a multiple of 8, not a pow2;
    refined degree buckets can have arbitrary widths) are handled by
    ``chunk_fold``'s static remainder tail — the historical round-to-8
    chunk could land on e.g. 24 against a 32-wide slab and trip the
    divisibility assert."""
    per_slot = max(rows * width, 1)
    c = max(budget // per_slot, 1)
    return 1 << (int(c).bit_length() - 1)


def chunk_fold(D: int, chunk: int, step, acc0):
    """Fold ``step(start, width, acc)`` over the degree axis ``[0, D)`` in
    ``chunk``-sized pieces: a ``fori_loop`` over the full chunks (bounded
    temps, in-place carry) plus ONE statically-shaped remainder tail of
    ``D % chunk`` columns when the chunk does not divide ``D``. ``start``
    may be traced; ``width`` is always a Python int so callers can
    ``dynamic_slice`` with it. Order is ascending-degree-slot either way,
    so order-invariant (OR/min/max/sum-of-int) reductions are bitwise
    equal to the unchunked single-shot fold."""
    full, rem = divmod(D, chunk)
    acc = acc0
    if full == 1 and rem == 0:
        return step(0, D, acc)
    if full:
        acc = jax.lax.fori_loop(
            0, full, lambda i, a: step(i * chunk, chunk, a), acc
        )
    if rem:
        acc = step(full * chunk, rem, acc)
    return acc


def _chunked_scatter(g: EllGraph, out, values_row, chunk: int, reducer: str):
    """Scatter values_row[:, None, :] over degree chunks of g.indices into
    ``out`` via ``chunk_fold`` (bounded temps, in-place carry)."""
    D = g.indices.shape[1]

    def step(start, width, acc):
        idx = (
            g.indices
            if width == D
            else jax.lax.dynamic_slice_in_dim(g.indices, start, width, 1)
        )
        contrib = jnp.broadcast_to(
            values_row[:, None, :], (*idx.shape, values_row.shape[-1])
        )
        return getattr(acc.at[idx], reducer)(contrib, mode="drop")

    if chunk >= D:
        return step(0, D, out)
    return chunk_fold(D, chunk, step, out)


def ell_reach_lanes(
    g: EllGraph, lanes: jax.Array, row_offset=None, n_out=None
) -> jax.Array:
    """[*, L] uint8 -> [n_out, L]: per-lane reach (shared edge scan across
    lanes — the MS-BFS economy; one gather of the neighbor list serves all L
    lanes). Layout contract as in ``ell_reach_dense``."""
    L = lanes.shape[-1]
    n = lanes.shape[0] if n_out is None else n_out
    local = _local_rows(lanes, g, row_offset)
    out = jnp.zeros((n, L), dtype=jnp.uint8)
    chunk = _deg_chunk(local.shape[0], L)
    return _chunked_scatter(g, out, local, chunk, "max")


def ell_min_dist(
    g: EllGraph, dist: jax.Array, frontier: jax.Array, row_offset=None,
    n_out=None,
) -> jax.Array:
    """Weighted relax: cand[v] = min over active u of dist[u] + w(u,v)."""
    n = dist.shape[0] if n_out is None else n_out
    w = g.weights if g.weights is not None else jnp.ones_like(
        g.indices, dtype=jnp.float32
    )
    du = _local_rows(jnp.where(frontier, dist, jnp.inf), g, row_offset)
    cand = du[:, None] + w
    out = jnp.full((n,), jnp.inf, dtype=jnp.float32)
    return out.at[g.indices].min(cand, mode="drop")


def ell_push_sum(
    g: EllGraph, values: jax.Array, row_offset=None, n_out=None,
    normalize: bool = False,
) -> jax.Array:
    """Additive push: out[v] = sum over local rows u with edge u->v of
    values[u] (optionally divided by u's out-degree first). This is the
    ``y += xᵀA`` linear-algebra primitive under the diffusion / pattern-count
    computes, restricted to this shard's rows; padding rows/slots carry the
    sentinel index and drop. Layout contract as in ``ell_reach_dense``."""
    n = values.shape[0] if n_out is None else n_out
    vloc = _local_rows(values, g, row_offset)
    if normalize:
        vloc = vloc / jnp.maximum(g.degrees, 1).astype(vloc.dtype)
    out = jnp.zeros((n, 1), vloc.dtype)
    if g.indices.shape[1] == 0:
        return out[:, 0]
    chunk = _deg_chunk(g.indices.shape[0], 4)
    return _chunked_scatter(g, out, vloc[:, None], chunk, "add")[:, 0]


def ell_min_topk(
    rev: EllGraph, gdists: jax.Array, seed_row: jax.Array
) -> jax.Array:
    """Full-Jacobi k-best relax over the reverse ELL: for each local row v,
    the k smallest of {gdists[u, :] + w(u, v) : u in-neighbor of v} plus v's
    own seed value (0 for sources, +inf otherwise). ``gdists`` is the global
    [n_out, k] sorted slot table; ``seed_row`` is [rows] local. Returns the
    sorted [rows, k] recompute. Degree-chunked: the running top-k merge keeps
    the candidate temp at [rows, (chunk+1)·k] instead of [rows, max_in_deg·k].
    """
    rows, D = rev.indices.shape
    k = gdists.shape[-1]
    acc0 = jnp.full((rows, k), jnp.inf, jnp.float32).at[:, 0].set(seed_row)
    if D == 0:  # edgeless/zero-cap slab: seed-only candidates
        return acc0
    w = (
        rev.weights
        if rev.weights is not None
        else jnp.ones_like(rev.indices, dtype=jnp.float32)
    )

    def step(start, width, acc):
        if width == D:
            idx, wts = rev.indices, w
        else:
            idx = jax.lax.dynamic_slice_in_dim(rev.indices, start, width, 1)
            wts = jax.lax.dynamic_slice_in_dim(w, start, width, 1)
        got = gdists.at[idx].get(mode="fill", fill_value=jnp.inf)
        cand = (got + wts[:, :, None]).reshape(rows, -1)
        merged = jnp.concatenate([acc, cand], axis=1)
        return jnp.sort(merged, axis=1)[:, :k]

    chunk = _deg_chunk(rows, 4 * k)
    if chunk >= D:
        return step(0, D, acc0)
    return chunk_fold(D, chunk, step, acc0)


def _row_ids(g: EllGraph, row_offset, row_base) -> jax.Array:
    """Global node ids of this shard's rows (after any slicing)."""
    rows = g.indices.shape[0]
    base = row_offset if row_offset is not None else row_base
    ids = jnp.arange(rows, dtype=jnp.int32)
    return ids if base is None else ids + base


def ell_min_parent(
    g: EllGraph, frontier: jax.Array, row_offset=None, n_out=None,
    row_base=None,
) -> jax.Array:
    """cand_parent[v] = min active u with edge u->v (NO_PARENT if none).
    ``row_base``: global id of the first local row (sharded layout)."""
    n = frontier.shape[0] if n_out is None else n_out
    local_f = _local_rows(frontier, g, row_offset)
    cand = jnp.where(local_f, _row_ids(g, row_offset, row_base), NO_PARENT)
    cand = jnp.broadcast_to(cand[:, None], g.indices.shape)
    out = jnp.full((n,), NO_PARENT, jnp.int32)
    return out.at[g.indices].min(cand, mode="drop")


def ell_min_parent_lanes(
    g: EllGraph, lanes: jax.Array, row_offset=None, n_out=None, row_base=None
) -> jax.Array:
    """Per-lane min-parent: [*, L] uint8 -> [n_out, L] int32."""
    L = lanes.shape[-1]
    n = lanes.shape[0] if n_out is None else n_out
    local = _local_rows(lanes, g, row_offset)
    u_ids = _row_ids(g, row_offset, row_base)[:, None]
    cand_row = jnp.where(local != 0, u_ids, NO_PARENT)
    out = jnp.full((n, L), NO_PARENT, jnp.int32)
    chunk = _deg_chunk(local.shape[0], 4 * L)
    return _chunked_scatter(g, out, cand_row, chunk, "min")


# ---------------------------------------------------------------------------
# Edge computes.
# ---------------------------------------------------------------------------

class SPLengthState(NamedTuple):
    frontier: jax.Array  # [n] bool
    visited: jax.Array  # [n] bool
    levels: jax.Array  # [n] int32 (-1 = unreached)


class SPLengths:
    """Unweighted shortest-path lengths (paper Listing 2)."""

    MERGE = "or"
    #: safe to fold into 64-lane MS-BFS batches (saturating-OR frontier);
    #: weighted/float/int frontiers have no lane form and must never be
    #: packed (admission checks this flag before nTkMS planning)
    LANES_OK = True

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> SPLengthState:
        f = jnp.zeros((n_nodes,), jnp.bool_).at[sources].set(True, mode="drop")
        levels = jnp.full((n_nodes,), -1, jnp.int32)
        levels = levels.at[sources].set(0, mode="drop")
        return SPLengthState(frontier=f, visited=f, levels=levels)

    @staticmethod
    def local_extend(g: EllGraph, state: SPLengthState, row_offset=None,
                     n_out=None, row_base=None) -> jax.Array:
        return ell_reach_dense(g, state.frontier, row_offset, n_out)

    @staticmethod
    def extend(be, ops, state: SPLengthState, ctx):
        """Backend-pluggable extension (core.extend): same contribution
        contract as ``local_extend``, physical scan chosen by ``be``."""
        return be.reach_dense(ops, state.frontier, state.visited, ctx)

    @staticmethod
    def gang_extend(be, ops, state: SPLengthState, ctx):
        """Batched multi-frontier extension for the gang-scheduled resume:
        state leaves carry a leading gang axis ``[S, ...]``; the S dense
        frontiers are repacked as MS-BFS lanes so one shared adjacency scan
        serves the whole gang. Bit-identical per morsel to ``extend`` (the
        lane scatter/gather computes the same OR per column)."""
        S = state.frontier.shape[0]
        reached = be.reach_lanes(
            ops, _gang_pack(state.frontier), _gang_pack(state.visited), ctx
        )
        return _gang_unpack(reached, S) != 0

    @staticmethod
    def apply(state: SPLengthState, reached: jax.Array, it: jax.Array):
        new = reached & ~state.visited
        return SPLengthState(
            frontier=new,
            visited=state.visited | new,
            levels=jnp.where(new, it + 1, state.levels),
        )


class BFSLevels(SPLengths):
    """Alias — BFS levels are unweighted SP lengths."""


class ReachState(NamedTuple):
    frontier: jax.Array
    visited: jax.Array


class Reachability:
    MERGE = "or"
    LANES_OK = True

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> ReachState:
        f = jnp.zeros((n_nodes,), jnp.bool_).at[sources].set(True, mode="drop")
        return ReachState(frontier=f, visited=f)

    @staticmethod
    def local_extend(g: EllGraph, state: ReachState, row_offset=None,
                     n_out=None, row_base=None) -> jax.Array:
        return ell_reach_dense(g, state.frontier, row_offset, n_out)

    @staticmethod
    def extend(be, ops, state: ReachState, ctx):
        return be.reach_dense(ops, state.frontier, state.visited, ctx)

    @staticmethod
    def gang_extend(be, ops, state: ReachState, ctx):
        S = state.frontier.shape[0]
        reached = be.reach_lanes(
            ops, _gang_pack(state.frontier), _gang_pack(state.visited), ctx
        )
        return _gang_unpack(reached, S) != 0

    @staticmethod
    def apply(state: ReachState, reached: jax.Array, it: jax.Array):
        new = reached & ~state.visited
        return ReachState(frontier=new, visited=state.visited | new)


class SPParentState(NamedTuple):
    frontier: jax.Array
    visited: jax.Array
    levels: jax.Array
    parents: jax.Array  # [n] int32, NO_PARENT where unreached


class SPParents:
    """Shortest paths with parent pointers (paper Listing 4).

    Paper: per-thread memory buffers + CAS into a dense pointer array. SPMD:
    contributions carry (reached, candidate-parent); merged with (or, min).
    """

    MERGE = "or_min"
    LANES_OK = True

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> SPParentState:
        f = jnp.zeros((n_nodes,), jnp.bool_).at[sources].set(True, mode="drop")
        levels = jnp.full((n_nodes,), -1, jnp.int32).at[sources].set(0, mode="drop")
        parents = jnp.full((n_nodes,), NO_PARENT, jnp.int32)
        return SPParentState(frontier=f, visited=f, levels=levels, parents=parents)

    @staticmethod
    def local_extend(g: EllGraph, state: SPParentState, row_offset=None,
                     n_out=None, row_base=None):
        return (
            ell_reach_dense(g, state.frontier, row_offset, n_out),
            ell_min_parent(g, state.frontier, row_offset, n_out, row_base),
        )

    @staticmethod
    def extend(be, ops, state: SPParentState, ctx):
        # paired call: the backend computes both contributions off one
        # frontier union / direction decision
        return be.reach_parent_dense(ops, state.frontier, state.visited, ctx)

    @staticmethod
    def gang_extend(be, ops, state: SPParentState, ctx):
        S = state.frontier.shape[0]
        reached, parents = be.reach_parent_lanes(
            ops, _gang_pack(state.frontier), _gang_pack(state.visited), ctx
        )
        return _gang_unpack(reached, S) != 0, _gang_unpack(parents, S)

    @staticmethod
    def apply(state: SPParentState, merged, it: jax.Array):
        reached, parent_cand = merged
        new = reached & ~state.visited
        return SPParentState(
            frontier=new,
            visited=state.visited | new,
            levels=jnp.where(new, it + 1, state.levels),
            parents=jnp.where(new, parent_cand, state.parents),
        )


class BellmanFordState(NamedTuple):
    frontier: jax.Array
    dist: jax.Array  # [n] float32


class BellmanFord:
    """Weighted SSSP — nodes may re-enter the frontier (walk semantics)."""

    MERGE = "min"
    LANES_OK = False  # float-min relax has no saturating lane form

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> BellmanFordState:
        f = jnp.zeros((n_nodes,), jnp.bool_).at[sources].set(True, mode="drop")
        dist = jnp.full((n_nodes,), jnp.inf, jnp.float32)
        dist = dist.at[sources].set(0.0, mode="drop")
        return BellmanFordState(frontier=f, dist=dist)

    @staticmethod
    def local_extend(g: EllGraph, state: BellmanFordState, row_offset=None,
                     n_out=None, row_base=None) -> jax.Array:
        return ell_min_dist(g, state.dist, state.frontier, row_offset, n_out)

    @staticmethod
    def extend(be, ops, state: BellmanFordState, ctx):
        return be.min_dist(ops, state.dist, state.frontier, ctx)

    @staticmethod
    def gang_extend(be, ops, state: BellmanFordState, ctx):
        # weighted relax has no saturating lane formulation (float min, not
        # OR); batch the gang with vmap instead — still one while_loop for
        # the whole gang, so re-dispatch does not serialize
        return jax.vmap(
            lambda st: BellmanFord.extend(be, ops, st, ctx)
        )(state)

    @staticmethod
    def apply(state: BellmanFordState, cand: jax.Array, it: jax.Array):
        improved = cand < state.dist
        return BellmanFordState(
            frontier=improved, dist=jnp.minimum(state.dist, cand)
        )


class MSBFSState(NamedTuple):
    frontier: jax.Array  # [n, L] uint8
    visited: jax.Array  # [n, L] uint8
    levels: jax.Array  # [n, L] uint8 (255 = unreached)


class MSBFSLengths:
    """Multi-source BFS lengths, L lanes (paper §3.4, Then et al. 2014).

    Levels stored as uint8 (paper stores 1-byte path lengths, §4.2):
    24 bytes/node of frontier+visited state per 64-lane morsel + 1 byte/lane.
    """

    MERGE = "or"
    LANES = 64
    LANES_OK = True

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> MSBFSState:
        L = sources.shape[0]
        f = jnp.zeros((n_nodes, L), jnp.uint8)
        f = f.at[sources, jnp.arange(L)].set(1, mode="drop")
        levels = jnp.full((n_nodes, L), INF_U8, jnp.uint8)
        levels = levels.at[sources, jnp.arange(L)].set(0, mode="drop")
        return MSBFSState(frontier=f, visited=f, levels=levels)

    @staticmethod
    def local_extend(g: EllGraph, state: MSBFSState, row_offset=None,
                     n_out=None, row_base=None) -> jax.Array:
        return ell_reach_lanes(g, state.frontier, row_offset, n_out)

    @staticmethod
    def extend(be, ops, state: MSBFSState, ctx):
        return be.reach_lanes(ops, state.frontier, state.visited, ctx)

    @staticmethod
    def gang_extend(be, ops, state: MSBFSState, ctx):
        # S surviving 64-lane morsels fold into one [rows, S*64] lane
        # tensor: the shared scan now amortizes over S*64 BFS instances
        S, L = state.frontier.shape[0], state.frontier.shape[-1]
        reached = be.reach_lanes(
            ops, _gang_pack(state.frontier), _gang_pack(state.visited), ctx
        )
        return _gang_unpack(reached, S, L)

    @staticmethod
    def apply(state: MSBFSState, reached: jax.Array, it: jax.Array):
        new = (reached & ~state.visited).astype(jnp.uint8)
        lvl = (it + 1).astype(jnp.uint8)
        return MSBFSState(
            frontier=new,
            visited=state.visited | new,
            levels=jnp.where(new != 0, lvl, state.levels),
        )


class MSBFSParentState(NamedTuple):
    frontier: jax.Array
    visited: jax.Array
    levels: jax.Array
    parents: jax.Array  # [n, L] int32


class MSBFSParents:
    """Multi-source BFS with per-lane parents (the memory-hungry variant the
    paper flags: 536 B/node/morsel upfront for paths vs 88 B for lengths)."""

    MERGE = "or_min"
    LANES = 64
    LANES_OK = True

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> MSBFSParentState:
        base = MSBFSLengths.init(n_nodes, sources)
        L = sources.shape[0]
        parents = jnp.full((n_nodes, L), NO_PARENT, jnp.int32)
        return MSBFSParentState(
            frontier=base.frontier,
            visited=base.visited,
            levels=base.levels,
            parents=parents,
        )

    @staticmethod
    def local_extend(g: EllGraph, state: MSBFSParentState, row_offset=None,
                     n_out=None, row_base=None):
        return (
            ell_reach_lanes(g, state.frontier, row_offset, n_out),
            ell_min_parent_lanes(g, state.frontier, row_offset, n_out,
                                 row_base),
        )

    @staticmethod
    def extend(be, ops, state: MSBFSParentState, ctx):
        return be.reach_parent_lanes(ops, state.frontier, state.visited, ctx)

    @staticmethod
    def gang_extend(be, ops, state: MSBFSParentState, ctx):
        S, L = state.frontier.shape[0], state.frontier.shape[-1]
        reached, parents = be.reach_parent_lanes(
            ops, _gang_pack(state.frontier), _gang_pack(state.visited), ctx
        )
        return _gang_unpack(reached, S, L), _gang_unpack(parents, S, L)

    @staticmethod
    def apply(state: MSBFSParentState, merged, it: jax.Array):
        reached, parent_cand = merged
        new = (reached & ~state.visited).astype(jnp.uint8)
        is_new = new != 0
        lvl = (it + 1).astype(jnp.uint8)
        return MSBFSParentState(
            frontier=new,
            visited=state.visited | new,
            levels=jnp.where(is_new, lvl, state.levels),
            parents=jnp.where(is_new, parent_cand, state.parents),
        )


class TopKState(NamedTuple):
    frontier: jax.Array  # [n] bool — some slot of this row improved
    dists: jax.Array  # [n, K] float32, sorted ascending (inf = empty slot)
    src_mask: jax.Array  # [n] bool


class TopKPaths:
    """Weighted top-k shortest-walk lengths (k-slot Bellman-Ford).

    Full-Jacobi pull each round: ``merged[v]`` is the k smallest of v's seed
    value (0 for sources) and ``dists[u, :] + w(u, v)`` over ALL in-neighbors
    u — a recompute, not a frontier-masked delta, so duplicate walks are
    never double-counted. From the seed-only init the recompute is monotone
    non-increasing, hence the engine's generic ``any(frontier != 0)`` loop
    condition terminates exactly at the k-best fixpoint; ``frontier`` marks
    rows whose slot vector improved last round. Pull-only: needs the reverse
    ELL operand (route ``extend='ell_pull'``)."""

    MERGE = "min"
    LANES_OK = False  # k-slot float frontier has no saturating lane form
    K = 4

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> TopKState:
        src = jnp.zeros((n_nodes,), jnp.bool_).at[sources].set(
            True, mode="drop"
        )
        dists = jnp.full((n_nodes, TopKPaths.K), jnp.inf, jnp.float32)
        dists = dists.at[sources, 0].set(0.0, mode="drop")
        return TopKState(frontier=src, dists=dists, src_mask=src)

    @staticmethod
    def local_extend(g: EllGraph, state: TopKState, row_offset=None,
                     n_out=None, row_base=None):
        raise NotImplementedError(
            "top-k relax is pull-only (scans the reverse ELL); run it "
            "through a backend with reverse operands (extend='ell_pull')"
        )

    @staticmethod
    def extend(be, ops, state: TopKState, ctx):
        return be.min_topk(ops, state.dists, state.src_mask, ctx)

    @staticmethod
    def gang_extend(be, ops, state: TopKState, ctx):
        return jax.vmap(
            lambda st: TopKPaths.extend(be, ops, st, ctx)
        )(state)

    @staticmethod
    def apply(state: TopKState, merged: jax.Array, it: jax.Array):
        improved = jnp.any(merged < state.dists, axis=-1)
        return TopKState(
            frontier=improved, dists=merged, src_mask=state.src_mask
        )


class PPRState(NamedTuple):
    frontier: jax.Array  # [n] f32: residual where > EPS, else exactly 0
    residual: jax.Array  # [n] f32
    mass: jax.Array  # [n] f32 — the PPR estimate


class PPRDiffusion:
    """Personalized PageRank via residual diffusion (push-style).

    Every round, all rows with residual above EPS settle at once: ALPHA of
    the settled residual lands in ``mass`` and (1-ALPHA), out-degree
    normalized, diffuses to the out-neighbors (summed across shards with
    MERGE='sum'). The epsilon termination lives in the frontier leaf —
    ``frontier`` holds the residual where it exceeds EPS and exactly 0
    elsewhere, so the engine's generic ``any(frontier != 0)`` loop condition
    IS the residual-mass convergence test; resume/gang builders need no
    modification. Seeds start with residual 1 each (multi-seed results are
    the sum of per-seed PPR vectors — linearity). Dangling rows (out-degree
    0) leak their (1-ALPHA) share, which is what guarantees convergence and
    what the numpy oracle mirrors exactly."""

    MERGE = "sum"
    LANES_OK = False
    ALPHA = 0.15
    EPS = 1e-4

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> PPRState:
        r = jnp.zeros((n_nodes,), jnp.float32).at[sources].set(
            1.0, mode="drop"
        )
        return PPRState(
            frontier=r, residual=r, mass=jnp.zeros((n_nodes,), jnp.float32)
        )

    @staticmethod
    def local_extend(g: EllGraph, state: PPRState, row_offset=None,
                     n_out=None, row_base=None) -> jax.Array:
        push = (1.0 - PPRDiffusion.ALPHA) * state.frontier
        return ell_push_sum(g, push, row_offset, n_out, normalize=True)

    @staticmethod
    def extend(be, ops, state: PPRState, ctx):
        push = (1.0 - PPRDiffusion.ALPHA) * state.frontier
        return be.push_sum(ops, push, ctx, normalize=True)

    @staticmethod
    def gang_extend(be, ops, state: PPRState, ctx):
        return jax.vmap(
            lambda st: PPRDiffusion.extend(be, ops, st, ctx)
        )(state)

    @staticmethod
    def apply(state: PPRState, pushed: jax.Array, it: jax.Array):
        settled = state.frontier  # the residual mass pushed this round
        r = state.residual - settled + pushed
        return PPRState(
            frontier=jnp.where(r > PPRDiffusion.EPS, r, 0.0),
            residual=r,
            mass=state.mass + PPRDiffusion.ALPHA * settled,
        )


class PatternState(NamedTuple):
    frontier: jax.Array  # [n] int32: walk counts of the current hop
    wedges: jax.Array  # [n] int32: 2-hop walk counts seed -> · -> v
    closed: jax.Array  # [n] int32: 3-hop walk counts seed -> · -> · -> v
    src_mask: jax.Array  # [n] bool


class PatternCounts:
    """2–3-hop pattern counts (wedges / triangles) as matmul chains.

    The frontier carries exact int32 walk multiplicities: hop t+1 is
    ``c[v] = Σ_u c[u]·A[u, v]`` — on the block path a chain of MXU matmuls
    over the existing ``ShardedBlocks``, on the push path the same additive
    scatter. After hop 2 the per-node wedge counts (2-walks from the seed
    set) are latched; after hop 3 the closed-walk counts are latched and the
    frontier zeroes itself, so the generic loop condition stops at exactly 3
    iterations. Triangle counts fall out host-side: ``closed`` at a seed row
    counts the directed 3-cycles through that seed (2 per undirected
    triangle); wedge totals are ``wedges.sum()``. Counts are exact (additive
    int32), not saturating — the saturating 0/1 matmul stays the
    reachability path."""

    MERGE = "sum"
    LANES_OK = False
    HOPS = 3

    @staticmethod
    def init(n_nodes: int, sources: jax.Array) -> PatternState:
        src = jnp.zeros((n_nodes,), jnp.bool_).at[sources].set(
            True, mode="drop"
        )
        z = jnp.zeros((n_nodes,), jnp.int32)
        return PatternState(
            frontier=src.astype(jnp.int32), wedges=z, closed=z, src_mask=src
        )

    @staticmethod
    def local_extend(g: EllGraph, state: PatternState, row_offset=None,
                     n_out=None, row_base=None) -> jax.Array:
        return ell_push_sum(g, state.frontier, row_offset, n_out)

    @staticmethod
    def extend(be, ops, state: PatternState, ctx):
        return be.push_sum(ops, state.frontier, ctx)

    @staticmethod
    def gang_extend(be, ops, state: PatternState, ctx):
        return jax.vmap(
            lambda st: PatternCounts.extend(be, ops, st, ctx)
        )(state)

    @staticmethod
    def apply(state: PatternState, pushed: jax.Array, it: jax.Array):
        # it=0 -> pushed = 1-hop counts; it=1 -> 2-hop; it=2 -> 3-hop
        return PatternState(
            frontier=jnp.where(it >= PatternCounts.HOPS - 1, 0, pushed),
            wedges=jnp.where(it == 1, pushed, state.wedges),
            closed=jnp.where(it == 2, pushed, state.closed),
            src_mask=state.src_mask,
        )


EDGE_COMPUTES = {
    "bfs_levels": BFSLevels,
    "sp_lengths": SPLengths,
    "sp_parents": SPParents,
    "bellman_ford": BellmanFord,
    "reachability": Reachability,
    "msbfs_lengths": MSBFSLengths,
    "msbfs_parents": MSBFSParents,
    "topk_paths": TopKPaths,
    "ppr": PPRDiffusion,
    "pattern_counts": PatternCounts,
}


class QueryKind(NamedTuple):
    """One row of the serving-surface query registry: how a client-facing
    ``query_kind`` maps onto edge computes and what comes back.

    ``edge_compute`` is None for the built-in reachability family, where the
    dispatcher still picks sp/msbfs × lengths/parents from (policy,
    returns_paths); every other kind names one compute. ``result_leaves``
    are the state fields delivered per query. ``lanes_ok`` mirrors the
    compute's LANES_OK and gates MS-BFS lane packing at admission."""

    edge_compute: str | None
    result_leaves: tuple
    needs_weights: bool = False
    lanes_ok: bool = True


QUERY_KINDS = {
    "reach": QueryKind(None, ("levels",)),
    "topk_paths": QueryKind(
        "topk_paths", ("dists",), needs_weights=True, lanes_ok=False
    ),
    "ppr": QueryKind("ppr", ("mass",), lanes_ok=False),
    "pattern_counts": QueryKind(
        "pattern_counts", ("wedges", "closed"), lanes_ok=False
    ),
}
