"""Pluggable, density-adaptive frontier-extension backends.

The paper's economy argument is "amount of scans": a morsel policy wins by
touching less adjacency data per iteration. This module makes the *physical
scan layout* of the extension step a per-engine choice (EmptyHeaded's
density-adaptive set layouts; Kuzu's per-operator physical scan selection),
with three backends sharing one contract plus a Beamer-style
direction-optimizing switch:

- ``ell_push``  — forward-ELL scatter (the original path): every local row
  broadcasts its frontier bit down its out-neighbor list. Scan cost is the
  whole ``[rows, max_deg]`` tensor regardless of frontier density.
- ``ell_pull``  — gather over the *reverse* ELL with visited-suppression:
  each unvisited v scans its in-neighbor list and ORs the frontier bits it
  finds — the classic bottom-up win when frontiers are large, because the
  rows that still need scanning (unvisited) shrink every iteration. The
  reverse ELL is one slab padded to ``max_in_deg``, so on heavy-tailed
  graphs (power-law: rev max_deg ≫ mean) each scan still pays
  ``n × max_in_deg`` slots.
- ``pull_binned`` — the same pull contract over **degree-binned reverse
  slabs** (``graph.csr.BinnedRevEll``): reverse rows are permuted into
  pow2-bounded degree buckets, each bucket padded only to its own width,
  and the per-slab gather results are un-permuted back to row order. A
  full scan costs ~``sum(in_deg)`` slots instead of ``n × max_in_deg`` —
  the EmptyHeaded lesson (degree-specialized physical layouts) applied to
  the bottom-up direction, which is what makes pull (and therefore the
  Beamer switch) profitable on skewed graphs.
- ``pull_binned_fused`` — the same contract and the same binned slabs,
  realized by the fused Pallas kernel (``kernels.binned_pull``): per-slab
  gathers, reductions, the un-permute, and the visited suppression in one
  VMEM pass per row tile, with ``pl.when``-gated skipping of fully-visited
  tiles. Bit-identical to ``pull_binned``; the raw-speed realization.
- ``block_mxu`` — the saturating-matmul path over the per-shard block-sparse
  adjacency (``ShardedBlocks``), upgraded to skip frontier-empty source
  row-block *stripes* (a per-row-block activity bitmap masks contributions;
  the Pallas kernel skips the same blocks via scalar-prefetch indices).

``direction="auto"`` realizes Beamer's alpha/beta direction optimization as
a per-iteration ``lax.cond`` between push and pull with fixed shapes, so it
composes with ``jit`` / ``while_loop`` / ``shard_map`` in both the
replicated and sharded state layouts. ``ExtendSpec.pull`` selects the
bottom-up flavor of the switch — ``"ell"`` (padded reverse ELL) or
``"binned"`` (degree-binned slabs; the ``"dopt_binned"`` alias and the
default ``recommend_backend`` path). The decision is a pure, stateless
function of (frontier, visited): pull when the frontier's out-edge mass
exceeds the unexplored edge mass / alpha AND the frontier holds more than
n / beta nodes — alpha/beta default to Beamer's CPU constants and can be
replaced per (dataset-family, degree-bucket) by
``core.policies.fit_direction_thresholds``. Collectives (global-frontier
union, stat psums) are hoisted *outside* the cond so both branches are
collective-free and every device in a sync group takes the same branch.

All backends produce bit-identical final states: push and pull enumerate the
same edge set (reverse operands are derived from the *truncated* forward
graph — see ``graph.csr.truncate_csr``), OR/min merges are order-invariant,
and visited-suppression only changes contribution values that
``ec.apply``'s ``& ~visited`` masks away.

Backends consume a ``GraphOperands`` bundle (forward ELL + optional reverse
ELL + optional degree-binned reverse slabs + optional per-shard blocks)
built once host-side by ``core.dispatcher.prepare_graph`` /
``build_operands``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.binned_pull.ops import (
    BinnedPullPack,
    binned_pull as _fused_pull,
    build_pack as build_binned_pack,
)
from ..graph.csr import (
    BinnedPlan,
    BinnedRevEll,
    CSRGraph,
    EllGraph,
    ShardedBlocks,
    binned_plan,
    binned_rev_csr,
    binned_rev_shard,
    ell_from_csr,
    ell_shard,
    sharded_blocks_from_csr,
    sharded_blocks_nb,
    sharded_blocks_shard,
    truncate_csr,
)
from ..graph.partition import pad_ell, padded_n, reverse_shard
from .collectives import min_allreduce, or_allreduce
from .edge_compute import (
    NO_PARENT,
    _deg_chunk,
    _local_rows,
    chunk_fold,
    ell_min_dist,
    ell_min_parent,
    ell_min_parent_lanes,
    ell_min_topk,
    ell_push_sum,
    ell_reach_dense,
    ell_reach_lanes,
)

BACKENDS = (
    "ell_push", "ell_pull", "pull_binned", "pull_binned_fused", "block_mxu"
)


@dataclasses.dataclass(frozen=True)
class ExtendSpec:
    """Static configuration of the extension step (hashable: engine-cache
    key material and jit static argument)."""

    backend: str = "ell_push"  # one of BACKENDS
    direction: str = "fixed"  # fixed | auto (Beamer push/pull switch)
    alpha: float = 14.0  # pull when m_frontier > m_unexplored / alpha
    beta: float = 24.0  # ... and n_frontier > n / beta
    block: int = 128  # tile size of the block_mxu operand
    pull: str = "binned"  # auto's bottom-up flavor:
    #                       binned slabs | fused-kernel slabs | padded ell

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown extension backend: {self.backend}")
        if self.direction not in ("fixed", "auto"):
            raise ValueError(f"unknown direction mode: {self.direction}")
        if self.pull not in ("binned", "binned_fused", "ell"):
            raise ValueError(f"unknown pull flavor: {self.pull}")
        if self.direction == "auto" and self.backend != "ell_push":
            # the auto switch IS the backend choice (push vs pull); pinning
            # another backend alongside it would be silently ignored
            raise ValueError(
                "direction='auto' switches between push and pull (flavor "
                "chosen by the `pull` field); it cannot be combined with "
                f"backend={self.backend!r}"
            )

    @property
    def needs_rev(self) -> bool:
        """Scans the single padded reverse-ELL slab."""
        return self.backend == "ell_pull" or (
            self.direction == "auto" and self.pull == "ell"
        )

    @property
    def needs_binned(self) -> bool:
        """Scans the degree-binned reverse slabs (the fused kernel keeps
        them too: ``frontier_stats``' pull-slot accounting reads the
        unpadded slab widths)."""
        return self.backend in ("pull_binned", "pull_binned_fused") or (
            self.direction == "auto"
            and self.pull in ("binned", "binned_fused")
        )

    @property
    def needs_binned_pack(self) -> bool:
        """Scans the kernel-ready row-padded repack of the binned slabs."""
        return self.backend == "pull_binned_fused" or (
            self.direction == "auto" and self.pull == "binned_fused"
        )

    @property
    def needs_blocks(self) -> bool:
        return self.direction == "fixed" and self.backend == "block_mxu"

    @property
    def pad_block(self) -> int:
        """Row-padding unit the operands need (block tiles must divide the
        per-shard row count; 32 keeps the bit-packed ring word-aligned)."""
        return self.block if self.needs_blocks else 32


#: convenience aliases accepted anywhere an ExtendSpec is
_ALIASES = {
    "dopt": ExtendSpec(direction="auto"),
    "auto": ExtendSpec(direction="auto"),
    "dopt_ell": ExtendSpec(direction="auto", pull="ell"),
    "dopt_binned": ExtendSpec(direction="auto", pull="binned"),
    "dopt_fused": ExtendSpec(direction="auto", pull="binned_fused"),
}


def as_spec(extend) -> ExtendSpec:
    """Normalize a backend name / alias / spec / None to an ExtendSpec."""
    if extend is None:
        return ExtendSpec()
    if isinstance(extend, ExtendSpec):
        return extend
    if isinstance(extend, str):
        if extend in _ALIASES:
            return _ALIASES[extend]
        return ExtendSpec(backend=extend)
    raise TypeError(f"cannot interpret extend={extend!r}")


@dataclasses.dataclass(frozen=True)
class GraphOperands:
    """The physical scan operands of one graph (or one graph shard).

    ``fwd`` is always present; ``rev`` / ``rev_binned`` / ``blocks`` are
    materialized only when the engine's ExtendSpec needs them (treedefs
    must match shard_map in_specs exactly, so engines carry precisely the
    operands they scan).

    ``version`` is the mutable-graph bookkeeping tag: the dispatcher
    stamps its monotonically increasing ``operands_version`` here when a
    ``GraphDelta`` folds new buffers into the bundle. It is a pytree
    *meta* field, so it must never reach a traced program — a distinct
    version would be a distinct treedef and force a retrace, defeating
    the whole warm-engine design. ``dispatcher.strip_operands`` (the
    mandatory coercion in front of every engine call) rebuilds the bundle
    without it, so traced code only ever sees ``version=0``.
    """

    fwd: EllGraph
    rev: Optional[EllGraph] = None
    rev_binned: Optional[BinnedRevEll] = None
    rev_binned_pack: Optional[BinnedPullPack] = None
    blocks: Optional[ShardedBlocks] = None
    version: int = 0

    @property
    def n_nodes(self) -> int:
        return self.fwd.n_nodes


jax.tree_util.register_dataclass(
    GraphOperands,
    data_fields=["fwd", "rev", "rev_binned", "rev_binned_pack", "blocks"],
    meta_fields=["version"],
)


def as_operands(graph) -> GraphOperands:
    if isinstance(graph, GraphOperands):
        return graph
    return GraphOperands(fwd=graph)


def build_operands(
    csr: CSRGraph,
    extend="ell_push",
    max_deg: int | None = None,
    shards: int = 1,
    block: int | None = None,
    binned_shards: int | None = None,
    version: int = 0,
) -> tuple[GraphOperands, int]:
    """Host-side operand construction (single-host variant; the mesh-aware
    path in ``dispatcher.prepare_graph`` adds device placement).

    Pads rows to a multiple of ``shards * pad_block`` and derives reverse /
    binned / block operands from the *truncated* forward graph so every
    backend scans the identical edge set. ``binned_shards`` overrides the
    shard count the binned slabs are built for (binning is per shard, so
    ``prepare_graph`` bins at the policy's own shard count even when rows
    pad for a larger ``pad_shards`` lcm). Returns (operands, n_pad).
    """
    spec = as_spec(extend)
    pad_block = block or spec.pad_block
    eff = effective_csr(csr, max_deg)
    fwd = pad_ell(ell_from_csr(eff), shards, block=pad_block)
    n_pad = fwd.n_nodes
    rev = None
    if spec.needs_rev:
        rev = pad_ell(ell_from_csr(eff.reverse()), shards, block=pad_block)
        assert rev.n_nodes == n_pad, (rev.n_nodes, n_pad)
    rev_binned = None
    rev_binned_pack = None
    if spec.needs_binned:
        k = shards if binned_shards is None else int(binned_shards)
        rev_binned = binned_rev_csr(eff, n_pad, k)
        if spec.needs_binned_pack:
            rev_binned_pack = build_binned_pack(rev_binned, n_pad)
    blocks = None
    if spec.needs_blocks:
        blocks = sharded_blocks_from_csr(eff, n_pad, shards, spec.block)
    return (
        GraphOperands(
            fwd=fwd,
            rev=rev,
            rev_binned=rev_binned,
            rev_binned_pack=rev_binned_pack,
            blocks=blocks,
            version=version,
        ),
        n_pad,
    )


def effective_csr(csr: CSRGraph, max_deg: int | None) -> CSRGraph:
    """The edge set every backend scans under a ``max_deg`` cap: the cap is
    the ELL row width (max_deg rounded up to the ELL pad multiple) —
    matching the historical ``ell_from_csr(csr, max_deg)`` semantics so
    capped queries return the same results as the seed engine."""
    cap = None if max_deg is None else -(-int(max_deg) // 8) * 8
    return truncate_csr(csr, cap)


def _round8(cap: int) -> int:
    return -(-cap // 8) * 8 if cap > 0 else 0


@dataclasses.dataclass(frozen=True)
class OperandStream:
    """Shard-at-a-time operand construction (the streamed half of
    ``build_operands``).

    ``operand_stream`` runs the global O(n) planning passes once (row
    padding, ELL widths, the binned-slab plan, the common block tile
    count); ``build_shard(k)`` then materializes only policy shard ``k``'s
    leaves as host numpy arrays — peak host memory is one shard's operand
    bytes plus the resident CSR, instead of the whole padded structure.
    Every leaf's axis 0 is the sharded axis (rows for ELL leaves, the
    stacked shard axis for binned/pack/block leaves), and a shard's piece
    is exactly ``global_shape[0] // k_shards`` entries of it, so the
    caller can place pieces per device and assemble global arrays
    (``dispatcher.prepare_graph(stream=True)``) or concatenate them into
    the wholesale host structure. Bitwise-identical to ``build_operands``
    by construction — see the per-shard builders' docstrings for why.
    """

    csr: CSRGraph  # effective (truncated) forward graph
    spec: ExtendSpec
    n_pad: int
    k_shards: int  # policy shard count — the build granularity
    fine_shards: int  # row-padding (lcm) shard count; blocks built fine
    cap_fwd: int
    cap_rev: Optional[int] = None
    plan: Optional[BinnedPlan] = None
    nb: Optional[int] = None

    @property
    def rows_local(self) -> int:
        return self.n_pad // self.k_shards

    def build_shard(self, k: int) -> dict:
        """Policy shard ``k``'s operand leaves: flat dict name → host
        numpy array (the key set is identical across shards)."""
        rl = self.rows_local
        lo, hi = k * rl, (k + 1) * rl
        leaves = {}
        idx, degs, w = ell_shard(self.csr, lo, hi, self.cap_fwd, self.n_pad)
        leaves["fwd.indices"], leaves["fwd.degrees"] = idx, degs
        if w is not None:
            leaves["fwd.weights"] = w
        rev_local = None
        if self.spec.needs_rev or self.spec.needs_binned:
            rev_local = reverse_shard(self.csr, lo, hi)
        if self.spec.needs_rev:
            idx, degs, w = ell_shard(rev_local, 0, rl, self.cap_rev,
                                     self.n_pad)
            leaves["rev.indices"], leaves["rev.degrees"] = idx, degs
            if w is not None:
                leaves["rev.weights"] = w
        if self.spec.needs_binned:
            bn = binned_rev_shard(self.plan, k, rev_local)
            leaves["bn.perm"], leaves["bn.inv"] = bn.perm, bn.inv
            for b, s in enumerate(bn.slabs):
                leaves[f"bn.slab{b}"] = s
            if bn.slab_weights is not None:
                for b, s in enumerate(bn.slab_weights):
                    leaves[f"bn.w{b}"] = s
            if self.spec.needs_binned_pack:
                pk = build_binned_pack(bn, self.n_pad, as_numpy=True)
                leaves["pack.inv_pad"] = pk.inv_pad
                leaves["pack.perm_pad"] = pk.perm_pad
                for b, s in enumerate(pk.slabs):
                    leaves[f"pack.slab{b}"] = s
                if pk.slab_weights is not None:
                    for b, s in enumerate(pk.slab_weights):
                        leaves[f"pack.w{b}"] = s
        if self.spec.needs_blocks:
            group = self.fine_shards // self.k_shards
            B = self.spec.block
            sb = sharded_blocks_shard(
                self.csr, self.n_pad, self.fine_shards, self.nb,
                k * group, (k + 1) * group, B,
            )
            # fold the fine subshards into one policy shard, re-basing the
            # local row-block ids exactly like ``_regroup_block_rows``
            rb_fine = (self.n_pad // self.fine_shards) // B
            offs = (np.arange(group, dtype=np.int32) * rb_fine)[:, None]
            leaves["blocks.blocks"] = sb.blocks.reshape(1, -1, B, B)
            leaves["blocks.rows"] = (
                (sb.block_rows + offs).reshape(1, -1).astype(np.int32)
            )
            leaves["blocks.cols"] = sb.block_cols.reshape(1, -1)
        return leaves

    def assemble(self, g: dict, version: int = 0) -> GraphOperands:
        """Rebuild ``GraphOperands`` from assembled global leaves (same
        key set ``build_shard`` emits; values may be jax or numpy)."""

        def ell(p):
            if f"{p}.indices" not in g:
                return None
            return EllGraph(
                indices=g[f"{p}.indices"],
                degrees=g[f"{p}.degrees"],
                weights=g.get(f"{p}.weights"),
            )

        bn = None
        pack = None
        if "bn.inv" in g:
            nb = len(self.plan.widths)
            bn = BinnedRevEll(
                slabs=tuple(g[f"bn.slab{b}"] for b in range(nb)),
                perm=g["bn.perm"],
                inv=g["bn.inv"],
                slab_weights=(
                    tuple(g[f"bn.w{b}"] for b in range(nb))
                    if "bn.w0" in g
                    else None
                ),
            )
            if "pack.inv_pad" in g:
                nnz = nb - 1
                pack = BinnedPullPack(
                    slabs=tuple(
                        g[f"pack.slab{b}"] for b in range(nnz)
                    ),
                    inv_pad=g["pack.inv_pad"],
                    perm_pad=g["pack.perm_pad"],
                    slab_weights=(
                        tuple(g[f"pack.w{b}"] for b in range(nnz))
                        if "pack.w0" in g
                        else None
                    ),
                )
        blocks = None
        if "blocks.blocks" in g:
            blocks = ShardedBlocks(
                blocks=g["blocks.blocks"],
                block_rows=g["blocks.rows"],
                block_cols=g["blocks.cols"],
            )
        return GraphOperands(
            fwd=ell("fwd"),
            rev=ell("rev"),
            rev_binned=bn,
            rev_binned_pack=pack,
            blocks=blocks,
            version=version,
        )


def operand_stream(
    csr: CSRGraph,
    extend="ell_push",
    max_deg: int | None = None,
    shards: int = 1,
    block: int | None = None,
    binned_shards: int | None = None,
) -> OperandStream:
    """Plan a streamed (shard-at-a-time) operand build — the counterpart
    of ``build_operands`` whose per-shard results are bitwise-identical to
    the wholesale build's slices. Same parameter semantics: rows pad for
    ``shards`` (the lcm count), binned slabs build at ``binned_shards``
    (the policy's own shard count), which is also the streaming
    granularity."""
    spec = as_spec(extend)
    pad_block = block or spec.pad_block
    eff = effective_csr(csr, max_deg)
    n = eff.n_nodes
    fine = max(int(shards), 1)
    k = fine if binned_shards is None else int(binned_shards)
    assert fine % k == 0, (fine, k)
    n_pad = padded_n(n, fine, pad_block)
    cap_fwd = _round8(int(eff.degrees.max()) if n else 0)
    cap_rev = None
    plan = None
    nb = None
    if spec.needs_rev or spec.needs_binned:
        rev_degs = (
            np.bincount(eff.indices, minlength=n)
            if n
            else np.zeros(0, np.int64)
        )
        if spec.needs_rev:
            cap_rev = _round8(int(rev_degs.max()) if n else 0)
        if spec.needs_binned:
            plan = binned_plan(rev_degs, n_pad, k)
    if spec.needs_blocks:
        nb = sharded_blocks_nb(eff, n_pad, fine, spec.block)
    return OperandStream(
        csr=eff,
        spec=spec,
        n_pad=n_pad,
        k_shards=k,
        fine_shards=fine,
        cap_fwd=cap_fwd,
        cap_rev=cap_rev,
        plan=plan,
        nb=nb,
    )


@dataclasses.dataclass(frozen=True)
class ExtendCtx:
    """Per-trace extension context (fields may be traced values).

    Layout contract mirrors ``edge_compute``: replicated state passes
    ``row_offset`` (slice the global array to this shard's rows) and global
    state tensors; sharded state passes local-row tensors with
    ``row_base`` = global id of the first local row. ``axes`` are the graph
    mesh axes collectives may span; ``sharded`` selects the local-row state
    convention.
    """

    n_out: int
    row_offset: object = None  # traced int or None (replicated layout)
    row_base: object = None  # traced int or None (sharded layout)
    axes: tuple = ()
    or_impl: str = "allgather"
    sharded: bool = False

    @property
    def start(self):
        """Global row id of the first local row (0 on a single shard)."""
        if self.row_offset is not None:
            return self.row_offset
        if self.row_base is not None:
            return self.row_base
        return None


def _place_rows(local: jax.Array, ctx: ExtendCtx, fill) -> jax.Array:
    """Embed a local-rows result into the global [n_out, ...] contribution
    (identity on a single full-width shard)."""
    start = ctx.start
    if start is None:
        return local
    out = jnp.full((ctx.n_out, *local.shape[1:]), fill, local.dtype)
    return lax.dynamic_update_slice(
        out, local, (start,) + (0,) * (local.ndim - 1)
    )


def _local_state(x: jax.Array, rows: int, ctx: ExtendCtx) -> jax.Array:
    """This shard's rows of a state tensor (sharded state is already local)."""
    if ctx.sharded or ctx.row_offset is None:
        return x
    return lax.dynamic_slice_in_dim(x, ctx.row_offset, rows, axis=0)


# ---------------------------------------------------------------------------
# ell_push — forward scatter (the original primitives, unchanged math).
# ---------------------------------------------------------------------------


def _min_topk_pull(ops, dists, src_mask, ctx):
    """Shared top-k relax: a full-Jacobi gather over the reverse ELL — the
    only physical form (a scatter cannot sorted-merge k slots), so every
    backend routes here. The slot table is globalized first (sharded rows
    place-with-inf + min-allreduce, the same inverse pattern as pull
    min_dist); contributions come back row-placed for the 'min' merge."""
    if ops.rev is None:
        raise ValueError(
            "top-k relax scans the reverse ELL; build operands with "
            "extend='ell_pull' (needs_rev)"
        )
    rev = ops.rev
    rows = rev.indices.shape[0]
    gd = _global_min(dists, ctx, jnp.float32(jnp.inf))
    seed = jnp.where(
        _local_state(src_mask, rows, ctx), 0.0, jnp.inf
    ).astype(jnp.float32)
    return _place_rows(ell_min_topk(rev, gd, seed), ctx, jnp.float32(jnp.inf))


class PushBackend:
    name = "ell_push"

    @staticmethod
    def reach_dense(ops, frontier, visited, ctx):
        return ell_reach_dense(ops.fwd, frontier, ctx.row_offset, ctx.n_out)

    @staticmethod
    def push_sum(ops, values, ctx, normalize=False):
        return ell_push_sum(
            ops.fwd, values, ctx.row_offset, ctx.n_out, normalize
        )

    min_topk = staticmethod(_min_topk_pull)

    @staticmethod
    def reach_lanes(ops, lanes, visited, ctx):
        return ell_reach_lanes(ops.fwd, lanes, ctx.row_offset, ctx.n_out)

    @staticmethod
    def min_parent(ops, frontier, visited, ctx):
        return ell_min_parent(
            ops.fwd, frontier, ctx.row_offset, ctx.n_out, ctx.row_base
        )

    @staticmethod
    def min_parent_lanes(ops, lanes, visited, ctx):
        return ell_min_parent_lanes(
            ops.fwd, lanes, ctx.row_offset, ctx.n_out, ctx.row_base
        )

    @staticmethod
    def min_dist(ops, dist, frontier, ctx):
        return ell_min_dist(
            ops.fwd, dist, frontier, ctx.row_offset, ctx.n_out
        )

    # or_min edge computes fetch both contributions in one call so backends
    # with per-call setup cost (collectives, direction predicate) pay it once
    @staticmethod
    def reach_parent_dense(ops, frontier, visited, ctx):
        return (
            PushBackend.reach_dense(ops, frontier, visited, ctx),
            PushBackend.min_parent(ops, frontier, visited, ctx),
        )

    @staticmethod
    def reach_parent_lanes(ops, lanes, visited, ctx):
        return (
            PushBackend.reach_lanes(ops, lanes, visited, ctx),
            PushBackend.min_parent_lanes(ops, lanes, visited, ctx),
        )


# ---------------------------------------------------------------------------
# ell_pull — reverse gather with visited-suppression.
# ---------------------------------------------------------------------------


def _global_or(x: jax.Array, ctx: ExtendCtx) -> jax.Array:
    """Global activation tensor from a state tensor. Replicated layout: the
    input is already global. Sharded layout: place local rows and OR-union
    across the graph axes (this is pull's inverse communication pattern —
    frontier bits travel instead of contributions)."""
    if not ctx.sharded:
        return x
    placed = _place_rows(x, ctx, jnp.zeros((), x.dtype))
    return or_allreduce(placed, ctx.axes, ctx.or_impl)


def _global_min(x: jax.Array, ctx: ExtendCtx, fill) -> jax.Array:
    if not ctx.sharded:
        return x
    return min_allreduce(_place_rows(x, ctx, fill), ctx.axes)


def _pull_gather_any(rev: EllGraph, gf: jax.Array) -> jax.Array:
    """[n_out] bool -> [rows] bool: row v active iff any in-neighbor is."""
    got = gf.at[rev.indices].get(mode="fill", fill_value=False)
    return got.any(axis=1)


def _pull_gather_lanes(rev: EllGraph, gl: jax.Array) -> jax.Array:
    """[n_out, L] uint8 -> [rows, L] uint8, degree-chunked like the push
    scatter so the gather temp stays bounded."""
    rows, D = rev.indices.shape
    L = gl.shape[-1]
    if D == 0:  # zero-width slab (edgeless/zero-cap): reductions over a
        return jnp.zeros((rows, L), gl.dtype)  # size-0 axis have no identity
    chunk = _deg_chunk(rows, L)
    if chunk >= D:
        got = gl.at[rev.indices].get(mode="fill", fill_value=0)
        return got.max(axis=1)

    def step(start, width, acc):
        idx = lax.dynamic_slice_in_dim(rev.indices, start, width, 1)
        got = gl.at[idx].get(mode="fill", fill_value=0)
        return jnp.maximum(acc, got.max(axis=1))

    acc0 = jnp.zeros((rows, L), gl.dtype)
    return chunk_fold(D, chunk, step, acc0)


def _pull_min_parent_lanes(rev: EllGraph, gl: jax.Array) -> jax.Array:
    rows, D = rev.indices.shape
    L = gl.shape[-1]
    if D == 0:
        return jnp.full((rows, L), NO_PARENT, jnp.int32)
    chunk = _deg_chunk(rows, 4 * L)

    def step(start, width, acc):
        idx = (
            rev.indices
            if width == D
            else lax.dynamic_slice_in_dim(rev.indices, start, width, 1)
        )
        act = gl.at[idx].get(mode="fill", fill_value=0)  # [rows, c, L]
        cand = jnp.where(
            act != 0, idx[:, :, None].astype(jnp.int32), NO_PARENT
        )
        return jnp.minimum(acc, cand.min(axis=1))

    acc0 = jnp.full((rows, L), NO_PARENT, jnp.int32)
    if chunk >= D:
        return step(0, D, acc0)
    return chunk_fold(D, chunk, step, acc0)


class PullBackend:
    name = "ell_pull"

    # -- collective-free cores (global activation tensors precomputed) ------

    @staticmethod
    def _reach_dense(ops, gf, visited, ctx):
        rev = ops.rev
        rows = rev.indices.shape[0]
        reached = _pull_gather_any(rev, gf)
        if visited is not None:
            reached &= ~_local_state(visited, rows, ctx)
        return _place_rows(reached, ctx, False)

    @staticmethod
    def _reach_lanes(ops, gl, visited, ctx):
        rev = ops.rev
        rows = rev.indices.shape[0]
        reached = _pull_gather_lanes(rev, gl)
        if visited is not None:
            vloc = _local_state(visited, rows, ctx)
            reached = jnp.where(vloc != 0, 0, reached)
        return _place_rows(reached, ctx, 0)

    @staticmethod
    def _min_parent(ops, gf, visited, ctx):
        rev = ops.rev
        rows = rev.indices.shape[0]
        if rev.indices.shape[1] == 0:
            cand = jnp.full((rows,), NO_PARENT, jnp.int32)
        else:
            got = gf.at[rev.indices].get(mode="fill", fill_value=False)
            cand = jnp.where(got, rev.indices, NO_PARENT).min(axis=1)
        if visited is not None:
            cand = jnp.where(
                _local_state(visited, rows, ctx), NO_PARENT, cand
            )
        return _place_rows(cand, ctx, NO_PARENT)

    @staticmethod
    def _min_parent_lanes(ops, gl, visited, ctx):
        rev = ops.rev
        rows = rev.indices.shape[0]
        cand = _pull_min_parent_lanes(rev, gl)
        if visited is not None:
            vloc = _local_state(visited, rows, ctx)
            cand = jnp.where(vloc != 0, NO_PARENT, cand)
        return _place_rows(cand, ctx, NO_PARENT)

    @staticmethod
    def _min_dist(ops, gdu, ctx):
        rev = ops.rev
        rows = rev.indices.shape[0]
        if rev.indices.shape[1] == 0:
            return _place_rows(
                jnp.full((rows,), jnp.inf, jnp.float32), ctx,
                jnp.float32(jnp.inf),
            )
        w = (
            rev.weights
            if rev.weights is not None
            else jnp.ones_like(rev.indices, dtype=jnp.float32)
        )
        got = gdu.at[rev.indices].get(mode="fill", fill_value=jnp.inf)
        cand = (got + w).min(axis=1)
        return _place_rows(cand, ctx, jnp.float32(jnp.inf))

    # -- public contract ----------------------------------------------------

    @staticmethod
    def reach_dense(ops, frontier, visited, ctx):
        return PullBackend._reach_dense(
            ops, _global_or(frontier, ctx), visited, ctx
        )

    @staticmethod
    def reach_lanes(ops, lanes, visited, ctx):
        return PullBackend._reach_lanes(
            ops, _global_or(lanes, ctx), visited, ctx
        )

    @staticmethod
    def min_parent(ops, frontier, visited, ctx):
        return PullBackend._min_parent(
            ops, _global_or(frontier, ctx), visited, ctx
        )

    @staticmethod
    def min_parent_lanes(ops, lanes, visited, ctx):
        return PullBackend._min_parent_lanes(
            ops, _global_or(lanes, ctx), visited, ctx
        )

    @staticmethod
    def min_dist(ops, dist, frontier, ctx):
        du = jnp.where(frontier, dist, jnp.inf)
        return PullBackend._min_dist(
            ops, _global_min(du, ctx, jnp.float32(jnp.inf)), ctx
        )

    @staticmethod
    def reach_parent_dense(ops, frontier, visited, ctx):
        gf = _global_or(frontier, ctx)  # one union serves both scans
        return (
            PullBackend._reach_dense(ops, gf, visited, ctx),
            PullBackend._min_parent(ops, gf, visited, ctx),
        )

    @staticmethod
    def reach_parent_lanes(ops, lanes, visited, ctx):
        gl = _global_or(lanes, ctx)
        return (
            PullBackend._reach_lanes(ops, gl, visited, ctx),
            PullBackend._min_parent_lanes(ops, gl, visited, ctx),
        )

    # additive push has no pull realization worth keeping (gather-sum over
    # rev scans the same edge set at the same cost); top-k is pull-native
    push_sum = staticmethod(PushBackend.push_sum)
    min_topk = staticmethod(_min_topk_pull)


# ---------------------------------------------------------------------------
# pull_binned — the pull gather over degree-binned reverse slabs.
# ---------------------------------------------------------------------------


def _binned_map(bn: BinnedRevEll, per_slab, neutral):
    """Run ``per_slab(slab_idx, slab)`` over every nonempty slab, produce
    the ``neutral(rows_b)`` value for zero-width/zero-row slabs, and
    un-permute the concatenated per-binned-row results back to original
    local-row order. ``per_slab`` maps ``[rows_b, width_b]`` indices to a
    ``[rows_b, ...]`` reduction; padding rows/slots carry the sentinel
    index so gathers fill with the reduction's neutral element."""
    parts = []
    for b, slab in enumerate(bn.slabs):
        s = slab[0]  # shard-local slice: [rows_b, width_b]
        if s.shape[0] == 0 or s.shape[1] == 0:
            parts.append(neutral(s.shape[0]))
        else:
            parts.append(per_slab(b, s))
    cat = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return cat[bn.inv[0]]


def _slab_gather_lanes(s: jax.Array, gl: jax.Array) -> jax.Array:
    """[rows_b, width_b] slab indices × [n_out, L] lanes -> [rows_b, L]
    OR-reduction, degree-chunked so the gather temp stays under the
    ``_deg_chunk`` budget even on the hub bucket's widest slab."""
    rows, D = s.shape
    L = gl.shape[-1]
    chunk = _deg_chunk(rows, L)
    if chunk >= D:
        return gl.at[s].get(mode="fill", fill_value=0).max(axis=1)

    def step(start, width, acc):
        idx = lax.dynamic_slice_in_dim(s, start, width, 1)
        got = gl.at[idx].get(mode="fill", fill_value=0)
        return jnp.maximum(acc, got.max(axis=1))

    return chunk_fold(D, chunk, step, jnp.zeros((rows, L), gl.dtype))


def _slab_min_parent_lanes(s: jax.Array, gl: jax.Array) -> jax.Array:
    """Per-lane min-parent over one binned slab, degree-chunked like
    ``_slab_gather_lanes`` (candidate temp is [rows_b, chunk, L] int32)."""
    rows, D = s.shape
    L = gl.shape[-1]
    chunk = _deg_chunk(rows, 4 * L)

    def step(start, width, acc):
        idx = (
            s if width == D else lax.dynamic_slice_in_dim(s, start, width, 1)
        )
        act = gl.at[idx].get(mode="fill", fill_value=0)
        cand = jnp.where(
            act != 0, idx[:, :, None].astype(jnp.int32), NO_PARENT
        )
        return jnp.minimum(acc, cand.min(axis=1))

    acc0 = jnp.full((rows, L), NO_PARENT, jnp.int32)
    if chunk >= D:
        return step(0, D, acc0)
    return chunk_fold(D, chunk, step, acc0)


class BinnedPullBackend:
    """The ``ell_pull`` contract over ``BinnedRevEll`` slabs.

    Identical math to PullBackend — same reverse edge set (both derive
    from the truncated forward graph), same OR/min merges, same
    visited-suppression — so final states stay bit-identical; only the
    scan layout changes: each degree bucket is padded to its own width,
    so a full scan costs ~sum(in_deg) slots instead of n·max_in_deg.
    """

    name = "pull_binned"

    # -- collective-free cores (global activation tensors precomputed) ------

    @staticmethod
    def _reach_dense(ops, gf, visited, ctx):
        bn = ops.rev_binned
        rows = bn.rows_local
        reached = _binned_map(
            bn,
            lambda b, s: gf.at[s]
            .get(mode="fill", fill_value=False)
            .any(axis=1),
            lambda r: jnp.zeros((r,), jnp.bool_),
        )
        if visited is not None:
            reached &= ~_local_state(visited, rows, ctx)
        return _place_rows(reached, ctx, False)

    @staticmethod
    def _reach_lanes(ops, gl, visited, ctx):
        bn = ops.rev_binned
        rows = bn.rows_local
        L = gl.shape[-1]
        reached = _binned_map(
            bn,
            lambda b, s: _slab_gather_lanes(s, gl),
            lambda r: jnp.zeros((r, L), gl.dtype),
        )
        if visited is not None:
            vloc = _local_state(visited, rows, ctx)
            reached = jnp.where(vloc != 0, 0, reached)
        return _place_rows(reached, ctx, 0)

    @staticmethod
    def _min_parent(ops, gf, visited, ctx):
        bn = ops.rev_binned
        rows = bn.rows_local
        cand = _binned_map(
            bn,
            lambda b, s: jnp.where(
                gf.at[s].get(mode="fill", fill_value=False), s, NO_PARENT
            ).min(axis=1),
            lambda r: jnp.full((r,), NO_PARENT, jnp.int32),
        )
        if visited is not None:
            cand = jnp.where(
                _local_state(visited, rows, ctx), NO_PARENT, cand
            )
        return _place_rows(cand, ctx, NO_PARENT)

    @staticmethod
    def _min_parent_lanes(ops, gl, visited, ctx):
        bn = ops.rev_binned
        rows = bn.rows_local
        L = gl.shape[-1]

        cand = _binned_map(
            bn,
            lambda b, s: _slab_min_parent_lanes(s, gl),
            lambda r: jnp.full((r, L), NO_PARENT, jnp.int32),
        )
        if visited is not None:
            vloc = _local_state(visited, rows, ctx)
            cand = jnp.where(vloc != 0, NO_PARENT, cand)
        return _place_rows(cand, ctx, NO_PARENT)

    @staticmethod
    def _min_dist(ops, gdu, ctx):
        bn = ops.rev_binned

        def per_slab(b, s):
            w = (
                bn.slab_weights[b][0]
                if bn.slab_weights is not None
                else jnp.ones(s.shape, jnp.float32)
            )
            got = gdu.at[s].get(mode="fill", fill_value=jnp.inf)
            return (got + w).min(axis=1)

        cand = _binned_map(
            bn, per_slab, lambda r: jnp.full((r,), jnp.inf, jnp.float32)
        )
        return _place_rows(cand, ctx, jnp.float32(jnp.inf))

    # -- public contract ----------------------------------------------------

    @staticmethod
    def reach_dense(ops, frontier, visited, ctx):
        return BinnedPullBackend._reach_dense(
            ops, _global_or(frontier, ctx), visited, ctx
        )

    @staticmethod
    def reach_lanes(ops, lanes, visited, ctx):
        return BinnedPullBackend._reach_lanes(
            ops, _global_or(lanes, ctx), visited, ctx
        )

    @staticmethod
    def min_parent(ops, frontier, visited, ctx):
        return BinnedPullBackend._min_parent(
            ops, _global_or(frontier, ctx), visited, ctx
        )

    @staticmethod
    def min_parent_lanes(ops, lanes, visited, ctx):
        return BinnedPullBackend._min_parent_lanes(
            ops, _global_or(lanes, ctx), visited, ctx
        )

    @staticmethod
    def min_dist(ops, dist, frontier, ctx):
        du = jnp.where(frontier, dist, jnp.inf)
        return BinnedPullBackend._min_dist(
            ops, _global_min(du, ctx, jnp.float32(jnp.inf)), ctx
        )

    @staticmethod
    def reach_parent_dense(ops, frontier, visited, ctx):
        gf = _global_or(frontier, ctx)  # one union serves both scans
        return (
            BinnedPullBackend._reach_dense(ops, gf, visited, ctx),
            BinnedPullBackend._min_parent(ops, gf, visited, ctx),
        )

    @staticmethod
    def reach_parent_lanes(ops, lanes, visited, ctx):
        gl = _global_or(lanes, ctx)
        return (
            BinnedPullBackend._reach_lanes(ops, gl, visited, ctx),
            BinnedPullBackend._min_parent_lanes(ops, gl, visited, ctx),
        )

    push_sum = staticmethod(PushBackend.push_sum)
    min_topk = staticmethod(_min_topk_pull)


# ---------------------------------------------------------------------------
# pull_binned_fused — the binned pull realized by the fused Pallas kernel.
# ---------------------------------------------------------------------------


class FusedBinnedPullBackend:
    """``pull_binned`` realized by the fused slab-major Pallas kernel.

    Same binned reverse edge set, same reductions, same suppression —
    bit-identical final states — but gathers, reductions, un-permute and
    suppression happen in one VMEM pass per row tile
    (``kernels.binned_pull``), with fully-visited row tiles skipped via the
    scalar-prefetched activity bitmap. Scans ``ops.rev_binned_pack``, the
    row-padded kernel repack of the same ``BinnedRevEll``.
    """

    name = "pull_binned_fused"

    # -- collective-free cores (global activation tensors precomputed) ------

    @staticmethod
    def _reach_dense(ops, gf, visited, ctx):
        pk = ops.rev_binned_pack
        vloc = (
            None
            if visited is None
            else _local_state(visited, pk.rows_local, ctx)
        )
        reached = _fused_pull(
            pk, gf.astype(jnp.uint8), vloc, op="reach"
        )
        return _place_rows(reached != 0, ctx, False)

    @staticmethod
    def _reach_lanes(ops, gl, visited, ctx):
        pk = ops.rev_binned_pack
        vloc = (
            None
            if visited is None
            else _local_state(visited, pk.rows_local, ctx)
        )
        reached = _fused_pull(pk, gl, vloc, op="reach_lanes")
        return _place_rows(reached.astype(gl.dtype), ctx, 0)

    @staticmethod
    def _min_parent(ops, gf, visited, ctx):
        pk = ops.rev_binned_pack
        vloc = (
            None
            if visited is None
            else _local_state(visited, pk.rows_local, ctx)
        )
        cand = _fused_pull(
            pk, gf.astype(jnp.uint8), vloc, op="min_parent"
        )
        return _place_rows(cand, ctx, NO_PARENT)

    @staticmethod
    def _min_parent_lanes(ops, gl, visited, ctx):
        pk = ops.rev_binned_pack
        vloc = (
            None
            if visited is None
            else _local_state(visited, pk.rows_local, ctx)
        )
        cand = _fused_pull(pk, gl, vloc, op="min_parent_lanes")
        return _place_rows(cand, ctx, NO_PARENT)

    @staticmethod
    def _min_dist(ops, gdu, ctx):
        pk = ops.rev_binned_pack
        cand = _fused_pull(pk, gdu, None, op="min_dist")
        return _place_rows(cand, ctx, jnp.float32(jnp.inf))

    # -- public contract ----------------------------------------------------

    @staticmethod
    def reach_dense(ops, frontier, visited, ctx):
        return FusedBinnedPullBackend._reach_dense(
            ops, _global_or(frontier, ctx), visited, ctx
        )

    @staticmethod
    def reach_lanes(ops, lanes, visited, ctx):
        return FusedBinnedPullBackend._reach_lanes(
            ops, _global_or(lanes, ctx), visited, ctx
        )

    @staticmethod
    def min_parent(ops, frontier, visited, ctx):
        return FusedBinnedPullBackend._min_parent(
            ops, _global_or(frontier, ctx), visited, ctx
        )

    @staticmethod
    def min_parent_lanes(ops, lanes, visited, ctx):
        return FusedBinnedPullBackend._min_parent_lanes(
            ops, _global_or(lanes, ctx), visited, ctx
        )

    @staticmethod
    def min_dist(ops, dist, frontier, ctx):
        du = jnp.where(frontier, dist, jnp.inf)
        return FusedBinnedPullBackend._min_dist(
            ops, _global_min(du, ctx, jnp.float32(jnp.inf)), ctx
        )

    @staticmethod
    def reach_parent_dense(ops, frontier, visited, ctx):
        gf = _global_or(frontier, ctx)  # one union serves both scans
        return (
            FusedBinnedPullBackend._reach_dense(ops, gf, visited, ctx),
            FusedBinnedPullBackend._min_parent(ops, gf, visited, ctx),
        )

    @staticmethod
    def reach_parent_lanes(ops, lanes, visited, ctx):
        gl = _global_or(lanes, ctx)
        return (
            FusedBinnedPullBackend._reach_lanes(ops, gl, visited, ctx),
            FusedBinnedPullBackend._min_parent_lanes(ops, gl, visited, ctx),
        )

    push_sum = staticmethod(PushBackend.push_sum)
    min_topk = staticmethod(_min_topk_pull)


# ---------------------------------------------------------------------------
# block_mxu — saturating matmul over per-shard blocks with stripe skipping.
# ---------------------------------------------------------------------------


def block_stripe_activity(lane_blocks: jax.Array) -> jax.Array:
    """[rb, B, L] -> [rb] bool: which source row-block stripes hold any
    frontier bit. The Pallas kernel uses the same bitmap to skip inactive
    blocks via scalar-prefetch indices; here it masks contributions (and is
    the measured 'touched blocks' economy in benchmarks)."""
    return (lane_blocks != 0).any(axis=(1, 2))


class BlockBackend:
    """OR-reach on the MXU block path; candidate-parent / weighted-relax
    scans have no saturating-0/1 formulation and stay on the push ELL
    (same merged values either way, so results remain bit-identical)."""

    name = "block_mxu"

    @staticmethod
    def reach_lanes(ops, lanes, visited, ctx):
        sb = ops.blocks
        blocks = sb.blocks[0]
        brows = sb.block_rows[0]
        bcols = sb.block_cols[0]
        B = sb.block_size
        rows = ops.fwd.indices.shape[0]
        local = _local_state(lanes, rows, ctx)
        L = local.shape[-1]
        lane_blocks = local.reshape(rows // B, B, L)
        act = block_stripe_activity(lane_blocks)
        src = jnp.take(lane_blocks, brows, axis=0)  # [nb, B, L]
        partial = lax.dot_general(
            blocks.astype(jnp.int32),
            src.astype(jnp.int32),
            dimension_numbers=(((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )  # [nb, B(dst), L]
        hit = ((partial > 0) & act[brows][:, None, None]).astype(jnp.uint8)
        G = ctx.n_out // B
        out = jnp.zeros((G, B, L), jnp.uint8)
        out = out.at[bcols].max(hit, mode="drop")  # sentinel col G drops
        return out.reshape(ctx.n_out, L)

    @staticmethod
    def reach_dense(ops, frontier, visited, ctx):
        lanes = frontier[:, None].astype(jnp.uint8)
        return BlockBackend.reach_lanes(ops, lanes, visited, ctx)[:, 0] != 0

    @staticmethod
    def push_sum(ops, values, ctx, normalize=False):
        """Additive count/mass propagation as a non-saturating block matmul:
        ``out[v] = Σ_u values[u]·A[u, v]`` — the pattern-count hop chain on
        the MXU. Bit-identical to the push-ELL scatter for integer values
        (addition is exact either way); float values may differ in the last
        ulp from the scatter order, so float diffusion routes to ell_push.
        """
        sb = ops.blocks
        if sb is None:
            return PushBackend.push_sum(ops, values, ctx, normalize)
        blocks = sb.blocks[0]
        brows = sb.block_rows[0]
        bcols = sb.block_cols[0]
        B = sb.block_size
        rows = ops.fwd.indices.shape[0]
        local = _local_state(values, rows, ctx)
        if normalize:
            local = local / jnp.maximum(ops.fwd.degrees, 1).astype(
                local.dtype
            )
        src = jnp.take(local.reshape(rows // B, B), brows, axis=0)
        partial = lax.dot_general(
            blocks.astype(local.dtype),
            src[:, :, None],
            dimension_numbers=(((1,), (1,)), ((0,), (0,))),
            preferred_element_type=local.dtype,
        )[:, :, 0]  # [nb, B(dst)]
        G = ctx.n_out // B
        out = jnp.zeros((G, B), local.dtype)
        out = out.at[bcols].add(partial, mode="drop")  # sentinel col drops
        return out.reshape(ctx.n_out)

    min_parent = staticmethod(PushBackend.min_parent)
    min_parent_lanes = staticmethod(PushBackend.min_parent_lanes)
    min_dist = staticmethod(PushBackend.min_dist)
    min_topk = staticmethod(_min_topk_pull)

    @staticmethod
    def reach_parent_dense(ops, frontier, visited, ctx):
        return (
            BlockBackend.reach_dense(ops, frontier, visited, ctx),
            PushBackend.min_parent(ops, frontier, visited, ctx),
        )

    @staticmethod
    def reach_parent_lanes(ops, lanes, visited, ctx):
        return (
            BlockBackend.reach_lanes(ops, lanes, visited, ctx),
            PushBackend.min_parent_lanes(ops, lanes, visited, ctx),
        )


# ---------------------------------------------------------------------------
# direction="auto" — Beamer alpha/beta switch between push and pull.
# ---------------------------------------------------------------------------


def _predicate_locals(ops, frontier, visited, ctx: ExtendCtx):
    """This shard's contributions to the Beamer predicate's inputs:
    ``(n_f, m_f, m_u, unvis)`` — active-row count, frontier out-edge
    mass, unexplored out-edge mass (all pre-psum local partials, float32)
    plus the local unvisited-row mask (None when the edge compute keeps
    no visited set — nothing is ever suppressed, so m_u degrades to
    total minus frontier mass)."""
    g = ops.fwd
    rows = g.indices.shape[0]
    floc = _local_state(frontier, rows, ctx)
    act = (floc != 0) if floc.ndim == 1 else (floc != 0).any(axis=-1)
    deg = g.degrees.astype(jnp.float32)
    n_f = act.sum(dtype=jnp.float32)
    m_f = jnp.sum(deg * act)
    if visited is not None:
        vloc = _local_state(visited, rows, ctx)
        vis = (vloc != 0) if vloc.ndim == 1 else (vloc != 0).any(-1)
        unvis = ~vis
        m_u = jnp.sum(deg * unvis)
    else:
        unvis = None
        m_u = deg.sum() - m_f
    return n_f, m_f, m_u, unvis


#: columns of one ``frontier_stats`` sample (and of the ``collect_stats``
#: carry rows the engine builders write)
STATS_WIDTH = 6
#: bytes one int32 adjacency slot streams through an extension scan
#: (4 B neighbor id + 1 B activation read/write) — the analytic factor the
#: measured-cost lane multiplies slot counts by
BYTES_PER_SLOT = 5.0


def frontier_stats(ops, state, ctx: ExtendCtx, bin_widths=None):
    """One per-iteration sample for the online direction-threshold
    learner: ``[n_f, m_f, m_u, pull_slots_binned, wall_ms, pull_bytes]``
    (float32, reduced over ``ctx.axes``) of the state ABOUT to extend —
    the inputs of the Beamer predicate plus the slots a degree-binned
    pull would scan at this state (the widths of the still-unvisited
    rows; full capacity when the edge compute keeps no visited set).
    ``bin_widths`` is this shard's per-local-row slab width vector; when
    the engine's operands carry no binned slabs the cost columns are the
    sentinel ``-1`` and the record is skipped by
    ``fit_direction_thresholds``.

    The measured-cost lane: ``pull_bytes`` is the device-computable
    analytic stream volume (``BYTES_PER_SLOT`` × slots); ``wall_ms`` is a
    *host-filled* column — it stays at the ``-1`` sentinel on device and
    the dispatcher's :class:`BackendCostProbe` converts slot columns to
    per-backend wall estimates when a ``cost="measured"`` consumer asks
    (device-time via a profiler hook on real TPU, ``time.perf_counter``
    under interpret/CPU).

    This is the sample tap ``build_engine(collect_stats=True)`` (and the
    resume/gang builders') writes into the while_loop carry: a pure
    readout of (frontier, visited), so instrumented engines stay
    bit-identical in result state. Semantics match
    benchmarks/direction_opt.py's host-side accounting record-for-record.
    """
    frontier = state.frontier
    visited = getattr(state, "visited", None)
    n_f, m_f, m_u, unvis = _predicate_locals(ops, frontier, visited, ctx)
    if bin_widths is None:
        pull = jnp.float32(0.0)
    elif unvis is None:
        pull = bin_widths.sum()
    else:
        pull = jnp.sum(bin_widths * unvis)
    stats = jnp.stack(
        [n_f, m_f, m_u, pull, jnp.float32(0.0), pull * BYTES_PER_SLOT]
    )
    if ctx.axes:
        stats = lax.psum(stats, ctx.axes)
    stats = stats.at[4].set(-1.0)  # wall: host-filled, never device-summed
    if bin_widths is None:
        stats = stats.at[3].set(-1.0).at[5].set(-1.0)
    return stats


class AutoBackend:
    """Per-iteration push/pull choice under fixed shapes.

    The predicate is a pure function of (frontier, visited) reduced over the
    graph axes, so every device of a sync group agrees; the pull branch's
    global activation tensors are computed *before* the ``lax.cond`` so the
    branches themselves hold no collectives (deadlock-free under shard_map).
    """

    name = "dopt"

    def __init__(self, spec: ExtendSpec):
        self.alpha = spec.alpha
        self.beta = spec.beta
        # bottom-up flavor of the switch: degree-binned slabs (default),
        # the fused kernel over the same slabs, or the single padded
        # reverse ELL — same math, different scan
        self.pull_be = {
            "binned": BinnedPullBackend,
            "binned_fused": FusedBinnedPullBackend,
            "ell": PullBackend,
        }[spec.pull]

    def _use_pull(self, ops, frontier, visited, ctx):
        n_f, m_f, m_u, _ = _predicate_locals(ops, frontier, visited, ctx)
        stats = jnp.stack([n_f, m_f, m_u])
        if ctx.axes:
            stats = lax.psum(stats, ctx.axes)
        n_f, m_f, m_u = stats[0], stats[1], stats[2]
        return (m_f * self.alpha > m_u) & (n_f * self.beta > ctx.n_out)

    def _switch(self, ops, frontier, visited, ctx, pull_fn, push_fn):
        pred = self._use_pull(ops, frontier, visited, ctx)
        return lax.cond(pred, pull_fn, push_fn)

    def reach_dense(self, ops, frontier, visited, ctx):
        gf = _global_or(frontier, ctx)
        return self._switch(
            ops, frontier, visited, ctx,
            lambda: self.pull_be._reach_dense(ops, gf, visited, ctx),
            lambda: PushBackend.reach_dense(ops, frontier, visited, ctx),
        )

    def reach_lanes(self, ops, lanes, visited, ctx):
        gl = _global_or(lanes, ctx)
        return self._switch(
            ops, lanes, visited, ctx,
            lambda: self.pull_be._reach_lanes(ops, gl, visited, ctx),
            lambda: PushBackend.reach_lanes(ops, lanes, visited, ctx),
        )

    def min_parent(self, ops, frontier, visited, ctx):
        gf = _global_or(frontier, ctx)
        return self._switch(
            ops, frontier, visited, ctx,
            lambda: self.pull_be._min_parent(ops, gf, visited, ctx),
            lambda: PushBackend.min_parent(ops, frontier, visited, ctx),
        )

    def min_parent_lanes(self, ops, lanes, visited, ctx):
        gl = _global_or(lanes, ctx)
        return self._switch(
            ops, lanes, visited, ctx,
            lambda: self.pull_be._min_parent_lanes(ops, gl, visited, ctx),
            lambda: PushBackend.min_parent_lanes(ops, lanes, visited, ctx),
        )

    def min_dist(self, ops, dist, frontier, ctx):
        du = jnp.where(frontier, dist, jnp.inf)
        gdu = _global_min(du, ctx, jnp.float32(jnp.inf))
        return self._switch(
            ops, frontier, None, ctx,
            lambda: self.pull_be._min_dist(ops, gdu, ctx),
            lambda: PushBackend.min_dist(ops, dist, frontier, ctx),
        )

    # additive push and top-k relax have one physical form each (scatter-add
    # resp. reverse gather) — no direction decision to make
    def push_sum(self, ops, values, ctx, normalize=False):
        return PushBackend.push_sum(ops, values, ctx, normalize)

    def min_topk(self, ops, dists, src_mask, ctx):
        return _min_topk_pull(ops, dists, src_mask, ctx)

    # one union + one predicate + one cond for or_min edge computes
    def reach_parent_dense(self, ops, frontier, visited, ctx):
        gf = _global_or(frontier, ctx)
        return self._switch(
            ops, frontier, visited, ctx,
            lambda: (
                self.pull_be._reach_dense(ops, gf, visited, ctx),
                self.pull_be._min_parent(ops, gf, visited, ctx),
            ),
            lambda: PushBackend.reach_parent_dense(
                ops, frontier, visited, ctx
            ),
        )

    def reach_parent_lanes(self, ops, lanes, visited, ctx):
        gl = _global_or(lanes, ctx)
        return self._switch(
            ops, lanes, visited, ctx,
            lambda: (
                self.pull_be._reach_lanes(ops, gl, visited, ctx),
                self.pull_be._min_parent_lanes(ops, gl, visited, ctx),
            ),
            lambda: PushBackend.reach_parent_lanes(ops, lanes, visited, ctx),
        )


_FIXED = {
    "ell_push": PushBackend,
    "ell_pull": PullBackend,
    "pull_binned": BinnedPullBackend,
    "pull_binned_fused": FusedBinnedPullBackend,
    "block_mxu": BlockBackend,
}


def make_backend(spec: ExtendSpec):
    """ExtendSpec -> backend object implementing the primitive contract."""
    if spec.direction == "auto":
        return AutoBackend(spec)
    return _FIXED[spec.backend]


class BackendCostProbe:
    """Measured per-slot extension cost — the ``cost="measured"`` lane.

    ``rates(ops, n_pad)`` times one jitted ``reach_dense`` step per backend
    the operand bundle supports (push always; jnp binned pull and the fused
    kernel when their operands are present) against a half-full frontier,
    and divides by each backend's full-scan slot count. The resulting
    ms/slot rates convert the slot columns of ``frontier_stats`` samples
    into per-iteration wall estimates without perturbing the engines — the
    probe runs out-of-band on the same device-placed operands.

    Timing source: ``device_timer(fn, *args) -> ms`` when given (on real
    TPU, a profiler hook reading device time / DMA bytes); otherwise the
    host fallback — ``block_until_ready`` + ``time.perf_counter`` median of
    ``reps``, which is what interpret/CPU CI exercises.
    """

    #: probed backends → the slot count their full scan pays
    def __init__(self, reps: int = 3, device_timer=None):
        self.reps = int(reps)
        self.device_timer = device_timer

    def measure_ms(self, fn, *args) -> float:
        if self.device_timer is not None:
            return float(self.device_timer(fn, *args))
        jax.block_until_ready(fn(*args))  # compile outside the timing
        walls = []
        for _ in range(self.reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            walls.append((time.perf_counter() - t0) * 1e3)
        walls.sort()
        return walls[len(walls) // 2]

    def rates(self, ops, n_pad: int) -> dict:
        """``{backend: {"ms_per_slot", "bytes_per_slot", "probe_ms",
        "slots"}}`` for every backend ``ops`` can run. Bytes are the
        analytic ``BYTES_PER_SLOT`` stream volume; wall is measured."""
        ops = as_operands(ops)
        ctx = ExtendCtx(n_out=n_pad)
        frontier = (
            jnp.arange(n_pad) < max(n_pad // 2, 1)
        )  # half-full: both directions do real work
        visited = jnp.zeros(n_pad, jnp.bool_)
        probes = {"ell_push": (PushBackend, int(ops.fwd.indices.size))}
        if ops.rev_binned is not None:
            probes["pull_binned"] = (
                BinnedPullBackend, ops.rev_binned.capacity_slots
            )
        if ops.rev_binned_pack is not None:
            probes["pull_binned_fused"] = (
                FusedBinnedPullBackend, ops.rev_binned_pack.capacity_slots
            )
        out = {}
        for name, (be, slots) in probes.items():
            fn = jax.jit(
                lambda f, v, be=be: be.reach_dense(ops, f, v, ctx)
            )
            ms = self.measure_ms(fn, frontier, visited)
            out[name] = {
                "ms_per_slot": ms / max(slots, 1),
                "bytes_per_slot": BYTES_PER_SLOT,
                "probe_ms": ms,
                "slots": slots,
            }
        return out
