"""The IFE operator (paper Listing 1/3): iterative frontier extension.

``run_ife`` is the single-chip serial engine (paper Listing 1). It is the unit
that morsel dispatching policies replicate/partition:

- 1T1S vmaps it over a per-device batch of sources (source morsels);
- nT1S/nTkS replace ``local_extend`` + MERGE with sharded extension and a
  frontier-union collective (see core/dispatcher.py);
- nTkMS runs it with a multi-source (lane) edge compute.

The FRONTIER_EXTENSION / OUTPUT phases of the paper's operator map to
``run_ife`` (extension, a ``lax.while_loop``) and the output-consumption
helpers below (``histogram_lengths``, ``reconstruct_paths``), which pipeline
results to downstream query operators.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..graph.csr import EllGraph
from .edge_compute import EDGE_COMPUTES, NO_PARENT
from .extend import ExtendCtx, as_operands, as_spec, make_backend


class IFEResult(NamedTuple):
    state: Any  # final edge-compute state pytree
    iterations: jax.Array  # int32, number of frontier extensions performed


def merge_identity(merge: str, contribution):
    return contribution


def run_ife(
    graph,
    sources: jax.Array,
    edge_compute: str = "sp_lengths",
    max_iters: int | None = None,
    extend="ell_push",
) -> IFEResult:
    """Run one IFE subroutine (one source morsel) to convergence.

    ``sources``: [k] int32 — for dense edge computes these all seed one shared
    frontier (a multi-source *query*); for msbfs_* computes sources[l] seeds
    lane l. Out-of-range ids are inert (empty lanes).

    ``graph``: an ``EllGraph`` (push-only) or a ``GraphOperands`` bundle
    (see ``core.extend.build_operands``). ``extend`` selects the extension
    backend ("ell_push" | "ell_pull" | "block_mxu" | "dopt" or an
    ``ExtendSpec``); all choices are bit-identical in final state.
    """
    ec = EDGE_COMPUTES[edge_compute]
    spec = as_spec(extend)
    ops = as_operands(graph)
    if spec.needs_rev and ops.rev is None:
        raise ValueError(f"extend={spec.backend!r} needs reverse operands; "
                         "build the graph with core.extend.build_operands")
    if spec.needs_binned and ops.rev_binned is None:
        raise ValueError(
            f"extend={spec.backend!r}/{spec.direction} needs degree-binned "
            "reverse operands; build the graph with "
            "core.extend.build_operands"
        )
    if spec.needs_blocks and ops.blocks is None:
        raise ValueError("extend='block_mxu' needs block operands; "
                         "build the graph with core.extend.build_operands")
    be = make_backend(spec)
    n = ops.n_nodes
    ctx = ExtendCtx(n_out=n)
    cap = jnp.int32(n if max_iters is None else max_iters)
    state0 = ec.init(n, sources)

    def cond(carry):
        state, it = carry
        return jnp.any(state.frontier != 0) & (it < cap)

    def body(carry):
        state, it = carry
        contribution = ec.extend(be, ops, state, ctx)
        state = ec.apply(state, contribution, it)
        return state, it + 1

    state, iters = jax.lax.while_loop(cond, body, (state0, jnp.int32(0)))
    return IFEResult(state=state, iterations=iters)


@partial(jax.jit, static_argnames=("edge_compute", "max_iters", "extend"))
def run_ife_jit(graph, sources, edge_compute="sp_lengths", max_iters=None,
                extend="ell_push"):
    return run_ife(graph, sources, edge_compute, max_iters, extend)


def run_ife_batch(
    graph,
    source_batch: jax.Array,
    edge_compute: str = "sp_lengths",
    max_iters: int | None = None,
    extend="ell_push",
) -> IFEResult:
    """vmap over independent source morsels: [m] int32 -> batched states.

    This is the 1T1S inner loop: each morsel is an independent IFE run with
    unsynchronized private state (paper §3.1 'fast data structures without
    synchronization primitives').
    """
    fn = lambda s: run_ife(graph, s[None], edge_compute, max_iters, extend)
    return jax.vmap(fn)(source_batch)


def run_ife_scan(
    graph,
    source_batch: jax.Array,
    edge_compute: str = "sp_lengths",
    max_iters: int | None = None,
    extend="ell_push",
) -> IFEResult:
    """Sequential (lax.map) variant of run_ife_batch — the true 1T1S semantics
    (one morsel at a time per worker), used when per-source state does not fit
    m-way vmapped. Same results, lower peak memory, serial."""
    fn = lambda s: run_ife(graph, s[None], edge_compute, max_iters, extend)
    return jax.lax.map(fn, source_batch)


# ---------------------------------------------------------------------------
# OUTPUT phase (paper §4.1): consume IFE results.
# ---------------------------------------------------------------------------

def histogram_lengths(levels: jax.Array, max_len: int = 64) -> jax.Array:
    """RETURN len(p) consumption: histogram of path lengths (ignores -1/255)."""
    lv = levels.astype(jnp.int32).reshape(-1)
    valid = (lv >= 0) & (lv < max_len)
    return jnp.zeros((max_len,), jnp.int32).at[lv].add(
        valid.astype(jnp.int32), mode="drop"
    )


def reconstruct_paths(
    parents: jax.Array, dests: jax.Array, max_len: int
) -> jax.Array:
    """RETURN p consumption: walk parent pointers from each destination.

    parents: [n] int32 (NO_PARENT where unreached / at source).
    dests: [d] int32. Returns [d, max_len] int32 node ids padded with -1,
    ordered dest -> source.
    """

    def step(carry, _):
        cur = carry
        nxt = jnp.where(
            cur >= 0,
            parents.at[cur].get(mode="fill", fill_value=int(NO_PARENT)),
            NO_PARENT,
        )
        nxt = jnp.where(nxt == NO_PARENT, -1, nxt)
        return nxt, cur

    _, path = jax.lax.scan(step, dests.astype(jnp.int32), None, length=max_len)
    return jnp.swapaxes(path, 0, 1)


def validate_parents(
    levels: jax.Array, parents: jax.Array, sources: jax.Array
) -> jax.Array:
    """Invariant: every reached non-source v has a parent with
    level(parent) == level(v) - 1. Returns bool."""
    n = levels.shape[0]
    is_src = jnp.zeros((n,), jnp.bool_).at[sources].set(True, mode="drop")
    reached = (levels > 0) & ~is_src
    p = jnp.clip(parents, 0, n - 1)
    ok = jnp.where(reached, levels[p] == levels - 1, True)
    has_parent = jnp.where(reached, parents != NO_PARENT, True)
    return jnp.all(ok & has_parent)
