"""Multi-source BFS — the MXU formulation (DESIGN.md §2).

The CPU MS-BFS trick (Then et al. 2014; paper §3.4) packs 64 BFS instances
into a uint64 per node and extends frontiers with bitwise OR, sharing one
adjacency scan across all 64. On TPU we make the 64 lanes a real tensor axis:

    next_block[dst, lane] = OR_{src} A[src, dst] & F[src, lane]
                          = (A_blockᵀ @ F_block)[dst, lane] > 0

i.e. saturating int8 matmul on the MXU over 128×128 adjacency blocks, skipping
all-zero blocks (block-sparsity ⇒ the 'fewer scans' economy). On top of the
*static* skip list, extension is density-adaptive at runtime: a per-row-block
frontier activity bitmap masks (jnp path) or DMA-skips (Pallas path)
adjacency blocks whose source stripe holds no frontier bit this iteration —
the block-granular realization of Ligra/Beamer's sparse-frontier economy
(see ``core.extend`` for the full direction-optimizing switch). This module
is the pure-jnp formulation; ``repro.kernels.msbfs_extend`` is the Pallas
kernel with explicit VMEM BlockSpecs, validated against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import BlockAdjacency


def gang_pack_lanes(x: jax.Array) -> jax.Array:
    """Stack-of-morsels state -> one lane-packed activation tensor.

    ``[S, rows]`` (dense per-morsel frontiers) or ``[S, rows, L]`` (lane
    morsels) becomes ``[rows, S*L]`` uint8 — the survivors of phase 1 are
    repacked as MS-BFS-style lanes so one shared adjacency scan per
    iteration serves the whole gang (Then et al.'s "more the merrier"
    economy applied to re-dispatch instead of admission). Per-morsel lanes
    stay contiguous: morsel s owns columns ``[s*L, (s+1)*L)``.
    """
    if x.ndim == 2:
        return jnp.moveaxis(x, 0, 1).astype(jnp.uint8)
    S, rows, L = x.shape
    return jnp.moveaxis(x, 0, 1).reshape(rows, S * L).astype(jnp.uint8)


def gang_unpack_lanes(y: jax.Array, gang: int, lanes: int = 0) -> jax.Array:
    """Inverse of ``gang_pack_lanes`` for a per-lane result ``[rows, S*L]``
    (any dtype — reach bits or int32 parent candidates): back to the
    stacked ``[S, rows]`` (``lanes=0``, dense morsels) or ``[S, rows, L]``
    layout. Callers convert dtype (e.g. ``!= 0`` for bool frontiers)."""
    rows = y.shape[0]
    if lanes == 0:
        return jnp.moveaxis(y, 0, 1)
    return jnp.moveaxis(y.reshape(rows, gang, lanes), 0, 1)


def frontier_block_activity(
    adj: BlockAdjacency, lanes: jax.Array
) -> jax.Array:
    """[n, L] -> [n_blocks] bool: which *materialized* adjacency blocks have
    any frontier bit in their source row-block stripe this iteration. This is
    the dynamic skip bitmap (static zero blocks are already absent)."""
    n, L = lanes.shape
    B = adj.block_size
    stripe = (lanes.reshape(n // B, B, L) != 0).any(axis=(1, 2))
    return stripe[adj.block_rows]


def active_block_count(adj: BlockAdjacency, lanes: jax.Array) -> jax.Array:
    """Measured 'touched blocks' for one extension: the adjacency tiles the
    block path actually consumes under the activity skip (benchmarked by
    benchmarks/direction_opt.py, realized as elided DMAs by the kernel)."""
    return frontier_block_activity(adj, lanes).sum(dtype=jnp.int32)


def block_extend_lanes(adj: BlockAdjacency, lanes: jax.Array) -> jax.Array:
    """Frontier extension over the block-sparse adjacency.

    lanes: [n, L] uint8 (n divisible by block size). Returns reached [n, L]
    uint8. Only materialized (nonzero) adjacency blocks whose source stripe
    is frontier-active contribute.
    """
    n, L = lanes.shape
    B = adj.block_size
    g = n // B
    lane_blocks = lanes.reshape(g, B, L)
    act = frontier_block_activity(adj, lanes)  # [nb]
    # gather source-lane blocks for every nonzero adjacency block
    src = jnp.take(lane_blocks, adj.block_rows, axis=0)  # [nb, B, L]
    # OR-aggregation as saturating matmul: A[src,dst]ᵀ @ F[src,lane]
    partial = jax.lax.dot_general(
        adj.blocks.astype(jnp.int32),
        src.astype(jnp.int32),
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )  # [nb, B(dst), L]
    hit = ((partial > 0) & act[:, None, None]).astype(jnp.uint8)
    out = jnp.zeros((g, B, L), jnp.uint8)
    out = out.at[adj.block_cols].max(hit, mode="drop")
    return out.reshape(n, L)


def block_extend_dense(adj: BlockAdjacency, frontier: jax.Array) -> jax.Array:
    """Single-frontier variant: [n] bool -> [n] bool via the same block path
    (lane width 1). Kept for policy parity tests."""
    reached = block_extend_lanes(adj, frontier[:, None].astype(jnp.uint8))
    return reached[:, 0] != 0


def scans_saved_factor(adj: BlockAdjacency, lanes: int = 64) -> float:
    """Analytic MS-BFS scan economy: independent BFS would read every block
    once per lane; lane packing reads it once per 64. Reported in fig14
    benchmark alongside measured bytes."""
    return float(lanes)


class LanePacker:
    """Incremental MS-BFS lane packing for the admission layer
    (repack-on-arrival).

    Queries arrive one at a time (``add``) and may leave before dispatch
    (``evict`` — the admission layer pulls a query out of the shared pack
    when the pack's predicted depth would blow that query's deadline, or
    sheds it outright). ``pack()`` lays the surviving queries' sources
    end-to-end in ARRIVAL ORDER into the flat source vector that
    ``pad_sources`` folds into 64-wide lane morsels, and returns each
    query's half-open span into the lane-major result rows.

    Arrival-order concatenation is a correctness lever, not a convenience:
    it is exactly the order the synchronous ``flush`` pools sources in, so
    a packed batch built here is bit-identical — result rows included — to
    the legacy pooled batch, and eviction (a pure deletion) never reorders
    the remaining queries. Lane assignment is an artifact of position; the
    per-query rows come back out by span regardless of which lane column
    each source landed in."""

    def __init__(self, lanes: int = 64):
        self.lanes = int(lanes)
        self._entries: list[tuple[str, np.ndarray]] = []  # arrival order

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, qid: str) -> bool:
        return any(q == qid for q, _ in self._entries)

    @property
    def qids(self) -> list[str]:
        return [q for q, _ in self._entries]

    @property
    def n_sources(self) -> int:
        return sum(len(s) for _, s in self._entries)

    @property
    def n_morsels(self) -> int:
        """Lane morsels the current pack folds into (ceil over lane width)."""
        return -(-self.n_sources // self.lanes)

    def add(self, qid: str, sources: np.ndarray) -> None:
        if qid in self:
            raise ValueError(f"duplicate qid in pack: {qid!r}")
        self._entries.append(
            (qid, np.asarray(sources, np.int32).reshape(-1))
        )

    def evict(self, qid: str) -> np.ndarray | None:
        """Remove one query from the pack; remaining queries keep their
        relative arrival order (the repack is a pure deletion). Returns the
        evicted sources, or None if the qid is not packed."""
        for i, (q, s) in enumerate(self._entries):
            if q == qid:
                del self._entries[i]
                return s
        return None

    def pack(self) -> tuple[np.ndarray, dict[str, tuple[int, int]]]:
        """(flat sources [arrival order], {qid: (start, stop)} row spans
        into the lane-major per-source result rows)."""
        spans: dict[str, tuple[int, int]] = {}
        parts = []
        i = 0
        for qid, s in self._entries:
            spans[qid] = (i, i + len(s))
            parts.append(s)
            i += len(s)
        flat = (
            np.concatenate(parts) if parts else np.zeros(0, np.int32)
        ).astype(np.int32)
        return flat, spans
