"""Multi-source BFS — the MXU formulation (DESIGN.md §2).

The CPU MS-BFS trick (Then et al. 2014; paper §3.4) packs 64 BFS instances
into a uint64 per node and extends frontiers with bitwise OR, sharing one
adjacency scan across all 64. On TPU we make the 64 lanes a real tensor axis:

    next_block[dst, lane] = OR_{src} A[src, dst] & F[src, lane]
                          = (A_blockᵀ @ F_block)[dst, lane] > 0

i.e. saturating int8 matmul on the MXU over 128×128 adjacency blocks, skipping
all-zero blocks (block-sparsity ⇒ the 'fewer scans' economy). On top of the
*static* skip list, extension is density-adaptive at runtime: a per-row-block
frontier activity bitmap masks (jnp path) or DMA-skips (Pallas path)
adjacency blocks whose source stripe holds no frontier bit this iteration —
the block-granular realization of Ligra/Beamer's sparse-frontier economy
(see ``core.extend`` for the full direction-optimizing switch). This module
is the pure-jnp formulation; ``repro.kernels.msbfs_extend`` is the Pallas
kernel with explicit VMEM BlockSpecs, validated against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..graph.csr import BlockAdjacency


def gang_pack_lanes(x: jax.Array) -> jax.Array:
    """Stack-of-morsels state -> one lane-packed activation tensor.

    ``[S, rows]`` (dense per-morsel frontiers) or ``[S, rows, L]`` (lane
    morsels) becomes ``[rows, S*L]`` uint8 — the survivors of phase 1 are
    repacked as MS-BFS-style lanes so one shared adjacency scan per
    iteration serves the whole gang (Then et al.'s "more the merrier"
    economy applied to re-dispatch instead of admission). Per-morsel lanes
    stay contiguous: morsel s owns columns ``[s*L, (s+1)*L)``.
    """
    if x.ndim == 2:
        return jnp.moveaxis(x, 0, 1).astype(jnp.uint8)
    S, rows, L = x.shape
    return jnp.moveaxis(x, 0, 1).reshape(rows, S * L).astype(jnp.uint8)


def gang_unpack_lanes(y: jax.Array, gang: int, lanes: int = 0) -> jax.Array:
    """Inverse of ``gang_pack_lanes`` for a per-lane result ``[rows, S*L]``
    (any dtype — reach bits or int32 parent candidates): back to the
    stacked ``[S, rows]`` (``lanes=0``, dense morsels) or ``[S, rows, L]``
    layout. Callers convert dtype (e.g. ``!= 0`` for bool frontiers)."""
    rows = y.shape[0]
    if lanes == 0:
        return jnp.moveaxis(y, 0, 1)
    return jnp.moveaxis(y.reshape(rows, gang, lanes), 0, 1)


def frontier_block_activity(
    adj: BlockAdjacency, lanes: jax.Array
) -> jax.Array:
    """[n, L] -> [n_blocks] bool: which *materialized* adjacency blocks have
    any frontier bit in their source row-block stripe this iteration. This is
    the dynamic skip bitmap (static zero blocks are already absent)."""
    n, L = lanes.shape
    B = adj.block_size
    stripe = (lanes.reshape(n // B, B, L) != 0).any(axis=(1, 2))
    return stripe[adj.block_rows]


def active_block_count(adj: BlockAdjacency, lanes: jax.Array) -> jax.Array:
    """Measured 'touched blocks' for one extension: the adjacency tiles the
    block path actually consumes under the activity skip (benchmarked by
    benchmarks/direction_opt.py, realized as elided DMAs by the kernel)."""
    return frontier_block_activity(adj, lanes).sum(dtype=jnp.int32)


def block_extend_lanes(adj: BlockAdjacency, lanes: jax.Array) -> jax.Array:
    """Frontier extension over the block-sparse adjacency.

    lanes: [n, L] uint8 (n divisible by block size). Returns reached [n, L]
    uint8. Only materialized (nonzero) adjacency blocks whose source stripe
    is frontier-active contribute.
    """
    n, L = lanes.shape
    B = adj.block_size
    g = n // B
    lane_blocks = lanes.reshape(g, B, L)
    act = frontier_block_activity(adj, lanes)  # [nb]
    # gather source-lane blocks for every nonzero adjacency block
    src = jnp.take(lane_blocks, adj.block_rows, axis=0)  # [nb, B, L]
    # OR-aggregation as saturating matmul: A[src,dst]ᵀ @ F[src,lane]
    partial = jax.lax.dot_general(
        adj.blocks.astype(jnp.int32),
        src.astype(jnp.int32),
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )  # [nb, B(dst), L]
    hit = ((partial > 0) & act[:, None, None]).astype(jnp.uint8)
    out = jnp.zeros((g, B, L), jnp.uint8)
    out = out.at[adj.block_cols].max(hit, mode="drop")
    return out.reshape(n, L)


def block_extend_dense(adj: BlockAdjacency, frontier: jax.Array) -> jax.Array:
    """Single-frontier variant: [n] bool -> [n] bool via the same block path
    (lane width 1). Kept for policy parity tests."""
    reached = block_extend_lanes(adj, frontier[:, None].astype(jnp.uint8))
    return reached[:, 0] != 0


def scans_saved_factor(adj: BlockAdjacency, lanes: int = 64) -> float:
    """Analytic MS-BFS scan economy: independent BFS would read every block
    once per lane; lane packing reads it once per 64. Reported in fig14
    benchmark alongside measured bytes."""
    return float(lanes)
