"""Frontier representations.

Three interchangeable layouts for the set(s) of active nodes:

- dense bool ``[n]`` — single IFE subroutine (policies 1T1S / nT1S / nTkS).
- lanes ``[n, L] uint8`` — L concurrent IFE subroutines (MS-BFS / nTkMS);
  L = 64 matches the paper's 64-bit lane packing, but here lanes are a real
  tensor dimension so frontier extension can ride the MXU (see DESIGN.md §2).
- packed ``[n, L//32] uint32`` — bit-packed lanes, used on the wire for
  inter-chip frontier unions (8× less traffic than uint8 lanes).

The paper's sparse-frontier optimization (Ligra's 1/8 switch) does not
transfer to SPMD lockstep execution as data-dependent *compaction* — shapes
are fixed under jit/while_loop — but its economy IS realized here, two ways
(see ``core.extend``): (1) a Beamer-style direction-optimizing switch — a
per-iteration ``lax.cond`` between the push scatter and a visited-suppressed
pull over the reverse ELL, chosen by alpha/beta thresholds on frontier
size/edge mass with fixed shapes on both branches; and (2) at block
granularity by the block_mxu backend / msbfs_extend kernel, which skips both
statically-zero and frontier-empty 128-wide blocks via a per-row-block
activity bitmap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANES = 64  # paper's multi-source morsel width (uint64 lanes)
PACK = 32  # bits per packed word


def dense_from_sources(n_nodes: int, sources: jax.Array) -> jax.Array:
    """[n] bool with True at each source (out-of-range sources dropped)."""
    f = jnp.zeros((n_nodes,), dtype=jnp.bool_)
    return f.at[sources].set(True, mode="drop")


def lanes_from_sources(n_nodes: int, sources: jax.Array) -> jax.Array:
    """[n, L] uint8 multi-source frontier; sources[l] activates lane l.

    Padding convention: a source id >= n_nodes (or < 0) leaves its lane empty,
    so partially-filled multi-source morsels (paper §5.6, <64 sources) work.
    """
    L = sources.shape[0]
    f = jnp.zeros((n_nodes, L), dtype=jnp.uint8)
    lanes = jnp.arange(L, dtype=jnp.int32)
    return f.at[sources, lanes].set(1, mode="drop")


def pack_lanes(lanes: jax.Array) -> jax.Array:
    """[n, L] uint8 → [n, L//PACK] uint32 bit-packed."""
    n, L = lanes.shape
    assert L % PACK == 0, L
    bits = lanes.astype(jnp.uint32).reshape(n, L // PACK, PACK)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack_lanes(packed: jax.Array, lanes: int = LANES) -> jax.Array:
    """[n, W] uint32 → [n, lanes] uint8."""
    n, w = packed.shape
    assert w * PACK == lanes, (w, lanes)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(n, lanes).astype(jnp.uint8)


def frontier_size(frontier: jax.Array) -> jax.Array:
    """Number of active (node, lane) entries (dense or lanes layout)."""
    return jnp.sum(frontier.astype(jnp.int32))


def any_active(frontier: jax.Array) -> jax.Array:
    return jnp.any(frontier != 0)
