"""pna [arXiv:2004.05718; paper] — n_layers=4 d_hidden=75,
aggregators mean-max-min-std, scalers id-amp-atten."""
from ..models.gnn.pna import PNAConfig
from .base import ArchSpec, GNN_SHAPES, register


def full_config() -> PNAConfig:
    return PNAConfig(n_layers=4, d_hidden=75, d_feat=1433, n_out=40)


def smoke_config() -> PNAConfig:
    return PNAConfig(n_layers=2, d_hidden=12, d_feat=16, n_out=4)


register(
    ArchSpec(
        arch_id="pna",
        family="gnn",
        source="arXiv:2004.05718; paper",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=GNN_SHAPES,
        skips={},
        notes="SpMM/segment-reduce regime; 4 aggregators x 3 degree scalers",
    )
)
