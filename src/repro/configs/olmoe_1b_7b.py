"""olmoe-1b-7b [arXiv:2409.02060; hf] — MoE, 64 experts top-8, QK-norm.
16L d_model=2048 16H (kv=16) d_ff=1024(expert) vocab=50304."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from ..nn.moe import MoESettings
from .base import ArchSpec, FULL_ATTENTION_SKIP, LM_SHAPES, register


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-1b-7b",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1024,
        vocab=50304,
        qk_norm=True,
        moe=MoESettings(n_experts=64, top_k=8, d_ff=1024, every=1),
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        remat="dots",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-1b-7b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=64,
        vocab=512,
        qk_norm=True,
        moe=MoESettings(n_experts=8, top_k=2, d_ff=64, every=1),
        tie_embeddings=False,
        dtype=jnp.float32,
        remat="none",
        attn_chunk=64,
    )


register(
    ArchSpec(
        arch_id="olmoe-1b-7b",
        family="lm",
        source="arXiv:2409.02060; hf",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTENTION_SKIP},
    )
)
