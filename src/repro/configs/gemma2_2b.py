"""gemma2-2b [arXiv:2408.00118; hf] — local+global alternating, logit softcap.
26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim=256,
sliding window 4096, attn softcap 50, final softcap 30, sandwich norms.

long_500k RUNS for this arch: sliding-window layers keep O(window) KV; only
the 13 global layers carry full 500k caches (sharded over data+model)."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .base import ArchSpec, LM_SHAPES, register


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-2b",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        d_head=256,
        d_ff=9216,
        vocab=256000,
        layer_pattern=("local", "global"),
        window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        use_post_norm=True,
        zero_centered_norm=True,
        emb_scale=2304 ** 0.5,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
        remat="dots",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-2b-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab=512,
        layer_pattern=("local", "global"),
        window=32,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        use_post_norm=True,
        zero_centered_norm=True,
        emb_scale=8.0,
        dtype=jnp.float32,
        remat="none",
        attn_chunk=64,
    )


register(
    ArchSpec(
        arch_id="gemma2-2b",
        family="lm",
        source="arXiv:2408.00118; hf",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=LM_SHAPES,
        skips={},
        notes="hybrid local/global attention -> long_500k supported",
    )
)
