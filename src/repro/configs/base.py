"""Arch/shape registry: every assigned (architecture × input-shape) cell.

Each arch module registers an ``ArchSpec`` carrying its full published config,
a reduced smoke config, its shape set, and documented skips. ``launch/dryrun``
iterates the registry; smoke tests instantiate ``smoke_config``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | full_graph | minibatch | batched
    #           | serve | bulk | retrieval
    dims: dict  # family-specific dimensions


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | paper
    source: str  # citation tag from the assignment
    full_config: Callable[[], Any]
    smoke_config: Callable[[], Any]
    shapes: tuple  # tuple[ShapeSpec, ...]
    skips: dict  # shape name -> reason (documented in DESIGN.md)
    schedule: str = "cosine"  # training LR schedule
    notes: str = ""


REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    REGISTRY[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    return REGISTRY[arch_id]


def all_archs() -> dict[str, ArchSpec]:
    _ensure_loaded()
    return dict(REGISTRY)


def all_cells():
    """Every runnable (arch, shape) cell + the documented skips."""
    _ensure_loaded()
    cells, skips = [], []
    for spec in REGISTRY.values():
        for shape in spec.shapes:
            if shape.name in spec.skips:
                skips.append((spec.arch_id, shape.name, spec.skips[shape.name]))
            else:
                cells.append((spec.arch_id, shape.name))
    return cells, skips


# ---- shared shape sets ------------------------------------------------------

LM_SHAPES = (
    ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeSpec("long_500k", "decode", dict(seq_len=524288, global_batch=1)),
)

GNN_SHAPES = (
    ShapeSpec(
        "full_graph_sm", "full_graph",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433),
    ),
    ShapeSpec(
        "minibatch_lg", "minibatch",
        dict(
            n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
            fanout=(15, 10),
        ),
    ),
    ShapeSpec(
        "ogb_products", "full_graph",
        dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    ),
    ShapeSpec(
        "molecule", "batched",
        dict(n_nodes=30, n_edges=64, batch=128),
    ),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", dict(batch=65536)),
    ShapeSpec("serve_p99", "serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "bulk", dict(batch=262144)),
    ShapeSpec(
        "retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)
    ),
)

FULL_ATTENTION_SKIP = (
    "long_500k needs sub-quadratic attention; this arch is pure "
    "full-attention (see DESIGN.md §4)"
)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        deepseek_coder_33b,
        gemma2_2b,
        minicpm_2b,
        olmoe_1b_7b,
        llama4_maverick,
        mace,
        equiformer_v2,
        pna,
        schnet,
        dcn_v2,
        paper_bfs,
    )
