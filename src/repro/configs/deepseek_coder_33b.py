"""deepseek-coder-33b [arXiv:2401.14196; hf] — dense llama-arch.
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .base import ArchSpec, FULL_ATTENTION_SKIP, LM_SHAPES, register


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-coder-33b",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=19200,
        vocab=32256,
        rope_theta=1e5,
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        remat="dots",
        norm_eps=1e-6,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-coder-33b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=128,
        vocab=512,
        tie_embeddings=False,
        dtype=jnp.float32,
        remat="none",
        attn_chunk=64,
    )


register(
    ArchSpec(
        arch_id="deepseek-coder-33b",
        family="lm",
        source="arXiv:2401.14196; hf",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTENTION_SKIP},
    )
)
