"""minicpm-2b [arXiv:2404.06395; hf] — llama-like with WSD schedule + mup-style
scaling. 40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753.
scale_emb=12, scale_depth=1.4 (residual scale 1.4/sqrt(40)),
logit scale dim_model_base/d_model = 256/2304."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .base import ArchSpec, FULL_ATTENTION_SKIP, LM_SHAPES, register


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm-2b",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_head=64,
        d_ff=5760,
        vocab=122753,
        emb_scale=12.0,
        residual_scale=1.4 / (40 ** 0.5),
        logit_scale=256.0 / 2304.0,
        tie_embeddings=True,
        dtype=jnp.bfloat16,
        remat="dots",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="minicpm-2b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab=515,  # deliberately non-multiple of 256: tests vocab padding
        emb_scale=12.0,
        residual_scale=1.4 / (2 ** 0.5),
        logit_scale=0.5,
        dtype=jnp.float32,
        remat="none",
        attn_chunk=64,
    )


register(
    ArchSpec(
        arch_id="minicpm-2b",
        family="lm",
        source="arXiv:2404.06395; hf",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTENTION_SKIP},
        schedule="wsd",
        notes="WSD schedule (optim/schedules.wsd_schedule)",
    )
)
