"""The paper's own engine as an arch: recursive query execution cells.

Four cells — one per paper dataset (Table 2) at FULL published scale — lower
the nTkS/nTkMS query engines on the production mesh (ShapeDtypeStruct graphs;
benchmarks run reduced-scale proxies with real data).
"""
import dataclasses

from .base import ArchSpec, ShapeSpec, register


@dataclasses.dataclass(frozen=True)
class PaperEngineConfig:
    name: str = "paper-bfs-engine"
    policy: str = "ntkms"  # recommended robust hybrid (+ lanes when >=64 srcs)
    edge_compute: str = "msbfs_lengths"
    n_sources: int = 64
    max_deg_cap: int = 64  # ELL truncation cap for the dry-run layout
    max_iters: int = 32
    or_impl: str = "ring"


def full_config() -> PaperEngineConfig:
    return PaperEngineConfig()


def smoke_config() -> PaperEngineConfig:
    return PaperEngineConfig(n_sources=8, max_deg_cap=16, max_iters=8,
                             policy="ntks", edge_compute="sp_lengths")


PAPER_SHAPES = (
    ShapeSpec("ldbc100", "query", dict(n_nodes=448_626, n_edges=19_941_198,
                                       avg_degree=44)),
    ShapeSpec("livejournal", "query", dict(n_nodes=4_847_571,
                                           n_edges=68_993_773, avg_degree=14)),
    ShapeSpec("spotify", "query", dict(n_nodes=3_604_454,
                                       n_edges=1_927_482_013, avg_degree=535)),
    ShapeSpec("graph500_28", "query", dict(n_nodes=121_242_388,
                                           n_edges=4_236_163_958,
                                           avg_degree=35)),
)


register(
    ArchSpec(
        arch_id="paper-bfs-engine",
        family="paper",
        source="this paper (PVLDB 18(11) 2025)",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=PAPER_SHAPES,
        skips={},
        notes="morsel policies as mesh programs; Table 2 datasets at full "
        "scale as dry-run cells",
    )
)
