"""dcn-v2 [arXiv:2008.13535; paper] — n_dense=13 n_sparse=26 embed_dim=16
n_cross_layers=3 mlp=1024-1024-512 interaction=cross."""
from ..models.dcn_v2 import DCNv2Config
from .base import ArchSpec, RECSYS_SHAPES, register


def full_config() -> DCNv2Config:
    return DCNv2Config()


def smoke_config() -> DCNv2Config:
    return DCNv2Config(
        mlp=(32, 32, 16),
        field_vocabs=tuple([97] * 26),
        embed_dim=8,
        retrieval_dim=8,
    )


register(
    ArchSpec(
        arch_id="dcn-v2",
        family="recsys",
        source="arXiv:2008.13535; paper",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=RECSYS_SHAPES,
        skips={},
        notes="fused-table EmbeddingBag (take+segment_sum), vocab rows "
        "sharded over model axis; retrieval = batched dot + top_k",
    )
)
