"""equiformer-v2 [arXiv:2306.12059; unverified] — n_layers=12 d_hidden=128
l_max=6 m_max=2 n_heads=8, SO(2)-eSCN equivariant graph attention."""
from ..models.gnn.equiformer_v2 import EquiformerV2Config
from .base import ArchSpec, GNN_SHAPES, register


def full_config() -> EquiformerV2Config:
    return EquiformerV2Config(
        n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8
    )


def smoke_config() -> EquiformerV2Config:
    return EquiformerV2Config(
        n_layers=2, d_hidden=8, l_max=3, m_max=2, n_heads=2, n_rbf=8,
        n_species=8,
    )


register(
    ArchSpec(
        arch_id="equiformer-v2",
        family="gnn",
        source="arXiv:2306.12059; unverified",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=GNN_SHAPES,
        skips={},
        notes="eSCN trick: O(L^6) tensor product -> O(L^3) SO(2) conv in the "
        "edge-aligned Wigner frame (irreps.align_matrices)",
    )
)
