"""mace [arXiv:2206.07697; paper] — n_layers=2 d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, E(3)-ACE equivariance."""
from ..models.gnn.mace import MACEConfig
from .base import ArchSpec, GNN_SHAPES, register


def full_config() -> MACEConfig:
    return MACEConfig(
        n_layers=2, d_hidden=128, l_max=2, correlation_order=3, n_rbf=8
    )


def smoke_config() -> MACEConfig:
    return MACEConfig(
        n_layers=2, d_hidden=8, l_max=2, correlation_order=3, n_rbf=4,
        n_species=8,
    )


register(
    ArchSpec(
        arch_id="mace",
        family="gnn",
        source="arXiv:2206.07697; paper",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=GNN_SHAPES,
        skips={},
        notes="irrep tensor-product regime (kernel taxonomy §GNN); "
        "Gaunt contraction implements the ACE product basis",
    )
)
