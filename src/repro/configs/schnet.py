"""schnet [arXiv:1706.08566; paper] — n_interactions=3 d_hidden=64 rbf=300
cutoff=10."""
from ..models.gnn.schnet import SchNetConfig
from .base import ArchSpec, GNN_SHAPES, register


def full_config() -> SchNetConfig:
    return SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)


def smoke_config() -> SchNetConfig:
    return SchNetConfig(
        n_interactions=2, d_hidden=8, n_rbf=16, cutoff=10.0, n_species=8
    )


register(
    ArchSpec(
        arch_id="schnet",
        family="gnn",
        source="arXiv:1706.08566; paper",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=GNN_SHAPES,
        skips={},
        notes="triplet-free continuous-filter conv (gather + segment_sum)",
    )
)
