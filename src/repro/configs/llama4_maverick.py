"""llama4-maverick-400b-a17b [hf:meta-llama; unverified] — MoE 128e top-1,
interleaved MoE (every 2nd layer), iRoPE attention (3 chunked-local layers +
1 NoPE global per period, chunk 8192), shared expert.
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.

long_500k RUNS: chunked-local layers keep O(chunk) KV; only the 12 global
layers carry the full 500k cache."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from ..nn.moe import MoESettings
from .base import ArchSpec, LM_SHAPES, register


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-maverick-400b-a17b",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=202048,
        rope_theta=5e5,
        layer_pattern=("chunk", "chunk", "chunk", "global_nope"),
        window=8192,
        moe=MoESettings(
            n_experts=128, top_k=1, d_ff=8192, n_shared=1, every=2
        ),
        tie_embeddings=False,
        dtype=jnp.bfloat16,
        remat="dots",
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name="llama4-maverick-smoke",
        n_layers=4,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=128,
        vocab=512,
        layer_pattern=("chunk", "chunk", "chunk", "global_nope"),
        window=32,
        moe=MoESettings(n_experts=8, top_k=1, d_ff=128, n_shared=1, every=2),
        tie_embeddings=False,
        dtype=jnp.float32,
        remat="none",
        attn_chunk=64,
    )


register(
    ArchSpec(
        arch_id="llama4-maverick-400b-a17b",
        family="lm",
        source="hf:meta-llama/Llama-4 family; unverified",
        full_config=full_config,
        smoke_config=smoke_config,
        shapes=LM_SHAPES,
        skips={},
        notes="hybrid chunked/global attention -> long_500k supported; "
        "early-fusion VLM frontend is out of scope ([moe] backbone only)",
    )
)
