"""Minimal functional module system (flax is not available offline).

Every layer is an (init, apply) pair. ``init`` returns a nested dict whose
leaves are ``Boxed(value, logical_axes)``; ``split_boxed`` separates the value
tree from the logical-axes tree. Logical axes map to mesh axes through
``sharding_rules`` (MaxText-style), giving PartitionSpec trees for
``jit(in_shardings=...)`` and activation constraints.

Logical axes:
  embed   — d_model dims                → FSDP axes ("data" / ("pod","data"))
  mlp     — ffn / fused head dims       → TP axis ("model",)
  vocab   — vocabulary                  → TP axis ("model",)
  experts — MoE expert dim              → EP axis ("model",)
  heads/kv/layers/stack/... — unsharded param dims
Activations:
  batch   — ("data",) or ("pod","data")
  act_seq — None by default; ("model",) under sequence parallelism
  act_model — ("model",)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Boxed:
    """A param leaf tagged with logical axis names. Registered as a pytree
    node with ``axes`` as static aux data, so Boxed trees pass through
    jax.eval_shape / jit (the dry-run inits models abstractly)."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Boxed({self.value!r}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    Boxed,
    lambda b: ((b.value,), b.axes),
    lambda axes, children: Boxed(children[0], axes),
)


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def split_boxed(tree):
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return params, axes


def sharding_rules(multi_pod: bool = False, seq_parallel: bool = False) -> dict:
    """seq_parallel (Megatron-SP style): the residual stream BETWEEN layers
    (logical axis ``res_seq``) is sharded over the model axis along sequence,
    so scan carries saved for backward shrink by the TP degree. Layer
    interiors keep TP feature sharding (``act_model``); GSPMD turns the
    boundary reshards into the standard SP all-gather/reduce-scatter pair
    (same wire volume as the TP all-reduce it replaces)."""
    fsdp = ("pod", "data") if multi_pod else ("data",)
    return {
        "embed": fsdp,
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "heads": (),
        "kv": (),
        "head_dim": (),
        "stack": (),
        "batch": fsdp,
        "act_seq": (),
        "act_model": ("model",),
        "act_vocab": ("model",),  # logits vocab dim — always TP
        "res_seq": ("model",) if seq_parallel else (),
        "seq_shard": fsdp + ("model",),  # long-context KV sharding
        "edges": fsdp + ("model",),  # GNN edge-parallel message tensors
        "edges_dp": fsdp,  # edge dim when channels claim "model"
        None: (),
    }


def logical_to_spec(axes: tuple, rules: dict) -> P:
    parts = []
    for a in axes:
        mesh_axes = rules.get(a, ())
        if not mesh_axes:
            parts.append(None)
        elif len(mesh_axes) == 1:
            parts.append(mesh_axes[0])
        else:
            parts.append(tuple(mesh_axes))
    return P(*parts)


def specs_from_axes(axes_tree, rules: dict):
    return jax.tree.map(
        lambda axes: logical_to_spec(axes, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )


def shardings_from_axes(axes_tree, mesh: Mesh, rules: dict):
    specs = specs_from_axes(axes_tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


_ACTIVATION_RULES: dict | None = None


def set_activation_rules(rules: dict | None):
    """Install the logical->mesh rules used by shard_activation. None disables
    constraints (single-device smoke tests)."""
    global _ACTIVATION_RULES
    _ACTIVATION_RULES = rules


def shard_activation(x: jax.Array, axes: tuple) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op when rules unset).
    Uneven dims are fine here — GSPMD pads internally."""
    if _ACTIVATION_RULES is None:
        return x
    spec = logical_to_spec(axes, _ACTIVATION_RULES)
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------- inits -----

def normal_init(rng, shape, dtype, scale: float):
    return (scale * jax.random.normal(rng, shape)).astype(dtype)


def boxed_param(
    rng, shape, axes, dtype=jnp.float32, scale: float | None = None
) -> Boxed:
    if scale is None:
        fan_in = shape[0] if len(shape) > 1 else shape[0]
        scale = 1.0 / np.sqrt(max(fan_in, 1))
    return Boxed(normal_init(rng, shape, dtype, scale), axes)


def boxed_zeros(shape, axes, dtype=jnp.float32) -> Boxed:
    return Boxed(jnp.zeros(shape, dtype), axes)


def boxed_ones(shape, axes, dtype=jnp.float32) -> Boxed:
    return Boxed(jnp.ones(shape, dtype), axes)


def abstract_init(init_fn, *args):
    """Run an init function abstractly: returns the Boxed tree with
    ShapeDtypeStruct values (dry-run: no allocation, any model size)."""
    return jax.eval_shape(init_fn, *args)


def count_params(params) -> int:
    return sum(int(np.prod(v.shape)) for v in jax.tree.leaves(params))
