"""EmbeddingBag for recsys: JAX has no native EmbeddingBag or CSR sparse —
this is ``jnp.take`` + ``jax.ops.segment_sum`` over a fused table
(FBGEMM-TBE style: all fields concatenated with row offsets, rows sharded
over the model axis). This IS part of the system per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .module import boxed_param, shard_activation


def fused_table_init(rng, field_vocabs, dim, dtype=jnp.float32):
    """One fused [sum(vocabs), dim] table + static row offsets per field."""
    total = int(np.sum(field_vocabs))
    offsets = np.concatenate([[0], np.cumsum(field_vocabs)[:-1]]).astype(
        np.int64
    )
    return (
        {
            "table": boxed_param(
                rng, (total, dim), ("vocab", None), dtype, scale=0.01
            )
        },
        offsets,
    )


def lookup_single(params, offsets, ids):
    """Single-hot per field: ids [B, n_fields] -> [B, n_fields, dim]."""
    flat = ids.astype(jnp.int64) + jnp.asarray(offsets)[None, :]
    out = jnp.take(params["table"], flat, axis=0)
    return shard_activation(out, ("batch", None, None))


def embedding_bag(params, offsets, ids, field_ids, bag_ids, n_bags, mode="sum"):
    """Multi-hot bags: ids [nnz], field_ids [nnz], bag_ids [nnz] ->
    [n_bags, dim]. mode in {sum, mean}."""
    flat = ids.astype(jnp.int64) + jnp.take(
        jnp.asarray(offsets), field_ids, axis=0
    )
    vecs = jnp.take(params["table"], flat, axis=0)  # [nnz, dim]
    out = jax.ops.segment_sum(vecs, bag_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(bag_ids, jnp.float32), bag_ids, num_segments=n_bags
        )
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out
