"""GQA attention: chunked-flash prefill/train, KV-cache decode.

Supports the attention variants of the assigned LM archs:
- grouped KV heads (GQA), uneven head counts handled via activation
  sharding constraints (params keep fused divisible dims);
- attention kinds: "global", "local" (sliding window, Gemma-2),
  "chunk" (chunked/iRoPE-style local, Llama-4), "global_nope" (no RoPE);
- attention logit softcapping (Gemma-2);
- optional QK-norm (OLMoE).

Train/prefill uses an online-softmax scan over KV chunks (the pure-jnp
flash formulation; ``kernels/flash_attention`` is the Pallas TPU version of
the same math). Decode uses a (optionally ring-buffered) KV cache with
absolute per-slot positions, so sliding-window caches stay O(window).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, softcap
from .module import boxed_ones, boxed_param, shard_activation
from .rope import apply_rope

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSettings:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 1e4
    kind: str = "global"  # global | local | chunk | global_nope
    window: int = 4096  # window size (local) or chunk size (chunk)
    logit_softcap: Optional[float] = None
    qk_norm: bool = False
    chunk_q: int = 512  # kv-chunk for the online-softmax scan
    query_scale: Optional[float] = None  # default 1/sqrt(d_head)


def attn_init(rng, s: AttnSettings, dtype=jnp.float32):
    r = jax.random.split(rng, 5)
    d, H, KV, hd = s.d_model, s.n_heads, s.n_kv_heads, s.d_head
    p = {
        "wq": dense_init(r[0], d, H * hd, ("embed", "mlp"), dtype),
        "wk": dense_init(r[1], d, KV * hd, ("embed", "mlp"), dtype),
        "wv": dense_init(r[2], d, KV * hd, ("embed", "mlp"), dtype),
        "wo": dense_init(r[3], H * hd, d, ("mlp", "embed"), dtype),
    }
    if s.qk_norm:
        p["q_norm"] = {"scale": boxed_ones((hd,), (None,), dtype)}
        p["k_norm"] = {"scale": boxed_ones((hd,), (None,), dtype)}
    return p


def _qk_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (
        x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale
    ).astype(x.dtype)


def _project_qkv(params, s: AttnSettings, x, positions):
    B, S, _ = x.shape
    H, KV, hd = s.n_heads, s.n_kv_heads, s.d_head
    q = (x @ params["wq"]["kernel"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]["kernel"]).reshape(B, S, KV, hd)
    v = (x @ params["wv"]["kernel"]).reshape(B, S, KV, hd)
    if s.qk_norm:
        q = _qk_norm(q, params["q_norm"]["scale"])
        k = _qk_norm(k, params["k_norm"]["scale"])
    if s.kind != "global_nope":
        q = apply_rope(q, positions, s.rope_theta)
        k = apply_rope(k, positions, s.rope_theta)
    # No per-head constraints: head counts (8..56) rarely divide the TP
    # axis (16); pinning them forces involuntary full rematerialization in
    # GSPMD. Propagation from the fused H*hd projection picks an even joint
    # (heads x head_dim) split instead.
    return q, k, v


def _mask_logits(s, qpos, kpos, logits):
    """Apply softcap + causal/local/chunk masking.
    qpos: [..., Sq, 1]; kpos: [..., 1, Sk] broadcastable int32."""
    if s.logit_softcap is not None:
        logits = softcap(logits, s.logit_softcap)
    ok = kpos <= qpos
    if s.kind == "local":
        ok &= kpos > qpos - s.window
    elif s.kind == "chunk":
        ok &= (kpos // s.window) == (qpos // s.window)
    ok &= kpos >= 0
    return jnp.where(ok, logits, NEG)


def attention_scan(params, s: AttnSettings, x, positions):
    """Train/prefill attention: [B,S,d] -> [B,S,d], online softmax over KV
    chunks (memory O(S·chunk) instead of O(S²))."""
    B, S, _ = x.shape
    H, KV, hd = s.n_heads, s.n_kv_heads, s.d_head
    G = H // KV
    q, k, v = _project_qkv(params, s, x, positions)
    # Sequence-parallel attention (EXPERIMENTS.md §Perf iteration 1):
    # queries stay seq-sharded (each device owns its q rows); keys/values
    # gather to full sequence — k/v are GQA-small, so this moves
    # 2·S·KV·hd bytes/layer instead of letting GSPMD replicate the full
    # H-wide activations. No-op when the res_seq rule is off (TP mode).
    q = shard_activation(q, ("batch", "res_seq", None, None))
    k = shard_activation(k, ("batch", None, None, None))
    v = shard_activation(v, ("batch", None, None, None))
    scale = s.query_scale if s.query_scale is not None else hd ** -0.5
    q = q.reshape(B, S, KV, G, hd) * scale
    C = min(s.chunk_q, S)
    nC = S // C
    assert S % C == 0, (S, C)
    # scan over kv chunks, carrying online-softmax state
    ks = jnp.moveaxis(k.reshape(B, nC, C, KV, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nC, C, KV, hd), 1, 0)
    kpos = jnp.moveaxis(positions.reshape(B, nC, C), 1, 0)
    qpos = positions  # [B, S]

    def step(carry, chunk):
        m, l, acc = carry
        kc, vc, kp = chunk
        # operands stay bf16 (accumulation in f32 via preferred_element_type)
        # — an explicit .astype(f32) here gets hoisted out of the scan by
        # XLA and materializes EVERY kv chunk in f32 (28 GB/device on
        # deepseek train_4k)
        sc = jnp.einsum(
            "bsgnd,bcgd->bsgnc",
            q,
            kc,
            preferred_element_type=jnp.float32,
        )  # [B,S,KV(g),G(n),C] — einsum dims: g=kv group, n=q-per-kv, c=chunk
        sc = _mask_logits(
            s,
            qpos[:, :, None, None, None],
            kp[:, None, None, None, :],
            sc,
        )
        m_cur = sc.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(sc - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bsgnc,bcgd->bsgnd",
            p.astype(vc.dtype),
            vc,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, KV, G, 1), NEG, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G, 1), jnp.float32)
    a0 = jnp.zeros((B, S, KV, G, hd), jnp.float32)
    # flash-style backward: checkpoint the chunk step so the [S, C] logits
    # and probabilities are RECOMPUTED per chunk in bwd instead of stacked
    # for all chunks (28 GB/device of f32 attention matrices on deepseek
    # train_4k otherwise)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (ks, vs, kpos)
    )
    out = (acc / jnp.maximum(l, 1e-30)).astype(x.dtype)
    out = out.reshape(B, S, H * hd)
    return out @ params["wo"]["kernel"]


class KVCache(NamedTuple):
    k: jax.Array  # [B, W, KV, hd]
    v: jax.Array  # [B, W, KV, hd]
    slot_pos: jax.Array  # [W] int32 absolute position per slot (-1 empty)


def init_cache(
    s: AttnSettings, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> KVCache:
    W = (
        min(s.window, max_seq)
        if s.kind in ("local", "chunk")
        else max_seq
    )
    return KVCache(
        k=jnp.zeros((batch, W, s.n_kv_heads, s.d_head), dtype),
        v=jnp.zeros((batch, W, s.n_kv_heads, s.d_head), dtype),
        slot_pos=jnp.full((W,), -1, jnp.int32),
    )


def cache_axes() -> KVCache:
    """Logical axes for cache sharding (seq sharded over model for
    flash-decoding-style distributed attention)."""
    return KVCache(
        k=("batch", "act_model", None, None),
        v=("batch", "act_model", None, None),
        slot_pos=(None,),
    )


def decode_step(params, s: AttnSettings, x, cache: KVCache, pos):
    """One-token decode: x [B,1,d], pos scalar int32 -> ([B,1,d], cache)."""
    B = x.shape[0]
    H, KV, hd = s.n_heads, s.n_kv_heads, s.d_head
    G = H // KV
    W = cache.k.shape[1]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k_new, v_new = _project_qkv(params, s, x, positions)
    slot = pos % W  # ring buffer for local/chunk; plain index for global
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), slot, axis=1
    )
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), slot, axis=1
    )
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        cache.slot_pos, pos[None], slot, axis=0
    )
    k = shard_activation(k, ("batch", "act_model", None, None))
    v = shard_activation(v, ("batch", "act_model", None, None))
    scale = s.query_scale if s.query_scale is not None else hd ** -0.5
    qg = q.reshape(B, KV, G, hd) * scale
    logits = jnp.einsum(
        "bgnd,bwgd->bgnw",
        qg.astype(k.dtype),
        k,
        preferred_element_type=jnp.float32,
    )  # [B, KV, G, W] — bf16 operands, f32 accumulation (no f32 cache copy)
    logits = _mask_logits(
        s, pos.astype(jnp.int32), slot_pos[None, None, None, :], logits
    )
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bgnw,bwgd->bgnd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ params["wo"]["kernel"], KVCache(k=k, v=v, slot_pos=slot_pos)


def prefill_kv(params, s: AttnSettings, x, positions, max_seq):
    """Compute the cache that decode_step expects after a prefill of length S
    (global kinds: slots 0..S-1; local/chunk kinds: last W positions)."""
    B, S, _ = x.shape
    _, k, v = _project_qkv(params, s, x, positions)
    cache = init_cache(s, B, max_seq, dtype=k.dtype)
    W = cache.k.shape[1]
    if W >= S:
        k_pad = jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (0, W - S), (0, 0), (0, 0)))
        sp = jnp.pad(
            positions[0], (0, W - S), constant_values=-1
        )
        return KVCache(k=k_pad, v=v_pad, slot_pos=sp)
    # ring layout: slot = pos % W for the last W tokens
    last_k = k[:, S - W :, :, :]
    last_v = v[:, S - W :, :, :]
    last_pos = positions[0, S - W :]
    slots = last_pos % W
    order = jnp.argsort(slots)
    return KVCache(
        k=jnp.take(last_k, order, axis=1),
        v=jnp.take(last_v, order, axis=1),
        slot_pos=jnp.take(last_pos, order),
    )
