"""Basic layers: Dense, Embedding, RMSNorm, LayerNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .module import Boxed, boxed_ones, boxed_param


def dense_init(rng, d_in, d_out, axes=("embed", "mlp"), dtype=jnp.float32,
               scale=None):
    return {"kernel": boxed_param(rng, (d_in, d_out), axes, dtype, scale)}


def dense(params, x):
    return x @ params["kernel"]


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": boxed_ones((d,), ("embed",), dtype)}


def rmsnorm(params, x, eps=1e-6, zero_centered=False):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if zero_centered:  # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (y * scale).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {
        "scale": boxed_ones((d,), ("embed",), dtype),
        "bias": Boxed(jnp.zeros((d,), dtype), ("embed",)),
    }


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.square(xf - mu).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def embedding_init(rng, vocab, d, dtype=jnp.float32, scale=1.0):
    return {
        "table": boxed_param(rng, (vocab, d), ("vocab", "embed"), dtype, scale)
    }


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Tied unembedding: logits over vocab."""
    return x @ params["table"].T


def softcap(x, cap):
    return cap * jnp.tanh(x / cap)
