"""Rotary position embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(d_head: int, theta: float = 1e4) -> jax.Array:
    half = d_head // 2
    return 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )


def apply_rope(
    x: jax.Array,  # [..., S, H, D]
    positions: jax.Array,  # [..., S] int32
    theta: float = 1e4,
) -> jax.Array:
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
